# One-command verify recipes (see ROADMAP.md "Tier-1 verify").
PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))
export PYTHONPATH

.PHONY: test test-fast bench-smoke bench dev-deps

test:  ## tier-1: the full suite, fail-fast
	python -m pytest -x -q

test-fast:  ## skip the slow XLA-compile cross-validation tests
	python -m pytest -x -q --ignore=tests/test_roofline_validation.py

bench-smoke:  ## quick end-to-end signal: the vectorized lease-plane bench
	python -c "from benchmarks.bench_lease_array import run; \
	  [print(f'{n},{u:.2f},\"{d}\"') for n, u, d in run()]"

bench:  ## every paper table (slow)
	python -m benchmarks.run

dev-deps:
	pip install -r requirements-dev.txt
