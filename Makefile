# One-command verify recipes (see ROADMAP.md "Tier-1 verify").
PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))
export PYTHONPATH

.PHONY: test test-all test-fast check falsify-smoke bench-smoke bench-delay bench-drift bench-renew bench-json bench-compare bench dev-deps

test:  ## fast default: skip the long @slow differential replays
	python -m pytest -x -q -m "not slow"

test-all:  ## tier-1: the full suite (including @slow), fail-fast
	python -m pytest -x -q

test-fast:  ## also skip the slow XLA-compile cross-validation tests
	python -m pytest -x -q -m "not slow" --ignore=tests/test_roofline_validation.py

check:  ## leaselint: static pack-budget proof, kernel purity, launch audit, convention lints + mutation self-test (docs/static_analysis.md)
	python -m repro.analysis.staticcheck --json findings.json
	@if command -v ruff >/dev/null 2>&1; then \
	  ruff check src tests benchmarks examples; \
	else \
	  echo "ruff not installed; skipping the crash-level baseline (CI runs it)"; \
	fi

falsify-smoke:  ## seeded fixed-budget falsification contract (docs/falsification.md): the corrupt negative control MUST violate, the honest search must NOT — each also run with the crash/restart planes enabled (honest faults: the corrupt pair still violates, the honest pair still must not)
	python -m repro.lease_array.falsify --mode corrupt --seed 7 --pop 128 --generations 6 --expect violation --out falsify_corrupt.json
	python -m repro.lease_array.falsify --mode honest --seed 7 --pop 128 --generations 6 --expect none --out falsify_honest.json
	python -m repro.lease_array.falsify --mode corrupt --restarts --seed 7 --pop 128 --generations 6 --expect violation --out falsify_corrupt_restart.json
	python -m repro.lease_array.falsify --mode honest --restarts --seed 7 --pop 128 --generations 6 --expect none --out falsify_honest_restart.json
	python -m repro.lease_array.falsify --mode corrupt --extends --seed 0 --pop 128 --generations 6 --expect violation --out falsify_corrupt_extend.json
	python -m repro.lease_array.falsify --mode honest --extends --seed 0 --pop 128 --generations 6 --expect none --out falsify_honest_extend.json

bench-smoke:  ## quick end-to-end signal: the vectorized lease-plane bench
	python -c "from benchmarks.bench_lease_array import run; \
	  [print(f'{n},{u:.2f},\"{d}\"') for n, u, d in run()]"

bench-delay:  ## netplane smoke: delay-depth sweep of the in-flight plane
	python -c "from benchmarks.bench_lease_array import run_delayed; \
	  [print(f'{n},{u:.2f},\"{d}\"') for n, u, d in run_delayed()]"

bench-drift:  ## drifted-clock smoke: the eps=0.25 netplane scan row
	python -c "from benchmarks.bench_lease_array import run_drift; \
	  [print(f'{n},{u:.2f},\"{d}\"') for n, u, d in run_drift()]"

bench-renew:  ## §6 renewal storm (quiescence-skip A/B, owned_frac >= 0.95 at delay<=4) + deposed-owner failover handoff
	python -c "from benchmarks.bench_lease_array import run_renew; \
	  [print(f'{n},{u:.2f},\"{d}\"') for n, u, d in run_renew()]"

bench-json:  ## all lease-plane modes -> machine-readable BENCH_lease_array.json
	python -m benchmarks.bench_lease_array

bench-compare:  ## fresh bench run diffed against the committed baseline (>25% regression fails; measured on row ratios when the machines differ)
	python -m benchmarks.bench_lease_array BENCH_candidate.json
	python -m benchmarks.compare_bench BENCH_lease_array.json BENCH_candidate.json > BENCH_compare.txt; \
	  status=$$?; cat BENCH_compare.txt; exit $$status

bench:  ## every paper table (slow)
	python -m benchmarks.run

dev-deps:
	pip install -r requirements-dev.txt
