"""§6 owner lease extension in the array plane (the ISSUE 10 tentpole).

The ``extends [T, N]`` registry plane schedules full in-flight renewal
rounds gated on the extender's own live belief. Contracts pinned here:
all-default extends is stripped host-side and leaves the honest engine
bit-identical (and the honest dispatch jaxpr byte-identical — the
staticcheck mirror); renewal-enabled chaos traces replay bit-exactly
against the event-sim referee on BOTH backends; the §6 edges (ghost
extend after guarded expiry, extend straddling a diskless acceptor
restart, extend racing a same-tick §7 release) agree with the referee;
the quiescence fast path (``skip_stable``) changes nothing bit-wise; and
an honest ≥1024-scenario extends sweep holds §4 in one dispatch.
"""
import numpy as np
import pytest

from repro.lease_array import LeaseArrayEngine, Scenario
from repro.lease_array.scenario import EXTEND_PLANES
from repro.lease_array.state import NO_PROPOSER
from repro.lease_array.trace import (
    Trace,
    random_trace,
    replay_array,
    replay_event_sim,
)
from test_lease_array_differential import assert_engines_agree

BACKENDS = ["jnp", "pallas"]
NA = NO_PROPOSER

#: the renewal-chaos mix every differential below draws from: sparse
#: attempts (dense attempts suppress the renew cadence — an extend too
#: close before a future attempt on the cell is dropped by the
#: generator), live §6 renewals, delay + drop + drift + outages
RENEW_CHAOS = dict(
    n_ticks=120, n_cells=6, n_acceptors=3, n_proposers=4, lease_ticks=6,
    p_attempt=0.12, p_release=0.04, renew=0.5, max_delay_ticks=1,
    p_drop=0.05, drift_eps=0.25,
    # the abandon deadline must outlive a full prepare+propose round over
    # the slowest links (4·delay + 1) or every extend round is abandoned
    # mid-flight — the renewal-collapse geometry the directory test pins
    round_ticks=5,
)


def _engine(trace: Trace, backend="jnp", **kw) -> LeaseArrayEngine:
    return LeaseArrayEngine(
        trace.n_cells, n_acceptors=trace.n_acceptors,
        n_proposers=trace.n_proposers, lease_ticks=trace.lease_ticks,
        round_ticks=trace.round_ticks, drift_eps=trace.drift_eps,
        backend=backend, **kw,
    )


# ------------------------------------------------------- all-default plane

def test_all_default_extends_bit_identical():
    """A scenario whose registry-filled extends plane is all-NO_PROPOSER
    is the pre-extend engine: same bits (the plane is stripped host-side,
    never uploaded, so honest replays don't compile the extend variant)."""
    tr = random_trace(7, n_ticks=60, n_cells=4, n_acceptors=3,
                      n_proposers=4, lease_ticks=3, max_delay_ticks=1,
                      p_drop=0.05, drift_eps=0.25)
    base_ow, base_cn = replay_array(tr)
    sc = tr.scenario()
    assert all(k in sc.planes for k in EXTEND_PLANES)  # registry-filled
    assert not sc.extended
    eng = _engine(tr)
    ow, cn = eng.run_trace(sc)
    assert np.array_equal(np.asarray(ow), np.asarray(base_ow))
    assert np.array_equal(np.asarray(cn), np.asarray(base_cn))


def test_honest_dispatch_jaxpr_untouched_by_default_extends():
    """The staticcheck mirror: stripping an all-default extends plane
    restores the honest ``_window_scan_impl`` jaxpr byte-for-byte."""
    from repro.analysis.staticcheck.purity import check_honest_strip

    assert check_honest_strip() == []


# ------------------------------------- renewal differentials vs the referee

def _longest_same_owner_run(owners: np.ndarray) -> np.ndarray:
    """Per-cell longest unbroken same-owner run, in ticks."""
    runs = np.zeros(owners.shape[1], np.int64)
    best = np.zeros(owners.shape[1], np.int64)
    prev = np.full(owners.shape[1], NA, np.int32)
    for row in owners:
        same = (row == prev) & (row >= 0)
        runs = np.where(same, runs + 1, (row >= 0).astype(np.int64))
        prev = row
        best = np.maximum(best, runs)
    return best


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_renew_differential_vs_referee(seed, backend):
    tr = random_trace(seed, **RENEW_CHAOS)
    assert tr.extended, "trace must actually schedule §6 renewals"
    owners = assert_engines_agree(tr, backend=backend)
    assert (owners >= 0).any()


@pytest.mark.parametrize("backend", BACKENDS)
def test_renewals_chain_past_the_lease_window(backend):
    """Drift-free renewal chaos: successful §6 extends must chain — an
    unbroken same-owner run longer than one un-renewed lease window could
    ever produce — and still replay bit-exactly against the referee.
    (With drift the guard-discounted window is shorter than the open-loop
    cadence, so chaining is a closed-loop property — the directory's.)"""
    tr = random_trace(9, **{**RENEW_CHAOS, "drift_eps": 0.0})
    assert tr.extended
    owners = assert_engines_agree(tr, backend=backend)
    assert (_longest_same_owner_run(owners)
            > RENEW_CHAOS["lease_ticks"] + 1).any(), \
        "no lease was ever extended past its own window"


@pytest.mark.slow
def test_thousand_tick_renew_chaos_differential():
    """1000 renewal-enabled ticks of delay + drop + drift + outages, both
    backends bit-exact against the referee — the tentpole's acceptance
    differential."""
    tr = random_trace(
        1234, **{**RENEW_CHAOS, "n_ticks": 1000, "n_cells": 8}
    )
    assert tr.extended
    jow = assert_engines_agree(tr, backend="jnp")
    pow_ = assert_engines_agree(tr, backend="pallas")
    assert np.array_equal(jow, pow_)
    # and drift-free at length: renewals chain through the whole replay
    calm = random_trace(
        1234, **{**RENEW_CHAOS, "n_ticks": 1000, "n_cells": 8,
                 "drift_eps": 0.0}
    )
    owners = assert_engines_agree(calm)
    assert (_longest_same_owner_run(owners)
            > RENEW_CHAOS["lease_ticks"] + 1).any()


# ------------------------------------------------------------ §6 edge cases

def _edge_trace(**kw) -> Trace:
    T, N, A, P = 16, 2, 3, 2
    base = dict(
        n_cells=N, n_acceptors=A, n_proposers=P, lease_ticks=2,
        attempts=np.full((T, N), NA, np.int32),
        releases=np.full((T, N), NA, np.int32),
        acc_up=np.ones((T, A), bool),
        extends=np.full((T, N), NA, np.int32),
        round_ticks=3,
    )
    base.update(kw)
    return Trace(
        base.pop("n_cells"), base.pop("n_acceptors"),
        base.pop("n_proposers"), **base,
    )


@pytest.mark.parametrize("backend", BACKENDS)
def test_extend_after_guarded_expiry_is_a_ghost_noop(backend):
    """§6 gates on the live belief: an extend scheduled after the owner's
    guarded window closed is a non-owner extend — a no-op in both engines
    (no resurrected lease), and a later fresh attempt still works."""
    tr = _edge_trace()
    tr.attempts[0, 0] = 0    # owner at t=0, expiry quarter 4·2+1 = 9
    tr.extends[6, 0] = 0     # lease lapsed at t=3; this is a ghost
    tr.attempts[10, 0] = 1   # the cell is genuinely free: cold acquire
    owners = assert_engines_agree(tr, backend=backend)
    assert (owners[:3, 0] == 0).all()
    assert (owners[3:10, 0] == NA).all(), "ghost extend resurrected a lease"
    assert (owners[10:13, 0] == 1).all()


@pytest.mark.parametrize("backend", BACKENDS)
def test_extend_in_time_rolls_the_lease(backend):
    """The positive control for the ghost test: the same schedule with the
    extend INSIDE the live window keeps the owner through a second span."""
    tr = _edge_trace()
    tr.attempts[0, 0] = 0
    tr.extends[2, 0] = 0     # still owned (expiry quarter 9 > 8)
    owners = assert_engines_agree(tr, backend=backend)
    assert (owners[:5, 0] == 0).all(), "in-window extend did not roll"
    assert (owners[6:, 0] == NA).all()


@pytest.mark.parametrize("backend", BACKENDS)
def test_extend_straddling_acceptor_restart_deaf_window(backend):
    """A diskless acceptor restart in the middle of an extend round: the
    restarted node is blank + deaf (§2/§3 M-wait), the round must win or
    lapse identically in both engines."""
    T, N, A, P = 24, 2, 3, 2
    tr = Trace(
        N, A, P, lease_ticks=6,
        attempts=np.full((T, N), NA, np.int32),
        releases=np.full((T, N), NA, np.int32),
        acc_up=np.ones((T, A), bool),
        delay=np.ones((T, A), np.int32),
        extends=np.full((T, N), NA, np.int32),
        acc_restarts=np.zeros((T, A), np.int32),
        round_ticks=5,
    )
    tr.attempts[0, 0] = 0     # 1-tick legs: owner at t=4, through t=8
    # t=5, not t=4: an extend issued the tick the win lands still sees the
    # stale pre-win belief (phase order) and is a no-op in both engines
    tr.extends[5, 0] = 0      # extend round runs t=5..9 (4·delay ticks)
    tr.acc_restarts[7, 0] = 1  # acceptor 0 blanks mid-round, goes deaf
    owners = assert_engines_agree(tr, backend=backend)
    # quorum of the two live acceptors carries the extend: the lease rolls
    # seamlessly into a second span (new expiry minted at propose tick 7)
    assert (owners[4:13, 0] == 0).all()
    assert (owners[14:, 0] == NA).all()


@pytest.mark.parametrize("backend", BACKENDS)
def test_extend_racing_same_tick_release(backend):
    """§7 release and a §6 extend on the same (tick, cell): the release
    lands first (it already cleared the belief), the extend is a no-op —
    same verdict in both engines, and the cell frees up."""
    tr = _edge_trace()
    tr.attempts[0, 0] = 0
    tr.releases[2, 0] = 0
    tr.extends[2, 0] = 0
    owners = assert_engines_agree(tr, backend=backend)
    assert (owners[:2, 0] == 0).all()
    assert (owners[2:, 0] == NA).all(), "extend outran the same-tick release"


# ------------------------------------------------- quiescence fast path

@pytest.mark.parametrize("backend", BACKENDS)
def test_skip_stable_is_bitwise_invisible(backend):
    """The quiescence compaction (skip near-zero VMEM work on stable
    (block, window) pairs) is a pure fast path: bit-identical owners and
    counts with it on and off, under live renewals."""
    tr = random_trace(5, **RENEW_CHAOS)
    sc = tr.scenario()
    on = _engine(tr, backend=backend, skip_stable=True)
    off = _engine(tr, backend=backend, skip_stable=False)
    ow1, cn1 = on.run_trace(sc)
    ow2, cn2 = off.run_trace(sc)
    assert np.array_equal(np.asarray(ow1), np.asarray(ow2))
    assert np.array_equal(np.asarray(cn1), np.asarray(cn2))


# --------------------------------------------------- honest extends sweep

def test_honest_extends_sweep_single_dispatch_holds_section4():
    """≥1024 random honest scenarios with live extends planes, one
    ``engine.sweep`` dispatch, zero §4 violations (verify=True raises on
    any owner-count overlap)."""
    from repro.lease_array.falsify.search import (
        FalsifyConfig,
        random_population,
    )

    cfg = FalsifyConfig(pop_size=1024, extends=True, corrupt=False)
    planes = random_population(np.random.default_rng(42), cfg)
    assert (planes["extends"] != NA).any()
    eng = cfg.engine()
    res = eng.sweep(Scenario(planes), collect="summary", verify=True)
    assert int(res.max_owner_count.max()) <= 1
    assert res.max_owner_count.shape == (1024,)


# -------------------------------------- the directory renewal-collapse fix

def _healthy_directory(max_delay_ticks: int, lease_ticks: int = 12,
                       **kw) -> "LeaseArrayDirectory":
    from repro.lease_array.directory import LeaseArrayDirectory

    d = LeaseArrayDirectory(
        128, n_acceptors=3, lease_ticks=lease_ticks, max_workers=4,
        max_delay_ticks=max_delay_ticks, **kw,
    )
    for i in range(4):
        d.add_worker(i, 32)
    return d


# an extend round takes 4·delay+1 ticks end to end, so the lease must be
# long enough to contain one: delay-4 legs need a lease past 17 ticks
@pytest.mark.parametrize("max_delay_ticks,lease_ticks",
                         [(0, 12), (2, 12), (4, 24)])
def test_directory_sustains_renewals_under_link_delay(max_delay_ticks,
                                                      lease_ticks):
    """The bugfix's acceptance shape: with the full-round renew margin,
    round-trip pacing and a round deadline sized to the links, the
    directory holds ≥ 95% of its shards through many lease generations at
    delay ≤ 4 (the seed collapsed to owned_frac 0.05 here)."""
    d = _healthy_directory(max_delay_ticks, lease_ticks)
    d.tick(8 * max_delay_ticks + 10)  # warmup: acquire everything
    assert d.coverage() == 1.0
    fracs = []
    for _ in range(6 * d.engine.lease_ticks):  # many renewal generations
        d.tick(1)
        fracs.append(d.coverage())
    assert min(fracs) >= 0.95, f"renewal collapse: min owned_frac {min(fracs)}"


def test_directory_delay_blind_margin_and_redrive_collapse():
    """Negative control: the seed's geometry — a delay-blind renew margin
    driven every tick (each re-issue overwrites the open extend round,
    netplane phase 3) — collapses coverage, proving the fix is what holds
    the line above."""
    d = _healthy_directory(4, 24)
    d.tick(50)
    assert d.coverage() == 1.0
    d._round_trip = 1       # per-tick re-drive: the old behavior
    d._cooldown[:] = 0
    d.tick(6 * d.engine.lease_ticks)
    assert d.coverage() <= 0.5, "per-tick re-drive should livelock renewals"


def test_directory_rejects_unservable_renewal_geometry():
    from repro.lease_array.directory import LeaseArrayDirectory

    with pytest.raises(ValueError, match="cannot be renewed"):
        LeaseArrayDirectory(8, n_acceptors=3, lease_ticks=2,
                            max_delay_ticks=2)
    # the half-trip fallacy: 12 ticks LOOKS renewable over delay-4 legs
    # (2·4+1 = 9 < 12) but a full extend round is 17 ticks — unservable
    with pytest.raises(ValueError, match="cannot be renewed"):
        LeaseArrayDirectory(8, n_acceptors=3, lease_ticks=12,
                            max_delay_ticks=4)
    with pytest.raises(ValueError, match="below the worst-case"):
        LeaseArrayDirectory(8, n_acceptors=3, lease_ticks=24,
                            max_delay_ticks=4, renew_margin=12)
