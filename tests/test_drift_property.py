"""§4 chaos property suite for drifting clocks: with every clock rate
inside the ε bound and proposers discounting their own timer by
T·(1-ε)/(1+ε), at most one node believes it holds the lease at any tick —
under arbitrary per-tick rate churn × asymmetric link delay × drop ×
release × outage chaos.

Three profiles:
  - a fast seeded profile that always runs in ``make test``;
  - a hypothesis-driven profile (``requirements-dev.txt``; skipped when
    hypothesis is absent) whose strategies draw the scenario *dimensions*
    directly, so counterexamples shrink to minimal tick counts and
    geometries;
  - a deep hypothesis profile under ``@slow`` for ``make test-all`` / the
    main-branch CI job.

Also here: the negative control proving the alarm isn't vacuous (no
guard + drifted clocks → a constructible violation the §4 owner-count
alarm reports as 2), the cross-engine discount regression pinning the
array's quantized guard to ``core/proposer.py``'s float arithmetic, and
the 1k-scenario drift × delay × drop ``engine.sweep`` acceptance check.
"""
import numpy as np
import pytest

from repro.configs import CellConfig
from repro.core.proposer import Proposer
from repro.lease_array import (
    DEFAULT_RATE,
    NO_PROPOSER,
    LeaseArrayEngine,
    Scenario,
    guarded_lease_q4,
    lease_quarters,
    random_trace,
)

NA = NO_PROPOSER


def _chaos_scenario(rng, n_ticks, n_cells, n_acc, n_prop, eps):
    """Unconstrained chaos: per-tick-varying rate planes inside the ε
    band (the array plane is more general than the constant-rate referee),
    asymmetric delays, drops, releases, outages. No slot-isolation
    spacing — overwritten slots only LOSE messages, and PaxosLease is
    safe under arbitrary loss."""
    lo = max(1, int(np.ceil(DEFAULT_RATE * (1 - eps))))
    hi = int(DEFAULT_RATE * (1 + eps))
    return Scenario.build(
        n_ticks, n_cells=n_cells, n_acceptors=n_acc, n_proposers=n_prop,
        attempts=np.where(rng.random((n_ticks, n_cells)) < 0.7,
                          rng.integers(0, n_prop, (n_ticks, n_cells)), NA),
        releases=np.where(rng.random((n_ticks, n_cells)) < 0.15,
                          rng.integers(0, n_prop, (n_ticks, n_cells)), NA),
        acc_up=rng.random((n_ticks, n_acc)) > 0.1,
        delay=rng.integers(0, 4, (n_ticks, n_prop, n_acc)),
        drop=rng.random((n_ticks, n_prop, n_acc)) < 0.15,
        prop_rate=rng.integers(lo, hi + 1, (n_ticks, n_prop)),
        acc_rate=rng.integers(lo, hi + 1, (n_ticks, n_acc)),
    )


def _invariant_holds(
    seed: int, n_ticks: int = 60, n_acc: int = None, n_prop: int = None,
    eps: float = 0.25,
) -> None:
    rng = np.random.default_rng(seed)
    n_cells = 5
    n_acc = int(rng.integers(1, 6)) if n_acc is None else n_acc
    n_prop = int(rng.integers(2, 5)) if n_prop is None else n_prop
    sc = _chaos_scenario(rng, n_ticks, n_cells, n_acc, n_prop, eps)
    eng = LeaseArrayEngine(
        n_cells, n_acceptors=n_acc, n_proposers=n_prop,
        lease_ticks=int(rng.integers(1, 7)),
        round_ticks=int(rng.integers(1, 5)),
        drift_eps=eps,
    )
    _, counts = eng.run_trace(sc, netplane=True)
    assert counts.shape == (n_ticks, n_cells)
    assert int(counts.max()) <= 1, (
        f"§4 violated under drift chaos seed {seed} "
        f"(A={n_acc}, P={n_prop}, eps={eps})"
    )


# ------------------------------------------------------- fast seeded profile
@pytest.mark.parametrize("seed", range(8))
@pytest.mark.parametrize("eps", [0.25, 0.5])
def test_at_most_one_owner_under_drift_chaos(seed, eps):
    _invariant_holds(seed, eps=eps)


# ------------------------------------------------ hypothesis-driven profiles
def _hypothesis_prop(max_examples: int):
    hyp = pytest.importorskip(
        "hypothesis", reason="property tests need hypothesis "
        "(requirements-dev.txt)"
    )
    from hypothesis import strategies as st

    @hyp.settings(max_examples=max_examples, deadline=None)
    @hyp.given(
        seed=st.integers(min_value=0, max_value=2**32 - 1),
        n_ticks=st.integers(min_value=1, max_value=48),
        n_acc=st.integers(min_value=1, max_value=5),
        n_prop=st.integers(min_value=2, max_value=4),
        eps=st.sampled_from([0.25, 0.5]),
    )
    def prop(seed, n_ticks, n_acc, n_prop, eps):
        # dimensions are drawn directly (not derived from the seed), so a
        # failing example shrinks toward minimal ticks and geometry
        _invariant_holds(seed, n_ticks=n_ticks, n_acc=n_acc,
                         n_prop=n_prop, eps=eps)

    prop()


def test_drift_chaos_hypothesis_property():
    """Fast bounded hypothesis profile (runs in ``make test``)."""
    _hypothesis_prop(max_examples=20)


@pytest.mark.slow
def test_drift_chaos_hypothesis_property_deep():
    """Deep profile for ``make test-all`` / main-branch CI."""
    _hypothesis_prop(max_examples=200)


# ------------------------------------------------------ the negative control
def _guard_scenario(n_ticks=12, n_cells=4):
    """Slow proposer 0 (rate 3) against fast acceptors (rate 5): without
    the discount its belief outlives the acceptors' timers, so proposer
    1's win at tick 4 overlaps it."""
    attempts = np.full((n_ticks, n_cells), NA, np.int32)
    attempts[1, :] = 0
    attempts[4, :] = 1
    prop_rate = np.full((n_ticks, 2), DEFAULT_RATE, np.int32)
    prop_rate[:, 0] = 3
    acc_rate = np.full((n_ticks, 3), 5, np.int32)
    return Scenario.build(
        n_ticks, n_cells=n_cells, n_acceptors=3, n_proposers=2,
        attempts=attempts, prop_rate=prop_rate, acc_rate=acc_rate,
    )


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_drift_without_guard_trips_the_alarm(backend):
    """ε lied about (engine assumes 0, clocks drift anyway): the §4
    owner-count alarm must report the second believer — the array-plane
    analogue of tests/test_drift.py's event-sim violation."""
    sc = _guard_scenario()
    eng = LeaseArrayEngine(
        4, n_acceptors=3, n_proposers=2, lease_ticks=3, backend=backend,
    )
    _, counts = eng.run_trace(sc)
    assert int(counts.max()) == 2, "expected a §4 alarm without the guard"


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_drift_guard_restores_invariant(backend):
    """The same scenario with the honest ε=0.25 discount: no overlap."""
    sc = _guard_scenario()
    eng = LeaseArrayEngine(
        4, n_acceptors=3, n_proposers=2, lease_ticks=3, drift_eps=0.25,
        backend=backend,
    )
    _, counts = eng.run_trace(sc)
    assert int(counts.max()) <= 1


# ------------------------------------------- cross-engine discount regression
def _core_guarded_timespan(lease_ticks: int, eps: float) -> float:
    cfg = CellConfig(
        n_acceptors=3, max_lease_time=10 * lease_ticks + 60.0,
        lease_timespan=lease_ticks + 0.25,
        clock_drift_bound=eps, drift_guard=eps > 0,
    )
    p = Proposer(
        0, [], cfg,
        set_timer=lambda d, fn: None, send=lambda dst, msg: None,
        random_backoff=lambda lo, hi: lo,
    )
    return p._guarded_timespan(cfg.lease_timespan)


@pytest.mark.parametrize("lease_ticks", [1, 2, 3, 4, 8, 16])
@pytest.mark.parametrize("eps", [0.0, 0.1, 0.25, 1 / 3, 0.5])
def test_core_and_array_discounts_agree_to_the_quarter_tick(lease_ticks, eps):
    """core/proposer.py's float T·(1-ε)/(1+ε) and the array plane's
    floor-quantized guard_q4, computed from the same (T, ε), must agree
    to the quarter-tick — exactly in the ε=0 degenerate case."""
    lease_q4 = lease_quarters(lease_ticks)
    guard_q4 = guarded_lease_q4(lease_q4, eps)
    core = _core_guarded_timespan(lease_ticks, eps)
    assert 0 <= 4 * core - guard_q4 < 1, (
        f"discounts disagree: core={4 * core} quarters, array={guard_q4}"
    )
    assert guard_q4 == int(4 * core)  # same floor quantization
    if eps == 0.0:
        assert guard_q4 == lease_q4
        assert core == lease_ticks + 0.25
    assert guard_q4 <= lease_q4


def test_guarded_lease_q4_rejects_bad_eps():
    with pytest.raises(ValueError, match="drift_eps"):
        guarded_lease_q4(13, -0.1)
    with pytest.raises(ValueError, match="drift_eps"):
        guarded_lease_q4(13, 1.0)


def test_guarded_lease_q4_rejects_collapsed_discount():
    """A discount that floors to 0 quarter-ticks means the proposer could
    never believe it owns — refuse it loudly instead of silently running
    an engine that never grants a lease."""
    with pytest.raises(ValueError, match="collapses"):
        guarded_lease_q4(lease_quarters(1), 0.8)  # 5 * 0.111 -> 0
    with pytest.raises(ValueError, match="collapses"):
        LeaseArrayEngine(4, n_acceptors=3, lease_ticks=1, drift_eps=0.8)


def test_pertick_scanner_defaults_missing_rate_planes():
    """A pre-drift-shaped planes dict (no rate keys) through the per-tick
    scanner runs the drift-free clock, bit-identical to the same dict
    with explicit all-DEFAULT_RATE planes — the documented hand-rolled-
    dict contract (`ops._local_clock_planes`)."""
    import jax.numpy as jnp

    from repro.lease_array import init_netplane, init_state
    from repro.lease_array.engine import _scenario_scanner

    tr = random_trace(9, n_ticks=30, n_cells=6, n_acceptors=3, n_proposers=3,
                      lease_ticks=2, p_release=0.1, max_delay_ticks=1,
                      p_drop=0.1, round_ticks=2)
    full = {k: jnp.asarray(v) for k, v in tr.scenario().planes.items()}
    legacy = {
        k: v for k, v in full.items() if k not in ("prop_rate", "acc_rate")
    }
    scanner = _scenario_scanner(2, lease_quarters(2), 8, "jnp", False)
    st, net = init_state(6, 3, 3), init_netplane(6, 3)
    _, _, ow_full, cn_full = scanner(st, net, jnp.int32(0), None, full)
    _, _, ow_leg, cn_leg = scanner(st, net, jnp.int32(0), None, legacy)
    assert np.array_equal(np.asarray(ow_full), np.asarray(ow_leg))
    assert np.array_equal(np.asarray(cn_full), np.asarray(cn_leg))


# ----------------------------------------------- the 1k-scenario sweep check
def test_sweep_1k_scenarios_drift_delay_drop():
    """Acceptance: a 1024-scenario batched sweep with drift × delay ×
    drop × release planes reports zero §4 violations in ONE dispatch
    (sweep(verify=True) raises on any)."""
    traces = [
        random_trace(
            1000 + s, n_ticks=12, n_cells=8, n_acceptors=3, n_proposers=4,
            lease_ticks=2, p_attempt=0.5, p_release=0.08, p_down_flip=0.05,
            max_delay_ticks=1, p_drop=0.1, round_ticks=2, drift_eps=0.25,
        )
        for s in range(1024)
    ]
    assert any(t.drifted for t in traces)
    stacked = Scenario.stack([t.scenario() for t in traces])
    eng = LeaseArrayEngine(
        8, n_acceptors=3, n_proposers=4, lease_ticks=2, round_ticks=2,
        drift_eps=0.25,
    )
    res = eng.sweep(stacked, verify=True)
    assert res.max_owner_count.shape == (1024,)
    assert int(res.max_owner_count.max()) <= 1
    assert float(res.owned_frac.mean()) > 0.0
