"""Checkpoint I/O: roundtrip, atomicity, retention, async writer, lease guard."""
import threading
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (
    AsyncCheckpointer,
    CheckpointManager,
    latest_step,
    restore_checkpoint,
    save_checkpoint,
)


def _state(x=1.0):
    return {
        "params": {"layer": {"w": jnp.full((4, 4), x), "b": jnp.zeros((4,))}},
        "opt": {"m": {"layer": {"w": jnp.ones((4, 4))}}, "step": jnp.int32(7)},
    }


def test_roundtrip(tmp_path):
    save_checkpoint(tmp_path, 100, _state(2.5))
    state, step = restore_checkpoint(tmp_path)
    assert step == 100
    np.testing.assert_allclose(state["params"]["layer"]["w"], np.full((4, 4), 2.5))
    assert int(state["opt"]["step"]) == 7


def test_retention_gc(tmp_path):
    for s in (10, 20, 30, 40):
        save_checkpoint(tmp_path, s, _state(), keep=2)
    steps = sorted(p.name for p in tmp_path.glob("step_*"))
    assert steps == ["step_00000030", "step_00000040"]
    assert latest_step(tmp_path) == 40


def test_no_tmp_left_behind(tmp_path):
    save_checkpoint(tmp_path, 5, _state())
    assert not list(tmp_path.glob(".tmp_*"))


def test_manager_cadence_and_lease_guard(tmp_path):
    holding = {"v": True}
    mgr = CheckpointManager(tmp_path, every_steps=10, lease_guard=lambda: holding["v"])
    for step in range(1, 31):
        mgr.maybe_save(step, _state)
    assert mgr.saved_steps == [10, 20, 30]
    holding["v"] = False  # lost the ckpt-writer lease (e.g. partitioned away)
    for step in range(31, 51):
        mgr.maybe_save(step, _state)
    assert mgr.saved_steps == [10, 20, 30]
    assert mgr.skipped_no_lease == 2


def test_async_checkpointer_overlaps(tmp_path):
    ck = AsyncCheckpointer(tmp_path, keep=5)
    for s in (10, 20):
        ck.submit(s, {"params": {"w": np.ones((8, 8)) * s}})
    ck.close(flush=True)
    assert latest_step(tmp_path) in (10, 20)  # coalescing may drop the older
    state, step = restore_checkpoint(tmp_path)
    np.testing.assert_allclose(state["params"]["w"], np.ones((8, 8)) * step)


def test_restore_missing_raises(tmp_path):
    with pytest.raises(FileNotFoundError):
        restore_checkpoint(tmp_path)
