"""Restart edge cases (ISSUE 9, S3): the boundaries where the diskless
restart rules could plausibly be off by one.

Four families: a majority restart landing on the exact guarded-expiry
tick of the live lease (with a rival attacking the same tick) must keep
the referee and the array bit-identical on either side of the boundary;
an acceptor restart mid-prepare forgets its promise and cancels its
expiry timer (the blankness that makes the M-wait necessary, plus the
stale-timer race); a double restart inside one M window extends the deaf
window instead of rejoining at the FIRST deadline (the stale-rejoin
guard in ``core.cell.LeaseNode``); and the packed restart-counter carve
orders ballots exactly as the event engine's lexicographic ``Ballot``."""
import numpy as np
import pytest

from repro.configs import CellConfig
from repro.core import build_cell
from repro.core.acceptor import Acceptor
from repro.core.ballot import Ballot, BallotGenerator
from repro.core.messages import (
    Answer,
    Lease,
    PrepareRequest,
    PrepareResponse,
    Proposal,
    ProposeRequest,
    ProposeResponse,
)
from repro.lease_array.state import MAX_RESTARTS, ballot_of
from repro.lease_array.trace import Trace, replay_array, replay_event_sim
from repro.sim.network import NetConfig

NET = NetConfig(delay_min=0.01, delay_max=0.02)
CFG = CellConfig(n_acceptors=3, max_lease_time=60.0, lease_timespan=20.0)


# ------------------------------------ restart ON the guarded-expiry tick

@pytest.mark.parametrize("nudge", [-1, 0, 1])
def test_restart_straddling_guarded_expiry_tick(nudge):
    """Every acceptor restarts exactly at (and one tick either side of)
    the tick the incumbent's guarded lease expires, while a rival
    prepares on that same tick. Whichever side of the boundary the
    restart lands on, the event-sim referee and the array plane must
    agree bit-for-bit and §4 must hold — the deaf window and the guarded
    expiry may NOT disagree about the edge tick."""
    T, N, A, P, L = 14, 2, 3, 3, 3
    t_edge = L + 1 + nudge  # first tick past the guarded belief, +/- 1
    att = np.full((T, N), -1, np.int32)
    att[0, :] = 0
    att[t_edge, :] = 1
    rst = np.zeros((T, A), np.int32)
    rst[t_edge, :] = 1
    tr = Trace(
        N, A, P, L, att, np.full((T, N), -1, np.int32),
        np.ones((T, A), bool), acc_restarts=rst,
    )
    ref = replay_event_sim(tr)
    ow, cn = replay_array(tr)
    assert np.array_equal(ref, np.asarray(ow)), nudge
    assert int(np.max(np.asarray(cn))) <= 1, nudge


# --------------------------------------------------- restart mid-prepare

class _Timer:
    def __init__(self):
        self.cancelled = False

    def cancel(self):
        self.cancelled = True


def _bare_acceptor():
    """An Acceptor on hand-cranked plumbing: timers are inert handles we
    can fire by hand, sends are recorded."""
    timers, sent = [], []

    def set_timer(delay, fn):
        h = _Timer()
        timers.append((h, delay, fn))
        return h

    acc = Acceptor(0, set_timer=set_timer, send=lambda dst, m: sent.append(m))
    return acc, timers, sent


def test_restart_mid_prepare_forgets_the_promise():
    """A restart between promise and propose blanks the promise: the
    acceptor then accepts a STRICTLY LOWER ballot it had already promised
    away — exactly the §3 hazard, which only the node-level M-wait (not
    the acceptor) defends against."""
    acc, _, sent = _bare_acceptor()
    hi, lo = Ballot(5, 0, 1), Ballot(2, 0, 0)
    acc.on_prepare_request(PrepareRequest("R", hi), "p1")
    assert sent[-1] == PrepareResponse("R", hi, Answer.ACCEPT, None)
    acc.on_prepare_request(PrepareRequest("R", lo), "p0")
    assert sent[-1].answer == Answer.REJECT  # the promise is doing its job

    acc.restart()
    assert acc._res == {}  # diskless: nothing survives

    acc.on_prepare_request(PrepareRequest("R", lo), "p0")
    assert sent[-1] == PrepareResponse("R", lo, Answer.ACCEPT, None)


def test_restart_mid_lease_cancels_timer_and_stale_fire_is_harmless():
    """Accepting a proposal arms the expiry timer; a restart must cancel
    it AND survive the race where the simulator already popped the
    callback — a stale ``_on_timeout`` after the restart may not raise or
    resurrect state."""
    acc, timers, sent = _bare_acceptor()
    b = Ballot(3, 0, 2)
    prop = Proposal(b, Lease(2, 20.0))
    acc.on_propose_request(ProposeRequest("R", b, prop), "p2")
    assert sent[-1] == ProposeResponse("R", b, Answer.ACCEPT)
    (handle, delay, fire), = timers
    assert delay == 20.0 and not handle.cancelled
    assert acc._res["R"].accepted is prop

    acc.restart()
    assert handle.cancelled
    fire()  # the popped-but-cancelled race
    assert acc._res.get("R") is None or acc._res["R"].accepted is None


# ------------------------------------- double restart inside one M window

def test_double_restart_extends_the_deaf_window():
    """Two crash/restarts inside one M window: the node must stay deaf
    through the FIRST rejoin deadline (the stale closure fires and must
    yield to the extended window) and rejoin only at the second."""
    cell = build_cell(CFG, n_proposers=4, seed=7, net=NET,
                      strict_monitor=False)
    node = cell.nodes[0]
    cell.env.run_until(1.0)
    node.crash()
    cell.env.run_until(1.5)
    node.restart()
    first_deadline = node.rejoin_deadline
    assert first_deadline == pytest.approx(1.5 + CFG.max_lease_time)
    cell.env.run_until(5.0)
    node.crash()  # second crash while still deaf
    cell.env.run_until(5.5)
    node.restart()
    assert node.rejoin_deadline == pytest.approx(5.5 + CFG.max_lease_time)
    cell.env.run_until(first_deadline + 0.25)
    assert node.crashed  # the FIRST rejoin closure fired stale: still deaf
    cell.env.run_until(node.rejoin_deadline + 0.25)
    assert not node.crashed


def test_double_restart_bumps_the_stable_counter_twice():
    """The proposer role's restart counter lives on stable storage and
    increments once per restart — two restarts, two bumps, and the
    post-restart generator starts a fresh run under the newest counter."""
    cell = build_cell(CFG, n_proposers=4, seed=7, net=NET,
                      strict_monitor=False)
    node = cell.nodes[0]
    assert node.proposer.ballots.restart == 0
    for t in (1.0, 2.0):
        cell.env.run_until(t)
        node.crash()
        cell.env.run_until(t + 0.5)
        node.restart()
    assert node.proposer.ballots.restart == 2
    assert node.proposer.ballots.run == 0
    stored = cell.env.stable.load(node.addr)
    assert stored["restart_counter"] == 2


# --------------------------------------- restart-counter ballot ordering

def test_ballot_generator_never_repeats_across_restart():
    gen = BallotGenerator(proposer_id=1, restart_counter=0)
    before = {gen.next() for _ in range(5)}
    gen.restart, gen.run = 1, 0  # what LeaseNode.restart does
    after = {gen.next() for _ in range(5)}
    assert not before & after  # globally unique across the restart


def test_packed_carve_orders_like_the_event_ballot():
    """``state.ballot_of(t, p, P, rc)`` must order ballots EXACTLY as the
    event engine's lexicographic ``Ballot(run, restart, proposer)`` on
    the full (t, rc, p) grid — the numeric carve is the same total order,
    so array-plane arbitration and referee arbitration can never split a
    tie differently. All values distinct (global uniqueness)."""
    P = 4
    grid = [
        (ballot_of(t, p, P, restart_counter=rc), Ballot(t + 1, rc, p))
        for t in range(6)
        for rc in range(MAX_RESTARTS + 1)
        for p in range(P)
    ]
    nums = [n for n, _ in grid]
    assert len(set(nums)) == len(nums)
    by_num = [b for _, b in sorted(grid, key=lambda kv: kv[0])]
    assert by_num == sorted(by_num)
