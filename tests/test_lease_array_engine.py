"""Vectorized lease plane: protocol semantics at the array level, pallas
kernel vs jnp oracle, batched-width floor, vmap-ability, and the shard
directory fast path."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.cluster.shards import ShardLeaseManager, build_shard_manager
from repro.configs import CellConfig
from repro.core import build_cell
from repro.lease_array import (
    NO_PROPOSER,
    LeaseArrayEngine,
    init_state,
    lease_quarters,
    make_tick,
    random_trace,
    replay_array,
)
from repro.lease_array.directory import LeaseArrayDirectory
from repro.lease_array.ref import lease_step_ref
from repro.sim.network import NetConfig

A = np.array
NA = NO_PROPOSER


def eng(n_cells=8, **kw):
    kw.setdefault("n_acceptors", 5)
    kw.setdefault("n_proposers", 4)
    kw.setdefault("lease_ticks", 3)
    return LeaseArrayEngine(n_cells, **kw)


def tick(e, **planes):
    """One validated TickInputs sized for engine ``e`` (registry names)."""
    return make_tick(
        n_cells=e.n_cells, n_acceptors=e.n_acceptors,
        n_proposers=e.n_proposers, **planes,
    )


# ----------------------------------------------------------- protocol steps
def test_acquire_hold_expire():
    e = eng(n_cells=4)
    own = e.step(tick(e, attempts=A([0, 1, NA, NA])))
    assert own.tolist() == [0, 1, NA, NA]
    # held without renewal for lease_ticks ticks, then expires
    for _ in range(e.lease_ticks):
        own = e.step()
        assert own.tolist() == [0, 1, NA, NA]
    assert e.step().tolist() == [NA] * 4


def test_extend_resets_clock_and_contender_is_shut_out():
    e = eng(n_cells=1)
    assert e.step(tick(e, attempts=A([0])))[0] == 0
    # a contender's higher ballot gets promises but no open majority
    assert e.step(tick(e, attempts=A([1])))[0] == 0
    # the owner extends (§6): its own accepted proposal counts as open
    assert e.step(tick(e, attempts=A([0])))[0] == 0
    for _ in range(e.lease_ticks):
        assert e.step()[0] == 0  # clock restarted at the extend tick
    assert e.step()[0] == NA


def test_release_frees_cell_immediately():
    e = eng(n_cells=2)
    e.step(tick(e, attempts=A([0, 1])))
    assert e.step(tick(e, releases=A([0, NA]))).tolist() == [NA, 1]
    # released cell is acquirable by someone else the very next tick
    assert e.step(tick(e, attempts=A([2, NA]))).tolist() == [2, 1]


def test_release_by_non_owner_is_noop():
    e = eng(n_cells=1)
    e.step(tick(e, attempts=A([0])))
    assert e.step(tick(e, releases=A([3])))[0] == 0


def test_quorum_loss_blocks_acquisition():
    e = eng(n_cells=1, n_acceptors=5)
    down3 = A([0, 0, 0, 1, 1])  # 3 of 5 unreachable -> no majority
    assert e.step(tick(e, attempts=A([0]), acc_up=down3))[0] == NA
    assert e.step(tick(e, attempts=A([0])))[0] == 0  # healed -> wins


def test_promises_survive_lease_expiry():
    e = eng(n_cells=1)
    e.step(tick(e, attempts=A([3])))
    for _ in range(e.lease_ticks + 1):
        e.step()
    assert e.owners()[0] == NA
    # later-tick ballots are higher, so a fresh acquire still works
    assert e.step(tick(e, attempts=A([0])))[0] == 0
    promised = np.asarray(e.state.highest_promised)
    assert (promised > 0).all()  # never reset by expiry


# ------------------------------------------------------- engine queries
def test_ticks_left_owned_unowned_expiring():
    e = eng(n_cells=3, lease_ticks=3)
    e.step(tick(e, attempts=A([0, 1, NA])))
    # owned cells: won at t=0, expiry quarter 4*3+1=13; unowned cell: 0
    # at t=1: (13 - 4) // 4 = 2 whole ticks beyond the current one
    assert e.ticks_left().tolist() == [2, 2, 0]
    e.step()
    assert e.ticks_left().tolist() == [1, 1, 0]
    e.step()
    assert e.ticks_left().tolist() == [0, 0, 0]  # expiring: no whole tick
    assert e.owners().tolist() == [0, 1, NA]  # ...but still owned...
    e.step()
    assert e.owners().tolist() == [0, 1, NA]  # ...through the expiry tick
    assert e.ticks_left().tolist() == [0, 0, 0]
    e.step()  # gone the tick after
    assert e.owners().tolist() == [NA] * 3
    assert e.ticks_left().tolist() == [0, 0, 0]


def test_ticks_left_resets_on_extend():
    e = eng(n_cells=1, lease_ticks=4)
    e.step(tick(e, attempts=A([2])))
    for _ in range(3):
        e.step()
    assert e.ticks_left().tolist() == [0]
    e.step(tick(e, attempts=A([2])))  # §6 extend restarts the clock
    assert e.ticks_left().tolist() == [3]


def test_row_rejects_ghost_proposer():
    e = eng(n_cells=2, n_proposers=4)
    with pytest.raises(ValueError, match=r"proposer id 4 out of range.*4 proposers"):
        e.step(tick(e, attempts=A([4, NA])))
    with pytest.raises(ValueError, match="out of range"):
        e.step(tick(e, releases=A([NA, 99])))


def test_row_rejects_below_sentinel():
    e = eng(n_cells=2)
    with pytest.raises(ValueError, match="out of range"):
        e.step(tick(e, attempts=A([-2, 0])))
    # the sentinel itself and valid ids are fine
    assert e.step(tick(e, attempts=A([NA, 0]))).tolist() == [NA, 0]


# -------------------------------------------------- kernel vs oracle, width
@pytest.mark.parametrize("n_cells", [64, 100, 1000])
def test_pallas_matches_jnp_oracle(n_cells):
    tr = random_trace(
        11, n_ticks=30, n_cells=n_cells, n_acceptors=5, n_proposers=6,
        lease_ticks=2, p_release=0.1, p_down_flip=0.05,
    )
    jo, jc = replay_array(tr, backend="jnp")
    po, pc = replay_array(tr, backend="pallas")
    assert np.array_equal(jo, po)
    assert np.array_equal(jc, pc)


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_single_batched_step_at_4096_cells(backend):
    e = eng(n_cells=4096, n_proposers=8, backend=backend)
    attempt = np.arange(4096, dtype=np.int32) % 8
    own = e.step(tick(e, attempts=attempt))
    assert (own == attempt).all()  # uncontended: everyone wins its cell
    assert np.asarray(e.last_owner_count).max() <= 1


def test_vmap_over_independent_planes():
    step = functools.partial(
        lease_step_ref, majority=3, lease_q4=lease_quarters(3)
    )
    n_planes, n_cells = 3, 16
    states = jax.tree.map(
        lambda *xs: jnp.stack(xs), *[init_state(n_cells, 5, 4)] * n_planes
    )
    attempts = jnp.stack(
        [jnp.full(n_cells, p % 4, jnp.int32) for p in range(n_planes)]
    )
    none = jnp.full((n_planes, n_cells), NA, jnp.int32)
    up = jnp.ones((n_planes, 5), jnp.int32)
    batched = jax.vmap(step, in_axes=(0, None, 0, 0, 0))
    states, counts = batched(states, jnp.int32(0), attempts, none, up)
    assert counts.shape == (n_planes, n_cells)
    assert (counts == 1).all()
    # planes are independent: each plane's owner is its own attempt row
    assert (np.asarray(states.owner_mask).sum(axis=1) == 1).all(), "one owner bit per cell"


# ----------------------------------------------------------- the directory
def test_directory_coverage_failover_drain_retarget():
    d = LeaseArrayDirectory(512, n_acceptors=3, lease_ticks=4, max_workers=8)
    for i in range(4):
        d.add_worker(i, 128)
    d.tick(3)
    assert d.coverage() == 1.0
    assert all(d.owned_count(i) == 128 for i in range(4))

    d.stall(0)  # straggler: stops renewing, says nothing
    d.tick(d.engine.lease_ticks + 2)
    assert d.owned_count(0) == 0
    # elastic pickup: retarget the healthy workers to absorb the loss
    for i in range(1, 4):
        d.set_target(i, 512 // 3 + 1)
    d.tick(3)
    assert d.coverage() == 1.0

    d.drain(1)  # graceful §7 release -> redistributed, not expired
    for i in (2, 3):
        d.set_target(i, 256)
    d.tick(4)
    assert d.owned_count(1) == 0
    assert d.coverage() == 1.0

    m = d.owner_map()
    assert len(m) == 512 and set(m.values()) <= {2, 3}


def test_build_shard_manager_backend_dispatch():
    assert isinstance(build_shard_manager(4096, max_workers=4), LeaseArrayDirectory)
    cfg = CellConfig(n_acceptors=3, max_lease_time=30.0, lease_timespan=5.0)
    d = build_shard_manager(2048, cfg=cfg, max_workers=4)
    assert isinstance(d, LeaseArrayDirectory)
    assert d.engine.n_acceptors == 3  # inherited from the cell config
    cell = build_cell(cfg, seed=0, net=NetConfig(delay_min=0.001, delay_max=0.002))
    m = build_shard_manager(64, cell=cell)
    assert isinstance(m, ShardLeaseManager)
    with pytest.raises(ValueError):
        build_shard_manager(64, backend="event")  # event path needs a Cell
