"""MoE block: dispatch-vs-dense oracle equivalence, capacity-drop
accounting, load-balance aux loss, property test over shapes."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis (requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.configs import MoEConfig, get_config, reduced
from repro.models import init_model
from repro.models.moe import apply_moe, moe_dense, moe_dispatch, router_topk


def _cfg(n_experts=4, top_k=2, cf=8.0):
    base = reduced(get_config("mixtral-8x22b"), dtype="float32", param_dtype="float32")
    return dataclasses.replace(
        base, moe=MoEConfig(n_experts=n_experts, top_k=top_k, d_expert=64,
                            capacity_factor=cf)
    )


def _params(cfg, key):
    return jax.tree.map(lambda a: a[0], init_model(cfg, key)["layers"]["moe"])


def test_dispatch_equals_dense_with_headroom():
    cfg = _cfg(cf=8.0)
    key = jax.random.PRNGKey(0)
    p = _params(cfg, key)
    x = jax.random.normal(key, (2, 16, cfg.d_model), jnp.float32)
    y_dense, aux_d, _ = moe_dense(cfg, p, x)
    y_disp, aux_s, dropped = moe_dispatch(cfg, p, x)
    assert float(dropped) == 0.0
    assert float(jnp.max(jnp.abs(y_dense - y_disp))) < 1e-5
    assert abs(float(aux_d) - float(aux_s)) < 1e-5


def test_capacity_drops_reported():
    cfg = _cfg(cf=0.25)  # starved capacity must drop tokens
    key = jax.random.PRNGKey(1)
    p = _params(cfg, key)
    x = jax.random.normal(key, (2, 32, cfg.d_model), jnp.float32)
    _, _, dropped = moe_dispatch(cfg, p, x)
    assert float(dropped) > 0.0


def test_router_gates_normalized_topk():
    cfg = _cfg()
    key = jax.random.PRNGKey(2)
    p = _params(cfg, key)
    x = jax.random.normal(key, (3, 8, cfg.d_model), jnp.float32)
    gates, idx, aux = router_topk(cfg, p, x)
    assert gates.shape == (3, 8, cfg.moe.top_k)
    assert jnp.allclose(gates.sum(-1), 1.0, atol=1e-5)
    assert float(aux) >= 1.0 - 1e-3  # >= 1 by Cauchy-Schwarz, ==1 iff balanced
    # indices within range and distinct per token
    assert int(idx.max()) < cfg.moe.n_experts
    assert bool(jnp.all(idx[..., 0] != idx[..., 1]))


@settings(deadline=None, max_examples=10)
@given(
    n_experts=st.sampled_from([2, 4, 8]),
    top_k=st.sampled_from([1, 2]),
    tokens=st.sampled_from([8, 24, 64]),
)
def test_dispatch_dense_property(n_experts, top_k, tokens):
    cfg = _cfg(n_experts=n_experts, top_k=top_k, cf=8.0)
    key = jax.random.PRNGKey(n_experts * 100 + top_k)
    p = _params(cfg, key)
    x = jax.random.normal(key, (1, tokens, cfg.d_model), jnp.float32)
    y1, _, _ = moe_dense(cfg, p, x)
    y2, _, d = moe_dispatch(cfg, p, x, group_size=16)
    if float(d) == 0.0:
        assert float(jnp.max(jnp.abs(y1 - y2))) < 2e-5


def test_dispatch_grad_flows():
    cfg = _cfg()
    key = jax.random.PRNGKey(3)
    p = _params(cfg, key)
    x = jax.random.normal(key, (1, 16, cfg.d_model), jnp.float32)

    def loss(p_):
        y, aux, _ = apply_moe(cfg, p_, x)
        return jnp.sum(y**2) + 0.01 * aux

    g = jax.grad(loss)(p)
    norms = jax.tree.map(lambda a: float(jnp.abs(a).sum()), g)
    assert norms["router"] > 0 and norms["wi"] > 0 and norms["wo"] > 0
