"""Pallas flash-attention kernel vs pure-jnp oracle: shape/dtype sweep
(assignment: per-kernel allclose against ref.py, interpret=True on CPU)."""
import jax
import jax.numpy as jnp
import pytest

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref

CASES = [
    # (b, sq, sk, hq, hkv, dh, causal, window, dtype)
    (2, 256, 256, 4, 2, 64, True, None, jnp.float32),
    (1, 128, 128, 8, 8, 128, True, None, jnp.float32),
    (1, 128, 128, 8, 8, 128, True, None, jnp.bfloat16),
    (2, 256, 256, 4, 1, 64, True, 96, jnp.float32),  # SWA + MQA
    (1, 128, 256, 2, 2, 64, False, None, jnp.float32),  # cross-attention
    (1, 64, 64, 6, 3, 112, True, None, jnp.float32),  # kimi head_dim
    (1, 256, 256, 2, 2, 64, True, 32, jnp.bfloat16),  # tight window, bf16
]


def _run(b, sq, sk, hq, hkv, dh, causal, window, dt, block=64):
    key = jax.random.PRNGKey(hash((b, sq, hq, dh)) & 0xFFFF)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, sq, hq, dh), jnp.float32).astype(dt)
    k = jax.random.normal(ks[1], (b, sk, hkv, dh), jnp.float32).astype(dt)
    v = jax.random.normal(ks[2], (b, sk, hkv, dh), jnp.float32).astype(dt)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          block_q=block, block_k=block)
    fold = lambda a, h: a.transpose(0, 2, 1, 3).reshape(b * h, a.shape[1], dh)
    ref = attention_ref(fold(q, hq), fold(k, hkv), fold(v, hkv),
                        causal=causal, window=window)
    ref = ref.reshape(b, hq, sq, dh).transpose(0, 2, 1, 3)
    return out, ref


@pytest.mark.parametrize("case", CASES, ids=lambda c: f"b{c[0]}s{c[1]}h{c[3]}kv{c[4]}d{c[5]}w{c[7]}{c[8].__name__}")
def test_flash_matches_ref(case):
    *dims, dt = case
    out, ref = _run(*dims, dt)
    err = float(jnp.max(jnp.abs(out.astype(jnp.float32) - ref.astype(jnp.float32))))
    tol = 2.5e-2 if dt == jnp.bfloat16 else 5e-5
    assert err < tol, f"{case}: err {err:.3e}"


def test_block_size_invariance():
    """Different BlockSpec tilings must give identical results."""
    outs = []
    for block in (32, 64, 128):
        out, _ = _run(1, 256, 256, 4, 2, 64, True, None, jnp.float32, block=block)
        outs.append(out)
    for o in outs[1:]:
        assert float(jnp.max(jnp.abs(o - outs[0]))) < 1e-6
