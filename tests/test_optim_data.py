"""Optimizer against a numpy reference; schedule; synthetic data pipeline."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import ShardedLoader, SyntheticTokens
from repro.optim import adamw_init, adamw_update, clip_by_global_norm, cosine_schedule


def _np_adamw(p, g, m, v, t, lr, b1=0.9, b2=0.95, eps=1e-8, wd=0.1):
    m = b1 * m + (1 - b1) * g
    v = b2 * v + (1 - b2) * g * g
    mh = m / (1 - b1**t)
    vh = v / (1 - b2**t)
    return p - lr * (mh / (np.sqrt(vh) + eps) + wd * p), m, v


def test_adamw_matches_numpy_reference():
    rng = np.random.default_rng(0)
    p0 = rng.normal(size=(4, 5)).astype(np.float32)
    params = {"w": jnp.asarray(p0)}
    state = adamw_init(params)
    pn, mn, vn = p0.copy(), np.zeros_like(p0), np.zeros_like(p0)
    for t in range(1, 6):
        g = rng.normal(size=(4, 5)).astype(np.float32) * 0.1
        params, state, _ = adamw_update(
            params, {"w": jnp.asarray(g)}, state, lr=1e-2, max_grad_norm=None
        )
        pn, mn, vn = _np_adamw(pn, g, mn, vn, t, 1e-2)
        np.testing.assert_allclose(np.asarray(params["w"]), pn, rtol=2e-5, atol=2e-6)


def test_grad_clipping():
    g = {"w": jnp.full((10,), 100.0)}
    clipped, gn = clip_by_global_norm(g, 1.0)
    assert float(gn) == pytest.approx(np.sqrt(10) * 100, rel=1e-5)
    assert float(jnp.linalg.norm(clipped["w"])) == pytest.approx(1.0, rel=1e-4)


def test_cosine_schedule_shape():
    lrs = [float(cosine_schedule(jnp.int32(s), peak_lr=1e-3, warmup_steps=10, total_steps=100))
           for s in range(0, 101, 10)]
    assert lrs[0] == 0.0
    assert max(lrs) == pytest.approx(1e-3, rel=1e-6)
    assert lrs[-1] == pytest.approx(1e-4, rel=1e-3)  # min_ratio * peak


def test_synthetic_deterministic_and_stateless():
    gen = SyntheticTokens(1000, 32, seed=7)
    b1 = gen.batch(shard=3, step=5, batch_size=4)
    b2 = gen.batch(shard=3, step=5, batch_size=4)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = gen.batch(shard=3, step=6, batch_size=4)
    assert not np.array_equal(b1["tokens"], b3["tokens"])
    # labels are the next-token shift
    np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["labels"][:, :-1])


def test_loader_respects_lease_ownership():
    gen = SyntheticTokens(1000, 16, seed=0)
    owned = {0, 2}
    loader = ShardedLoader(gen, n_shards=4, batch_size=4, owned_shards=lambda: owned)
    b = loader.next_batch()
    assert b["tokens"].shape == (4, 16)
    assert loader.step_per_shard[0] == 1 and loader.step_per_shard[1] == 0
    owned.clear()
    with pytest.raises(RuntimeError):
        loader.next_batch()  # lease-starved worker must not fabricate data


def test_loader_handoff_resumes_stream():
    gen = SyntheticTokens(1000, 16, seed=0)
    l1 = ShardedLoader(gen, 2, 2, owned_shards=lambda: {0})
    b1 = l1.next_batch()
    # worker 2 takes over shard 0 at the committed step
    l2 = ShardedLoader(gen, 2, 2, owned_shards=lambda: {0})
    l2.step_per_shard[0] = 0
    b2 = l2.next_batch()
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])  # exactly-once replay
