"""Config registry + shape support + cell config invariants."""
import pytest

from repro.configs import (
    CellConfig,
    SHAPES,
    arch_ids,
    get_config,
    get_shape,
    reduced,
    supports_shape,
)


def test_all_ten_assigned_archs_present():
    assert len(arch_ids()) == 10


def test_unknown_arch_raises():
    with pytest.raises(KeyError):
        get_config("not-a-model")


def test_shapes_table():
    assert SHAPES["train_4k"].tokens_per_step == 4096 * 256
    assert SHAPES["decode_32k"].tokens_per_step == 128  # one token per seq
    assert SHAPES["long_500k"].seq_len == 524288


def test_long_context_skip_logic():
    runnable = [a for a in arch_ids() if supports_shape(get_config(a), get_shape("long_500k"))[0]]
    assert sorted(runnable) == ["hymba-1.5b", "mixtral-8x22b", "rwkv6-3b"]
    ok, reason = supports_shape(get_config("granite-3-8b"), get_shape("long_500k"))
    assert not ok and "full-attention" in reason


def test_total_cell_count_is_40():
    cells = [(a, s) for a in arch_ids() for s in SHAPES]
    assert len(cells) == 40


def test_reduced_configs_stay_in_family():
    for a in arch_ids():
        cfg, red = get_config(a), reduced(get_config(a))
        assert red.family == cfg.family
        assert (red.moe is None) == (cfg.moe is None)
        assert red.attention_free == cfg.attention_free
        assert red.enc_dec == cfg.enc_dec
        assert red.n_params() < 10_000_000, f"{a}: reduced config too big"


def test_cell_config_enforces_t_less_than_m():
    with pytest.raises(ValueError):
        CellConfig(lease_timespan=60.0, max_lease_time=60.0)
    assert CellConfig().majority == 3  # 5 acceptors
    assert CellConfig(n_acceptors=4).majority == 3  # strict majority
