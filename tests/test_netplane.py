"""Unit tests for the in-flight message plane (netplane) edge cases:
late responses after round abandonment, duplicate deliveries, full
partitions, and response-leg loss — at the array level, driving
LeaseArrayEngine.step with explicit per-tick delay/drop schedules."""
import jax.numpy as jnp
import numpy as np

from repro.lease_array import LeaseArrayEngine, NO_PROPOSER, make_tick, pack_slot
from repro.lease_array.netplane import R_IDLE, R_PREPARING, R_PROPOSING

A = np.array


def eng(n_cells=1, **kw):
    kw.setdefault("n_acceptors", 3)
    kw.setdefault("n_proposers", 2)
    kw.setdefault("lease_ticks", 3)
    return LeaseArrayEngine(n_cells, **kw)


def tick(e, **planes):
    """One validated TickInputs sized for engine ``e`` (registry names)."""
    return make_tick(
        n_cells=e.n_cells, n_acceptors=e.n_acceptors,
        n_proposers=e.n_proposers, **planes,
    )


def test_response_after_abandon_is_ignored():
    """Round abandoned at t0 + round_ticks; the prepare responses land
    later and must not resurrect it — but the acceptors still processed
    the requests (promises were raised)."""
    e = eng(round_ticks=2)
    # requests take 3 ticks; the round is abandoned at t=2, requests land t=3
    assert e.step(tick(e, attempts=A([0]), delay=A([3, 3, 3]))).tolist() == [NO_PROPOSER]
    assert int(e.net.rnd_phase[0, 0]) == R_PREPARING
    e.step()  # t=1: request still in flight
    assert int(np.asarray(e.net.preq_b).max()) > 0
    e.step()  # t=2: timeout-and-abandon fires (before any delivery)
    assert int(e.net.rnd_ballot[0, 0]) == 0
    assert int(e.net.rnd_phase[0, 0]) == R_IDLE
    for _ in range(6):  # t=3: requests delivered, responses return, ignored
        assert e.step().tolist() == [NO_PROPOSER]
    promised = np.asarray(e.state.highest_promised)
    assert (promised == 2).all(), "acceptors promised ballot (0+1)*2+0 = 2"
    assert int(np.asarray(e.net.presp_b).max()) == 0, "late responses consumed"
    assert int(np.asarray(e.state.owner_mask).sum()) == 0


def test_duplicate_prepare_response_cannot_double_count_quorum():
    """The event engine counts votes as sets of acceptor ids; the array
    plane's rnd_open mask must be equally duplicate-proof."""
    e = eng(round_ticks=10)  # majority = 2 of 3
    # acceptor 0 answers fast; acceptors 1, 2 are 5 ticks away
    e.step(tick(e, attempts=A([0]), delay=A([1, 5, 5])))  # t=0
    e.step()  # t=1: acc0 processes the request, response (0 delay) arrives
    assert int(e.net.rnd_open[0, 0]) == 1
    assert int(np.asarray(e.net.rnd_open).sum()) == 1
    # adversarial transport: duplicate acc0's open response, delivered t=2
    dup = e.net.presp.at[0, 0].set(
        int(pack_slot(int(e.net.rnd_ballot[0, 0]), 4 * 2))
    )
    dup_pay = e.net.presp_pay.at[0, 0].set(NO_PROPOSER)
    e.net = e.net._replace(presp=dup, presp_pay=dup_pay)
    own = e.step()  # t=2: duplicate delivered
    assert own.tolist() == [NO_PROPOSER]
    assert int(np.asarray(e.net.rnd_open).sum()) == 1, "no double count"
    assert int(e.net.rnd_phase[0, 0]) == R_PREPARING, "quorum not faked"
    e.step()  # t=3
    e.step()  # t=4
    assert e.owners().tolist() == [NO_PROPOSER]
    # t=5: the genuine second and third opens arrive -> propose -> owner
    own = e.step()
    assert own.tolist() == [0]
    assert int(np.asarray(e.last_owner_count).max()) <= 1


def test_full_partition_tick_leaves_acceptors_untouched():
    """drop[t] all-True: every message sent this tick is lost — the
    acceptors never see the round at all."""
    e = eng(round_ticks=4)
    before = np.asarray(e.state.highest_promised).copy()
    e.step(tick(e, attempts=A([0]), drop=A([1, 1, 1])))
    assert int(np.asarray(e.net.preq_b).max()) == 0, "requests never sent"
    for _ in range(6):
        assert e.step().tolist() == [NO_PROPOSER]
    assert np.array_equal(np.asarray(e.state.highest_promised), before)
    assert int(np.asarray(e.state.accepted_ballot).max()) == 0


def test_dropped_response_leg_still_raises_promise():
    """Loss is per leg: when the responses are dropped the acceptors have
    still processed the requests (promises raised), like the event
    acceptor answering into a lossy socket."""
    e = eng(round_ticks=4)
    e.step(tick(e, attempts=A([1]), delay=A([1, 1, 1])))  # t=0: requests in flight
    e.step(tick(e, drop=A([1, 1, 1])))  # t=1: requests land; every response is lost
    promised = np.asarray(e.state.highest_promised)
    assert (promised == 3).all(), "ballot (0+1)*2+1 = 3 promised everywhere"
    assert int(np.asarray(e.net.presp_b).max()) == 0, "responses lost at send"
    for _ in range(6):
        assert e.step().tolist() == [NO_PROPOSER]


def test_response_arriving_while_proposing_is_ignored():
    """A straggler open response landing after the round moved to the
    propose phase must not re-enter quorum counting (the event proposer
    ignores PrepareResponses once phase != PREPARING)."""
    e = eng(round_ticks=10)
    # acc0 and acc1 answer immediately (majority!), acc2 is 4 ticks away
    e.step(tick(e, attempts=A([0]), delay=A([0, 0, 4])))  # t=0: quorum of 2 -> owner
    assert e.owners().tolist() == [0]
    assert int(e.net.rnd_ballot[0, 0]) == 0, "round completed and cleared"
    opens_before = int(np.asarray(e.net.rnd_open).sum())
    for _ in range(5):  # acc2's response lands around t=4+: round is gone
        e.step()
    assert int(np.asarray(e.net.rnd_open).sum()) == opens_before == 0
    assert int(np.asarray(e.net.presp_b).max()) == 0


def test_accepts_after_own_lease_window_do_not_grant_ownership():
    """§3 step 5: the proposer's timer (started at the propose broadcast)
    bounds the ownership claim. If the accepts crawl back after that window
    elapsed, the proposer must NOT become owner — otherwise it would hold a
    'lease' that outlives every acceptor's timer (a §4 hazard)."""
    e = eng(round_ticks=10, lease_ticks=2)
    e.step(tick(e, attempts=A([0]), delay=A([1, 1, 1])))  # t=0: requests out
    e.step(tick(e, delay=A([1, 1, 1])))  # t=1: requests land, responses out
    e.step(tick(e, delay=A([4, 4, 4])))  # t=2: majority opens -> timer starts,
    #                                  propose requests crawl (4 ticks)
    assert int(e.net.rnd_phase[0, 0]) == R_PROPOSING
    assert int(e.net.rnd_expiry[0, 0]) == 4 * 2 + 4 * 2 + 1  # expires ~t=4
    for _ in range(3, 8):  # t=6: requests land, accepts return instantly —
        e.step()           # but our window closed at quarter-tick 17 (t<=4)
        assert e.owners().tolist() == [NO_PROPOSER]
    # the acceptors DID accept (their leases run) — only the claim is dead
    assert int(np.asarray(e.state.accepted_ballot).max()) > 0


def test_late_accepts_differential_vs_event_sim():
    """The same late-accept scenario through the differential referee:
    the event proposer must also refuse the ghost lease (its lease timer
    already fired), keeping both engines bit-identical."""
    from repro.lease_array import Trace
    from test_lease_array_differential import assert_engines_agree

    T, N, A_, P = 16, 2, 3, 2
    attempts = np.full((T, N), NO_PROPOSER, np.int32)
    attempts[0, 0] = 0
    attempts[3, 1] = 1  # control cell: a fast zero-delay round -> owner
    delay = np.zeros((T, A_), np.int32)
    delay[0] = 1  # prepare requests: land t=1
    delay[1] = 1  # prepare responses: land t=2 (majority -> timer starts)
    delay[2] = 4  # propose requests: land t=6, after the window (t<=4)
    trace = Trace(
        N, A_, P, lease_ticks=2,
        attempts=attempts,
        releases=np.full((T, N), NO_PROPOSER, np.int32),
        acc_up=np.ones((T, A_), bool),
        delay=delay, round_ticks=10,
    )
    owners = assert_engines_agree(trace)
    assert (owners[:, 0] == NO_PROPOSER).all(), "late accepts: no owner ever"
    assert (owners[3:6, 1] == 1).all(), "control cell owned normally"


def test_multi_tick_round_timing():
    """A symmetric 1-tick delay: prepare out t=0..1, responses t=2,
    propose out t=2..3, accepts t=4 -> ownership visible at tick 4, and
    the proposer's own timer started at the propose tick (t=2)."""
    e = eng(round_ticks=10, lease_ticks=3)
    e.step(tick(e, attempts=A([0]), delay=A([1, 1, 1])))          # t=0
    assert e.owners().tolist() == [NO_PROPOSER]
    e.step(tick(e, delay=A([1, 1, 1])))                           # t=1: preq lands, resp sent (1 tick)
    assert e.owners().tolist() == [NO_PROPOSER]
    e.step(tick(e, delay=A([1, 1, 1])))                           # t=2: opens -> propose sent (1 tick)
    assert int(e.net.rnd_phase[0, 0]) == R_PROPOSING
    assert e.owners().tolist() == [NO_PROPOSER]
    e.step(tick(e, delay=A([1, 1, 1])))                           # t=3: accepts sent (1 tick)
    assert e.owners().tolist() == [NO_PROPOSER]
    own = e.step()                                       # t=4: accepts land -> owner
    assert own.tolist() == [0]
    # timer started at t=2 -> expiry quarter 4*2 + 4*3 + 1 = 21
    assert int(np.asarray(e.state.owner_expiry).max()) == 21
    # owned through tick 5 (21 > 20), gone at tick 6 (21 < 24)
    assert e.step().tolist() == [0]
    assert e.step().tolist() == [NO_PROPOSER]
