"""Pallas WKV6 kernel vs exact sequential oracle (interpret=True)."""
import jax
import jax.numpy as jnp
import pytest

from repro.kernels.rwkv6.ops import wkv6
from repro.kernels.rwkv6.ref import wkv6_ref
from repro.models.rwkv6 import wkv_chunked

CASES = [
    # (b, s, h, n, omega_hi, chunk, dtype)
    (2, 64, 4, 64, 0.5, 32, jnp.float32),
    (1, 128, 2, 64, 1.0, 32, jnp.float32),
    (1, 96, 2, 64, 0.5, 16, jnp.float32),  # chunk invariance
    (2, 96, 3, 32, 0.5, 32, jnp.bfloat16),
    (1, 64, 1, 128, 0.0, 32, jnp.float32),  # aggressive decay
]


def _inputs(b, s, h, n, omega_hi, dt, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    r = jax.random.normal(ks[0], (b, s, h, n)).astype(dt)
    k = jax.random.normal(ks[1], (b, s, h, n)).astype(dt)
    v = jax.random.normal(ks[2], (b, s, h, n)).astype(dt)
    omega = jax.random.uniform(ks[3], (b, s, h, n), minval=-6.0, maxval=omega_hi)
    logw = (-jnp.exp(omega)).astype(dt)
    u = (jax.random.normal(ks[4], (h, n)) * 0.3).astype(dt)
    return r, k, v, logw, u


def _ref(r, k, v, logw, u):
    b, s, h, n = r.shape
    fold = lambda a: a.transpose(0, 2, 1, 3).reshape(b * h, s, n)
    ue = jnp.broadcast_to(u[None], (b, h, n)).reshape(b * h, n)
    out = wkv6_ref(fold(r), fold(k), fold(v), fold(logw), ue)
    return out.reshape(b, h, s, n).transpose(0, 2, 1, 3)


@pytest.mark.parametrize("case", CASES, ids=lambda c: f"b{c[0]}s{c[1]}h{c[2]}n{c[3]}c{c[5]}{c[6].__name__}")
def test_wkv6_kernel_matches_sequential_ref(case):
    b, s, h, n, ohi, chunk, dt = case
    r, k, v, logw, u = _inputs(b, s, h, n, ohi, dt)
    out = wkv6(r, k, v, logw, u, chunk=chunk)
    ref = _ref(r, k, v, logw, u)
    scale = float(jnp.max(jnp.abs(ref.astype(jnp.float32)))) + 1e-9
    rel = float(jnp.max(jnp.abs(out.astype(jnp.float32) - ref.astype(jnp.float32)))) / scale
    tol = 3e-2 if dt == jnp.bfloat16 else 5e-4
    assert rel < tol, f"{case}: rel {rel:.2e}"


def test_model_chunked_form_matches_sequential_ref():
    """The model's pairwise-exact chunked form (oracle for training) agrees
    with the plain recurrence too — kernel, model, and scan are one family."""
    b, s, h, n = 2, 64, 2, 32
    r, k, v, logw, u = _inputs(b, s, h, n, 0.5, jnp.float32, seed=7)
    state0 = jnp.zeros((b, h, n, n), jnp.float32)
    out_model, _ = wkv_chunked(r, k, v, logw, u, state0, chunk=16)
    ref = _ref(r, k, v, logw, u)
    assert float(jnp.max(jnp.abs(out_model - ref))) < 1e-4


def test_state_carry_across_calls():
    """Kernel processes a long sequence == two half-sequences with carried
    state (sequential grid dim semantics)."""
    b, s, h, n = 1, 128, 2, 64
    r, k, v, logw, u = _inputs(b, s, h, n, 0.5, jnp.float32, seed=9)
    full = wkv6(r, k, v, logw, u, chunk=32)
    # reference: model-side chunked with explicit state carry
    st = jnp.zeros((b, h, n, n), jnp.float32)
    o1, st = wkv_chunked(r[:, :64], k[:, :64], v[:, :64], logw[:, :64], u, st, chunk=32)
    o2, st = wkv_chunked(r[:, 64:], k[:, 64:], v[:, 64:], logw[:, 64:], u, st, chunk=32)
    two = jnp.concatenate([o1, o2], axis=1)
    assert float(jnp.max(jnp.abs(full - two))) < 5e-4
