"""Network accounting: loss causes are distinguished (src down, partition,
policy, random loss, dst down mid-flight, unregistered address) and
surfaced by Network.stats(); pinned delay/drop policies are deterministic."""
from repro.sim.events import Scheduler
from repro.sim.network import NetConfig, Network


def make_net(**cfg):
    cfg.setdefault("delay_min", 0.0)
    cfg.setdefault("delay_max", 0.0)
    sched = Scheduler()
    net = Network(sched, NetConfig(**cfg), seed=0)
    inbox = []
    net.register("a", lambda msg, src: inbox.append(("a", src, msg)))
    net.register("b", lambda msg, src: inbox.append(("b", src, msg)))
    return sched, net, inbox


def test_sent_vs_delivered_vs_dropped_causes():
    sched, net, inbox = make_net()
    net.send("a", "b", "m1")  # delivered
    net.set_down("a")
    net.send("a", "b", "m2")  # src down: a crashed node doesn't speak
    net.set_down("a", False)
    net.partition({"a"}, {"b"})
    net.send("a", "b", "m3")  # partitioned at send
    net.heal()
    net.send("a", "ghost", "m4")  # nothing registered there
    sched.run_until(1.0)  # m1 lands; m2-m4 were dropped at send
    net.send("a", "b", "m5")  # dst goes down while m5 is in flight
    net.set_down("b")
    sched.run_until(10.0)
    s = net.stats()
    assert s["sent"] == 5
    assert s["delivered"] == 1 and len(inbox) == 1
    assert s["dropped"]["src_down"] == 1
    assert s["dropped"]["partition"] == 1
    assert s["dropped"]["no_handler"] == 1
    assert s["dropped"]["dst_down"] == 1
    assert s["dropped_total"] == 4
    assert s["sent"] == s["delivered"] + s["dropped_total"]


def test_random_loss_is_counted_as_loss():
    sched, net, inbox = make_net(loss=1.0)
    for _ in range(7):
        net.send("a", "b", "x")
    sched.run_until(1.0)
    s = net.stats()
    assert s["sent"] == 7 and s["delivered"] == 0
    assert s["dropped"]["loss"] == 7 and len(inbox) == 0


def test_partition_mid_flight_counts_as_partition():
    sched, net, inbox = make_net(delay_min=1.0, delay_max=1.0)
    net.send("a", "b", "slow")
    net.partition({"a"}, {"b"})  # cut while the message is in transit
    sched.run_until(5.0)
    assert net.stats()["dropped"]["partition"] == 1
    assert len(inbox) == 0


def test_drop_and_delay_policies_are_deterministic():
    sched, net, inbox = make_net()
    net.set_drop_policy(lambda src, dst, msg, now: msg == "lose-me")
    net.set_delay_policy(lambda src, dst, msg, now: 2.5)
    net.send("a", "b", "lose-me")
    net.send("a", "b", "keep-me")
    sched.run_until(2.0)
    assert len(inbox) == 0, "pinned delay: not delivered yet"
    sched.run_until(3.0)
    assert [m for _, _, m in inbox] == ["keep-me"]
    s = net.stats()
    assert s["dropped"]["policy"] == 1 and s["delivered"] == 1


def test_duplicate_delivery_inflates_delivered():
    sched, net, inbox = make_net(duplicate=1.0)
    net.send("a", "b", "twin")
    sched.run_until(1.0)
    s = net.stats()
    assert s["sent"] == 1 and s["delivered"] == 2 and len(inbox) == 2
