"""Proposer flow (§3 steps 1/3/5, §6, §7) + the proof-critical ordering."""
import pytest

from repro.configs import CellConfig
from repro.core import build_cell
from repro.core.ballot import Ballot
from repro.core.messages import (
    Answer,
    Lease,
    PrepareRequest,
    PrepareResponse,
    Proposal,
    ProposeRequest,
    ProposeResponse,
)
from repro.core.proposer import Proposer

CFG = CellConfig(n_acceptors=3, max_lease_time=60.0, lease_timespan=10.0)


class Recorder:
    """Instrumented proposer harness recording the order of externally
    visible actions (timer starts vs. sends)."""

    def __init__(self, cfg=CFG):
        self.log = []
        self.timers = []

        class H:
            def __init__(h):
                h.cancelled = False

            def cancel(h):
                h.cancelled = True

        def set_timer(d, fn):
            self.log.append(("timer", d))
            h = H()
            self.timers.append((h, d, fn))
            return h

        def send(dst, msg):
            self.log.append(("send", dst, type(msg).__name__))

        self.p = Proposer(
            1, ["a0", "a1", "a2"], cfg,
            set_timer=set_timer, send=send, random_backoff=lambda lo, hi: lo,
        )


def test_two_round_trips_and_timer_before_propose():
    r = Recorder()
    r.p.acquire("R")
    # round 1: prepare to all acceptors
    prepares = [e for e in r.log if e[0] == "send" and e[2] == "PrepareRequest"]
    assert len(prepares) == 3
    ballot = r.p._state("R").round.ballot
    # two empty prepare responses = majority of 3
    r.log.clear()
    r.p.on_prepare_response(PrepareResponse("R", ballot, Answer.ACCEPT, None), "a0")
    assert not [e for e in r.log if e[0] == "send"], "must wait for majority"
    r.p.on_prepare_response(PrepareResponse("R", ballot, Answer.ACCEPT, None), "a1")
    # CRITICAL (§4 / Fig 2): own lease timer starts BEFORE propose broadcast
    kinds = [e[0] for e in r.log]
    first_send = kinds.index("send")
    assert "timer" in kinds[:first_send], f"timer must precede sends: {r.log}"
    proposes = [e for e in r.log if e[0] == "send" and e[2] == "ProposeRequest"]
    assert len(proposes) == 3
    # majority of propose accepts -> owner
    assert not r.p.is_owner("R")
    r.p.on_propose_response(ProposeResponse("R", ballot, Answer.ACCEPT), "a0")
    r.p.on_propose_response(ProposeResponse("R", ballot, Answer.ACCEPT), "a2")
    assert r.p.is_owner("R")


def test_duplicate_responses_not_double_counted():
    r = Recorder()
    r.p.acquire("R")
    ballot = r.p._state("R").round.ballot
    for _ in range(5):  # same acceptor, duplicated network
        r.p.on_prepare_response(PrepareResponse("R", ballot, Answer.ACCEPT, None), "a0")
    assert r.p._state("R").round.phase == "preparing", "one acceptor is not a majority"


def test_nonempty_prepare_blocks_non_owner():
    r = Recorder()
    r.p.acquire("R")
    ballot = r.p._state("R").round.ballot
    other = Proposal(Ballot(1, 0, 9), Lease(9, 10.0))
    r.p.on_prepare_response(PrepareResponse("R", ballot, Answer.ACCEPT, other), "a0")
    r.p.on_prepare_response(PrepareResponse("R", ballot, Answer.ACCEPT, other), "a1")
    r.p.on_prepare_response(PrepareResponse("R", ballot, Answer.ACCEPT, other), "a2")
    assert r.p._state("R").round.phase == "preparing"  # never proposed


def test_extend_counts_own_unexpired_proposal():
    r = Recorder()
    r.p.acquire("R")
    st = r.p._state("R")
    b1 = st.round.ballot
    for a in ("a0", "a1"):
        r.p.on_prepare_response(PrepareResponse("R", b1, Answer.ACCEPT, None), a)
    for a in ("a0", "a1"):
        r.p.on_propose_response(ProposeResponse("R", b1, Answer.ACCEPT), a)
    assert r.p.is_owner("R")
    # renewal round: acceptors now hold OUR proposal
    r.p._renew("R")
    b2 = st.round.ballot
    assert b2 > b1
    mine = Proposal(b1, Lease(1, 10.0))
    r.p.on_prepare_response(PrepareResponse("R", b2, Answer.ACCEPT, mine), "a0")
    r.p.on_prepare_response(PrepareResponse("R", b2, Answer.ACCEPT, mine), "a1")
    assert st.round.phase == "proposing"  # counted as open (§6)


def test_release_switches_state_before_sending():
    r = Recorder()
    r.p.acquire("R")
    st = r.p._state("R")
    b1 = st.round.ballot
    for a in ("a0", "a1"):
        r.p.on_prepare_response(PrepareResponse("R", b1, Answer.ACCEPT, None), a)
    for a in ("a0", "a1"):
        r.p.on_propose_response(ProposeResponse("R", b1, Answer.ACCEPT), a)
    assert r.p.is_owner("R")
    r.log.clear()
    r.p.release("R")
    assert not r.p.is_owner("R")
    rel = [e for e in r.log if e[0] == "send" and e[2] == "Release"]
    assert len(rel) == 3


def test_reject_majority_aborts_and_jumps_ballot():
    r = Recorder()
    r.p.acquire("R")
    st = r.p._state("R")
    b1 = st.round.ballot
    high = Ballot(40, 0, 9)
    r.p.on_prepare_response(PrepareResponse("R", b1, Answer.REJECT, None, promised=high), "a0")
    r.p.on_prepare_response(PrepareResponse("R", b1, Answer.REJECT, None, promised=high), "a1")
    assert r.p.stats["aborted"] == 1
    # fire the backoff retry timer manually
    retry = [t for t in r.timers if not t[0].cancelled][-1]
    retry[2]()
    assert st.round.ballot > high


def test_failed_extend_fast_retry_clamped_inside_lease_window():
    """A failed-extend fast retry (backoff/4) scheduled AFTER the guarded
    lease timer fires silently converts the extend into a cold acquire and
    a handoff. With a local clock wired in, the retry is clamped to half
    of what is left of our own lease window."""
    clock = [0.0]
    cfg = CellConfig(n_acceptors=3, max_lease_time=60.0, lease_timespan=10.0,
                     backoff_min=32.0, backoff_max=48.0)
    r = Recorder(cfg)
    r.p._local_now = lambda: clock[0]

    r.p.acquire("R")
    st = r.p._state("R")
    b1 = st.round.ballot
    for a in ("a0", "a1"):
        r.p.on_prepare_response(PrepareResponse("R", b1, Answer.ACCEPT, None), a)
    for a in ("a0", "a1"):
        r.p.on_propose_response(ProposeResponse("R", b1, Answer.ACCEPT), a)
    assert r.p.is_owner("R")
    assert st.owner_deadline == pytest.approx(10.0)  # minted at step 3

    # 4s into the lease, the renewal round's prepares are reject-majoritied
    clock[0] = 4.0
    r.p._renew("R")
    b2 = st.round.ballot
    r.log.clear()
    high = Ballot(40, 0, 9)
    for a in ("a0", "a1"):
        r.p.on_prepare_response(
            PrepareResponse("R", b2, Answer.REJECT, None, promised=high), a)
    assert r.p.stats["aborted"] == 1 and r.p.is_owner("R")
    (_, delay), = [e for e in r.log if e[0] == "timer"]
    # backoff_min/4 = 8s would land at t=12, after the guarded expiry at
    # t=10; the clamp pulls it to half the remaining window instead
    assert delay == pytest.approx((st.owner_deadline - clock[0]) / 2) == 3.0
    assert delay < cfg.backoff_min / 4
    # the retry still runs and opens a fresh round past the seen ballot
    retry = [t for t in r.timers if not t[0].cancelled][-1]
    retry[2]()
    assert st.round.ballot > high


def test_failed_extend_fast_retry_unclamped_without_local_clock():
    """Negative control: no local clock wired in — the fast retry is the
    bare backoff/4, which can outlive the lease window (the old bug)."""
    cfg = CellConfig(n_acceptors=3, max_lease_time=60.0, lease_timespan=10.0,
                     backoff_min=32.0, backoff_max=48.0)
    r = Recorder(cfg)
    r.p.acquire("R")
    st = r.p._state("R")
    b1 = st.round.ballot
    for a in ("a0", "a1"):
        r.p.on_prepare_response(PrepareResponse("R", b1, Answer.ACCEPT, None), a)
    for a in ("a0", "a1"):
        r.p.on_propose_response(ProposeResponse("R", b1, Answer.ACCEPT), a)
    assert st.owner_deadline is None  # no clock, no guarded deadline
    r.p._renew("R")
    b2 = st.round.ballot
    r.log.clear()
    high = Ballot(40, 0, 9)
    for a in ("a0", "a1"):
        r.p.on_prepare_response(
            PrepareResponse("R", b2, Answer.REJECT, None, promised=high), a)
    (_, delay), = [e for e in r.log if e[0] == "timer"]
    assert delay == pytest.approx(cfg.backoff_min / 4)  # 8s > lease remnant


def test_t_less_than_m_enforced():
    r = Recorder()
    with pytest.raises(AssertionError):
        r.p.acquire("R", timespan=999.0)


def test_in_sim_two_rtt_acquisition():
    """Fig 2: in a clean network the lease is held after ~2 RTTs."""
    cfg = CellConfig(n_acceptors=5, max_lease_time=60.0, lease_timespan=10.0)
    from repro.sim.network import NetConfig

    cell = build_cell(cfg, n_proposers=1, seed=0,
                      net=NetConfig(delay_min=0.05, delay_max=0.05))
    cell.proposers[0].proposer.acquire()
    cell.env.run_until(0.19)
    assert cell.monitor.owner_of("R") is None  # < 2 RTT: not yet possible
    cell.env.run_until(0.21)  # 2 RTT = 0.2s
    assert cell.monitor.owner_of("R") == 0
