"""Asymmetric per-(proposer, acceptor) link matrices and in-flight §7
releases, differentially vs the event sim: every leg sent at tick t on
the (p, a) link takes delay[t, p, a] ticks and is lost iff drop[t, p, a]
— including a release's discard legs, which now ride the netplane instead
of bypassing it. Exact-match construction in repro/lease_array/trace.py."""
import numpy as np
import pytest

from repro.lease_array import NO_PROPOSER, Trace, random_trace, replay_array

from test_lease_array_differential import assert_engines_agree

NA = NO_PROPOSER


def _hand_trace(n_ticks, *, n_cells=1, n_acceptors=3, n_proposers=2,
                lease_ticks=6, round_ticks=1):
    """All-quiet asymmetric trace skeleton to write schedules into."""
    return Trace(
        n_cells, n_acceptors, n_proposers, lease_ticks,
        attempts=np.full((n_ticks, n_cells), NA, np.int32),
        releases=np.full((n_ticks, n_cells), NA, np.int32),
        acc_up=np.ones((n_ticks, n_acceptors), bool),
        delay=np.zeros((n_ticks, n_proposers, n_acceptors), np.int32),
        drop=np.zeros((n_ticks, n_proposers, n_acceptors), bool),
        round_ticks=round_ticks,
    )


# ---------------------------------------------------------------- randomized
@pytest.mark.slow
def test_thousand_tick_asymmetric_trace():
    """Acceptance: a 1000-tick trace with non-trivial [T, P, A] delay/drop
    planes replays bit-exactly through both engines."""
    trace = random_trace(
        4242,
        n_ticks=1000,
        n_cells=8,
        n_acceptors=5,
        n_proposers=4,
        lease_ticks=8,
        p_attempt=0.9,
        p_release=0.06,
        p_down_flip=0.02,
        max_delay_ticks=1,
        p_drop=0.05,
        asymmetric=True,
        round_ticks=3,
    )
    assert trace.delay.shape == (1000, 4, 5) and trace.delayed
    # genuinely asymmetric: some tick has two proposers seeing different links
    assert (trace.delay.max(axis=1) != trace.delay.min(axis=1)).any()
    owners = assert_engines_agree(trace)
    assert (owners >= 0).any() and (owners == -1).any()
    assert float((owners >= 0).mean()) > 0.1


@pytest.mark.parametrize(
    "seed,n_acceptors,n_proposers,lease_ticks,max_delay",
    [(21, 3, 2, 4, 1), (22, 5, 6, 6, 3), (23, 7, 3, 5, 2)],
)
def test_asymmetric_geometry_sweep(seed, n_acceptors, n_proposers, lease_ticks, max_delay):
    trace = random_trace(
        seed,
        n_ticks=150,
        n_cells=8,
        n_acceptors=n_acceptors,
        n_proposers=n_proposers,
        lease_ticks=lease_ticks,
        p_attempt=0.6,
        p_release=0.1,
        p_down_flip=0.05,
        max_delay_ticks=max_delay,
        p_drop=0.1,
        asymmetric=True,
    )
    assert_engines_agree(trace)


def test_asymmetric_through_pallas_kernel():
    trace = random_trace(
        31, n_ticks=80, n_cells=12, n_acceptors=5, n_proposers=4,
        lease_ticks=4, max_delay_ticks=2, p_drop=0.05, p_down_flip=0.03,
        asymmetric=True,
    )
    jnp_owners, jnp_counts = replay_array(trace, backend="jnp")
    pal_owners, pal_counts = replay_array(trace, backend="pallas")
    assert np.array_equal(jnp_owners, pal_owners)
    assert np.array_equal(jnp_counts, pal_counts)
    assert_engines_agree(trace, backend="pallas")


# ------------------------------------------------------------- structured
def test_straggler_proposer_loses_contended_cell():
    """Per-proposer asymmetry the old [T, A] planes could not express: p0's
    links lag 2 ticks everywhere, p1's are instant — attempting one tick
    apart on the same cell, the slow proposer's round is overtaken."""
    tr = _hand_trace(12, n_proposers=2, lease_ticks=4, round_ticks=6)
    tr.delay[:, 0, :] = 2  # p0 is behind a straggler uplink
    tr.attempts[0, 0] = 0  # p0 starts first...
    tr.attempts[1, 0] = 1  # ...p1 starts a tick later, with a higher ballot
    owners = assert_engines_agree(tr)
    # p1's instant round wins at its attempt tick; p0's responses come back
    # to an already-raised promise floor and never assemble a quorum
    assert owners[1, 0] == 1
    assert (owners[1:5, 0] == 1).all()
    assert not (owners[:, 0] == 0).any()


def test_release_discard_is_delayed_through_netplane():
    """§7 discards ride the in-flight plane: the releasing owner stops
    believing immediately, but acceptors keep the accepted lease until the
    discard leg lands — a contender in that window still finds the cell
    taken, in BOTH engines."""
    tr = _hand_trace(10)
    tr.attempts[0, 0] = 0          # p0 acquires instantly at t=0
    tr.releases[2, 0] = 0          # p0 releases at t=2 ...
    tr.delay[2, 0, :] = 3          # ... but its discard legs take 3 ticks
    tr.attempts[3, 0] = 1          # p1 probes inside the in-flight window
    tr.attempts[6, 0] = 1          # and again once the discards have landed
    owners = assert_engines_agree(tr)
    col = owners[:, 0]
    assert col[0] == 0 and col[1] == 0      # owned by p0
    assert (col[2:6] == NA).all()           # released locally at t=2; p1's
                                            # t=3 probe hits undischarged state
    assert (col[6:] == 1).all()             # discards landed at t=5 -> p1 wins
    assert col[6:].size > 0


def test_dropped_release_keeps_lease_until_expiry():
    """A fully dropped release discards nothing: acceptors hold the lease
    to its natural expiry, and only then can a contender win."""
    tr = _hand_trace(11)
    tr.attempts[0, 0] = 0
    tr.releases[2, 0] = 0
    tr.drop[2, 0, :] = True        # every discard leg is lost
    tr.attempts[4, 0] = 1          # blocked: acceptors still hold p0's lease
    tr.attempts[8, 0] = 1          # lease (t=0, 6 ticks) expired -> wins
    owners = assert_engines_agree(tr)
    col = owners[:, 0]
    assert (col[:2] == 0).all()
    assert (col[2:8] == NA).all()
    assert (col[8:] == 1).all()


def test_release_discard_dropped_at_one_acceptor_only():
    """Asymmetric drop row: one acceptor never hears the discard but the
    other two do — a fresh contender still finds an open majority."""
    tr = _hand_trace(8)
    tr.attempts[0, 0] = 0
    tr.releases[2, 0] = 0
    tr.drop[2, 0, 0] = True        # acc0 keeps p0's stale accepted lease
    tr.attempts[3, 0] = 1          # 2 of 3 opens is a majority -> wins
    owners = assert_engines_agree(tr)
    col = owners[:, 0]
    assert (col[:2] == 0).all() and col[2] == NA
    assert (col[3:] == 1).all()
