"""Training loop (resume, microbatch equivalence) and serving engine."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.models import init_model
from repro.train import Trainer, TrainerConfig
from repro.train.serve import Request, ServeEngine

TINY = dataclasses.replace(
    reduced(get_config("qwen1.5-0.5b")), name="tiny", vocab_size=128
)


def test_trainer_runs_and_checkpoints(tmp_path):
    tc = TrainerConfig(steps=4, batch_size=4, seq_len=32, ckpt_dir=str(tmp_path),
                       ckpt_every=2, log_every=100)
    tr = Trainer(TINY, tc, verbose=False)
    hist = tr.run()
    assert len(hist) == 4
    assert all(np.isfinite(h["loss"]) for h in hist)
    assert tr.ckpt.saved_steps == [2, 4]


def test_trainer_resume_continues_not_restarts(tmp_path):
    tc = TrainerConfig(steps=3, batch_size=4, seq_len=32, ckpt_dir=str(tmp_path),
                       ckpt_every=3, log_every=100)
    Trainer(TINY, tc, verbose=False).run()
    tc2 = dataclasses.replace(tc, steps=5)
    tr2 = Trainer(TINY, tc2, verbose=False)
    assert tr2.step == 3  # resumed, not restarted
    hist = tr2.run()
    assert hist[-1]["step"] == 5


def test_microbatch_accumulation_matches_full_batch():
    tc1 = TrainerConfig(steps=1, batch_size=8, seq_len=32, microbatches=1, seed=5)
    tc2 = TrainerConfig(steps=1, batch_size=8, seq_len=32, microbatches=4, seed=5)
    t1 = Trainer(TINY, tc1, verbose=False)
    t2 = Trainer(TINY, tc2, verbose=False)
    # same data, same init -> updated params must match closely
    t1.run()
    t2.run()
    diffs = jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a - b))), t1.params, t2.params
    )
    assert max(jax.tree.leaves(diffs)) < 2e-4


def test_lease_guard_blocks_checkpoints(tmp_path):
    tc = TrainerConfig(steps=4, batch_size=2, seq_len=16, ckpt_dir=str(tmp_path),
                       ckpt_every=1, log_every=100)
    tr = Trainer(TINY, tc, lease_guard=lambda: False, verbose=False)
    tr.run()
    assert tr.ckpt.saved_steps == []
    assert tr.ckpt.skipped_no_lease == 4


def test_serve_engine_matches_reference_decode():
    cfg = dataclasses.replace(TINY, dtype="float32", param_dtype="float32")
    params = init_model(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, slots=2, max_len=64)
    prompts = [np.array([5, 9, 2], np.int32), np.array([7, 1], np.int32)]
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p, max_new=4))
    done = eng.run_until_drained()
    assert len(done) == 2
    assert all(len(r.out) == 4 for r in done)
    # greedy decode of request 0 alone must agree with a batch-of-1 engine
    eng2 = ServeEngine(cfg, params, slots=1, max_len=64)
    eng2.submit(Request(rid=0, prompt=prompts[0], max_new=4))
    solo = eng2.run_until_drained()[0]
    r0 = next(r for r in done if r.rid == 0)
    assert solo.out == r0.out, "batching must not change greedy outputs"


def test_serve_continuous_batching_frees_slots():
    cfg = dataclasses.replace(TINY, dtype="float32", param_dtype="float32")
    params = init_model(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, slots=1, max_len=64)
    for i in range(3):
        eng.submit(Request(rid=i, prompt=np.array([i + 1], np.int32), max_new=2))
    done = eng.run_until_drained()
    assert sorted(r.rid for r in done) == [0, 1, 2]  # queue drained through 1 slot
