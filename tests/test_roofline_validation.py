"""Cross-validate the analytic FLOPs model against XLA cost_analysis.

cost_analysis counts a scan body once (why the roofline is analytic — see
analysis/roofline.py); on an UNROLLED reduced config the two must agree to
within tolerance. Also checks the scan-undercount factor itself.
"""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.analysis.costs import cost_analysis_dict
from repro.analysis.roofline import flops_fwd, flops_step, model_flops, roofline_terms, MESHES
from repro.configs import ShapeConfig, get_config, reduced
from repro.models import init_model, loss_fn, synth_inputs, transformer


def _compiled_flops(cfg, shape, train: bool):
    batch = synth_inputs(cfg, shape, jax.random.PRNGKey(0))["batch"]
    params = transformer.abstract_model(cfg)
    batch_abs = jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), batch)
    if train:
        fn = lambda p, b: jax.grad(lambda q: loss_fn(cfg, q, b, remat=False)[0])(p)
    else:
        fn = lambda p, b: transformer.forward(cfg, p, b)[0]
    compiled = jax.jit(fn).lower(params, batch_abs).compile()
    return cost_analysis_dict(compiled)["flops"]


@pytest.mark.parametrize("arch", ["qwen1.5-0.5b", "internlm2-1.8b"])
def test_analytic_fwd_flops_vs_unrolled_cost_analysis(arch):
    cfg = dataclasses.replace(
        reduced(get_config(arch)), scan_unroll=True, remat_policy="nothing",
        n_layers=4, vocab_size=512,
    )
    shape = ShapeConfig("t", "prefill", 64, 4)
    got = _compiled_flops(cfg, shape, train=False)
    want = flops_fwd(cfg, shape)
    assert got == pytest.approx(want, rel=0.25), f"analytic {want:.3e} vs HLO {got:.3e}"


def test_scan_undercount_factor_is_n_layers():
    cfg = dataclasses.replace(
        reduced(get_config("qwen1.5-0.5b")), remat_policy="nothing",
        n_layers=8, vocab_size=512,
    )
    shape = ShapeConfig("t", "prefill", 64, 4)
    scanned = _compiled_flops(cfg, shape, train=False)
    unrolled = _compiled_flops(dataclasses.replace(cfg, scan_unroll=True), shape, train=False)
    # per-layer flops dominate at vocab 512, so ratio ~ n_layers
    assert unrolled / scanned > cfg.n_layers / 2


def test_train_flops_roughly_3x_forward():
    cfg = dataclasses.replace(
        reduced(get_config("qwen1.5-0.5b")), scan_unroll=True,
        remat_policy="nothing", n_layers=4, vocab_size=512,
    )
    shape = ShapeConfig("t", "train", 64, 4)
    fwd = _compiled_flops(cfg, ShapeConfig("t", "prefill", 64, 4), train=False)
    train = _compiled_flops(cfg, shape, train=True)
    assert 2.0 < train / fwd < 4.0


def test_model_flops_is_6nd():
    cfg = get_config("granite-3-8b")
    shape = ShapeConfig("t", "train", 4096, 256)
    assert model_flops(cfg, shape) == pytest.approx(
        6 * cfg.matmul_params() * 4096 * 256, rel=1e-9
    )


@pytest.mark.parametrize("mesh", list(MESHES))
def test_roofline_terms_positive_and_classified(mesh):
    for arch, shape_name, kind in [
        ("granite-3-8b", "train_4k", "train"),
        ("kimi-k2-1t-a32b", "decode_32k", "decode"),
    ]:
        cfg = get_config(arch)
        from repro.configs import get_shape

        t = roofline_terms(cfg, get_shape(shape_name), MESHES[mesh])
        assert t["compute_s"] > 0 and t["memory_s"] > 0 and t["collective_s"] > 0
        assert t["dominant"] in ("compute", "memory", "collective")
        assert 0 < t["useful_flops_frac"] <= 1.2
