"""Per-assigned-architecture smoke tests: a REDUCED config of the same
family runs one forward + one train step + decode steps on CPU, asserting
output shapes and finiteness (assignment requirement)."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ShapeConfig, arch_ids, get_config, reduced
from repro.models import decode_step, init_cache, init_model, loss_fn, synth_inputs
from repro.optim import adamw_init, adamw_update

SHAPE = ShapeConfig("smoke", "train", 32, 2)


@pytest.fixture(scope="module")
def key():
    return jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", arch_ids())
def test_forward_train_step(arch, key):
    cfg = reduced(get_config(arch))
    params = init_model(cfg, key)
    batch = synth_inputs(cfg, SHAPE, key)["batch"]

    @jax.jit
    def step(p, o, b):
        (loss, metrics), grads = jax.value_and_grad(
            lambda q: loss_fn(cfg, q, b), has_aux=True
        )(p)
        p, o, _ = adamw_update(p, grads, o, lr=1e-3)
        return p, o, loss

    opt = adamw_init(params)
    params2, opt2, loss = step(params, opt, batch)
    assert jnp.isfinite(loss), f"{arch}: non-finite loss"
    # params actually changed
    delta = jax.tree.reduce(
        lambda a, b: a + b,
        jax.tree.map(lambda x, y: float(jnp.abs(x - y).sum()), params, params2),
    )
    assert delta > 0, f"{arch}: train step was a no-op"


@pytest.mark.parametrize("arch", arch_ids())
def test_decode_steps(arch, key):
    cfg = reduced(get_config(arch))
    params = init_model(cfg, key)
    b = 2
    cache = init_cache(cfg, b, 16)
    if cfg.enc_dec:  # decoder needs cross K/V from a (stub) encoder pass
        from repro.models import forward

        batch = synth_inputs(cfg, ShapeConfig("x", "train", 8, b), key)["batch"]
        _, c2, _ = forward(cfg, params, batch, emit_cache=True)
        cache["ck"], cache["cv"] = c2["ck"], c2["cv"]
    step = jax.jit(lambda p, c, t, pos: decode_step(cfg, p, c, t, pos))
    toks = jnp.zeros((b, 1), jnp.int32)
    for i in range(4):
        logits, cache = step(params, cache, toks, jnp.int32(i))
        toks = jnp.argmax(logits[:, :, :50], axis=-1).astype(jnp.int32)
    assert logits.shape == (b, 1, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))


def test_param_counts_match_published_sizes():
    expected = {  # billions, tolerance 12% (embeddings/bias conventions vary)
        "internlm2-1.8b": 1.89,
        "granite-3-8b": 8.37,
        "qwen1.5-0.5b": 0.62,
        "starcoder2-15b": 16.0,
        "whisper-large-v3": 1.6,
        "hymba-1.5b": 1.6,
        "mixtral-8x22b": 141.0,
        "kimi-k2-1t-a32b": 1041.0,
        "rwkv6-3b": 3.1,
        "internvl2-2b": 1.9,
    }
    for arch, exp in expected.items():
        n = get_config(arch).n_params() / 1e9
        assert abs(n - exp) / exp < 0.12, f"{arch}: {n:.2f}B vs expected {exp}B"


def test_kimi_active_params_match_a32b():
    n_act = get_config("kimi-k2-1t-a32b").n_params(active=True) / 1e9
    assert 25 < n_act < 40, n_act  # "a32b"
