"""Elastic autoscaling: membership drives shard targets; leases keep it safe."""
from repro.cluster.autoscale import AutoscaleController
from repro.cluster.coordinator import build_coordinated_cluster
from repro.cluster.membership import HeartbeatSender, MembershipTracker
from repro.cluster.shards import ShardLeaseManager
from repro.configs import CellConfig
from repro.sim.network import NetConfig

NET = NetConfig(delay_min=0.005, delay_max=0.03)
CFG = CellConfig(n_acceptors=3, max_lease_time=30.0, lease_timespan=4.0,
                 backoff_min=0.1, backoff_max=0.4)


def _settle(cell, cond, t_max):
    while cell.env.now < t_max and not cond():
        cell.env.run_until(cell.env.now + 1.0)


def test_autoscale_rebalances_on_join_and_silence():
    cell, coord = build_coordinated_cluster(CFG, n_workers=3, seed=5, net=NET)
    master_node = cell.nodes[0]
    coord.campaign(master_node)
    mgr = ShardLeaseManager(cell, n_shards=6, shard_timespan=3.0, scan_period=0.4)
    tracker = MembershipTracker(cell.env, master_node.addr, suspect_after=4.0)
    cell.env.network._handlers[master_node.addr + ":hb"] = lambda m, s: tracker.on_heartbeat(m)

    workers, senders = [], []
    for i in range(2):  # start with two workers
        node = cell.proposers[3 + i]
        workers.append(mgr.add_worker(node, target=0))
        senders.append(HeartbeatSender(cell.env, node.addr, node.node_id,
                                       [master_node.addr + ":hb"], period=1.0))
    AutoscaleController(cell, mgr, tracker, master_node=master_node, period=1.0)

    _settle(cell, lambda: mgr.coverage() == 1.0, 30.0)
    assert mgr.coverage() == 1.0
    assert all(w.target == 3 for w in workers)  # 6 shards / 2 workers

    # a third worker joins: targets drop to ceil(6/3)=2 and it picks up shards
    node3 = cell.proposers[5]
    w3 = mgr.add_worker(node3, target=0)
    senders.append(HeartbeatSender(cell.env, node3.addr, node3.node_id,
                                   [master_node.addr + ":hb"], period=1.0))
    _settle(cell, lambda: len(w3.owned) >= 1 and mgr.coverage() == 1.0, cell.env.now + 40.0)
    assert w3.owned and mgr.coverage() == 1.0
    assert all(w.target == 2 for w in [*workers, w3])

    # worker 0 goes silent: suspected -> target 0; survivors absorb its shards
    senders[0].stop()
    mgr.stall(workers[0].node.node_id)
    _settle(cell, lambda: mgr.coverage() == 1.0 and not workers[0].owned,
            cell.env.now + 60.0)
    assert workers[0].target == 0
    assert mgr.coverage() == 1.0 and not workers[0].owned
    cell.monitor.assert_clean()
