"""§7 release hints: advisory wake-ups make handoff faster, never less safe."""
from repro.configs import CellConfig
from repro.core import build_cell
from repro.sim.network import NetConfig

NET = NetConfig(delay_min=0.01, delay_max=0.02)
# large backoff so the hint's fast path is clearly distinguishable
CFG = CellConfig(n_acceptors=5, max_lease_time=60.0, lease_timespan=10.0,
                 backoff_min=3.0, backoff_max=4.0)


def _handoff_time(hints_enabled: bool) -> float:
    cell = build_cell(CFG, n_proposers=2, seed=1, net=NET)
    if not hints_enabled:
        for n in cell.proposers:
            n.proposer.hint_addrs = []
    p0, p1 = (n.proposer for n in cell.proposers[:2])
    p0.acquire(renew=False)
    cell.env.run_until(1.0)
    assert p0.is_owner()
    p1.acquire()  # blocked: p0 holds it; p1 backs off 3-4s between rounds
    cell.env.run_until(2.0)
    t0 = cell.env.now
    p0.release()
    cell.env.run_until(t0 + 8.0)
    gained = [t for t in cell.monitor.acquire_times if t > t0]
    cell.monitor.assert_clean()
    assert gained, "p1 must eventually take the released lease"
    return min(gained) - t0


def test_release_hint_wakes_waiter_early():
    with_hints = _handoff_time(True)
    without = _handoff_time(False)
    assert with_hints < 0.5, f"hinted handoff should be ~2 RTT, got {with_hints:.2f}s"
    assert without > 1.0, f"unhinted handoff waits out the backoff, got {without:.2f}s"


def test_hints_never_grant_ownership():
    """A hint alone must not make anyone an owner — the rounds still decide."""
    from repro.core.messages import LearnHint

    cell = build_cell(CFG, n_proposers=2, seed=2, net=NET)
    p1 = cell.proposers[1].proposer
    # spurious hint for a resource p1 never asked for: no effect at all
    p1.on_hint(LearnHint("R", 0, "released"), "node0")
    cell.env.run_until(1.0)
    assert not p1.is_owner()
    assert cell.monitor.owner_of("R") is None
