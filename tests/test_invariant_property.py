"""Property tests of the §4 lease invariant under adversarial conditions.

hypothesis drives: network loss/duplication/delay/stragglers, contention
level, crash/restart schedules (with the M-wait rule), lease timespans and
multi-resource workloads. The monitor (strict) raises on any overlap of
ownership intervals — running to completion IS the proof check.
"""
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis (requirements-dev.txt)")
from hypothesis import HealthCheck, given, settings, strategies as st

from repro.configs import CellConfig
from repro.core import build_cell
from repro.sim.network import NetConfig

FAST = dict(
    suppress_health_check=[HealthCheck.too_slow],
    deadline=None,
    max_examples=30,
)


@st.composite
def net_configs(draw):
    dmin = draw(st.floats(0.001, 0.05))
    return NetConfig(
        delay_min=dmin,
        delay_max=dmin + draw(st.floats(0.0, 0.3)),
        loss=draw(st.floats(0.0, 0.4)),
        duplicate=draw(st.floats(0.0, 0.3)),
        jitter_tail=draw(st.floats(0.0, 0.05)),
        tail_delay=draw(st.floats(1.0, 20.0)),
    )


@settings(**FAST)
@given(
    net=net_configs(),
    seed=st.integers(0, 10_000),
    n_prop=st.integers(2, 5),
    timespan=st.floats(2.0, 20.0),
)
def test_invariant_under_contention_and_bad_network(net, seed, n_prop, timespan):
    cfg = CellConfig(n_acceptors=5, max_lease_time=30.0,
                     lease_timespan=min(timespan, 29.0))
    cell = build_cell(cfg, n_proposers=n_prop, seed=seed, net=net)
    for p in cell.proposers:
        p.proposer.acquire()
    cell.env.run_until(150.0)
    cell.monitor.assert_clean()  # strict monitor would already have raised


@settings(**FAST)
@given(
    seed=st.integers(0, 10_000),
    crashes=st.lists(
        st.tuples(st.floats(1.0, 80.0), st.integers(0, 4), st.floats(0.1, 30.0)),
        min_size=1, max_size=6,
    ),
)
def test_invariant_under_crash_restart_with_m_wait(seed, crashes):
    """Acceptor nodes crash at arbitrary times and restart after arbitrary
    downtime; the M-wait rule is enforced by LeaseNode. Invariant must hold."""
    cfg = CellConfig(n_acceptors=5, max_lease_time=25.0, lease_timespan=8.0)
    cell = build_cell(cfg, n_proposers=3, seed=seed,
                      net=NetConfig(delay_min=0.005, delay_max=0.1, loss=0.1))
    for p in cell.proposers:
        p.proposer.acquire()
    events = sorted(crashes)
    t_cursor = 0.0
    for t, node_i, downtime in events:
        cell.env.run_until(t)
        node = cell.nodes[node_i]
        if not node.crashed:
            node.crash()
            cell.env.sched.after(downtime, node.restart)
        t_cursor = t
    cell.env.run_until(t_cursor + 120.0)
    cell.monitor.assert_clean()


@settings(**FAST)
@given(seed=st.integers(0, 10_000), n_res=st.integers(2, 8))
def test_invariant_multi_resource(seed, n_res):
    """§8: independent instances per resource; cross-resource interference
    must not exist."""
    cfg = CellConfig(n_acceptors=3, max_lease_time=20.0, lease_timespan=5.0)
    cell = build_cell(cfg, n_proposers=3, seed=seed,
                      net=NetConfig(delay_min=0.01, delay_max=0.1, loss=0.15))
    for j, p in enumerate(cell.proposers):
        for r in range(n_res):
            if (r + j) % 2 == 0:
                p.proposer.acquire(f"res:{r}")
    cell.env.run_until(60.0)
    cell.monitor.assert_clean()
    owners = {r: cell.monitor.owner_of(f"res:{r}") for r in range(n_res)}
    assert any(o is not None for o in owners.values())


@settings(**FAST)
@given(seed=st.integers(0, 10_000))
def test_partition_heals_without_violation(seed):
    """Network split (§1 failure 2): minority side cannot acquire; after
    healing exactly one owner exists."""
    cfg = CellConfig(n_acceptors=5, max_lease_time=20.0, lease_timespan=6.0)
    cell = build_cell(cfg, n_proposers=5, seed=seed,
                      net=NetConfig(delay_min=0.01, delay_max=0.05))
    for p in cell.proposers:
        p.proposer.acquire()
    cell.env.run_until(10.0)
    majority = {cell.nodes[i].addr for i in range(3)}
    minority = {cell.nodes[i].addr for i in range(3, 5)}
    cell.env.network.partition(minority, majority)
    cell.env.run_until(40.0)
    owner = cell.monitor.owner_of("R")
    if owner is not None:
        assert owner in range(0, 3), "minority-side proposer cannot hold the lease"
    cell.env.network.heal()
    cell.env.run_until(80.0)
    cell.monitor.assert_clean()
    assert cell.monitor.owner_of("R") is not None


def test_liveness_eventually_acquires_under_duel():
    """§5: randomized backoff breaks dynamic deadlock (statistical check)."""
    cfg = CellConfig(n_acceptors=3, max_lease_time=20.0, lease_timespan=5.0,
                     backoff_min=0.2, backoff_max=1.5)
    cell = build_cell(cfg, n_proposers=2, seed=123,
                      net=NetConfig(delay_min=0.01, delay_max=0.03))
    for p in cell.proposers:
        p.proposer.acquire()
    cell.env.run_until(60.0)
    assert cell.monitor.total_owned_time("R") > 30.0  # held most of the time
