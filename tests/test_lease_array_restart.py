"""Array-plane crash/restart injection (the ISSUE 9 tentpole contracts).

The two registry planes — ``acc_restart [T, A]`` (diskless acceptor
restart: blank + deaf for M local quarter-ticks, §3) and ``prop_restart
[T, P]`` (proposer restart-counter bump carved into the packed ballot,
§2) — must: reproduce the pre-restart engine bit-for-bit when all-default
(stripped host-side, never uploaded); replay bit-exactly against the
event-sim referee under crash + drift + delay + drop on BOTH backends;
trip the §4 owner-count-2 alarm when the deaf window is disabled (the
M-wait negative control) while a guarded ≥1024-scenario sweep stays
violation-free in a single dispatch; and refuse schedules the packed
restart-counter carve cannot represent."""
import numpy as np
import pytest

from repro.lease_array import LeaseArrayEngine, Scenario
from repro.lease_array.scenario import RESTART_PLANES
from repro.lease_array.state import (
    MAX_RESTARTS,
    check_pack_budget,
    max_pack_tick,
)
from repro.lease_array.trace import (
    Trace,
    random_trace,
    replay_array,
    replay_event_sim,
    trace_from_scenario,
)

BACKENDS = ["jnp", "pallas"]

#: the chaos-family fault mix every differential below draws from
CHAOS = dict(
    n_ticks=80, n_cells=4, n_acceptors=3, n_proposers=4, lease_ticks=3,
    max_delay_ticks=2, p_drop=0.05, restarts=0.02,
)


def _engine(trace: Trace, backend="jnp", **kw) -> LeaseArrayEngine:
    return LeaseArrayEngine(
        trace.n_cells, n_acceptors=trace.n_acceptors,
        n_proposers=trace.n_proposers, lease_ticks=trace.lease_ticks,
        round_ticks=trace.round_ticks, drift_eps=trace.drift_eps,
        backend=backend, **kw,
    )


# ------------------------------------------------------- all-default planes

def test_all_default_restart_planes_bit_identical():
    """A scenario whose registry-filled restart planes are all zero is the
    pre-restart engine: same bits, and the engine never enters restart
    mode (the planes are stripped host-side, not uploaded — no restart
    ballot carve, no deaf/counter streams in the dispatch)."""
    tr = random_trace(3, max_delay_ticks=1, p_drop=0.05, drift_eps=0.25,
                      **{k: v for k, v in CHAOS.items()
                         if k not in ("max_delay_ticks", "p_drop", "restarts")})
    base_ow, base_cn = replay_array(tr)
    sc = tr.scenario()
    assert all(k in sc.planes for k in RESTART_PLANES)  # registry-filled
    assert not sc.restarted
    eng = _engine(tr)
    ow, cn = eng.run_trace(sc)
    assert np.array_equal(np.asarray(ow), np.asarray(base_ow))
    assert np.array_equal(np.asarray(cn), np.asarray(base_cn))
    assert eng._restart_active is False  # zero uploads: mode never latched

    stacked = Scenario(
        {k: np.asarray(v)[None] for k, v in sc.planes.items()}
    )
    res = eng.sweep(stacked, collect="owners")
    assert eng._restart_active is False
    assert np.array_equal(np.asarray(res.owners[0]), np.asarray(base_ow))


# ------------------------------------- differential replay vs the referee

@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_crash_restart_differential_vs_referee(seed):
    """Randomized crash + drift + delay + drop traces: the event-driven
    referee and the array plane agree bit-for-bit on every believed-owner
    bit, and §4 holds throughout."""
    tr = random_trace(
        seed, drift_eps=0.25 if seed % 2 else 0.0,
        asymmetric=bool(seed % 2), **CHAOS,
    )
    assert tr.restarted  # the fault family is actually exercised
    ref = replay_event_sim(tr)
    ow, cn = replay_array(tr)
    assert np.array_equal(ref, np.asarray(ow))
    assert int(np.max(np.asarray(cn))) <= 1


@pytest.mark.parametrize("backend", BACKENDS)
def test_restart_trace_backends_bit_identical(backend):
    """The restart-mode dispatch (blanking, deaf gating, counter-carved
    ballots) is backend-independent: jnp scan and the fused Pallas window
    kernel produce identical owners/counts."""
    tr = random_trace(5, drift_eps=0.25, asymmetric=True, **CHAOS)
    ref_ow, ref_cn = replay_array(tr, backend="jnp")
    ow, cn = replay_array(tr, backend=backend)
    assert np.array_equal(np.asarray(ow), np.asarray(ref_ow))
    assert np.array_equal(np.asarray(cn), np.asarray(ref_cn))


@pytest.mark.slow
def test_1000_tick_crash_drift_delay_drop_differential():
    """ISSUE 9 acceptance: a 1000-tick randomized trace combining
    restarts, drifting clocks, link delays and drops replays bit-exactly
    against the event-sim referee on both backends."""
    tr = random_trace(
        42, n_ticks=1000, max_delay_ticks=2, p_drop=0.05,
        drift_eps=0.25, asymmetric=True, restarts=0.02,
    )
    assert tr.restarted
    ref = replay_event_sim(tr)
    for backend in BACKENDS:
        ow, cn = replay_array(tr, backend=backend)
        assert np.array_equal(ref, np.asarray(ow)), backend
        assert int(np.max(np.asarray(cn))) <= 1, backend


# -------------------------------------------- the §4 deaf-window controls

def _m_wait_trace() -> Trace:
    """Proposer 0 acquires everywhere; every acceptor crash-restarts
    mid-lease at tick 2 (blank majority); proposer 1 attacks at tick 3
    while p0's guarded belief is still live — the §3 M-wait showdown."""
    T, N, A, P = 10, 4, 5, 4
    att = np.full((T, N), -1, np.int32)
    att[0, :] = 0
    att[3, :] = 1
    rst = np.zeros((T, A), np.int32)
    rst[2, :] = 1
    return Trace(
        N, A, P, 4, att, np.full((T, N), -1, np.int32),
        np.ones((T, A), bool), acc_restarts=rst,
    )


@pytest.mark.parametrize("backend", BACKENDS)
def test_unguarded_restart_trips_owner_alarm(backend):
    """The negative control: with the deaf window disabled
    (``restart_guard=False``) the blank-restarted majority grants the
    rival a second live lease — owner count 2, the exact violation
    ``tests/test_restart_m.py`` demonstrates on the event engine."""
    ow, cn = replay_array(_m_wait_trace(), backend=backend,
                          restart_guard=False)
    assert int(np.max(np.asarray(cn))) == 2


@pytest.mark.parametrize("backend", BACKENDS)
def test_guarded_restart_holds_and_matches_referee(backend):
    """The guarded twin: same schedule, deaf window on — §4 holds and the
    event-sim referee agrees on every owner bit."""
    tr = _m_wait_trace()
    ow, cn = replay_array(tr, backend=backend)
    assert int(np.max(np.asarray(cn))) <= 1
    assert np.array_equal(replay_event_sim(tr), np.asarray(ow))


def test_guarded_sweep_1024_restart_scenarios_single_dispatch():
    """ISSUE 9 acceptance: >= 1024 random restart scenarios sweep through
    ONE vmapped dispatch with the built-in §4 verification on, and none
    violates."""
    from repro.lease_array.falsify import FalsifyConfig, random_population

    cfg = FalsifyConfig(restarts=True, pop_size=1024, seed=3,
                        p_restart=0.08)
    planes = random_population(np.random.default_rng(3), cfg)
    assert planes["acc_restart"].any() and planes["prop_restart"].any()
    res = cfg.engine().sweep(Scenario(planes))  # verify=True: raises on §4
    assert res.max_owner_count.shape == (1024,)
    assert (np.asarray(res.max_owner_count) <= 1).all()


# ------------------------------------------- S2: scenario -> trace triage

def _restart_scenario(acc_val=1, prop_hits=1):
    T, N, A, P = 12, 2, 3, 4
    att = np.full((T, N), -1, np.int32)
    att[0, :] = 0
    arst = np.zeros((T, A), np.int32)
    arst[4, 1] = acc_val
    prst = np.zeros((T, P), np.int32)
    prst[2:2 + prop_hits, 0] = 1
    return Scenario.build(
        T, n_cells=N, n_acceptors=A, n_proposers=P,
        attempts=att, acc_restart=arst, prop_restart=prst,
    )


def test_trace_from_scenario_refuses_multi_restart_ticks():
    with pytest.raises(ValueError, match="binary restart"):
        trace_from_scenario(_restart_scenario(acc_val=2), lease_ticks=2)


def test_trace_from_scenario_refuses_carve_overflow():
    sc = _restart_scenario(prop_hits=MAX_RESTARTS + 1)
    with pytest.raises(ValueError, match="MAX_RESTARTS"):
        trace_from_scenario(sc, lease_ticks=2)


def test_trace_from_scenario_converts_restarts_faithfully():
    """A legal restart scenario converts with its schedules intact, and
    the converted trace replays referee == array (the triage path a
    shrunk restart survivor takes)."""
    sc = _restart_scenario()
    tr = trace_from_scenario(sc, lease_ticks=2, round_ticks=3)
    assert np.array_equal(tr.acc_restarts,
                          np.asarray(sc.planes["acc_restart"]))
    assert np.array_equal(tr.prop_restarts,
                          np.asarray(sc.planes["prop_restart"]))
    ref = replay_event_sim(tr)
    ow, cn = replay_array(tr)
    assert np.array_equal(ref, np.asarray(ow))
    assert int(np.max(np.asarray(cn))) <= 1


# --------------------------------------------- the packed-ballot carve

def test_restart_carve_shrinks_the_pack_budget():
    """The RESTART_SHIFT carve costs the run field its two low bits: the
    P=8 honest bound 4094 collapses to 1022, where the final ballot
    ((1023 << 2) | 3) * 8 + 7 fills PACK_MASK exactly."""
    assert max_pack_tick(8, 13, 0) == 4094
    for mr in (1, MAX_RESTARTS):
        assert max_pack_tick(8, 13, 0, max_restarts=mr) == 1022
    check_pack_budget(1022, 8, 13, max_restarts=MAX_RESTARTS)
    with pytest.raises(ValueError, match="budget"):
        check_pack_budget(1023, 8, 13, max_restarts=MAX_RESTARTS)
    with pytest.raises(ValueError, match="carve"):
        check_pack_budget(10, 8, 13, max_restarts=MAX_RESTARTS + 1)


def test_engine_refuses_restarts_beyond_the_carve():
    """A trace restarting one proposer more often than the carve holds
    must be refused up front (host-side), not silently mis-encoded."""
    T, N, A, P = 16, 2, 3, 4
    prst = np.zeros((T, P), np.int32)
    prst[: MAX_RESTARTS + 1, 1] = 1
    sc = Scenario.build(T, n_cells=N, n_acceptors=A, n_proposers=P,
                        prop_restart=prst)
    eng = LeaseArrayEngine(N, n_acceptors=A, n_proposers=P, lease_ticks=2)
    with pytest.raises(ValueError, match="carve"):
        eng.run_trace(sc)
