"""The restart-wait-M rule (§3) is NECESSARY, not decorative.

One test demonstrates a concrete invariant violation when restarting
acceptors skip the wait (they come back blank and immediately grant a
second majority); the twin test shows the same schedule is safe with the
rule enforced."""
from repro.configs import CellConfig
from repro.core import build_cell
from repro.sim.network import NetConfig

NET = NetConfig(delay_min=0.01, delay_max=0.02)
CFG = CellConfig(n_acceptors=3, max_lease_time=60.0, lease_timespan=20.0)


def _scenario(skip_wait: bool):
    cell = build_cell(CFG, n_proposers=4, seed=5, net=NET, strict_monitor=False)
    for n in cell.nodes:
        n.skip_restart_wait = skip_wait
    p1, p2 = cell.proposers[3], cell.proposers[2]  # pure proposer + combined
    # Use node 3 (proposer-only) and node 2 so crashes hit acceptors 0,1 only.
    p1.proposer.acquire(timespan=20.0, renew=False)
    cell.env.run_until(2.0)
    assert cell.monitor.owner_of("R") == p1.node_id
    # acceptors 0 and 1 (a majority) crash and restart immediately
    for i in (0, 1):
        cell.nodes[i].crash()
    cell.env.run_until(2.5)
    for i in (0, 1):
        cell.nodes[i].restart()
    cell.env.run_until(3.0)
    # another proposer tries while p1's lease (until t=22) is still live
    p2.proposer.acquire(timespan=20.0, renew=False)
    cell.env.run_until(15.0)
    return cell


def test_skipping_m_wait_violates_invariant():
    cell = _scenario(skip_wait=True)
    assert cell.monitor.violations, (
        "expected a demonstrated violation: blank-restarted majority granted "
        "a second lease while the first is live"
    )


def test_m_wait_prevents_violation():
    cell = _scenario(skip_wait=False)
    assert not cell.monitor.violations
    # and the second proposer is NOT owner while restarted nodes are deaf
    assert cell.monitor.owner_of("R") != cell.proposers[2].node_id
