"""The deprecation surface of the per-kwarg engine forms (S2).

Every legacy form keeps working bit-for-bit — it builds the Scenario /
TickInputs pytree and forwards — but now announces itself with a real
DeprecationWarning, and the new forms stay silent. This file is on the
convention lint's shim allowlist: it exists to exercise the deprecated
spellings on purpose.
"""
import warnings

import numpy as np
import pytest

jax = pytest.importorskip("jax")

from repro.lease_array import (  # noqa: E402
    LeaseArrayEngine,
    Scenario,
    make_tick,
)
from repro.lease_array.netplane import init_netplane  # noqa: E402
from repro.lease_array.ops import (  # noqa: E402
    lease_plane_step,
    lease_plane_step_delayed,
    lease_plane_tick,
)
from repro.lease_array.state import NO_PROPOSER, init_state  # noqa: E402

N, A, P = 8, 3, 2


def _engine():
    return LeaseArrayEngine(N, n_acceptors=A, n_proposers=P)


def _planes(T):
    attempts = np.full((T, N), NO_PROPOSER, np.int32)
    attempts[0] = 0
    return attempts


# ------------------------------------------------------------ engine.step
def test_step_legacy_kwargs_warn_and_still_work():
    eng = _engine()
    attempt = np.zeros(N, np.int32)
    with pytest.warns(DeprecationWarning, match="per-plane .*step"):
        owners = eng.step(attempt=attempt)
    assert (np.asarray(owners) == 0).all()


def test_step_legacy_positional_plane_warns():
    eng = _engine()
    with pytest.warns(DeprecationWarning, match="make_tick"):
        eng.step(np.zeros(N, np.int32))


def test_step_tickinputs_form_is_silent():
    eng = _engine()
    tick = make_tick(n_cells=N, n_acceptors=A, n_proposers=P,
                     attempts=np.zeros(N, np.int32))
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        owners = eng.step(tick)
    assert (np.asarray(owners) == 0).all()


def test_bare_step_is_silent():
    eng = _engine()
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        eng.step()


def test_step_legacy_matches_tickinputs():
    a = np.zeros(N, np.int32)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        old = np.asarray(_engine().step(attempt=a))
    tick = make_tick(n_cells=N, n_acceptors=A, n_proposers=P, attempts=a)
    new = np.asarray(_engine().step(tick))
    np.testing.assert_array_equal(old, new)


# -------------------------------------------------------- engine.run_trace
def test_run_trace_legacy_planes_warn_and_still_work():
    T = 6
    with pytest.warns(DeprecationWarning, match="raw plane arrays"):
        owners, _ = _engine().run_trace(_planes(T))
    assert (np.asarray(owners)[0] == 0).all()


def test_run_trace_attempts_kwarg_warns():
    with pytest.warns(DeprecationWarning, match="raw plane arrays"):
        _engine().run_trace(attempts=_planes(4))


def test_run_trace_scenario_form_is_silent():
    T = 6
    sc = Scenario.build(T, n_cells=N, n_acceptors=A, n_proposers=P,
                        attempts=_planes(T))
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        owners, _ = _engine().run_trace(sc)
    assert (np.asarray(owners)[0] == 0).all()


def test_run_trace_legacy_matches_scenario():
    T = 6
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        old, old_c = _engine().run_trace(_planes(T))
    sc = Scenario.build(T, n_cells=N, n_acceptors=A, n_proposers=P,
                        attempts=_planes(T))
    new, new_c = _engine().run_trace(sc)
    np.testing.assert_array_equal(np.asarray(old), np.asarray(new))
    np.testing.assert_array_equal(np.asarray(old_c), np.asarray(new_c))


# ------------------------------------------------- the lease_plane_* shims
def test_lease_plane_step_shim_warns():
    state = init_state(N, A, P)
    with pytest.warns(DeprecationWarning, match="lease_plane_step is deprecated"):
        state, count = lease_plane_step(
            state, 0, np.zeros(N, np.int32),
            np.full(N, NO_PROPOSER, np.int32), np.ones(A, np.int32),
            majority=2, lease_q4=13,
        )
    assert int(np.asarray(count).max()) >= 0


def test_lease_plane_step_delayed_shim_warns():
    state, net = init_state(N, A, P), init_netplane(N, A)
    with pytest.warns(DeprecationWarning,
                      match="lease_plane_step_delayed is deprecated"):
        lease_plane_step_delayed(
            state, net, 0, np.zeros(N, np.int32),
            np.full(N, NO_PROPOSER, np.int32), np.ones(A, np.int32),
            np.zeros(A, np.int32), np.zeros(A, np.int32),
            majority=2, lease_q4=13, round_q4=8,
        )


def test_lease_plane_tick_is_silent():
    state, net = init_state(N, A, P), init_netplane(N, A)
    tick = make_tick(n_cells=N, n_acceptors=A, n_proposers=P,
                     attempts=np.zeros(N, np.int32))
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        lease_plane_tick(state, net, 0, tick,
                         majority=2, lease_q4=13, round_q4=8)
