"""The deprecation surface of the per-kwarg engine forms (S2).

Every legacy form keeps working bit-for-bit — it builds the Scenario /
TickInputs pytree and forwards — but now announces itself with a real
DeprecationWarning, and the new forms stay silent. This file is on the
convention lint's shim allowlist and holds THE one intentional exercise
of each shim; everything else in tests/ runs the Scenario forms and
would fail the suite-wide ``error::DeprecationWarning`` filter.
"""
import warnings

import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

from repro.lease_array import (  # noqa: E402
    LeaseArrayEngine,
    Scenario,
    lease_quarters,
    make_tick,
    random_trace,
)
from repro.lease_array.netplane import init_netplane  # noqa: E402
from repro.lease_array.ops import (  # noqa: E402
    lease_plane_step,
    lease_plane_step_delayed,
    lease_plane_tick,
)
from repro.lease_array.state import NO_PROPOSER, init_state  # noqa: E402

N, A, P = 8, 3, 2
NA = NO_PROPOSER


def _engine(n_cells=N):
    return LeaseArrayEngine(n_cells, n_acceptors=A, n_proposers=P)


def _planes(T):
    attempts = np.full((T, N), NO_PROPOSER, np.int32)
    attempts[0] = 0
    return attempts


# ------------------------------------------- shim 1: engine.step per-plane
def test_step_legacy_kwargs_and_positionals_warn_and_match_tickinputs():
    """The pre-Scenario step spellings — per-plane kwargs, the bare
    positional attempt row, and the full positional signature — all warn
    and stay bit-identical to the TickInputs form."""
    a = np.zeros(N, np.int32)
    with pytest.warns(DeprecationWarning, match="per-plane .*step"):
        old = np.asarray(_engine().step(attempt=a))
    tick = make_tick(n_cells=N, n_acceptors=A, n_proposers=P, attempts=a)
    new = np.asarray(_engine().step(tick))
    np.testing.assert_array_equal(old, new)

    with pytest.warns(DeprecationWarning, match="make_tick"):
        bare = np.asarray(_engine().step(a))  # bare positional attempt row
    np.testing.assert_array_equal(bare, new)

    # the full pre-Scenario signature: step(attempt, release, acc_up, ...)
    e = _engine(2)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        e.step(np.array([0, 1], np.int32))
        own = e.step(None, np.array([0, NA], np.int32), np.ones(A, np.int32))
    assert own.tolist() == [NA, 1]
    with pytest.raises(TypeError, match="not both"):
        e.step(np.array([0, NA], np.int32), attempt=np.array([0, NA], np.int32))
    with pytest.raises(TypeError, match="inside the TickInputs"):
        e.step(make_tick(n_cells=2, n_acceptors=A, n_proposers=P),
               release=np.array([0, NA], np.int32))


def test_step_tickinputs_form_is_silent():
    eng = _engine()
    tick = make_tick(n_cells=N, n_acceptors=A, n_proposers=P,
                     attempts=np.zeros(N, np.int32))
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        owners = eng.step(tick)
    assert (np.asarray(owners) == 0).all()


def test_bare_step_is_silent():
    eng = _engine()
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        eng.step()


# --------------------------------------- shim 2: engine.run_trace raw planes
def test_run_trace_legacy_planes_warn_and_match_scenario():
    """Raw plane arrays (positional or attempts=) warn and replay
    bit-identically to the Scenario form — including the delayed model
    driven through the legacy delay/drop kwargs."""
    T = 6
    with pytest.warns(DeprecationWarning, match="raw plane arrays"):
        old, old_c = _engine().run_trace(_planes(T))
    sc = Scenario.build(T, n_cells=N, n_acceptors=A, n_proposers=P,
                        attempts=_planes(T))
    new, new_c = _engine().run_trace(sc)
    np.testing.assert_array_equal(np.asarray(old), np.asarray(new))
    np.testing.assert_array_equal(np.asarray(old_c), np.asarray(new_c))

    with pytest.warns(DeprecationWarning, match="raw plane arrays"):
        kw, _ = _engine().run_trace(attempts=_planes(T))
    np.testing.assert_array_equal(np.asarray(kw), np.asarray(new))
    with pytest.raises(TypeError, match="not both"):
        _engine().run_trace(_planes(T), attempts=_planes(T))

    tr = random_trace(3, n_ticks=40, n_cells=6, n_acceptors=3, n_proposers=3,
                      lease_ticks=2, p_release=0.1, max_delay_ticks=1,
                      p_drop=0.1)
    e1 = LeaseArrayEngine(6, n_acceptors=3, n_proposers=3, lease_ticks=2,
                          round_ticks=tr.round_ticks)
    o1, c1 = e1.run_trace(tr.scenario())
    e2 = LeaseArrayEngine(6, n_acceptors=3, n_proposers=3, lease_ticks=2,
                          round_ticks=tr.round_ticks)
    with pytest.warns(DeprecationWarning, match="raw plane arrays"):
        o2, c2 = e2.run_trace(
            tr.attempts, tr.releases, tr.acc_up,
            delay=tr.delay, drop=tr.drop,
        )
    assert np.array_equal(o1, o2) and np.array_equal(c1, c2)


def test_run_trace_scenario_form_is_silent():
    T = 6
    sc = Scenario.build(T, n_cells=N, n_acceptors=A, n_proposers=P,
                        attempts=_planes(T))
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        owners, _ = _engine().run_trace(sc)
    assert (np.asarray(owners)[0] == 0).all()


# ---------------------------------------------- shim 3: ops.lease_plane_step
def test_lease_plane_step_shim_warns_matches_tick_and_stays_traceable():
    state = init_state(4, 3, 2)
    att = np.array([0, 1, NA, NA], np.int32)
    rel = np.full(4, NA, np.int32)
    up = np.ones(3, np.int32)
    with pytest.warns(DeprecationWarning, match="lease_plane_step is deprecated"):
        old_state, old_count = lease_plane_step(
            state, 0, att, rel, up, majority=2, lease_q4=lease_quarters(2),
        )
    tick = make_tick(n_cells=4, n_acceptors=3, n_proposers=2,
                     attempts=att, releases=rel, acc_up=up)
    new_state, _, new_count = lease_plane_tick(
        state, None, 0, tick,
        majority=2, lease_q4=lease_quarters(2), round_q4=0, sync=True,
    )
    assert all(np.array_equal(a, b) for a, b in zip(old_state, new_state))
    assert np.array_equal(old_count, new_count)

    # pre-Scenario callers traced the @jax.jit shim inside their own scans
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        traced = jax.jit(lambda s, a: lease_plane_step(
            s, 0, a, jnp.asarray(rel), jnp.asarray(up),
            majority=2, lease_q4=lease_quarters(2),
        ))
        _, count = traced(state, jnp.asarray(att))
    assert count.tolist() == [1, 1, 0, 0]


# -------------------------------------- shim 4: ops.lease_plane_step_delayed
def test_lease_plane_step_delayed_shim_warns_matches_tick_and_stays_traceable():
    state, net = init_state(4, 3, 2), init_netplane(4, 3)
    att = np.array([0, NA, NA, NA], np.int32)
    none = np.full(4, NA, np.int32)
    up = np.ones(3, np.int32)
    with pytest.warns(DeprecationWarning,
                      match="lease_plane_step_delayed is deprecated"):
        st1, net1, c1 = lease_plane_step_delayed(
            state, net, 0, att, none, up,
            np.array([1, 1, 1]), np.zeros(3, np.int32),
            majority=2, lease_q4=lease_quarters(2), round_q4=8,
        )
    # the [A] form is the P-broadcast of the [P, A] link matrix
    tick = make_tick(n_cells=4, n_acceptors=3, n_proposers=2,
                     attempts=att, acc_up=up, delay=np.ones((2, 3), np.int32))
    st2, net2, c2 = lease_plane_tick(
        state, net, 0, tick,
        majority=2, lease_q4=lease_quarters(2), round_q4=8,
    )
    assert all(np.array_equal(a, b) for a, b in zip(st1, st2))
    assert all(np.array_equal(a, b) for a, b in zip(net1, net2))
    assert np.array_equal(c1, c2)

    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        traced_d = jax.jit(lambda s, n, a: lease_plane_step_delayed(
            s, n, 0, a, jnp.asarray(none), jnp.asarray(up),
            jnp.ones(3, jnp.int32), jnp.zeros(3, jnp.int32),
            majority=2, lease_q4=lease_quarters(2), round_q4=8,
        ))
        st3, net3, c3 = traced_d(state, net, jnp.asarray(att))
    assert c3.tolist() == [0, 0, 0, 0]  # request still in flight
    assert (np.asarray(net3.preq_b) > 0).any()


# ------------------------------------------------------- modern forms: silent
def test_lease_plane_tick_is_silent():
    state, net = init_state(N, A, P), init_netplane(N, A)
    tick = make_tick(n_cells=N, n_acceptors=A, n_proposers=P,
                     attempts=np.zeros(N, np.int32))
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        lease_plane_tick(state, net, 0, tick,
                         majority=2, lease_q4=13, round_q4=8)
