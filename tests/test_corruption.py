"""Adversarial corruption planes (acc_stale/acc_equiv): the falsifier's
negative controls. A Byzantine acceptor that equivocates about its
accepted lease (§3.3 poisoned) or honors below-promise ballots
(§3.2/§3.4 broken) MUST be able to trip the §4 at-most-one-owner alarm —
on both backends — while the honest path stays bit-identical to a build
that never heard of corruption."""
import numpy as np
import pytest

from repro.lease_array import LeaseArrayEngine, Scenario
from repro.lease_array.scenario import CORRUPTION_PLANES

GEOM = dict(n_cells=4, n_acceptors=3, n_proposers=4)
T = 16

BACKENDS = ["jnp", "pallas"]


def _engine(backend="jnp", **kw):
    kw.setdefault("lease_ticks", 8)
    kw.setdefault("round_ticks", 2)
    return LeaseArrayEngine(GEOM["n_cells"], n_acceptors=GEOM["n_acceptors"],
                            n_proposers=GEOM["n_proposers"], backend=backend,
                            **kw)


def _scenario(corrupt: bool) -> Scenario:
    """Alternating p0/p1 attempts under a live p0 lease; during the
    corruption window (ticks 3..7) every acceptor both equivocates (its
    prepare response claims no accepted lease) and honors stale ballots —
    p1's round then completes over p0's live belief: two owners."""
    att = np.full((T, GEOM["n_cells"]), -1, np.int32)
    att[0, :] = 0
    att[4, :] = 1
    att[8, :] = 0
    att[12, :] = 1
    planes = {"attempts": att}
    if corrupt:
        mask = np.zeros((T, GEOM["n_acceptors"]), np.int32)
        mask[3:8, :] = 1
        planes["acc_stale"] = mask
        planes["acc_equiv"] = mask
    return Scenario.build(T, **GEOM, **planes)


@pytest.mark.parametrize("backend", BACKENDS)
def test_corruption_trips_the_alarm(backend):
    """The negative control: with the Byzantine planes enabled the sweep's
    built-in §4 verification must fire, and the error must identify the
    offending scenario by plane digest (and tag, when given)."""
    eng = _engine(backend)
    with pytest.raises(AssertionError, match="§4 at-most-one-owner") as ei:
        eng.sweep([_scenario(corrupt=True)], tags=["neg-control"])
    msg = str(ei.value)
    assert "digest=" in msg
    assert "tag=neg-control" in msg


@pytest.mark.parametrize("backend", BACKENDS)
def test_honest_twin_holds(backend):
    """The same world without the Byzantine window never violates."""
    eng = _engine(backend)
    res = eng.sweep([_scenario(corrupt=False)])
    assert (res.max_owner_count <= 1).all()


def test_backends_agree_on_the_violation():
    """The corrupted replay itself (owners, counts) is bit-identical
    across backends — corruption is a semantic plane, not a kernel."""
    outs = []
    for backend in BACKENDS:
        res = _engine(backend).sweep(
            [_scenario(corrupt=True)], collect="owners", verify=False,
        )
        outs.append(res)
    assert np.array_equal(outs[0].owners, outs[1].owners)
    assert np.array_equal(outs[0].counts, outs[1].counts)
    assert (outs[0].max_owner_count > 1).all()


def test_sync_model_rejects_corruption():
    """The zero-delay synchronous step has no acceptor response path to
    corrupt: forcing netplane=False on a corrupted scenario must raise."""
    eng = _engine()
    with pytest.raises(ValueError, match="corruption"):
        eng.run_trace(_scenario(corrupt=True), netplane=False)


def test_zero_corruption_planes_are_honest():
    """All-zero acc_stale/acc_equiv planes are the honest path: the sync
    model accepts them (they are stripped host-side, never traced) and the
    replay equals one that never carried them."""
    eng = _engine()
    sc = _scenario(corrupt=False)
    assert not sc.corrupted
    assert all(k in sc.planes for k in CORRUPTION_PLANES)  # registry-filled
    ow, cn = eng.run_trace(sc, netplane=False)
    ow2, cn2 = _engine().run_trace(sc, netplane=True)
    assert np.array_equal(np.asarray(ow), np.asarray(ow2))
    assert np.array_equal(np.asarray(cn), np.asarray(cn2))
    # stepping the engine with a zero-corruption tick keeps the fast path
    eng2 = _engine()
    eng2.step(sc[0])
    assert not eng2._netplane_active


@pytest.mark.parametrize("collect", ["margins"])
def test_margins_are_backend_free(collect):
    """collect="margins" runs the always-jnp delayed scan whatever the
    engine backend: margin vectors agree bit-for-bit, honest or corrupt."""
    from repro.lease_array.falsify.search import FalsifyConfig, random_population

    for corrupt in (False, True):
        cfg = FalsifyConfig(pop_size=32, corrupt=corrupt, seed=5)
        pop = Scenario(random_population(np.random.default_rng(5), cfg))
        margins = []
        for backend in BACKENDS:
            res = FalsifyConfig(backend=backend).engine().sweep(
                pop, collect=collect, verify=False,
            )
            assert res.margins is not None
            margins.append(res.margins)
        for k in margins[0]:
            assert margins[0][k].dtype == np.int32
            assert np.array_equal(margins[0][k], margins[1][k]), k
