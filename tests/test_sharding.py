"""Sharding rules: divisibility fallback, ZeRO-1 axes, spec trees, hints."""
import numpy as np
import pytest

jax = pytest.importorskip("jax")
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs import get_config, reduced
from repro.models import transformer
from repro.parallel import sharding as shd


@pytest.fixture(scope="module")
def mesh():
    # 1-device "production-shaped" mesh: axis sizes 1, rules still resolve
    dev = np.array(jax.devices()[:1]).reshape(1, 1)
    return Mesh(dev, ("data", "model"))


def test_spec_divisible(mesh):
    rules = shd.make_rules(mesh)
    spec = shd.spec_for(mesh, rules, ("embed", "mlp"), (64, 128))
    assert spec == P(None, "model")


def test_spec_fallback_replicates_nondivisible():
    # fake 16-way model axis via mesh axis sizes in rules logic: use spec_for
    # directly with a mesh of shape (1, 1) but a synthetic check of the
    # divisibility branch via axis size 1 is trivial; exercise the logic with
    # a virtual mesh of 4 devices if present, else shape math only.
    devs = jax.devices()
    if len(devs) >= 4:
        mesh4 = Mesh(np.array(devs[:4]).reshape(1, 4), ("data", "model"))
    else:
        pytest.skip("single device: fallback branch covered by dryrun artifacts")
    rules = shd.make_rules(mesh4)
    spec = shd.spec_for(mesh4, rules, ("embed", "heads"), (64, 6))  # 6 % 4 != 0
    assert spec == P()


def test_no_mesh_axis_reuse(mesh):
    rules = shd.make_rules(mesh, {"embed": "model"})
    spec = shd.spec_for(mesh, rules, ("embed", "mlp"), (64, 128))
    # "model" must appear only once in the spec
    flat = [a for a in spec if a is not None]
    assert len(flat) == len(set(flat))


def test_zero1_adds_data_axis(mesh):
    rules = shd.make_rules(mesh)
    ax = shd.zero1_axes(("embed", "mlp"), (64, 128), mesh, rules)
    assert ax[0] == "batch"  # first replicated divisible dim gets data axes
    # already data-sharded (experts) stays untouched
    ax2 = shd.zero1_axes(("experts", "embed", "expert_ff"), (8, 64, 128), mesh, rules)
    assert ax2 == ("experts", "embed", "expert_ff")


def test_tree_shardings_match_param_tree(mesh):
    cfg = reduced(get_config("internlm2-1.8b"))
    axes = transformer.model_axes(cfg)
    ab = transformer.abstract_model(cfg)
    tree = shd.tree_shardings(mesh, shd.make_rules(mesh), axes, ab)
    flat_p = jax.tree.leaves(ab)
    flat_s = jax.tree.leaves(tree, is_leaf=lambda x: hasattr(x, "spec"))
    assert len(flat_p) == len(flat_s)


def test_hint_noop_without_mesh():
    import jax.numpy as jnp

    x = jnp.ones((4, 4))
    assert shd.hint(x, ("batch", None)) is x


def test_hint_applies_under_mesh(mesh):
    import jax.numpy as jnp

    with shd.use_mesh(mesh):
        y = jax.jit(lambda x: shd.hint(x, ("batch", "mlp")))(jnp.ones((4, 128)))
    assert y.shape == (4, 128)


def test_make_rules_filters_missing_axes(mesh):
    rules = shd.make_rules(mesh)  # no "pod" axis on this mesh
    assert rules["batch"] == "data"
    assert rules["experts"] == "data"
