"""Acceptor boundary cases per §3/§7 that the main suite skips over:
the < vs ≤ ballot comparison, stale releases, and stale expiry timeouts."""
from repro.core.acceptor import Acceptor
from repro.core.ballot import Ballot
from repro.core.messages import (
    Answer,
    Lease,
    PrepareRequest,
    Proposal,
    ProposeRequest,
    Release,
)
from repro.sim.events import Scheduler


class Harness:
    def __init__(self):
        self.sched = Scheduler()
        self.sent = []
        self.acc = Acceptor(
            0,
            set_timer=lambda d, fn: self.sched.after(d, fn),
            send=lambda dst, msg: self.sent.append((dst, msg)),
        )

    def last(self):
        return self.sent[-1][1]


def b(run, pid=1):
    return Ballot(run, 0, pid)


def prop(run, pid=1, t=10.0):
    return Proposal(b(run, pid), Lease(pid, t))


def test_equal_ballot_prepare_and_propose_accepted():
    """§3 steps 2 & 4 reject strictly-lower ballots only: a retransmitted
    request with the ballot equal to highest_promised must be accepted."""
    h = Harness()
    h.acc.on_prepare_request(PrepareRequest("R", b(4)), "p1")
    assert h.last().answer == Answer.ACCEPT
    # equal-ballot prepare (e.g. duplicated over UDP): accepted again
    h.acc.on_prepare_request(PrepareRequest("R", b(4)), "p1")
    assert h.last().answer == Answer.ACCEPT
    # propose with ballot == highest_promised: the normal success path
    h.acc.on_propose_request(ProposeRequest("R", b(4), prop(4)), "p1")
    assert h.last().answer == Answer.ACCEPT
    # duplicated propose with the same ballot: accepted again (idempotent)
    h.acc.on_propose_request(ProposeRequest("R", b(4), prop(4)), "p1")
    assert h.last().answer == Answer.ACCEPT


def test_release_with_stale_ballot_after_newer_accept_is_noop():
    """§7: a release from a *previous* lease holder must not discard the
    current holder's proposal — only an exact ballot match discards."""
    h = Harness()
    h.acc.on_propose_request(ProposeRequest("R", b(1), prop(1)), "p1")
    # ownership moved on: p2 accepted under a newer ballot
    h.acc.on_prepare_request(PrepareRequest("R", b(2, pid=2)), "p2")
    h.acc.on_propose_request(ProposeRequest("R", b(2, pid=2), prop(2, pid=2)), "p2")
    # p1's late release (its old ballot) arrives: must be a no-op
    h.acc.on_release(Release("R", b(1)), "p1")
    h.acc.on_prepare_request(PrepareRequest("R", b(3, pid=3)), "p3")
    assert h.last().accepted == prop(2, pid=2)
    # and the expiry timer of p2's lease must still be armed
    assert h.sched.pending >= 1


def test_on_timeout_ignores_proposal_accepted_under_newer_ballot():
    """An expiry timeout armed for an old proposal must not clear a
    proposal that was re-accepted under a newer ballot in the meantime."""
    h = Harness()
    h.acc.on_propose_request(ProposeRequest("R", b(1), prop(1, t=5.0)), "p1")
    h.acc.on_prepare_request(PrepareRequest("R", b(9, pid=2)), "p2")
    h.acc.on_propose_request(ProposeRequest("R", b(9, pid=2), prop(9, pid=2, t=50.0)), "p2")
    # fire the stale timeout path directly: ballot mismatch -> no-op
    h.acc._on_timeout("R", b(1))
    st = h.acc._state("R")
    assert st.accepted == prop(9, pid=2, t=50.0)
    # the matching timeout DOES clear it (and only then)
    h.acc._on_timeout("R", b(9, pid=2))
    assert st.accepted is None
    # highest_promised survives the expiry (never reset except by restart)
    h.acc.on_prepare_request(PrepareRequest("R", b(3, pid=3)), "p3")
    assert h.last().answer == Answer.REJECT
