"""Differential test: the event-driven core/ engine and the vectorized
lease_array plane replay IDENTICAL fault/timing traces and must agree on
ownership at every tick — and never violate the §4 at-most-one-owner
invariant. The construction that makes exact agreement possible (zero-delay
network, one attempt per cell/tick, quarter-tick expiry offsets, pinned
ballot ordering) is documented in repro/lease_array/trace.py."""
import numpy as np
import pytest

from repro.lease_array import random_trace, replay_array, replay_event_sim


def assert_engines_agree(trace, backend="jnp"):
    array_owners, owner_counts = replay_array(trace, backend=backend)
    # §4 invariant in the array plane, checked at every tick
    assert owner_counts.max() <= 1, "at-most-one-owner violated"
    # the event sim's strict LeaseMonitor raises on any overlap as it runs
    event_owners = replay_event_sim(trace, strict_monitor=True)
    mism = np.nonzero(array_owners != event_owners)
    assert len(mism[0]) == 0, (
        f"{len(mism[0])} ownership mismatches; first at tick {mism[0][0]} "
        f"cell {mism[1][0]}: array={array_owners[mism[0][0], mism[1][0]]} "
        f"event={event_owners[mism[0][0], mism[1][0]]}"
    )
    return array_owners


@pytest.mark.slow
def test_thousand_tick_randomized_trace():
    trace = random_trace(
        1234,
        n_ticks=1000,
        n_cells=16,
        n_acceptors=5,
        n_proposers=4,
        lease_ticks=3,
        p_attempt=0.35,
        p_release=0.06,
        p_down_flip=0.02,
    )
    owners = assert_engines_agree(trace)
    # the trace actually exercises the plane: ownership, handoffs, vacancy
    assert (owners >= 0).any() and (owners == -1).any()
    handoffs = (owners[1:] != owners[:-1]) & (owners[1:] >= 0) & (owners[:-1] >= 0)
    assert handoffs.any(), "trace produced no ownership handoffs"


@pytest.mark.parametrize(
    "seed,n_acceptors,n_proposers,lease_ticks",
    [(1, 3, 2, 1), (2, 5, 6, 2), (3, 7, 3, 5), (4, 1, 2, 2)],
)
def test_geometry_sweep(seed, n_acceptors, n_proposers, lease_ticks):
    trace = random_trace(
        seed,
        n_ticks=120,
        n_cells=10,
        n_acceptors=n_acceptors,
        n_proposers=n_proposers,
        lease_ticks=lease_ticks,
        p_attempt=0.5,
        p_release=0.1,
        p_down_flip=0.05,
    )
    assert_engines_agree(trace)


def test_heavy_faults_and_contention():
    trace = random_trace(
        99,
        n_ticks=300,
        n_cells=8,
        n_acceptors=5,
        n_proposers=5,
        lease_ticks=2,
        p_attempt=0.8,
        p_release=0.15,
        p_down_flip=0.10,
    )
    assert_engines_agree(trace)


def test_differential_through_pallas_kernel():
    trace = random_trace(
        7, n_ticks=60, n_cells=12, n_acceptors=5, n_proposers=4, lease_ticks=3,
    )
    assert_engines_agree(trace, backend="pallas")
