import os

# Tests run on the single real CPU device. (The dry-run sets its own
# 512-device XLA_FLAGS in a separate process — never here.)
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import pytest


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: long differential replays (excluded by `make test`; "
        "run with `make test-all`)",
    )


@pytest.fixture(scope="session")
def rng_key():
    import jax

    return jax.random.PRNGKey(0)
