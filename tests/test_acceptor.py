"""Acceptor state machine (§3 steps 2 & 4, §7, restart)."""
from repro.core.acceptor import Acceptor
from repro.core.ballot import Ballot
from repro.core.messages import (
    Answer,
    Lease,
    PrepareRequest,
    PrepareResponse,
    Proposal,
    ProposeRequest,
    ProposeResponse,
    Release,
)
from repro.sim.events import Scheduler


class Harness:
    def __init__(self):
        self.sched = Scheduler()
        self.sent = []
        self.acc = Acceptor(
            0,
            set_timer=lambda d, fn: self.sched.after(d, fn),
            send=lambda dst, msg: self.sent.append((dst, msg)),
        )

    def last(self):
        return self.sent[-1][1]


def b(run, pid=1):
    return Ballot(run, 0, pid)


def prop(run, pid=1, t=10.0):
    return Proposal(b(run, pid), Lease(pid, t))


def test_prepare_promise_monotone():
    h = Harness()
    h.acc.on_prepare_request(PrepareRequest("R", b(5)), "p1")
    assert h.last().answer == Answer.ACCEPT and h.last().accepted is None
    # lower ballot rejected
    h.acc.on_prepare_request(PrepareRequest("R", b(3, pid=2)), "p2")
    r = h.last()
    assert r.answer == Answer.REJECT and r.promised == b(5)
    # equal ballot re-accepted (paper: "equal or higher")
    h.acc.on_prepare_request(PrepareRequest("R", b(5)), "p1")
    assert h.last().answer == Answer.ACCEPT


def test_propose_accept_and_expiry():
    h = Harness()
    h.acc.on_prepare_request(PrepareRequest("R", b(1)), "p1")
    h.acc.on_propose_request(ProposeRequest("R", b(1), prop(1, t=10.0)), "p1")
    assert h.last().answer == Answer.ACCEPT
    # visible to a later prepare before expiry
    h.acc.on_prepare_request(PrepareRequest("R", b(2, pid=2)), "p2")
    assert h.last().accepted == prop(1)
    # expired after T: state empty again
    h.sched.run_until(10.1)
    h.acc.on_prepare_request(PrepareRequest("R", b(3, pid=2)), "p2")
    assert h.last().accepted is None
    # but highest promised survived the expiry
    h.acc.on_prepare_request(PrepareRequest("R", b(1)), "p1")
    assert h.last().answer == Answer.REJECT


def test_propose_below_promise_rejected():
    h = Harness()
    h.acc.on_prepare_request(PrepareRequest("R", b(9)), "p1")
    h.acc.on_propose_request(ProposeRequest("R", b(2, pid=2), prop(2, pid=2)), "p2")
    assert h.last().answer == Answer.REJECT


def test_new_proposal_discards_old_and_its_timer():
    h = Harness()
    h.acc.on_propose_request(ProposeRequest("R", b(1), prop(1, t=5.0)), "p1")
    h.sched.run_until(3.0)
    h.acc.on_prepare_request(PrepareRequest("R", b(2, pid=2)), "p2")
    h.acc.on_propose_request(ProposeRequest("R", b(2, pid=2), prop(2, pid=2, t=10.0)), "p2")
    # old timer (t=5) must not clear the new proposal
    h.sched.run_until(6.0)
    h.acc.on_prepare_request(PrepareRequest("R", b(3, pid=3)), "p3")
    assert h.last().accepted == prop(2, pid=2, t=10.0)


def test_release_only_on_ballot_match():
    h = Harness()
    h.acc.on_propose_request(ProposeRequest("R", b(1), prop(1)), "p1")
    h.acc.on_release(Release("R", b(9)), "p1")  # wrong ballot: no-op
    h.acc.on_prepare_request(PrepareRequest("R", b(2, pid=2)), "p2")
    assert h.last().accepted == prop(1)
    h.acc.on_release(Release("R", b(1)), "p1")  # match: discard
    h.acc.on_prepare_request(PrepareRequest("R", b(3, pid=2)), "p2")
    assert h.last().accepted is None


def test_restart_blanks_everything():
    h = Harness()
    h.acc.on_prepare_request(PrepareRequest("R", b(7)), "p1")
    h.acc.on_propose_request(ProposeRequest("R", b(7), prop(7)), "p1")
    h.acc.restart()
    h.acc.on_prepare_request(PrepareRequest("R", b(1, pid=2)), "p2")
    r = h.last()
    assert r.answer == Answer.ACCEPT and r.accepted is None  # diskless


def test_multi_resource_isolation():
    h = Harness()
    h.acc.on_propose_request(ProposeRequest("shard:1", b(1), prop(1)), "p1")
    h.acc.on_prepare_request(PrepareRequest("shard:2", b(1, pid=2)), "p2")
    assert h.last().accepted is None  # different resource, independent state
