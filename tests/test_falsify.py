"""The falsification engine: coverage-guided adversarial scenario search.

Covers the ISSUE 8 contracts: the seed corpus re-finds known bug species
(top-percentile margins), mutation operators are deterministic and closed
under scenario validation, mutant batches flow through the vmapped sweep,
the corrupt negative control finds a §4 violation within a fixed seeded
budget on both backends (and the error carries digest + lineage), the
honest search finds none while concentrating its survivors at the
boundary — and, under ``@slow``, a >= 1M-scenario seeded honest run.
"""
import numpy as np
import pytest

from repro.lease_array import Scenario
from repro.lease_array.falsify import (
    FalsifyConfig,
    load_corpus,
    margin_score,
    mutate,
    random_population,
    search,
    shrink,
)
from repro.lease_array.falsify.search import replace_config
from repro.lease_array.scenario import PLANES, CORRUPTION_PLANES, RESTART_PLANES
from repro.lease_array.trace import trace_from_scenario, replay_event_sim

BACKENDS = ["jnp", "pallas"]


def _cfg(**kw):
    return FalsifyConfig(**kw)


# ---------------------------------------------------------------- S1: corpus

def test_corpus_loads_and_names_species():
    corpus = load_corpus()
    assert set(corpus) == {"tie", "ghost", "restart", "extend"}
    assert corpus["tie"][1]["species"] == "guarded-expiry-tie"
    assert corpus["ghost"][1]["species"] == "ghost-lease"
    assert corpus["restart"][1]["species"] == "deaf-window-boundary"
    assert corpus["extend"][1]["species"] == "extend-expiry-tie"


@pytest.mark.parametrize("name", ["tie", "ghost", "restart", "extend"])
def test_corpus_fixture_ranks_top_percentile(name):
    """The margin scorer must keep ranking each known species within the
    top percentile of a random batch evaluated under the same engine —
    a falsifier that cannot re-find known bugs cannot find new ones."""
    fixture, meta = load_corpus()[name]
    cfg = _cfg(
        n_cells=fixture.n_cells, n_acceptors=fixture.n_acceptors,
        n_proposers=fixture.n_proposers, n_ticks=fixture.n_ticks,
        **meta["engine"],
    )
    eng = cfg.engine()
    got = eng.sweep([fixture], collect="margins", verify=False)
    # the fixture sits exactly at its species' recorded boundary distance
    for comp, expect in meta["expect_margins"].items():
        assert int(got.margins[comp][0]) == expect, comp
    rand = eng.sweep(
        Scenario(random_population(np.random.default_rng(2024), cfg)),
        collect="margins", verify=False,
    )
    for comp, expect in meta["expect_margins"].items():
        floor = np.percentile(rand.margins[comp], 1)
        assert expect <= floor, (comp, expect, floor)


def test_corpus_digests_are_intact():
    """load_scenario re-hashes the stored planes — a hand-edited fixture
    fails loudly (exercised by loading; corrupting one plane must raise)."""
    import json

    from repro.lease_array.falsify.corpus import CORPUS_DIR, load_scenario

    doc = json.loads((CORPUS_DIR / "tie.json").read_text())
    doc["planes"]["attempts"][0][0] = 3
    tmp = CORPUS_DIR / "_tampered.json"
    tmp.write_text(json.dumps(doc))
    try:
        with pytest.raises(ValueError, match="drifted"):
            load_scenario(tmp)
    finally:
        tmp.unlink()


# ------------------------------------------------------------- S3: mutation

def _seed_planes(cfg, seed=0):
    return random_population(np.random.default_rng(seed), cfg)


def test_mutation_is_deterministic():
    cfg = _cfg(pop_size=64, corrupt=True)
    space = cfg.mutation_space()
    outs = []
    for _ in range(2):
        planes = _seed_planes(cfg, seed=9)
        out, ops = mutate(planes, np.random.default_rng(42), space)
        outs.append((out, ops))
    assert np.array_equal(outs[0][1], outs[1][1])
    for k in outs[0][0]:
        assert np.array_equal(outs[0][0][k], outs[1][0][k]), k


def test_mutation_closed_under_validation():
    """Many rounds of mutation never leave the registry's legal ranges:
    ids stay in [-1, P), delays respect min_value >= 0, rates >= 1 —
    every member still passes Scenario.validate_for."""
    cfg = _cfg(pop_size=32, corrupt=True)
    space = cfg.mutation_space()
    rng = np.random.default_rng(3)
    planes = _seed_planes(cfg, seed=3)
    for _ in range(25):
        planes, _ = mutate(planes, rng, space)
    for b in range(cfg.pop_size):
        sc = Scenario({k: np.asarray(v)[b] for k, v in planes.items()})
        sc.validate_for(
            n_cells=cfg.n_cells, n_acceptors=cfg.n_acceptors,
            n_proposers=cfg.n_proposers,
        )
    # the floors are genuinely exercised, not vacuously satisfied
    assert planes["delay"].min() == 0
    assert planes["prop_rate"].min() >= 1


def test_mutation_only_touches_enabled_planes():
    """Honest mutation spaces never write the corruption planes, and
    restart-disabled spaces never write the crash/restart planes."""
    cfg = _cfg(pop_size=64, corrupt=False)
    space = cfg.mutation_space()
    assert not set(space.op_names()) & {
        "flip_stale", "flip_equiv",
        "crash_insert", "crash_shift", "deaf_boundary_nudge",
    }
    planes = _seed_planes(cfg, seed=1)
    rng = np.random.default_rng(1)
    for _ in range(10):
        planes, _ = mutate(planes, rng, space)
    for k in CORRUPTION_PLANES + RESTART_PLANES:
        assert not planes[k].any()


def test_restart_mutation_closed_under_carve():
    """With the crash ops enabled, arbitrarily many mutation rounds keep
    every member's per-proposer restart total inside the RESTART_SHIFT
    carve (check_pack_budget's refusal boundary) and every plane legal."""
    from repro.lease_array.state import MAX_RESTARTS

    cfg = _cfg(pop_size=32, restarts=True)
    space = cfg.mutation_space()
    assert set(space.op_names()) >= {
        "crash_insert", "crash_shift", "deaf_boundary_nudge",
    }
    rng = np.random.default_rng(11)
    planes = _seed_planes(cfg, seed=11)
    for _ in range(25):
        planes, _ = mutate(planes, rng, space)
    assert planes["prop_restart"].sum(axis=1).max() <= MAX_RESTARTS
    assert set(np.unique(planes["acc_restart"])) <= {0, 1}
    for b in range(cfg.pop_size):
        sc = Scenario({k: np.asarray(v)[b] for k, v in planes.items()})
        sc.validate_for(
            n_cells=cfg.n_cells, n_acceptors=cfg.n_acceptors,
            n_proposers=cfg.n_proposers,
        )


def test_mutants_flow_through_vmapped_sweep():
    """A stacked mutant batch is a legal sweep input (vmap-compat) and
    margins come back per-member."""
    cfg = _cfg(pop_size=16)
    planes, _ = mutate(
        _seed_planes(cfg, seed=4), np.random.default_rng(4),
        cfg.mutation_space(),
    )
    res = cfg.engine().sweep(
        Scenario(planes), collect="margins", verify=False,
    )
    assert res.max_owner_count.shape == (16,)
    assert all(v.shape == (16,) for v in res.margins.values())


# ------------------------------------------------- search + S2: error digest

@pytest.mark.parametrize("backend", BACKENDS)
def test_corrupt_search_finds_violation(backend):
    """The negative control: with the Byzantine planes in the mutation
    space, the seeded fixed-budget search must reach a §4 violation on
    both backends — proof the alarm (and the search) can fire at all."""
    res = search(_cfg(
        corrupt=True, backend=backend, seed=7, pop_size=128, generations=6,
    ))
    assert res.found
    assert res.violation is not None
    assert res.lineage.startswith("s7.")
    assert len(res.digest) == 12
    assert res.evaluations <= 128 * 6


def test_sweep_error_carries_digest_and_lineage():
    """S2: when a violating population hits sweep(verify=True), the error
    names the offender by plane digest and its mutation lineage tag."""
    res = search(_cfg(corrupt=True, seed=7, pop_size=128, generations=6))
    assert res.found
    eng = _cfg().engine()
    stacked = Scenario(
        {k: np.asarray(v)[None] for k, v in res.violation.planes.items()}
    )
    with pytest.raises(AssertionError) as ei:
        eng.sweep(stacked, tags=[res.lineage])
    msg = str(ei.value)
    assert f"digest={res.digest}" in msg
    assert f"tag={res.lineage}" in msg


def test_honest_search_concentrates_without_violating():
    res = search(_cfg(seed=7, pop_size=128, generations=6))
    assert not res.found
    assert res.evaluations == 128 * 6
    assert res.concentrated()
    assert float(np.median(res.survivor_scores)) < float(
        np.median(res.random_scores)
    )


def test_shrink_preserves_the_violation():
    """Shrinking a violating survivor keeps it violating while shedding
    ticks and non-default entries (deterministic, budgeted)."""
    res = search(_cfg(corrupt=True, seed=7, pop_size=128, generations=6))
    eng = _cfg().engine()
    small = shrink(res.violation, eng, budget=120)
    assert small.n_ticks <= res.violation.n_ticks
    sweep = eng.sweep(
        Scenario({k: np.asarray(v)[None] for k, v in small.planes.items()}),
        verify=False,
    )
    assert sweep.max_owner_count[0] > 1
    nz = lambda sc: sum(
        int((np.asarray(sc.planes[k]) != s.default).sum())
        for k, s in PLANES.items()
    )
    assert nz(small) <= nz(res.violation)


def test_replace_config_roundtrip():
    cfg = replace_config(_cfg(), pop_size=8, corrupt=True)
    assert cfg.pop_size == 8 and cfg.corrupt


# ------------------------------------------------------- survivor triage

def test_triage_rejects_corrupt_and_varying_rates():
    res = search(_cfg(corrupt=True, seed=7, pop_size=128, generations=6))
    with pytest.raises(ValueError, match="Byzantine"):
        trace_from_scenario(res.violation, lease_ticks=2, round_ticks=3)


def test_tie_fixture_replays_through_the_referee():
    """The corpus tie fixture converts to a Trace and the event-driven
    referee agrees with the array bit-for-bit (§4 clean) — the triage
    path a shrunk honest survivor would take."""
    from repro.lease_array.trace import replay_array

    fixture, meta = load_corpus()["tie"]
    tr = trace_from_scenario(
        fixture, lease_ticks=meta["engine"]["lease_ticks"],
        round_ticks=meta["engine"]["round_ticks"],
        drift_eps=meta["engine"]["drift_eps"],
    )
    ev = replay_event_sim(tr)
    ow, cn = replay_array(tr)
    assert np.array_equal(ev, np.asarray(ow))
    assert int(np.max(cn)) <= 1


# ------------------------------------------------------------ the @slow run

@pytest.mark.slow
def test_million_scenario_honest_run():
    """ISSUE 8 acceptance: a seeded >= 1M-scenario honest search (drift +
    delay + drop all enabled) finds zero violations, and its margin
    distribution shows the search concentrating — median survivor margin
    strictly below the random batch's median."""
    cfg = _cfg(seed=0, pop_size=8192, generations=128)
    res = search(cfg)
    assert not res.found
    assert res.evaluations == 8192 * 128  # 1,048,576 >= 1M
    assert res.concentrated()
    assert float(np.median(res.survivor_scores)) < float(
        np.median(res.random_scores)
    )
