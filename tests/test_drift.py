"""Clock-RATE drift: the paper assumes well-behaved interval timers (it
needs no synchronized clocks, but timers must measure T accurately).

We make that assumption explicit: with drifted clock rates and no guard a
violation is constructible; the beyond-paper drift guard
(T_own = T*(1-eps)/(1+eps)) restores the invariant. See DESIGN.md §2."""
from repro.configs import CellConfig
from repro.core import build_cell
from repro.sim.network import NetConfig

NET = NetConfig(delay_min=0.01, delay_max=0.02)


def _scenario(guard: bool):
    eps = 0.25
    cfg = CellConfig(
        n_acceptors=3, max_lease_time=60.0, lease_timespan=10.0,
        clock_drift_bound=eps, drift_guard=guard,
    )
    # proposer node 3 runs SLOW (its 10s lease lasts 13.3s of real time);
    # acceptor nodes 0-2 run FAST (their 10s timers last 8s of real time).
    rates = {0: 1.25, 1: 1.25, 2: 1.25, 3: 0.75, 4: 1.0}
    cell = build_cell(cfg, n_proposers=5, seed=2, net=NET,
                      clock_rates=rates, strict_monitor=False)
    slow, other = cell.proposers[3], cell.proposers[4]
    slow.proposer.acquire(renew=False)
    cell.env.run_until(1.0)
    assert cell.monitor.owner_of("R") == 3
    other.proposer.acquire(renew=False)
    cell.env.run_until(30.0)
    return cell


def test_drift_without_guard_can_violate():
    cell = _scenario(guard=False)
    assert cell.monitor.violations, "fast acceptors + slow owner must overlap"


def test_drift_guard_restores_invariant():
    cell = _scenario(guard=True)
    assert not cell.monitor.violations
