"""Incremental decode == full forward (per-family, fp32, no capacity drops).
This is the serving-correctness contract: a token decoded against the cache
must see exactly the distribution the training forward produces."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, reduced
from repro.models import decode_step, forward, init_cache, init_model

ARCHS = ["internlm2-1.8b", "qwen1.5-0.5b", "rwkv6-3b", "hymba-1.5b",
         "whisper-large-v3", "mixtral-8x22b", "starcoder2-15b"]


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_forward(arch):
    cfg = reduced(get_config(arch), dtype="float32", param_dtype="float32")
    if cfg.moe is not None:  # no capacity drops for exactness
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0)
        )
    key = jax.random.PRNGKey(1)
    S, B = 12, 2
    params = init_model(cfg, key)
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size, jnp.int32)
    batch = {"tokens": toks}
    if cfg.enc_dec:
        batch["frames"] = (
            jax.random.normal(key, (B, cfg.encoder_seq, cfg.d_model), jnp.float32) * 0.02
        )
    full_logits, _, _ = forward(cfg, params, batch)
    cache = init_cache(cfg, B, S)
    if cfg.enc_dec:
        _, c2, _ = forward(cfg, params, batch, emit_cache=True)
        cache["ck"], cache["cv"] = c2["ck"], c2["cv"]
    step = jax.jit(lambda p, c, t, pos: decode_step(cfg, p, c, t, pos))
    outs = []
    for t in range(S):
        lg, cache = step(params, cache, toks[:, t : t + 1], jnp.int32(t))
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    err = float(jnp.max(jnp.abs(dec - full_logits)))
    scale = float(jnp.max(jnp.abs(full_logits))) + 1e-9
    assert err / scale < 2e-4, f"{arch}: rel err {err/scale:.2e}"


def test_swa_ring_buffer_decode():
    """Sliding-window cache is a ring buffer; positions behind the window
    must be masked out exactly as the windowed forward does."""
    cfg = reduced(get_config("mixtral-8x22b"), dtype="float32", param_dtype="float32")
    cfg = dataclasses.replace(
        cfg, sliding_window=8,
        moe=dataclasses.replace(cfg.moe, capacity_factor=8.0),
    )
    key = jax.random.PRNGKey(3)
    S, B = 20, 1  # > window: ring wraps
    params = init_model(cfg, key)
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size, jnp.int32)
    full_logits, _, _ = forward(cfg, params, {"tokens": toks})
    cache = init_cache(cfg, B, S)
    assert cache["k"].shape[2] == 8  # bounded by the window
    step = jax.jit(lambda p, c, t, pos: decode_step(cfg, p, c, t, pos))
    outs = []
    for t in range(S):
        lg, cache = step(params, cache, toks[:, t : t + 1], jnp.int32(t))
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    err = float(jnp.max(jnp.abs(dec - full_logits)))
    scale = float(jnp.max(jnp.abs(full_logits))) + 1e-9
    assert err / scale < 2e-4, f"ring-buffer decode diverged: {err/scale:.2e}"
