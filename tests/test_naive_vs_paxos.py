"""§1: the naive majority algorithm blocks under contention; PaxosLease
doesn't (its prepare phase overwrites stale acceptor state)."""
from repro.configs import CellConfig
from repro.core import build_cell
from repro.core.naive import build_naive_cell
from repro.sim.network import NetConfig

NET = NetConfig(delay_min=0.01, delay_max=0.02)
CFG = CellConfig(n_acceptors=3, max_lease_time=60.0, lease_timespan=15.0,
                 backoff_min=0.05, backoff_max=0.3)


def test_naive_blocks_with_three_contenders():
    """The paper's example: proposers 1,2,3 vs acceptors A,B,C with split
    grants — nobody reaches majority until timers expire. The static-deadlock
    probability is 3!/3^3 ~ 22% per simultaneous round; over 20 seeds at
    least one full deadlock is overwhelmingly likely."""
    n_deadlock = 0
    for seed in range(20):
        env, monitor, accs, props = build_naive_cell(CFG, n_proposers=3, seed=seed, net=NET)
        for p in props:
            p.acquire()
        env.run_until(10.0)  # lease T=15: expiry can't have freed anyone yet
        if monitor.owner_of("R") is None:
            n_deadlock += 1
            assert sum(p.stats["blocked_rounds"] for p in props) >= 3
    assert n_deadlock >= 1, "naive majority should fully deadlock for some seed"


def test_paxoslease_acquires_under_same_contention():
    for seed in range(8):
        cell = build_cell(CFG, n_proposers=3, seed=seed, net=NET)
        for p in cell.proposers:
            p.proposer.acquire()
        cell.env.run_until(10.0)
        assert cell.monitor.owner_of("R") is not None, f"seed {seed}: nobody acquired"
        cell.monitor.assert_clean()


def test_naive_is_at_least_safe():
    """Blocking aside, the naive algorithm must never double-grant."""
    for seed in range(5):
        env, monitor, accs, props = build_naive_cell(CFG, n_proposers=4, seed=seed, net=NET)
        for p in props:
            p.acquire()
        env.run_until(120.0)
        assert not monitor.violations
