"""Differential tests for the delayed (in-flight message) lease plane:
the event-driven core/ engine with trace-pinned per-message delays/drops
and the vectorized netplane model must agree on ownership at every tick —
and never violate §4 at-most-one-owner. The construction that makes exact
agreement possible (pinned delay/drop planes, DELIVER_EPS drain-window
scheduling, round abandonment timers, attempt spacing) is documented in
repro/lease_array/trace.py and repro/lease_array/netplane.py."""
import numpy as np
import pytest

from repro.lease_array import random_trace, replay_array, replay_event_sim

from test_lease_array_differential import assert_engines_agree


@pytest.mark.slow
def test_thousand_tick_delayed_trace():
    trace = random_trace(
        777,
        n_ticks=1000,
        n_cells=8,
        n_acceptors=5,
        n_proposers=4,
        lease_ticks=8,
        p_attempt=0.9,
        p_release=0.05,
        p_down_flip=0.02,
        max_delay_ticks=1,
        p_drop=0.04,
        round_ticks=3,
    )
    assert trace.delayed
    owners = assert_engines_agree(trace)
    # the delayed trace actually exercises the plane: multi-tick rounds
    # still produce ownership, and losses/abandons leave vacancies
    assert (owners >= 0).any() and (owners == -1).any()
    assert float((owners >= 0).mean()) > 0.1


@pytest.mark.parametrize(
    "seed,n_acceptors,n_proposers,lease_ticks,max_delay",
    [(1, 3, 2, 4, 1), (2, 5, 6, 6, 3), (3, 7, 3, 5, 2), (4, 1, 2, 4, 1)],
)
def test_delayed_geometry_sweep(seed, n_acceptors, n_proposers, lease_ticks, max_delay):
    trace = random_trace(
        seed,
        n_ticks=150,
        n_cells=8,
        n_acceptors=n_acceptors,
        n_proposers=n_proposers,
        lease_ticks=lease_ticks,
        p_attempt=0.6,
        p_release=0.1,
        p_down_flip=0.05,
        max_delay_ticks=max_delay,
        p_drop=0.1,
    )
    assert_engines_agree(trace)


def test_harsh_delay_regime_abandons_rounds():
    """round_ticks == max_delay + 1 (the default): slow rounds are
    abandoned mid-flight and responses arrive after abandonment — both
    engines must still agree tick-for-tick."""
    trace = random_trace(
        99,
        n_ticks=300,
        n_cells=6,
        n_acceptors=5,
        n_proposers=5,
        lease_ticks=6,
        p_attempt=0.8,
        p_release=0.1,
        p_down_flip=0.05,
        max_delay_ticks=2,
        p_drop=0.08,
    )
    assert trace.round_ticks == 3
    owners = assert_engines_agree(trace)
    assert (owners >= 0).any(), "some fast rounds must still complete"


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_zero_delay_netplane_bitexact_vs_sync(backend):
    """Acceptance: zero-delay traces reproduce the PR 1 synchronous model
    bit-identically through the in-flight netplane path, on both backends."""
    trace = random_trace(
        1234, n_ticks=120, n_cells=10, n_acceptors=5, n_proposers=4,
        lease_ticks=3, p_release=0.06, p_down_flip=0.02,
    )
    assert not trace.delayed
    sync_owners, sync_counts = replay_array(trace, backend=backend, netplane=False)
    net_owners, net_counts = replay_array(trace, backend=backend, netplane=True)
    assert np.array_equal(sync_owners, net_owners)
    assert np.array_equal(sync_counts, net_counts)


def test_delayed_through_pallas_kernel():
    trace = random_trace(
        21, n_ticks=80, n_cells=12, n_acceptors=5, n_proposers=4,
        lease_ticks=4, max_delay_ticks=2, p_drop=0.05, p_down_flip=0.03,
    )
    jnp_owners, jnp_counts = replay_array(trace, backend="jnp")
    pal_owners, pal_counts = replay_array(trace, backend="pallas")
    assert np.array_equal(jnp_owners, pal_owners)
    assert np.array_equal(jnp_counts, pal_counts)
    assert_engines_agree(trace, backend="pallas")


def test_drop_only_trace_uses_netplane_and_agrees():
    """A trace with zero delays but nonzero drops still needs the
    netplane model (lost legs, abandoned rounds)."""
    trace = random_trace(
        5, n_ticks=150, n_cells=8, n_acceptors=3, n_proposers=3,
        lease_ticks=3, p_drop=0.25, p_down_flip=0.0,
    )
    assert trace.delayed and trace.delay is None
    assert_engines_agree(trace)
