"""engine.sweep: a stacked batch of fault scenarios in ONE dispatch,
per-scenario §4 verification built in, engine state untouched."""
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.lease_array import LeaseArrayEngine, Scenario, random_trace

GEOM = dict(n_cells=8, n_acceptors=3, n_proposers=4)


def _traces(n, n_ticks=12, delayed=False, seed0=100):
    return [
        random_trace(
            seed0 + s, n_ticks=n_ticks, lease_ticks=2,
            p_attempt=0.5, p_release=0.08, p_down_flip=0.05,
            max_delay_ticks=1 if delayed else 0,
            p_drop=0.1 if delayed else 0.0,
            round_ticks=2, **GEOM,
        )
        for s in range(n)
    ]


def _engine(**kw):
    return LeaseArrayEngine(lease_ticks=2, round_ticks=2, **GEOM, **kw)


@pytest.mark.parametrize("delayed", [False, True])
def test_sweep_matches_solo_replays(delayed):
    """collect="owners": every scenario in the batch equals its solo
    run_trace replay bit-for-bit."""
    traces = _traces(6, delayed=delayed)
    eng = _engine()
    res = eng.sweep(
        [t.scenario() for t in traces], collect="owners",
        netplane=delayed or None,
    )
    assert res.owners.shape == (6, 12, GEOM["n_cells"])
    assert (res.max_owner_count <= 1).all()
    for b, tr in enumerate(traces):
        solo = _engine()
        ow, cn = solo.run_trace(tr.scenario(), netplane=delayed or None)
        assert np.array_equal(res.owners[b], ow)
        assert np.array_equal(res.counts[b], cn)
        assert np.array_equal(res.final_owners[b], ow[-1])
        owned = float((ow >= 0).mean())
        assert res.owned_frac[b] == pytest.approx(owned, abs=1e-6)


def test_sweep_is_read_only():
    """A sweep never advances the engine: state, netplane, and tick are
    exactly what they were before the dispatch."""
    eng = _engine()
    warm = _traces(1, n_ticks=6)[0]
    eng.run_trace(warm.scenario())  # give the engine nontrivial state
    t_before = eng.t
    state_before = [np.asarray(a).copy() for a in eng.state]
    res = eng.sweep([t.scenario() for t in _traces(4, seed0=300)])
    assert eng.t == t_before
    for a, b in zip(eng.state, state_before):
        assert np.array_equal(np.asarray(a), b)
    # the sweep continued from the engine's CURRENT tick, not zero
    assert (res.max_owner_count <= 1).all()


def test_sweep_1024_scenarios_single_dispatch():
    """The acceptance-floor batch: >=1024 scenarios, one dispatch, summary
    reductions only (no [B, T, N] materialization), §4 verified per
    scenario."""
    traces = _traces(1024, n_ticks=8)
    stacked = Scenario.stack([t.scenario() for t in traces])
    eng = _engine()
    res = eng.sweep(stacked)
    assert res.max_owner_count.shape == (1024,)
    assert (res.max_owner_count <= 1).all()
    assert res.final_owners.shape == (1024, GEOM["n_cells"])
    assert res.owners is None and res.counts is None
    assert float(res.owned_frac.mean()) > 0.1, "sweeps actually lease"


@pytest.mark.slow
def test_sweep_10k_scenarios():
    """The 10k-fault-scenario workload from the ISSUE, end to end."""
    traces = _traces(10_000, n_ticks=8)
    stacked = Scenario.stack([t.scenario() for t in traces])
    res = _engine().sweep(stacked)
    assert res.max_owner_count.shape == (10_000,)
    assert (res.max_owner_count <= 1).all()


def test_sweep_rejects_bad_input():
    eng = _engine()
    with pytest.raises(ValueError, match="at least one scenario"):
        eng.sweep([])
    with pytest.raises(ValueError, match="collect"):
        eng.sweep([t.scenario() for t in _traces(2)], collect="everything")


def test_stack_rejects_mismatched_scenarios():
    a = _traces(1)[0].scenario()
    b = _traces(1, n_ticks=9)[0].scenario()
    with pytest.raises(ValueError, match="cannot stack"):
        Scenario.stack([a, b])
    with pytest.raises(ValueError, match="at least one"):
        Scenario.stack([])


@pytest.mark.slow
def test_sweep_shard_map_across_forced_devices(tmp_path):
    """With >1 JAX device the sweep shard_maps the batch axis; forcing two
    host devices in a subprocess must reproduce the single-device owners
    bit-for-bit (the driver falls back to vmap for uneven batches)."""
    out = tmp_path / "sweep_sharded.npy"
    code = f"""
import numpy as np, jax
assert jax.device_count() == 2, jax.devices()
from repro.lease_array import LeaseArrayEngine, Scenario, random_trace
traces = [
    random_trace(100 + s, n_ticks=12, n_cells=8, n_acceptors=3,
                 n_proposers=4, lease_ticks=2, p_attempt=0.5,
                 p_release=0.08, p_down_flip=0.05, round_ticks=2)
    for s in range(4)
]
eng = LeaseArrayEngine(8, n_acceptors=3, n_proposers=4, lease_ticks=2,
                       round_ticks=2)
res = eng.sweep([t.scenario() for t in traces], collect="owners")
np.save({str(out)!r}, res.owners)
"""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        env.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=2"
    ).strip()
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (env.get("PYTHONPATH", ""), "src") if p
    )
    subprocess.run(
        [sys.executable, "-c", code], check=True, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )
    sharded = np.load(out)
    eng = _engine()
    res = eng.sweep(
        [t.scenario() for t in _traces(4)], collect="owners"
    )
    assert np.array_equal(sharded, res.owners)
