"""The Scenario-plane API: defaulting, validation, slicing, concatenation,
vmap batching, the ghost-proposer regression on run_trace, and the §4
at-most-one-owner property under random asymmetric [T, P, A] link
scenarios (see docs/scenario_api.md; the deprecation shims for the old
one-kwarg-per-fault API live in test_deprecations.py)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.lease_array import (
    NO_PROPOSER,
    LeaseArrayEngine,
    Scenario,
    TickInputs,
    init_netplane,
    init_state,
    lease_quarters,
    make_tick,
    random_trace,
)
from repro.lease_array.engine import _scenario_scanner
from repro.lease_array.scenario import PLANES, register_plane

A = np.array
NA = NO_PROPOSER
GEOM = dict(n_cells=4, n_acceptors=3, n_proposers=2)


# ------------------------------------------------------------ build/validate
def test_build_defaults_all_planes():
    sc = Scenario.build(5, **GEOM)
    assert sc.n_ticks == 5
    assert set(sc.planes) == set(PLANES)
    assert sc.attempts.shape == (5, 4) and (sc.attempts == NA).all()
    assert sc.releases.shape == (5, 4) and (sc.releases == NA).all()
    assert sc.acc_up.shape == (5, 3) and (sc.acc_up == 1).all()
    assert sc.delay.shape == (5, 2, 3) and not sc.delay.any()
    assert sc.drop.shape == (5, 2, 3) and not sc.drop.any()
    assert not sc.delayed
    assert (sc.n_cells, sc.n_acceptors, sc.n_proposers) == (4, 3, 2)


def test_build_infers_ticks_and_broadcasts_symmetric_links():
    att = np.full((7, 4), NA, np.int32)
    sym = np.arange(3, dtype=np.int32)[None, :].repeat(7, 0)  # [T, A]
    sc = Scenario.build(attempts=att, delay=sym, **GEOM)
    assert sc.n_ticks == 7
    assert sc.delay.shape == (7, 2, 3)
    # the [T, A] form is the P-broadcast special case
    assert (sc.delay == sym[:, None, :]).all()
    assert sc.delayed


def test_build_rejects_bad_shapes_unknown_planes_and_negative_delay():
    with pytest.raises(ValueError, match="plane 'acc_up' has shape"):
        Scenario.build(3, acc_up=np.ones((3, 5), np.int32), **GEOM)
    with pytest.raises(ValueError, match="unknown scenario plane.*typo"):
        Scenario.build(3, typo=np.zeros((3, 4)), **GEOM)
    with pytest.raises(ValueError, match="negative"):
        Scenario.build(3, delay=np.full((3, 3), -1, np.int32), **GEOM)
    with pytest.raises(ValueError, match="n_ticks is required"):
        Scenario.build(**GEOM)


def test_bool_planes_coerce_to_int32():
    sc = Scenario.build(2, drop=np.ones((2, 3), bool), **GEOM)
    assert sc.drop.dtype == np.int32 and sc.drop.all()
    tick = make_tick(drop=np.ones(3, bool), **GEOM)
    assert tick.drop.dtype == np.int32 and tick.drop.shape == (2, 3)


# -------------------------------------------------- ghost-id regression (bugfix)
def test_run_trace_rejects_ghost_proposer_ids():
    """Regression: run_trace used to skip the proposer-id validation that
    step does — out-of-range ids silently leased cells to ghost proposers.
    Both paths now validate in scenario.validate_proposer_ids."""
    e = LeaseArrayEngine(4, n_acceptors=3, n_proposers=2)
    bad = Scenario.build(3, **GEOM)
    bad.planes["attempts"][1, 2] = 2  # == n_proposers: a ghost
    with pytest.raises(ValueError, match=r"proposer id 2 out of range.*2 proposers"):
        e.run_trace(bad)
    rel = Scenario.build(3, **GEOM)
    rel.planes["releases"][0, 1] = -7
    with pytest.raises(ValueError, match="out of range"):
        e.run_trace(rel)
    assert e.t == 0  # nothing advanced


def test_run_trace_validates_prebuilt_scenario_pytrees():
    e = LeaseArrayEngine(4, n_acceptors=3, n_proposers=2)
    sc = Scenario.build(3, **GEOM)
    sc.planes["attempts"][0, 0] = 5  # hand-mutated pytree skips build checks
    with pytest.raises(ValueError, match="proposer id 5 out of range"):
        e.run_trace(sc)
    wrong = Scenario.build(3, n_cells=8, n_acceptors=3, n_proposers=2)
    with pytest.raises(ValueError, match="engine geometry wants"):
        e.run_trace(wrong)
    neg = Scenario.build(3, **GEOM)
    neg.planes["delay"][1] = -2  # negative deliver-at: legs land in the past
    with pytest.raises(ValueError, match="negative"):
        e.run_trace(neg)


def test_step_validates_tick_geometry_against_engine():
    """A TickInputs built for the wrong geometry must not reach the step:
    e.g. a [1] acc_up column would silently broadcast one acceptor's
    reachability over the whole ensemble."""
    e = LeaseArrayEngine(4, n_acceptors=5, n_proposers=2)
    tick = make_tick(n_cells=4, n_acceptors=1, n_proposers=2)
    with pytest.raises(ValueError, match="acc_up.*engine geometry wants"):
        e.step(tick)
    with pytest.raises(ValueError, match="engine geometry wants"):
        e.step(make_tick(n_cells=8, n_acceptors=5, n_proposers=2))
    assert e.t == 0


# ------------------------------------------------------- slicing/concat/stack
def test_tick_slice_and_subscenario():
    att = np.full((4, 4), NA, np.int32)
    att[2, 1] = 1
    sc = Scenario.build(attempts=att, **GEOM)
    tick = sc[2]
    assert isinstance(tick, TickInputs)
    assert tick.attempts.tolist() == [NA, 1, NA, NA]
    assert tick.delay.shape == (2, 3)
    sub = sc[1:3]
    assert isinstance(sub, Scenario) and sub.n_ticks == 2
    assert sub.attempts[1, 1] == 1


def test_concat_joins_ticks_and_checks_geometry():
    a = Scenario.build(2, **GEOM)
    b = Scenario.build(3, **GEOM)
    assert a.concat(b).n_ticks == 5
    other = Scenario.build(2, n_cells=8, n_acceptors=3, n_proposers=2)
    with pytest.raises(ValueError, match="cannot concat"):
        a.concat(other)


# ------------------------------------------------------------- vmap batching
def test_vmap_stacked_scenarios():
    """A stacked batch of scenarios runs through ONE vmapped scanner and
    agrees bit-for-bit with running each scenario alone."""
    n_cells, n_acc, n_prop, lease = 6, 3, 3, 2
    traces = [
        random_trace(s, n_ticks=30, n_cells=n_cells, n_acceptors=n_acc,
                     n_proposers=n_prop, lease_ticks=lease, p_release=0.1,
                     max_delay_ticks=1, p_drop=0.1, asymmetric=True,
                     round_ticks=2)
        for s in (11, 12, 13)
    ]
    stacked = Scenario.stack([t.scenario() for t in traces])
    planes = {k: jnp.asarray(v) for k, v in stacked.planes.items()}
    scanner = _scenario_scanner(
        n_acc // 2 + 1, lease_quarters(lease), 8, "jnp", False
    )
    state = init_state(n_cells, n_acc, n_prop)
    net = init_netplane(n_cells, n_acc)
    _, _, owners, counts = jax.vmap(
        scanner, in_axes=(None, None, None, None, 0)
    )(state, net, jnp.int32(0), None, planes)
    assert owners.shape == (3, 30, n_cells)
    assert int(counts.max()) <= 1
    for b, tr in enumerate(traces):
        eng = LeaseArrayEngine(
            n_cells, n_acceptors=n_acc, n_proposers=n_prop,
            lease_ticks=lease, round_ticks=tr.round_ticks,
        )
        solo_owners, solo_counts = eng.run_trace(tr.scenario(), netplane=True)
        assert np.array_equal(np.asarray(owners)[b], solo_owners)
        assert np.array_equal(np.asarray(counts)[b], solo_counts)


# ------------------------------------- §4 invariant under asymmetric chaos
def _invariant_holds(seed: int, n_ticks: int = 60) -> None:
    """Unconstrained random asymmetric link scenario (no slot-isolation
    spacing: overwritten slots only LOSE messages, and PaxosLease is safe
    under arbitrary loss) — at most one believed owner per cell per tick."""
    rng = np.random.default_rng(seed)
    n_cells, n_acc, n_prop = 5, int(rng.integers(1, 6)), int(rng.integers(2, 5))
    sc = Scenario.build(
        n_ticks, n_cells=n_cells, n_acceptors=n_acc, n_proposers=n_prop,
        attempts=np.where(rng.random((n_ticks, n_cells)) < 0.7,
                          rng.integers(0, n_prop, (n_ticks, n_cells)), NA),
        releases=np.where(rng.random((n_ticks, n_cells)) < 0.15,
                          rng.integers(0, n_prop, (n_ticks, n_cells)), NA),
        acc_up=rng.random((n_ticks, n_acc)) > 0.1,
        delay=rng.integers(0, 4, (n_ticks, n_prop, n_acc)),
        drop=rng.random((n_ticks, n_prop, n_acc)) < 0.15,
    )
    eng = LeaseArrayEngine(
        n_cells, n_acceptors=n_acc, n_proposers=n_prop,
        lease_ticks=int(rng.integers(1, 7)), round_ticks=int(rng.integers(1, 5)),
    )
    _, counts = eng.run_trace(sc, netplane=True)
    assert counts.shape == (n_ticks, n_cells)
    assert int(counts.max()) <= 1, f"§4 violated under scenario seed {seed}"


@pytest.mark.parametrize("seed", range(8))
def test_at_most_one_owner_under_asymmetric_chaos(seed):
    _invariant_holds(seed)


def test_at_most_one_owner_hypothesis_property():
    hyp = pytest.importorskip("hypothesis")
    from hypothesis import strategies as st

    @hyp.settings(max_examples=25, deadline=None)
    @hyp.given(st.integers(min_value=0, max_value=2**32 - 1))
    def prop(seed):
        _invariant_holds(seed, n_ticks=40)

    prop()


# ------------------------------------------------- model-selection regressions
def test_run_trace_netplane_false_rejects_delayed_scenario():
    """Regression: netplane=False used to silently run a faulty scenario
    through the sync step, discarding its delay/drop planes."""
    e = LeaseArrayEngine(2, n_acceptors=3, n_proposers=2)
    sc = Scenario.build(
        4, n_cells=2, n_acceptors=3, n_proposers=2,
        attempts=np.where(np.eye(4, 2, dtype=bool), 0, NA),
        drop=np.ones((4, 3), np.int32),
    )
    with pytest.raises(ValueError, match="netplane=False"):
        e.run_trace(sc, netplane=False)
    assert e.t == 0
    owners, _ = e.run_trace(sc)  # auto-select honors the drop plane
    assert (owners == NA).all()


def test_failed_step_does_not_corrupt_network_model():
    """Regression: a step that fails validation must not flip the engine
    onto the delayed model."""
    e = LeaseArrayEngine(4, n_acceptors=3, n_proposers=2)
    # wrong acceptor count, nonzero delay: validate_for must fire before
    # the tick's delay plane can flip the engine onto the netplane
    bad = make_tick(n_cells=4, n_acceptors=7, n_proposers=2,
                    delay=np.ones(7, np.int32))
    with pytest.raises(ValueError, match="engine geometry wants"):
        e.step(bad)
    sc = Scenario.build(2, **GEOM)
    e.run_trace(sc, netplane=False)  # still a pure-sync engine
    assert e.t == 2


def test_scenario_and_tick_pickle_roundtrip():
    import pickle

    sc = Scenario.build(3, **GEOM)
    back = pickle.loads(pickle.dumps(sc))
    assert isinstance(back, Scenario) and back.n_ticks == 3
    assert all(np.array_equal(back.planes[k], sc.planes[k]) for k in PLANES)
    tick = pickle.loads(pickle.dumps(sc[1]))
    assert isinstance(tick, TickInputs) and tick.attempts.shape == (4,)


# ------------------------------------------------------------- registry
def test_register_plane_rides_through_build_and_slicing():
    spec = register_plane("tmp_test_plane", ("A",), 7, "test-only plane")
    try:
        assert PLANES["tmp_test_plane"] is spec
        sc = Scenario.build(3, **GEOM)
        assert sc.tmp_test_plane.shape == (3, 3)
        assert (sc.tmp_test_plane == 7).all()  # registered default
        assert sc[1].tmp_test_plane.shape == (3,)
        got = Scenario.build(
            3, tmp_test_plane=np.zeros((3, 3), np.int32), **GEOM
        )
        assert not got.tmp_test_plane.any()
    finally:
        del PLANES["tmp_test_plane"]


def test_unknown_plane_message_names_registry():
    with pytest.raises(ValueError, match="register_plane"):
        make_tick(bogus=np.zeros(3), **GEOM)
