"""The bench-regression gate: benchmarks/compare_bench.py row diffing."""
import json

from benchmarks.compare_bench import compare

MACHINE = {
    "platform": "Linux-x", "device_kind": "cpu", "n_devices": 2,
    "jax_backend": "cpu",
}


def _write(tmp_path, name, rows, **hdr):
    doc = {
        "benchmark": "lease_array",
        "git_rev": "abc123",
        **MACHINE,
        "rows": [
            {"name": n, "us_per_cell_tick": us, "detail": "d"}
            for n, us in rows.items()
        ],
        **hdr,
    }
    p = tmp_path / name
    p.write_text(json.dumps(doc))
    return p


def test_improvements_and_new_rows_pass(tmp_path, capsys):
    base = _write(tmp_path, "base.json", {"a": 1.0, "b": 2.0, "gone": 3.0})
    cand = _write(tmp_path, "cand.json", {"a": 0.5, "b": 2.1, "new": 9.9})
    assert compare(base, cand, 0.25) == 0
    out = capsys.readouterr().out
    assert "-50.0%" in out and "gone" in out and "new" in out


def test_regression_beyond_threshold_fails(tmp_path, capsys):
    base = _write(tmp_path, "base.json", {"a": 1.0, "b": 1.0})
    cand = _write(tmp_path, "cand.json", {"a": 1.0, "b": 1.3})
    assert compare(base, cand, 0.25) == 1
    assert "REGRESSION" in capsys.readouterr().out
    # a looser threshold tolerates the same delta
    assert compare(base, cand, 0.40) == 0


def test_regression_exactly_at_threshold_passes(tmp_path):
    base = _write(tmp_path, "base.json", {"a": 1.0})
    cand = _write(tmp_path, "cand.json", {"a": 1.25})
    assert compare(base, cand, 0.25) == 0


def test_cross_machine_gates_catastrophic_only(tmp_path, capsys):
    """Different machine stamps (and no shared reference row) relax the
    gate to the catastrophic threshold: hardware variance warns, a real
    cliff still fails."""
    base = _write(tmp_path, "base.json", {"a": 1.0, "b": 1.0})
    cand = _write(
        tmp_path, "cand.json", {"a": 1.6, "b": 1.0}, n_devices=4,
    )
    assert compare(base, cand, 0.25) == 0  # +60% across machines: warn only
    out = capsys.readouterr().out
    assert "cross-machine" in out
    cand2 = _write(
        tmp_path, "cand2.json", {"a": 5.0, "b": 1.0}, n_devices=4,
    )
    assert compare(base, cand2, 0.25) == 1  # 5x cliff fails anywhere
    # --strict restores the same-machine gate across machines
    assert compare(base, cand, 0.25, strict=True) == 1


def test_cross_machine_relative_gate(tmp_path, capsys):
    """With the reference row in both files, a cross-machine run still
    applies the strict threshold — to each row's ratio against the
    reference, which cancels machine speed."""
    ref = "lease_array_scan"
    base = _write(tmp_path, "base.json", {ref: 1.0, "a": 1.0, "b": 1.0})
    # candidate machine is uniformly 2x slower: ratios unchanged, passes
    # despite every raw delta being +100%
    cand = _write(
        tmp_path, "cand.json", {ref: 2.0, "a": 2.0, "b": 2.0}, n_devices=4,
    )
    assert compare(base, cand, 0.25) == 0
    assert "relative" in capsys.readouterr().out
    # same 2x machine, but row "a" also regressed 1.5x vs the reference —
    # invisible to the catastrophic raw gate (+200% < 300%), caught by the
    # relative one
    cand2 = _write(
        tmp_path, "cand2.json", {ref: 2.0, "a": 3.0, "b": 2.0}, n_devices=4,
    )
    assert compare(base, cand2, 0.25) == 1
    assert "REGRESSION (relative)" in capsys.readouterr().out
