"""leaselint: the static-analysis pass that gates CI (`make check`).

Four checkers over the *real* traced jaxprs / launch plans / sources:

  intervals    — abstract interpretation proving the packed int32 tick math
                 cannot overflow, deriving max_pack_tick independently
  purity       — no floats / silent int64 / gathers on the Pallas path
  launch       — BlockSpec bounds, write-race freedom, coverage, VMEM budget
  conventions  — AST lints: shim quarantine, clock-domain deadline compares,
                 registry-generated plane table in the docs

Each checker is mutation-tested: a seeded mutant fixture must trip it and
a clean twin must pass, else the lint itself has lost its teeth.
"""
import json
import shutil
import warnings
from pathlib import Path

import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

from repro.analysis.staticcheck import (  # noqa: E402
    TickConfig,
    analyze_tick_config,
    check_conventions,
    check_tick_cores,
    check_window_kernels,
    check_window_launches,
    derived_max_pack_tick,
    run_all,
)
from repro.analysis.staticcheck.cli import main, write_plane_table  # noqa: E402
from repro.analysis.staticcheck.fixtures import (  # noqa: E402
    FIXTURES,
    run_mutation_tests,
)
from repro.lease_array import LeaseArrayEngine, Scenario  # noqa: E402
from repro.lease_array.state import check_pack_budget, max_pack_tick  # noqa: E402

NA = -1

# round_ticks chosen so round deadlines (rnd_clk + 4*round_ticks) sit just
# under int32 max at t=0 and cross it within ~100 ticks — invisible to
# check_pack_budget, which never consults round_q4.
HUGE_ROUND_TICKS = 536_870_900
HUGE_ROUND_Q4 = 4 * HUGE_ROUND_TICKS


# --------------------------------------------------------------- clean tree
def test_clean_tree_is_clean():
    findings = run_all(skip_mutation=True)
    assert findings == [], "\n".join(str(f) for f in findings)


def test_purity_clean_on_real_cores():
    assert check_tick_cores() == []
    assert check_window_kernels(256, n_ticks=16, block_n=256, window=16) == []


def test_launch_clean_on_shipped_plans():
    assert check_window_launches() == []


def test_conventions_clean_on_real_sources():
    assert check_conventions() == []


# ----------------------------------------------- the interval analysis core
@pytest.mark.parametrize("n_proposers", [2, 3, 8, 16])
@pytest.mark.parametrize("max_rate", [4, 9])
def test_derived_bound_matches_hand_exactly(n_proposers, max_rate):
    """The acceptance bar: the abstract interpreter re-derives the hand
    max_pack_tick bound to the tick (±0) with no knowledge of the formula."""
    hand = max_pack_tick(n_proposers, 13, 0, max_rate, 0)
    derived = derived_max_pack_tick(n_proposers, 13, 0, max_rate, 0)
    assert derived == hand


def test_interval_analysis_rejects_what_runtime_check_misses():
    """round_q4 never enters check_pack_budget, so a huge round horizon
    sails through the hand check — the jaxpr-level analysis catches the
    add that overflows."""
    # the runtime hand check is blind to this config...
    check_pack_budget(100, 2, 13, 0)  # does not raise
    # ...the interval analysis is not
    cfg = TickConfig(t_end=100, n_proposers=2, n_acceptors=3,
                     lease_q4=13, round_q4=HUGE_ROUND_Q4)
    rules = {f.rule for f in analyze_tick_config(cfg)}
    assert "int32-overflow" in rules


def test_interval_analysis_accepts_genuinely_safe_short_horizon():
    """At t_end=3 the same round deadline still fits int32 — the analysis
    proves exactly where overflow becomes reachable, not a blanket ban."""
    cfg = TickConfig(t_end=3, n_proposers=2, n_acceptors=3,
                     lease_q4=13, round_q4=HUGE_ROUND_Q4)
    assert analyze_tick_config(cfg) == []


# ------------------------------------------------------- mutation fixtures
@pytest.mark.parametrize("checker", sorted(FIXTURES))
def test_seeded_mutant_is_caught(checker):
    mutant, want_rules, _ = FIXTURES[checker]
    rules = {f.rule for f in mutant()}
    assert rules & want_rules, (
        f"{checker} mutant produced {sorted(rules)}, "
        f"expected one of {sorted(want_rules)}"
    )


@pytest.mark.parametrize("checker", sorted(FIXTURES))
def test_clean_twin_passes(checker):
    _, _, clean = FIXTURES[checker]
    findings = clean()
    assert findings == [], "\n".join(str(f) for f in findings)


def test_mutation_self_test_is_green():
    assert run_mutation_tests() == []


# ------------------------------------- engine wiring: the static gate (S1)
def test_engine_run_trace_refuses_overflowing_round_horizon():
    eng = LeaseArrayEngine(4, n_acceptors=3, n_proposers=2,
                           round_ticks=HUGE_ROUND_TICKS)
    sc = Scenario.build(100, n_cells=4, n_acceptors=3, n_proposers=2)
    with pytest.raises(ValueError, match="static analysis refused"):
        eng.run_trace(sc)


def test_engine_sweep_refuses_overflowing_round_horizon():
    eng = LeaseArrayEngine(4, n_acceptors=3, n_proposers=2,
                           round_ticks=HUGE_ROUND_TICKS)
    sc = Scenario.build(100, n_cells=4, n_acceptors=3, n_proposers=2)
    with pytest.raises(ValueError, match="static analysis refused"):
        eng.sweep([sc])


def test_engine_accepts_default_configs():
    eng = LeaseArrayEngine(8, n_acceptors=5, n_proposers=8)
    sc = Scenario.build(20, n_cells=8, n_acceptors=5, n_proposers=8,
                        attempts=np.zeros((20, 8), np.int32))
    owners, counts = eng.run_trace(sc)
    assert owners.shape == (20, 8)
    assert (np.asarray(owners)[-1] == 0).all()


def test_traced_pack_budget_skip_warns_once(monkeypatch):
    """When the tick count is a tracer the host-side guard cannot run;
    the skip must announce itself (once), pointing at the static check."""
    import repro.lease_array.ops as ops_mod
    from repro.lease_array.netplane import init_netplane
    from repro.lease_array.ops import lease_window_scan
    from repro.lease_array.state import init_state

    monkeypatch.setattr(ops_mod, "_WARNED_TRACED_SKIP", False)
    T, N, P, A = 4, 4, 2, 3
    st, net = init_state(N, A, P), init_netplane(N, A)
    planes = {
        "attempts": np.full((T, N), NA, np.int32),
        "releases": np.full((T, N), NA, np.int32),
        "acc_up": np.ones((T, A), np.int32),
        "delay": np.zeros((T, P, A), np.int32),
        "drop": np.zeros((T, P, A), np.int32),
    }

    def scan(round_q4):
        return jax.jit(lambda s, n, t: lease_window_scan(
            s, n, t, planes, majority=2, lease_q4=13, round_q4=round_q4,
            block_n=N, window=T))

    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        scan(8)(st, net, jnp.int32(0))
        scan(9)(st, net, jnp.int32(0))  # second trace: no repeat
    skips = [x for x in w if issubclass(x.category, RuntimeWarning)
             and "check_pack_budget skipped" in str(x.message)]
    assert len(skips) == 1
    assert ops_mod._WARNED_TRACED_SKIP is True


def test_engine_static_check_failure_degrades_to_warning(monkeypatch):
    """If the analyzer itself crashes the engine must warn once and fall
    back to the hand check — never block a replay on a lint bug."""
    import repro.lease_array.engine as engine_mod

    def boom(*a, **k):
        raise RuntimeError("analyzer exploded")

    monkeypatch.setattr(engine_mod, "_static_pack_findings", boom)
    monkeypatch.setattr(engine_mod, "_STATIC_CHECK_FAILED", False)
    eng = LeaseArrayEngine(4, n_acceptors=3, n_proposers=2)
    sc = Scenario.build(5, n_cells=4, n_acceptors=3, n_proposers=2)
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        eng.run_trace(sc)
        eng.run_trace(sc)  # warn once, not per call
    msgs = [x for x in w if "static pack-budget analysis unavailable"
            in str(x.message)]
    assert len(msgs) == 1


# ------------------------------------------------------------ CLI & output
def test_cli_clean_run_writes_json_artifact(tmp_path, capsys):
    out = tmp_path / "findings.json"
    rc = main(["--json", str(out), "--skip-mutation"])
    assert rc == 0
    assert "leaselint: clean" in capsys.readouterr().out
    payload = json.loads(out.read_text())
    assert payload["findings"] == []
    assert payload["ok"] is True
    assert payload["n_findings"] == 0


def test_write_plane_table_is_idempotent(tmp_path):
    repo = Path(__file__).resolve().parents[1]
    doc = repo / "docs" / "scenario_api.md"
    (tmp_path / "docs").mkdir()
    shutil.copy(doc, tmp_path / "docs" / "scenario_api.md")
    write_plane_table(root=tmp_path)
    # the committed table already matches the registry — a rewrite is a no-op
    assert (tmp_path / "docs" / "scenario_api.md").read_text() == doc.read_text()
