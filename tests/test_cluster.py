"""Control-plane integration: coordinator election/failover, shard-lease
straggler mitigation, elastic scale up/down, membership."""
from repro.cluster.coordinator import MASTER_RESOURCE, CoordinatorService, build_coordinated_cluster
from repro.cluster.membership import Heartbeat, HeartbeatSender, MembershipTracker
from repro.cluster.shards import ShardLeaseManager
from repro.configs import CellConfig
from repro.sim.network import NetConfig

NET = NetConfig(delay_min=0.005, delay_max=0.05)
CFG = CellConfig(n_acceptors=3, max_lease_time=30.0, lease_timespan=6.0,
                 backoff_min=0.1, backoff_max=0.5)


def test_master_election_and_failover():
    cell, coord = build_coordinated_cluster(CFG, n_workers=0, seed=1, net=NET)
    gained = []
    for n in cell.proposers:
        coord.campaign(n, on_gain=lambda i=n.node_id: gained.append(i))
    cell.env.run_until(5.0)
    first = coord.master()
    assert first is not None and gained[0] == first
    # kill the master; someone else takes over within ~T + backoff + 2RTT
    cell.nodes[first].crash()
    t_crash = cell.env.now
    cell.env.run_until(t_crash + CFG.lease_timespan + 3.0)
    second = coord.master()
    assert second is not None and second != first
    cell.monitor.assert_clean()
    assert coord.failover_times(), "failover gap should be recorded"


def test_abdication_hands_over_quickly():
    cell, coord = build_coordinated_cluster(CFG, n_workers=0, seed=2, net=NET)
    for n in cell.proposers:
        coord.campaign(n)
    cell.env.run_until(5.0)
    first = coord.master()
    coord.abdicate(cell.nodes[first])
    cell.env.run_until(cell.env.now + 3.0)  # release: no need to wait out T
    nxt = coord.master()
    assert nxt is not None and nxt != first


def test_shard_straggler_reassignment():
    cell, coord = build_coordinated_cluster(CFG, n_workers=3, seed=3, net=NET)
    mgr = ShardLeaseManager(cell, n_shards=6, shard_timespan=4.0, scan_period=0.5)
    workers = [mgr.add_worker(cell.proposers[3 + i], target=2) for i in range(3)]
    cell.env.run_until(20.0)
    assert mgr.coverage() == 1.0, f"all shards owned, got {mgr.owner_map()}"
    victim = workers[0]
    owned_before = set(victim.owned)
    assert owned_before
    mgr.stall(victim.node.node_id)  # straggler: stops renewing, says nothing
    for w in workers[1:]:
        w.target = 3  # survivors can absorb the load
    cell.env.run_until(45.0)
    assert not victim.owned or mgr.coverage() == 1.0
    # every shard the straggler held is now owned by someone else
    omap = mgr.owner_map()
    for k in owned_before:
        assert omap.get(k) is not None and omap[k] != victim.node.node_id
    cell.monitor.assert_clean()


def test_elastic_scale_down_via_release():
    cell, coord = build_coordinated_cluster(CFG, n_workers=2, seed=4, net=NET)
    mgr = ShardLeaseManager(cell, n_shards=4, shard_timespan=5.0, scan_period=0.5)
    w0 = mgr.add_worker(cell.proposers[3], target=4)
    cell.env.run_until(15.0)
    assert len(w0.owned) == 4
    w1 = mgr.add_worker(cell.proposers[4], target=4)
    mgr.drain(w0.node.node_id)  # graceful handoff (§7 release, no T wait)
    cell.env.run_until(30.0)
    assert len(w0.owned) == 0 and len(w1.owned) == 4
    cell.monitor.assert_clean()


def test_membership_tracker_suspects_silent_worker():
    from repro.sim.env import SimEnv

    env = SimEnv(seed=0, net=NET)
    tracker = MembershipTracker(env, "ctl", suspect_after=3.0)
    env.add_node("ctl", lambda m, s: tracker.on_heartbeat(m))
    env.add_node("w1", lambda m, s: None)
    env.add_node("w2", lambda m, s: None)
    hb1 = HeartbeatSender(env, "w1", 1, ["ctl"], period=1.0)
    hb2 = HeartbeatSender(env, "w2", 2, ["ctl"], period=1.0)
    env.run_until(5.0)
    assert tracker.live_workers() == [1, 2]
    hb2.stop()
    env.run_until(10.0)
    assert tracker.live_workers() == [1]
    assert tracker.suspected() == [2]
