"""Differential tests for DRIFTING per-node clocks in the array engine:
the event-driven core/ engine with trace-pinned ``NodeClock`` rates and
the vectorized plane's accumulated local-clock planes must agree on
ownership at every tick — and never violate §4 at-most-one-owner, because
both apply the T·(1-ε)/(1+ε) proposer discount (quantized identically,
see ``guarded_lease_q4`` and the pinning notes in
repro/lease_array/trace.py). Drift composes with every other fault plane:
asymmetric link delays, drops, outages, §7 releases.
"""
import numpy as np
import pytest

from repro.lease_array import (
    DEFAULT_RATE,
    LeaseArrayEngine,
    random_trace,
    replay_array,
    replay_event_sim,
)

from test_lease_array_differential import assert_engines_agree


def _drift_trace(seed, *, n_ticks=150, depth=1, eps=0.25, **kw):
    args = dict(
        n_ticks=n_ticks, n_cells=8, n_acceptors=5, n_proposers=4,
        lease_ticks=4, p_attempt=0.6, p_release=0.08, p_down_flip=0.03,
        max_delay_ticks=depth, p_drop=0.08 if depth else 0.0,
        drift_eps=eps,
    )
    args.update(kw)
    return random_trace(seed, **args)


@pytest.mark.slow
def test_thousand_tick_drifted_trace():
    """The acceptance bar: a 1000-tick drifted + delayed + lossy trace
    replays bit-exactly against the NodeClock referee."""
    trace = _drift_trace(
        4242, n_ticks=1000, depth=1, eps=0.25, lease_ticks=8,
        p_attempt=0.8, p_release=0.06, round_ticks=3,
    )
    assert trace.drifted and trace.delayed
    owners = assert_engines_agree(trace)
    assert (owners >= 0).any() and (owners == -1).any()
    # drift thins ownership by design: a fast-clock owner's guarded belief
    # (19 of 33 quarters) ends ticks before slow-clock acceptors release
    # their full timers, so re-acquisition has long safe dead zones — the
    # trace must still produce real ownership and handoffs
    assert float((owners >= 0).mean()) > 0.03
    handoffs = (
        (owners[1:] != owners[:-1]) & (owners[1:] >= 0) & (owners[:-1] >= 0)
    )
    assert handoffs.any() or (
        (owners[1:] >= 0) & (owners[:-1] == -1)
    ).any()


@pytest.mark.slow
def test_thousand_tick_drifted_trace_pallas_backend():
    """Same 1000-tick drifted replay through the fused Pallas window
    kernel (interpret mode): kernel == oracle == event sim."""
    trace = _drift_trace(
        4242, n_ticks=1000, depth=1, eps=0.25, lease_ticks=8,
        p_attempt=0.8, p_release=0.06, round_ticks=3,
    )
    assert_engines_agree(trace, backend="pallas")


@pytest.mark.parametrize(
    "seed,depth,eps,n_acceptors,n_proposers",
    [
        (1, 0, 0.25, 5, 4),   # drift alone, zero-delay network
        (2, 1, 0.25, 3, 2),
        (3, 2, 0.25, 5, 6),   # drift x deeper delays x more proposers
        (4, 1, 0.5, 7, 3),    # wider drift bound: rates in [2, 6]
        (5, 2, 0.5, 5, 5),
    ],
)
def test_drifted_geometry_sweep(seed, depth, eps, n_acceptors, n_proposers):
    trace = _drift_trace(
        seed, depth=depth, eps=eps,
        n_acceptors=n_acceptors, n_proposers=n_proposers,
    )
    assert trace.drifted
    assert_engines_agree(trace)


def test_drifted_trace_on_pallas_backend():
    """Drifted clocks through the fused window kernel, differentially."""
    trace = _drift_trace(7, depth=1, eps=0.25)
    assert_engines_agree(trace, backend="pallas")


def test_drift_with_asymmetric_links_and_releases():
    """Drift composed with [T, P, A] asymmetric link matrices and §7
    releases riding the in-flight plane — the full fault stack."""
    trace = _drift_trace(
        11, depth=2, eps=0.25, asymmetric=True, p_release=0.12,
    )
    owners = assert_engines_agree(trace)
    assert (owners >= 0).any()


def test_no_drift_trace_unchanged_by_rate_planes():
    """A drift-free trace replays identically whether its rate planes are
    omitted or written out as all-DEFAULT_RATE: the drifted time base
    degenerates to the rate-1 engine bit-for-bit."""
    plain = random_trace(
        21, n_ticks=80, n_cells=6, n_acceptors=3, n_proposers=3,
        lease_ticks=3, p_release=0.1, max_delay_ticks=1, p_drop=0.1,
    )
    o1, c1 = replay_array(plain, netplane=True)
    explicit = random_trace(
        21, n_ticks=80, n_cells=6, n_acceptors=3, n_proposers=3,
        lease_ticks=3, p_release=0.1, max_delay_ticks=1, p_drop=0.1,
    )
    explicit.prop_rate = np.full(3, DEFAULT_RATE, np.int32)
    explicit.acc_rate = np.full(3, DEFAULT_RATE, np.int32)
    assert not explicit.drifted
    o2, c2 = replay_array(explicit, netplane=True)
    assert np.array_equal(o1, o2) and np.array_equal(c1, c2)


def test_split_drifted_trace_equals_one_trace():
    """Clock offsets survive the dispatch boundary: two run_trace calls
    over a drifted scenario (engine carries prop_clk/acc_clk between
    them) equal one call over the whole scenario."""
    trace = _drift_trace(31, n_ticks=60, depth=1, eps=0.25)
    sc = trace.scenario()
    geom = dict(
        n_acceptors=trace.n_acceptors, n_proposers=trace.n_proposers,
        lease_ticks=trace.lease_ticks, round_ticks=trace.round_ticks,
        drift_eps=trace.drift_eps,
    )
    whole = LeaseArrayEngine(trace.n_cells, **geom)
    ow_full, _ = whole.run_trace(sc, netplane=True)
    split = LeaseArrayEngine(trace.n_cells, **geom)
    ow_a, _ = split.run_trace(sc[:23], netplane=True)
    ow_b, _ = split.run_trace(sc[23:], netplane=True)
    assert np.array_equal(np.vstack([ow_a, ow_b]), ow_full)
    assert np.array_equal(split.prop_clk, whole.prop_clk)
    assert np.array_equal(split.acc_clk, whole.acc_clk)
    for a, b in zip(split.state, whole.state):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_step_path_matches_run_trace_under_drift():
    """The host-driven per-tick step accumulates the same local clocks as
    the fused trace replay."""
    trace = _drift_trace(41, n_ticks=25, depth=0, eps=0.25)
    sc = trace.scenario()
    geom = dict(
        n_acceptors=trace.n_acceptors, n_proposers=trace.n_proposers,
        lease_ticks=trace.lease_ticks, round_ticks=trace.round_ticks,
        drift_eps=trace.drift_eps,
    )
    fused = LeaseArrayEngine(trace.n_cells, **geom)
    ow_full, _ = fused.run_trace(sc)
    stepped = LeaseArrayEngine(trace.n_cells, **geom)
    rows = [stepped.step(sc[t]) for t in range(sc.n_ticks)]
    assert np.array_equal(np.stack(rows), ow_full)
    assert np.array_equal(stepped.prop_clk, fused.prop_clk)
    for a, b in zip(stepped.state, fused.state):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_referee_rejects_unreplayable_rates():
    trace = _drift_trace(51, n_ticks=10, depth=0, eps=0.25)
    trace.prop_rate = trace.prop_rate.copy()
    trace.prop_rate[0] = 12  # > MAX_REFEREE_RATE: fractions collide
    with pytest.raises(ValueError, match="exact event-sim replay"):
        replay_event_sim(trace)
