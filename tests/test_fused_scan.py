"""The time-resident fused window scan: window-boundary correctness.

The Pallas window kernel replays ``window`` ticks per grid step with all
state VMEM-resident; an in-flight message whose deliver-at falls in a LATER
window than its send must ride the resident slot across the boundary and
land bit-identically to the unwindowed jnp oracle. These tests split
windows adversarially (prime window sizes, windows shorter than the delay,
window=1 = the old per-tick regime) at delay depths 0/1/4, symmetric and
asymmetric, and also pin the fused jnp fallback to the legacy per-tick
scanner and the packed layout to its public round-trip.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.lease_array import (
    LeaseArrayEngine,
    NO_PROPOSER,
    Scenario,
    init_netplane,
    init_state,
    lease_quarters,
    max_pack_tick,
    pack_state,
    random_trace,
    unpack_state,
)
from repro.lease_array.engine import _scenario_scanner
from repro.lease_array.ops import lease_window_scan
from repro.lease_array.state import QUARTERS

GEOM = dict(n_cells=6, n_acceptors=3, n_proposers=4)


def _delayed_trace(seed, depth, asym, n_ticks=48, drift_eps=0.0):
    return random_trace(
        seed, n_ticks=n_ticks, lease_ticks=3,
        p_attempt=0.6, p_release=0.08, p_down_flip=0.03,
        max_delay_ticks=depth, p_drop=0.15 if depth else 0.0,
        asymmetric=asym, round_ticks=depth + 1, drift_eps=drift_eps,
        **GEOM,
    )


def _run(trace, *, backend, window, netplane):
    eng = LeaseArrayEngine(
        backend=backend, window=window, lease_ticks=trace.lease_ticks,
        round_ticks=trace.round_ticks, drift_eps=trace.drift_eps, **GEOM,
    )
    owners, counts = eng.run_trace(trace.scenario(), netplane=netplane)
    return owners, counts, eng.state, eng.net


@pytest.mark.parametrize("depth,asym", [
    (0, False), (1, False), (1, True), (4, False), (4, True),
])
@pytest.mark.parametrize("window", [1, 3, 5, 64])
def test_window_boundaries_bit_exact_vs_unwindowed_oracle(depth, asym, window):
    """Deliver-ats split across window boundaries (window < 4*delay splits
    every round; window=64 > T never splits): every partition must equal
    the unwindowed jnp oracle bit-for-bit — owners, §4 counts, final
    state, and the in-flight netplane slots."""
    trace = _delayed_trace(17 + depth, depth, asym)
    ow_ref, cn_ref, st_ref, net_ref = _run(
        trace, backend="jnp", window=window, netplane=True
    )
    ow, cn, st, net = _run(
        trace, backend="pallas", window=window, netplane=True
    )
    assert np.array_equal(ow, ow_ref)
    assert np.array_equal(cn, cn_ref)
    assert cn.max() <= 1
    for a, b in zip(st, st_ref):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(net, net_ref):
        assert np.array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("window", [1, 3, 5, 64])
def test_window_boundaries_bit_exact_under_drift(window):
    """Accumulated local-time carry across window splits: drifted clock
    planes (per-node rates in {3, 4, 5}, the ε=0.25 guard discount) must
    survive every window partition bit-exactly vs the unwindowed jnp
    oracle — owners, §4 counts, final state AND the in-flight slots,
    mirroring the deliver-at split coverage above. The local-clock
    prefix-sum planes stream per window; a lease minted in window ``w``
    on a drifted clock must expire correctly in window ``w + k``."""
    trace = _delayed_trace(29, 2, True, drift_eps=0.25)
    assert trace.drifted
    ow_ref, cn_ref, st_ref, net_ref = _run(
        trace, backend="jnp", window=window, netplane=True
    )
    ow, cn, st, net = _run(
        trace, backend="pallas", window=window, netplane=True
    )
    assert np.array_equal(ow, ow_ref)
    assert np.array_equal(cn, cn_ref)
    assert cn.max() <= 1
    for a, b in zip(st, st_ref):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(net, net_ref):
        assert np.array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("window", [1, 3, 5, 64])
def test_split_drifted_windows_continue_across_dispatches(window):
    """A drifted trace split across two run_trace dispatches (clock
    offsets carried by the engine) equals the one-dispatch replay on the
    Pallas backend for every window size."""
    trace = _delayed_trace(37, 1, False, n_ticks=40, drift_eps=0.25)
    sc = trace.scenario()
    kw = dict(
        lease_ticks=3, round_ticks=2, drift_eps=0.25, window=window,
        backend="pallas", **GEOM,
    )
    whole = LeaseArrayEngine(**kw)
    ow_full, _ = whole.run_trace(sc, netplane=True)
    split = LeaseArrayEngine(**kw)
    ow_a, _ = split.run_trace(sc[:17], netplane=True)
    ow_b, _ = split.run_trace(sc[17:], netplane=True)
    assert np.array_equal(np.vstack([ow_a, ow_b]), ow_full)
    for a, b in zip(split.state, whole.state):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_message_in_flight_across_window_boundary():
    """A hand-built round whose request is sent in window 0 (tick 3) and
    delivered in window 1 (tick 6, delay 3): the fused kernel with
    window=4 must carry the slot across the boundary."""
    T, N = 12, GEOM["n_cells"]
    attempts = np.full((T, N), NO_PROPOSER, np.int32)
    attempts[3, 0] = 1
    delay = np.zeros((T, GEOM["n_acceptors"]), np.int32)
    delay[3] = 3   # requests land t=6; responses (sent t=6) land t=9
    delay[6] = 3
    sc = Scenario.build(
        T, attempts=attempts, delay=delay, **GEOM,
    )
    ow_ref, _, _, _ = _run_scenario(sc, backend="jnp", window=4)
    ow, _, _, _ = _run_scenario(sc, backend="pallas", window=4)
    assert np.array_equal(ow, ow_ref)
    assert (ow[:9, 0] == NO_PROPOSER).all()
    assert ow[9, 0] == 1, "round completes at t=9, across two boundaries"


def _run_scenario(sc, *, backend, window):
    eng = LeaseArrayEngine(
        backend=backend, window=window, lease_ticks=3, round_ticks=8, **GEOM,
    )
    owners, counts = eng.run_trace(sc, netplane=True)
    return owners, counts, eng.state, eng.net


def test_fused_scan_matches_legacy_pertick_scanner():
    """run_trace's fused path and the pre-PR-4 per-tick scanner are the
    same math in different drivers — bit-identical outputs."""
    trace = _delayed_trace(23, 2, True)
    sc = trace.scenario()
    ow, cn, st, net = _run(trace, backend="jnp", window=16, netplane=True)
    scanner = _scenario_scanner(
        GEOM["n_acceptors"] // 2 + 1, lease_quarters(trace.lease_ticks),
        QUARTERS * trace.round_ticks, "jnp", False,
    )
    st0 = init_state(**GEOM)
    net0 = init_netplane(GEOM["n_cells"], GEOM["n_acceptors"])
    planes = {k: jnp.asarray(v) for k, v in sc.planes.items()}
    st1, net1, ow1, cn1 = scanner(st0, net0, jnp.int32(0), None, planes)
    assert np.array_equal(ow, np.asarray(ow1))
    assert np.array_equal(cn, np.asarray(cn1))
    for a, b in zip(st, st1):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(net, net1):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_split_trace_equals_one_trace():
    """Two run_trace calls (state carried between dispatches, messages
    still in flight at the cut) equal one call over the full scenario."""
    trace = _delayed_trace(31, 2, False, n_ticks=40)
    sc = trace.scenario()
    whole = LeaseArrayEngine(
        lease_ticks=3, round_ticks=3, window=7, **GEOM,
    )
    ow_full, _ = whole.run_trace(sc, netplane=True)
    split = LeaseArrayEngine(
        lease_ticks=3, round_ticks=3, window=7, **GEOM,
    )
    ow_a, _ = split.run_trace(sc[:13], netplane=True)
    ow_b, _ = split.run_trace(sc[13:], netplane=True)
    assert np.array_equal(np.vstack([ow_a, ow_b]), ow_full)
    for a, b in zip(split.state, whole.state):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_non_multiple_block_and_window_padding():
    """Cell counts that don't divide the Pallas block and tick counts that
    don't divide the window exercise both padding paths."""
    n_cells = 5
    trace = random_trace(
        41, n_ticks=13, n_cells=n_cells, n_acceptors=3, n_proposers=4,
        lease_ticks=2, p_attempt=0.7, max_delay_ticks=1, p_drop=0.1,
        round_ticks=2,
    )
    e1 = LeaseArrayEngine(n_cells, n_acceptors=3, n_proposers=4,
                          lease_ticks=2, round_ticks=2, backend="jnp")
    ow_ref, cn_ref = e1.run_trace(trace.scenario(), netplane=True)
    e2 = LeaseArrayEngine(n_cells, n_acceptors=3, n_proposers=4,
                          lease_ticks=2, round_ticks=2, backend="pallas",
                          window=4)
    ow, cn = e2.run_trace(trace.scenario(), netplane=True)
    assert np.array_equal(ow, ow_ref)
    assert np.array_equal(cn, cn_ref)


def test_packed_state_roundtrip():
    """pack_state/unpack_state is lossless on evolved public states."""
    trace = _delayed_trace(5, 1, False, n_ticks=20)
    eng = LeaseArrayEngine(lease_ticks=3, round_ticks=2, **GEOM)
    eng.run_trace(trace.scenario(), netplane=True)
    back = unpack_state(pack_state(eng.state), GEOM["n_proposers"])
    for a, b in zip(back, eng.state):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_pack_budget_guard():
    """Traces that would overflow the 15-bit ballot field raise instead of
    silently corrupting packed planes."""
    eng = LeaseArrayEngine(2, n_acceptors=3, n_proposers=4, lease_ticks=2)
    limit = max_pack_tick(4, lease_quarters(2))
    idle = Scenario.build(2, n_cells=2, n_acceptors=3, n_proposers=4)
    eng.t = limit  # pretend the engine already ran to the edge
    with pytest.raises(ValueError, match="packed int32"):
        eng.run_trace(idle)
    eng.t = limit - 2
    eng.run_trace(idle)  # inside: fine


def test_window_scan_direct_api():
    """ops.lease_window_scan is usable standalone (the engine-free path)."""
    sc = Scenario.build(
        8, attempts=np.zeros((8, 6), np.int32), **GEOM,
    )
    st = init_state(**GEOM)
    net = init_netplane(GEOM["n_cells"], GEOM["n_acceptors"])
    planes = {k: jnp.asarray(v) for k, v in sc.planes.items()}
    st1, net1, owners, counts = lease_window_scan(
        st, net, jnp.int32(0), planes,
        majority=2, lease_q4=lease_quarters(3), round_q4=4 * QUARTERS,
        sync=True,
    )
    assert owners.shape == (8, 6)
    assert (np.asarray(owners)[0] == 0).all(), "proposer 0 wins everywhere"
    assert int(np.asarray(counts).max()) <= 1


def test_window_scan_direct_api_refuses_pack_overflow():
    """The engine-free entry points guard the packed layout too: a t0 past
    max_pack_tick would silently corrupt (deadline, ballot) fields, so the
    standalone API must refuse it rather than mint garbage ballots."""
    sc = Scenario.build(4, attempts=np.zeros((4, 6), np.int32), **GEOM)
    st = init_state(**GEOM)
    net = init_netplane(GEOM["n_cells"], GEOM["n_acceptors"])
    planes = {k: jnp.asarray(v) for k, v in sc.planes.items()}
    lease_q4 = lease_quarters(3)
    t0 = max_pack_tick(GEOM["n_proposers"], lease_q4)  # t0 + 4 overflows
    with pytest.raises(ValueError, match="packed int32"):
        lease_window_scan(
            st, net, jnp.int32(t0), planes,
            majority=2, lease_q4=lease_q4, round_q4=4 * QUARTERS, sync=True,
        )
