"""End-to-end behaviour: a lease-coordinated training cluster survives
master failure, straggling workers and checkpoint handoff — the paper's
control plane driving the JAX data plane."""
import dataclasses

import numpy as np
import pytest

from repro.cluster.coordinator import CKPT_RESOURCE, build_coordinated_cluster
from repro.cluster.shards import ShardLeaseManager
from repro.configs import CellConfig, get_config, reduced
from repro.sim.network import NetConfig
from repro.train import Trainer, TrainerConfig

NET = NetConfig(delay_min=0.005, delay_max=0.05, loss=0.05)
CFG = CellConfig(n_acceptors=3, max_lease_time=30.0, lease_timespan=5.0,
                 backoff_min=0.1, backoff_max=0.5)


def test_lease_coordinated_training_with_failover(tmp_path):
    """The full story: control plane elects a checkpoint writer; training
    steps only checkpoint under the lease; when the writer dies another node
    takes over and training resumes from its checkpoint."""
    cell, coord = build_coordinated_cluster(CFG, n_workers=0, seed=0, net=NET)
    n0, n1 = cell.proposers[0], cell.proposers[1]
    for n in (n0, n1):
        n.proposer.acquire(CKPT_RESOURCE, timespan=5.0, renew=True)
    cell.env.run_until(3.0)
    holder = cell.monitor.owner_of(CKPT_RESOURCE)
    assert holder in (0, 1)
    holder_node = cell.nodes[holder]
    other_node = n1 if holder == 0 else n0

    tiny = dataclasses.replace(reduced(get_config("qwen1.5-0.5b")), vocab_size=128)
    tc = TrainerConfig(steps=4, batch_size=2, seq_len=16, ckpt_dir=str(tmp_path),
                       ckpt_every=2, log_every=100)
    # trainer 1 runs on the lease holder
    tr1 = Trainer(tiny, tc, lease_guard=lambda: holder_node.proposer.is_owner(CKPT_RESOURCE),
                  verbose=False)
    tr1.run()
    assert tr1.ckpt.saved_steps == [2, 4]

    # holder crashes; the other control node takes the writer lease within
    # T + backoff + a settle window (renewal flaps under 5% loss allowed)
    holder_node.crash()
    deadline = cell.env.now + CFG.lease_timespan + 10.0
    while cell.env.now < deadline and not other_node.proposer.is_owner(CKPT_RESOURCE):
        cell.env.run_until(cell.env.now + 0.5)
    assert other_node.proposer.is_owner(CKPT_RESOURCE)
    cell.monitor.assert_clean()

    # trainer 2 resumes from the checkpoint and continues writing
    tc2 = dataclasses.replace(tc, steps=6)
    tr2 = Trainer(tiny, tc2, lease_guard=lambda: other_node.proposer.is_owner(CKPT_RESOURCE),
                  verbose=False)
    assert tr2.step == 4  # resumed where the dead writer left off
    tr2.run()
    assert 6 in tr2.ckpt.saved_steps


def test_shard_leases_feed_the_loader():
    """Worker's data loader reads exactly the shards its leases cover, and a
    straggler's shards keep flowing through the survivor."""
    cell, coord = build_coordinated_cluster(CFG, n_workers=2, seed=1, net=NET)
    mgr = ShardLeaseManager(cell, n_shards=4, shard_timespan=4.0, scan_period=0.3)
    w0 = mgr.add_worker(cell.proposers[3], target=2)
    w1 = mgr.add_worker(cell.proposers[4], target=2)
    cell.env.run_until(15.0)
    assert len(w0.owned) == 2 and len(w1.owned) == 2

    from repro.data import ShardedLoader, SyntheticTokens

    gen = SyntheticTokens(512, 16, seed=0)
    loader1 = ShardedLoader(gen, 4, 2, owned_shards=lambda: w1.owned)
    batch = loader1.next_batch()
    assert batch["tokens"].shape == (2, 16)

    mgr.stall(w0.node.node_id)
    w1.target = 4
    deadline = cell.env.now + 60.0
    while cell.env.now < deadline and len(w1.owned) < 4:
        cell.env.run_until(cell.env.now + 1.0)
    assert len(w1.owned) == 4  # absorbed the straggler's shards
    b2 = loader1.next_batch()
    assert b2["tokens"].shape == (2, 16)
    cell.monitor.assert_clean()


def test_dryrun_artifacts_complete_if_present():
    """If the 512-chip dry-run has been run, its artifact set must cover all
    40 cells x 2 meshes with no failures."""
    import json
    import pathlib

    art_dir = pathlib.Path(__file__).resolve().parent.parent / "artifacts" / "dryrun"
    files = sorted(art_dir.glob("*.json")) if art_dir.exists() else []
    files = [f for f in files if "_pod" in f.name and not f.name.startswith("opt")]
    if len(files) < 80:
        pytest.skip("dry-run artifacts not generated in this environment")
    statuses = {}
    for f in files:
        a = json.loads(f.read_text())
        statuses[(a["arch"], a["shape"], a["mesh"])] = a["status"]
    assert len(statuses) >= 80
    assert "failed" not in statuses.values()
    n_ok = sum(1 for s in statuses.values() if s == "ok")
    n_skip = sum(1 for s in statuses.values() if s == "skipped")
    assert n_ok >= 66 and n_skip >= 14  # 7 long_500k skips per mesh
