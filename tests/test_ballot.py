"""Ballot numbers (§2): global uniqueness + per-proposer monotonicity."""
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis (requirements-dev.txt)")
from hypothesis import given, strategies as st

from repro.core.ballot import Ballot, BallotGenerator


def test_ordering_run_most_significant():
    assert Ballot(2, 0, 0) > Ballot(1, 99, 99)
    assert Ballot(1, 2, 0) > Ballot(1, 1, 99)
    assert Ballot(1, 1, 2) > Ballot(1, 1, 1)


def test_generator_monotone():
    g = BallotGenerator(proposer_id=3, restart_counter=0)
    seq = [g.next() for _ in range(100)]
    assert all(a < b for a, b in zip(seq, seq[1:]))


def test_generator_jump_past_observed():
    g = BallotGenerator(proposer_id=1, restart_counter=0)
    b = g.next()
    higher = Ballot(50, 7, 2)
    nxt = g.next(at_least=higher)
    assert nxt > higher and nxt > b


def test_restart_preserves_uniqueness():
    g1 = BallotGenerator(proposer_id=1, restart_counter=0)
    pre = [g1.next() for _ in range(10)]
    g2 = BallotGenerator(proposer_id=1, restart_counter=1)  # restarted
    post = [g2.next() for _ in range(10)]
    assert len(set(pre + post)) == 20
    # restart counter is more significant than run within same proposer? No —
    # run is most significant, so ballots are NOT monotone across restarts,
    # only unique. Uniqueness is what §2 requires; monotonicity is per run.


@given(
    st.tuples(st.integers(0, 5), st.integers(0, 5), st.integers(0, 5)),
    st.tuples(st.integers(0, 5), st.integers(0, 5), st.integers(0, 5)),
)
def test_total_order_matches_tuple_order(a, b):
    ba, bb = Ballot(*a), Ballot(*b)
    assert (ba < bb) == (a < b)
    assert (ba == bb) == (a == b)


def test_distinct_proposers_never_collide():
    g1 = BallotGenerator(proposer_id=1, restart_counter=0)
    g2 = BallotGenerator(proposer_id=2, restart_counter=0)
    s1 = {g1.next() for _ in range(50)}
    s2 = {g2.next() for _ in range(50)}
    assert not (s1 & s2)
