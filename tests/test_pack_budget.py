"""max_pack_tick boundary behavior (S3).

The packed int32 layout budgets PACK_SHIFT bits for ballots and the rest
for quarter-tick deadlines; max_pack_tick is the hand-derived last safe
tick. These tests nail the exact edge (limit passes, limit+1 raises), the
MAX_REFEREE_RATE worst case, and cross-check the hand bound against the
interval analysis's independently derived bound on a (P, rate) grid.
"""
import pytest

pytest.importorskip("jax")

from repro.analysis.staticcheck import derived_max_pack_tick  # noqa: E402
from repro.lease_array.state import (  # noqa: E402
    MAX_PACK_Q4,
    MAX_RESTARTS,
    PACK_MASK,
    PACK_SHIFT,
    QUARTERS,
    check_pack_budget,
    max_pack_tick,
)
from repro.lease_array.trace import MAX_REFEREE_RATE  # noqa: E402

LEASE_Q4 = 13  # the engine default: 3 lease ticks + 1 guard quarter


# ------------------------------------------------------------- exact edges
def test_default_bound_value():
    # P=8, lease_q4=13: ballot budget (32767 - 7)//8 - 1 = 4094 binds first
    assert max_pack_tick(8, LEASE_Q4) == 4094


def test_edge_tick_passes_and_next_raises():
    limit = max_pack_tick(8, LEASE_Q4)
    check_pack_budget(limit, 8, LEASE_Q4)  # exactly at the edge: fine
    with pytest.raises(ValueError, match="exceeds the packed int32"):
        check_pack_budget(limit + 1, 8, LEASE_Q4)


def test_edge_ballot_fits_and_next_does_not():
    """The bound is tight on the ballot side at P=8: the last attempt's
    ballot fits PACK_SHIFT bits, one tick later it would not."""
    P = 8
    limit = max_pack_tick(P, LEASE_Q4)
    assert (limit + 1) * P + (P - 1) <= PACK_MASK
    assert (limit + 2) * P + (P - 1) > PACK_MASK


def test_q4_side_binds_for_small_p():
    """At P=2 the ballot budget is huge; the deadline (q4) side binds:
    the last deadline any safe tick can mint fits MAX_PACK_Q4."""
    P, rate = 2, QUARTERS
    limit = max_pack_tick(P, LEASE_Q4, 0, rate, 0)
    assert rate * limit + LEASE_Q4 <= MAX_PACK_Q4
    assert rate * (limit + 1) + LEASE_Q4 > MAX_PACK_Q4


def test_max_referee_rate_worst_case():
    """A rate-9 clock mints deadlines 9/4 faster — the q4 side shrinks
    accordingly and the edge stays exact."""
    limit = max_pack_tick(2, LEASE_Q4, 0, MAX_REFEREE_RATE, 0)
    assert MAX_REFEREE_RATE * limit + LEASE_Q4 <= MAX_PACK_Q4
    assert MAX_REFEREE_RATE * (limit + 1) + LEASE_Q4 > MAX_PACK_Q4
    check_pack_budget(limit, 2, LEASE_Q4, 0, MAX_REFEREE_RATE)
    with pytest.raises(ValueError):
        check_pack_budget(limit + 1, 2, LEASE_Q4, 0, MAX_REFEREE_RATE)


# ----------------------------------------------------------- monotonicity
def test_bound_monotone_in_delay_rate_slack():
    base = max_pack_tick(8, LEASE_Q4, 0, QUARTERS, 0)
    assert max_pack_tick(8, LEASE_Q4, 5, QUARTERS, 0) <= base
    assert max_pack_tick(8, LEASE_Q4, 0, MAX_REFEREE_RATE, 0) <= base
    assert max_pack_tick(8, LEASE_Q4, 0, QUARTERS, 100) <= base


def test_slack_shifts_q4_bound_exactly():
    """clk_slack models clocks already `slack` quarter-ticks ahead: on the
    q4-bound side each unit of slack costs 1/rate ticks, floor-divided."""
    P, rate, slack = 2, QUARTERS, 37
    assert max_pack_tick(P, LEASE_Q4, 0, rate, slack) == (
        (MAX_PACK_Q4 - LEASE_Q4 - slack) // rate
    )


# -------------------------------------- hand bound vs the interval theorem
@pytest.mark.parametrize("n_proposers", [2, 3, 8, 16])
@pytest.mark.parametrize("max_rate", [QUARTERS, MAX_REFEREE_RATE])
@pytest.mark.parametrize("max_restarts", [0, 1, MAX_RESTARTS])
def test_hand_bound_agrees_with_interval_bound(
    n_proposers, max_rate, max_restarts
):
    """The static analyzer re-derives the same last-safe tick from the
    traced jaxpr with no knowledge of the formula — the hand bound is
    neither optimistic (unsound) nor pessimistic (wasteful), to the tick,
    in both the honest encoding and the restart-counter carve
    (docs/restarts.md)."""
    hand = max_pack_tick(
        n_proposers, LEASE_Q4, 0, max_rate, 0, max_restarts
    )
    assert derived_max_pack_tick(
        n_proposers, LEASE_Q4, 0, max_rate, 0, max_restarts=max_restarts
    ) == hand


@pytest.mark.parametrize("max_delay", [1, 3])
def test_hand_bound_never_optimistic_under_delay(max_delay):
    """With in-flight delay the hand bound charges a full QUARTERS*delay;
    it must stay at or below what the analysis proves safe (sound), and
    within one delay-charge of it (not gratuitously loose)."""
    hand = max_pack_tick(8, LEASE_Q4, max_delay)
    derived = derived_max_pack_tick(8, LEASE_Q4, max_delay)
    assert hand <= derived
    assert derived - hand <= QUARTERS * max_delay + 1


def test_pack_geometry_consistency():
    # the layout constants the bounds are derived from
    assert MAX_PACK_Q4 == (2**31 - 1) >> PACK_SHIFT
    assert PACK_MASK == (1 << PACK_SHIFT) - 1
