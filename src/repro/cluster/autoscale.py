"""Elastic shard-target controller.

The coordinator (master-lease holder) watches heartbeat membership and
re-publishes per-worker shard targets so the pool always covers ``n_shards``:
workers joining lowers everyone's target, workers going silent raises the
survivors'. Safety never depends on this — targets only steer how many
leases a worker *tries* to hold; actual ownership is always decided by the
PaxosLease rounds, and a dead worker's shards migrate by expiry regardless.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Optional

from ..core.cell import Cell, LeaseNode
from .membership import MembershipTracker
from .shards import ShardLeaseManager


class AutoscaleController:
    def __init__(
        self,
        cell: Cell,
        mgr: ShardLeaseManager,
        tracker: MembershipTracker,
        *,
        master_node: LeaseNode,
        period: float = 2.0,
        headroom: int = 0,  # extra leases each worker may chase (work stealing)
    ) -> None:
        self.cell = cell
        self.mgr = mgr
        self.tracker = tracker
        self.master_node = master_node
        self.period = period
        self.headroom = headroom
        self.decisions: list[tuple[float, dict]] = []
        self._tick()

    def _tick(self) -> None:
        # Only the master steers (it alone knows it holds the master lease —
        # §3: ownership is local knowledge). A deposed master stops steering.
        from .coordinator import MASTER_RESOURCE

        if self.master_node.proposer is not None and self.master_node.proposer.is_owner(
            MASTER_RESOURCE
        ):
            live = [w for w in self.tracker.live_workers() if w in self.mgr.workers]
            if live:
                per = math.ceil(self.mgr.n_shards / len(live)) + self.headroom
                targets = {}
                for wid, w in self.mgr.workers.items():
                    w.target = per if wid in live else 0
                    targets[wid] = w.target
                self.decisions.append((self.cell.env.now, targets))
        self.cell.env.set_timer(self.master_node.addr, self.period, self._tick)
