from .autoscale import AutoscaleController
from .coordinator import CoordinatorService
from .membership import MembershipTracker
from .shards import ShardLeaseManager, ShardWorker

__all__ = [
    "AutoscaleController",
    "CoordinatorService",
    "MembershipTracker",
    "ShardLeaseManager",
    "ShardWorker",
]
