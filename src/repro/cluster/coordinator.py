"""Cluster coordinator election — the paper's own deployment story (§9):
PaxosLease negotiates the *master lease* exactly as in Keyspace/ScalienDB,
here for a training cluster. The master drives checkpoint cadence, publishes
data-shard assignment and admits elastic workers. Mastership is just lease
ownership on the reserved resource ``master``; renewal (§6) keeps a healthy
master in place, expiry (no disk, no clock sync needed) replaces a dead one
within ~T + backoff.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Optional

from ..configs.paxoslease_cell import CellConfig
from ..core.cell import Cell, LeaseNode, build_cell

MASTER_RESOURCE = "master"
CKPT_RESOURCE = "ckpt-writer"


@dataclass
class CoordinatorEvents:
    gained: list = field(default_factory=list)  # (t, node_id)
    lost: list = field(default_factory=list)


class CoordinatorService:
    """Wraps a lease cell; every control node runs one of these. Callbacks
    fire on LOCAL mastership transitions (only the owner knows — §3)."""

    def __init__(self, cell: Cell, *, lease_timespan: Optional[float] = None) -> None:
        self.cell = cell
        self.events = CoordinatorEvents()
        self._on_gain: dict[int, Callable] = {}
        self._on_lose: dict[int, Callable] = {}
        self._wrap_monitors()
        self.T = lease_timespan or cell.cfg.lease_timespan

    def _wrap_monitors(self) -> None:
        mon = self.cell.monitor
        orig_acq, orig_lose = mon.on_acquire, mon.on_lose

        def on_acquire(pid: int, resource: str) -> None:
            orig_acq(pid, resource)
            if resource == MASTER_RESOURCE:
                self.events.gained.append((self.cell.env.now, pid))
                cb = self._on_gain.get(pid)
                if cb:
                    cb()

        def on_lose(pid: int, resource: str) -> None:
            orig_lose(pid, resource)
            if resource == MASTER_RESOURCE:
                self.events.lost.append((self.cell.env.now, pid))
                cb = self._on_lose.get(pid)
                if cb:
                    cb()

        mon.on_acquire, mon.on_lose = on_acquire, on_lose

    # ------------------------------------------------------------------ API
    def campaign(self, node: LeaseNode, *, on_gain: Callable = None, on_lose: Callable = None) -> None:
        """Node volunteers for mastership (it keeps campaigning forever)."""
        if on_gain:
            self._on_gain[node.node_id] = on_gain
        if on_lose:
            self._on_lose[node.node_id] = on_lose
        node.proposer.acquire(MASTER_RESOURCE, timespan=self.T, renew=True)

    def abdicate(self, node: LeaseNode) -> None:
        node.proposer.release(MASTER_RESOURCE)

    def master(self) -> Optional[int]:
        """Global-observer view (harness/tests only — real nodes can't ask)."""
        return self.cell.monitor.owner_of(MASTER_RESOURCE)

    def failover_times(self) -> list[float]:
        """Gaps between a master loss and the next gain (bench_failover)."""
        gaps = []
        for t_lost, _pid in self.events.lost:
            nxt = [t for t, _ in self.events.gained if t >= t_lost]
            if nxt:
                gaps.append(min(nxt) - t_lost)
        return gaps


def build_coordinated_cluster(
    cfg: CellConfig,
    *,
    n_workers: int,
    seed: int = 0,
    net=None,
) -> tuple[Cell, CoordinatorService]:
    """Standard production topology: cfg.n_acceptors control nodes (acceptor
    + proposer) and ``n_workers`` elastic proposer-only worker nodes."""
    cell = build_cell(cfg, n_proposers=cfg.n_acceptors + n_workers, seed=seed, net=net)
    return cell, CoordinatorService(cell)
