"""Heartbeat membership for elastic worker pools.

Liveness tracking is NOT a lease problem (the paper is explicit that only an
owner knows its lease), so workers send plain heartbeat messages to control
nodes; a worker unheard-of for ``suspect_after`` is suspected. The master
uses this to size shard targets; actual shard safety never depends on it —
that's what the leases are for.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Optional

from ..sim.env import SimEnv


@dataclass(frozen=True)
class Heartbeat:
    worker_id: int
    load: float = 0.0


class MembershipTracker:
    def __init__(self, env: SimEnv, addr: str, *, suspect_after: float = 5.0) -> None:
        self.env = env
        self.addr = addr
        self.suspect_after = suspect_after
        self.last_seen: dict[int, float] = {}
        self.loads: dict[int, float] = {}

    def on_heartbeat(self, hb: Heartbeat) -> None:
        self.last_seen[hb.worker_id] = self.env.now
        self.loads[hb.worker_id] = hb.load

    def live_workers(self) -> list[int]:
        t = self.env.now
        return sorted(w for w, ts in self.last_seen.items() if t - ts < self.suspect_after)

    def suspected(self) -> list[int]:
        t = self.env.now
        return sorted(w for w, ts in self.last_seen.items() if t - ts >= self.suspect_after)


class HeartbeatSender:
    def __init__(self, env: SimEnv, addr: str, worker_id: int, targets: list[str],
                 *, period: float = 1.0) -> None:
        self.env = env
        self.addr = addr
        self.worker_id = worker_id
        self.targets = targets
        self.period = period
        self.stopped = False
        self._tick()

    def stop(self) -> None:
        self.stopped = True

    def _tick(self) -> None:
        if self.stopped:
            return
        for t in self.targets:
            self.env.send(self.addr, t, Heartbeat(self.worker_id))
        self.env.set_timer(self.addr, self.period, self._tick)
