"""Data-shard ownership via fine-grained leases (§8: leases for many
resources) — the framework's straggler mitigation and elastic-scaling
mechanism.

Every data shard is an independent PaxosLease instance (``shard:<k>``).
A worker holds leases on the shards it is processing and renews them while
healthy. A straggling/stalled/dead worker simply stops renewing: the lease
expires after T without any fencing or coordinator intervention, and another
worker acquires the shard. Workers are proposers — PaxosLease allows any
number of them (§2), so the pool can grow/shrink freely (elasticity).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Optional

from ..configs.paxoslease_cell import CellConfig
from ..core.cell import Cell, LeaseNode


def shard_resource(k: int) -> str:
    return f"shard:{k}"


@dataclass
class ShardWorker:
    node: LeaseNode
    target: int  # how many shards this worker tries to hold
    owned: set = field(default_factory=set)
    stalled: bool = False
    processed: dict = field(default_factory=dict)  # shard -> batches done


class ShardLeaseManager:
    """Runs on top of an existing cell. Scan-based acquisition: each worker
    periodically tries to top up to its target with unowned shards (it can't
    see the global owner map — it just proposes and loses quickly if someone
    holds the lease; a reject costs one round)."""

    def __init__(
        self,
        cell: Cell,
        n_shards: int,
        *,
        shard_timespan: Optional[float] = None,
        scan_period: float = 1.0,
    ) -> None:
        self.cell = cell
        self.n_shards = n_shards
        self.T = shard_timespan or cell.cfg.lease_timespan
        self.scan_period = scan_period
        self.workers: dict[int, ShardWorker] = {}
        self._wrap_monitor()

    def _wrap_monitor(self) -> None:
        mon = self.cell.monitor
        orig_acq, orig_lose = mon.on_acquire, mon.on_lose

        def on_acquire(pid: int, resource: str) -> None:
            orig_acq(pid, resource)
            w = self.workers.get(pid)
            if w is not None and resource.startswith("shard:"):
                w.owned.add(int(resource.split(":")[1]))

        def on_lose(pid: int, resource: str) -> None:
            orig_lose(pid, resource)
            w = self.workers.get(pid)
            if w is not None and resource.startswith("shard:"):
                w.owned.discard(int(resource.split(":")[1]))

        mon.on_acquire, mon.on_lose = on_acquire, on_lose

    # ------------------------------------------------------------------ API
    def add_worker(self, node: LeaseNode, target: int) -> ShardWorker:
        w = ShardWorker(node, target)
        self.workers[node.node_id] = w
        self._schedule_scan(w, first=True)
        return w

    def stall(self, node_id: int) -> None:
        """Straggler injection: the worker stops renewing (and scanning) but
        does NOT crash — its leases silently expire after T."""
        w = self.workers[node_id]
        w.stalled = True
        for k in list(w.owned):
            # stop renewal without sending Release (a true straggler says nothing)
            st = w.node.proposer._state(shard_resource(k))
            st.want = False
            if st.renew_timer is not None:
                st.renew_timer.cancel()
                st.renew_timer = None

    def unstall(self, node_id: int) -> None:
        self.workers[node_id].stalled = False

    def drain(self, node_id: int) -> None:
        """Graceful scale-down: release all shards immediately (§7)."""
        w = self.workers[node_id]
        w.target = 0
        for k in list(w.owned):
            w.node.proposer.release(shard_resource(k))

    # ------------------------------------------------------------ internals
    def _schedule_scan(self, w: ShardWorker, first: bool = False) -> None:
        delay = self.cell.env.random_backoff(0.0, self.scan_period) if first else self.scan_period
        self.cell.env.set_timer(w.node.addr, delay, lambda: self._scan(w))

    def _scan(self, w: ShardWorker) -> None:
        if not w.node.crashed and not w.stalled:
            # shed excess when the target was lowered (elastic rebalancing):
            # §7 release + hints means waiters pick these up within ~2 RTT
            excess = len(w.owned) - w.target
            for k in sorted(w.owned, reverse=True)[:max(excess, 0)]:
                w.node.proposer.release(shard_resource(k))
            deficit = w.target - len(w.owned)
            if deficit > 0:
                # prefer shards by (worker_id + i) stride to reduce collisions
                start = (w.node.node_id * 7919) % self.n_shards
                tried = 0
                for i in range(self.n_shards):
                    k = (start + i) % self.n_shards
                    res = shard_resource(k)
                    st = w.node.proposer._state(res)
                    if k not in w.owned and not st.want:
                        w.node.proposer.acquire(res, timespan=self.T, renew=True)
                        tried += 1
                        if tried >= deficit:
                            break
            # abandon pursuit of shards we failed to win (someone owns them)
            for k in range(self.n_shards):
                res = shard_resource(k)
                st = w.node.proposer._state(res)
                if st.want and not st.owner and k not in w.owned and len(w.owned) >= w.target:
                    st.want = False
        self._schedule_scan(w)

    # --------------------------------------------------------------- queries
    def coverage(self) -> float:
        """Fraction of shards currently owned by someone (global observer)."""
        owned = sum(
            1 for k in range(self.n_shards)
            if self.cell.monitor.owner_of(shard_resource(k)) is not None
        )
        return owned / max(self.n_shards, 1)

    def owner_map(self) -> dict[int, int]:
        out = {}
        for k in range(self.n_shards):
            o = self.cell.monitor.owner_of(shard_resource(k))
            if o is not None:
                out[k] = o
        return out


# --------------------------------------------------------------------------
# Fast path: at thousands of shards the per-object event sim is message-bound
# (§8 note + the Paxos-in-the-cloud per-message-overhead result), so large
# planes run on the dense lease_array engine instead — one batched array step
# advances every shard cell per tick.

ARRAY_DIRECTORY_MIN_SHARDS = 1024


def build_shard_manager(
    n_shards: int,
    *,
    cell: Optional[Cell] = None,
    cfg: Optional[CellConfig] = None,
    backend: str = "auto",
    shard_timespan: Optional[float] = None,
    scan_period: float = 1.0,
    **array_kwargs,
):
    """Pick the shard-lease backend.

    ``backend="event"`` -> :class:`ShardLeaseManager` over an existing
    :class:`Cell` (faithful per-message simulation; needs ``cell``).
    ``backend="array"`` -> :class:`~repro.lease_array.directory.LeaseArrayDirectory`
    (vectorized plane; thousands of shards per batched step).
    ``backend="auto"`` -> array when ``n_shards >= ARRAY_DIRECTORY_MIN_SHARDS``
    or when no cell was supplied.
    """
    if backend == "auto":
        backend = (
            "array"
            if cell is None or n_shards >= ARRAY_DIRECTORY_MIN_SHARDS
            else "event"
        )
    if backend == "array":
        from ..lease_array.directory import LeaseArrayDirectory

        c = cfg or (cell.cfg if cell is not None else None)
        if c is not None:
            array_kwargs.setdefault("n_acceptors", c.n_acceptors)
            # one directory tick ~ one scan period of the event manager, so
            # the configured timespan carries over as lease_ticks
            t = shard_timespan if shard_timespan is not None else c.lease_timespan
            array_kwargs.setdefault(
                "lease_ticks", max(int(round(t / scan_period)), 1)
            )
        return LeaseArrayDirectory(n_shards, **array_kwargs)
    if backend != "event":
        raise ValueError(f"unknown shard-lease backend {backend!r}")
    if cell is None:
        raise ValueError("event backend needs a built Cell")
    return ShardLeaseManager(
        cell, n_shards, shard_timespan=shard_timespan, scan_period=scan_period
    )
