"""RWKV6 (Finch) block: data-dependent-decay time mixing + channel mixing.

Training uses a *chunked* formulation: within a chunk of length Lc the
recurrence is evaluated in closed form with pairwise decay factors
``exp(cum[t-1] - cum[s])`` (always <= 1, numerically safe for any decay);
across chunks a ``lax.scan`` carries the (B, H, N, N) state. This jnp version
is the oracle for the Pallas kernel in ``repro.kernels.rwkv6`` (which uses the
matmul form with bounded decay — see kernel docs).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .schema import P, Schema


def rwkv_schema(cfg: ModelConfig) -> Schema:
    assert cfg.rwkv is not None
    d, f = cfg.d_model, cfg.d_ff
    lora = cfg.rwkv.decay_lora
    tm: Schema = {
        "mu": P((5, d), (None, "embed"), init="zeros"),  # r,k,v,g,w token-shift mixes
        "wr": P((d, d), ("embed", "rwkv_inner")),
        "wk": P((d, d), ("embed", "rwkv_inner")),
        "wv": P((d, d), ("embed", "rwkv_inner")),
        "wg": P((d, d), ("embed", "rwkv_inner")),
        "wo": P((d, d), ("rwkv_inner", "embed")),
        "w0": P((d,), ("embed",), init="decay_base"),
        "wa": P((d, lora), ("embed", None), scale=0.01),
        "wb": P((lora, d), (None, "rwkv_inner"), scale=0.01),
        "u": P((d,), ("embed",), init="zeros"),
        "ln": P((d,), ("embed",), init="ones"),
    }
    cm: Schema = {
        "mu": P((2, d), (None, "embed"), init="zeros"),  # k, r mixes
        "wk": P((d, f), ("embed", "mlp")),
        "wv": P((f, d), ("mlp", "embed")),
        "wr": P((d, d), ("embed", "rwkv_inner")),
    }
    return {"tm": tm, "cm": cm}


def _token_shift(x: jax.Array, prev: jax.Array) -> jax.Array:
    """x: (B,S,d); prev: (B,d) last token of the previous segment."""
    return jnp.concatenate([prev[:, None, :], x[:, :-1, :]], axis=1)


def _lerp(x, xs, mu):
    return x + (xs - x) * mu


def wkv_chunked(
    r: jax.Array,
    k: jax.Array,
    v: jax.Array,
    logw: jax.Array,
    u: jax.Array,
    state: jax.Array,
    chunk: int = 32,
):
    """Chunked WKV6 recurrence.

    r,k,v,logw: (B, S, H, N) with logw <= 0; u: (H, N);
    state: (B, H, N, N) mapping keys -> values. Returns (out (B,S,H,N), state').

    Per head:  o_t = r_t @ (S_{t-1} + diag(u) k_t^T v_t)
               S_t = diag(w_t) S_{t-1} + k_t^T v_t
    """
    b, s, h, n = r.shape
    if s % chunk != 0:
        pad = chunk - s % chunk
        z = lambda a: jnp.pad(a, ((0, 0), (0, pad), (0, 0), (0, 0)))
        r, k, v = z(r), z(k), z(v)
        logw = jnp.pad(logw, ((0, 0), (0, pad), (0, 0), (0, 0)))  # pad logw=0 (w=1)
        s_pad = s + pad
    else:
        s_pad = s
    nc = s_pad // chunk

    def to_chunks(a):  # (B, S, H, N) -> (nc, B, H, Lc, N)
        return jnp.moveaxis(a.reshape(b, nc, chunk, h, n), (1, 3), (0, 2))

    rc, kc, vc, wc = map(to_chunks, (r, k, v, logw))
    rc = rc.astype(jnp.float32)
    kc = kc.astype(jnp.float32)
    vc = vc.astype(jnp.float32)
    wc = wc.astype(jnp.float32)
    uf = u.astype(jnp.float32)

    tri = jnp.tril(jnp.ones((chunk, chunk), jnp.float32), k=-1)  # strict lower

    def body(S, inputs):
        rj, kj, vj, wj = inputs  # (B,H,Lc,N)
        cum = jnp.cumsum(wj, axis=2)  # inclusive, (B,H,Lc,N), decreasing
        cum_ex = cum - wj  # exclusive
        # pairwise decay factors exp(cum_ex[t] - cum[s]) for t > s, <= 1 always
        dmat = cum_ex[:, :, :, None, :] - cum[:, :, None, :, :]  # (B,H,T,S,N)
        fac = jnp.exp(jnp.minimum(dmat, 0.0))
        scores = jnp.einsum("bhtn,bhsn,bhtsn->bhts", rj, kj, fac) * tri
        diag = jnp.einsum("bhtn,bhtn->bht", rj * uf[None, :, None, :], kj)
        scores = scores + diag[..., None] * jnp.eye(chunk, dtype=jnp.float32)
        o_intra = jnp.einsum("bhts,bhsn->bhtn", scores, vj)
        # inter-chunk: decay from chunk start
        r_dec = rj * jnp.exp(cum_ex)
        o_inter = jnp.einsum("bhtn,bhnm->bhtm", r_dec, S)
        # state update: S' = diag(exp(cum_end)) S + sum_s exp(cum_end - cum_s) k_s^T v_s
        cum_end = cum[:, :, -1:, :]  # (B,H,1,N)
        k_dec = kj * jnp.exp(cum_end - cum)
        S_new = jnp.exp(cum_end[:, :, 0, :, None]) * S + jnp.einsum(
            "bhsn,bhsm->bhnm", k_dec, vj
        )
        return S_new, o_intra + o_inter

    state, outs = jax.lax.scan(body, state.astype(jnp.float32), (rc, kc, vc, wc))
    out = jnp.moveaxis(outs, (0, 2), (1, 3)).reshape(b, s_pad, h, n)[:, :s]
    return out, state


def wkv_step(r, k, v, logw, u, state):
    """Single-token recurrence (decode). r,k,v,logw: (B,H,N); state: (B,H,N,N)."""
    rf, kf, vf = (a.astype(jnp.float32) for a in (r, k, v))
    w = jnp.exp(logw.astype(jnp.float32))
    kv = kf[..., :, None] * vf[..., None, :]  # (B,H,N,N)
    o = jnp.einsum("bhn,bhnm->bhm", rf, state + u.astype(jnp.float32)[..., None] * kv)
    state = w[..., :, None] * state + kv
    return o, state


def _headnorm(x: jax.Array, scale: jax.Array, h: int, n: int, eps: float = 1e-5):
    """Per-head layernorm on (B,S,H*N)."""
    b, s, _ = x.shape
    xh = x.reshape(b, s, h, n).astype(jnp.float32)
    mu = xh.mean(-1, keepdims=True)
    var = jnp.square(xh - mu).mean(-1, keepdims=True)
    y = (xh - mu) * jax.lax.rsqrt(var + eps)
    return (y.reshape(b, s, h * n) * scale.astype(jnp.float32)).astype(x.dtype)


def apply_time_mix(cfg: ModelConfig, params, x: jax.Array, prev: jax.Array, state: jax.Array, *, chunk: int = 32):
    """x: (B,S,d); prev: (B,d); state: (B,H,N,N) -> (y, prev', state')."""
    hsize = cfg.rwkv.head_size
    h = cfg.d_model // hsize
    xs = _token_shift(x, prev)
    mu = params["mu"].astype(x.dtype)
    xr, xk, xv, xg, xw = (_lerp(x, xs, mu[i]) for i in range(5))
    r = xr @ params["wr"]
    k = xk @ params["wk"]
    v = xv @ params["wv"]
    g = jax.nn.silu(xg @ params["wg"])
    omega = params["w0"].astype(jnp.float32) + jnp.tanh(
        xw.astype(jnp.float32) @ params["wa"].astype(jnp.float32)
    ) @ params["wb"].astype(jnp.float32)
    logw = -jnp.exp(omega)  # <= 0 always
    b, s, d = x.shape
    shp = (b, s, h, hsize)
    out, state = wkv_chunked(
        r.reshape(shp), k.reshape(shp), v.reshape(shp), logw.reshape(shp),
        params["u"].astype(jnp.float32).reshape(h, hsize), state, chunk=chunk,
    )
    out = _headnorm(out.astype(x.dtype).reshape(b, s, d), params["ln"], h, hsize)
    y = (out * g) @ params["wo"]
    return y, x[:, -1, :], state


def apply_time_mix_step(cfg: ModelConfig, params, x: jax.Array, prev: jax.Array, state: jax.Array):
    """Decode: x (B,1,d)."""
    hsize = cfg.rwkv.head_size
    h = cfg.d_model // hsize
    b = x.shape[0]
    xt = x[:, 0, :]
    mu = params["mu"].astype(x.dtype)
    xr, xk, xv, xg, xw = (xt + (prev - xt) * mu[i] for i in range(5))
    r = xr @ params["wr"]
    k = xk @ params["wk"]
    v = xv @ params["wv"]
    g = jax.nn.silu(xg @ params["wg"])
    omega = params["w0"].astype(jnp.float32) + jnp.tanh(
        xw.astype(jnp.float32) @ params["wa"].astype(jnp.float32)
    ) @ params["wb"].astype(jnp.float32)
    logw = -jnp.exp(omega)
    shp = (b, h, hsize)
    o, state = wkv_step(
        r.reshape(shp), k.reshape(shp), v.reshape(shp), logw.reshape(shp),
        params["u"].astype(jnp.float32).reshape(h, hsize), state,
    )
    o = o.astype(x.dtype).reshape(b, 1, cfg.d_model)
    o = _headnorm(o, params["ln"], h, hsize)
    y = (o[:, 0] * g) @ params["wo"]
    return y[:, None, :], xt, state


def apply_channel_mix(cfg: ModelConfig, params, x: jax.Array, prev: jax.Array):
    """x: (B,S,d); prev: (B,d) -> (y, prev')."""
    xs = _token_shift(x, prev)
    mu = params["mu"].astype(x.dtype)
    xk = _lerp(x, xs, mu[0])
    xr = _lerp(x, xs, mu[1])
    k = jnp.square(jax.nn.relu(xk @ params["wk"]))
    y = jax.nn.sigmoid(xr @ params["wr"]) * (k @ params["wv"])
    return y, x[:, -1, :]


def apply_channel_mix_step(cfg: ModelConfig, params, x: jax.Array, prev: jax.Array):
    xt = x[:, 0, :]
    mu = params["mu"].astype(x.dtype)
    xk = xt + (prev - xt) * mu[0]
    xr = xt + (prev - xt) * mu[1]
    k = jnp.square(jax.nn.relu(xk @ params["wk"]))
    y = jax.nn.sigmoid(xr @ params["wr"]) * (k @ params["wv"])
    return y[:, None, :], xt
