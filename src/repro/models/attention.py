"""GQA attention: full / chunked (online-softmax, flash-style in jnp) / decode.

The chunked implementation is the pure-jnp oracle for the Pallas flash kernel
in ``repro.kernels.flash_attention`` and is the default for training/prefill
(it never materializes the (Sq, Sk) score matrix).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .layers import apply_rope, rope_freqs
from .schema import P, Schema

NEG_INF = -1e30


def attn_schema(cfg: ModelConfig) -> Schema:
    d, hq, hkv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    s: Schema = {
        "wq": P((d, hq, dh), ("embed", "heads", "head")),
        "wk": P((d, hkv, dh), ("embed", "kv_heads", "head")),
        "wv": P((d, hkv, dh), ("embed", "kv_heads", "head")),
        "wo": P((hq, dh, d), ("heads", "head", "embed")),
    }
    if cfg.qkv_bias:
        s["bq"] = P((hq, dh), ("heads", "head"), init="zeros")
        s["bk"] = P((hkv, dh), ("kv_heads", "head"), init="zeros")
        s["bv"] = P((hkv, dh), ("kv_heads", "head"), init="zeros")
    if cfg.linear_bias:
        s["bo"] = P((d,), ("embed",), init="zeros")
    return s


def qkv_project(cfg: ModelConfig, params, x: jax.Array, positions: Optional[jax.Array]):
    """x: (B, S, d) -> q (B,S,Hq,Dh), k/v (B,S,Hkv,Dh); RoPE applied if configured."""
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
    if cfg.qkv_bias:
        q, k, v = q + params["bq"], k + params["bk"], v + params["bv"]
    if cfg.use_rope and positions is not None:
        inv = rope_freqs(cfg)
        q = apply_rope(q, positions, inv)
        k = apply_rope(k, positions, inv)
    return q, k, v


def out_project(cfg: ModelConfig, params, o: jax.Array) -> jax.Array:
    y = jnp.einsum("bshk,hkd->bsd", o, params["wo"])
    if cfg.linear_bias:
        y = y + params["bo"]
    return y


def _group(q: jax.Array, n_kv: int) -> jax.Array:
    """(B,S,Hq,Dh) -> (B,S,Hkv,G,Dh)."""
    b, s, hq, dh = q.shape
    return q.reshape(b, s, n_kv, hq // n_kv, dh)


def attention_full(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool,
    window: Optional[int] = None,
    q_offset: jax.Array | int = 0,
    kv_len: Optional[jax.Array] = None,
) -> jax.Array:
    """Reference (score-matrix materializing) attention.

    q: (B,Sq,Hq,Dh); k,v: (B,Sk,Hkv,Dh). Returns (B,Sq,Hq,Dh).
    ``q_offset`` is the absolute position of q[0] (decode). ``kv_len`` masks
    cache slots >= kv_len (decode with a fixed-size cache).
    """
    b, sq, hq, dh = q.shape
    hkv = k.shape[2]
    qg = _group(q, hkv)
    scale = dh**-0.5
    scores = jnp.einsum("bsngk,btnk->bngst", qg, k).astype(jnp.float32) * scale
    q_pos = q_offset + jnp.arange(sq)
    k_pos = jnp.arange(k.shape[1])
    mask = jnp.ones((sq, k.shape[1]), bool)
    if causal:
        mask &= k_pos[None, :] <= q_pos[:, None]
    if window is not None:
        mask &= k_pos[None, :] > q_pos[:, None] - window
    scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    if kv_len is not None:
        valid = k_pos < kv_len
        scores = jnp.where(valid[None, None, None, None, :], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    o = jnp.einsum("bngst,btnk->bsngk", p, v)
    return o.reshape(b, sq, hq, dh)


def attention_chunked(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool,
    window: Optional[int] = None,
    chunk: int = 512,
    q_offset: jax.Array | int = 0,
    kv_len: Optional[jax.Array] = None,
    skip_out_of_window: bool = False,
) -> jax.Array:
    """Online-softmax attention scanning over KV chunks (no (Sq,Sk) matrix).

    With ``skip_out_of_window`` (SWA optimization), chunks fully outside the
    sliding window contribute via a no-op branch — the flops still appear in
    the HLO (lax.cond both branches are compiled) but the achieved-perf model
    counts only in-window work; the Pallas kernel realizes the skip for real.
    """
    b, sq, hq, dh = q.shape
    sk = k.shape[1]
    hkv = k.shape[2]
    g = hq // hkv
    if sk % chunk != 0:
        pad = chunk - sk % chunk
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        if kv_len is None:
            kv_len = sk
        sk = k.shape[1]
    n_chunks = sk // chunk
    qg = _group(q, hkv).astype(jnp.float32)
    scale = dh**-0.5
    q_pos = q_offset + jnp.arange(sq)

    kc = k.reshape(b, n_chunks, chunk, hkv, dh)
    vc = v.reshape(b, n_chunks, chunk, hkv, dh)

    def body(carry, inputs):
        m, l, acc = carry
        kj, vj, j = inputs
        k_pos = j * chunk + jnp.arange(chunk)
        s = jnp.einsum("bsngk,btnk->bngst", qg, kj.astype(jnp.float32)) * scale
        mask = jnp.ones((sq, chunk), bool)
        if causal:
            mask &= k_pos[None, :] <= q_pos[:, None]
        if window is not None:
            mask &= k_pos[None, :] > q_pos[:, None] - window
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        if kv_len is not None:
            s = jnp.where((k_pos < kv_len)[None, None, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bngst,btnk->bngsk", p, vj.astype(jnp.float32)
        )
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, hkv, g, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hkv, g, sq), jnp.float32)
    acc0 = jnp.zeros((b, hkv, g, sq, dh), jnp.float32)
    kc_t = jnp.moveaxis(kc, 1, 0)
    vc_t = jnp.moveaxis(vc, 1, 0)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, acc0), (kc_t, vc_t, jnp.arange(n_chunks)))
    o = acc / jnp.maximum(l[..., None], 1e-30)
    o = jnp.moveaxis(o, 3, 1).reshape(b, sq, hq, dh)
    return o.astype(q.dtype)


def attention(
    cfg: ModelConfig,
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    q_offset: jax.Array | int = 0,
    kv_len: Optional[jax.Array] = None,
    impl: Optional[str] = None,
) -> jax.Array:
    impl = impl or ("full" if q.shape[1] * k.shape[1] <= 256 * 256 else "chunked")
    if impl == "full":
        return attention_full(
            q, k, v, causal=causal, window=cfg.sliding_window, q_offset=q_offset, kv_len=kv_len
        )
    return attention_chunked(
        q,
        k,
        v,
        causal=causal,
        window=cfg.sliding_window,
        chunk=min(cfg.attn_chunk, k.shape[1]),
        q_offset=q_offset,
        kv_len=kv_len,
    )
