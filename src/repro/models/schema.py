"""Schema-driven parameter trees.

A *schema* is a nested dict whose leaves are ``P`` descriptors (shape, logical
axes, init kind). Both the parameter pytree and the logical-sharding pytree are
derived from the same schema, so they can never diverge structurally.

Logical axis names used across the model zoo (mapped to mesh axes by
``repro.parallel.sharding``):

  embed       d_model                    -> replicated
  vocab       vocabulary                 -> "model"
  heads       merged q heads             -> "model"
  kv_heads    merged kv heads            -> "model" when divisible else repl.
  head        per-head dim               -> replicated
  mlp         FFN hidden                 -> "model"
  experts     MoE expert index           -> data axes (EP) when divisible
  expert_ff   per-expert FFN hidden      -> "model"
  ssm_inner   SSM expanded width         -> "model"
  rwkv_inner  RWKV projection output     -> "model"
  layers      stacked scan axis          -> replicated
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class P:
    shape: tuple
    axes: tuple  # logical axis names (or None), len == len(shape)
    init: str = "normal"  # normal | zeros | ones | a_log | decay_base
    scale: Optional[float] = None  # stddev override; default fan-in scaled

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


Schema = dict  # nested dict[str, "Schema | P"]


def _leaf_paths(schema: Schema, prefix=()):  # depth-first, deterministic order
    for k in sorted(schema):
        v = schema[k]
        if isinstance(v, P):
            yield prefix + (k,), v
        else:
            yield from _leaf_paths(v, prefix + (k,))


def _set_path(tree: dict, path: tuple, value) -> None:
    for k in path[:-1]:
        tree = tree.setdefault(k, {})
    tree[path[-1]] = value


def _fan_in(shape: tuple) -> int:
    if len(shape) == 1:
        return shape[0]
    # last dim is the output dim by convention in this codebase
    return int(np.prod(shape[:-1])) or 1


def _init_leaf(key: jax.Array, p: P, dtype) -> jax.Array:
    if p.init == "zeros":
        return jnp.zeros(p.shape, dtype)
    if p.init == "ones":
        return jnp.ones(p.shape, dtype)
    if p.init == "a_log":
        # mamba-style: A = -(1..state) broadcast over the inner dim
        s = p.shape[-1]
        a = jnp.tile(jnp.arange(1, s + 1, dtype=jnp.float32), p.shape[:-1] + (1,))
        return jnp.log(a).astype(dtype)
    if p.init == "decay_base":
        # rwkv base decay omega_0: spread in [-6, 1] across channels
        n = p.shape[-1]
        r = jnp.linspace(0.0, 1.0, n, dtype=jnp.float32)
        base = -6.0 + 7.0 * (r**1.5)
        return jnp.broadcast_to(base, p.shape).astype(dtype)
    scale = p.scale if p.scale is not None else 1.0 / math.sqrt(_fan_in(p.shape))
    return (jax.random.normal(key, p.shape, jnp.float32) * scale).astype(dtype)


def init_params(schema: Schema, key: jax.Array, dtype=jnp.float32) -> dict:
    params: dict = {}
    for path, p in _leaf_paths(schema):
        sub = jax.random.fold_in(key, hash("/".join(path)) & 0x7FFFFFFF)
        _set_path(params, path, _init_leaf(sub, p, dtype))
    return params


def abstract_params(schema: Schema, dtype=jnp.float32) -> dict:
    """ShapeDtypeStruct tree (for dry-runs — no allocation)."""
    tree: dict = {}
    for path, p in _leaf_paths(schema):
        _set_path(tree, path, jax.ShapeDtypeStruct(p.shape, dtype))
    return tree


def logical_axes(schema: Schema) -> dict:
    tree: dict = {}
    for path, p in _leaf_paths(schema):
        _set_path(tree, path, p.axes)
    return tree


def stacked(schema: Schema, n: int) -> Schema:
    """Add a leading ``layers`` axis of size n to every leaf (scan-over-layers)."""
    out: dict = {}
    for path, p in _leaf_paths(schema):
        _set_path(out, path, P((n,) + p.shape, ("layers",) + p.axes, p.init, p.scale))
    return out


def count_params(schema: Schema) -> int:
    return sum(int(np.prod(p.shape)) for _, p in _leaf_paths(schema))
