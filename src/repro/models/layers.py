"""Shared primitive layers: norms, MLPs, rotary / sinusoidal positions."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .schema import P, Schema


# ----------------------------------------------------------------------------
# Norms
# ----------------------------------------------------------------------------
def norm_schema(cfg: ModelConfig) -> Schema:
    s: Schema = {"scale": P((cfg.d_model,), ("embed",), init="ones")}
    if cfg.norm_type == "layernorm":
        s["bias"] = P((cfg.d_model,), ("embed",), init="zeros")
    return s


def apply_norm(cfg: ModelConfig, params, x: jax.Array) -> jax.Array:
    xf = x.astype(jnp.float32)
    if cfg.norm_type == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + cfg.norm_eps)
        y = y * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    else:  # rmsnorm
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + cfg.norm_eps) * params["scale"].astype(jnp.float32)
    return y.astype(x.dtype)


# ----------------------------------------------------------------------------
# Dense MLP (SwiGLU or plain)
# ----------------------------------------------------------------------------
def mlp_schema(cfg: ModelConfig) -> Schema:
    d, f = cfg.d_model, cfg.d_ff
    s: Schema = {
        "w1": P((d, f), ("embed", "mlp")),
        "w2": P((f, d), ("mlp", "embed")),
    }
    if cfg.mlp_gated:
        s["w3"] = P((d, f), ("embed", "mlp"))
    if cfg.linear_bias:
        s["b1"] = P((f,), ("mlp",), init="zeros")
        s["b2"] = P((d,), ("embed",), init="zeros")
        if cfg.mlp_gated:
            s["b3"] = P((f,), ("mlp",), init="zeros")
    return s


def _act(name: str, x: jax.Array) -> jax.Array:
    if name == "gelu":
        return jax.nn.gelu(x)
    return jax.nn.silu(x)


def apply_mlp(cfg: ModelConfig, params, x: jax.Array) -> jax.Array:
    h = x @ params["w1"]
    if cfg.linear_bias:
        h = h + params["b1"]
    h = _act(cfg.mlp_act, h)
    if cfg.mlp_gated:
        g = x @ params["w3"]
        if cfg.linear_bias:
            g = g + params["b3"]
        h = h * g
    y = h @ params["w2"]
    if cfg.linear_bias:
        y = y + params["b2"]
    return y


# ----------------------------------------------------------------------------
# Positions
# ----------------------------------------------------------------------------
def rope_freqs(cfg: ModelConfig) -> jax.Array:
    dh = cfg.head_dim
    inv = 1.0 / (cfg.rope_theta ** (jnp.arange(0, dh, 2, dtype=jnp.float32) / dh))
    return inv  # (dh/2,)


def apply_rope(x: jax.Array, positions: jax.Array, inv_freq: jax.Array) -> jax.Array:
    """x: (..., S, H, Dh); positions: (S,) or (B, S)."""
    angles = positions[..., None].astype(jnp.float32) * inv_freq  # (..., S, dh/2)
    if angles.ndim == 2:  # (S, dh/2) -> broadcast over batch/heads
        angles = angles[None, :, None, :]
    else:  # (B, S, dh/2)
        angles = angles[:, :, None, :]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(seq_len: int, d_model: int, offset: jax.Array | int = 0) -> jax.Array:
    pos = (jnp.arange(seq_len, dtype=jnp.float32) + offset)[:, None]
    dim = jnp.arange(0, d_model, 2, dtype=jnp.float32)[None, :]
    angle = pos / jnp.power(10000.0, dim / d_model)
    pe = jnp.zeros((seq_len, d_model), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(angle))
    pe = pe.at[:, 1::2].set(jnp.cos(angle[:, : (d_model + 1) // 2]))
    return pe
