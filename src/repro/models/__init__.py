from .frontends import input_specs, synth_inputs
from .transformer import (
    abstract_cache,
    abstract_model,
    decode_step,
    forward,
    init_cache,
    init_model,
    loss_fn,
    model_axes,
    model_schema,
)

__all__ = [
    "abstract_cache",
    "abstract_model",
    "decode_step",
    "forward",
    "init_cache",
    "init_model",
    "input_specs",
    "loss_fn",
    "model_axes",
    "model_schema",
    "synth_inputs",
]
