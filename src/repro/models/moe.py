"""Mixture-of-experts block.

Two implementations sharing one router:

- ``dispatch`` (default): MaxText-style group-capacity one-hot dispatch.
  Tokens are processed in groups; per (group, expert) capacity buffers are
  built with cumsum position indices (no sort), all compute is einsums, so
  GSPMD can shard it: groups follow the batch (data) sharding, the expert
  axis is sharded over data axes when divisible (true expert parallelism —
  GSPMD materializes the G->E resharding as all-to-alls) and the per-expert
  hidden dim is sharded over "model".
- ``dense``: every expert computes every token, combined with router weights.
  Simple, exact (no capacity drops), top_k/n_experts-fraction wasteful; used
  as the correctness oracle and as a fallback.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..parallel.sharding import hint
from .layers import _act
from .schema import P, Schema


def moe_schema(cfg: ModelConfig) -> Schema:
    assert cfg.moe is not None
    d, e, fe = cfg.d_model, cfg.moe.n_experts, cfg.moe.d_expert
    s: Schema = {
        "router": P((d, e), ("embed", None), scale=1.0 / math.sqrt(d)),
        "wi": P((e, d, fe), ("experts", "embed", "expert_ff")),
        "wo": P((e, fe, d), ("experts", "expert_ff", "embed")),
    }
    if cfg.mlp_gated:
        s["wg"] = P((e, d, fe), ("experts", "embed", "expert_ff"))
    return s


def router_topk(cfg: ModelConfig, params, x: jax.Array):
    """x: (..., d) -> gates (..., k) normalized, idx (..., k), aux load-balance loss."""
    moe = cfg.moe
    logits = (x.astype(jnp.float32) @ params["router"].astype(jnp.float32))
    gates_all = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(gates_all, moe.top_k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    # Switch-style load-balancing aux loss: E * sum_e f_e * p_e
    tokens = gates_all.reshape(-1, moe.n_experts)
    me = tokens.mean(0)
    onehot = jax.nn.one_hot(idx.reshape(-1, moe.top_k), moe.n_experts, dtype=jnp.float32)
    ce = onehot.sum(1).mean(0) / moe.top_k
    aux = moe.n_experts * jnp.sum(me * ce)
    return gates, idx, aux


def _expert_ffn(cfg: ModelConfig, params, xb: jax.Array) -> jax.Array:
    """xb: (..., E, C, d) batched per-expert FFN -> same shape."""
    h = jnp.einsum("...ecd,edf->...ecf", xb, params["wi"])
    h = _act(cfg.mlp_act, h)
    if cfg.mlp_gated:
        h = h * jnp.einsum("...ecd,edf->...ecf", xb, params["wg"])
    return jnp.einsum("...ecf,efd->...ecd", h, params["wo"])


def moe_dispatch(cfg: ModelConfig, params, x: jax.Array, *, group_size: int = 512):
    """Group-capacity dispatch. x: (B, S, d) -> (y, aux_loss)."""
    moe = cfg.moe
    b, s, d = x.shape
    t = b * s
    tg = min(group_size, t)
    if t % tg != 0:  # group size must divide tokens; shrink to a divisor
        tg = math.gcd(t, tg)
    g = t // tg
    cap = max(1, math.ceil(tg * moe.top_k * moe.capacity_factor / moe.n_experts))
    # round capacity up to a multiple of 4 for friendlier tiling
    cap = (cap + 3) // 4 * 4

    xg = x.reshape(g, tg, d)
    gates, idx, aux = router_topk(cfg, params, xg)  # (g,tg,k)

    # position of each (token, slot) within its expert, cumsum over the group
    onehot_e = jax.nn.one_hot(idx, moe.n_experts, dtype=jnp.float32)  # (g,tg,k,e)
    flat = onehot_e.reshape(g, tg * moe.top_k, moe.n_experts)
    pos = jnp.cumsum(flat, axis=1) - flat  # (g, tg*k, e)
    pos_tok = jnp.sum(flat * pos, axis=-1).reshape(g, tg, moe.top_k)  # (g,tg,k)
    keep = pos_tok < cap

    dispatch = jnp.zeros((g, tg, moe.n_experts, cap), jnp.float32)
    combine = jnp.zeros((g, tg, moe.n_experts, cap), jnp.float32)
    for kk in range(moe.top_k):  # k is small (<=8); unrolled outer products
        oc = jax.nn.one_hot(pos_tok[:, :, kk], cap, dtype=jnp.float32)
        oc = oc * keep[:, :, kk, None]
        ec = onehot_e[:, :, kk, :, None] * oc[:, :, None, :]  # (g,tg,e,cap)
        dispatch = dispatch + ec
        combine = combine + ec * gates[:, :, kk, None, None]

    xb = jnp.einsum("gtd,gtec->gecd", xg, dispatch.astype(x.dtype))
    # Optional EP constraints (active only when the run's sharding rules
    # define "moe_group"): pin the capacity buffers to expert-sharded layout,
    # forcing GSPMD to all-to-all activations instead of gathering expert
    # weights across the data axes. See EXPERIMENTS.md §Perf (kimi-k2).
    xb = hint(xb, ("moe_group", "experts", None, "embed"))
    yb = _expert_ffn(cfg, params, xb)
    yb = hint(yb, ("moe_group", "experts", None, "embed"))
    y = jnp.einsum("gecd,gtec->gtd", yb, combine.astype(x.dtype))
    dropped = 1.0 - jnp.mean(keep.astype(jnp.float32))
    return y.reshape(b, s, d), aux, dropped


def moe_dense(cfg: ModelConfig, params, x: jax.Array):
    """Oracle: compute all experts for all tokens, weighted-combine."""
    moe = cfg.moe
    b, s, d = x.shape
    gates, idx, aux = router_topk(cfg, params, x)  # (b,s,k)
    weights = jnp.zeros((b, s, moe.n_experts), jnp.float32)
    for kk in range(moe.top_k):
        weights = weights + jax.nn.one_hot(idx[:, :, kk], moe.n_experts) * gates[:, :, kk, None]
    xb = x[:, :, None, None, :]  # (b,s,1,1,d) broadcast as capacity buffer of 1
    xe = jnp.broadcast_to(xb, (b, s, moe.n_experts, 1, d))
    ye = _expert_ffn(cfg, params, xe.reshape(b * s, moe.n_experts, 1, d))
    ye = ye.reshape(b, s, moe.n_experts, d)
    y = jnp.einsum("bsed,bse->bsd", ye, weights.astype(x.dtype))
    return y, aux, jnp.float32(0.0)


def apply_moe(
    cfg: ModelConfig,
    params,
    x: jax.Array,
    *,
    impl: str = "dispatch",
    group_size: int = 512,
):
    if impl == "dense":
        return moe_dense(cfg, params, x)
    return moe_dispatch(cfg, params, x, group_size=group_size)
