"""Mamba-style selective SSM head (used by Hymba's parallel attn+SSM blocks).

Diagonal state-space recurrence with input-dependent dt/B/C:
    h_t = exp(dt_t * A) * h_{t-1} + dt_t * B_t * x_t        (per channel, state)
    y_t = C_t . h_t + D * x_t
Training evaluates chunks with ``jax.lax.associative_scan`` (first-order linear
recurrence), scanning chunk-to-chunk to bound the materialized state tensor.
No conv1d frontend (documented simplification — see DESIGN.md).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .schema import P, Schema


def ssm_schema(cfg: ModelConfig) -> Schema:
    assert cfg.ssm is not None
    d, di, st, r = cfg.d_model, cfg.ssm.d_inner, cfg.ssm.state_size, cfg.ssm.dt_rank
    return {
        "in_proj": P((d, 2 * di), ("embed", "ssm_inner")),
        "x_proj": P((di, r + 2 * st), ("ssm_inner", None)),
        "dt_proj": P((r, di), (None, "ssm_inner")),
        "dt_bias": P((di,), ("ssm_inner",), init="zeros"),
        "a_log": P((di, st), ("ssm_inner", None), init="a_log"),
        "d_skip": P((di,), ("ssm_inner",), init="ones"),
        "out_proj": P((di, d), ("ssm_inner", "embed")),
    }


def _selective(params, x: jax.Array, cfg: ModelConfig):
    """x: (B,S,di) -> (da (B,S,di,st), db_x (B,S,di,st), C (B,S,st), dt (B,S,di))."""
    r, st = cfg.ssm.dt_rank, cfg.ssm.state_size
    proj = x @ params["x_proj"]  # (B,S,r+2st)
    dt_r, bmat, cmat = jnp.split(proj, [r, r + st], axis=-1)
    dt = jax.nn.softplus(dt_r @ params["dt_proj"] + params["dt_bias"])  # (B,S,di)
    a = -jnp.exp(params["a_log"].astype(jnp.float32))  # (di, st), negative
    da = jnp.exp(dt.astype(jnp.float32)[..., None] * a)  # (B,S,di,st) in (0,1)
    db_x = (dt * x).astype(jnp.float32)[..., None] * bmat.astype(jnp.float32)[..., None, :]
    return da, db_x, cmat, dt


def ssm_scan(params, x: jax.Array, state: jax.Array, cfg: ModelConfig, *, chunk: int = 64):
    """x: (B,S,di); state: (B,di,st) -> (y (B,S,di), state')."""
    b, s, di = x.shape
    st = cfg.ssm.state_size
    da, db, cmat, _ = _selective(params, x, cfg)
    pad = (-s) % chunk
    if pad:
        da = jnp.pad(da, ((0, 0), (0, pad), (0, 0), (0, 0)), constant_values=1.0)
        db = jnp.pad(db, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nc = (s + pad) // chunk

    def chunks(a):  # (B, S, di, st) -> (nc, B, Lc, di, st)
        return jnp.moveaxis(a.reshape(b, nc, chunk, di, st), 1, 0)

    da_c, db_c = chunks(da), chunks(db)

    def body(h0, inp):
        a_j, b_j = inp  # (B,Lc,di,st)

        def combine(l, r_):
            al, bl = l
            ar, br = r_
            return al * ar, bl * ar + br

        aa, bb = jax.lax.associative_scan(combine, (a_j, b_j), axis=1)
        h = aa * h0[:, None] + bb  # (B,Lc,di,st)
        return h[:, -1], h

    state, hs = jax.lax.scan(body, state.astype(jnp.float32), (da_c, db_c))
    h_all = jnp.moveaxis(hs, 0, 1).reshape(b, s + pad, di, st)[:, :s]
    y = jnp.einsum("bsdn,bsn->bsd", h_all, cmat.astype(jnp.float32))
    y = y + x.astype(jnp.float32) * params["d_skip"].astype(jnp.float32)
    return y.astype(x.dtype), state


def apply_ssm(cfg: ModelConfig, params, xres: jax.Array, state: jax.Array):
    """Full SSM branch: in_proj -> selective scan -> gate -> out_proj."""
    di = cfg.ssm.d_inner
    xz = xres @ params["in_proj"]
    x, z = jnp.split(xz, [di], axis=-1)
    y, state = ssm_scan(params, x, state, cfg)
    y = y * jax.nn.silu(z)
    return y @ params["out_proj"], state


def apply_ssm_step(cfg: ModelConfig, params, xres: jax.Array, state: jax.Array):
    """Decode: xres (B,1,d); state (B,di,st)."""
    di = cfg.ssm.d_inner
    xz = xres @ params["in_proj"]
    x, z = jnp.split(xz, [di], axis=-1)
    da, db, cmat, _ = _selective(params, x, cfg)
    state = da[:, 0] * state.astype(jnp.float32) + db[:, 0]  # (B,di,st)
    y = jnp.einsum("bdn,bn->bd", state, cmat[:, 0].astype(jnp.float32))
    y = y + x[:, 0].astype(jnp.float32) * params["d_skip"].astype(jnp.float32)
    y = (y.astype(xres.dtype) * jax.nn.silu(z[:, 0]))[:, None, :]
    return y @ params["out_proj"], state
