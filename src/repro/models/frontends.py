"""Modality frontend STUBS + input_specs (per assignment: [audio]/[vlm] entries
specify the transformer backbone only; ``input_specs()`` provides precomputed
frame/patch embeddings as ShapeDtypeStructs for the dry-run and the smoke
tests synthesize them with a deterministic PRNG)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig, ShapeConfig
from .transformer import abstract_cache


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of (arch, shape).

    train   -> loss_fn/train_step inputs: tokens+labels (+frontend embeds)
    prefill -> forward(..., emit_cache=True) inputs: tokens (+frontend embeds)
    decode  -> decode_step inputs: cache + one token per sequence + position
    """
    b, s = shape.global_batch, shape.seq_len
    dt = jnp.dtype(cfg.dtype)
    tok = lambda *sh: jax.ShapeDtypeStruct(sh, jnp.int32)

    def text_inputs(with_labels: bool) -> dict:
        d: dict = {}
        if cfg.frontend == "vision":
            p = cfg.n_frontend_tokens
            d["patch_embeds"] = jax.ShapeDtypeStruct((b, p, cfg.d_model), dt)
            d["tokens"] = tok(b, s - p)
            if with_labels:
                d["labels"] = tok(b, s - p)
        elif cfg.enc_dec:
            d["frames"] = jax.ShapeDtypeStruct((b, cfg.encoder_seq, cfg.d_model), dt)
            d["tokens"] = tok(b, s)
            if with_labels:
                d["labels"] = tok(b, s)
        else:
            d["tokens"] = tok(b, s)
            if with_labels:
                d["labels"] = tok(b, s)
        return d

    if shape.kind == "train":
        return {"batch": text_inputs(with_labels=True)}
    if shape.kind == "prefill":
        return {"batch": text_inputs(with_labels=False)}
    # decode: one new token against a cache of seq_len
    return {
        "cache": abstract_cache(cfg, b, s),
        "tokens": tok(b, 1),
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
    }


def synth_inputs(cfg: ModelConfig, shape: ShapeConfig, key: jax.Array) -> dict:
    """Concrete random inputs matching input_specs (smoke tests / examples)."""
    specs = input_specs(cfg, shape)

    def make(path, s):
        k = jax.random.fold_in(key, hash(path) & 0x7FFFFFFF)
        if s.dtype == jnp.int32 and s.shape == ():
            return jnp.int32(0)
        if s.dtype == jnp.int32:
            return jax.random.randint(k, s.shape, 0, min(cfg.vocab_size, 1000), jnp.int32)
        return jax.random.normal(k, s.shape, jnp.float32).astype(s.dtype) * 0.02

    def go(tree, prefix=""):
        if isinstance(tree, dict):
            return {k: go(v, prefix + "/" + k) for k, v in tree.items()}
        return make(prefix, tree)

    out = go(specs)
    if shape.kind == "decode":
        # a fresh cache must be empty (slot_pos = -1), not random
        from .transformer import init_cache

        out["cache"] = init_cache(cfg, shape.global_batch, shape.seq_len)
        out["pos"] = jnp.int32(0)
    return out
