"""Model assembly: schema, forward (train/prefill), decode step, loss.

One code path covers all 10 assigned architectures, driven by ``ModelConfig``:
dense GQA LMs, MoE (dispatch/dense), RWKV6, Hymba hybrid, Whisper enc-dec and
the VLM/audio stub-frontend variants. Layers are stacked and scanned
(``lax.scan``) so compile time is O(1) in depth; decoding threads a per-layer
cache pytree through the same scan.
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..parallel.sharding import hint
from . import rwkv6, ssm
from .attention import attention_full, attn_schema, out_project, qkv_project
from .layers import apply_mlp, apply_norm, mlp_schema, norm_schema, sinusoidal_positions
from .moe import apply_moe, moe_schema
from .schema import P, Schema, abstract_params, init_params, logical_axes, stacked

AUX_COEF = 0.01  # MoE load-balance loss coefficient


def cast_tree(tree, dtype):
    """Cast floating-point leaves to the compute dtype (mixed precision:
    fp32 master params, bf16 compute)."""
    dt = jnp.dtype(dtype)
    return jax.tree.map(
        lambda a: a.astype(dt) if jnp.issubdtype(a.dtype, jnp.floating) else a, tree
    )


# ---------------------------------------------------------------------------
# Schema
# ---------------------------------------------------------------------------
def block_schema(cfg: ModelConfig, *, encoder: bool = False, decoder_cross: bool = False) -> Schema:
    if cfg.attention_free:
        s = rwkv6.rwkv_schema(cfg)
        s["norm1"] = norm_schema(cfg)
        s["norm2"] = norm_schema(cfg)
        return s
    s = {"norm1": norm_schema(cfg), "attn": attn_schema(cfg), "norm2": norm_schema(cfg)}
    if cfg.moe is not None and not encoder:
        s["moe"] = moe_schema(cfg)
    else:
        s["mlp"] = mlp_schema(cfg)
    if cfg.hybrid_parallel_ssm and not encoder:
        s["ssm"] = ssm.ssm_schema(cfg)
        s["branch_scale"] = P((2,), (None,), init="ones")
    if decoder_cross:
        s["norm_c"] = norm_schema(cfg)
        s["cross"] = attn_schema(cfg)
    return s


def model_schema(cfg: ModelConfig) -> Schema:
    d, v = cfg.d_model, cfg.vocab_size
    s: Schema = {
        "embed": P((v, d), ("vocab", "embed"), scale=0.02),
        "final_norm": norm_schema(cfg),
        "layers": stacked(block_schema(cfg, decoder_cross=cfg.enc_dec), cfg.n_layers),
    }
    if not cfg.tie_embeddings:
        s["unembed"] = P((d, v), ("embed", "vocab"))
    if cfg.enc_dec:
        s["encoder"] = {
            "layers": stacked(block_schema(cfg, encoder=True), cfg.n_encoder_layers),
            "final_norm": norm_schema(cfg),
        }
    return s


def init_model(cfg: ModelConfig, key: jax.Array):
    return init_params(model_schema(cfg), key, dtype=jnp.dtype(cfg.param_dtype))


def abstract_model(cfg: ModelConfig):
    return abstract_params(model_schema(cfg), dtype=jnp.dtype(cfg.param_dtype))


def model_axes(cfg: ModelConfig):
    return logical_axes(model_schema(cfg))


# ---------------------------------------------------------------------------
# Cache
# ---------------------------------------------------------------------------
def cache_spec(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    """Shapes/dtypes of the decode cache (leading ``layers`` axis on leaves)."""
    L = cfg.n_layers
    spec: dict = {}
    if cfg.attention_free:
        h = cfg.d_model // cfg.rwkv.head_size
        n = cfg.rwkv.head_size
        spec = {
            "wkv": ((L, batch, h, n, n), jnp.float32),
            "tm_prev": ((L, batch, cfg.d_model), jnp.dtype(cfg.dtype)),
            "cm_prev": ((L, batch, cfg.d_model), jnp.dtype(cfg.dtype)),
        }
        return spec
    sc = min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len
    spec = {
        "k": ((L, batch, sc, cfg.n_kv_heads, cfg.head_dim), jnp.dtype(cfg.dtype)),
        "v": ((L, batch, sc, cfg.n_kv_heads, cfg.head_dim), jnp.dtype(cfg.dtype)),
        "slot_pos": ((L, batch, sc), jnp.int32),  # per-sequence ring positions
    }
    if cfg.hybrid_parallel_ssm:
        spec["ssm"] = ((L, batch, cfg.ssm.d_inner, cfg.ssm.state_size), jnp.float32)
    if cfg.enc_dec:
        spec["ck"] = ((L, batch, cfg.encoder_seq, cfg.n_kv_heads, cfg.head_dim), jnp.dtype(cfg.dtype))
        spec["cv"] = ((L, batch, cfg.encoder_seq, cfg.n_kv_heads, cfg.head_dim), jnp.dtype(cfg.dtype))
    return spec


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    out = {}
    for k, (shape, dt) in cache_spec(cfg, batch, max_len).items():
        fill = -1 if k == "slot_pos" else 0
        out[k] = jnp.full(shape, fill, dt)
    return out


def abstract_cache(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    return {k: jax.ShapeDtypeStruct(shape, dt) for k, (shape, dt) in cache_spec(cfg, batch, max_len).items()}


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------
def _attn_seq(cfg, p, h, positions, *, causal=True):
    """Sequence-mode attention; returns (out, (k, v)) for cache emission."""
    q, k, v = qkv_project(cfg, p, h, positions if cfg.use_rope else None)
    from .attention import attention  # local import to avoid cycle at module load

    o = attention(cfg, q, k, v, causal=causal, impl="chunked" if h.shape[1] > 256 else "full")
    return out_project(cfg, p, o), (k, v)


def _attn_step(cfg, p, h, pos, kc, vc, slot_pos, *, window):
    """Decode-mode attention against a (ring-buffer) cache.

    ``pos`` is a (B,) int32 vector of per-sequence absolute positions —
    continuous-batching serving decodes lanes at different depths."""
    q, k, v = qkv_project(cfg, p, h, pos[:, None] if cfg.use_rope else None)
    sc = kc.shape[1]
    slot = (pos % sc).astype(jnp.int32)
    upd = jax.vmap(lambda c, kk, s: jax.lax.dynamic_update_slice(c, kk, (s, 0, 0)))
    kc = upd(kc, k, slot)
    vc = upd(vc, v, slot)
    slot_pos = jax.vmap(
        lambda sp, pp, s: jax.lax.dynamic_update_slice(sp, pp[None], (s,))
    )(slot_pos, pos.astype(jnp.int32), slot)
    o = _cache_attention(cfg, q, kc, vc, slot_pos, pos, window)
    return out_project(cfg, p, o), kc, vc, slot_pos


def _cache_attention(cfg, q, kc, vc, slot_pos, pos, window):
    """q: (B,1,Hq,Dh); kc/vc: (B,Sc,Hkv,Dh); slot_pos: (B,Sc) absolute
    positions per lane; pos: (B,)."""
    b, _, hq, dh = q.shape
    hkv = kc.shape[2]
    qg = q.reshape(b, 1, hkv, hq // hkv, dh)
    s = jnp.einsum("bsngk,btnk->bngst", qg.astype(jnp.float32), kc.astype(jnp.float32))
    s = s * (dh**-0.5)
    valid = (slot_pos >= 0) & (slot_pos <= pos[:, None])
    if window is not None:
        valid &= slot_pos > (pos[:, None] - window)
    s = jnp.where(valid[:, None, None, None, :], s, -1e30)
    pr = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bngst,btnk->bsngk", pr, vc.astype(jnp.float32))
    return o.reshape(b, 1, hq, dh).astype(q.dtype)


def _ffn(cfg, p, h):
    """Second half of a block: MLP or MoE. Returns (out, aux)."""
    if cfg.moe is not None:
        y, aux, _dropped = apply_moe(cfg, p["moe"], h)
        return y, aux
    return apply_mlp(cfg, p["mlp"], h), jnp.float32(0.0)


def block_seq(cfg: ModelConfig, p, x, positions, *, enc_out=None, causal=True,
              emit_cache=False, cross_kv=None):
    """One decoder block over a full sequence. Returns (x, cache_emit, aux)."""
    emit = None
    if cfg.attention_free:
        b = x.shape[0]
        h0 = cfg.d_model // cfg.rwkv.head_size
        n = cfg.rwkv.head_size
        st0 = jnp.zeros((b, h0, n, n), jnp.float32)
        pv0 = jnp.zeros((b, cfg.d_model), x.dtype)
        y, tm_prev, wkv = rwkv6.apply_time_mix(cfg, p["tm"], apply_norm(cfg, p["norm1"], x), pv0, st0)
        x = x + y
        y, cm_prev = rwkv6.apply_channel_mix(cfg, p["cm"], apply_norm(cfg, p["norm2"], x), pv0)
        x = x + y
        if emit_cache:
            emit = {"wkv": wkv, "tm_prev": tm_prev, "cm_prev": cm_prev}
        return x, emit, jnp.float32(0.0)

    h = apply_norm(cfg, p["norm1"], x)
    a, (k, v) = _attn_seq(cfg, p["attn"], h, positions, causal=causal)
    if cfg.hybrid_parallel_ssm:
        b_ssm = x.shape[0]
        s0 = jnp.zeros((b_ssm, cfg.ssm.d_inner, cfg.ssm.state_size), jnp.float32)
        sy, s_state = ssm.apply_ssm(cfg, p["ssm"], h, s0)
        scale = p["branch_scale"].astype(x.dtype)
        x = x + 0.5 * (scale[0] * a + scale[1] * sy)
    else:
        x = x + a
        s_state = None
    if enc_out is not None:  # whisper decoder cross-attention
        hc = apply_norm(cfg, p["norm_c"], x)
        qc, _, _ = qkv_project(cfg, p["cross"], hc, None)
        if cross_kv is not None:  # precomputed outside the layer scan
            ke, ve = cross_kv
        else:
            ke = jnp.einsum("bsd,dhk->bshk", enc_out, p["cross"]["wk"])
            ve = jnp.einsum("bsd,dhk->bshk", enc_out, p["cross"]["wv"])
            if cfg.qkv_bias:
                ke, ve = ke + p["cross"]["bk"], ve + p["cross"]["bv"]
        o = attention_full(qc, ke, ve, causal=False)
        x = x + out_project(cfg, p["cross"], o)
    y, aux = _ffn(cfg, p, apply_norm(cfg, p["norm2"], x))
    x = x + y
    if emit_cache:
        emit = {"k": k, "v": v}
        if cfg.hybrid_parallel_ssm:
            emit["ssm"] = s_state
        if cfg.enc_dec:
            emit["ck"] = ke
            emit["cv"] = ve
    return x, emit, aux


def block_step(cfg: ModelConfig, p, x, pos, cache_l):
    """One decoder block for a single decode step. Returns (x, cache_l')."""
    new_cache = dict(cache_l)
    if cfg.attention_free:
        y, tm_prev, wkv = rwkv6.apply_time_mix_step(
            cfg, p["tm"], apply_norm(cfg, p["norm1"], x), cache_l["tm_prev"], cache_l["wkv"]
        )
        x = x + y
        y, cm_prev = rwkv6.apply_channel_mix_step(
            cfg, p["cm"], apply_norm(cfg, p["norm2"], x), cache_l["cm_prev"]
        )
        x = x + y
        new_cache.update(wkv=wkv, tm_prev=tm_prev, cm_prev=cm_prev)
        return x, new_cache

    h = apply_norm(cfg, p["norm1"], x)
    a, kc, vc, slot_pos = _attn_step(
        cfg, p["attn"], h, pos, cache_l["k"], cache_l["v"], cache_l["slot_pos"],
        window=cfg.sliding_window,
    )
    new_cache.update(k=kc, v=vc, slot_pos=slot_pos)
    if cfg.hybrid_parallel_ssm:
        sy, s_state = ssm.apply_ssm_step(cfg, p["ssm"], h, cache_l["ssm"])
        scale = p["branch_scale"].astype(x.dtype)
        x = x + 0.5 * (scale[0] * a + scale[1] * sy)
        new_cache["ssm"] = s_state
    else:
        x = x + a
    if cfg.enc_dec:
        hc = apply_norm(cfg, p["norm_c"], x)
        qc, _, _ = qkv_project(cfg, p["cross"], hc, None)
        o = attention_full(qc, cache_l["ck"], cache_l["cv"], causal=False)
        x = x + out_project(cfg, p["cross"], o)
    y, _aux = _ffn(cfg, p, apply_norm(cfg, p["norm2"], x))
    return x + y, new_cache


# ---------------------------------------------------------------------------
# Encoder (whisper)
# ---------------------------------------------------------------------------
def run_encoder(cfg: ModelConfig, params, frames: jax.Array) -> jax.Array:
    h = frames + sinusoidal_positions(frames.shape[1], cfg.d_model).astype(frames.dtype)
    h = hint(h, ("batch", "seq", "embed"))
    positions = jnp.arange(frames.shape[1])

    def body(carry, layer_p):
        y, _, _ = block_seq(cfg, cast_tree(layer_p, cfg.dtype), carry, positions, causal=False)
        return y, None

    h, _ = jax.lax.scan(body, h, params["encoder"]["layers"], unroll=cfg.n_encoder_layers if cfg.scan_unroll else 1)
    return apply_norm(cfg, params["encoder"]["final_norm"], h)


# ---------------------------------------------------------------------------
# Forward / loss / decode
# ---------------------------------------------------------------------------
def _embed_tokens(cfg, params, tokens):
    e = jnp.take(params["embed"], tokens, axis=0).astype(jnp.dtype(cfg.dtype))
    return e


def _unembed(cfg, params, h):
    w = params["embed"].T if cfg.tie_embeddings else params["unembed"]
    return jnp.einsum("bsd,dv->bsv", h, w.astype(h.dtype))


def _remat(cfg, fn):
    if cfg.remat_policy == "dots":
        return jax.checkpoint(fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    if cfg.remat_policy == "full":
        return jax.checkpoint(fn)
    return fn


def forward(cfg: ModelConfig, params, batch: dict, *, emit_cache: bool = False,
            remat: bool = False, logits_mode: str = "all"):
    """Returns (logits, cache_or_None, aux). batch keys per family:

    LM:    tokens (B,S)
    VLM:   tokens (B,S_text) + patch_embeds (B,P,d)
    audio: tokens (B,S) + frames (B,S_enc,d)

    ``logits_mode="last"`` unembeds only the final position — prefill only
    needs the next-token distribution, and at 32k x vocab the full-sequence
    unembedding is ~1/3 of prefill FLOPs (EXPERIMENTS.md §Perf, mixtral).
    """
    enc_out = None
    cross_kv_all = None
    if cfg.enc_dec:
        enc_out = run_encoder(cfg, params, batch["frames"].astype(jnp.dtype(cfg.dtype)))
        # Precompute every decoder layer's cross K/V in one stacked einsum
        # BEFORE the layer scan: computing them from (replicated) enc_out
        # inside each layer made GSPMD re-gather the encoder output per layer
        # (the collective-bound whisper-prefill finding in EXPERIMENTS.md).
        cp = cast_tree(params["layers"]["cross"], cfg.dtype)
        ke = jnp.einsum("bsd,ldhk->lbshk", enc_out, cp["wk"])
        ve = jnp.einsum("bsd,ldhk->lbshk", enc_out, cp["wv"])
        if cfg.qkv_bias:
            ke = ke + cp["bk"][:, None, None]
            ve = ve + cp["bv"][:, None, None]
        cross_kv_all = (ke, ve)
        h = _embed_tokens(cfg, params, batch["tokens"])
        h = h + sinusoidal_positions(h.shape[1], cfg.d_model).astype(h.dtype)
    elif cfg.frontend == "vision":
        th = _embed_tokens(cfg, params, batch["tokens"])
        h = jnp.concatenate([batch["patch_embeds"].astype(th.dtype), th], axis=1)
    else:
        h = _embed_tokens(cfg, params, batch["tokens"])
    if not cfg.use_rope and not cfg.enc_dec:
        h = h + sinusoidal_positions(h.shape[1], cfg.d_model).astype(h.dtype)
    h = hint(h, ("batch", "seq", "embed"))
    positions = jnp.arange(h.shape[1])

    def body(carry, xs):
        layer_p, ckv = xs
        x, aux = carry
        x, emit, aux_l = block_seq(
            cfg, cast_tree(layer_p, cfg.dtype), x, positions,
            enc_out=enc_out, causal=True, emit_cache=emit_cache, cross_kv=ckv,
        )
        x = hint(x, ("batch", "seq", "embed"))
        return (x, aux + aux_l), emit

    body_fn = _remat(cfg, body) if remat else body
    (h, aux), emits = jax.lax.scan(
        body_fn, (h, jnp.float32(0.0)), (params["layers"], cross_kv_all),
        unroll=cfg.n_layers if cfg.scan_unroll else 1,
    )
    h = apply_norm(cfg, params["final_norm"], h)
    if logits_mode == "last":
        h = h[:, -1:, :]
    logits = _unembed(cfg, params, h)
    logits = hint(logits, ("batch", "seq", "vocab"))

    cache = None
    if emit_cache:
        cache = _assemble_cache(cfg, emits, seq_len=h.shape[1])
    return logits, cache, aux


def _assemble_cache(cfg: ModelConfig, emits: dict, *, seq_len: int) -> dict:
    """Turn scan-emitted per-layer tensors into the decode cache layout."""
    if cfg.attention_free:
        return {"wkv": emits["wkv"], "tm_prev": emits["tm_prev"], "cm_prev": emits["cm_prev"]}
    cache: dict = {}
    sc = min(seq_len, cfg.sliding_window) if cfg.sliding_window else seq_len
    k, v = emits["k"], emits["v"]  # (L,B,S,Hkv,Dh)
    b = k.shape[1]
    if sc < seq_len:  # keep the last `window` keys, slot = pos % sc
        start = seq_len - sc
        k, v = k[:, :, start:], v[:, :, start:]
        pos = jnp.arange(start, seq_len)
        slot = pos % sc
        order = jnp.argsort(slot)
        k = k[:, :, order]
        v = v[:, :, order]
        slot_pos = jnp.broadcast_to(pos[order], (cfg.n_layers, b, sc)).astype(jnp.int32)
    else:
        slot_pos = jnp.broadcast_to(jnp.arange(sc), (cfg.n_layers, b, sc)).astype(jnp.int32)
    cache.update(k=k, v=v, slot_pos=slot_pos)
    if cfg.hybrid_parallel_ssm:
        cache["ssm"] = emits["ssm"]
    if cfg.enc_dec:
        cache["ck"], cache["cv"] = emits["ck"], emits["cv"]
    return cache


def decode_step(cfg: ModelConfig, params, cache: dict, tokens: jax.Array, pos: jax.Array):
    """One token for every sequence. tokens: (B,1); pos: scalar int32 or
    (B,) int32 per-sequence absolute positions (continuous batching).
    Returns (logits (B,1,V), cache')."""
    if jnp.ndim(pos) == 0:
        pos = jnp.broadcast_to(pos, (tokens.shape[0],))
    pos = pos.astype(jnp.int32)
    h = _embed_tokens(cfg, params, tokens)
    if not cfg.use_rope:
        pe = jax.vmap(lambda o: sinusoidal_positions(1, cfg.d_model, offset=o))(pos)
        h = h + pe.astype(h.dtype)
    h = hint(h, ("batch", None, "embed"))

    def body(x, layer):
        layer_p, cache_l = layer
        x, new_cache = block_step(cfg, cast_tree(layer_p, cfg.dtype), x, pos, cache_l)
        x = hint(x, ("batch", None, "embed"))
        return x, new_cache

    h, new_cache = jax.lax.scan(body, h, (params["layers"], cache), unroll=cfg.n_layers if cfg.scan_unroll else 1)
    h = apply_norm(cfg, params["final_norm"], h)
    logits = _unembed(cfg, params, h)
    return logits, new_cache


def loss_fn(cfg: ModelConfig, params, batch: dict, *, remat: bool = True):
    """Next-token cross entropy (fp32), MoE aux added. Returns (loss, metrics)."""
    logits, _, aux = forward(cfg, params, batch, remat=remat)
    labels = batch["labels"]
    if cfg.frontend == "vision":
        # text starts after P patches; position P-1+j predicts text token j
        p_len = batch["patch_embeds"].shape[1]
        s_text = labels.shape[1]
        logits = jax.lax.dynamic_slice_in_dim(logits, p_len - 1, s_text, axis=1)
    lf = logits.astype(jnp.float32)
    mask = (labels >= 0).astype(jnp.float32)
    labels_c = jnp.maximum(labels, 0)
    lse = jax.nn.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, labels_c[..., None], axis=-1)[..., 0]
    ce = (lse - gold) * mask
    ntok = jnp.maximum(mask.sum(), 1.0)
    loss = ce.sum() / ntok
    total = loss + AUX_COEF * aux
    return total, {"ce": loss, "aux": aux, "tokens": ntok}
