"""AdamW on raw pytrees (no optax dependency).

Moments may be kept in bf16 (``moment_dtype``) — a distributed-optimization
memory trick evaluated in EXPERIMENTS.md §Perf; fp32 is the default.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def adamw_init(params, moment_dtype=jnp.float32):
    zeros = lambda p: jnp.zeros(p.shape, moment_dtype)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), gn


def adamw_update(
    params,
    grads,
    state,
    *,
    lr: jax.Array | float,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    max_grad_norm: Optional[float] = 1.0,
):
    """Returns (params', state', metrics)."""
    if max_grad_norm is not None:
        grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
    else:
        _, gnorm = clip_by_global_norm(grads, 1e30)
    step = state["step"] + 1
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        m_new = b1 * m.astype(jnp.float32) + (1 - b1) * gf
        v_new = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(gf)
        mh = m_new / bc1
        vh = v_new / bc2
        delta = mh / (jnp.sqrt(vh) + eps) + weight_decay * p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - lr * delta
        return p_new.astype(p.dtype), m_new.astype(m.dtype), v_new.astype(v.dtype)

    out = jax.tree.map(upd, params, grads, state["m"], state["v"])
    params_new = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    m_new = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    v_new = jax.tree.map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
    return params_new, {"m": m_new, "v": v_new, "step": step}, {"grad_norm": gnorm}
