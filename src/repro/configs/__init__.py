"""Config registry: ``get_config(arch_id)`` resolves any assigned architecture."""
from __future__ import annotations

from .archs import ASSIGNED, EXTRAS
from .base import (
    LONG_CONTEXT_CAPABLE,
    SHAPES,
    ModelConfig,
    MoEConfig,
    RWKVConfig,
    ShapeConfig,
    SSMConfig,
    reduced,
    supports_shape,
)
from .paxoslease_cell import DEFAULT_CELL, MASTER_CELL, CellConfig

REGISTRY: dict[str, ModelConfig] = {**ASSIGNED, **EXTRAS}


def get_config(name: str) -> ModelConfig:
    if name not in REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(REGISTRY)}")
    return REGISTRY[name]


def get_shape(name: str) -> ShapeConfig:
    if name not in SHAPES:
        raise KeyError(f"unknown shape {name!r}; known: {sorted(SHAPES)}")
    return SHAPES[name]


def arch_ids(assigned_only: bool = True) -> list[str]:
    return sorted(ASSIGNED if assigned_only else REGISTRY)


__all__ = [
    "ASSIGNED",
    "CellConfig",
    "DEFAULT_CELL",
    "LONG_CONTEXT_CAPABLE",
    "MASTER_CELL",
    "ModelConfig",
    "MoEConfig",
    "REGISTRY",
    "RWKVConfig",
    "SHAPES",
    "SSMConfig",
    "ShapeConfig",
    "arch_ids",
    "get_config",
    "get_shape",
    "reduced",
    "supports_shape",
]
