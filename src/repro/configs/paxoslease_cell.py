"""The paper's own configuration: a PaxosLease cell (§2) and its timing knobs.

This mirrors the deployment described in §9 (Keyspace/ScalienDB master lease):
a small fixed acceptor ensemble, any number of proposers, a globally known
maximal lease time M, and leases always acquired for T < M.
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class CellConfig:
    n_acceptors: int = 5
    max_lease_time: float = 60.0  # M — globally known; acceptors wait M on restart
    lease_timespan: float = 15.0  # T — always < M (§2)
    renew_fraction: float = 0.5  # extend the lease after T * renew_fraction (§6)
    backoff_min: float = 0.5  # randomized retry backoff (§5 liveness workaround)
    backoff_max: float = 2.0
    rtt_estimate: float = 0.05  # informational; algorithm never relies on it
    round_timeout: float = 0.0  # give up on a round after this; 0 = 8x RTT estimate
    clock_drift_bound: float = 0.0  # ε: |rate-1| ≤ ε for every local clock
    drift_guard: bool = False  # proposer discounts own timer to T/(1+2ε) when True

    def __post_init__(self) -> None:
        if self.lease_timespan >= self.max_lease_time:
            raise ValueError("PaxosLease requires T < M (paper §2)")
        if self.n_acceptors < 1:
            raise ValueError("need at least one acceptor")

    @property
    def majority(self) -> int:
        return self.n_acceptors // 2 + 1


DEFAULT_CELL = CellConfig()

# Keyspace-style master-lease cell: 3 replicas, aggressive renewal.
MASTER_CELL = CellConfig(
    n_acceptors=3,
    max_lease_time=30.0,
    lease_timespan=7.0,
    renew_fraction=0.4,
)
