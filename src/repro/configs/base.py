"""Model / shape configuration dataclasses shared by the whole framework.

Every assigned architecture is expressed as a ``ModelConfig``; the dry-run,
smoke tests, sharding rules and roofline analysis all read from here so there
is exactly one source of truth per architecture.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional


@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts block configuration (dense one-hot dispatch)."""

    n_experts: int
    top_k: int
    d_expert: int  # per-expert FFN hidden width
    capacity_factor: float = 1.25
    router_dtype: str = "float32"


@dataclass(frozen=True)
class SSMConfig:
    """Mamba-style selective-SSM head configuration (used by hybrid archs)."""

    state_size: int
    d_inner: int  # inner (expanded) width of the SSM branch
    dt_rank: int = 8


@dataclass(frozen=True)
class RWKVConfig:
    """RWKV6 (Finch) configuration: data-dependent decay token mixing."""

    head_size: int = 64
    decay_lora: int = 64  # low-rank width of the data-dependent decay projection
    tokenshift_lora: int = 32


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: Optional[int] = None  # defaults to d_model // n_heads
    # attention details
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    use_rope: bool = True
    sliding_window: Optional[int] = None  # SWA window; None = full attention
    attn_chunk: int = 512  # kv-block size for chunked online-softmax attention
    # norms / mlp
    norm_type: str = "rmsnorm"  # rmsnorm | layernorm
    norm_eps: float = 1e-5
    mlp_gated: bool = True  # SwiGLU when True, plain act(W1 x) W2 when False
    mlp_act: str = "silu"  # silu | gelu
    linear_bias: bool = False  # bias on all dense layers (starcoder2/whisper style)
    tie_embeddings: bool = False
    # family extensions
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    rwkv: Optional[RWKVConfig] = None
    attention_free: bool = False  # rwkv6: no attention at all
    hybrid_parallel_ssm: bool = False  # hymba: attention + SSM heads in parallel
    # encoder-decoder (whisper)
    enc_dec: bool = False
    n_encoder_layers: int = 0
    encoder_seq: int = 1500  # audio frames after the (stubbed) conv frontend
    # modality frontend stubs
    frontend: Optional[str] = None  # audio | vision | None
    n_frontend_tokens: int = 0  # vision patch tokens prepended to the text sequence
    # numerics
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    remat_policy: str = "nothing"  # nothing | dots | full
    scan_unroll: bool = False  # unroll the layer scan (cost_analysis validation)
    source: str = ""  # provenance note ([arXiv/hf; tier])

    @property
    def head_dim(self) -> int:
        return self.d_head if self.d_head is not None else self.d_model // self.n_heads

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    # ------------------------------------------------------------------
    # Parameter counting (used for MODEL_FLOPS = 6 * N * D in the roofline)
    # ------------------------------------------------------------------
    def _attn_params(self) -> int:
        d = self.d_model
        p = d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
        if self.qkv_bias:
            p += self.q_dim + 2 * self.kv_dim
        if self.linear_bias:
            p += d
        return p

    def _mlp_params_dense(self) -> int:
        d, f = self.d_model, self.d_ff
        n_mats = 3 if self.mlp_gated else 2
        p = n_mats * d * f
        if self.linear_bias:
            p += (f + d) if not self.mlp_gated else (2 * f + d)
        return p

    def _moe_params(self, active: bool) -> int:
        assert self.moe is not None
        d, fe = self.d_model, self.moe.d_expert
        n_mats = 3 if self.mlp_gated else 2
        per_expert = n_mats * d * fe
        router = d * self.moe.n_experts
        n_used = self.moe.top_k if active else self.moe.n_experts
        return router + n_used * per_expert

    def _ssm_params(self) -> int:
        assert self.ssm is not None
        d, di, s = self.d_model, self.ssm.d_inner, self.ssm.state_size
        # in_proj (x and z), dt/B/C projections, out_proj, A log, D
        return d * di * 2 + di * (self.ssm.dt_rank + 2 * s) + self.ssm.dt_rank * di + di * d + di * s + di

    def _rwkv_layer_params(self) -> int:
        assert self.rwkv is not None
        d = self.d_model
        lora_w = self.rwkv.decay_lora
        lora_x = self.rwkv.tokenshift_lora
        # time-mix: r,k,v,g,o projections + decay LoRA + tokenshift LoRAs + u (bonus)
        tm = 5 * d * d + (d * lora_w + lora_w * d) + 5 * (d * lora_x + lora_x * d) + d
        # channel-mix: Wk (d->f), Wv (f->d), Wr (d->d)
        cm = d * self.d_ff + self.d_ff * d + d * d
        return tm + cm

    def layer_params(self, active: bool = False) -> int:
        if self.attention_free:
            return self._rwkv_layer_params()
        p = self._attn_params()
        if self.hybrid_parallel_ssm:
            p += self._ssm_params()
        if self.moe is not None:
            p += self._moe_params(active=active)
        else:
            p += self._mlp_params_dense()
        # two (or three for hybrid) norm scales — negligible but counted
        p += 2 * self.d_model
        return p

    def n_params(self, active: bool = False, include_embeddings: bool = True) -> int:
        """Total (or activated, for MoE) parameter count."""
        n_dec = self.n_layers * self.layer_params(active=active)
        n_enc = 0
        if self.enc_dec:
            # encoder layers: self-attn + dense mlp; decoder layers additionally
            # carry cross-attention (same shape as self-attention).
            n_enc = self.n_encoder_layers * (self._attn_params() + self._mlp_params_dense() + 2 * self.d_model)
            n_dec += self.n_layers * self._attn_params()  # cross-attn in decoder
        emb = self.vocab_size * self.d_model
        unemb = 0 if self.tie_embeddings else self.vocab_size * self.d_model
        if not include_embeddings:
            emb = 0
        return n_dec + n_enc + emb + unemb

    def matmul_params(self, active: bool = False) -> int:
        """Params that participate in per-token matmuls (for 6*N*D):
        excludes the input embedding gather, includes the unembedding."""
        n = self.n_params(active=active, include_embeddings=False)
        if self.tie_embeddings:
            n += self.vocab_size * self.d_model  # unembed matmul still happens
        return n


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str  # train | prefill | decode
    seq_len: int
    global_batch: int

    @property
    def tokens_per_step(self) -> int:
        if self.kind == "decode":
            return self.global_batch  # one new token per sequence
        return self.global_batch * self.seq_len


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524288, 1),
}

# Archs able to run long_500k (sub-quadratic / bounded-state decode):
#   rwkv6 (attention-free O(1) state), hymba (SWA + SSM), mixtral (SWA cache).
# All others are pure full-attention — skipped per assignment, see DESIGN.md §4.
LONG_CONTEXT_CAPABLE = {"rwkv6-3b", "hymba-1.5b", "mixtral-8x22b"}


def supports_shape(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Whether (arch, shape) is a runnable cell; returns (ok, reason_if_skipped)."""
    if shape.name == "long_500k" and cfg.name not in LONG_CONTEXT_CAPABLE:
        return False, "pure full-attention arch: 500k dense KV cache excluded by assignment"
    return True, ""


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """Smoke-test-sized config of the same family (small widths, few experts)."""
    kv_ratio = max(1, cfg.n_heads // cfg.n_kv_heads)
    n_heads = 4
    n_kv = max(1, n_heads // kv_ratio)
    changes: dict = dict(
        n_layers=2,
        d_model=64,
        n_heads=n_heads,
        n_kv_heads=n_kv,
        d_head=16,
        d_ff=128,
        vocab_size=256,
        encoder_seq=16,
        attn_chunk=32,
    )
    if cfg.moe is not None:
        changes["moe"] = MoEConfig(
            n_experts=min(4, cfg.moe.n_experts),
            top_k=min(2, cfg.moe.top_k),
            d_expert=64,
        )
    if cfg.ssm is not None:
        changes["ssm"] = SSMConfig(state_size=8, d_inner=128, dt_rank=4)
    if cfg.rwkv is not None:
        changes["rwkv"] = RWKVConfig(head_size=16, decay_lora=8, tokenshift_lora=8)
    if cfg.enc_dec:
        changes["n_encoder_layers"] = 2
    if cfg.sliding_window is not None:
        changes["sliding_window"] = 32
    if cfg.n_frontend_tokens:
        changes["n_frontend_tokens"] = 8
    changes.update(overrides)
    return dataclasses.replace(cfg, **changes)
