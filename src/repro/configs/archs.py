"""Exact configurations for the 10 assigned architectures (+ example configs).

Each entry reproduces the assignment table verbatim; provenance in ``source``.
"""
from __future__ import annotations

from .base import ModelConfig, MoEConfig, RWKVConfig, SSMConfig

INTERNLM2_1_8B = ModelConfig(
    name="internlm2-1.8b",
    family="dense",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    d_head=128,
    d_ff=8192,
    vocab_size=92544,
    rope_theta=1_000_000.0,
    remat_policy="dots",
    source="[arXiv:2403.17297; hf] GQA kv=8",
)

GRANITE_3_8B = ModelConfig(
    name="granite-3-8b",
    family="dense",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_head=128,
    d_ff=12800,
    vocab_size=49155,
    remat_policy="dots",
    source="[hf:ibm-granite/granite-3.0-2b-base; hf] GQA kv=8",
)

QWEN1_5_0_5B = ModelConfig(
    name="qwen1.5-0.5b",
    family="dense",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_head=64,
    d_ff=2816,
    vocab_size=151936,
    qkv_bias=True,
    remat_policy="dots",
    source="[hf:Qwen/Qwen1.5-0.5B; hf] QKV bias, MHA",
)

STARCODER2_15B = ModelConfig(
    name="starcoder2-15b",
    family="dense",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=4,
    d_head=128,
    d_ff=24576,
    vocab_size=49152,
    norm_type="layernorm",
    mlp_gated=False,
    mlp_act="gelu",
    linear_bias=True,
    rope_theta=100_000.0,
    remat_policy="dots",
    source="[arXiv:2402.19173; hf] GQA kv=4, RoPE, plain-GELU MLP, biases",
)

# whisper-large-v3: the assignment's "32L" is realized as 32 encoder + 32
# decoder layers (the real checkpoint's layout at d_model=1280). Conv audio
# frontend is a STUB: input_specs() supplies precomputed frame embeddings.
WHISPER_LARGE_V3 = ModelConfig(
    name="whisper-large-v3",
    family="audio",
    n_layers=32,
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,
    d_head=64,
    d_ff=5120,
    vocab_size=51866,
    enc_dec=True,
    n_encoder_layers=32,
    encoder_seq=1500,
    use_rope=False,
    norm_type="layernorm",
    mlp_gated=False,
    mlp_act="gelu",
    linear_bias=True,
    frontend="audio",
    remat_policy="dots",
    source="[arXiv:2212.04356; unverified] enc-dec, conv frontend stubbed",
)

HYMBA_1_5B = ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    d_head=64,
    d_ff=5504,
    vocab_size=32001,
    sliding_window=1024,  # Hymba uses SWA on most layers; global attn is the exception
    ssm=SSMConfig(state_size=16, d_inner=3200, dt_rank=8),
    hybrid_parallel_ssm=True,
    remat_policy="dots",
    source="[arXiv:2411.13676; hf] parallel attn+mamba heads, ssm_state=16",
)

MIXTRAL_8X22B = ModelConfig(
    name="mixtral-8x22b",
    family="moe",
    n_layers=56,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_head=128,
    d_ff=16384,
    vocab_size=32768,
    sliding_window=4096,  # per assignment table ("SWA")
    moe=MoEConfig(n_experts=8, top_k=2, d_expert=16384),
    rope_theta=1_000_000.0,
    remat_policy="dots",
    source="[arXiv:2401.04088; hf] 8 experts top-2, SWA",
)

KIMI_K2_1T_A32B = ModelConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv_heads=8,
    d_head=112,
    d_ff=2048,  # = per-expert hidden width
    vocab_size=163840,
    moe=MoEConfig(n_experts=384, top_k=8, d_expert=2048),
    rope_theta=50_000.0,
    remat_policy="full",
    source="[arXiv:2501.kimi2; unverified] trillion-param MoE, 384e top-8 (paper-table)",
)

RWKV6_3B = ModelConfig(
    name="rwkv6-3b",
    family="ssm",
    n_layers=32,
    d_model=2560,
    n_heads=40,  # = d_model / rwkv head_size
    n_kv_heads=40,
    d_head=64,
    d_ff=8960,
    vocab_size=65536,
    attention_free=True,
    use_rope=False,
    rwkv=RWKVConfig(head_size=64, decay_lora=64, tokenshift_lora=32),
    remat_policy="dots",
    source="[arXiv:2404.05892; hf] Finch — data-dependent decay, attn-free",
)

# internvl2-2b: InternViT frontend is a STUB (precomputed patch embeddings);
# the backbone below is the InternLM2-1.8b layout with the VLM vocab.
INTERNVL2_2B = ModelConfig(
    name="internvl2-2b",
    family="vlm",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    d_head=128,
    d_ff=8192,
    vocab_size=92553,
    rope_theta=1_000_000.0,
    frontend="vision",
    n_frontend_tokens=256,
    remat_policy="dots",
    source="[arXiv:2404.16821; hf] InternViT(stub) + InternLM2 backbone",
)

# Example / driver configs (not part of the assigned table) -----------------

LM100M = ModelConfig(
    name="lm100m",
    family="dense",
    n_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=4,
    d_head=64,
    d_ff=2048,
    vocab_size=32768,
    source="example ~100M-param training driver config",
)

LM20M = ModelConfig(
    name="lm20m",
    family="dense",
    n_layers=8,
    d_model=384,
    n_heads=6,
    n_kv_heads=2,
    d_head=64,
    d_ff=1024,
    vocab_size=8192,
    tie_embeddings=True,
    source="small CPU-friendly demo config",
)

ASSIGNED: dict[str, ModelConfig] = {
    c.name: c
    for c in [
        INTERNLM2_1_8B,
        GRANITE_3_8B,
        QWEN1_5_0_5B,
        STARCODER2_15B,
        WHISPER_LARGE_V3,
        HYMBA_1_5B,
        MIXTRAL_8X22B,
        KIMI_K2_1T_A32B,
        RWKV6_3B,
        INTERNVL2_2B,
    ]
}

EXTRAS: dict[str, ModelConfig] = {c.name: c for c in [LM100M, LM20M]}
