"""Logical-axis sharding rules -> concrete NamedShardings.

Params and activations are annotated with *logical* axis names (see
``repro.models.schema``); this module maps them onto mesh axes with
per-tensor divisibility fallback (a dim that doesn't divide its mesh axes is
replicated rather than failing — e.g. 40 RWKV heads on a 16-way "model" axis).

An ambient context (``use_mesh``) lets model code drop sharding hints
(``hint(x, ("batch", None, "embed"))``) that become
``lax.with_sharding_constraint`` under a mesh and no-ops otherwise.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional

import jax
import numpy as np
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from ..configs.base import ModelConfig

# Logical axis -> mesh axis (or tuple of mesh axes, or None = replicate).
DEFAULT_RULES: dict[str, object] = {
    "batch": ("pod", "data"),
    "experts": ("pod", "data"),  # EP: expert axis over the data axes when divisible
    "vocab": "model",
    "heads": "model",
    "kv_heads": "model",
    "head": None,
    "mlp": "model",
    "expert_ff": "model",
    "ssm_inner": "model",
    "rwkv_inner": "model",
    "rwkv_heads": "model",
    "embed": None,
    "seq": None,  # becomes data axes under sequence parallelism (hillclimb)
    "layers": None,
    None: None,
}


def make_rules(mesh: Mesh, overrides: Optional[dict] = None) -> dict:
    rules = dict(DEFAULT_RULES)
    if overrides:
        rules.update(overrides)
    # Drop mesh axes that don't exist (e.g. "pod" on the single-pod mesh).
    def _filter(v):
        if v is None:
            return None
        axes = v if isinstance(v, tuple) else (v,)
        axes = tuple(a for a in axes if a in mesh.axis_names)
        if not axes:
            return None
        return axes if len(axes) > 1 else axes[0]

    return {k: _filter(v) for k, v in rules.items()}


def _axis_size(mesh: Mesh, v) -> int:
    if v is None:
        return 1
    axes = v if isinstance(v, tuple) else (v,)
    return int(np.prod([mesh.shape[a] for a in axes]))


def spec_for(mesh: Mesh, rules: dict, logical: tuple, shape: tuple) -> PartitionSpec:
    """PartitionSpec for one tensor, replicating non-divisible dims."""
    out, used = [], set()
    for dim, name in zip(shape, logical):
        v = rules.get(name)
        axes = () if v is None else (v if isinstance(v, tuple) else (v,))
        axes = tuple(a for a in axes if a not in used)
        size = int(np.prod([mesh.shape[a] for a in axes])) if axes else 1
        if axes and dim % size == 0:
            used.update(axes)
            out.append(axes if len(axes) > 1 else axes[0])
        else:
            out.append(None)
    while out and out[-1] is None:
        out.pop()
    return PartitionSpec(*out)


def tree_shardings(mesh: Mesh, rules: dict, axes_tree, abstract_tree):
    """NamedSharding tree matching ``abstract_tree`` (dict-of-dicts of arrays)."""

    def go(ax, ab):
        if isinstance(ab, dict):
            return {k: go(ax[k], ab[k]) for k in ab}
        return NamedSharding(mesh, spec_for(mesh, rules, ax, ab.shape))

    return go(axes_tree, abstract_tree)


def zero1_axes(logical: tuple, shape: tuple, mesh: Mesh, rules: dict) -> tuple:
    """Optimizer-state logical axes: additionally shard the first dim that is
    currently replicated and divisible by the data axes (ZeRO-1)."""
    dp = rules.get("batch")
    if dp is None:
        return logical
    dp_size = _axis_size(mesh, dp)
    current = [rules.get(n) for n in logical]
    if any(v is not None and set((v if isinstance(v, tuple) else (v,))) & {"pod", "data"} for v in current):
        return logical  # already uses a data axis (e.g. experts)
    for i, (dim, name) in enumerate(zip(shape, logical)):
        if rules.get(name) is None and dim % dp_size == 0 and dim > 1:
            return logical[:i] + ("batch",) + logical[i + 1 :]
    return logical


# ---------------------------------------------------------------------------
# Ambient mesh context for activation sharding hints
# ---------------------------------------------------------------------------
class _Ctx(threading.local):
    mesh: Optional[Mesh] = None
    rules: Optional[dict] = None


_CTX = _Ctx()


@contextlib.contextmanager
def use_mesh(mesh: Optional[Mesh], rules: Optional[dict] = None):
    prev = (_CTX.mesh, _CTX.rules)
    _CTX.mesh = mesh
    _CTX.rules = rules if rules is not None else (make_rules(mesh) if mesh else None)
    try:
        yield
    finally:
        _CTX.mesh, _CTX.rules = prev


def current_mesh() -> Optional[Mesh]:
    return _CTX.mesh


def current_rules() -> Optional[dict]:
    return _CTX.rules


_MISSING = object()


def hint(x: jax.Array, logical: tuple) -> jax.Array:
    """Sharding constraint under an ambient mesh; identity otherwise.

    If any named logical axis is absent from the active rules the hint is a
    no-op (lets optional hints — e.g. MoE buffer EP constraints — be enabled
    per-run by adding the rule, without constraining baseline runs)."""
    if _CTX.mesh is None:
        return x
    if any(n is not None and _CTX.rules.get(n, _MISSING) is _MISSING for n in logical):
        return x
    spec = spec_for(_CTX.mesh, _CTX.rules, logical, x.shape)
    return lax.with_sharding_constraint(x, NamedSharding(_CTX.mesh, spec))
