"""In-flight message plane: a multi-tick delay/loss network model for the
vectorized lease engine.

PaxosLease's whole claim (§1) is safety under message loss, reordering and
in-transit delays. The synchronous tick (`ref.lease_step_ref`) resolves a
whole prepare/propose round in one zero-delay instant, so none of those
behaviors exist at array scale. This module adds them as *dense state*:

  - five in-flight planes, one per protocol phase plus §7 releases
    (``prepare_req / prepare_resp / propose_req / propose_resp / rel``),
    each a ``[A, N]`` slot array carrying the message's ballot and its delivery
    quarter-tick (ballot 0 = empty slot). A slot holds at most one message
    per (acceptor, cell) — the ``random_trace`` spacing construction
    guarantees live messages never collide (see ``trace.py``);
  - a proposer *round* plane: open ballot, phase (preparing/proposing),
    the quarter-tick the proposer's own lease timer will expire (started
    when a majority of opens is in hand — the §4 ordering), a
    timeout-and-abandon deadline, and per-acceptor response masks so
    duplicated deliveries can never double-count a quorum (the event
    engine's ``set``-of-acceptors bookkeeping, vectorized).

Per tick, messages *sent* at tick ``t`` on the link between proposer ``p``
and acceptor ``a`` — request or response, either direction — take
``delay[p, a]`` whole ticks and are lost iff ``drop[p, a]``: asymmetric
per-(proposer, acceptor) link matrices (a straggler replica, a lossy rack
uplink, a slow cross-zone pair), mirroring a deterministic per-message
delay policy pinned onto the event-driven ``sim.network.Network`` (see
``trace.replay_event_sim``). The link matrices arrive flattened as
``[P*A, bn]`` blocks (row ``p*A + a``); each send leg gathers its row by
the proposer id it involves (``_link_rows``) — the attempt row for
prepare broadcasts, the in-flight ballot's proposer for response legs.
Symmetric per-acceptor schedules are the P-broadcast special case.
Reachability (``acc_up``) is checked when a *request* is delivered,
exactly like the event transport checks ``set_down`` at delivery time;
responses generated at that same tick see the same mask, like ``send``
checking its source.

§7 releases are routed through the same plane: a releasing proposer stops
believing it owns immediately (a local action), but the discard messages
to the acceptors ride the ``rel_*`` in-flight slots — delayed by their
link and droppable like any other leg. In the event sim they deliver at
``REL_EPS`` inside the drain window, before any phase message (see
``trace.py``).

With all-zero delay/drop planes every message is generated and consumed
inside one tick, the slots stay empty, and the step is bit-identical to
the synchronous `lease_step_ref` — the PR 1 model is the zero-delay
special case.

``delayed_tick_math`` is pure elementwise/sublane-reduction jnp on plain
arrays, so the SAME function is the jnp oracle's body (`ref.py`) and the
fused Pallas kernel's body (`kernel.py`): the two backends agree bit-for-
bit by construction.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .state import NO_PROPOSER, QUARTERS

# round phases
R_IDLE, R_PREPARING, R_PROPOSING = 0, 1, 2


class NetPlaneState(NamedTuple):
    """In-flight messages + open proposer rounds. All arrays int32.

    Slot encoding: ``*_b`` is the message ballot (0 = empty slot), ``*_at``
    the delivery quarter-tick (``4 * deliver_tick``). ``presp_pay`` is the
    prepare response's payload: the acceptor's accepted proposer at grant
    time (NO_PROPOSER = empty/open). Round rows are ``[1, N]``; response
    masks ``[A, N]``.
    """

    preq_b: jax.Array      # [A, N] prepare requests in flight
    preq_at: jax.Array     # [A, N]
    presp_b: jax.Array     # [A, N] prepare responses (grants only) in flight
    presp_at: jax.Array    # [A, N]
    presp_pay: jax.Array   # [A, N] accepted proposer payload (-1 = open)
    poreq_b: jax.Array     # [A, N] propose requests in flight
    poreq_at: jax.Array    # [A, N]
    poresp_b: jax.Array    # [A, N] propose responses (accepts only) in flight
    poresp_at: jax.Array   # [A, N]
    rel_b: jax.Array       # [A, N] §7 release messages in flight
    rel_at: jax.Array      # [A, N]
    rnd_ballot: jax.Array    # [1, N] open round's ballot (0 = no round)
    rnd_phase: jax.Array     # [1, N] R_IDLE / R_PREPARING / R_PROPOSING
    rnd_expiry: jax.Array    # [1, N] quarter-tick the proposer's timer expires
    rnd_deadline: jax.Array  # [1, N] quarter-tick the round is abandoned
    rnd_open: jax.Array      # [A, N] acceptors whose open response counted
    rnd_acc: jax.Array       # [A, N] acceptors whose accept counted

    @property
    def n_acceptors(self) -> int:
        return self.preq_b.shape[0]

    @property
    def n_cells(self) -> int:
        return self.preq_b.shape[1]


def init_netplane(n_cells: int, n_acceptors: int) -> NetPlaneState:
    za = jnp.zeros((n_acceptors, n_cells), jnp.int32)
    zr = jnp.zeros((1, n_cells), jnp.int32)
    return NetPlaneState(
        preq_b=za, preq_at=za,
        presp_b=za, presp_at=za, presp_pay=jnp.full_like(za, NO_PROPOSER),
        poreq_b=za, poreq_at=za,
        poresp_b=za, poresp_at=za,
        rel_b=za, rel_at=za,
        rnd_ballot=zr, rnd_phase=zr, rnd_expiry=zr, rnd_deadline=zr,
        rnd_open=za, rnd_acc=za,
    )


def _link_rows(flat: jnp.ndarray, prop, n_acceptors: int) -> jnp.ndarray:
    """Gather the [A, bn] link rows of a flattened ``[P*A, bn]`` matrix for
    the proposer each column's leg involves.

    ``prop`` is an int32 proposer-id array, either ``[1, bn]`` (one sender
    per cell: attempts, open rounds, releases) or ``[A, bn]`` (per-slot:
    the in-flight ballot's proposer on response legs). Ids outside
    [0, P) — the no-attempt sentinel, empty slots — select zeros; every
    such leg is gated off by its own send/due mask anyway. The P loop is
    compile-time (P is tiny), keeping the math elementwise on 2D blocks —
    Pallas-sublane friendly, no dynamic gather.
    """
    A = n_acceptors
    P = flat.shape[0] // A
    out = jnp.zeros((A,) + flat.shape[1:], flat.dtype)
    for p in range(P):
        out = jnp.where(prop == p, flat[p * A:(p + 1) * A], out)
    return out


def delayed_tick_math(
    lease: tuple,      # LeaseArrayState fields, [A, bn] / [P, bn] blocks
    net: tuple,        # NetPlaneState fields, [A, bn] / [1, bn] blocks
    t,                 # scalar int32 tick
    attempt,           # [1, bn] int32 proposer id attempting (-1 = none)
    release,           # [1, bn] int32 proposer id releasing (-1 = none)
    up,                # [A, bn] int32 acceptor reachability this tick
    delay,             # [P*A, bn] int32 link delays (ticks) for legs sent this tick
    drop,              # [P*A, bn] int32 1 = lose legs sent this tick
    *,
    majority: int,
    lease_q4: int,     # lease timespan in quarter-ticks
    round_q4: int,     # timeout-and-abandon horizon in quarter-ticks
) -> tuple[tuple, tuple, jnp.ndarray]:
    """One tick of the delayed model. Returns (lease', net', owner_count).

    Within-tick order mirrors the event scheduler's drain window exactly:
    expiries fired before the tick boundary, then releases/attempts issued
    at the boundary, then the round-abandon timer, then deliveries in
    causal phase order (a zero-delay message cascades through all four
    phases inside this same tick).
    """
    (promised, acc_ballot, acc_prop, acc_expiry,
     own_mask, own_expiry, own_ballot) = lease
    (preq_b, preq_at, presp_b, presp_at, presp_pay,
     poreq_b, poreq_at, poresp_b, poresp_at,
     rel_b, rel_at,
     rnd_ballot, rnd_phase, rnd_expiry, rnd_deadline,
     rnd_open, rnd_acc) = net

    A = up.shape[0]
    P = own_mask.shape[0]
    t4 = QUARTERS * t
    p_ids = jax.lax.broadcasted_iota(jnp.int32, own_mask.shape, 0)  # [P, bn]
    up = up > 0
    dq4 = QUARTERS * delay                                          # [P*A, bn]
    # per-leg link gathers: [A, bn] delay/drop rows for a given sender id
    leg_dq4 = lambda prop: _link_rows(dq4, prop, A)
    leg_drop = lambda prop: _link_rows(drop, prop, A) > 0

    # -- 1. expiry ---------------------------------------------------------
    acc_live = (acc_ballot > 0) & (acc_expiry > t4)
    acc_ballot = jnp.where(acc_live, acc_ballot, 0)
    acc_prop = jnp.where(acc_live, acc_prop, NO_PROPOSER)
    acc_expiry = jnp.where(acc_live, acc_expiry, 0)
    own_live = (own_mask > 0) & (own_expiry > t4)
    own_mask = own_live.astype(jnp.int32)
    own_expiry = jnp.where(own_live, own_expiry, 0)
    own_ballot = jnp.where(own_live, own_ballot, 0)

    # -- 2. release (§7, routed through the network) -----------------------
    # 2a. the local action: the releasing owner stops believing NOW (the
    #     §7 "switch to non-owner first" ordering) ...
    rel = release                                                   # [1, bn]
    rel_owner = (p_ids == rel) & (own_mask > 0)                     # [P, bn]
    rel_ballot = jnp.sum(jnp.where(rel_owner, own_ballot, 0), axis=0, keepdims=True)
    own_mask = jnp.where(rel_owner, 0, own_mask)
    # 2b. ... then the discard messages ride the in-flight plane, delayed
    #     and droppable per (releasing proposer, acceptor) link
    send_rel = (rel_ballot > 0) & ~leg_drop(rel)                    # [A, bn]
    rel_b = jnp.where(send_rel, rel_ballot, rel_b)
    rel_at = jnp.where(send_rel, t4 + leg_dq4(rel), rel_at)
    # 2c. deliver due releases (a zero-delay one lands this same tick):
    #     discard iff still reachable and the accepted ballot matches
    rel_due = (rel_b > 0) & (rel_at <= t4)
    discard = rel_due & up & (acc_ballot == rel_b)                  # [A, bn]
    acc_ballot = jnp.where(discard, 0, acc_ballot)
    acc_prop = jnp.where(discard, NO_PROPOSER, acc_prop)
    acc_expiry = jnp.where(discard, 0, acc_expiry)
    rel_b = jnp.where(rel_due, 0, rel_b)
    rel_at = jnp.where(rel_due, 0, rel_at)

    # -- 3. round lifecycle ------------------------------------------------
    # a release wipes the releasing proposer's open round (Proposer.release
    # sets st.round = None); a timed-out round is abandoned (the event
    # round timer fires before this tick's deliveries); a new attempt
    # overwrites whatever round was open (Proposer._start_round).
    rnd_prop = rnd_ballot % P                                       # [1, bn]
    rel_kills = (rnd_ballot > 0) & (rel >= 0) & (rnd_prop == rel)
    timed_out = (rnd_ballot > 0) & (t4 >= rnd_deadline)
    att = attempt                                                   # [1, bn]
    has_att = att >= 0
    new_ballot = jnp.where(has_att, (t + 1) * P + att, 0)
    keep = (rnd_ballot > 0) & ~timed_out & ~rel_kills & ~has_att
    rnd_ballot = jnp.where(has_att, new_ballot, jnp.where(keep, rnd_ballot, 0))
    rnd_phase = jnp.where(
        has_att, R_PREPARING, jnp.where(keep, rnd_phase, R_IDLE)
    )
    rnd_expiry = jnp.where(keep, rnd_expiry, 0)
    rnd_deadline = jnp.where(
        has_att, t4 + round_q4, jnp.where(keep, rnd_deadline, 0)
    )
    fresh = has_att | ~keep                                         # [1, bn]
    rnd_open = jnp.where(fresh, 0, rnd_open)                        # [A, bn]
    rnd_acc = jnp.where(fresh, 0, rnd_acc)

    # -- 4a. broadcast prepare requests for new attempts -------------------
    send_preq = has_att & ~leg_drop(att)                            # [A, bn]
    preq_b = jnp.where(send_preq, new_ballot, preq_b)
    preq_at = jnp.where(send_preq, t4 + leg_dq4(att), preq_at)

    # -- 4b. deliver prepare requests at acceptors (§3.2) ------------------
    preq_due = (preq_b > 0) & (preq_at <= t4)
    grant = preq_due & up & (preq_b >= promised)
    promised = jnp.where(grant, preq_b, promised)
    # the response leg belongs to the REQUESTER's link: each slot's ballot
    # names the proposer the grant travels back to
    preq_prop = preq_b % P                                          # [A, bn]
    send_presp = grant & ~leg_drop(preq_prop)
    presp_b = jnp.where(send_presp, preq_b, presp_b)
    presp_at = jnp.where(send_presp, t4 + leg_dq4(preq_prop), presp_at)
    presp_pay = jnp.where(send_presp, acc_prop, presp_pay)
    preq_b = jnp.where(preq_due, 0, preq_b)
    preq_at = jnp.where(preq_due, 0, preq_at)

    # -- 4c. deliver prepare responses at proposers (§3.3) -----------------
    presp_due = (presp_b > 0) & (presp_at <= t4)
    rnd_prop = rnd_ballot % P  # recompute: the round may have changed above
    match_prep = (
        presp_due & (presp_b == rnd_ballot) & (rnd_phase == R_PREPARING)
    )
    # §6 extend: a response carrying our own proposal counts as open only
    # while we still believe we own (checked at ARRIVAL, like st.owner)
    rnd_prop_owns = jnp.sum(
        jnp.where((p_ids == rnd_prop) & (own_mask > 0), 1, 0),
        axis=0, keepdims=True,
    ) > 0                                                           # [1, bn]
    is_open = match_prep & (
        (presp_pay == NO_PROPOSER) | ((presp_pay == rnd_prop) & rnd_prop_owns)
    )
    rnd_open = jnp.where(is_open, 1, rnd_open)  # set-union: duplicate-proof
    opens = jnp.sum(rnd_open, axis=0, keepdims=True)                # [1, bn]
    to_propose = (
        (rnd_ballot > 0) & (rnd_phase == R_PREPARING) & (opens >= majority)
    )
    # majority open: start OUR timer first, then broadcast the proposal —
    # the ordering the §4 proof depends on
    rnd_phase = jnp.where(to_propose, R_PROPOSING, rnd_phase)
    rnd_expiry = jnp.where(to_propose, t4 + lease_q4, rnd_expiry)
    send_poreq = to_propose & ~leg_drop(rnd_prop)                   # [A, bn]
    poreq_b = jnp.where(send_poreq, rnd_ballot, poreq_b)
    poreq_at = jnp.where(send_poreq, t4 + leg_dq4(rnd_prop), poreq_at)
    presp_b = jnp.where(presp_due, 0, presp_b)
    presp_at = jnp.where(presp_due, 0, presp_at)
    presp_pay = jnp.where(presp_due, NO_PROPOSER, presp_pay)

    # -- 4d. deliver propose requests at acceptors (§3.4) ------------------
    poreq_due = (poreq_b > 0) & (poreq_at <= t4)
    accept = poreq_due & up & (poreq_b >= promised)
    poreq_prop = poreq_b % P                                        # [A, bn]
    acc_ballot = jnp.where(accept, poreq_b, acc_ballot)
    acc_prop = jnp.where(accept, poreq_prop, acc_prop)
    acc_expiry = jnp.where(accept, t4 + lease_q4, acc_expiry)
    send_poresp = accept & ~leg_drop(poreq_prop)
    poresp_b = jnp.where(send_poresp, poreq_b, poresp_b)
    poresp_at = jnp.where(send_poresp, t4 + leg_dq4(poreq_prop), poresp_at)
    poreq_b = jnp.where(poreq_due, 0, poreq_b)
    poreq_at = jnp.where(poreq_due, 0, poreq_at)

    # -- 4e. deliver propose responses at proposers (§3.5) -----------------
    poresp_due = (poresp_b > 0) & (poresp_at <= t4)
    match_prop = (
        poresp_due & (poresp_b == rnd_ballot) & (rnd_phase == R_PROPOSING)
    )
    rnd_acc = jnp.where(match_prop, 1, rnd_acc)
    accs = jnp.sum(rnd_acc, axis=0, keepdims=True)
    # the timer started in 4c bounds the claim (§3 step 5): accepts landing
    # after our own lease window elapsed must not make us owner
    win = (
        (rnd_ballot > 0) & (rnd_phase == R_PROPOSING)
        & (accs >= majority) & (rnd_expiry > t4)
    )
    new_owner = (p_ids == (rnd_ballot % P)) & win                   # [P, bn]
    own_mask = jnp.where(new_owner, 1, own_mask)
    own_expiry = jnp.where(new_owner, rnd_expiry, own_expiry)  # timer from 4c
    own_ballot = jnp.where(new_owner, rnd_ballot, own_ballot)
    rnd_ballot = jnp.where(win, 0, rnd_ballot)
    rnd_phase = jnp.where(win, R_IDLE, rnd_phase)
    rnd_expiry = jnp.where(win, 0, rnd_expiry)
    rnd_deadline = jnp.where(win, 0, rnd_deadline)
    rnd_open = jnp.where(win, 0, rnd_open)
    rnd_acc = jnp.where(win, 0, rnd_acc)
    poresp_b = jnp.where(poresp_due, 0, poresp_b)
    poresp_at = jnp.where(poresp_due, 0, poresp_at)

    lease_out = (promised, acc_ballot, acc_prop, acc_expiry,
                 own_mask, own_expiry, own_ballot)
    net_out = (preq_b, preq_at, presp_b, presp_at, presp_pay,
               poreq_b, poreq_at, poresp_b, poresp_at,
               rel_b, rel_at,
               rnd_ballot, rnd_phase, rnd_expiry, rnd_deadline,
               rnd_open, rnd_acc)
    owner_count = jnp.sum(own_mask, axis=0, keepdims=True)          # [1, bn]
    return lease_out, net_out, owner_count
