"""In-flight message plane: a multi-tick delay/loss network model for the
vectorized lease engine.

PaxosLease's whole claim (§1) is safety under message loss, reordering and
in-transit delays. The synchronous tick (`ref.lease_step_ref`) resolves a
whole prepare/propose round in one zero-delay instant, so none of those
behaviors exist at array scale. This module adds them as *dense state*:

  - five in-flight planes, one per protocol phase plus §7 releases
    (``prepare / prepare-response / propose / propose-response / rel``),
    each a ``[A, N]`` slot array. A slot packs the message's ballot and its
    delivery quarter-tick into ONE int32 — ``deliver_q4 << PACK_SHIFT |
    ballot`` (0 = empty slot) — so "is this slot due at t?" is two compares
    on one plane (``0 < slot < (t4+1) << PACK_SHIFT``) and a delivery
    clears it with a single select. A slot holds at most one message per
    (acceptor, cell) — the ``random_trace`` spacing construction
    guarantees live messages never collide (see ``trace.py``);
  - a proposer *round* plane, all ``[1, N]`` rows: open ballot, phase
    (preparing/proposing), the quarter-tick the proposer's own lease timer
    will expire (started when a majority of opens is in hand — the §4
    ordering), a timeout-and-abandon deadline, and per-acceptor response
    *bitmasks* (bit ``a`` set = acceptor ``a``'s vote counted) so
    duplicated deliveries can never double-count a quorum (the event
    engine's ``set``-of-acceptors bookkeeping, vectorized into one int).

Per tick, messages *sent* at tick ``t`` on the link between proposer ``p``
and acceptor ``a`` — request or response, either direction — take
``delay[p, a]`` whole ticks and are lost iff ``drop[p, a]``: asymmetric
per-(proposer, acceptor) link matrices (a straggler replica, a lossy rack
uplink, a slow cross-zone pair), mirroring a deterministic per-message
delay policy pinned onto the event-driven ``sim.network.Network`` (see
``trace.replay_event_sim``). Both planes arrive fused into one tiny
``[P, A]`` *link matrix* — ``delay << 1 | drop`` (``pack_link``) — that is
indexed block-locally per leg by the proposer id the leg involves: the
jnp oracle gathers rows with ``take_along_axis`` (``legs_gather``), the
Pallas kernel selects them in a compile-time P-loop (``legs_select``) so
no gather indices ever materialize in HBM. Both produce identical int32
values; the flattened ``[P*A, N]`` per-cell broadcast of earlier
revisions is gone. Symmetric per-acceptor schedules are the P-broadcast
special case. Reachability (``acc_up``) is checked when a *request* is
delivered, exactly like the event transport checks ``set_down`` at
delivery time; responses generated at that same tick see the same mask,
like ``send`` checking its source.

§7 releases are routed through the same plane: a releasing proposer stops
believing it owns immediately (a local action), but the discard messages
to the acceptors ride the ``rel`` in-flight slots — delayed by their
link and droppable like any other leg. In the event sim they deliver at
``REL_EPS`` inside the drain window, before any phase message (see
``trace.py``).

With all-zero delay/drop planes every message is generated and consumed
inside one tick, the slots stay empty, and the step is bit-identical to
the synchronous `lease_step_ref` — the PR 1 model is the zero-delay
special case.

``delayed_tick_math`` is pure elementwise/sublane-reduction jnp on plain
arrays, so the SAME function is the jnp oracle's body (`ref.py`) and the
fused Pallas kernel's body (`kernel.py`): the two backends agree bit-for-
bit by construction.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .state import (
    NO_PROPOSER,
    PACK_MASK,
    PACK_SHIFT,
    QUARTERS,
    RESTART_SHIFT,
    ballot_proposer,
    clock_select,
    pack_pair,
    packed_ballot,
    packed_q4,
)

# round phases
R_IDLE, R_PREPARING, R_PROPOSING = 0, 1, 2

MAX_VOTE_ACCEPTORS = PACK_SHIFT  # vote bitmasks must stay positive int32


def pack_slot(ballot, deliver_q4):
    """One in-flight message as one int32 (0 = empty slot)."""
    return pack_pair(deliver_q4, ballot)


def pack_link(delay, drop):
    """Fuse (delay ticks, drop mask) into the one-plane link matrix."""
    return (jnp.asarray(delay, jnp.int32) << 1) | (
        jnp.asarray(drop, jnp.int32) & 1
    )


class NetPlaneState(NamedTuple):
    """In-flight messages + open proposer rounds. All arrays int32.

    Slot planes are ``[A, N]`` packed ``deliver_q4 << PACK_SHIFT | ballot``
    ints (``pack_slot``; 0 = empty). ``presp_pay`` is the prepare
    response's payload: the acceptor's accepted proposer at grant time
    (NO_PROPOSER = empty/open). Round rows are ``[1, N]``; the vote sets
    ``rnd_open_bits``/``rnd_acc_bits`` are per-acceptor bitmasks.

    The unpacked views of earlier revisions remain as properties
    (``preq_b``/``preq_at``/…, ``rnd_open``/``rnd_acc`` as [A, N] 0/1
    masks) for tests and diagnostics.
    """

    preq: jax.Array          # [A, N] prepare requests in flight (packed)
    presp: jax.Array         # [A, N] prepare responses (grants only, packed)
    presp_pay: jax.Array     # [A, N] accepted proposer payload (-1 = open)
    poreq: jax.Array         # [A, N] propose requests in flight (packed)
    poresp: jax.Array        # [A, N] propose responses (accepts only, packed)
    rel: jax.Array           # [A, N] §7 release messages in flight (packed)
    rnd_ballot: jax.Array    # [1, N] open round's ballot (0 = no round)
    rnd_phase: jax.Array     # [1, N] R_IDLE / R_PREPARING / R_PROPOSING
    rnd_expiry: jax.Array    # [1, N] LOCAL quarter-tick (round owner's clock) its guarded timer expires
    rnd_deadline: jax.Array  # [1, N] LOCAL quarter-tick (round owner's clock) the round is abandoned
    rnd_open_bits: jax.Array  # [1, N] bitmask of acceptors whose open counted
    rnd_acc_bits: jax.Array   # [1, N] bitmask of acceptors whose accept counted

    @property
    def n_acceptors(self) -> int:
        return self.preq.shape[0]

    @property
    def n_cells(self) -> int:
        return self.preq.shape[1]

    # ------------------------------------------------- unpacked views
    def _bits_mask(self, bits: jax.Array) -> jax.Array:
        a_ids = jax.lax.broadcasted_iota(jnp.int32, self.preq.shape, 0)
        return (bits >> a_ids) & 1

    @property
    def rnd_open(self) -> jax.Array:
        """[A, N] 0/1: acceptors whose open response counted."""
        return self._bits_mask(self.rnd_open_bits)

    @property
    def rnd_acc(self) -> jax.Array:
        """[A, N] 0/1: acceptors whose accept counted."""
        return self._bits_mask(self.rnd_acc_bits)


def _slot_views(name: str):
    def ballot_view(self) -> jax.Array:
        return packed_ballot(getattr(self, name))

    def at_view(self) -> jax.Array:
        return packed_q4(getattr(self, name))

    return property(ballot_view), property(at_view)


for _slot in ("preq", "presp", "poreq", "poresp", "rel"):
    _b, _at = _slot_views(_slot)
    setattr(NetPlaneState, f"{_slot}_b", _b)
    setattr(NetPlaneState, f"{_slot}_at", _at)


def init_netplane(n_cells: int, n_acceptors: int) -> NetPlaneState:
    if n_acceptors > MAX_VOTE_ACCEPTORS:
        raise ValueError(
            f"netplane vote bitmasks support at most {MAX_VOTE_ACCEPTORS} "
            f"acceptors; got {n_acceptors}"
        )
    za = jnp.zeros((n_acceptors, n_cells), jnp.int32)
    zr = jnp.zeros((1, n_cells), jnp.int32)
    return NetPlaneState(
        preq=za,
        presp=za, presp_pay=jnp.full_like(za, NO_PROPOSER),
        poreq=za, poresp=za,
        rel=za,
        rnd_ballot=zr, rnd_phase=zr, rnd_expiry=zr, rnd_deadline=zr,
        rnd_open_bits=zr, rnd_acc_bits=zr,
    )


# ---------------------------------------------------------------------------
# per-leg link indexing: [P, A] link matrix -> ([A, bn] delay_q4, drop) rows
# for the proposer each column's leg involves. ``prop`` is an int32
# proposer-id array, either [1, bn] (one sender per cell: attempts, open
# rounds, releases) or [A, bn] (per-slot: the in-flight ballot's proposer
# on response legs). Ids outside [0, P) — the no-attempt sentinel — pick
# arbitrary rows; every such leg is gated off by its own send/due mask.
# ---------------------------------------------------------------------------
def legs_select(link, prop):
    """Compile-time P-loop of selects — no dynamic gather, block-local:
    the Pallas kernel's strategy (`link` is a VMEM-resident [P, A] block,
    its rows broadcast against the lane axis)."""
    P, A = link.shape
    v = jnp.zeros((A,) + prop.shape[1:], link.dtype)
    for p in range(P):
        v = jnp.where(prop == p, link[p][:, None], v)
    return QUARTERS * (v >> 1), (v & 1) > 0


def legs_gather(link, prop):
    """One `take_along_axis` row gather — the XLA-lowered strategy (the
    jnp oracle / fused fallback). Bit-identical to `legs_select`."""
    P, A = link.shape
    idx = jnp.clip(prop, 0, P - 1)
    if idx.shape[0] == 1:
        idx = jnp.broadcast_to(idx, (A,) + idx.shape[1:])
    v = jnp.take_along_axis(link.T, idx, axis=1)
    return QUARTERS * (v >> 1), (v & 1) > 0


def delayed_tick_math(
    lease: tuple,      # PackedLeaseState fields, [A, bn] / [1, bn] blocks
    net: tuple,        # NetPlaneState fields, [A, bn] / [1, bn] blocks
    t,                 # scalar int32 tick
    attempt,           # [1, bn] int32 proposer id attempting (-1 = none)
    release,           # [1, bn] int32 proposer id releasing (-1 = none)
    up,                # [A, 1|bn] int32 acceptor reachability this tick
    pclk,              # [P, 1|bn] int32 proposer local clocks (quarter-ticks)
    aclk,              # [A, 1|bn] int32 acceptor local clocks (quarter-ticks)
    link,              # [P, A] int32 fused link matrix (delay << 1 | drop)
    *,
    majority: int,
    lease_q4: int,     # lease timespan in quarter-ticks
    round_q4: int,     # timeout-and-abandon horizon in quarter-ticks
    n_proposers: int,
    guard_q4: int = None,  # proposer's guarded own timer (default: no drift)
    legs=legs_gather,  # per-leg link strategy (select inside Pallas)
    extend=None,       # [1, bn] int32 proposer id extending its own lease (§6)
    stale=None,        # [A, 1|bn] adversarial: honor below-promise ballots
    equiv=None,        # [A, 1|bn] adversarial: report a live lease as open
    acc_restart=None,  # [A, 1|bn] diskless acceptor crash+restart this tick
    acc_deaf=None,     # [A, 1|bn] acceptor inside its post-restart deaf window
    prop_restart=None,  # [P, 1|bn] proposer crash+restart this tick
    prop_rc=None,       # [P, 1|bn] accumulated per-proposer restart counters
) -> tuple[tuple, tuple, jnp.ndarray]:
    """One tick of the delayed model on the packed layout. Returns
    (lease', net', owner_count[1, bn]).

    Within-tick order mirrors the event scheduler's drain window exactly:
    expiries fired before the tick boundary, then releases/attempts issued
    at the boundary, then the round-abandon timer, then deliveries in
    causal phase order (a zero-delay message cascades through all four
    phases inside this same tick). ``owner_count`` is 0/1 from the single
    believed-owner row, plus 1 at any tick a win would overwrite a live
    *other* belief — the §4 alarm survives the packed owner plane.

    Two time bases coexist (§4: no clock synchrony): message deliver-ats
    are GLOBAL quarter-ticks (the network has no clock), while every
    node-side timer — acceptor lease expiry, the proposer's guarded own
    timer (``guard_q4``), the round-abandon horizon — is minted from and
    compared against that node's LOCAL clock (``pclk``/``aclk``,
    accumulated local quarter-ticks; per-cell owner/round rows read the
    relevant proposer's entry via `state.clock_select`). All-``4t`` clock
    planes reproduce the rate-1 engine bit-for-bit.

    ``extend`` is the §6 owner-extension plane: an owner re-proposes
    in-flight to renew before expiry. The id is gated on the proposer's
    OWN belief AFTER this tick's expiry/restart/release phases (so a
    same-tick §7 release wins and the extend is a no-op, exactly like
    ``Proposer._renew``'s ``st.want and st.owner`` guard), then merged
    into the attempt row — an extend is a full fresh §3 round whose
    prepare responses count the owner's live proposal as open (phase 4c
    below). A non-owner extend id is a no-op. An explicit attempt on the
    same cell takes precedence. ``None`` traces no extend ops at all
    (honest path byte-identical).

    ``stale``/``equiv`` are the adversarial corruption masks (the
    falsification engine's negative controls — Byzantine acceptors in the
    spirit of dca's byzantine variants): where ``stale`` is set the
    acceptor grants prepares and accepts proposes whose ballot is BELOW
    its promise (§3.2/§3.4 broken; its promise still only ratchets up),
    and where ``equiv`` is set its prepare response lies that it holds no
    accepted lease (the §3.3 open count poisons). Passing ``None`` (the
    default) traces no corruption ops at all, so the honest path's jaxpr
    is byte-identical to a build without these arguments.

    ``acc_restart``/``acc_deaf``/``prop_restart``/``prop_rc`` are the
    crash/restart inputs (paper §2's diskless failure model). An acceptor
    restart blanks its column — promises, accepted lease and its own
    not-yet-delivered responses — and ``acc_deaf`` (precomputed by the ops
    layer from the accumulated clock planes: deaf while the local clock is
    within a maximal lease span of the restart) makes it unreachable like
    ``acc_up = 0``. A proposer restart drops its owner belief, abandons its
    open round, and — via ``prop_rc``, the inclusive running restart count
    — mints subsequent ballots with the restart counter carved into the
    upper word (``state.RESTART_SHIFT``), so numeric ballot order equals
    the event engine's (run, restart, proposer) ``Ballot`` order. ``None``
    defaults trace no restart ops at all (honest path byte-identical); the
    four arrive together or not at all.
    """
    promised, acc_lease, own_id, ownp = lease
    (preq, presp, presp_pay, poreq, poresp, rel_s,
     rnd_ballot, rnd_phase, rnd_expiry, rnd_deadline,
     rnd_open_bits, rnd_acc_bits) = net

    P = n_proposers
    if guard_q4 is None:
        guard_q4 = lease_q4
    t4 = QUARTERS * t
    live_min = (t4 + 1) << PACK_SHIFT  # GLOBAL time base: slot due iff <
    a_ids = jax.lax.broadcasted_iota(jnp.int32, promised.shape, 0)
    a_bit = 1 << a_ids                                             # [A, bn]
    up = up > 0
    stale_b = None if stale is None else stale > 0
    equiv_b = None if equiv is None else equiv > 0

    def due(slot):
        return (slot > 0) & (slot < live_min)

    def votes(bits):  # popcount over the A vote bits (A is compile-time)
        n = bits & 1
        for a in range(1, promised.shape[0]):
            n = n + ((bits >> a) & 1)
        return n

    # -- 1. expiry (each node's own local clock) ---------------------------
    acc_lease = jnp.where(acc_lease >= ((aclk + 1) << PACK_SHIFT), acc_lease, 0)
    own_clk = clock_select(pclk, own_id)                           # [1, bn]
    own_live = ownp >= ((own_clk + 1) << PACK_SHIFT)
    ownp = jnp.where(own_live, ownp, 0)
    own_id = jnp.where(own_live, own_id, NO_PROPOSER)

    # -- 1.5 crash/restart injection (§2: the diskless failure model) ------
    if acc_restart is not None:
        rst_a = acc_restart > 0                                    # [A, bn]
        # a diskless acceptor comes back BLANK: its promises, accepted
        # lease and its own not-yet-delivered responses are gone; requests
        # in flight TO it live in the network and survive
        promised = jnp.where(rst_a, 0, promised)
        acc_lease = jnp.where(rst_a, 0, acc_lease)
        presp = jnp.where(rst_a, 0, presp)
        presp_pay = jnp.where(rst_a, NO_PROPOSER, presp_pay)
        poresp = jnp.where(rst_a, 0, poresp)
    if acc_deaf is not None:
        # ... and stays deaf for a maximal lease span ON ITS OWN CLOCK (the
        # window is precomputed from the accumulated clock planes); a deaf
        # acceptor is unreachable exactly like acc_up = 0
        up = up & ~(acc_deaf > 0)
    if prop_restart is not None:
        # a restarted proposer loses its volatile owner belief NOW (its
        # open round is abandoned in phase 3 below)
        own_rst = clock_select(prop_restart, own_id) > 0           # [1, bn]
        ownp = jnp.where(own_rst, 0, ownp)
        own_id = jnp.where(own_rst, NO_PROPOSER, own_id)

    # -- 2. release (§7, routed through the network) -----------------------
    # 2a. the local action: the releasing owner stops believing NOW (the
    #     §7 "switch to non-owner first" ordering) ...
    rel = release                                                   # [1, bn]
    has_rel = rel >= 0
    rel_owner = has_rel & (own_id == rel)
    rel_ballot = jnp.where(rel_owner, ownp & PACK_MASK, 0)
    ownp = jnp.where(rel_owner, 0, ownp)
    own_id = jnp.where(rel_owner, NO_PROPOSER, own_id)
    # 2b. ... then the discard messages ride the in-flight plane, delayed
    #     and droppable per (releasing proposer, acceptor) link
    dq4, lost = legs(link, rel)                                     # [A, bn]
    send_rel = (rel_ballot > 0) & ~lost
    rel_s = jnp.where(send_rel, pack_slot(rel_ballot, t4 + dq4), rel_s)
    # 2c. deliver due releases (a zero-delay one lands this same tick):
    #     discard iff still reachable and the accepted ballot matches
    rel_due = due(rel_s)
    discard = rel_due & up & ((acc_lease & PACK_MASK) == (rel_s & PACK_MASK))
    acc_lease = jnp.where(discard, 0, acc_lease)
    rel_s = jnp.where(rel_due, 0, rel_s)

    # -- 3. round lifecycle ------------------------------------------------
    # a release wipes the releasing proposer's open round (Proposer.release
    # sets st.round = None); a timed-out round is abandoned (the event
    # round timer fires before this tick's deliveries); a new attempt
    # overwrites whatever round was open (Proposer._start_round).
    rnd_prop = ballot_proposer(rnd_ballot, P)                       # [1, bn]
    rel_kills = (rnd_ballot > 0) & has_rel & (rnd_prop == rel)
    if prop_restart is not None:
        # a restarted round owner abandons its open round (a crash loses
        # the volatile _Round; stale responses can no longer match it)
        rel_kills = rel_kills | (
            (rnd_ballot > 0) & (clock_select(prop_restart, rnd_prop) > 0)
        )
    # the abandon timer is a LOCAL timer: it fires once the round OWNER's
    # clock has advanced round_q4 local quarters past the attempt
    rnd_clk = clock_select(pclk, rnd_prop)                          # [1, bn]
    timed_out = (rnd_ballot > 0) & (rnd_clk >= rnd_deadline)
    att = attempt                                                   # [1, bn]
    if extend is not None:
        # §6: an extend is a fresh round started by the live owner — gated
        # on the local belief AFTER expiry/restart/release above, so a
        # same-tick §7 release (or a crash, or a lapsed timer) turns the
        # extend into a no-op. Attempts take precedence on collisions.
        ext_ok = (att < 0) & (extend >= 0) & (own_id == extend) & (ownp > 0)
        att = jnp.where(ext_ok, extend, att)
    has_att = att >= 0
    att_clk = clock_select(pclk, att)                               # [1, bn]
    if prop_rc is None:
        new_ballot = jnp.where(has_att, (t + 1) * P + att, 0)
    else:
        # restart mode: carve the attempting proposer's restart counter
        # into the ballot's upper word (state.RESTART_SHIFT) — numeric
        # order equals core.ballot's (run, restart, proposer) order
        rc_att = clock_select(prop_rc, att)                         # [1, bn]
        upper = ((t + 1) << RESTART_SHIFT) | rc_att
        new_ballot = jnp.where(has_att, upper * P + att, 0)
    keep = (rnd_ballot > 0) & ~timed_out & ~rel_kills & ~has_att
    rnd_ballot = jnp.where(has_att, new_ballot, jnp.where(keep, rnd_ballot, 0))
    rnd_phase = jnp.where(
        has_att, R_PREPARING, jnp.where(keep, rnd_phase, R_IDLE)
    )
    rnd_expiry = jnp.where(keep, rnd_expiry, 0)
    rnd_deadline = jnp.where(
        has_att, att_clk + round_q4, jnp.where(keep, rnd_deadline, 0)
    )
    fresh = has_att | ~keep                                         # [1, bn]
    rnd_open_bits = jnp.where(fresh, 0, rnd_open_bits)
    rnd_acc_bits = jnp.where(fresh, 0, rnd_acc_bits)

    # -- 4a. broadcast prepare requests for new attempts -------------------
    dq4, lost = legs(link, att)
    send_preq = has_att & ~lost                                     # [A, bn]
    preq = jnp.where(send_preq, pack_slot(new_ballot, t4 + dq4), preq)

    # -- 4b. deliver prepare requests at acceptors (§3.2) ------------------
    preq_due = due(preq)
    preq_b = preq & PACK_MASK
    if stale_b is None:
        grant = preq_due & up & (preq_b >= promised)
        promised = jnp.where(grant, preq_b, promised)
    else:
        # stale-ballot injection: the corrupted acceptor grants below its
        # promise too (the promise itself still only ratchets upward)
        grant = preq_due & up & ((preq_b >= promised) | stale_b)
        promised = jnp.where(grant, jnp.maximum(promised, preq_b), promised)
    # the response leg belongs to the REQUESTER's link: each slot's ballot
    # names the proposer the grant travels back to
    dq4, lost = legs(link, ballot_proposer(preq_b, P))
    send_presp = grant & ~lost
    acc_b = acc_lease & PACK_MASK                                   # [A, bn]
    acc_prop = jnp.where(acc_b > 0, ballot_proposer(acc_b, P), NO_PROPOSER)
    if equiv_b is not None:
        # equivocation: the corrupted acceptor's grant payload claims it
        # holds no accepted lease, whatever acc_lease says
        acc_prop = jnp.where(equiv_b, NO_PROPOSER, acc_prop)
    presp = jnp.where(send_presp, pack_slot(preq_b, t4 + dq4), presp)
    presp_pay = jnp.where(send_presp, acc_prop, presp_pay)
    preq = jnp.where(preq_due, 0, preq)

    # -- 4c. deliver prepare responses at proposers (§3.3) -----------------
    presp_due = due(presp)
    rnd_prop = ballot_proposer(rnd_ballot, P)  # recompute: round changed above
    rnd_clk = clock_select(pclk, rnd_prop)     # the round owner's clock
    match_prep = (
        presp_due & ((presp & PACK_MASK) == rnd_ballot)
        & (rnd_phase == R_PREPARING)
    )
    # §6 extend: a response carrying our own proposal counts as open only
    # while we still believe we own (checked at ARRIVAL, like st.owner)
    rnd_prop_owns = (own_id == rnd_prop) & (ownp > 0)               # [1, bn]
    is_open = match_prep & (
        (presp_pay == NO_PROPOSER) | ((presp_pay == rnd_prop) & rnd_prop_owns)
    )
    # set-union via the vote bitmask: duplicate-proof
    rnd_open_bits = rnd_open_bits | jnp.sum(
        jnp.where(is_open, a_bit, 0), axis=0, keepdims=True
    )
    opens = votes(rnd_open_bits)                                    # [1, bn]
    to_propose = (
        (rnd_ballot > 0) & (rnd_phase == R_PREPARING) & (opens >= majority)
    )
    # majority open: start OUR timer first, then broadcast the proposal —
    # the ordering the §4 proof depends on. The timer is the proposer's
    # LOCAL guarded timespan (the T·(1-ε)/(1+ε) drift discount)
    rnd_phase = jnp.where(to_propose, R_PROPOSING, rnd_phase)
    rnd_expiry = jnp.where(to_propose, rnd_clk + guard_q4, rnd_expiry)
    dq4, lost = legs(link, rnd_prop)
    send_poreq = to_propose & ~lost                                 # [A, bn]
    poreq = jnp.where(send_poreq, pack_slot(rnd_ballot, t4 + dq4), poreq)
    presp = jnp.where(presp_due, 0, presp)
    presp_pay = jnp.where(presp_due, NO_PROPOSER, presp_pay)

    # -- 4d. deliver propose requests at acceptors (§3.4) ------------------
    poreq_due = due(poreq)
    poreq_b = poreq & PACK_MASK
    accept = poreq_due & up & (poreq_b >= promised)
    if stale_b is not None:
        accept = poreq_due & up & ((poreq_b >= promised) | stale_b)
    # each accepting acceptor restarts the full-length timer on ITS clock
    acc_lease = jnp.where(accept, pack_pair(aclk + lease_q4, poreq_b), acc_lease)
    dq4, lost = legs(link, ballot_proposer(poreq_b, P))
    send_poresp = accept & ~lost
    poresp = jnp.where(send_poresp, pack_slot(poreq_b, t4 + dq4), poresp)
    poreq = jnp.where(poreq_due, 0, poreq)

    # -- 4e. deliver propose responses at proposers (§3.5) -----------------
    poresp_due = due(poresp)
    match_prop = (
        poresp_due & ((poresp & PACK_MASK) == rnd_ballot)
        & (rnd_phase == R_PROPOSING)
    )
    rnd_acc_bits = rnd_acc_bits | jnp.sum(
        jnp.where(match_prop, a_bit, 0), axis=0, keepdims=True
    )
    accs = votes(rnd_acc_bits)
    # the timer started in 4c bounds the claim (§3 step 5): accepts landing
    # after our own (local, guarded) lease window elapsed must not make us
    # owner — compared on the round owner's clock
    win = (
        (rnd_ballot > 0) & (rnd_phase == R_PROPOSING)
        & (accs >= majority) & (rnd_expiry > rnd_clk)
    )
    # a win that would overwrite a live OTHER belief is the §4 alarm
    viol = win & (ownp > 0) & (own_id != rnd_prop)
    own_id = jnp.where(win, rnd_prop, own_id)
    ownp = jnp.where(win, pack_pair(rnd_expiry, rnd_ballot), ownp)  # 4c timer
    rnd_ballot = jnp.where(win, 0, rnd_ballot)
    rnd_phase = jnp.where(win, R_IDLE, rnd_phase)
    rnd_expiry = jnp.where(win, 0, rnd_expiry)
    rnd_deadline = jnp.where(win, 0, rnd_deadline)
    rnd_open_bits = jnp.where(win, 0, rnd_open_bits)
    rnd_acc_bits = jnp.where(win, 0, rnd_acc_bits)
    poresp = jnp.where(poresp_due, 0, poresp)

    lease_out = (promised, acc_lease, own_id, ownp)
    net_out = (preq, presp, presp_pay, poreq, poresp, rel_s,
               rnd_ballot, rnd_phase, rnd_expiry, rnd_deadline,
               rnd_open_bits, rnd_acc_bits)
    owner_count = (ownp > 0).astype(jnp.int32) + viol.astype(jnp.int32)
    return lease_out, net_out, owner_count
