"""Time-resident fused Pallas kernels for the lease plane: the WHOLE tick
loop lives inside the kernel, not just one tick.

Earlier revisions dispatched one `pallas_call` per tick, round-tripping
every state plane through HBM ``T`` times per scenario and paying a kernel
launch per tick (the dispatch-dominated `lease_array_kernel_step` bench
row). The window kernels here replay a full ``[T, ...]`` scenario with ONE
launch: the grid is ``(cell_blocks, windows)`` with the window axis minor,
so each cell block's packed state stays **resident in VMEM** across the
whole scenario (the state BlockSpecs ignore the window index — Pallas
revisits the same block, no HBM writeback until the block changes), while
the per-tick scenario planes stream in one ``window``-tick slab at a time
and a `jax.lax.fori_loop` walks the ticks inside.

The tick bodies are the SAME functions the jnp oracle scans
(`ref.sync_tick_math`, `netplane.delayed_tick_math`), so kernel and oracle
are bit-identical by construction — including across window boundaries: a
message sent in window ``w`` with a deliver-at in window ``w+1`` simply
stays in its packed in-flight slot (part of the resident state) until the
later window's tick loop finds it due. Per-leg link delays are resolved
block-locally (`netplane.legs_select`): the tiny ``[P, A]`` link matrix of
the current tick is selected row-by-row in a compile-time P loop, so no
gather indices (and no flattened ``[P*A, N]`` planes) ever touch HBM.

Drifting clocks (§4) stream the same way: the per-tick ``[P, 1]``/
``[A, 1]`` *absolute local-clock* columns (exclusive prefix sums of the
scenario's rate planes, computed once in ops.py) ride the broadcast plane
specs like ``acc_up``, so drifted node time needs NO extra carry — the
deadline fields already resident in VMEM are simply minted from and
compared against these columns (per-cell owner clocks via the
compile-time P-loop ``state.clock_select``, the proposer discount
``guard_q4`` a closure constant like ``lease_q4``).

Layout: the acceptor (A) and proposer-bitmask axes ride on sublanes, the
cell axis N on the 128-wide lane axis. All state is int32, all updates are
`jnp.where` selects — pure VPU work, no MXU. ``backend="pallas_tpu"``
compiles for real TPUs (mind the sublane padding notes in docs/perf.md);
``backend="pallas"`` runs the same kernel in interpret mode anywhere.

The scan scalars (t0, total ticks) live in SMEM; protocol constants
(majority, lease length, round horizon, P, window) are compile-time
closure constants, mirroring how kernels/flash_attention bakes its block
geometry.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:  # TPU backend name moved across versions (same guard as flash_attention)
    from jax.experimental.pallas import tpu as pltpu

    _SMEM = pltpu.SMEM
except Exception:  # pragma: no cover
    pltpu = None
    _SMEM = None

from .netplane import NetPlaneState, delayed_tick_math, legs_select
from .ref import sync_tick_math
from .state import PACK_SHIFT, PackedLeaseState, clock_select

N_LEASE = len(PackedLeaseState._fields)
N_NET = len(NetPlaneState._fields)

#: index of own_id inside PackedLeaseState — the per-tick owner row
_OWN_ID = PackedLeaseState._fields.index("owner_id")
#: index of the packed owner lease — the quiescence check reads its expiry
_OWN_LEASE = PackedLeaseState._fields.index("owner_lease")

# BlockSpecs for the packed lease plane ([A, bn] x2 then [1, bn] x2)
_LEASE_ROWS = (None, None, 1, 1)  # None -> the plane keeps its A rows
# NetPlaneState: 6 [A, bn] slot planes then 6 [1, bn] round rows
_NET_ROWS = (None,) * 6 + (1,) * 6


def _scalar_spec(n: int):
    """Spec for the [n] int32 scan-scalar vector (SMEM on real TPUs)."""
    if _SMEM is not None:
        return pl.BlockSpec(memory_space=_SMEM)
    return pl.BlockSpec((n,), lambda i, w: (0,))


def _state_specs(rows, n_acceptors: int, block_n: int):
    """One resident-block spec per state plane: index map ignores the
    window axis, so the block stays in VMEM across all windows."""
    return [
        pl.BlockSpec(
            ((n_acceptors if r is None else r), block_n), lambda i, w: (0, i)
        )
        for r in rows
    ]


def _cell_plane_spec(tw: int, rows: int, block_n: int):
    """One streamed [W, tw, rows, block_n] scenario-plane slab per window
    (the leading W axis is squeezed away inside the kernel)."""
    return pl.BlockSpec((None, tw, rows, block_n), lambda i, w: (w, 0, 0, i))


def _bcast_plane_spec(tw: int, rows: int, cols: int):
    """A cell-independent plane (acc_up columns, link matrices): every cell
    block streams the same [tw, rows, cols] slab."""
    return pl.BlockSpec((None, tw, rows, cols), lambda i, w: (w, 0, 0, 0))


class LaunchPlan(NamedTuple):
    """The complete launch geometry of one window kernel: grid, BlockSpecs
    and the *logical* (full-array) shape behind every spec, in call order.

    The ``pallas_call`` entry points below consume a plan verbatim, and the
    static launch checker (``repro.analysis.staticcheck.launch``) audits the
    same object — bounds, write-race partition of the cell axis, VMEM
    residency — so there is no second hand-maintained description of the
    launch to drift out of sync.

    ``in_shapes``/``out_shapes`` align 1:1 with ``in_specs``/``out_specs``.
    The leading scalar-vector input rides in SMEM on real TPUs; its spec has
    no block shape, which the checker treats as exempt from tiling rules.
    """

    grid: tuple[int, int]
    in_specs: tuple
    out_specs: tuple
    in_shapes: tuple[tuple[int, ...], ...]
    out_shapes: tuple[tuple[int, ...], ...]
    block_n: int
    tw: int
    n_windows: int


def _window_geometry(n_cells: int, n_ticks: int, block_n: int, window: int):
    block_n = min(block_n, n_cells)
    assert n_cells % block_n == 0, \
        "pad the cell axis to a block multiple (ops.py)"
    tw = max(1, min(window, n_ticks))
    n_windows = -(-n_ticks // tw)
    return block_n, tw, n_windows


def _launch_plan(
    rows, n_acceptors: int, n_cells: int, n_proposers: int, n_ticks: int,
    block_n: int, window: int, bcast_rows: tuple[tuple[int, int], ...],
    n_cell_planes: int = 2,
) -> LaunchPlan:
    """Shared plan builder: ``rows`` describes the resident state planes
    (None -> A rows), ``n_cell_planes`` how many [T, N] cell-plane streams
    follow them (attempts/releases, plus the §6 extends stream), and
    ``bcast_rows`` the trailing cell-independent streams as (rows, cols)
    pairs."""
    A, N, T = n_acceptors, n_cells, n_ticks
    block_n, tw, n_windows = _window_geometry(N, T, block_n, window)
    grid = (N // block_n, n_windows)
    state_specs = _state_specs(rows, A, block_n)
    state_shapes = tuple((A if r is None else r, N) for r in rows)
    cell_spec = _cell_plane_spec(tw, 1, block_n)
    cell_shape = (n_windows, tw, 1, N)
    in_specs = (
        (_scalar_spec(2), *state_specs, *(cell_spec,) * n_cell_planes)
        + tuple(_bcast_plane_spec(tw, r, c) for r, c in bcast_rows)
    )
    in_shapes = (
        ((2,), *state_shapes, *(cell_shape,) * n_cell_planes)
        + tuple((n_windows, tw, r, c) for r, c in bcast_rows)
    )
    return LaunchPlan(
        grid=grid,
        in_specs=in_specs,
        out_specs=(*state_specs, cell_spec, cell_spec),
        in_shapes=in_shapes,
        out_shapes=(*state_shapes, cell_shape, cell_shape),
        block_n=block_n,
        tw=tw,
        n_windows=n_windows,
    )


def sync_launch_plan(
    n_acceptors: int, n_cells: int, n_proposers: int, n_ticks: int,
    *, block_n: int = 512, window: int = 16,
) -> LaunchPlan:
    """Launch geometry of ``lease_window_sync_pallas``: lease state +
    attempt/release cell planes + acc_up/pclk/aclk broadcast columns."""
    A, P = n_acceptors, n_proposers
    return _launch_plan(
        _LEASE_ROWS, A, n_cells, P, n_ticks, block_n, window,
        bcast_rows=((A, 1), (P, 1), (A, 1)),
    )


def delayed_launch_plan(
    n_acceptors: int, n_cells: int, n_proposers: int, n_ticks: int,
    *, block_n: int = 512, window: int = 16, corrupt: bool = False,
    restart: bool = False, extend: bool = False,
) -> LaunchPlan:
    """Launch geometry of ``lease_window_delayed_pallas``: lease + netplane
    state, the same streams as sync, plus the fused [P, A] link matrices.
    ``extend`` inserts the §6 extends stream as a THIRD [T, N] cell plane
    right after releases (the owner-extension proposer ids). ``corrupt``
    appends the two adversarial [A, 1] corruption columns (stale-ballot /
    equivocation masks) to the streamed planes; ``restart`` appends the
    four crash/restart columns (acceptor restart + deaf-window masks
    [A, 1], proposer restart + running restart counters [P, 1]) — the
    honest launch is geometry-identical to the pre-falsifier kernel."""
    A, P = n_acceptors, n_proposers
    bcast = ((A, 1), (P, 1), (A, 1), (P, A))
    if corrupt:
        bcast += ((A, 1), (A, 1))
    if restart:
        bcast += ((A, 1), (A, 1), (P, 1), (P, 1))
    return _launch_plan(
        _LEASE_ROWS + _NET_ROWS, A, n_cells, P, n_ticks, block_n, window,
        bcast_rows=bcast, n_cell_planes=3 if extend else 2,
    )


def _init_resident(w, in_refs, out_refs):
    """At the first window, seed the resident state blocks from the inputs
    (afterwards the out blocks ARE the carried state)."""

    @pl.when(w == 0)
    def _():
        for o, i in zip(out_refs, in_refs):
            o[...] = i[...]


def _window_bounds(sc_ref, tw: int):
    w = pl.program_id(1)
    base = w * tw
    n_ticks = jnp.minimum(tw, sc_ref[1] - base)
    return sc_ref[0] + base, n_ticks


def _sync_window_kernel(
    sc_ref,  # [2] int32 (t0, T) in SMEM
    *refs,
    majority: int, lease_q4: int, guard_q4: int, n_proposers: int, tw: int,
):
    ins, outs = refs[: N_LEASE + 5], refs[N_LEASE + 5:]
    att_ref, rel_ref, up_ref, pclk_ref, aclk_ref = ins[N_LEASE:]
    st_refs = outs[:N_LEASE]
    own_ref, cnt_ref = outs[N_LEASE], outs[N_LEASE + 1]
    _init_resident(pl.program_id(1), ins[:N_LEASE], st_refs)
    t_base, n_ticks = _window_bounds(sc_ref, tw)

    def body(tau, lease):
        lease, count = sync_tick_math(
            lease, t_base + tau,
            att_ref[tau], rel_ref[tau], up_ref[tau],
            pclk_ref[tau], aclk_ref[tau],
            majority=majority, lease_q4=lease_q4, n_proposers=n_proposers,
            guard_q4=guard_q4,
        )
        own_ref[tau] = lease[_OWN_ID]
        cnt_ref[tau] = count
        return lease

    lease = jax.lax.fori_loop(
        0, n_ticks, body, tuple(r[...] for r in st_refs)
    )
    for r, v in zip(st_refs, lease):
        r[...] = v


def _quiescent(
    st_refs, att_ref, rel_ref, ext_ref, pclk_ref, aclk_ref,
    stale_ref, equiv_ref, rst_refs, tw: int,
):
    """True iff this (cell block, window) pair provably cannot change the
    resident state: no message in flight, no open round, no scheduled
    attempt/release/extend (all-sentinel slabs — the zero tail padding of a
    partial last window reads as proposer 0 and correctly disqualifies it),
    no scheduled fault, and every lease — the owner row on the owner's
    clock, each acceptor's on its own — stays live through the window's
    LAST local-clock reading. Ticks inside such a window are pure owner
    samples: phase 1 expires nothing, phases 2-4 see only empty slots and
    sentinel rows."""
    rnd_ballot = st_refs[N_LEASE + 6]
    quiet = (
        jnp.all(att_ref[...] < 0)
        & jnp.all(rel_ref[...] < 0)
        & jnp.all(rnd_ballot[...] == 0)
    )
    if ext_ref is not None:
        quiet &= jnp.all(ext_ref[...] < 0)
    # the five in-flight slot planes (presp_pay is inert while presp == 0)
    for i in (0, 1, 3, 4, 5):
        quiet &= jnp.all(st_refs[N_LEASE + i][...] == 0)
    if stale_ref is not None:
        quiet &= jnp.all(stale_ref[...] == 0) & jnp.all(equiv_ref[...] == 0)
    if rst_refs is not None:
        arst_ref, _, prst_ref, _ = rst_refs
        quiet &= jnp.all(arst_ref[...] == 0) & jnp.all(prst_ref[...] == 0)
    # leases must outlive the window on their holder's LOCAL clock: clocks
    # only advance, so the slab's last reading is the window's worst case
    own_id = st_refs[_OWN_ID][...]
    ownp = st_refs[_OWN_LEASE][...]
    own_clk_end = clock_select(pclk_ref[tw - 1], own_id)
    quiet &= jnp.all(
        (ownp == 0) | (ownp >= ((own_clk_end + 1) << PACK_SHIFT))
    )
    acc_lease = st_refs[1][...]
    aclk_end = aclk_ref[tw - 1]
    quiet &= jnp.all(
        (acc_lease == 0) | (acc_lease >= ((aclk_end + 1) << PACK_SHIFT))
    )
    return quiet


def _delayed_window_kernel(
    sc_ref,
    *refs,
    majority: int, lease_q4: int, round_q4: int, guard_q4: int,
    n_proposers: int, tw: int, corrupt: bool = False, restart: bool = False,
    extend: bool = False, skip_stable: bool = True,
):
    n_state = N_LEASE + N_NET
    n_cell = 3 if extend else 2
    n_in = (
        n_state + n_cell + 4 + (2 if corrupt else 0) + (4 if restart else 0)
    )
    ins, outs = refs[:n_in], refs[n_in:]
    att_ref, rel_ref = ins[n_state:n_state + 2]
    ext_ref = ins[n_state + 2] if extend else None
    up_ref, pclk_ref, aclk_ref, link_ref = \
        ins[n_state + n_cell:n_state + n_cell + 4]
    extra = n_state + n_cell + 4
    stale_ref = equiv_ref = None
    if corrupt:
        stale_ref, equiv_ref = ins[extra:extra + 2]
        extra += 2
    rst_refs = ins[extra:extra + 4] if restart else None
    st_refs = outs[:n_state]
    own_ref, cnt_ref = outs[n_state], outs[n_state + 1]
    _init_resident(pl.program_id(1), ins[:n_state], st_refs)
    t_base, n_ticks = _window_bounds(sc_ref, tw)

    def body(tau, carry):
        lease, net = carry[:N_LEASE], carry[N_LEASE:]
        adv = (
            {"stale": stale_ref[tau], "equiv": equiv_ref[tau]}
            if corrupt else {}
        )
        if extend:
            adv["extend"] = ext_ref[tau]
        if restart:
            arst_ref, deaf_ref, prst_ref, rc_ref = rst_refs
            adv.update(
                acc_restart=arst_ref[tau], acc_deaf=deaf_ref[tau],
                prop_restart=prst_ref[tau], prop_rc=rc_ref[tau],
            )
        lease, net, count = delayed_tick_math(
            lease, net, t_base + tau,
            att_ref[tau], rel_ref[tau], up_ref[tau],
            pclk_ref[tau], aclk_ref[tau], link_ref[tau],
            majority=majority, lease_q4=lease_q4, round_q4=round_q4,
            n_proposers=n_proposers, guard_q4=guard_q4, legs=legs_select,
            **adv,
        )
        own_ref[tau] = lease[_OWN_ID]
        cnt_ref[tau] = count
        return (*lease, *net)

    def run_window():
        carry = jax.lax.fori_loop(
            0, n_ticks, body, tuple(r[...] for r in st_refs)
        )
        for r, v in zip(st_refs, carry):
            r[...] = v

    if not skip_stable:
        run_window()
        return

    skip = _quiescent(
        st_refs, att_ref, rel_ref, ext_ref, pclk_ref, aclk_ref,
        stale_ref, equiv_ref, rst_refs, tw,
    )

    @pl.when(skip)
    def _():
        # quiescent fast path: the window is pure owner sampling — the
        # resident state is untouched and every tick reads the same row
        own_row = st_refs[_OWN_ID][...]
        cnt_row = (st_refs[_OWN_LEASE][...] > 0).astype(jnp.int32)
        own_ref[...] = jnp.broadcast_to(own_row[None], own_ref.shape)
        cnt_ref[...] = jnp.broadcast_to(cnt_row[None], cnt_ref.shape)

    @pl.when(jnp.logical_not(skip))
    def _():
        run_window()


def _windowed(plane, n_windows: int, tw: int, rows: int, n: int):
    """[T, rows(, n)] plane -> [W, tw, rows, n] slabs (zero tail padding —
    the in-kernel dynamic trip count never reads the pad)."""
    t = plane.shape[0]
    plane = plane.reshape(t, rows, n)
    pad = n_windows * tw - t
    if pad:
        plane = jnp.pad(plane, ((0, pad), (0, 0), (0, 0)))
    return plane.reshape(n_windows, tw, rows, n)


def lease_window_sync_pallas(
    packed: PackedLeaseState,
    t0,          # scalar int32 first tick
    attempts,    # [T, N] int32
    releases,    # [T, N] int32
    acc_up,      # [T, A] bool/int32
    pclk,        # [T, P] int32 proposer local clocks per tick
    aclk,        # [T, A] int32 acceptor local clocks per tick
    *,
    majority: int,
    lease_q4: int,
    n_proposers: int,
    guard_q4: int = None,
    block_n: int = 512,
    window: int = 16,
    interpret: bool = True,  # False on real TPUs
) -> tuple[PackedLeaseState, jax.Array, jax.Array]:
    """Replay T synchronous ticks in ONE kernel launch; N must be a
    multiple of ``block_n`` (ops.py pads). Returns
    (packed_state', owners [T, N], counts [T, N])."""
    A, N = packed.promised.shape
    P = n_proposers
    T = attempts.shape[0]
    plan = sync_launch_plan(A, N, P, T, block_n=block_n, window=window)
    tw, n_windows = plan.tw, plan.n_windows

    kernel = functools.partial(
        _sync_window_kernel,
        majority=majority, lease_q4=lease_q4,
        guard_q4=lease_q4 if guard_q4 is None else guard_q4,
        n_proposers=P, tw=tw,
    )
    row_plane = lambda p: _windowed(
        jnp.asarray(p, jnp.int32), n_windows, tw, 1, N
    )
    col_plane = lambda p, rows: _windowed(
        jnp.asarray(p, jnp.int32), n_windows, tw, rows, 1
    )
    sds = jax.ShapeDtypeStruct
    outs = pl.pallas_call(
        kernel,
        grid=plan.grid,
        in_specs=list(plan.in_specs),
        out_specs=list(plan.out_specs),
        out_shape=[sds(s, jnp.int32) for s in plan.out_shapes],
        interpret=interpret,
    )(
        jnp.stack([jnp.asarray(t0, jnp.int32), jnp.int32(T)]),
        *packed,
        row_plane(attempts), row_plane(releases),
        col_plane(jnp.asarray(acc_up).astype(jnp.int32), A),
        col_plane(pclk, P), col_plane(aclk, A),
    )
    new_packed = PackedLeaseState(*outs[:N_LEASE])
    owners = outs[N_LEASE].reshape(n_windows * tw, N)[:T]
    counts = outs[N_LEASE + 1].reshape(n_windows * tw, N)[:T]
    return new_packed, owners, counts


def lease_window_delayed_pallas(
    packed: PackedLeaseState,
    net: NetPlaneState,
    t0,          # scalar int32 first tick
    attempts,    # [T, N] int32
    releases,    # [T, N] int32
    acc_up,      # [T, A] bool/int32
    pclk,        # [T, P] int32 proposer local clocks per tick
    aclk,        # [T, A] int32 acceptor local clocks per tick
    link,        # [T, P, A] int32 fused link matrices (netplane.pack_link)
    *,
    majority: int,
    lease_q4: int,
    round_q4: int,
    n_proposers: int,
    guard_q4: int = None,
    block_n: int = 512,
    window: int = 16,
    interpret: bool = True,  # False on real TPUs
    extends=None,  # [T, N] §6 owner-extension proposer ids (None = honest)
    skip_stable: bool = True,  # compile the quiescence fast path
    stale=None,  # [T, A] adversarial stale-ballot mask (None = honest)
    equiv=None,  # [T, A] adversarial equivocation mask (None = honest)
    acc_restart=None,   # [T, A] acceptor crash+restart mask (None = honest)
    acc_deaf=None,      # [T, A] post-restart deaf-window mask
    prop_restart=None,  # [T, P] proposer crash+restart mask
    prop_rc=None,       # [T, P] running per-proposer restart counters
) -> tuple[PackedLeaseState, NetPlaneState, jax.Array, jax.Array]:
    """Replay T delayed-model ticks in ONE kernel launch (state AND the
    in-flight netplane stay VMEM-resident across windows). Returns
    (packed_state', net', owners [T, N], counts [T, N]). Passing
    ``extends`` streams the §6 owner-extension ids as a third [T, N]
    cell plane and compiles the extend gate. Passing either corruption
    mask streams both as extra [A, 1] broadcast columns and compiles the
    corrupted tick body; passing any restart input streams all four
    crash/restart columns likewise; the honest launch is unchanged.
    ``skip_stable`` compiles the per-(block, window) quiescence check:
    windows whose cell block provably cannot change (no traffic, no
    events, no expiry in reach) collapse to owner-row broadcasts instead
    of running the tick loop — bit-identical results, a fraction of the
    VPU work on steady-state phases (``False`` is the A/B bench control)."""
    A, N = packed.promised.shape
    P = n_proposers
    T = attempts.shape[0]
    extend = extends is not None
    corrupt = stale is not None or equiv is not None
    restart = any(
        x is not None for x in (acc_restart, acc_deaf, prop_restart, prop_rc)
    )
    plan = delayed_launch_plan(
        A, N, P, T, block_n=block_n, window=window, corrupt=corrupt,
        restart=restart, extend=extend,
    )
    tw, n_windows = plan.tw, plan.n_windows

    kernel = functools.partial(
        _delayed_window_kernel,
        majority=majority, lease_q4=lease_q4, round_q4=round_q4,
        guard_q4=lease_q4 if guard_q4 is None else guard_q4,
        n_proposers=P, tw=tw, corrupt=corrupt, restart=restart,
        extend=extend, skip_stable=skip_stable,
    )
    row_plane = lambda p: _windowed(
        jnp.asarray(p, jnp.int32), n_windows, tw, 1, N
    )
    col_plane = lambda p, rows: _windowed(
        jnp.asarray(p, jnp.int32), n_windows, tw, rows, 1
    )
    sds = jax.ShapeDtypeStruct
    outs = pl.pallas_call(
        kernel,
        grid=plan.grid,
        in_specs=list(plan.in_specs),
        out_specs=list(plan.out_specs),
        out_shape=[sds(s, jnp.int32) for s in plan.out_shapes],
        interpret=interpret,
    )(
        jnp.stack([jnp.asarray(t0, jnp.int32), jnp.int32(T)]),
        *packed,
        *net,
        row_plane(attempts), row_plane(releases),
        *((row_plane(extends),) if extend else ()),
        col_plane(jnp.asarray(acc_up).astype(jnp.int32), A),
        col_plane(pclk, P), col_plane(aclk, A),
        _windowed(jnp.asarray(link, jnp.int32), n_windows, tw, P, A),
        *(
            (
                col_plane(jnp.zeros((T, A), jnp.int32) if stale is None
                          else stale, A),
                col_plane(jnp.zeros((T, A), jnp.int32) if equiv is None
                          else equiv, A),
            )
            if corrupt else ()
        ),
        *(
            (
                col_plane(jnp.zeros((T, A), jnp.int32) if acc_restart is None
                          else acc_restart, A),
                col_plane(jnp.zeros((T, A), jnp.int32) if acc_deaf is None
                          else acc_deaf, A),
                col_plane(jnp.zeros((T, P), jnp.int32) if prop_restart is None
                          else prop_restart, P),
                col_plane(jnp.zeros((T, P), jnp.int32) if prop_rc is None
                          else prop_rc, P),
            )
            if restart else ()
        ),
    )
    n_state = N_LEASE + N_NET
    new_packed = PackedLeaseState(*outs[:N_LEASE])
    new_net = NetPlaneState(*outs[N_LEASE:n_state])
    owners = outs[n_state].reshape(n_windows * tw, N)[:T]
    counts = outs[n_state + 1].reshape(n_windows * tw, N)[:T]
    return new_packed, new_net, owners, counts
