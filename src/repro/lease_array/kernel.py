"""Pallas TPU kernels for the lease-plane tick: fused expiry + release +
prepare/quorum-count + propose/state-update in a single VMEM pass.

Two kernels share the layout: the synchronous zero-delay tick
(`lease_tick_pallas`, PR 1) and the delayed in-flight-message tick
(`lease_tick_delayed_pallas`), whose body is `netplane.delayed_tick_math`
— the same function the jnp oracle runs, so kernel and oracle are
bit-identical by construction.

Grid: (n_cell_blocks,) — each program owns a ``block_n``-wide column slice of
every state array. The acceptor (A) and proposer (P) axes ride on sublanes,
so quorum counting (`sum over A`) and owner lookups (`any over P`) are
sublane reductions; the cell axis N is the 128-lane axis. All state is
int32, all updates are `jnp.where` selects — pure VPU work, no MXU.

The tick scalar lives in SMEM (it is traced — `lax.scan` drives it); the
protocol constants (majority, lease length, round horizon, P) are
compile-time closure constants, mirroring how kernels/flash_attention bakes
its block geometry.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:  # TPU backend name moved across versions (same guard as flash_attention)
    from jax.experimental.pallas import tpu as pltpu

    _SMEM = pltpu.SMEM
except Exception:  # pragma: no cover
    pltpu = None
    _SMEM = None

from .netplane import NetPlaneState, delayed_tick_math
from .ref import flat_links
from .state import NO_PROPOSER, QUARTERS, LeaseArrayState

N_LEASE = len(LeaseArrayState._fields)
N_NET = len(NetPlaneState._fields)


def _lease_tick_kernel(
    t_ref,            # (1, 1) int32 in SMEM — current tick
    promised_ref,     # (A, bn)
    acc_ballot_ref,   # (A, bn)
    acc_prop_ref,     # (A, bn)
    acc_expiry_ref,   # (A, bn)
    own_mask_ref,     # (P, bn)
    own_expiry_ref,   # (P, bn)
    own_ballot_ref,   # (P, bn)
    attempt_ref,      # (1, bn)
    release_ref,      # (1, bn)
    up_ref,           # (A, bn) int32 0/1
    # outputs
    o_promised_ref, o_acc_ballot_ref, o_acc_prop_ref, o_acc_expiry_ref,
    o_own_mask_ref, o_own_expiry_ref, o_own_ballot_ref, o_count_ref,
    *, majority: int, lease_q4: int, n_proposers: int,
):
    P = n_proposers
    t = t_ref[0, 0]
    t4 = QUARTERS * t
    shape_p = own_mask_ref.shape
    p_ids = jax.lax.broadcasted_iota(jnp.int32, shape_p, 0)   # [P, bn]
    up = up_ref[...] > 0                                      # [A, bn]

    # -- 1. expiry
    acc_live = (acc_ballot_ref[...] > 0) & (acc_expiry_ref[...] > t4)
    acc_ballot = jnp.where(acc_live, acc_ballot_ref[...], 0)
    acc_prop = jnp.where(acc_live, acc_prop_ref[...], NO_PROPOSER)
    acc_expiry = jnp.where(acc_live, acc_expiry_ref[...], 0)
    own_live = (own_mask_ref[...] > 0) & (own_expiry_ref[...] > t4)
    own_mask = own_live.astype(jnp.int32)
    own_expiry = jnp.where(own_live, own_expiry_ref[...], 0)
    own_ballot = jnp.where(own_live, own_ballot_ref[...], 0)

    # -- 2. release
    rel = release_ref[...]                                    # [1, bn]
    rel_owner = (p_ids == rel) & (own_mask > 0)               # [P, bn]
    rel_ballot = jnp.sum(jnp.where(rel_owner, own_ballot, 0), axis=0, keepdims=True)
    own_mask = jnp.where(rel_owner, 0, own_mask)
    discard = up & (rel_ballot > 0) & (acc_ballot == rel_ballot)
    acc_ballot = jnp.where(discard, 0, acc_ballot)
    acc_prop = jnp.where(discard, NO_PROPOSER, acc_prop)
    acc_expiry = jnp.where(discard, 0, acc_expiry)

    # -- 3. prepare + quorum count
    att = attempt_ref[...]                                    # [1, bn]
    has_att = att >= 0
    ballot = jnp.where(has_att, (t + 1) * P + att, 0)
    att_owns = jnp.sum(
        jnp.where((p_ids == att) & (own_mask > 0), 1, 0), axis=0, keepdims=True
    ) > 0
    grant = up & has_att & (ballot >= promised_ref[...])
    is_open = grant & ((acc_ballot == 0) | ((acc_prop == att) & att_owns))
    opens = jnp.sum(is_open.astype(jnp.int32), axis=0, keepdims=True)
    won = opens >= majority
    promised = jnp.where(grant, ballot, promised_ref[...])

    # -- 4. propose + proposer update
    accept = grant & won
    acc_ballot = jnp.where(accept, ballot, acc_ballot)
    acc_prop = jnp.where(accept, att, acc_prop)
    acc_expiry = jnp.where(accept, t4 + lease_q4, acc_expiry)
    new_owner = (p_ids == att) & won
    own_mask = jnp.where(new_owner, 1, own_mask)
    own_expiry = jnp.where(new_owner, t4 + lease_q4, own_expiry)
    own_ballot = jnp.where(new_owner, ballot, own_ballot)

    o_promised_ref[...] = promised
    o_acc_ballot_ref[...] = acc_ballot
    o_acc_prop_ref[...] = acc_prop
    o_acc_expiry_ref[...] = acc_expiry
    o_own_mask_ref[...] = own_mask
    o_own_expiry_ref[...] = own_expiry
    o_own_ballot_ref[...] = own_ballot
    o_count_ref[...] = jnp.sum(own_mask, axis=0, keepdims=True)


def lease_tick_pallas(
    state: LeaseArrayState,
    t,         # scalar int32
    attempt,   # [N] int32
    release,   # [N] int32
    acc_up,    # [A] bool/int32
    *,
    majority: int,
    lease_q4: int,
    block_n: int = 512,
    interpret: bool = True,  # False on real TPUs
) -> tuple[LeaseArrayState, jax.Array]:
    """One fused tick over all N cells; N must be a multiple of ``block_n``
    (ops.py pads). Returns (new_state, owner_count[N])."""
    A, N = state.highest_promised.shape
    P = state.owner_mask.shape[0]
    block_n = min(block_n, N)
    assert N % block_n == 0, "pad the cell axis to a block multiple (ops.py)"
    grid = (N // block_n,)

    kernel = functools.partial(
        _lease_tick_kernel, majority=majority, lease_q4=lease_q4, n_proposers=P,
    )
    arow = lambda r: jnp.asarray(r, jnp.int32).reshape(1, N)
    up2d = jnp.broadcast_to(
        jnp.asarray(acc_up).astype(jnp.int32)[:, None], (A, N)
    )
    t2d = jnp.asarray(t, jnp.int32).reshape(1, 1)

    spec_a = pl.BlockSpec((A, block_n), lambda i: (0, i))
    spec_p = pl.BlockSpec((P, block_n), lambda i: (0, i))
    spec_r = pl.BlockSpec((1, block_n), lambda i: (0, i))
    spec_t = (
        pl.BlockSpec(memory_space=_SMEM)
        if _SMEM is not None
        else pl.BlockSpec((1, 1), lambda i: (0, 0))
    )
    sds = jax.ShapeDtypeStruct
    outs = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            spec_t,
            spec_a, spec_a, spec_a, spec_a,
            spec_p, spec_p, spec_p,
            spec_r, spec_r, spec_a,
        ],
        out_specs=[
            spec_a, spec_a, spec_a, spec_a,
            spec_p, spec_p, spec_p,
            spec_r,
        ],
        out_shape=[
            sds((A, N), jnp.int32), sds((A, N), jnp.int32),
            sds((A, N), jnp.int32), sds((A, N), jnp.int32),
            sds((P, N), jnp.int32), sds((P, N), jnp.int32),
            sds((P, N), jnp.int32), sds((1, N), jnp.int32),
        ],
        interpret=interpret,
    )(
        t2d,
        state.highest_promised, state.accepted_ballot,
        state.accepted_proposer, state.lease_expiry,
        state.owner_mask, state.owner_expiry, state.owner_ballot,
        arow(attempt), arow(release), up2d,
    )
    new_state = LeaseArrayState(*outs[:7])
    return new_state, outs[7].reshape(N)


def _delayed_tick_kernel(t_ref, *refs, majority, lease_q4, round_q4):
    """Fused delayed tick: loads every block, runs the shared netplane math,
    stores every block. Inputs: lease + net planes + 5 per-tick blocks
    (attempt/release rows, up columns, [P*A] link delay/drop matrices);
    outputs: lease + net planes + count."""
    n_in = N_LEASE + N_NET + 5
    ins, outs = refs[:n_in], refs[n_in:]
    lease = tuple(r[...] for r in ins[:N_LEASE])
    net = tuple(r[...] for r in ins[N_LEASE:N_LEASE + N_NET])
    attempt, release, up, delay, drop = (r[...] for r in ins[N_LEASE + N_NET:])
    new_lease, new_net, count = delayed_tick_math(
        lease, net, t_ref[0, 0], attempt, release, up, delay, drop,
        majority=majority, lease_q4=lease_q4, round_q4=round_q4,
    )
    for r, v in zip(outs, (*new_lease, *new_net, count)):
        r[...] = v


def lease_tick_delayed_pallas(
    state: LeaseArrayState,
    net: NetPlaneState,
    t,         # scalar int32
    attempt,   # [N] int32
    release,   # [N] int32
    acc_up,    # [A] bool/int32
    delay,     # [P, A] (or legacy [A]) int32 link delays (ticks)
    drop,      # [P, A] (or legacy [A]) bool/int32 link drop masks
    *,
    majority: int,
    lease_q4: int,
    round_q4: int,
    block_n: int = 512,
    interpret: bool = True,  # False on real TPUs
) -> tuple[LeaseArrayState, NetPlaneState, jax.Array]:
    """One fused delayed tick over all N cells; N must be a multiple of
    ``block_n`` (ops.py pads). Returns (new_state, new_net, owner_count[N])."""
    A, N = state.highest_promised.shape
    P = state.owner_mask.shape[0]
    block_n = min(block_n, N)
    assert N % block_n == 0, "pad the cell axis to a block multiple (ops.py)"
    grid = (N // block_n,)

    kernel = functools.partial(
        _delayed_tick_kernel,
        majority=majority, lease_q4=lease_q4, round_q4=round_q4,
    )
    arow = lambda r: jnp.asarray(r, jnp.int32).reshape(1, N)
    acol = lambda c: jnp.broadcast_to(
        jnp.asarray(c).astype(jnp.int32)[:, None], (A, N)
    )
    t2d = jnp.asarray(t, jnp.int32).reshape(1, 1)

    spec_a = pl.BlockSpec((A, block_n), lambda i: (0, i))
    spec_p = pl.BlockSpec((P, block_n), lambda i: (0, i))
    spec_r = pl.BlockSpec((1, block_n), lambda i: (0, i))
    spec_pa = pl.BlockSpec((P * A, block_n), lambda i: (0, i))
    spec_t = (
        pl.BlockSpec(memory_space=_SMEM)
        if _SMEM is not None
        else pl.BlockSpec((1, 1), lambda i: (0, 0))
    )
    lease_specs = [spec_a] * 4 + [spec_p] * 3
    net_specs = [spec_a] * 11 + [spec_r] * 4 + [spec_a] * 2
    sds = jax.ShapeDtypeStruct
    lease_shapes = [sds((A, N), jnp.int32)] * 4 + [sds((P, N), jnp.int32)] * 3
    net_shapes = (
        [sds((A, N), jnp.int32)] * 11
        + [sds((1, N), jnp.int32)] * 4
        + [sds((A, N), jnp.int32)] * 2
    )
    outs = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=(
            [spec_t] + lease_specs + net_specs
            + [spec_r] * 2 + [spec_a] + [spec_pa] * 2
        ),
        out_specs=lease_specs + net_specs + [spec_r],
        out_shape=lease_shapes + net_shapes + [sds((1, N), jnp.int32)],
        interpret=interpret,
    )(
        t2d,
        *state,
        *net,
        arow(attempt), arow(release), acol(acc_up),
        flat_links(delay, P, A, N), flat_links(drop, P, A, N),
    )
    new_state = LeaseArrayState(*outs[:N_LEASE])
    new_net = NetPlaneState(*outs[N_LEASE:N_LEASE + N_NET])
    return new_state, new_net, outs[-1].reshape(N)
