"""Scenario plane: one declarative pytree for every fault dimension.

The paper's failure model (§1) is open-ended — "messages may be delayed,
reordered, lost, and nodes may crash and restart" — so the engine API must
not grow one positional argument per failure dimension. A ``Scenario`` is
a *registry-driven* bundle of named planes, each a dense array with a
leading tick axis:

  attempts  [T, N]     proposer id attempting each cell (-1 = none)
  releases  [T, N]     proposer id releasing each cell (-1 = none)
  acc_up    [T, A]     acceptor reachability (1 = reachable)
  delay     [T, P, A]  per-(proposer, acceptor) link delay in whole ticks
  drop      [T, P, A]  per-(proposer, acceptor) link loss mask
  prop_rate [T, P]     proposer local-clock step (local quarter-ticks/tick)
  acc_rate  [T, A]     acceptor local-clock step (local quarter-ticks/tick)

``delay``/``drop`` are *asymmetric link matrices*: every message leg sent
at tick ``t`` on the link between proposer ``p`` and acceptor ``a`` —
request or response, either direction — takes ``delay[t, p, a]`` ticks
and is lost iff ``drop[t, p, a]``. The symmetric per-acceptor ``[T, A]``
schedules of earlier revisions are the P-broadcast special case and are
accepted everywhere a plane is (see each spec's ``alts``).

``prop_rate``/``acc_rate`` are the §4 clock-drift planes — the first
planes added through ``register_plane`` after the registry shipped (the
worked example in docs/scenario_api.md): each node's local clock advances
by its rate-plane entry in *local quarter-ticks per global tick*
(``state.DEFAULT_RATE`` = 4 = a drift-free rate-1.0 clock; 3 and 5 bound
ε = 0.25). Node-side deadlines — acceptor lease timers, the proposer's
guarded own timer, round-abandon horizons — are minted and compared in
each node's accumulated local time; message deliver-ats stay global (the
network has no clock). Rates are validated ≥ 1 (``min_value``): a rate-0
clock would freeze every timer it owns.

Adding a failure dimension (restart planes, clock-rate planes, …) is now
"register a plane": ``register_plane`` extends the schema, ``Scenario``
defaults/validates/slices it, and the scan machinery carries it without
any signature change (see docs/scenario_api.md).

Both ``Scenario`` and its per-tick slice ``TickInputs`` are registered
JAX pytrees: they flow through ``jax.jit``/``jax.lax.scan`` unchanged and
batch with ``jax.vmap`` over a ``Scenario.stack`` of stacked scenarios.
"""
from __future__ import annotations

from typing import Iterable, NamedTuple, Optional

import jax
import numpy as np

from .state import DEFAULT_RATE, NO_PROPOSER

__all__ = [
    "PlaneSpec",
    "PLANES",
    "CORRUPTION_PLANES",
    "RESTART_PLANES",
    "EXTEND_PLANES",
    "register_plane",
    "plane_table_md",
    "plane_digest",
    "Scenario",
    "TickInputs",
    "make_tick",
    "validate_proposer_ids",
]


class PlaneSpec(NamedTuple):
    """Schema of one scenario plane (shapes are per tick, sans the T axis)."""

    name: str
    dims: tuple[str, ...]  # per-tick dims, of {"N", "A", "P"}
    default: int           # fill value when the plane is omitted
    doc: str = ""
    #: alternate per-tick shapes accepted from callers; missing axes are
    #: broadcast (e.g. delay's ("A",): a symmetric [T, A] plane is expanded
    #: to [T, P, A] by repeating it for every proposer)
    alts: tuple[tuple[str, ...], ...] = ()
    #: validated as proposer-id rows (-1 sentinel .. n_proposers - 1)
    proposer_ids: bool = False
    #: entries below this raise at build/validate time (None = unchecked)
    min_value: Optional[int] = None


#: the plane registry — insertion order is the canonical plane order
PLANES: dict[str, PlaneSpec] = {}


def register_plane(
    name: str,
    dims: Iterable[str],
    default: int,
    doc: str = "",
    *,
    alts: Iterable[Iterable[str]] = (),
    proposer_ids: bool = False,
    min_value: Optional[int] = None,
) -> PlaneSpec:
    """Extend the scenario schema with a new named plane."""
    spec = PlaneSpec(
        name, tuple(dims), int(default), doc,
        tuple(tuple(a) for a in alts), proposer_ids,
        None if min_value is None else int(min_value),
    )
    PLANES[name] = spec
    return spec


register_plane(
    "attempts", ("N",), NO_PROPOSER,
    "proposer id attempting each cell this tick (-1 = none)",
    proposer_ids=True,
)
register_plane(
    "releases", ("N",), NO_PROPOSER,
    "proposer id releasing each cell this tick (-1 = none)",
    proposer_ids=True,
)
register_plane(
    "acc_up", ("A",), 1,
    "acceptor reachability this tick (1 = reachable)",
)
register_plane(
    "delay", ("P", "A"), 0,
    "per-(proposer, acceptor) link delay (whole ticks) for legs sent this tick",
    alts=(("A",),),
    min_value=0,
)
register_plane(
    "drop", ("P", "A"), 0,
    "per-(proposer, acceptor) link loss mask for legs sent this tick",
    alts=(("A",),),
)
register_plane(
    "prop_rate", ("P",), DEFAULT_RATE,
    "proposer local-clock step this tick (local quarter-ticks; 4 = rate 1.0)",
    min_value=1,
)
register_plane(
    "acc_rate", ("A",), DEFAULT_RATE,
    "acceptor local-clock step this tick (local quarter-ticks; 4 = rate 1.0)",
    min_value=1,
)
register_plane(
    "acc_stale", ("A",), 0,
    "adversarial (falsifier negative control): acceptor honors "
    "below-promise ballots this tick",
    min_value=0,
)
register_plane(
    "acc_equiv", ("A",), 0,
    "adversarial (falsifier negative control): acceptor reports its live "
    "accepted lease as open this tick",
    min_value=0,
)
register_plane(
    "acc_restart", ("A",), 0,
    "diskless acceptor crash+restart this tick: state blanks, then deaf "
    "for a maximal lease span on its local clock",
    min_value=0,
)
register_plane(
    "prop_restart", ("P",), 0,
    "proposer crash+restart this tick: abandons its round, drops its owner "
    "belief, bumps its ballot restart counter",
    min_value=0,
)
register_plane(
    "extends", ("N",), NO_PROPOSER,
    "proposer id extending its own live lease on each cell this tick "
    "(§6 in-flight re-propose; -1 = none, non-owners are a no-op)",
    proposer_ids=True,
)

#: the adversarial corruption planes — Byzantine acceptor behaviors the
#: honest protocol must never exhibit; the falsification engine enables
#: them as negative controls proving the §4 alarm can fire at all
CORRUPTION_PLANES = ("acc_stale", "acc_equiv")

#: the crash/restart planes (paper §1 failure model): diskless acceptor
#: restarts + proposer restart counters. All-zero planes are stripped from
#: dispatch like the corruption planes, keeping the honest engine
#: bit-identical with zero extra uploads
RESTART_PLANES = ("acc_restart", "prop_restart")

#: the §6 owner-extension plane: an owner re-proposes in-flight to renew
#: its lease before expiry. All-default (-1 everywhere) is stripped from
#: dispatch host-side like the corruption/restart planes, so the honest
#: jaxpr stays byte-identical
EXTEND_PLANES = ("extends",)


def plane_table_md(planes: Optional[dict[str, PlaneSpec]] = None) -> str:
    """Render the registry as the markdown plane table embedded in
    docs/scenario_api.md (between the ``plane-table`` markers).

    The registry is the single source of truth: the table in the docs is
    generated by this function, and the convention lint
    (``repro.analysis.staticcheck.conventions``) fails CI whenever the two
    drift — including when a plane is registered with an empty ``doc``.
    """
    specs = (PLANES if planes is None else planes).values()
    rows = [
        "| plane | per-tick shape | default | meaning |",
        "|-------|----------------|---------|---------|",
    ]
    for spec in specs:
        shape = "`[" + ", ".join(spec.dims) + "]`"
        if spec.alts:
            shape += " (or " + " / ".join(
                "`[" + ", ".join(a) + "]`" for a in spec.alts
            ) + ")"
        rows.append(
            f"| `{spec.name}` | {shape} | `{spec.default}` | {spec.doc} |"
        )
    return "\n".join(rows) + "\n"


def plane_digest(planes: dict) -> str:
    """Content hash of one scenario's planes (12 hex chars): a stable,
    seed-independent identifier for "which exact scenario was this".
    ``engine.sweep`` prints it for §4-violating batch members so a
    10k-batch offender can be re-identified standalone, and the
    falsification engine stamps it into survivor lineage tags. Plane
    *names* participate, so two scenarios differing only in which plane
    holds a value hash differently."""
    import hashlib

    h = hashlib.sha256()
    for name in sorted(planes):
        arr = np.ascontiguousarray(np.asarray(planes[name], np.int32))
        h.update(name.encode())
        h.update(np.asarray(arr.shape, np.int64).tobytes())
        h.update(arr.tobytes())
    return h.hexdigest()[:12]


def validate_proposer_ids(arr, n_proposers: int) -> None:
    """Reject ids outside [-1, n_proposers): an out-of-range id would lease
    cells to a proposer the plane has no row for — a ghost owner nobody
    believes in. Shared by ``LeaseArrayEngine.step`` and every Scenario
    build (so ``run_trace`` traces are checked too)."""
    a = np.asarray(arr)
    if a.size == 0:
        return
    hi, lo = int(a.max()), int(a.min())
    if hi >= n_proposers:
        raise ValueError(
            f"proposer id {hi} out of range "
            f"(plane has {n_proposers} proposers)"
        )
    if lo < NO_PROPOSER:
        raise ValueError(
            f"proposer id {lo} out of range ({NO_PROPOSER} means no proposer)"
        )


def _dim_sizes(n_cells: int, n_acceptors: int, n_proposers: int) -> dict[str, int]:
    return {"N": int(n_cells), "A": int(n_acceptors), "P": int(n_proposers)}


def _check_min_value(spec: PlaneSpec, arr: np.ndarray, what: str) -> None:
    """Registry-driven range floor: delays must be >= 0 (legs cannot land
    in the past), clock rates >= 1 (a rate-0 clock freezes its timers)."""
    if spec.min_value is None or arr.size == 0:
        return
    lo = int(arr.min())
    if lo < spec.min_value:
        kind = (
            "negative entries" if spec.min_value == 0
            else f"entries below {spec.min_value}"
        )
        raise ValueError(
            f"{what} plane {spec.name!r} has {kind} (min {lo}); "
            f"valid entries are >= {spec.min_value}"
        )


def _coerce_plane(
    spec: PlaneSpec,
    value,
    sizes: dict[str, int],
    lead: tuple[int, ...],
    what: str,
) -> np.ndarray:
    """Default / validate / broadcast one plane to ``lead + canonical``."""
    shape = lead + tuple(sizes[d] for d in spec.dims)
    if value is None:
        return np.full(shape, spec.default, np.int32)
    arr = np.asarray(value)
    if arr.dtype == bool:
        arr = arr.astype(np.int32)
    arr = arr.astype(np.int32, copy=False)
    forms = (spec.dims,) + spec.alts
    for dims in forms:
        want = lead + tuple(sizes[d] for d in dims)
        if arr.shape == want:
            if dims != spec.dims:  # expand the alternate form, e.g. [T,A]
                missing = [d for d in spec.dims if d not in dims]
                for d in missing:
                    ax = len(lead) + spec.dims.index(d)
                    arr = np.expand_dims(arr, ax)
                arr = np.broadcast_to(arr, shape).copy()
            if spec.proposer_ids:
                validate_proposer_ids(arr, sizes["P"])
            _check_min_value(spec, arr, what)
            return arr
    accepted = " or ".join(
        str(lead + tuple(sizes[d] for d in dims)) for dims in forms
    )
    raise ValueError(
        f"{what} plane {spec.name!r} has shape {arr.shape}; expected "
        f"{accepted} (T, N, A, P = ticks, cells, acceptors, proposers)"
    )


def _raise_unknown(bad):
    raise ValueError(
        f"unknown scenario plane(s) {sorted(bad)}; registered planes: "
        f"{sorted(PLANES)} (extend with register_plane)"
    )


class _PlaneBundle:
    """Shared dict-of-planes pytree behavior for Scenario / TickInputs."""

    __slots__ = ("planes",)
    _lead_ndim = 0  # leading axes before the per-tick dims

    def __init__(self, planes: dict) -> None:
        if bad := set(planes) - set(PLANES):
            _raise_unknown(bad)
        self.planes = {k: planes[k] for k in PLANES if k in planes}

    def __getattr__(self, name: str):
        if name == "planes":  # unset slot (e.g. during unpickling probes)
            raise AttributeError(name)
        try:
            return self.planes[name]
        except KeyError:
            raise AttributeError(name) from None

    def _dim(self, plane: str, axis: int) -> int:
        return int(self.planes[plane].shape[self._lead_ndim + axis])

    @property
    def n_cells(self) -> int:
        return self._dim("attempts", 0)

    @property
    def n_acceptors(self) -> int:
        return self._dim("acc_up", 0)

    @property
    def n_proposers(self) -> int:
        return self._dim("delay", 0)

    @property
    def delayed(self) -> bool:
        """True iff the delay or drop plane is nonzero anywhere (needs the
        in-flight netplane model). Host-side only — not traceable."""
        return bool(
            np.asarray(self.planes["delay"]).any()
            or np.asarray(self.planes["drop"]).any()
        )

    @property
    def drifted(self) -> bool:
        """True iff any clock-rate plane departs from the drift-free
        DEFAULT_RATE step. Host-side only — not traceable."""
        return bool(
            (np.asarray(self.planes["prop_rate"]) != DEFAULT_RATE).any()
            or (np.asarray(self.planes["acc_rate"]) != DEFAULT_RATE).any()
        )

    @property
    def corrupted(self) -> bool:
        """True iff an adversarial corruption plane is nonzero anywhere
        (needs the delayed model with the corruption inputs threaded).
        Host-side only — not traceable."""
        return bool(any(
            np.asarray(self.planes[k]).any() for k in CORRUPTION_PLANES
        ))

    @property
    def restarted(self) -> bool:
        """True iff a crash/restart plane is nonzero anywhere (needs the
        delayed model with the restart inputs threaded and switches ballots
        to the restart-counter carve). Host-side only — not traceable."""
        return bool(any(
            np.asarray(self.planes[k]).any() for k in RESTART_PLANES
        ))

    @property
    def extended(self) -> bool:
        """True iff the §6 extends plane schedules any owner extension
        (needs the delayed model with the extend input threaded).
        Host-side only — not traceable."""
        return bool(any(
            (np.asarray(self.planes[k]) != PLANES[k].default).any()
            for k in EXTEND_PLANES
        ))

    def validate_for(
        self, *, n_cells: int, n_acceptors: int, n_proposers: int
    ) -> None:
        """Check every plane against an engine's geometry (shape + ids +
        delay sign). ``build``/``make_tick`` output always passes;
        hand-rolled pytrees are checked here before they reach the step or
        the scanner."""
        sizes = _dim_sizes(n_cells, n_acceptors, n_proposers)
        lead: tuple[int, ...] = ()
        if self._lead_ndim:
            lead = (int(self.planes["attempts"].shape[0]),)
        what = type(self).__name__
        for name, spec in PLANES.items():
            if name not in self.planes:
                raise ValueError(f"{what} is missing plane {name!r}")
            arr = np.asarray(self.planes[name])
            want = lead + tuple(sizes[d] for d in spec.dims)
            if arr.shape != want:
                raise ValueError(
                    f"{what} plane {name!r} has shape {arr.shape}; "
                    f"engine geometry wants {want}"
                )
            if spec.proposer_ids:
                validate_proposer_ids(arr, sizes["P"])
            _check_min_value(spec, arr, what)

    def __repr__(self) -> str:
        inner = ", ".join(
            f"{k}{tuple(v.shape)}" for k, v in self.planes.items()
        )
        return f"{type(self).__name__}({inner})"


def _register(cls):
    jax.tree_util.register_pytree_node(
        cls,
        lambda s: (tuple(s.planes.values()), tuple(s.planes.keys())),
        lambda names, leaves: cls(dict(zip(names, leaves))),
    )
    return cls


@_register
class TickInputs(_PlaneBundle):
    """One tick's worth of every scenario plane (no leading T axis)."""


def make_tick(
    *,
    n_cells: int,
    n_acceptors: int,
    n_proposers: int,
    **planes,
) -> TickInputs:
    """Build a validated single-tick input bundle (engine.step's currency).

    Omitted planes get their registered defaults; ``delay``/``drop`` accept
    the symmetric per-acceptor ``[A]`` form and broadcast it over P.
    """
    if bad := set(planes) - set(PLANES):
        _raise_unknown(bad)
    sizes = _dim_sizes(n_cells, n_acceptors, n_proposers)
    return TickInputs({
        name: _coerce_plane(spec, planes.get(name), sizes, (), "tick")
        for name, spec in PLANES.items()
    })


@_register
class Scenario(_PlaneBundle):
    """A [T]-tick fault scenario: every registered plane, leading T axis.

    Build with :meth:`Scenario.build` (defaulting + shape/dtype/id
    validation + broadcasting), slice with ``scenario[t]`` (→ TickInputs)
    or ``scenario[a:b]`` (→ sub-Scenario), join with :meth:`concat`, and
    batch with :meth:`stack` for ``jax.vmap``.
    """

    _lead_ndim = 1

    @classmethod
    def build(
        cls,
        n_ticks: Optional[int] = None,
        *,
        n_cells: int,
        n_acceptors: int,
        n_proposers: int,
        **planes,
    ) -> "Scenario":
        """Default, validate and broadcast every registered plane.

        ``n_ticks`` may be omitted when at least one plane is given (it is
        inferred from the first one). Unknown plane names are rejected with
        the list of registered planes.
        """
        if bad := {k for k in planes if k not in PLANES}:
            _raise_unknown(bad)
        if n_ticks is None:
            for v in planes.values():
                if v is not None:
                    n_ticks = int(np.asarray(v).shape[0])
                    break
            else:
                raise ValueError(
                    "n_ticks is required when no plane is provided"
                )
        sizes = _dim_sizes(n_cells, n_acceptors, n_proposers)
        lead = (int(n_ticks),)
        return cls({
            name: _coerce_plane(spec, planes.get(name), sizes, lead, "scenario")
            for name, spec in PLANES.items()
        })

    # ------------------------------------------------------------- queries
    @property
    def n_ticks(self) -> int:
        return int(self.planes["attempts"].shape[0])

    # -------------------------------------------------------- composition
    def __getitem__(self, key):
        if isinstance(key, slice):
            return Scenario({k: v[key] for k, v in self.planes.items()})
        return TickInputs({k: v[key] for k, v in self.planes.items()})

    def concat(self, *others: "Scenario") -> "Scenario":
        """Concatenate scenarios along the tick axis (same geometry)."""
        for o in others:
            for name in PLANES:
                a, b = self.planes[name], o.planes[name]
                if a.shape[1:] != b.shape[1:]:
                    raise ValueError(
                        f"cannot concat: plane {name!r} per-tick shapes "
                        f"differ ({a.shape[1:]} vs {b.shape[1:]})"
                    )
        return Scenario({
            k: np.concatenate(
                [np.asarray(self.planes[k])]
                + [np.asarray(o.planes[k]) for o in others], axis=0,
            )
            for k in self.planes
        })

    @classmethod
    def stack(cls, scenarios: Iterable["Scenario"]):
        """Stack same-shape scenarios on a new leading batch axis — the
        ``jax.vmap`` batching form (``engine.sweep``'s currency). Returns a
        Scenario-shaped pytree whose leaves are [B, T, ...] (its per-tick
        properties no longer apply); feed it to a vmapped scanner with
        ``in_axes=0``."""
        scenarios = list(scenarios)
        if not scenarios:
            raise ValueError("Scenario.stack needs at least one scenario")
        first = scenarios[0]
        for i, sc in enumerate(scenarios[1:], 1):
            for name in PLANES:
                a = np.asarray(first.planes[name])
                b = np.asarray(sc.planes[name])
                if a.shape != b.shape:
                    raise ValueError(
                        f"cannot stack: scenario 0 plane {name!r} has shape "
                        f"{a.shape} but scenario {i} has {b.shape} "
                        f"(same tick count and geometry required)"
                    )
        return jax.tree.map(lambda *xs: np.stack(xs), *scenarios)
