"""LeaseArrayDirectory: shard-ownership on the vectorized lease plane.

The event-driven ``cluster.shards.ShardLeaseManager`` tops out at a few
hundred resources (every lease is Python objects trading one message at a
time); this directory drives *thousands* of shard cells through one batched
array step per tick. Same operational surface: workers with a target shard
count, stall (straggler: leases silently expire), drain (graceful §7
release), elastic retargeting, coverage/owner queries.

Policy per tick (host-side numpy; the protocol itself runs in the array):
  - active owners whose lease is inside the renew margin attempt an extend,
  - draining or over-target workers release their extra shards,
  - unowned cells are attempted by workers with a deficit, spread
    round-robin with a per-worker stride to reduce collisions.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from .engine import LeaseArrayEngine
from .scenario import make_tick
from .state import NO_PROPOSER


@dataclass
class ArrayWorker:
    slot: int  # proposer index inside the array plane
    target: int
    stalled: bool = False
    draining: bool = False


class LeaseArrayDirectory:
    def __init__(
        self,
        n_shards: int,
        *,
        n_acceptors: int = 5,
        lease_ticks: int = 6,
        renew_margin: int | None = None,
        max_workers: int = 32,
        backend: str = "jnp",
    ) -> None:
        self.n_shards = n_shards
        self.max_workers = max_workers
        self.renew_margin = (
            renew_margin if renew_margin is not None else max(lease_ticks // 2, 1)
        )
        self.engine = LeaseArrayEngine(
            n_shards,
            n_acceptors=n_acceptors,
            n_proposers=max_workers,
            lease_ticks=lease_ticks,
            backend=backend,
        )
        self.workers: dict[int, ArrayWorker] = {}
        self._owners = np.full(n_shards, NO_PROPOSER, np.int32)

    # ------------------------------------------------------------------ API
    def add_worker(self, worker_id: int, target: int) -> ArrayWorker:
        if worker_id in self.workers:
            raise ValueError(f"worker {worker_id} already registered")
        if len(self.workers) >= self.max_workers:
            raise ValueError(f"plane sized for {self.max_workers} workers")
        slot = len(self.workers)
        w = ArrayWorker(slot=slot, target=target)
        self.workers[worker_id] = w
        return w

    def set_target(self, worker_id: int, target: int) -> None:
        self.workers[worker_id].target = target

    def stall(self, worker_id: int) -> None:
        """Straggler: stops renewing; its leases expire after the timespan."""
        self.workers[worker_id].stalled = True

    def unstall(self, worker_id: int) -> None:
        self.workers[worker_id].stalled = False

    def drain(self, worker_id: int) -> None:
        """Graceful scale-down: release everything over the next tick (§7)."""
        w = self.workers[worker_id]
        w.draining = True
        w.target = 0

    # ------------------------------------------------------------ the tick
    def tick(self, n: int = 1) -> np.ndarray:
        for _ in range(n):
            self._owners = self._tick_once()
        return self._owners

    def _tick_once(self) -> np.ndarray:
        attempt = np.full(self.n_shards, NO_PROPOSER, np.int32)
        release = np.full(self.n_shards, NO_PROPOSER, np.int32)
        owners = self._owners
        ticks_left = self.engine.ticks_left()
        by_slot = {w.slot: w for w in self.workers.values()}
        counts = np.bincount(
            owners[owners >= 0], minlength=self.engine.n_proposers
        )

        deficits: dict[int, int] = {}
        for w in self.workers.values():
            if w.stalled:
                continue  # a true straggler says nothing — leases just lapse
            owned = int(counts[w.slot])
            if w.draining or owned > w.target:
                mine = np.flatnonzero(owners == w.slot)
                n_shed = owned if w.draining else owned - w.target
                release[mine[len(mine) - n_shed:]] = w.slot  # shed highest k
            if owned < w.target:
                deficits[w.slot] = w.target - owned

        # owners inside the renew margin extend (stalled/draining don't)
        for cell in np.flatnonzero(
            (owners >= 0) & (ticks_left <= self.renew_margin)
        ):
            w = by_slot.get(int(owners[cell]))
            if w is not None and not w.stalled and not w.draining:
                if release[cell] != w.slot:  # not shedding this one
                    attempt[cell] = w.slot

        # spread unowned cells over deficit workers round-robin (vectorized:
        # the per-cell Python loop would rival the batched step itself)
        if deficits:
            slots = np.array(sorted(deficits), np.int32)
            wants = np.array([deficits[int(s)] for s in slots])
            rank = np.concatenate([np.arange(w) for w in wants])
            seq = np.repeat(slots, wants)[np.argsort(rank, kind="stable")]
            free = np.flatnonzero((owners < 0) & (attempt < 0))
            k = min(len(seq), len(free))
            attempt[free[:k]] = seq[:k]
        tick = make_tick(
            n_cells=self.engine.n_cells, n_acceptors=self.engine.n_acceptors,
            n_proposers=self.engine.n_proposers,
            attempts=attempt, releases=release,
        )
        return self.engine.step(tick).astype(np.int32)

    # -------------------------------------------------------------- queries
    def coverage(self) -> float:
        return float((self._owners >= 0).mean()) if self.n_shards else 0.0

    def owner_map(self) -> dict[int, int]:
        slot_to_id = {w.slot: wid for wid, w in self.workers.items()}
        return {
            int(k): slot_to_id[int(s)]
            for k, s in enumerate(self._owners)
            if s >= 0 and int(s) in slot_to_id
        }

    def owned_count(self, worker_id: int) -> int:
        return int((self._owners == self.workers[worker_id].slot).sum())
