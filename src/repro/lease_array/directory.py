"""LeaseArrayDirectory: shard-ownership on the vectorized lease plane.

The event-driven ``cluster.shards.ShardLeaseManager`` tops out at a few
hundred resources (every lease is Python objects trading one message at a
time); this directory drives *thousands* of shard cells through one batched
array step per tick. Same operational surface: workers with a target shard
count, stall (straggler: leases silently expire), drain (graceful §7
release), elastic retargeting, coverage/owner queries.

Policy per tick (host-side numpy; the protocol itself runs in the array):
  - active owners whose lease is inside the renew margin extend in-flight
    (§6, the ``extends`` plane: a fresh round gated on the live belief),
  - draining or over-target workers release their extra shards,
  - unowned cells are attempted by workers with a deficit, spread
    round-robin with a per-worker stride to reduce collisions.

The renew margin must clear the worst-case round trip: an extend is a
full fresh round (§6) — prepares out, promises back, proposes out,
accepts back — so its accepts land up to ``4·max_delay + 1`` ticks after
issue. A margin below that (the old ``lease_ticks // 2`` default ignored
link delay entirely; an earlier fix used the half-trip ``2·max_delay+1``)
lets every lease lapse mid-renewal — the renewal-collapse geometry the
regression test pins (owned_frac 0.05 instead of ≥ 0.95).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from .engine import LeaseArrayEngine
from .scenario import make_tick
from .state import NO_PROPOSER


@dataclass
class ArrayWorker:
    slot: int  # proposer index inside the array plane
    target: int
    stalled: bool = False
    draining: bool = False


class LeaseArrayDirectory:
    def __init__(
        self,
        n_shards: int,
        *,
        n_acceptors: int = 5,
        lease_ticks: int = 6,
        renew_margin: int | None = None,
        max_workers: int = 32,
        backend: str = "jnp",
        max_delay_ticks: int = 0,
    ) -> None:
        self.n_shards = n_shards
        self.max_workers = max_workers
        self.max_delay_ticks = int(max_delay_ticks)
        # an extend is a FULL fresh round (§6): prepares + promises +
        # proposes + accepts, up to 4·max_delay + 1 ticks end to end.
        # Renewals scheduled any later than that before expiry can NEVER
        # land in time (the half-trip 2·max_delay+1 looks plausible but
        # only covers one leg pair — it still collapses at delay ≥ 2).
        rtt = 4 * self.max_delay_ticks + 1
        if rtt >= lease_ticks:
            raise ValueError(
                f"a {lease_ticks}-tick lease cannot be renewed over links "
                f"with up to {max_delay_ticks}-tick legs (extend round "
                f"{rtt} >= lease); lengthen the lease or shorten the links"
            )
        if renew_margin is None:
            renew_margin = max(lease_ticks // 2, rtt, 1)
        elif renew_margin < rtt:
            raise ValueError(
                f"renew_margin={renew_margin} is below the worst-case "
                f"extend round ({rtt} ticks at max_delay_ticks="
                f"{max_delay_ticks}): every renewal would start too late "
                f"to land before expiry"
            )
        self.renew_margin = renew_margin
        self.engine = LeaseArrayEngine(
            n_shards,
            n_acceptors=n_acceptors,
            n_proposers=max_workers,
            lease_ticks=lease_ticks,
            backend=backend,
            # the abandon deadline must outlive a full prepare+propose
            # round over the slowest links, or no round ever completes
            round_ticks=4 * self.max_delay_ticks + 1,
        )
        self.workers: dict[int, ArrayWorker] = {}
        self._owners = np.full(n_shards, NO_PROPOSER, np.int32)
        # per-cell pacing: an attempt/extend OVERWRITES any open round
        # (netplane phase 3), so re-issuing every tick livelocks at
        # delay ≥ 1 — today's collapse. Hold off a full prepare+propose
        # round trip (4·delay + 1 ticks) before re-driving a cell.
        self._round_trip = rtt
        self._cooldown = np.zeros(n_shards, np.int32)

    # ------------------------------------------------------------------ API
    def add_worker(self, worker_id: int, target: int) -> ArrayWorker:
        if worker_id in self.workers:
            raise ValueError(f"worker {worker_id} already registered")
        if len(self.workers) >= self.max_workers:
            raise ValueError(f"plane sized for {self.max_workers} workers")
        slot = len(self.workers)
        w = ArrayWorker(slot=slot, target=target)
        self.workers[worker_id] = w
        return w

    def set_target(self, worker_id: int, target: int) -> None:
        self.workers[worker_id].target = target

    def stall(self, worker_id: int) -> None:
        """Straggler: stops renewing; its leases expire after the timespan."""
        self.workers[worker_id].stalled = True

    def unstall(self, worker_id: int) -> None:
        self.workers[worker_id].stalled = False

    def drain(self, worker_id: int) -> None:
        """Graceful scale-down: release everything over the next tick (§7)."""
        w = self.workers[worker_id]
        w.draining = True
        w.target = 0

    # ------------------------------------------------------------ the tick
    def tick(self, n: int = 1) -> np.ndarray:
        for _ in range(n):
            self._owners = self._tick_once()
        return self._owners

    def _tick_once(self) -> np.ndarray:
        attempt = np.full(self.n_shards, NO_PROPOSER, np.int32)
        release = np.full(self.n_shards, NO_PROPOSER, np.int32)
        extend = np.full(self.n_shards, NO_PROPOSER, np.int32)
        owners = self._owners
        self._cooldown = np.maximum(self._cooldown - 1, 0)
        ticks_left = self.engine.ticks_left()
        by_slot = {w.slot: w for w in self.workers.values()}
        counts = np.bincount(
            owners[owners >= 0], minlength=self.engine.n_proposers
        )

        deficits: dict[int, int] = {}
        for w in self.workers.values():
            if w.stalled:
                continue  # a true straggler says nothing — leases just lapse
            owned = int(counts[w.slot])
            if w.draining or owned > w.target:
                mine = np.flatnonzero(owners == w.slot)
                n_shed = owned if w.draining else owned - w.target
                release[mine[len(mine) - n_shed:]] = w.slot  # shed highest k
            if owned < w.target:
                deficits[w.slot] = w.target - owned

        # owners inside the renew margin extend in-flight (§6: the extends
        # plane re-proposes under the live belief; stalled/draining don't)
        for cell in np.flatnonzero(
            (owners >= 0)
            & (ticks_left <= self.renew_margin)
            & (self._cooldown == 0)
        ):
            w = by_slot.get(int(owners[cell]))
            if w is not None and not w.stalled and not w.draining:
                if release[cell] != w.slot:  # not shedding this one
                    extend[cell] = w.slot
                    self._cooldown[cell] = self._round_trip

        # spread unowned cells over deficit workers round-robin (vectorized:
        # the per-cell Python loop would rival the batched step itself)
        if deficits:
            slots = np.array(sorted(deficits), np.int32)
            wants = np.array([deficits[int(s)] for s in slots])
            rank = np.concatenate([np.arange(w) for w in wants])
            seq = np.repeat(slots, wants)[np.argsort(rank, kind="stable")]
            free = np.flatnonzero(
                (owners < 0) & (attempt < 0) & (self._cooldown == 0)
            )
            k = min(len(seq), len(free))
            attempt[free[:k]] = seq[:k]
            self._cooldown[free[:k]] = self._round_trip
        planes = dict(attempts=attempt, releases=release, extends=extend)
        if self.max_delay_ticks:
            planes["delay"] = np.full(
                self.engine.n_acceptors, self.max_delay_ticks, np.int32
            )
        tick = make_tick(
            n_cells=self.engine.n_cells, n_acceptors=self.engine.n_acceptors,
            n_proposers=self.engine.n_proposers, **planes,
        )
        return self.engine.step(tick).astype(np.int32)

    # -------------------------------------------------------------- queries
    def coverage(self) -> float:
        return float((self._owners >= 0).mean()) if self.n_shards else 0.0

    def owner_map(self) -> dict[int, int]:
        slot_to_id = {w.slot: wid for wid, w in self.workers.items()}
        return {
            int(k): slot_to_id[int(s)]
            for k, s in enumerate(self._owners)
            if s >= 0 and int(s) in slot_to_id
        }

    def owned_count(self, worker_id: int) -> int:
        return int((self._owners == self.workers[worker_id].slot).sum())
