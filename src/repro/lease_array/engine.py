"""LeaseArrayEngine: a stateful driver over the vectorized lease plane.

Two modes:
  - ``step(...)``    — advance one tick (host-driven; the directory uses it)
  - ``run_trace``    — ``jax.lax.scan`` over a whole [T, ...] trace in one
                       jitted call (the bulk/benchmark path); independent
                       planes batch further with ``jax.vmap`` (see
                       ``scan_fn``'s pytree-in/pytree-out signature and
                       tests/test_lease_array_engine.py::test_vmap_planes).

Two network models: the synchronous zero-delay tick (every round resolves
in one tick) and the delayed in-flight message plane (``netplane.py``).
Passing ``delay=``/``drop=`` to ``step``/``run_trace`` switches the engine
onto the delayed model; it stays there (messages may be in flight) with
zero-delay defaults from then on.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from .netplane import NetPlaneState, init_netplane
from .ops import lease_plane_step, lease_plane_step_delayed
from .ref import owner_row
from .state import NO_PROPOSER, QUARTERS, LeaseArrayState, init_state, lease_quarters


@functools.lru_cache(maxsize=None)
def _trace_scanner(majority: int, lease_q4: int, backend: str):
    """Jitted (state, t0, attempts, releases, acc_up) -> (state, owners, counts)."""

    def scan_fn(state, t0, attempts, releases, acc_up):
        def body(carry, xs):
            st, t = carry
            att, rel, up = xs
            st, count = lease_plane_step(
                st, t, att, rel, up,
                majority=majority, lease_q4=lease_q4, backend=backend,
            )
            return (st, t + 1), (owner_row(st), count)

        (state, _), (owners, counts) = jax.lax.scan(
            body, (state, t0), (attempts, releases, acc_up)
        )
        return state, owners, counts

    return jax.jit(scan_fn)


@functools.lru_cache(maxsize=None)
def _delayed_trace_scanner(
    majority: int, lease_q4: int, round_q4: int, backend: str
):
    """Jitted delayed-model scan: carries (lease state, netplane state)."""

    def scan_fn(state, net, t0, attempts, releases, acc_up, delays, drops):
        def body(carry, xs):
            st, nt, t = carry
            att, rel, up, dl, dr = xs
            st, nt, count = lease_plane_step_delayed(
                st, nt, t, att, rel, up, dl, dr,
                majority=majority, lease_q4=lease_q4, round_q4=round_q4,
                backend=backend,
            )
            return (st, nt, t + 1), (owner_row(st), count)

        (state, net, _), (owners, counts) = jax.lax.scan(
            body, (state, net, t0), (attempts, releases, acc_up, delays, drops)
        )
        return state, net, owners, counts

    return jax.jit(scan_fn)


class LeaseArrayEngine:
    def __init__(
        self,
        n_cells: int,
        *,
        n_acceptors: int = 5,
        n_proposers: int = 8,
        lease_ticks: int = 3,
        round_ticks: int = 1,
        backend: str = "jnp",
    ) -> None:
        if n_acceptors < 1 or n_proposers < 1:
            raise ValueError("need at least one acceptor and one proposer")
        self.n_cells = n_cells
        self.n_acceptors = n_acceptors
        self.n_proposers = n_proposers
        self.majority = n_acceptors // 2 + 1
        self.lease_ticks = lease_ticks
        self.lease_q4 = lease_quarters(lease_ticks)
        self.round_ticks = round_ticks
        self.round_q4 = QUARTERS * int(round_ticks)
        self.backend = backend
        self.state = init_state(n_cells, n_acceptors, n_proposers)
        self.net: NetPlaneState = init_netplane(n_cells, n_acceptors)
        self.t = 0
        self.last_owner_count = jnp.zeros(n_cells, jnp.int32)
        # flips True on the first delayed step; once messages may be in
        # flight, every later tick must run the delayed model too
        self._netplane_active = False

    # ------------------------------------------------------------ one tick
    def step(
        self, attempt=None, release=None, acc_up=None, delay=None, drop=None
    ) -> np.ndarray:
        """Advance one tick; returns the per-cell owner row (id or -1).

        ``delay``/``drop`` are per-acceptor [A] schedules for messages sent
        this tick (delay in whole ticks); passing either switches the
        engine onto the delayed in-flight model permanently.

        Slot-isolation precondition (netplane.py): a new attempt on a cell
        overwrites that cell's in-flight request slots, so attempts on the
        SAME cell must be spaced more than ``4 * max_delay`` ticks apart
        while older messages may still be in flight (``random_trace``
        enforces this; hand-driven schedules must too).
        """
        attempt = self._row(attempt)
        release = self._row(release)
        acc_up = (
            jnp.ones(self.n_acceptors, jnp.int32) if acc_up is None
            else jnp.asarray(acc_up)
        )
        if delay is not None or drop is not None:
            self._netplane_active = True
        if not self._netplane_active:
            self.state, self.last_owner_count = lease_plane_step(
                self.state, self.t, attempt, release, acc_up,
                majority=self.majority, lease_q4=self.lease_q4,
                backend=self.backend,
            )
        else:
            delay = self._schedule(delay, (self.n_acceptors,))
            drop = self._schedule(drop, (self.n_acceptors,))
            self.state, self.net, self.last_owner_count = lease_plane_step_delayed(
                self.state, self.net, self.t, attempt, release, acc_up,
                delay, drop,
                majority=self.majority, lease_q4=self.lease_q4,
                round_q4=self.round_q4, backend=self.backend,
            )
        self.t += 1
        return np.asarray(owner_row(self.state))

    # ------------------------------------------------------------ bulk path
    def run_trace(self, attempts, releases=None, acc_up=None, delay=None, drop=None):
        """Scan a [T, N] trace in one jitted call.

        ``delay``/``drop`` are optional [T, A] schedules (per-tick,
        per-acceptor); providing either runs the delayed in-flight model.
        Returns (owners [T, N], owner_counts [T, N]) as numpy; the engine's
        state/tick advance past the trace.
        """
        attempts = jnp.asarray(attempts, jnp.int32)
        T = attempts.shape[0]
        releases = (
            jnp.full((T, self.n_cells), NO_PROPOSER, jnp.int32)
            if releases is None else jnp.asarray(releases, jnp.int32)
        )
        acc_up = (
            jnp.ones((T, self.n_acceptors), jnp.int32)
            if acc_up is None else jnp.asarray(acc_up).astype(jnp.int32)
        )
        if delay is not None or drop is not None:
            self._netplane_active = True
        if not self._netplane_active:
            scanner = _trace_scanner(self.majority, self.lease_q4, self.backend)
            self.state, owners, counts = scanner(
                self.state, jnp.int32(self.t), attempts, releases, acc_up
            )
        else:
            delay = self._schedule(delay, (T, self.n_acceptors))
            drop = self._schedule(drop, (T, self.n_acceptors))
            scanner = _delayed_trace_scanner(
                self.majority, self.lease_q4, self.round_q4, self.backend
            )
            self.state, self.net, owners, counts = scanner(
                self.state, self.net, jnp.int32(self.t),
                attempts, releases, acc_up, delay, drop,
            )
        self.t += int(T)
        if T > 0:
            self.last_owner_count = counts[-1]
        return np.asarray(owners), np.asarray(counts)

    # ------------------------------------------------------------- queries
    def owners(self) -> np.ndarray:
        return np.asarray(owner_row(self.state))

    def ticks_left(self) -> np.ndarray:
        """Per cell: whole ticks of ownership remaining (0 if unowned)."""
        expiry = np.asarray(
            jnp.max(
                jnp.where(self.state.owner_mask > 0, self.state.owner_expiry, 0),
                axis=0,
            )
        )
        return np.maximum(expiry - QUARTERS * self.t, 0) // QUARTERS

    @staticmethod
    def _schedule(v, shape) -> jnp.ndarray:
        """Zero-default int32 coercion for delay/drop schedules."""
        if v is None:
            return jnp.zeros(shape, jnp.int32)
        return jnp.asarray(v).astype(jnp.int32)

    def _row(self, row) -> jnp.ndarray:
        if row is None:
            return jnp.full(self.n_cells, NO_PROPOSER, jnp.int32)
        arr = np.asarray(row, np.int32)
        if arr.size and int(arr.max()) >= self.n_proposers:
            # an out-of-range id would lease cells to a proposer the plane
            # has no row for — a ghost owner nobody believes in
            raise ValueError(
                f"proposer id {int(arr.max())} out of range "
                f"(plane has {self.n_proposers} proposers)"
            )
        if arr.size and int(arr.min()) < NO_PROPOSER:
            raise ValueError(
                f"proposer id {int(arr.min())} out of range "
                f"({NO_PROPOSER} means no proposer)"
            )
        return jnp.asarray(arr)
