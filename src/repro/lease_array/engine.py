"""LeaseArrayEngine: a stateful driver over the vectorized lease plane.

Three modes:
  - ``step(...)``    — advance one tick (host-driven; the directory uses it)
  - ``run_trace``    — a whole [T]-tick ``Scenario`` in ONE dispatch (the
                       bulk/benchmark path): the fused window scan
                       (``ops.lease_window_scan``) runs the packed tick
                       math under ``lax.scan`` (jnp) or inside the
                       time-resident Pallas window kernel (pallas backends)
  - ``sweep``        — a stacked BATCH of scenarios in one dispatch
                       (``jax.vmap`` inside, ``shard_map`` across devices
                       when more than one is visible), each replayed from
                       the engine's current state with donated plane
                       buffers; per-scenario §4 verification built in.

Inputs are declarative **Scenario planes** (``scenario.py``): one pytree
carries every fault dimension — attempts, releases, acceptor reachability,
asymmetric per-(proposer, acceptor) link delay/drop matrices, and per-node
clock-rate planes — so new fault planes register into the schema instead
of growing new arguments. The legacy per-plane kwargs still work as thin
shims that build the pytree.

Clock drift (§4): the engine carries each node's accumulated local clock
(``prop_clk``/``acc_clk``, local quarter-ticks) across dispatches, so a
drifted trace split over many ``run_trace``/``step`` calls replays
bit-identically to one call. ``drift_eps`` is the ε the proposers' guard
discount assumes (``guard_q4 = ⌊lease_q4·(1-ε)/(1+ε)⌋``); rate planes
beyond that bound can — by design — trip the §4 owner-count alarm.

Two network models share the machinery: the synchronous zero-delay tick
(every round resolves in one tick) and the delayed in-flight message plane
(``netplane.py``). A scenario (or ``step`` call) carrying nonzero delay or
drop planes switches the engine onto the delayed model; it stays there
(messages may be in flight) with zero-delay defaults from then on.

The packed int32 layout bounds the clock: ballots must fit in
``state.PACK_MASK`` — ``run_trace``/``step``/``sweep`` raise once a trace
would cross ``state.max_pack_tick`` (≈ 4k ticks at P = 8; see
docs/perf.md).
"""
from __future__ import annotations

import functools
import warnings
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .netplane import NetPlaneState, init_netplane
from .ops import _margin_scan_impl, _window_scan_impl, lease_plane_tick
from .ref import owner_row
from .scenario import (
    CORRUPTION_PLANES,
    EXTEND_PLANES,
    PLANES,
    RESTART_PLANES,
    Scenario,
    TickInputs,
    make_tick,
    plane_digest,
)
from .state import (
    DEFAULT_RATE,
    NO_PROPOSER,
    QUARTERS,
    check_pack_budget,
    guarded_lease_q4,
    init_state,
    lease_quarters,
    rate1_clock,
)


#: flips True after the first analyzer failure so a broken static checker
#: warns once instead of blocking (or spamming) every dispatch
_STATIC_CHECK_FAILED = False

_DEPRECATED_STEP_KWARGS = (
    "per-plane LeaseArrayEngine.step arguments (attempt=, release=, "
    "acc_up=, delay=, drop=) are deprecated; build a TickInputs with "
    "make_tick(...) and pass it as the single argument"
)
_DEPRECATED_TRACE_PLANES = (
    "LeaseArrayEngine.run_trace with raw plane arrays is deprecated; "
    "pass a Scenario (Scenario.build(...) or Trace.scenario())"
)


@functools.lru_cache(maxsize=512)
def _static_pack_findings(
    t_end: int, n_proposers: int, n_acceptors: int, lease_q4: int,
    round_q4: int, guard_q4: Optional[int], max_delay: int, max_rate: int,
    clk_slack: int, max_restarts: int = 0,
) -> tuple[str, ...]:
    """Interval-analysis twin of ``state.check_pack_budget``: walk the
    traced delayed tick core (the conservative superset of the sync one)
    and bound EVERY int32 intermediate for replays up to ``t_end``. The
    hand check budgets only ballots and lease deadlines — this one also
    sees round horizons, clock sums and any future field the core grows.
    Cached because the same protocol config is re-proved per dispatch."""
    from ..analysis.staticcheck.intervals import (
        TickConfig,
        analyze_tick_config,
    )

    cfg = TickConfig(
        t_end=t_end, n_proposers=n_proposers, n_acceptors=n_acceptors,
        lease_q4=lease_q4, round_q4=round_q4, guard_q4=guard_q4,
        max_delay=max_delay, max_rate=max_rate, clk_slack=clk_slack,
        max_restarts=max_restarts,
    )
    return tuple(str(f) for f in analyze_tick_config(cfg))


@functools.lru_cache(maxsize=None)
def _scenario_scanner(
    majority: int, lease_q4: int, round_q4: int, backend: str, sync: bool,
    guard_q4: int = None,
):
    """Jitted (state, net, t0, clk0, planes) -> (state, net, owners, counts).

    The pre-PR 4 per-tick scanner: ``lax.scan`` whose body is ONE
    ``lease_plane_tick`` — every plane crosses the scan boundary every
    tick. Kept as the dispatch-overhead baseline (benchmarks) and the
    cross-check that the fused window scan (``ops.lease_window_scan``,
    what ``run_trace`` uses) changes nothing but speed; both run the same
    packed tick math, so they agree bit-for-bit. The local-clock columns
    ``clk0 = (prop [P], acc [A])`` ride the scan carry here (the fused
    path precomputes them as prefix-sum planes instead) — bit-identical
    accumulation either way, since everything is int32.
    """
    if guard_q4 is None:
        guard_q4 = lease_q4

    def scan_fn(state, net, t0, clk0, planes):
        if clk0 is None:  # the rate-1 reading at t0, like ops' default
            clk0 = (
                rate1_clock(t0, state.n_proposers),
                rate1_clock(t0, state.n_acceptors),
            )

        def body(carry, xs):
            st, nt, t, pc, ac = carry
            st, nt, count = lease_plane_tick(
                st, nt, t, TickInputs(xs),
                majority=majority, lease_q4=lease_q4, round_q4=round_q4,
                guard_q4=guard_q4, clk0=(pc, ac),
                backend=backend, sync=sync,
            )
            # a rate plane missing from a hand-rolled dict means the
            # drift-free step, like ops._local_clock_planes' contract
            carry = (
                st, nt, t + 1,
                pc + xs.get("prop_rate", DEFAULT_RATE),
                ac + xs.get("acc_rate", DEFAULT_RATE),
            )
            return carry, (owner_row(st), count)

        (state, net, _, _, _), (owners, counts) = jax.lax.scan(
            body, (state, net, t0, clk0[0], clk0[1]), planes
        )
        return state, net, owners, counts

    jitted = jax.jit(scan_fn)

    def strip_and_scan(state, net, t0, clk0, planes):
        # all-default corruption/restart/extends planes are the honest
        # path: drop them host-side (same contract as
        # ops.lease_window_scan) so the sync step never sees them and the
        # honest trace stays fault-free
        for k in RESTART_PLANES:
            v = planes.get(k)
            if (
                v is not None and not isinstance(v, jax.core.Tracer)
                and np.asarray(v).any()
            ):
                raise ValueError(
                    "the per-tick scanner cannot accumulate restart "
                    "history across ticks; replay restart scenarios "
                    "through run_trace/lease_window_scan instead"
                )
        planes = {
            k: v for k, v in planes.items()
            if not (
                k in CORRUPTION_PLANES + RESTART_PLANES + EXTEND_PLANES
                and not isinstance(v, jax.core.Tracer)
                and (np.asarray(v) == PLANES[k].default).all()
            )
        }
        return jitted(state, net, t0, clk0, planes)

    return strip_and_scan


class SweepResult(NamedTuple):
    """Per-scenario results of one :meth:`LeaseArrayEngine.sweep` dispatch.

    ``max_owner_count`` is the §4 verdict: >1 anywhere means some tick of
    that scenario would have produced a second simultaneous believer.
    """

    max_owner_count: np.ndarray  # [B] max per-cell owner count over T x N
    owned_frac: np.ndarray       # [B] fraction of (tick, cell) slots owned
    final_owners: np.ndarray     # [B, N] owner row after the last tick
    owners: Optional[np.ndarray] = None  # [B, T, N] iff collect="owners"
    counts: Optional[np.ndarray] = None  # [B, T, N] iff collect="owners"
    #: [B] int32 per margin component iff collect="margins" (see
    #: ops._margin_scan_impl for the definitions; MARGIN_BIG = never close)
    margins: Optional[dict] = None


def _cell_sharding_specs(planes_keys):
    """shard_map PartitionSpecs for a (state, net, t0, clk0, rst0, planes)
    call: every state/output plane splits on its trailing cell axis;
    scenario planes split iff their registered dims carry the cell axis
    "N" (acc_up, the [T, P, A] link matrices and the clock-rate planes are
    replicated, as are the [P]/[A] clock offsets and restart history)."""
    from jax.sharding import PartitionSpec as P

    from .scenario import PLANES

    cells = P(None, "cells")
    plane_specs = {
        k: (P(None, "cells") if "N" in PLANES[k].dims else P())
        for k in planes_keys
    }
    # the clk0/rst0 slots take bare prefix specs: they cover both the
    # per-node tuples and the None fast path (no leaves) identically
    return (
        (cells, cells, P(), P(), P(), plane_specs),
        (cells, cells, cells, cells),
    )


@functools.lru_cache(maxsize=None)
def _trace_fn(
    majority: int, lease_q4: int, round_q4: int, guard_q4: int, backend: str,
    sync: bool, block_n: int, window: int, n_devices: int, planes_keys: tuple,
    restart_guard: bool = True, skip_stable: bool = True,
):
    """The fused scenario replay, jitted; with >1 device the cell axis is
    shard_map-ed across a 1-D device mesh (cells are independent — the
    tick math never reduces across N), so a trace uses every device."""

    def run(state, net, t0, clk0, rst0, planes):
        return _window_scan_impl(
            state, net, t0, clk0, rst0, planes,
            majority=majority, lease_q4=lease_q4, round_q4=round_q4,
            guard_q4=guard_q4, backend=backend, sync=sync, block_n=block_n,
            window=window, restart_guard=restart_guard,
            skip_stable=skip_stable,
        )

    if n_devices > 1:
        from jax.experimental.shard_map import shard_map
        from jax.sharding import Mesh

        mesh = Mesh(np.array(jax.devices()[:n_devices]), ("cells",))
        in_specs, out_specs = _cell_sharding_specs(planes_keys)
        run = shard_map(
            run, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_rep=False,
        )
    return jax.jit(run)


@functools.lru_cache(maxsize=None)
def _sweep_fn(
    majority: int, lease_q4: int, round_q4: int, guard_q4: int, backend: str,
    sync: bool, block_n: int, window: int, collect: str, n_devices: int,
    restart_guard: bool = True, skip_stable: bool = True,
):
    """One-dispatch batched scenario replay: vmap over the stacked planes
    (state broadcast), reductions inside the jit so a summary sweep never
    materializes [B, T, N] outputs, shard_map over the device mesh when
    more than one device is visible. The planes dict arrives split in two
    so that in ``collect="owners"`` mode only the [B, T, N] attempts/
    releases leaves are donated — exactly the buffers XLA can reuse for
    the owners/counts cubes; a summary sweep's outputs are [B]-shaped, so
    nothing could reuse any plane and donating would only warn."""

    def one(state, net, t0, clk0, rst0, cell_planes, rest_planes):
        if collect == "margins":
            # the margin mode always runs the delayed jnp oracle scan —
            # the backends agree bit-for-bit, so margins are backend-free
            owners, counts, margins = _margin_scan_impl(
                state, net, t0, clk0, {**cell_planes, **rest_planes},
                majority=majority, lease_q4=lease_q4, round_q4=round_q4,
                guard_q4=guard_q4, rst0=rst0, restart_guard=restart_guard,
            )
        else:
            margins = None
            _, _, owners, counts = _window_scan_impl(
                state, net, t0, clk0, rst0, {**cell_planes, **rest_planes},
                majority=majority, lease_q4=lease_q4, round_q4=round_q4,
                guard_q4=guard_q4, backend=backend, sync=sync,
                block_n=block_n, window=window, restart_guard=restart_guard,
                skip_stable=skip_stable,
            )
        out = {
            "max_owner_count": counts.max(),
            "owned_frac": (owners >= 0).mean(),
            "final_owners": owners[-1],
        }
        if collect == "owners":
            out["owners"] = owners
            out["counts"] = counts
        if collect == "margins":
            out["margins"] = margins
        return out

    batched = jax.vmap(one, in_axes=(None, None, None, None, None, 0, 0))
    if n_devices > 1:
        from jax.experimental.shard_map import shard_map
        from jax.sharding import Mesh, PartitionSpec as P

        mesh = Mesh(np.array(jax.devices()[:n_devices]), ("b",))
        batched = shard_map(
            batched, mesh=mesh,
            in_specs=(P(), P(), P(), P(), P(), P("b"), P("b")),
            out_specs=P("b"),
            check_rep=False,
        )
    donate = (5,) if collect == "owners" else ()
    return jax.jit(batched, donate_argnums=donate)


class LeaseArrayEngine:
    def __init__(
        self,
        n_cells: int,
        *,
        n_acceptors: int = 5,
        n_proposers: int = 8,
        lease_ticks: int = 3,
        round_ticks: int = 1,
        drift_eps: float = 0.0,
        backend: str = "jnp",
        window: int = 16,
        restart_guard: bool = True,
        skip_stable: bool = True,
    ) -> None:
        if n_acceptors < 1 or n_proposers < 1:
            raise ValueError("need at least one acceptor and one proposer")
        self.n_cells = n_cells
        self.n_acceptors = n_acceptors
        self.n_proposers = n_proposers
        self.majority = n_acceptors // 2 + 1
        self.lease_ticks = lease_ticks
        self.lease_q4 = lease_quarters(lease_ticks)
        self.round_ticks = round_ticks
        self.round_q4 = QUARTERS * int(round_ticks)
        #: ε, the assumed clock-drift bound (§4): proposers discount their
        #: own lease timer to T·(1-ε)/(1+ε) so a slow believer never
        #: outlives a fast acceptor's timer. ε=0 = the exact rate-1 engine.
        self.drift_eps = float(drift_eps)
        self.guard_q4 = guarded_lease_q4(self.lease_q4, self.drift_eps)
        self.backend = backend
        self.window = int(window)
        self.state = init_state(n_cells, n_acceptors, n_proposers)
        self.net: NetPlaneState = init_netplane(n_cells, n_acceptors)
        self.t = 0
        # accumulated local clocks (local quarter-ticks at global tick t);
        # advanced by the scenario's prop_rate/acc_rate planes each tick
        self.prop_clk = np.zeros(n_proposers, np.int32)
        self.acc_clk = np.zeros(n_acceptors, np.int32)
        self.last_owner_count = jnp.zeros(n_cells, jnp.int32)
        # flips True on the first delayed step; once messages may be in
        # flight, every later tick must run the delayed model too
        self._netplane_active = False
        #: §2 diskless deaf window honored? False is the chaos suite's
        #: negative control: restarted acceptors answer immediately with
        #: blank state, which provably breaks §4 under crash schedules
        self.restart_guard = bool(restart_guard)
        #: quiescence fast path in the Pallas window kernels: stable
        #: (block, window) pairs collapse to owner-row broadcasts.
        #: Bit-identical results either way; False is the A/B bench control
        self.skip_stable = bool(skip_stable)
        # restart history carried across dispatches (mirrors the clocks):
        # per-proposer restart counters and each acceptor's deaf-until
        # reading on ITS local clock. flips _restart_active once any
        # restart plane fires so the restart-mode ballot encoding (the
        # RESTART_SHIFT carve) never switches off mid-trace
        self._rc = np.zeros(n_proposers, np.int32)
        self._deaf_until = np.zeros(n_acceptors, np.int32)
        self._restart_active = False

    # -------------------------------------------------------- packing budget
    def _max_restarts(self, prop_restart=None) -> int:
        """The pack-budget ``max_restarts`` charge for a dispatch that may
        add ``prop_restart`` ([T, P], [B, T, P] or a single [P] row) to the
        carried counters — 0 while the engine has never seen a restart
        (the honest encoding), else at least 1 so the RESTART_SHIFT carve
        is always charged once restart mode is on."""
        rc_end = self._rc.astype(np.int64)
        seen = self._restart_active
        if prop_restart is not None:
            prst = np.asarray(prop_restart, np.int64)
            if prst.size:
                if prst.ndim >= 3:
                    # [B, T, P] stack: each scenario replays independently,
                    # so charge the worst per-scenario total, not the sum
                    add = (
                        prst.reshape(prst.shape[0], -1, self.n_proposers)
                        .sum(axis=1).max(axis=0)
                    )
                else:
                    add = prst.reshape(-1, self.n_proposers).sum(axis=0)
                rc_end = rc_end + add
                seen = seen or bool(prst.any())
        if not seen:
            return 0
        return max(1, int(rc_end.max(initial=0)))

    def _check_pack_budget(
        self, t_end: int, max_delay: int = 0, max_rate: int = QUARTERS,
        max_restarts: int = 0,
    ) -> None:
        max_rate = max(int(max_rate), QUARTERS)
        clk_max = int(max(self.prop_clk.max(), self.acc_clk.max(), 0))
        check_pack_budget(
            t_end, self.n_proposers, self.lease_q4, max_delay,
            max_rate=max_rate,
            clk_slack=max(0, clk_max - max_rate * self.t),
            max_restarts=max_restarts,
        )

    def _static_bound_check(
        self, t_end: int, max_delay: int = 0, max_rate: int = QUARTERS,
        max_restarts: int = 0,
    ) -> None:
        """Run the leaselint interval analysis host-side before a bulk
        dispatch. Complements ``_check_pack_budget``: the hand bound is
        skipped under tracing and blind to everything but ballots and
        lease deadlines, while this proves every traced-core intermediate
        stays in int32. Best-effort by design — an analyzer import/bug
        failure warns once and never blocks a dispatch; a *finding*
        (an actual overflow proof) raises."""
        global _STATIC_CHECK_FAILED
        max_rate = max(int(max_rate), QUARTERS)
        clk_max = int(max(self.prop_clk.max(), self.acc_clk.max(), 0))
        try:
            findings = _static_pack_findings(
                int(t_end), self.n_proposers, self.n_acceptors,
                self.lease_q4, self.round_q4, self.guard_q4,
                int(max_delay), max_rate,
                max(0, clk_max - max_rate * self.t),
                int(max_restarts),
            )
        except Exception as e:
            if not _STATIC_CHECK_FAILED:
                _STATIC_CHECK_FAILED = True
                warnings.warn(
                    f"static pack-budget analysis unavailable "
                    f"(falling back to the hand check only): {e!r}",
                    RuntimeWarning, stacklevel=3,
                )
            return
        if findings:
            raise ValueError(
                f"static analysis refused a {t_end}-tick replay — the "
                f"traced tick core can overflow where the runtime check "
                f"does not look:\n  " + "\n  ".join(findings)
            )

    def _clk0(self):
        """The engine's local-clock offsets for a dispatch — or None while
        every clock still equals the rate-1 reading ``4t`` (an engine that
        never saw a drifted plane), so the jitted scan derives the default
        clocks in-graph and the host-driven step path pays no per-tick
        clock uploads."""
        t4 = QUARTERS * self.t
        if (self.prop_clk == t4).all() and (self.acc_clk == t4).all():
            return None
        return jnp.asarray(self.prop_clk), jnp.asarray(self.acc_clk)

    def _rst0(self):
        """The engine's restart history for a dispatch — or None while no
        restart plane has ever fired, so honest replays trace the
        restart-free tick core (and the honest ballot encoding) with zero
        extra uploads. Once active, always a concrete (rc [P],
        deaf_until [A]) pair: mode must stay pinned even through quiet
        dispatches so ballot encodings never mix mid-trace."""
        if not self._restart_active:
            return None
        return jnp.asarray(self._rc), jnp.asarray(self._deaf_until)

    def _advance_restarts(self, acc_restart, prop_restart, acc_rate) -> None:
        """Fold a dispatched schedule's restart planes into the carried
        history. MUST run before ``_advance_clocks``: deaf-until deadlines
        are minted against each acceptor's local clock reading AT the
        restart tick (``self.acc_clk`` + the exclusive rate prefix), the
        same readings ``ops._restart_planes`` derives in-graph."""
        prst = np.asarray(prop_restart, np.int64).reshape(
            -1, self.n_proposers
        )
        self._rc = (self._rc + prst.sum(axis=0)).astype(np.int32)
        arst = np.asarray(acc_restart, np.int64).reshape(
            -1, self.n_acceptors
        )
        rate = np.asarray(acc_rate, np.int64).reshape(-1, self.n_acceptors)
        aclk = self.acc_clk.astype(np.int64) + np.concatenate(
            [np.zeros((1, self.n_acceptors), np.int64),
             np.cumsum(rate, axis=0)[:-1]]
        )
        minted = np.where(arst > 0, aclk + self.lease_q4, 0)
        self._deaf_until = np.maximum(
            self._deaf_until, minted.max(axis=0, initial=0)
        ).astype(np.int32)

    def _advance_clocks(self, prop_rate, acc_rate) -> None:
        """Accumulate the scenario's rate planes ([T, P]/[T, A] or one
        tick's [P]/[A] rows) into the engine's local clocks."""
        self.prop_clk = (
            self.prop_clk
            + np.asarray(prop_rate, np.int64).reshape(-1, self.n_proposers)
            .sum(axis=0)
        ).astype(np.int32)
        self.acc_clk = (
            self.acc_clk
            + np.asarray(acc_rate, np.int64).reshape(-1, self.n_acceptors)
            .sum(axis=0)
        ).astype(np.int32)

    # ------------------------------------------------------------ one tick
    def step(
        self, tick=None, release=None, acc_up=None, delay=None, drop=None,
        *, attempt=None,
    ) -> np.ndarray:
        """Advance one tick; returns the per-cell owner row (id or -1).

        Pass a :class:`TickInputs` (``make_tick(...)``) — or the legacy
        per-plane kwargs, which build one: ``delay``/``drop`` are ``[P, A]``
        link matrices (legacy ``[A]`` broadcasts over P) for legs sent this
        tick, in whole ticks; passing either kwarg — or a tick whose
        delay/drop planes are nonzero — switches the engine onto the
        delayed in-flight model permanently. (For backward compatibility
        the legacy planes are also accepted positionally — the first
        positional argument doubles as the bare attempt row.)

        Slot-isolation precondition (netplane.py): a new attempt on a cell
        overwrites that cell's in-flight request slots, so attempts on the
        SAME cell must be spaced more than ``4 * max_delay`` ticks apart
        while older messages may still be in flight; same for releases
        with ``max_delay`` (``random_trace`` enforces both; hand-driven
        schedules must too).
        """
        if tick is not None and not isinstance(tick, TickInputs):
            if attempt is not None:
                raise TypeError(
                    "pass the attempt row positionally or as attempt=, not both"
                )
            attempt, tick = tick, None  # legacy positional attempt row
        elif tick is not None and any(
            x is not None for x in (attempt, release, acc_up, delay, drop)
        ):
            raise TypeError(
                "pass planes inside the TickInputs, not alongside it"
            )
        if tick is None:
            if any(
                x is not None
                for x in (attempt, release, acc_up, delay, drop)
            ):
                warnings.warn(
                    _DEPRECATED_STEP_KWARGS, DeprecationWarning,
                    stacklevel=2,
                )
            tick = make_tick(  # validates ghost proposer ids, shapes, dtypes
                n_cells=self.n_cells, n_acceptors=self.n_acceptors,
                n_proposers=self.n_proposers,
                attempts=attempt, releases=release, acc_up=acc_up,
                delay=delay, drop=drop,
            )
            if delay is not None or drop is not None:
                self._netplane_active = True  # only once validation passed
        else:
            tick.validate_for(
                n_cells=self.n_cells, n_acceptors=self.n_acceptors,
                n_proposers=self.n_proposers,
            )
            if (
                np.asarray(tick.delay).any()
                or np.asarray(tick.drop).any()
                or tick.corrupted
                or tick.restarted
                or tick.extended
            ):
                self._netplane_active = True
        self._check_pack_budget(
            self.t + 1,
            int(np.asarray(tick.delay).max(initial=0)),
            max(
                int(np.asarray(tick.prop_rate).max(initial=0)),
                int(np.asarray(tick.acc_rate).max(initial=0)),
            ),
            self._max_restarts(tick.prop_restart),
        )
        if tick.restarted:
            # crashes imply in-flight state (restart mode is delayed-only)
            # and pin the restart-mode ballot encoding from here on
            self._netplane_active = True
            self._restart_active = True
        self.state, self.net, self.last_owner_count = lease_plane_tick(
            self.state, self.net, self.t, tick,
            majority=self.majority, lease_q4=self.lease_q4,
            round_q4=self.round_q4, guard_q4=self.guard_q4,
            clk0=self._clk0(), rst0=self._rst0(),
            restart_guard=self.restart_guard, backend=self.backend,
            sync=not self._netplane_active, window=self.window,
            skip_stable=self.skip_stable,
        )
        self.t += 1
        if self._restart_active:
            self._advance_restarts(
                tick.acc_restart, tick.prop_restart, tick.acc_rate
            )
        self._advance_clocks(tick.prop_rate, tick.acc_rate)
        return np.asarray(owner_row(self.state))

    # ---------------------------------------------------------- validation
    def _coerce_scenario(self, scenario, releases, acc_up, delay, drop):
        if not isinstance(scenario, Scenario):
            scenario = Scenario.build(
                n_cells=self.n_cells, n_acceptors=self.n_acceptors,
                n_proposers=self.n_proposers,
                attempts=scenario, releases=releases, acc_up=acc_up,
                delay=delay, drop=drop,
            )
        else:
            scenario.validate_for(
                n_cells=self.n_cells, n_acceptors=self.n_acceptors,
                n_proposers=self.n_proposers,
            )
        return scenario

    def _pick_model(self, netplane, delayed: bool, *, mutate: bool = True) -> bool:
        """Returns sync=True/False. With ``mutate`` the engine flips onto
        the netplane permanently (run_trace/step); a read-only caller
        (sweep) passes ``mutate=False`` and the engine is left untouched."""
        if netplane is False and (delayed or self._netplane_active):
            raise ValueError(
                "netplane=False but the scenario carries nonzero delay/drop, "
                "corruption or restart planes (or messages are already in "
                "flight); the synchronous model cannot honor them"
            )
        wants_net = bool(netplane) or (netplane is None and delayed)
        if mutate and wants_net:
            self._netplane_active = True
        return not (wants_net or self._netplane_active)

    # ------------------------------------------------------------ bulk path
    def run_trace(
        self, scenario=None, releases=None, acc_up=None, delay=None,
        drop=None, *, netplane=None, attempts=None,
    ):
        """Replay a [T]-tick :class:`Scenario` in one fused dispatch.

        The first argument is a ``Scenario`` (``Scenario.build(...)``); the
        legacy form — a [T, N] attempts array (positionally or as the
        ``attempts=`` keyword) plus per-plane kwargs, with ``delay``/
        ``drop`` as [T, A] or [T, P, A] schedules — builds one (and is
        validated identically, ghost proposer ids included).

        ``netplane`` picks the network model: None (default) auto-selects
        the delayed in-flight model iff the scenario carries nonzero
        delay/drop planes (or the engine is already on it); True forces it
        (zero-delay scenarios are bit-identical either way); False forces
        the synchronous step — the sync tick cannot honor fault planes, so
        a delayed scenario (or an engine already on the in-flight model)
        raises rather than silently dropping them.
        Returns (owners [T, N], owner_counts [T, N]) as numpy; the
        engine's state/tick advance past the trace.
        """
        if attempts is not None:
            if scenario is not None:
                raise TypeError(
                    "pass the attempts plane positionally or as attempts=, "
                    "not both"
                )
            scenario = attempts  # legacy keyword call sites
        if not isinstance(scenario, Scenario):
            warnings.warn(
                _DEPRECATED_TRACE_PLANES, DeprecationWarning, stacklevel=2
            )
        scenario = self._coerce_scenario(
            scenario, releases, acc_up, delay, drop
        )
        T = scenario.n_ticks
        restarted = scenario.restarted
        sync = self._pick_model(
            netplane,
            scenario.delayed or scenario.corrupted or restarted
            or scenario.extended,
        )
        if T == 0:
            empty = np.zeros((0, self.n_cells), np.int32)
            return empty, empty.copy()
        dmax = int(np.asarray(scenario.delay).max(initial=0))
        rmax = max(
            int(np.asarray(scenario.prop_rate).max(initial=0)),
            int(np.asarray(scenario.acc_rate).max(initial=0)),
        )
        mr = self._max_restarts(scenario.prop_restart)
        self._check_pack_budget(self.t + T, dmax, rmax, mr)
        self._static_bound_check(self.t + T, dmax, rmax, mr)
        if restarted:
            self._restart_active = True  # pins the restart ballot encoding
        # all-default corruption/restart/extends planes stay host-side:
        # the honest replay never compiles the faulted tick variants
        # (bit-identical jaxpr, zero extra uploads); once restart mode is
        # pinned, rst0 (not the planes) keeps it on across quiet dispatches
        planes = {
            k: jnp.asarray(v) for k, v in scenario.planes.items()
            if not (
                k in CORRUPTION_PLANES + RESTART_PLANES + EXTEND_PLANES
                and (np.asarray(v) == PLANES[k].default).all()
            )
        }
        n_dev = len(jax.devices())
        if n_dev > 1 and self.n_cells % n_dev != 0:
            n_dev = 1  # uneven cell split: stay on one device
        fn = _trace_fn(
            self.majority, self.lease_q4, self.round_q4, self.guard_q4,
            self.backend, sync, 512, self.window, n_dev, tuple(planes),
            self.restart_guard, self.skip_stable,
        )
        self.state, self.net, owners, counts = fn(
            self.state, self.net, jnp.int32(self.t), self._clk0(),
            self._rst0(), planes
        )
        self.t += int(T)
        if self._restart_active:
            self._advance_restarts(
                scenario.acc_restart, scenario.prop_restart,
                scenario.acc_rate,
            )
        self._advance_clocks(scenario.prop_rate, scenario.acc_rate)
        self.last_owner_count = counts[-1]
        return np.asarray(owners), np.asarray(counts)

    # ----------------------------------------------------------- the sweep
    def sweep(
        self, scenarios, *, netplane=None, collect: str = "summary",
        verify: bool = True, backend: Optional[str] = None, tags=None,
    ) -> SweepResult:
        """Replay a BATCH of scenarios in ONE dispatch — "replay 10k fault
        scenarios" as a single call.

        ``scenarios`` is a list of same-geometry same-length
        :class:`Scenario`\\ s or an already-stacked ``Scenario.stack``
        pytree ([B, T, ...] planes). Every scenario starts from THIS
        engine's current state/tick; the engine itself is NOT advanced
        (a sweep is a fan-out query, not a state transition). The batch is
        ``jax.vmap``-ed inside one jit (in ``collect="owners"`` mode the
        stacked planes are donated — their buffers become the output cubes);
        with more than one JAX device visible it is additionally
        ``shard_map``-ed across a 1-D device mesh over the batch axis
        (B must then divide by the device count).

        ``collect="summary"`` (default) reduces inside the dispatch — only
        [B]-shaped verdicts and the [B, N] final owner rows come back, so
        10k-scenario sweeps never materialize [B, T, N] on the host;
        ``collect="owners"`` also returns the full owners/counts cubes;
        ``collect="margins"`` additionally folds the §4 boundary-proximity
        margins (``ops._margin_scan_impl``) into the dispatch — [B] int32
        scalars per component, the falsifier's fitness signal, still never
        materializing [B, T, N]. With ``verify=True`` a per-scenario §4
        violation (max owner count > 1) raises immediately; the message
        carries each offender's ``plane_digest`` (and its ``tags[i]``
        lineage string when the caller — e.g. ``falsify.search`` — passes
        per-scenario ``tags``), so a 10k-batch violation reproduces
        standalone.
        """
        if collect not in ("summary", "owners", "margins"):
            raise ValueError(f"unknown collect mode {collect!r}")
        if isinstance(scenarios, (list, tuple)):
            if not scenarios:
                raise ValueError("sweep needs at least one scenario")
            for sc in scenarios:
                sc.validate_for(
                    n_cells=self.n_cells, n_acceptors=self.n_acceptors,
                    n_proposers=self.n_proposers,
                )
            stacked = Scenario.stack(scenarios)
        else:
            stacked = scenarios
        # one host read per fault plane (the delay plane feeds both the
        # model choice and the pack-budget check; don't pull it twice)
        dmax = int(np.asarray(stacked.planes["delay"]).max(initial=0))
        delayed = dmax > 0 or bool(np.asarray(stacked.planes["drop"]).any())
        # all-DEFAULT_RATE rate planes are the in-graph default clock:
        # don't ship [B, T, P]/[B, T, A] constants into the dispatch
        # (ops._local_clock_planes derives the same readings bit-for-bit);
        # likewise all-zero corruption planes stay host-side so an honest
        # sweep never compiles (or pays for) the corrupt tick variant
        drop_keys = []
        rmax = QUARTERS
        for k in ("prop_rate", "acc_rate"):
            plane = np.asarray(stacked.planes[k])
            if plane.size == 0 or (plane == DEFAULT_RATE).all():
                drop_keys.append(k)
            else:
                rmax = max(rmax, int(plane.max()))
        corrupt = False
        for k in CORRUPTION_PLANES:
            plane = stacked.planes.get(k)
            if plane is None:
                continue
            if np.asarray(plane).any():
                corrupt = True
            else:
                drop_keys.append(k)
        # all-zero restart planes drop like corruption planes; when the
        # engine already carries restart history, rst0 (below) keeps
        # restart mode — and its ballot encoding — on regardless
        restarted = self._restart_active
        for k in RESTART_PLANES:
            plane = stacked.planes.get(k)
            if plane is None:
                continue
            if np.asarray(plane).any():
                restarted = True
            else:
                drop_keys.append(k)
        # all-sentinel extends planes drop the same way (their default is
        # NO_PROPOSER, not zero): an extend-free sweep never compiles the
        # §6 gate
        extended = False
        for k in EXTEND_PLANES:
            plane = stacked.planes.get(k)
            if plane is None:
                continue
            if (np.asarray(plane) != PLANES[k].default).any():
                extended = True
            else:
                drop_keys.append(k)
        # in collect="owners" mode the [B, T, N] attempts/releases planes
        # are DONATED to the dispatch (XLA reuses their buffers for the
        # output cubes); copy those leaves when they are already device
        # arrays so a caller can reuse its stacked Scenario
        donating = collect == "owners"
        cell_planes, rest_planes = {}, {}
        for k, v in stacked.planes.items():
            if k in drop_keys:
                continue
            arr = jnp.asarray(v)
            if k in ("attempts", "releases"):
                cell_planes[k] = (
                    arr.copy() if donating and arr is v else arr
                )
            else:
                rest_planes[k] = arr
        B, T = cell_planes["attempts"].shape[:2]
        if T == 0:
            raise ValueError("sweep scenarios must have at least one tick")
        # a sweep is read-only: pick the model without flipping the engine
        # (corruption, restart and extends planes only exist in the
        # delayed tick)
        sync = self._pick_model(
            netplane, delayed or corrupt or restarted or extended,
            mutate=False,
        )
        mr = self._max_restarts(stacked.planes.get("prop_restart"))
        self._check_pack_budget(self.t + T, dmax, rmax, mr)
        self._static_bound_check(self.t + T, dmax, rmax, mr)
        n_dev = len(jax.devices())
        if n_dev > 1 and B % n_dev != 0:
            n_dev = 1  # uneven batch: fall back to single-device vmap
        fn = _sweep_fn(
            self.majority, self.lease_q4, self.round_q4, self.guard_q4,
            backend or self.backend, sync, 512, self.window, collect, n_dev,
            self.restart_guard, self.skip_stable,
        )
        out = fn(
            self.state, self.net, jnp.int32(self.t), self._clk0(),
            self._rst0(), cell_planes, rest_planes,
        )
        result = SweepResult(
            max_owner_count=np.asarray(out["max_owner_count"]),
            owned_frac=np.asarray(out["owned_frac"]),
            final_owners=np.asarray(out["final_owners"]),
            owners=(
                np.asarray(out["owners"]) if collect == "owners" else None
            ),
            counts=(
                np.asarray(out["counts"]) if collect == "owners" else None
            ),
            margins=(
                {k: np.asarray(v) for k, v in out["margins"].items()}
                if collect == "margins" else None
            ),
        )
        if verify and (result.max_owner_count > 1).any():
            bad = np.flatnonzero(result.max_owner_count > 1)
            # name each offender by its content digest (+ the caller's
            # lineage tag): batch indices alone don't reproduce standalone
            ids = []
            for i in bad[:8]:
                sc_planes = {
                    k: np.asarray(v)[i] for k, v in stacked.planes.items()
                }
                label = f"#{i} digest={plane_digest(sc_planes)}"
                if tags is not None and i < len(tags):
                    label += f" tag={tags[i]}"
                ids.append(label)
            raise AssertionError(
                f"§4 at-most-one-owner violated in {bad.size} scenario(s) "
                f"of the sweep: " + "; ".join(ids)
            )
        return result

    # ------------------------------------------------------------- queries
    def owners(self) -> np.ndarray:
        return np.asarray(owner_row(self.state))

    def ticks_left(self) -> np.ndarray:
        """Per cell: whole LOCAL ticks of ownership remaining as the owner
        sees it (0 if unowned). Owner expiries live in the owning
        proposer's local time, so remaining time is measured against that
        proposer's accumulated clock (= ``4t`` when nothing drifts)."""
        expiry = np.asarray(
            jnp.max(
                jnp.where(self.state.owner_mask > 0, self.state.owner_expiry, 0),
                axis=0,
            )
        )
        owners = np.asarray(owner_row(self.state))
        clk = np.where(
            owners == NO_PROPOSER, 0,
            self.prop_clk[np.clip(owners, 0, self.n_proposers - 1)],
        )
        return np.maximum(expiry - clk, 0) // QUARTERS
