"""LeaseArrayEngine: a stateful driver over the vectorized lease plane.

Two modes:
  - ``step(...)``    — advance one tick (host-driven; the directory uses it)
  - ``run_trace``    — ``jax.lax.scan`` over a whole [T]-tick ``Scenario``
                       in one jitted call (the bulk/benchmark path);
                       independent planes batch further with ``jax.vmap``
                       over ``Scenario.stack`` (see ``_scenario_scanner``'s
                       pytree-in/pytree-out signature and
                       tests/test_scenario.py::test_vmap_stacked_scenarios).

Inputs are declarative **Scenario planes** (``scenario.py``): one pytree
carries every fault dimension — attempts, releases, acceptor reachability,
and asymmetric per-(proposer, acceptor) delay/drop link matrices — so new
fault planes register into the schema instead of growing new arguments.
The legacy per-plane kwargs still work as thin shims that build the pytree.

Two network models share one scanner: the synchronous zero-delay tick
(every round resolves in one tick) and the delayed in-flight message plane
(``netplane.py``). A scenario (or ``step`` call) carrying nonzero delay or
drop planes switches the engine onto the delayed model; it stays there
(messages may be in flight) with zero-delay defaults from then on.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from .netplane import NetPlaneState, init_netplane
from .ops import lease_plane_tick
from .ref import owner_row
from .scenario import Scenario, TickInputs, make_tick
from .state import QUARTERS, LeaseArrayState, init_state, lease_quarters


@functools.lru_cache(maxsize=None)
def _scenario_scanner(
    majority: int, lease_q4: int, round_q4: int, backend: str, sync: bool
):
    """Jitted (state, net, t0, planes) -> (state, net, owners, counts).

    ONE scanner serves both network models: ``sync`` statically picks the
    zero-delay body (net passes through untouched, delay/drop planes are
    dead code) or the in-flight netplane body. ``planes`` is a dict pytree
    of [T, ...] scenario planes — lax.scan slices every registered plane
    per tick, so newly registered planes ride along with no new argument.
    """

    def scan_fn(state, net, t0, planes):
        def body(carry, xs):
            st, nt, t = carry
            st, nt, count = lease_plane_tick(
                st, nt, t, TickInputs(xs),
                majority=majority, lease_q4=lease_q4, round_q4=round_q4,
                backend=backend, sync=sync,
            )
            return (st, nt, t + 1), (owner_row(st), count)

        (state, net, _), (owners, counts) = jax.lax.scan(
            body, (state, net, t0), planes
        )
        return state, net, owners, counts

    return jax.jit(scan_fn)


class LeaseArrayEngine:
    def __init__(
        self,
        n_cells: int,
        *,
        n_acceptors: int = 5,
        n_proposers: int = 8,
        lease_ticks: int = 3,
        round_ticks: int = 1,
        backend: str = "jnp",
    ) -> None:
        if n_acceptors < 1 or n_proposers < 1:
            raise ValueError("need at least one acceptor and one proposer")
        self.n_cells = n_cells
        self.n_acceptors = n_acceptors
        self.n_proposers = n_proposers
        self.majority = n_acceptors // 2 + 1
        self.lease_ticks = lease_ticks
        self.lease_q4 = lease_quarters(lease_ticks)
        self.round_ticks = round_ticks
        self.round_q4 = QUARTERS * int(round_ticks)
        self.backend = backend
        self.state = init_state(n_cells, n_acceptors, n_proposers)
        self.net: NetPlaneState = init_netplane(n_cells, n_acceptors)
        self.t = 0
        self.last_owner_count = jnp.zeros(n_cells, jnp.int32)
        # flips True on the first delayed step; once messages may be in
        # flight, every later tick must run the delayed model too
        self._netplane_active = False

    # ------------------------------------------------------------ one tick
    def step(
        self, tick=None, release=None, acc_up=None, delay=None, drop=None,
        *, attempt=None,
    ) -> np.ndarray:
        """Advance one tick; returns the per-cell owner row (id or -1).

        Pass a :class:`TickInputs` (``make_tick(...)``) — or the legacy
        per-plane kwargs, which build one: ``delay``/``drop`` are ``[P, A]``
        link matrices (legacy ``[A]`` broadcasts over P) for legs sent this
        tick, in whole ticks; passing either kwarg — or a tick whose
        delay/drop planes are nonzero — switches the engine onto the
        delayed in-flight model permanently. (For backward compatibility
        the legacy planes are also accepted positionally — the first
        positional argument doubles as the bare attempt row.)

        Slot-isolation precondition (netplane.py): a new attempt on a cell
        overwrites that cell's in-flight request slots, so attempts on the
        SAME cell must be spaced more than ``4 * max_delay`` ticks apart
        while older messages may still be in flight; same for releases
        with ``max_delay`` (``random_trace`` enforces both; hand-driven
        schedules must too).
        """
        if tick is not None and not isinstance(tick, TickInputs):
            if attempt is not None:
                raise TypeError(
                    "pass the attempt row positionally or as attempt=, not both"
                )
            attempt, tick = tick, None  # legacy positional attempt row
        elif tick is not None and any(
            x is not None for x in (attempt, release, acc_up, delay, drop)
        ):
            raise TypeError(
                "pass planes inside the TickInputs, not alongside it"
            )
        if tick is None:
            tick = make_tick(  # validates ghost proposer ids, shapes, dtypes
                n_cells=self.n_cells, n_acceptors=self.n_acceptors,
                n_proposers=self.n_proposers,
                attempts=attempt, releases=release, acc_up=acc_up,
                delay=delay, drop=drop,
            )
            if delay is not None or drop is not None:
                self._netplane_active = True  # only once validation passed
        else:
            tick.validate_for(
                n_cells=self.n_cells, n_acceptors=self.n_acceptors,
                n_proposers=self.n_proposers,
            )
            if np.asarray(tick.delay).any() or np.asarray(tick.drop).any():
                self._netplane_active = True
        self.state, self.net, self.last_owner_count = lease_plane_tick(
            self.state, self.net, self.t, tick,
            majority=self.majority, lease_q4=self.lease_q4,
            round_q4=self.round_q4, backend=self.backend,
            sync=not self._netplane_active,
        )
        self.t += 1
        return np.asarray(owner_row(self.state))

    # ------------------------------------------------------------ bulk path
    def run_trace(
        self, scenario=None, releases=None, acc_up=None, delay=None,
        drop=None, *, netplane=None, attempts=None,
    ):
        """Scan a [T]-tick :class:`Scenario` in one jitted call.

        The first argument is a ``Scenario`` (``Scenario.build(...)``); the
        legacy form — a [T, N] attempts array (positionally or as the
        ``attempts=`` keyword) plus per-plane kwargs, with ``delay``/
        ``drop`` as [T, A] or [T, P, A] schedules — builds one (and is
        validated identically, ghost proposer ids included).

        ``netplane`` picks the network model: None (default) auto-selects
        the delayed in-flight model iff the scenario carries nonzero
        delay/drop planes (or the engine is already on it); True forces it
        (zero-delay scenarios are bit-identical either way); False forces
        the synchronous step — the sync tick cannot honor fault planes, so
        a delayed scenario (or an engine already on the in-flight model)
        raises rather than silently dropping them.
        Returns (owners [T, N], owner_counts [T, N]) as numpy; the
        engine's state/tick advance past the trace.
        """
        if attempts is not None:
            if scenario is not None:
                raise TypeError(
                    "pass the attempts plane positionally or as attempts=, "
                    "not both"
                )
            scenario = attempts  # legacy keyword call sites
        if not isinstance(scenario, Scenario):
            scenario = Scenario.build(
                n_cells=self.n_cells, n_acceptors=self.n_acceptors,
                n_proposers=self.n_proposers,
                attempts=scenario, releases=releases, acc_up=acc_up,
                delay=delay, drop=drop,
            )
        else:
            scenario.validate_for(
                n_cells=self.n_cells, n_acceptors=self.n_acceptors,
                n_proposers=self.n_proposers,
            )
        T = scenario.n_ticks
        if netplane is False and (scenario.delayed or self._netplane_active):
            raise ValueError(
                "netplane=False but the scenario carries nonzero delay/drop "
                "planes (or messages are already in flight); the synchronous "
                "model cannot honor them"
            )
        if netplane or (netplane is None and scenario.delayed):
            self._netplane_active = True
        scanner = _scenario_scanner(
            self.majority, self.lease_q4, self.round_q4, self.backend,
            not self._netplane_active,
        )
        planes = {k: jnp.asarray(v) for k, v in scenario.planes.items()}
        self.state, self.net, owners, counts = scanner(
            self.state, self.net, jnp.int32(self.t), planes
        )
        self.t += int(T)
        if T > 0:
            self.last_owner_count = counts[-1]
        return np.asarray(owners), np.asarray(counts)

    # ------------------------------------------------------------- queries
    def owners(self) -> np.ndarray:
        return np.asarray(owner_row(self.state))

    def ticks_left(self) -> np.ndarray:
        """Per cell: whole ticks of ownership remaining (0 if unowned)."""
        expiry = np.asarray(
            jnp.max(
                jnp.where(self.state.owner_mask > 0, self.state.owner_expiry, 0),
                axis=0,
            )
        )
        return np.maximum(expiry - QUARTERS * self.t, 0) // QUARTERS
