"""jit'd public entry points for the lease plane: backend dispatch
(pure-jnp fallback vs fused Pallas window kernel) plus cell-axis padding so
callers can use any N. Mirrors the kernels/flash_attention kernel/ops/ref
layout.

The bulk path is :func:`lease_window_scan`: a whole ``[T, …]`` scenario in
ONE dispatch. All backends run the identical packed tick math
(``ref.sync_tick_math`` / ``netplane.delayed_tick_math``), so they agree
bit-for-bit:

  - ``"jnp"``        — `lax.scan` over the packed planes (the XLA-lowered
                       fallback; also the oracle every kernel is tested
                       against);
  - ``"pallas"``     — the time-resident window kernel, interpret mode
                       (runs anywhere; correctness CI);
  - ``"pallas_tpu"`` — the same kernel compiled for real TPUs.

One step: :func:`lease_plane_tick` advances every cell one tick of either
network model — the synchronous zero-delay tick (``sync=True``) or the
delayed in-flight message plane (see ``netplane.py``). Its per-tick inputs
are a :class:`~repro.lease_array.scenario.TickInputs` pytree, so
registering a new fault plane never changes this signature.

``lease_plane_step`` / ``lease_plane_step_delayed`` are deprecation shims
for the old one-positional-argument-per-fault-dimension API.
"""
from __future__ import annotations

import functools
import warnings

import jax
import jax.numpy as jnp
import numpy as np

from .kernel import lease_window_delayed_pallas, lease_window_sync_pallas
from .netplane import (
    R_PROPOSING,
    NetPlaneState,
    delayed_tick_math,
    pack_link,
)
from .ref import link_matrix, sync_tick_math
from .scenario import (
    CORRUPTION_PLANES,
    EXTEND_PLANES,
    PLANES,
    RESTART_PLANES,
    TickInputs,
    make_tick,
)
from .state import (
    NO_PROPOSER,
    PACK_MASK,
    PACK_SHIFT,
    QUARTERS,
    LeaseArrayState,
    PackedLeaseState,
    ballot_proposer,
    check_pack_budget,
    clock_select,
    pack_state,
    packed_q4,
    rate1_clock,
    unpack_state,
)

BACKENDS = ("jnp", "pallas", "pallas_tpu")


def _local_clock_planes(t0, T: int, clk0, planes: dict, n_proposers: int,
                        n_acceptors: int):
    """Absolute per-tick local-clock planes ``(pclk [T, P], aclk [T, A])``:
    ``clk0`` (each node's accumulated local quarter-ticks at ``t0``) plus
    the exclusive prefix sum of the scenario's rate planes. Clock readings
    are a pure function of the rate planes, so drifted node time needs no
    scan carry — the planes stream into the kernel like ``acc_up``.

    ``clk0=None`` is the no-history default (``4·t0`` on every node: the
    rate-1 reading, so legacy rate-free callers reproduce the old global
    time base bit-for-bit); a rate plane missing from a hand-rolled dict
    means the drift-free DEFAULT_RATE step."""
    t0 = jnp.asarray(t0, jnp.int32)

    def one(rate, rows: int, c0):
        if c0 is None:
            c0 = rate1_clock(t0, rows)
        c0 = jnp.asarray(c0, jnp.int32)
        if rate is None:
            steps = QUARTERS * jnp.arange(T, dtype=jnp.int32)
            return c0[None, :] + steps[:, None]
        rate = jnp.asarray(rate, jnp.int32)
        return c0[None, :] + jnp.cumsum(rate, axis=0) - rate

    pc0, ac0 = (None, None) if clk0 is None else clk0
    return (
        one(planes.get("prop_rate"), n_proposers, pc0),
        one(planes.get("acc_rate"), n_acceptors, ac0),
    )


def _restart_planes(rst0, arst, prst, aclk, lease_q4: int, guard: bool):
    """Absolute per-tick crash/restart planes, precomputed like the clock
    planes so restart state needs NO scan carry:

      ``rc [T, P]``        INCLUSIVE running per-proposer restart count
                           (a proposer restarting at tick t attempts at t
                           with the bumped counter, like core/cell's
                           persisted-counter bump);
      ``deaf [T, A]``      1 while the acceptor is inside its post-restart
                           deaf window: its local clock has not yet
                           advanced a maximal lease span (``lease_q4``
                           local quarter-ticks — M on ITS clock domain)
                           past the latest restart (a running cummax of
                           restart-minted horizons vs ``aclk``);
      ``deaf_rem [T, A]``  local quarter-ticks of deaf window remaining
                           (0 = not deaf; the margins scan's boundary
                           distance).

    ``rst0`` is the (rc0 [P], deaf_until0 [A]) restart history at t0
    (None = fresh). ``guard=False`` (the §4 negative control) zeroes the
    deaf window: restarted acceptors come back blank but answer
    immediately — the unsafe diskless restart the paper's M-wait forbids.
    """
    rc0, du0 = (None, None) if rst0 is None else rst0
    rc = jnp.cumsum(jnp.asarray(prst, jnp.int32), axis=0)
    if rc0 is not None:
        rc = rc + jnp.asarray(rc0, jnp.int32)[None, :]
    minted = jnp.where(jnp.asarray(arst, jnp.int32) > 0, aclk + lease_q4, 0)
    du = jax.lax.cummax(minted, axis=0)
    if du0 is not None:
        du = jnp.maximum(du, jnp.asarray(du0, jnp.int32)[None, :])
    deaf_rem = jnp.maximum(du - aclk, 0)
    if not guard:
        deaf_rem = jnp.zeros_like(deaf_rem)
    return rc, (deaf_rem > 0).astype(jnp.int32), deaf_rem


def _pad_cells(arrays, multiple: int, pad_values):
    """Pad the trailing cell axis of each array to a block multiple."""
    n = arrays[0].shape[-1]
    pad = (-n) % multiple
    if pad == 0:
        return arrays, n
    width = [(0, 0)] * (arrays[0].ndim - 1) + [(0, pad)]
    return [
        jnp.pad(a, width, constant_values=v)
        for a, v in zip(arrays, pad_values)
    ], n


def _pad_packed(packed: PackedLeaseState, multiple: int):
    # padded cells never attempt or own anything (owner_id's empty
    # sentinel is NO_PROPOSER; every other plane's is 0)
    arrays, n = _pad_cells(
        list(packed), multiple,
        tuple(
            NO_PROPOSER if f == "owner_id" else 0
            for f in PackedLeaseState._fields
        ),
    )
    return PackedLeaseState(*arrays), n


def _pad_net(net: NetPlaneState, multiple: int) -> NetPlaneState:
    pad = (-net.n_cells) % multiple
    if pad == 0:
        return net
    # zero padding = empty slots / no open round in the padded cells;
    # presp_pay's empty sentinel is NO_PROPOSER, matching init_netplane
    return NetPlaneState(*(
        jnp.pad(
            arr, ((0, 0), (0, pad)),
            constant_values=NO_PROPOSER if name == "presp_pay" else 0,
        )
        for name, arr in zip(NetPlaneState._fields, net)
    ))


def _window_scan_impl(
    state: LeaseArrayState,
    net,
    t0,
    clk0,
    rst0,
    planes: dict,
    *,
    majority: int,
    lease_q4: int,
    round_q4: int,
    guard_q4: int,
    backend: str,
    sync: bool,
    block_n: int,
    window: int,
    restart_guard: bool = True,
    skip_stable: bool = True,
):
    """Shared unjitted body of the fused scan (also vmapped by
    ``engine.sweep``). ``planes`` is the Scenario plane dict ([T, ...]
    arrays); ``clk0`` the (prop [P], acc [A]) local-clock offsets at
    ``t0`` (None = the rate-1 reading ``4·t0``); ``rst0`` the
    (restart-counter [P], deaf-until [A]) restart history at ``t0``
    (None = fresh). Returns (state', net', owners [T, N], counts [T, N])."""
    if backend not in BACKENDS:
        raise ValueError(f"unknown lease-plane backend {backend!r}")
    P = state.n_proposers
    A, N = state.highest_promised.shape
    t0 = jnp.asarray(t0, jnp.int32)
    attempts = jnp.asarray(planes["attempts"], jnp.int32)
    releases = jnp.asarray(planes["releases"], jnp.int32)
    acc_up = jnp.asarray(planes["acc_up"], jnp.int32)
    T = attempts.shape[0]
    pclk, aclk = _local_clock_planes(t0, T, clk0, planes, P, A)
    packed = pack_state(state)
    # the adversarial corruption planes: absent from the dict means the
    # honest tick math traces with NO corruption ops (the callers omit
    # all-zero planes host-side, so honest replays stay byte-identical)
    stale = planes.get("acc_stale")
    equiv = planes.get("acc_equiv")
    corrupt = stale is not None or equiv is not None
    if corrupt:
        if sync:
            raise ValueError(
                "corruption planes (acc_stale/acc_equiv) need the delayed "
                "model; the synchronous tick cannot honor them"
            )
        za = jnp.zeros((T, A), jnp.int32)
        stale = za if stale is None else jnp.asarray(stale, jnp.int32)
        equiv = za if equiv is None else jnp.asarray(equiv, jnp.int32)
    # the §6 extends plane: same omit-means-honest contract (all-default
    # -1 planes are stripped by the callers, so honest replays never
    # compile the extend gate)
    ext = planes.get("extends")
    extend = ext is not None
    if extend:
        if sync:
            raise ValueError(
                "the extends plane (§6 owner extension) needs the delayed "
                "model; the synchronous tick cannot honor it"
            )
        ext = jnp.asarray(ext, jnp.int32)
    # the crash/restart planes: same omit-means-honest contract; a restart
    # history (rst0) keeps restart mode on across incremental steps even
    # when this dispatch's planes are quiet, so ballot encoding never
    # switches mid-trace
    arst = planes.get("acc_restart")
    prst = planes.get("prop_restart")
    restart = arst is not None or prst is not None or rst0 is not None
    if restart:
        if sync:
            raise ValueError(
                "restart planes (acc_restart/prop_restart) need the "
                "delayed model; the synchronous tick cannot honor them"
            )
        arst = (
            jnp.zeros((T, A), jnp.int32) if arst is None
            else jnp.asarray(arst, jnp.int32)
        )
        prst = (
            jnp.zeros((T, P), jnp.int32) if prst is None
            else jnp.asarray(prst, jnp.int32)
        )
        rc, deaf, _ = _restart_planes(
            rst0, arst, prst, aclk, lease_q4, restart_guard
        )
    if not sync:
        link = pack_link(planes["delay"], planes["drop"])  # [T, P, A]

    if backend == "jnp":
        if sync:
            def body(carry, xs):
                lease, t = carry
                a, r, u, pc, ac = xs
                lease, count = sync_tick_math(
                    lease, t, a[None, :], r[None, :], u[:, None],
                    pc[:, None], ac[:, None],
                    majority=majority, lease_q4=lease_q4, n_proposers=P,
                    guard_q4=guard_q4,
                )
                return (lease, t + 1), (lease[2], count)

            (lease, _), (owners, counts) = jax.lax.scan(
                body, (tuple(packed), t0),
                (attempts, releases, acc_up, pclk, aclk),
            )
            new_net = net
        else:
            def body(carry, xs):
                lease, netc, t = carry
                a, r, u, pc, ac, lk = xs[:6]
                i = 6
                adv = {}
                if extend:
                    adv["extend"] = xs[i][None, :]
                    i += 1
                if corrupt:
                    adv.update(stale=xs[i][:, None], equiv=xs[i + 1][:, None])
                    i += 2
                if restart:
                    adv.update(
                        acc_restart=xs[i][:, None],
                        acc_deaf=xs[i + 1][:, None],
                        prop_restart=xs[i + 2][:, None],
                        prop_rc=xs[i + 3][:, None],
                    )
                lease, netc, count = delayed_tick_math(
                    lease, netc, t, a[None, :], r[None, :], u[:, None],
                    pc[:, None], ac[:, None], lk,
                    majority=majority, lease_q4=lease_q4, round_q4=round_q4,
                    n_proposers=P, guard_q4=guard_q4, **adv,
                )
                return (lease, netc, t + 1), (lease[2], count)

            xs = (attempts, releases, acc_up, pclk, aclk, link)
            if extend:
                xs += (ext,)
            if corrupt:
                xs += (stale, equiv)
            if restart:
                xs += (arst, deaf, prst, rc)
            (lease, netc, _), (owners, counts) = jax.lax.scan(
                body, (tuple(packed), tuple(net), t0), xs
            )
            new_net = NetPlaneState(*netc)
        new_state = unpack_state(PackedLeaseState(*lease), P)
        return new_state, new_net, owners.reshape(T, N), counts.reshape(T, N)

    interpret = backend == "pallas"
    padded, n = _pad_packed(packed, block_n)
    cell_planes = [attempts, releases] + ([ext] if extend else [])
    cell_planes, _ = _pad_cells(
        cell_planes, block_n, (NO_PROPOSER,) * len(cell_planes)
    )
    attempts_p, releases_p = cell_planes[:2]
    ext_p = cell_planes[2] if extend else None
    if sync:
        padded, owners, counts = lease_window_sync_pallas(
            padded, t0, attempts_p, releases_p, acc_up, pclk, aclk,
            majority=majority, lease_q4=lease_q4, n_proposers=P,
            guard_q4=guard_q4, block_n=block_n, window=window,
            interpret=interpret,
        )
        new_net = net
    else:
        net_p = _pad_net(net, block_n)
        rst_kw = (
            dict(acc_restart=arst, acc_deaf=deaf, prop_restart=prst,
                 prop_rc=rc)
            if restart else {}
        )
        padded, net_p, owners, counts = lease_window_delayed_pallas(
            padded, net_p, t0, attempts_p, releases_p, acc_up, pclk, aclk,
            link, extends=ext_p, stale=stale, equiv=equiv, **rst_kw,
            majority=majority, lease_q4=lease_q4, round_q4=round_q4,
            n_proposers=P, guard_q4=guard_q4, block_n=block_n,
            window=window, interpret=interpret, skip_stable=skip_stable,
        )
        new_net = NetPlaneState(*(a[:, :n] for a in net_p))
    new_state = unpack_state(
        PackedLeaseState(*(a[:, :n] for a in padded)), P
    )
    return new_state, new_net, owners[:, :n], counts[:, :n]


_window_scan_jit = functools.partial(
    jax.jit,
    static_argnames=(
        "majority", "lease_q4", "round_q4", "guard_q4", "backend", "sync",
        "block_n", "window", "restart_guard", "skip_stable",
    ),
)(_window_scan_impl)


#: "never got close" sentinel for the min-tracked margin components
MARGIN_BIG = 1 << 28

#: the margin components, in the order the scan carry holds them
MARGIN_NAMES = ("votes_gap", "tie_q4", "ghost_q4", "deaf_q4", "open_rounds")


def _margin_scan_impl(
    state: LeaseArrayState,
    net,
    t0,
    clk0,
    planes: dict,
    *,
    majority: int,
    lease_q4: int,
    round_q4: int,
    guard_q4: int,
    rst0=None,
    restart_guard: bool = True,
):
    """The delayed jnp scan with §4 boundary-proximity margins folded into
    the carry — the body of ``engine.sweep(collect="margins")``. Margins
    are whole-scenario int32 scalars reduced in-dispatch (never [T, N],
    let alone [B, T, N]):

      ``votes_gap``   min votes still missing for a *foreign* round to
                      reach a majority while another proposer's belief is
                      live — the ticks-to-second-believer proxy (0 ⇔ the
                      violating vote is already in flight);
      ``tie_q4``      min |owner expiry − owner local clock| in quarter-
                      ticks over ticks whose release names the live owner
                      — the guarded-expiry tie species (the PR 5 bug was
                      exactly tie_q4 = 0);
      ``ghost_q4``    min local quarter-ticks by which a majority-accepted
                      claim missed its own guarded timer (§3 step 5: the
                      ghost-lease guard refused the win; 1 = refused by a
                      single quarter-tick);
      ``deaf_q4``     min local quarter-ticks of deaf window left when a
                      post-restart deaf acceptor refused a due request
                      that would have completed a *foreign* quorum (one
                      vote short while another belief is live) — the
                      restart species' boundary distance (1 = the M-wait
                      saved §4 by a single quarter-tick);
      ``open_rounds`` max cells with a round open at once (contention).

    Min components start at ``MARGIN_BIG`` ("never got close"). Always
    the jnp oracle path of the delayed model — the backends are
    bit-identical by construction, so margins are backend-independent,
    and zero-delay planes are the sync special case bit-for-bit. Returns
    (owners [T, N], counts [T, N], margins dict of scalars).
    """
    P = state.n_proposers
    A, N = state.highest_promised.shape
    t0 = jnp.asarray(t0, jnp.int32)
    attempts = jnp.asarray(planes["attempts"], jnp.int32)
    releases = jnp.asarray(planes["releases"], jnp.int32)
    acc_up = jnp.asarray(planes["acc_up"], jnp.int32)
    T = attempts.shape[0]
    pclk, aclk = _local_clock_planes(t0, T, clk0, planes, P, A)
    packed = pack_state(state)
    link = pack_link(planes["delay"], planes["drop"])
    stale = planes.get("acc_stale")
    equiv = planes.get("acc_equiv")
    corrupt = stale is not None or equiv is not None
    if corrupt:
        za = jnp.zeros((T, A), jnp.int32)
        stale = za if stale is None else jnp.asarray(stale, jnp.int32)
        equiv = za if equiv is None else jnp.asarray(equiv, jnp.int32)
    ext = planes.get("extends")
    extend = ext is not None
    if extend:
        ext = jnp.asarray(ext, jnp.int32)
    arst = planes.get("acc_restart")
    prst = planes.get("prop_restart")
    restart = arst is not None or prst is not None or rst0 is not None
    if restart:
        arst = (
            jnp.zeros((T, A), jnp.int32) if arst is None
            else jnp.asarray(arst, jnp.int32)
        )
        prst = (
            jnp.zeros((T, P), jnp.int32) if prst is None
            else jnp.asarray(prst, jnp.int32)
        )
        rc, deaf, deaf_rem = _restart_planes(
            rst0, arst, prst, aclk, lease_q4, restart_guard
        )
    big = jnp.int32(MARGIN_BIG)

    def vote_count(bits):  # popcount over the A vote bits (compile-time A)
        n = bits & 1
        for a in range(1, A):
            n = n + ((bits >> a) & 1)
        return n

    def body(carry, xs):
        lease, netc, t, m = carry
        a, r, u, pc, ac, lk = xs[:6]
        i = 6
        adv = {}
        ext_row = None
        if extend:
            ext_row = xs[i][None, :]
            adv["extend"] = ext_row
            i += 1
        if corrupt:
            adv.update(stale=xs[i][:, None], equiv=xs[i + 1][:, None])
            i += 2
        if restart:
            adv.update(
                acc_restart=xs[i][:, None], acc_deaf=xs[i + 1][:, None],
                prop_restart=xs[i + 2][:, None], prop_rc=xs[i + 3][:, None],
            )
            deaf_rem_col = xs[i + 4][:, None]
        att_row, rel_row = a[None, :], r[None, :]
        pc_col = pc[:, None]
        # pre-tick: guarded-expiry tie distance at releases — and, in
        # extend mode, at extends — that name the live owner: its packed
        # expiry vs its local clock right now (an extend racing its own
        # guarded expiry is the §6 twin of the PR 5 release tie)
        own_id_pre, ownp_pre = lease[2], lease[3]
        own_clk = clock_select(pc_col, own_id_pre)
        names_owner = (
            (rel_row >= 0) & (own_id_pre == rel_row) & (ownp_pre > 0)
        )
        if extend:
            names_owner = names_owner | (
                (ext_row >= 0) & (own_id_pre == ext_row) & (ownp_pre > 0)
            )
        tie_clk_d = jnp.abs(packed_q4(ownp_pre) - own_clk)
        tie_q4 = jnp.min(jnp.where(names_owner, tie_clk_d, big))

        # pre-tick: deaf-window boundary distance — a due request at a deaf
        # acceptor, belonging to the open round, while that round is one
        # vote short of a foreign quorum: the refusal the M-wait exists
        # for. Margin = deaf quarter-ticks remaining on the acceptor's
        # clock when it refused.
        if restart:
            preq_pre, poreq_pre = netc[0], netc[3]
            rnd_ballot_pre = netc[6]
            live_min_pre = (QUARTERS * t + 1) << PACK_SHIFT
            req_due = lambda s: (s > 0) & (s < live_min_pre)
            round_req = (
                (req_due(preq_pre) & ((preq_pre & PACK_MASK) == rnd_ballot_pre))
                | (req_due(poreq_pre) & ((poreq_pre & PACK_MASK) == rnd_ballot_pre))
            )
            rnd_prop_pre = ballot_proposer(rnd_ballot_pre, P)
            foreign_pre = (
                (rnd_ballot_pre > 0) & (ownp_pre > 0)
                & (own_id_pre != rnd_prop_pre)
            )
            if extend:
                # extend mode: a deaf refusal of the owner's OWN extend
                # round (one vote short) is the §6 boundary — the extend
                # that almost completed before the M-wait swallowed it
                foreign_pre = foreign_pre | (
                    (rnd_ballot_pre > 0) & (ownp_pre > 0)
                    & (own_id_pre == rnd_prop_pre)
                )
            nv_pre = jnp.maximum(
                vote_count(netc[10]), vote_count(netc[11])
            )
            one_short = nv_pre == (majority - 1)
            saved = (
                (deaf_rem_col > 0) & round_req & foreign_pre & one_short
            )
            deaf_q4 = jnp.min(jnp.where(saved, deaf_rem_col, big))
        else:
            deaf_q4 = big

        lease, netc, count = delayed_tick_math(
            lease, netc, t, att_row, rel_row, u[:, None],
            pc_col, ac[:, None], lk,
            majority=majority, lease_q4=lease_q4, round_q4=round_q4,
            n_proposers=P, guard_q4=guard_q4, **adv,
        )

        # post-tick: contention gap + ghost-guard refusals still visible
        # in the round rows (a refused §3-step-5 claim leaves its round
        # R_PROPOSING with a majority of accept bits set)
        own_id, ownp = lease[2], lease[3]
        rnd_ballot, rnd_phase, rnd_expiry = netc[6], netc[7], netc[8]
        rnd_open_bits, rnd_acc_bits = netc[10], netc[11]
        rnd_prop = ballot_proposer(rnd_ballot, P)
        rnd_clk = clock_select(pc_col, rnd_prop)
        nvotes = jnp.maximum(
            vote_count(rnd_open_bits), vote_count(rnd_acc_bits)
        )
        contested = (rnd_ballot > 0) & (ownp > 0) & (own_id != rnd_prop)
        gap = jnp.maximum(majority - nvotes, 0)
        votes_gap = jnp.min(jnp.where(contested, gap, big))
        refused = (
            (rnd_ballot > 0) & (rnd_phase == R_PROPOSING)
            & (vote_count(rnd_acc_bits) >= majority)
        )
        ghost_clk_d = rnd_clk - rnd_expiry + 1
        ghost_q4 = jnp.min(jnp.where(refused, ghost_clk_d, big))
        open_rounds = jnp.sum((rnd_ballot > 0).astype(jnp.int32))
        m = (
            jnp.minimum(m[0], votes_gap),
            jnp.minimum(m[1], tie_q4),
            jnp.minimum(m[2], ghost_q4),
            jnp.minimum(m[3], deaf_q4),
            jnp.maximum(m[4], open_rounds),
        )
        return (lease, netc, t + 1, m), (lease[2], count)

    m0 = (big, big, big, big, jnp.int32(0))
    xs = (attempts, releases, acc_up, pclk, aclk, link)
    if extend:
        xs += (ext,)
    if corrupt:
        xs += (stale, equiv)
    if restart:
        xs += (arst, deaf, prst, rc, deaf_rem)
    (_, _, _, m), (owners, counts) = jax.lax.scan(
        body, (tuple(packed), tuple(net), t0, m0), xs
    )
    margins = dict(zip(MARGIN_NAMES, m))
    return owners.reshape(T, N), counts.reshape(T, N), margins


#: one-time flag: the traced-away skip below is a real coverage gap (the
#: guard silently not running), so the first occurrence per process warns
_WARNED_TRACED_SKIP = False


def _guard_pack_budget(
    t0, n_ticks, planes, *, n_proposers, lease_q4, sync, clk0=None,
    rst0=None,
):
    """Best-effort host-side overflow guard for the public entry points:
    a tick past ``state.max_pack_tick`` would silently corrupt the packed
    (deadline, ballot) fields, so refuse it here. Skipped when ``t0`` or
    any consulted plane is a tracer (a caller jitting over time owns the
    check, like ``engine.step`` does). Fast clocks shrink the budget: the
    rate planes' maximum step and any clock offsets already ahead of the
    rate-1 reading are both charged. Restart mode (any restart plane or a
    restart history) charges the ballot carve: the budget shrinks by
    RESTART_SHIFT bits plus the highest per-proposer restart count."""
    delay = None if sync else planes.get("delay")
    consulted = (t0, delay, planes.get("prop_rate"), planes.get("acc_rate"),
                 planes.get("acc_restart"), planes.get("prop_restart"))
    if clk0 is not None:
        consulted += tuple(clk0)
    if rst0 is not None:
        consulted += tuple(rst0)
    if any(isinstance(x, jax.core.Tracer) for x in consulted):
        global _WARNED_TRACED_SKIP
        if not _WARNED_TRACED_SKIP:
            _WARNED_TRACED_SKIP = True
            warnings.warn(
                "check_pack_budget skipped: the tick count or a consulted "
                "plane is a tracer, so the host-side overflow guard cannot "
                "run. The jitting caller owns the check — verify the "
                "config statically first (engine.run_trace/sweep do, via "
                "repro.analysis.staticcheck), or a replay past "
                "state.max_pack_tick will silently corrupt the packed "
                "fields.",
                RuntimeWarning, stacklevel=3,
            )
        return
    t0 = int(np.asarray(t0))
    max_delay = 0 if delay is None else int(np.asarray(delay).max(initial=0))
    max_rate = max(
        (
            int(np.asarray(planes[k]).max(initial=0))
            for k in ("prop_rate", "acc_rate") if planes.get(k) is not None
        ),
        default=QUARTERS,
    )
    max_rate = max(max_rate, QUARTERS)
    clk_slack = 0
    if clk0 is not None:
        clk_max = max(int(np.asarray(c).max(initial=0)) for c in clk0)
        clk_slack = max(0, clk_max - max_rate * t0)
    arst = planes.get("acc_restart")
    prst = planes.get("prop_restart")
    max_restarts = 0
    if arst is not None or prst is not None or rst0 is not None:
        rc_end = np.zeros(n_proposers, np.int64)
        if prst is not None:
            rc_end += np.asarray(prst, np.int64).reshape(
                -1, n_proposers).sum(axis=0)
        if rst0 is not None:
            rc_end += np.asarray(rst0[0], np.int64)
        # acc-only restart schedules still switch the ballot encoding, so
        # charge at least one carve slot
        max_restarts = max(1, int(rc_end.max(initial=0)))
    check_pack_budget(
        t0 + n_ticks, n_proposers, lease_q4, max_delay,
        max_rate=max_rate, clk_slack=clk_slack, max_restarts=max_restarts,
    )


def strip_default_planes(planes: dict) -> dict:
    """Drop optional fault planes sitting entirely at their registered
    default. All-default corruption/restart/extends planes ARE the honest
    engine, so stripping them host-side keeps the honest replay from
    compiling the fault variants — staticcheck's ``check_honest_strip``
    pins the resulting dispatch-jaxpr byte-identity. Tracers are never
    stripped (their values are unknown at trace time)."""
    return {
        k: v for k, v in planes.items()
        if not (
            k in CORRUPTION_PLANES + RESTART_PLANES + EXTEND_PLANES
            and not isinstance(v, jax.core.Tracer)
            and (np.asarray(v) == PLANES[k].default).all()
        )
    }


def lease_window_scan(
    state: LeaseArrayState,
    net,
    t0,
    planes: dict,
    *,
    majority: int,
    lease_q4: int,
    round_q4: int,
    guard_q4: int = None,
    clk0=None,
    rst0=None,
    restart_guard: bool = True,
    backend: str = "jnp",
    sync: bool = False,
    block_n: int = 512,
    window: int = 16,
    skip_stable: bool = True,
) -> tuple[LeaseArrayState, NetPlaneState, jax.Array, jax.Array]:
    """Replay a whole [T]-tick scenario-plane dict in ONE dispatch.

    ``sync=True`` runs the zero-delay synchronous model (``net`` passes
    through untouched; the planes' delay/drop entries are ignored);
    ``sync=False`` runs the delayed in-flight model. ``window`` is the
    number of ticks each Pallas kernel window keeps VMEM-resident per
    streamed plane slab (jnp ignores it). ``guard_q4`` is the proposer's
    drift-guarded own timespan (`state.guarded_lease_q4`; default: the
    full ``lease_q4``, the ε=0 case) and ``clk0`` the (prop [P], acc [A])
    accumulated local-clock offsets at ``t0`` (default: the rate-1
    reading ``4·t0`` on every node). ``rst0`` is the (restart-counter [P],
    deaf-until [A]) restart history at ``t0`` (None = fresh; its presence
    keeps restart mode on even for quiet planes); ``restart_guard=False``
    disables the post-restart deaf window — the §4 negative control.
    ``skip_stable=False`` disables the Pallas quiescence fast path (the
    A/B bench control; results are bit-identical either way).
    Returns (new_state, new_net, owners [T, N], owner_counts [T, N]).
    """
    if guard_q4 is None:
        guard_q4 = lease_q4
    planes = strip_default_planes(planes)
    _guard_pack_budget(
        t0, int(jnp.shape(planes["attempts"])[0]), planes,
        n_proposers=state.n_proposers, lease_q4=lease_q4, sync=sync,
        clk0=clk0, rst0=rst0,
    )
    return _window_scan_jit(
        state, net, t0, clk0, rst0, planes,
        majority=majority, lease_q4=lease_q4, round_q4=round_q4,
        guard_q4=guard_q4, backend=backend, sync=sync, block_n=block_n,
        window=window, restart_guard=restart_guard,
        skip_stable=skip_stable,
    )


def lease_plane_tick(
    state: LeaseArrayState,
    net: NetPlaneState,
    t,
    tick: TickInputs,
    *,
    majority: int,
    lease_q4: int,
    round_q4: int,
    guard_q4: int = None,
    clk0=None,
    rst0=None,
    restart_guard: bool = True,
    backend: str = "jnp",
    block_n: int = 512,
    sync: bool = False,
    window: int = 16,
    skip_stable: bool = True,
) -> tuple[LeaseArrayState, NetPlaneState, jax.Array]:
    """Advance all cells one tick.

    ``sync=True`` runs the zero-delay synchronous model (``net`` passes
    through untouched; the tick's delay/drop planes are ignored);
    ``sync=False`` runs the delayed in-flight model with the tick's
    ``[P, A]`` link matrices. ``guard_q4``/``clk0`` are the drift
    parameters (see :func:`lease_window_scan`); the tick's
    ``prop_rate``/``acc_rate`` planes advance the clocks *after* this
    tick's deadlines are evaluated, so a stateful caller carries
    ``clk0 + rate`` into the next tick (``engine.step`` does). backend:
    "jnp" (reference), "pallas" (kernel, interpret mode — runs anywhere),
    "pallas_tpu" (compiled kernel, real TPUs). Returns
    (new_state, new_net, owner_count[N]) — owner_count is the per-cell
    number of proposers who believe they own it (>1 would be a §4
    violation).
    """
    if guard_q4 is None:
        guard_q4 = lease_q4

    def _default_plane(k, v):
        # an all-DEFAULT_RATE rate plane is the in-graph default clock,
        # and an all-zero corruption/restart plane is the honest engine:
        # omit either from the dispatch dict (one fewer host->device
        # upload per step; the scan derives identical behavior
        # bit-for-bit). A restart history (rst0) pins the restart planes
        # in, so ballot encoding never switches mid-trace.
        if isinstance(v, jax.core.Tracer):
            return False
        if k in ("prop_rate", "acc_rate"):
            return bool((np.asarray(v) == QUARTERS).all())
        if k in CORRUPTION_PLANES:
            return not np.asarray(v).any()
        if k in EXTEND_PLANES:
            return bool((np.asarray(v) == PLANES[k].default).all())
        if k in RESTART_PLANES and rst0 is None:
            return not np.asarray(v).any()
        return False

    planes = {
        k: jnp.asarray(v)[None, ...] for k, v in tick.planes.items()
        if not _default_plane(k, v)
    }
    _guard_pack_budget(
        t, 1, tick.planes,
        n_proposers=state.n_proposers, lease_q4=lease_q4, sync=sync,
        clk0=clk0, rst0=rst0,
    )
    new_state, new_net, _, counts = _window_scan_jit(
        state, net, t, clk0, rst0, planes,
        majority=majority, lease_q4=lease_q4, round_q4=round_q4,
        guard_q4=guard_q4, backend=backend, sync=sync, block_n=block_n,
        window=window, restart_guard=restart_guard,
        skip_stable=skip_stable,
    )
    return new_state, new_net, counts[0]


# --------------------------------------------------------------------------
# deprecation shims: the pre-Scenario one-argument-per-fault-dimension API
# --------------------------------------------------------------------------
def _shim_tick(state: LeaseArrayState, attempt, release, acc_up, delay, drop):
    A, N = state.highest_promised.shape
    P = state.n_proposers
    if any(
        isinstance(x, jax.core.Tracer)
        for x in (attempt, release, acc_up, delay, drop)
    ):
        # the old step functions were jit-traceable; keep the shims so too —
        # coerce with jnp and skip the host-side validation make_tick does
        links = lambda m: (
            jnp.zeros((P, A), jnp.int32) if m is None else link_matrix(m, P, A)
        )
        return TickInputs({
            "attempts": (
                jnp.full((N,), NO_PROPOSER, jnp.int32) if attempt is None
                else jnp.asarray(attempt, jnp.int32)
            ),
            "releases": (
                jnp.full((N,), NO_PROPOSER, jnp.int32) if release is None
                else jnp.asarray(release, jnp.int32)
            ),
            "acc_up": (
                jnp.ones((A,), jnp.int32) if acc_up is None
                else jnp.asarray(acc_up).astype(jnp.int32)
            ),
            "delay": links(delay),
            "drop": links(drop),
        })
    return make_tick(
        n_cells=N, n_acceptors=A, n_proposers=P,
        attempts=attempt, releases=release, acc_up=acc_up,
        delay=delay, drop=drop,
    )


def lease_plane_step(
    state: LeaseArrayState,
    t,
    attempt,
    release,
    acc_up,
    *,
    majority: int,
    lease_q4: int,
    backend: str = "jnp",
    block_n: int = 512,
) -> tuple[LeaseArrayState, jax.Array]:
    """Deprecated: build a :class:`TickInputs` and call
    :func:`lease_plane_tick` with ``sync=True`` instead."""
    warnings.warn(
        "lease_plane_step is deprecated; use lease_plane_tick(state, net, "
        "t, tick, ..., sync=True) with a scenario.TickInputs",
        DeprecationWarning, stacklevel=2,
    )
    tick = _shim_tick(state, attempt, release, acc_up, None, None)
    new_state, _, count = lease_plane_tick(
        state, None, t, tick,
        majority=majority, lease_q4=lease_q4, round_q4=0,
        backend=backend, block_n=block_n, sync=True,
    )
    return new_state, count


def lease_plane_step_delayed(
    state: LeaseArrayState,
    net: NetPlaneState,
    t,
    attempt,
    release,
    acc_up,
    delay,     # [A] or [P, A] int32 delays (ticks) for legs sent this tick
    drop,      # [A] or [P, A] bool/int32 drop masks for legs sent this tick
    *,
    majority: int,
    lease_q4: int,
    round_q4: int,
    backend: str = "jnp",
    block_n: int = 512,
) -> tuple[LeaseArrayState, NetPlaneState, jax.Array]:
    """Deprecated: build a :class:`TickInputs` and call
    :func:`lease_plane_tick` instead."""
    warnings.warn(
        "lease_plane_step_delayed is deprecated; use lease_plane_tick with "
        "a scenario.TickInputs",
        DeprecationWarning, stacklevel=2,
    )
    tick = _shim_tick(state, attempt, release, acc_up, delay, drop)
    return lease_plane_tick(
        state, net, t, tick,
        majority=majority, lease_q4=lease_q4, round_q4=round_q4,
        backend=backend, block_n=block_n, sync=False,
    )
