"""jit'd public entry points for the lease plane: backend dispatch
(pure-jnp oracle vs fused Pallas kernel) plus cell-axis padding so callers
can use any N. Mirrors the kernels/flash_attention kernel/ops/ref layout.

One step: ``lease_plane_tick`` advances every cell one tick of either
network model — the synchronous zero-delay tick (``sync=True``, PR 1) or
the delayed in-flight message plane (multi-tick rounds, asymmetric
per-(proposer, acceptor) link delay/drop — see ``netplane.py``). Its
per-tick inputs are a :class:`~repro.lease_array.scenario.TickInputs`
pytree, so registering a new fault plane never changes this signature.

``lease_plane_step`` / ``lease_plane_step_delayed`` are deprecation shims
for the old one-positional-argument-per-fault-dimension API.
"""
from __future__ import annotations

import functools
import warnings

import jax
import jax.numpy as jnp

from .kernel import lease_tick_delayed_pallas, lease_tick_pallas
from .netplane import NetPlaneState
from .ref import lease_step_delayed_ref, lease_step_ref, link_matrix
from .scenario import TickInputs, make_tick
from .state import NO_PROPOSER, LeaseArrayState

BACKENDS = ("jnp", "pallas", "pallas_tpu")


def _pad_cells(state: LeaseArrayState, attempt, release, multiple: int):
    n = state.n_cells
    pad = (-n) % multiple
    if pad == 0:
        return state, attempt, release, n
    state = LeaseArrayState(*(
        jnp.pad(arr, ((0, 0), (0, pad))) for arr in state
    ))
    # padded cells never attempt, never release, never own anything
    attempt = jnp.pad(attempt, (0, pad), constant_values=NO_PROPOSER)
    release = jnp.pad(release, (0, pad), constant_values=NO_PROPOSER)
    return state, attempt, release, n


def _pad_net(net: NetPlaneState, multiple: int) -> NetPlaneState:
    pad = (-net.n_cells) % multiple
    if pad == 0:
        return net
    # zero padding = empty slots / no open round in the padded cells;
    # presp_pay's empty sentinel is NO_PROPOSER, matching init_netplane
    return NetPlaneState(*(
        jnp.pad(
            arr, ((0, 0), (0, pad)),
            constant_values=NO_PROPOSER if name == "presp_pay" else 0,
        )
        for name, arr in zip(NetPlaneState._fields, net)
    ))


@functools.partial(
    jax.jit,
    static_argnames=(
        "majority", "lease_q4", "round_q4", "backend", "block_n", "sync",
    ),
)
def lease_plane_tick(
    state: LeaseArrayState,
    net: NetPlaneState,
    t,
    tick: TickInputs,
    *,
    majority: int,
    lease_q4: int,
    round_q4: int,
    backend: str = "jnp",
    block_n: int = 512,
    sync: bool = False,
) -> tuple[LeaseArrayState, NetPlaneState, jax.Array]:
    """Advance all cells one tick.

    ``sync=True`` runs the zero-delay synchronous model (``net`` passes
    through untouched; the tick's delay/drop planes are ignored);
    ``sync=False`` runs the delayed in-flight model with the tick's
    ``[P, A]`` link matrices. backend: "jnp" (reference), "pallas"
    (kernel, interpret mode — runs anywhere), "pallas_tpu" (compiled
    kernel, real TPUs). Returns (new_state, new_net, owner_count[N]) —
    owner_count is the per-cell number of proposers who believe they own
    it (>1 would be a §4 violation).
    """
    if backend not in BACKENDS:
        raise ValueError(f"unknown lease-plane backend {backend!r}")
    t = jnp.asarray(t, jnp.int32)
    attempt = jnp.asarray(tick.attempts, jnp.int32)
    release = jnp.asarray(tick.releases, jnp.int32)
    acc_up = jnp.asarray(tick.acc_up, jnp.int32)
    if sync:
        if backend == "jnp":
            new_state, count = lease_step_ref(
                state, t, attempt, release, acc_up,
                majority=majority, lease_q4=lease_q4,
            )
            return new_state, net, count
        padded, attempt, release, n = _pad_cells(
            state, attempt, release, block_n
        )
        new_state, count = lease_tick_pallas(
            padded, t, attempt, release, acc_up,
            majority=majority, lease_q4=lease_q4,
            block_n=block_n, interpret=(backend == "pallas"),
        )
        if new_state.n_cells != n:
            new_state = LeaseArrayState(*(a[:, :n] for a in new_state))
            count = count[:n]
        return new_state, net, count
    delay = jnp.asarray(tick.delay, jnp.int32)
    drop = jnp.asarray(tick.drop, jnp.int32)
    if backend == "jnp":
        return lease_step_delayed_ref(
            state, net, t, attempt, release, acc_up, delay, drop,
            majority=majority, lease_q4=lease_q4, round_q4=round_q4,
        )
    padded, attempt, release, n = _pad_cells(state, attempt, release, block_n)
    net_p = _pad_net(net, block_n)
    new_state, new_net, count = lease_tick_delayed_pallas(
        padded, net_p, t, attempt, release, acc_up, delay, drop,
        majority=majority, lease_q4=lease_q4, round_q4=round_q4,
        block_n=block_n, interpret=(backend == "pallas"),
    )
    if new_state.n_cells != n:
        new_state = LeaseArrayState(*(a[:, :n] for a in new_state))
        new_net = NetPlaneState(*(a[:, :n] for a in new_net))
        count = count[:n]
    return new_state, new_net, count


# --------------------------------------------------------------------------
# deprecation shims: the pre-Scenario one-argument-per-fault-dimension API
# --------------------------------------------------------------------------
def _shim_tick(state: LeaseArrayState, attempt, release, acc_up, delay, drop):
    A, N = state.highest_promised.shape
    P = state.n_proposers
    if any(
        isinstance(x, jax.core.Tracer)
        for x in (attempt, release, acc_up, delay, drop)
    ):
        # the old step functions were jit-traceable; keep the shims so too —
        # coerce with jnp and skip the host-side validation make_tick does
        links = lambda m: (
            jnp.zeros((P, A), jnp.int32) if m is None else link_matrix(m, P, A)
        )
        return TickInputs({
            "attempts": (
                jnp.full((N,), NO_PROPOSER, jnp.int32) if attempt is None
                else jnp.asarray(attempt, jnp.int32)
            ),
            "releases": (
                jnp.full((N,), NO_PROPOSER, jnp.int32) if release is None
                else jnp.asarray(release, jnp.int32)
            ),
            "acc_up": (
                jnp.ones((A,), jnp.int32) if acc_up is None
                else jnp.asarray(acc_up).astype(jnp.int32)
            ),
            "delay": links(delay),
            "drop": links(drop),
        })
    return make_tick(
        n_cells=N, n_acceptors=A, n_proposers=P,
        attempts=attempt, releases=release, acc_up=acc_up,
        delay=delay, drop=drop,
    )


def lease_plane_step(
    state: LeaseArrayState,
    t,
    attempt,
    release,
    acc_up,
    *,
    majority: int,
    lease_q4: int,
    backend: str = "jnp",
    block_n: int = 512,
) -> tuple[LeaseArrayState, jax.Array]:
    """Deprecated: build a :class:`TickInputs` and call
    :func:`lease_plane_tick` with ``sync=True`` instead."""
    warnings.warn(
        "lease_plane_step is deprecated; use lease_plane_tick(state, net, "
        "t, tick, ..., sync=True) with a scenario.TickInputs",
        DeprecationWarning, stacklevel=2,
    )
    tick = _shim_tick(state, attempt, release, acc_up, None, None)
    new_state, _, count = lease_plane_tick(
        state, None, t, tick,
        majority=majority, lease_q4=lease_q4, round_q4=0,
        backend=backend, block_n=block_n, sync=True,
    )
    return new_state, count


def lease_plane_step_delayed(
    state: LeaseArrayState,
    net: NetPlaneState,
    t,
    attempt,
    release,
    acc_up,
    delay,     # [A] or [P, A] int32 delays (ticks) for legs sent this tick
    drop,      # [A] or [P, A] bool/int32 drop masks for legs sent this tick
    *,
    majority: int,
    lease_q4: int,
    round_q4: int,
    backend: str = "jnp",
    block_n: int = 512,
) -> tuple[LeaseArrayState, NetPlaneState, jax.Array]:
    """Deprecated: build a :class:`TickInputs` and call
    :func:`lease_plane_tick` instead."""
    warnings.warn(
        "lease_plane_step_delayed is deprecated; use lease_plane_tick with "
        "a scenario.TickInputs",
        DeprecationWarning, stacklevel=2,
    )
    tick = _shim_tick(state, attempt, release, acc_up, delay, drop)
    return lease_plane_tick(
        state, net, t, tick,
        majority=majority, lease_q4=lease_q4, round_q4=round_q4,
        backend=backend, block_n=block_n, sync=False,
    )
