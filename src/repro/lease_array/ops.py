"""jit'd public entry points for the lease plane: backend dispatch
(pure-jnp oracle vs fused Pallas kernel) plus cell-axis padding so callers
can use any N. Mirrors the kernels/flash_attention kernel/ops/ref layout.

Two steps: `lease_plane_step` (synchronous zero-delay tick, PR 1) and
`lease_plane_step_delayed` (in-flight message plane: multi-tick rounds,
per-acceptor delay/drop — see `netplane.py`)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import lease_tick_delayed_pallas, lease_tick_pallas
from .netplane import NetPlaneState
from .ref import lease_step_delayed_ref, lease_step_ref
from .state import NO_PROPOSER, LeaseArrayState

BACKENDS = ("jnp", "pallas", "pallas_tpu")


def _pad_cells(state: LeaseArrayState, attempt, release, multiple: int):
    n = state.n_cells
    pad = (-n) % multiple
    if pad == 0:
        return state, attempt, release, n
    state = LeaseArrayState(*(
        jnp.pad(arr, ((0, 0), (0, pad))) for arr in state
    ))
    # padded cells never attempt, never release, never own anything
    attempt = jnp.pad(attempt, (0, pad), constant_values=NO_PROPOSER)
    release = jnp.pad(release, (0, pad), constant_values=NO_PROPOSER)
    return state, attempt, release, n


def _pad_net(net: NetPlaneState, multiple: int) -> NetPlaneState:
    pad = (-net.n_cells) % multiple
    if pad == 0:
        return net
    # zero padding = empty slots / no open round in the padded cells;
    # presp_pay's empty sentinel is NO_PROPOSER, matching init_netplane
    return NetPlaneState(*(
        jnp.pad(
            arr, ((0, 0), (0, pad)),
            constant_values=NO_PROPOSER if name == "presp_pay" else 0,
        )
        for name, arr in zip(NetPlaneState._fields, net)
    ))


@functools.partial(
    jax.jit, static_argnames=("majority", "lease_q4", "backend", "block_n")
)
def lease_plane_step(
    state: LeaseArrayState,
    t,
    attempt,
    release,
    acc_up,
    *,
    majority: int,
    lease_q4: int,
    backend: str = "jnp",
    block_n: int = 512,
) -> tuple[LeaseArrayState, jax.Array]:
    """Advance all cells one synchronous tick.

    backend: "jnp" (reference), "pallas" (kernel, interpret mode — runs
    anywhere), "pallas_tpu" (compiled kernel, real TPUs).
    Returns (new_state, owner_count[N]) — owner_count is the per-cell number
    of proposers who believe they own it (>1 would be a §4 violation).
    """
    t = jnp.asarray(t, jnp.int32)
    attempt = jnp.asarray(attempt, jnp.int32)
    release = jnp.asarray(release, jnp.int32)
    if backend == "jnp":
        return lease_step_ref(
            state, t, attempt, release, acc_up,
            majority=majority, lease_q4=lease_q4,
        )
    if backend not in BACKENDS:
        raise ValueError(f"unknown lease-plane backend {backend!r}")
    padded, attempt, release, n = _pad_cells(state, attempt, release, block_n)
    new_state, count = lease_tick_pallas(
        padded, t, attempt, release, acc_up,
        majority=majority, lease_q4=lease_q4,
        block_n=block_n, interpret=(backend == "pallas"),
    )
    if new_state.n_cells != n:
        new_state = LeaseArrayState(*(a[:, :n] for a in new_state))
        count = count[:n]
    return new_state, count


@functools.partial(
    jax.jit,
    static_argnames=("majority", "lease_q4", "round_q4", "backend", "block_n"),
)
def lease_plane_step_delayed(
    state: LeaseArrayState,
    net: NetPlaneState,
    t,
    attempt,
    release,
    acc_up,
    delay,     # [A] int32 per-acceptor delay (ticks) for messages sent this tick
    drop,      # [A] bool/int32 per-acceptor drop mask for messages sent this tick
    *,
    majority: int,
    lease_q4: int,
    round_q4: int,
    backend: str = "jnp",
    block_n: int = 512,
) -> tuple[LeaseArrayState, NetPlaneState, jax.Array]:
    """Advance all cells one tick of the delayed (in-flight message) model.

    Same backends as `lease_plane_step`. Returns
    (new_state, new_net, owner_count[N]).
    """
    t = jnp.asarray(t, jnp.int32)
    attempt = jnp.asarray(attempt, jnp.int32)
    release = jnp.asarray(release, jnp.int32)
    delay = jnp.asarray(delay, jnp.int32)
    if backend == "jnp":
        return lease_step_delayed_ref(
            state, net, t, attempt, release, acc_up, delay, drop,
            majority=majority, lease_q4=lease_q4, round_q4=round_q4,
        )
    if backend not in BACKENDS:
        raise ValueError(f"unknown lease-plane backend {backend!r}")
    padded, attempt, release, n = _pad_cells(state, attempt, release, block_n)
    net_p = _pad_net(net, block_n)
    new_state, new_net, count = lease_tick_delayed_pallas(
        padded, net_p, t, attempt, release, acc_up, delay, drop,
        majority=majority, lease_q4=lease_q4, round_q4=round_q4,
        block_n=block_n, interpret=(backend == "pallas"),
    )
    if new_state.n_cells != n:
        new_state = LeaseArrayState(*(a[:, :n] for a in new_state))
        new_net = NetPlaneState(*(a[:, :n] for a in new_net))
        count = count[:n]
    return new_state, new_net, count
