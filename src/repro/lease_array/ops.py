"""jit'd public entry points for the lease plane: backend dispatch
(pure-jnp oracle vs fused Pallas kernel) plus cell-axis padding so callers
can use any N. Mirrors the kernels/flash_attention kernel/ops/ref layout."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import lease_tick_pallas
from .ref import lease_step_ref
from .state import NO_PROPOSER, LeaseArrayState

BACKENDS = ("jnp", "pallas", "pallas_tpu")


def _pad_cells(state: LeaseArrayState, attempt, release, multiple: int):
    n = state.n_cells
    pad = (-n) % multiple
    if pad == 0:
        return state, attempt, release, n
    state = LeaseArrayState(*(
        jnp.pad(arr, ((0, 0), (0, pad))) for arr in state
    ))
    # padded cells never attempt, never release, never own anything
    attempt = jnp.pad(attempt, (0, pad), constant_values=NO_PROPOSER)
    release = jnp.pad(release, (0, pad), constant_values=NO_PROPOSER)
    return state, attempt, release, n


@functools.partial(
    jax.jit, static_argnames=("majority", "lease_q4", "backend", "block_n")
)
def lease_plane_step(
    state: LeaseArrayState,
    t,
    attempt,
    release,
    acc_up,
    *,
    majority: int,
    lease_q4: int,
    backend: str = "jnp",
    block_n: int = 512,
) -> tuple[LeaseArrayState, jax.Array]:
    """Advance all cells one synchronous tick.

    backend: "jnp" (reference), "pallas" (kernel, interpret mode — runs
    anywhere), "pallas_tpu" (compiled kernel, real TPUs).
    Returns (new_state, owner_count[N]) — owner_count is the per-cell number
    of proposers who believe they own it (>1 would be a §4 violation).
    """
    t = jnp.asarray(t, jnp.int32)
    attempt = jnp.asarray(attempt, jnp.int32)
    release = jnp.asarray(release, jnp.int32)
    if backend == "jnp":
        return lease_step_ref(
            state, t, attempt, release, acc_up,
            majority=majority, lease_q4=lease_q4,
        )
    if backend not in BACKENDS:
        raise ValueError(f"unknown lease-plane backend {backend!r}")
    padded, attempt, release, n = _pad_cells(state, attempt, release, block_n)
    new_state, count = lease_tick_pallas(
        padded, t, attempt, release, acc_up,
        majority=majority, lease_q4=lease_q4,
        block_n=block_n, interpret=(backend == "pallas"),
    )
    if new_state.n_cells != n:
        new_state = LeaseArrayState(*(a[:, :n] for a in new_state))
        count = count[:n]
    return new_state, count
