"""Dense state of a vectorized lease plane: N independent PaxosLease cells
x A acceptors x P proposers as int32 arrays (§8: "leases for many resources").

Layout note: the ISSUE-level view is ``highest_promised[N, A]`` etc.; we
store the transpose ``[A, N]`` (and ``[P, N]`` for the proposer plane) so the
cell axis N lands on TPU lanes (128-wide) and the tiny acceptor/proposer axes
on sublanes — reductions over acceptors become cheap sublane reductions.

Time is integer *quarter-ticks*: protocol rounds run at integer ticks
(``t4 = 4*t``) while lease expiries land at ``t4 + 4*L + 1`` — strictly
between ticks, so "expired at tick boundary" is never ambiguous, and the
event-driven ``core/`` engine reproduces the exact same schedule with
``T = L + 0.25`` sim-seconds (see ``lease_array.trace``).

Ballot numbers are globally unique and totally ordered by (tick, proposer):
``ballot(t, p) = (t+1)*P + p`` — the array-plane analogue of the paper's
(run counter | proposer id) composition. 0 means "no ballot".
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

NO_PROPOSER = -1  # "no owner / no attempt" sentinel in proposer-id arrays
QUARTERS = 4  # quarter-ticks per tick


class LeaseArrayState(NamedTuple):
    """One lease plane. All arrays int32; see module docstring for layout."""

    highest_promised: jax.Array  # [A, N] highest promised ballot (0 = none)
    accepted_ballot: jax.Array   # [A, N] ballot of the accepted proposal (0 = none)
    accepted_proposer: jax.Array  # [A, N] proposer id of the accepted lease (-1 = none)
    lease_expiry: jax.Array      # [A, N] quarter-tick at which the accepted lease expires
    owner_mask: jax.Array        # [P, N] 1 where proposer p believes it owns cell n
    owner_expiry: jax.Array      # [P, N] quarter-tick at which that belief expires
    owner_ballot: jax.Array      # [P, N] ballot the ownership was won under

    @property
    def n_acceptors(self) -> int:
        return self.highest_promised.shape[0]

    @property
    def n_proposers(self) -> int:
        return self.owner_mask.shape[0]

    @property
    def n_cells(self) -> int:
        return self.highest_promised.shape[1]


def init_state(n_cells: int, n_acceptors: int, n_proposers: int) -> LeaseArrayState:
    za = jnp.zeros((n_acceptors, n_cells), jnp.int32)
    zp = jnp.zeros((n_proposers, n_cells), jnp.int32)
    return LeaseArrayState(
        highest_promised=za,
        accepted_ballot=za,
        accepted_proposer=jnp.full_like(za, NO_PROPOSER),
        lease_expiry=za,
        owner_mask=zp,
        owner_expiry=zp,
        owner_ballot=zp,
    )


def lease_quarters(lease_ticks: int) -> int:
    """Lease timespan in quarter-ticks: L ticks + 1 quarter (see docstring)."""
    return QUARTERS * int(lease_ticks) + 1


def ballot_of(t, proposer, n_proposers: int):
    """Globally unique ballot for an attempt by ``proposer`` at tick ``t``."""
    return (t + 1) * n_proposers + proposer
