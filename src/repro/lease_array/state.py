"""Dense state of a vectorized lease plane: N independent PaxosLease cells
x A acceptors x P proposers as int32 arrays (§8: "leases for many resources").

Layout note: the ISSUE-level view is ``highest_promised[N, A]`` etc.; we
store the transpose ``[A, N]`` (and ``[P, N]`` for the proposer plane) so the
cell axis N lands on TPU lanes (128-wide) and the tiny acceptor/proposer axes
on sublanes — reductions over acceptors become cheap sublane reductions.

Time is integer *quarter-ticks*: protocol rounds run at integer ticks
(``t4 = 4*t``) while lease expiries land at ``t4 + 4*L + 1`` — strictly
between ticks, so "expired at tick boundary" is never ambiguous, and the
event-driven ``core/`` engine reproduces the exact same schedule with
``T = L + 0.25`` sim-seconds (see ``lease_array.trace``).

Ballot numbers are globally unique and totally ordered by (tick, proposer):
``ballot(t, p) = (t+1)*P + p`` — the array-plane analogue of the paper's
(run counter | proposer id) composition. 0 means "no ballot".

Packed compute layout (PR 4): every hot path runs on a *packed* view of
this state in which each (deadline-quarter-tick, ballot) pair lives in ONE
int32 — ``packed = q4 << PACK_SHIFT | ballot`` — so liveness is a single
compare (``packed >= (t4+1) << PACK_SHIFT``) on a single plane, and the
at-most-one-owner §4 invariant lets the three ``[P, N]`` owner planes
collapse to an ``owner_id``/``owner_lease`` pair of ``[1, N]`` rows (a
would-be second believer is surfaced as an owner-count of 2 at the tick it
appears — see ``ref.sync_tick_math``). ``LeaseArrayState`` stays the
public at-rest format; ``pack_state``/``unpack_state`` convert at the
boundary of every jitted driver. The packing budget bounds the clock:
``ballot <= PACK_MASK`` and ``q4 <= MAX_PACK_Q4`` (see ``max_pack_tick``).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

NO_PROPOSER = -1  # "no owner / no attempt" sentinel in proposer-id arrays
QUARTERS = 4  # quarter-ticks per tick

#: a drift-free local clock advances QUARTERS local quarter-ticks per global
#: tick; a drifted node's rate plane holds its own integer step instead
DEFAULT_RATE = QUARTERS

PACK_SHIFT = 15  # low bits: ballot; high bits: a quarter-tick deadline
PACK_MASK = (1 << PACK_SHIFT) - 1  # max packable ballot (32767)
MAX_PACK_Q4 = (2**31 - 1) >> PACK_SHIFT  # max packable quarter-tick (65535)

#: restart-mode ballot carve (diskless proposer restarts, paper §2): the
#: ballot's run field is shifted left by RESTART_SHIFT and the low bits of
#: the upper word hold a per-proposer restart counter, mirroring the event
#: engine's ``core.ballot.Ballot`` (run, restart, proposer) lexicographic
#: order numerically: ``ballot = (((t+1) << RESTART_SHIFT) | rc) * P + p``.
#: The carve spends ballot-budget bits, so ``max_pack_tick`` shrinks in
#: restart mode — see its ``max_restarts=`` term.
RESTART_SHIFT = 2
MAX_RESTARTS = (1 << RESTART_SHIFT) - 1  # restart counters must stay below the carve


class LeaseArrayState(NamedTuple):
    """One lease plane. All arrays int32; see module docstring for layout."""

    highest_promised: jax.Array  # [A, N] highest promised ballot (0 = none)
    accepted_ballot: jax.Array   # [A, N] ballot of the accepted proposal (0 = none)
    accepted_proposer: jax.Array  # [A, N] proposer id of the accepted lease (-1 = none)
    lease_expiry: jax.Array      # [A, N] LOCAL quarter-tick (on acceptor a's clock) at which the accepted lease expires
    owner_mask: jax.Array        # [P, N] 1 where proposer p believes it owns cell n
    owner_expiry: jax.Array      # [P, N] LOCAL quarter-tick (on proposer p's clock) at which that belief expires
    owner_ballot: jax.Array      # [P, N] ballot the ownership was won under

    @property
    def n_acceptors(self) -> int:
        return self.highest_promised.shape[0]

    @property
    def n_proposers(self) -> int:
        return self.owner_mask.shape[0]

    @property
    def n_cells(self) -> int:
        return self.highest_promised.shape[1]


def init_state(n_cells: int, n_acceptors: int, n_proposers: int) -> LeaseArrayState:
    za = jnp.zeros((n_acceptors, n_cells), jnp.int32)
    zp = jnp.zeros((n_proposers, n_cells), jnp.int32)
    return LeaseArrayState(
        highest_promised=za,
        accepted_ballot=za,
        accepted_proposer=jnp.full_like(za, NO_PROPOSER),
        lease_expiry=za,
        owner_mask=zp,
        owner_expiry=zp,
        owner_ballot=zp,
    )


def lease_quarters(lease_ticks: int) -> int:
    """Lease timespan in quarter-ticks: L ticks + 1 quarter (see docstring)."""
    return QUARTERS * int(lease_ticks) + 1


def guarded_lease_q4(lease_q4: int, drift_eps: float) -> int:
    """The §4 drift guard on the packed time base: the proposer's own lease
    timer, discounted to T·(1-ε)/(1+ε) (DESIGN.md; `core.proposer.
    Proposer._guarded_timespan` is the float original) and floored to a
    whole local quarter-tick. Flooring only ever *shortens* the proposer's
    belief, so the discount stays safe after quantization: with every
    clock rate within [1-ε, 1+ε], a slow proposer's guarded timer still
    ends (in global time) before a fast acceptor's full timer does.
    ε = 0 is the exact no-drift degenerate case (no discount at all)."""
    if not 0.0 <= drift_eps < 1.0:
        raise ValueError(f"drift_eps must be in [0, 1); got {drift_eps}")
    if drift_eps == 0.0:
        return int(lease_q4)
    guarded = int(lease_q4 * (1.0 - drift_eps) / (1.0 + drift_eps))
    if guarded < 1:
        raise ValueError(
            f"the drift discount collapses a {lease_q4}-quarter lease to "
            f"{guarded} quarter-ticks at eps={drift_eps}: the proposer "
            f"could never believe it owns; lengthen the lease or lower eps"
        )
    return guarded


def rate1_clock(t, rows: int) -> jax.Array:
    """``[rows]`` int32: the drift-free local-clock reading ``4t`` on
    every node — THE default-clock definition, shared by the fused scan's
    clk0 fallback (ops), the per-tick scanner's carry seed (engine) and
    the public per-tick wrappers (ref)."""
    t4 = QUARTERS * jnp.asarray(t, jnp.int32)
    return jnp.broadcast_to(t4, (rows,))


def clock_select(clk, ids):
    """Per-cell local-clock gather: ``clk`` is a per-proposer clock column
    ``[P, 1]`` (local quarter-ticks), ``ids`` a proposer-id row ``[1, bn]``;
    returns each cell's named proposer's clock reading ``[1, bn]``.

    A compile-time P-loop of selects — block-local, no dynamic gather, so
    the SAME code runs inside the Pallas window kernel and under XLA (cf.
    ``netplane.legs_select``). Out-of-range ids (the NO_PROPOSER sentinel)
    read 0; every use is gated by its own ballot/owner mask."""
    P = clk.shape[0]
    v = jnp.zeros(ids.shape, clk.dtype)
    for p in range(P):
        v = jnp.where(ids == p, clk[p], v)
    return v


def ballot_of(t, proposer, n_proposers: int, restart_counter=None):
    """Globally unique ballot for an attempt by ``proposer`` at tick ``t``.

    With ``restart_counter`` (restart mode) the run field is carved as
    ``(t+1) << RESTART_SHIFT | rc`` so numeric order equals the event
    engine's (run, restart, proposer) lexicographic ``Ballot`` order; the
    proposer stays the low mod-P field either way, so ``ballot_proposer``
    needs no mode switch."""
    if restart_counter is None:
        return (t + 1) * n_proposers + proposer
    upper = ((t + 1) << RESTART_SHIFT) | restart_counter
    return upper * n_proposers + proposer


# ---------------------------------------------------------------------------
# packed compute layout
# ---------------------------------------------------------------------------
def pack_pair(q4, ballot):
    """One int32 carrying (deadline quarter-tick, ballot); 0 = empty."""
    return (q4 << PACK_SHIFT) | ballot


def ballot_proposer(ballot, n_proposers: int):
    """The proposer a ballot belongs to (``ballot % P``, strength-reduced
    to a mask when P is a power of two — ballots are nonnegative)."""
    if n_proposers & (n_proposers - 1) == 0:
        return ballot & (n_proposers - 1)
    return ballot % n_proposers


def packed_ballot(packed):
    return packed & PACK_MASK


def packed_q4(packed):
    return packed >> PACK_SHIFT


def max_pack_tick(
    n_proposers: int,
    lease_q4: int,
    max_delay_ticks: int = 0,
    max_rate: int = QUARTERS,
    clk_slack: int = 0,
    max_restarts: int = 0,
) -> int:
    """Highest tick the packed layout can represent: the last attempt's
    ballot must fit in PACK_SHIFT bits and the latest deadline any tick can
    mint (send at t4 + delay, then a full lease) in the remaining bits.

    With drifting clocks node deadlines live in *local* quarter-ticks,
    which a fast clock mints at up to ``max_rate`` per tick; ``clk_slack``
    is how far ahead of ``max_rate * t`` an engine's accumulated clocks
    already run (0 for a fresh engine).

    ``max_restarts > 0`` switches to the restart-mode ballot carve (see
    RESTART_SHIFT): the run field loses RESTART_SHIFT bits of headroom to
    the restart counter, so the tick budget shrinks by ~4x."""
    upper_budget = (PACK_MASK - (n_proposers - 1)) // n_proposers
    if max_restarts:
        by_ballot = ((upper_budget - int(max_restarts)) >> RESTART_SHIFT) - 1
    else:
        by_ballot = upper_budget - 1
    rate = max(int(max_rate), QUARTERS)  # deliver-at slots tick at QUARTERS
    by_q4 = (
        MAX_PACK_Q4 - lease_q4 - QUARTERS * max_delay_ticks - int(clk_slack)
    ) // rate
    return min(by_ballot, by_q4)


def check_pack_budget(
    t_end: int,
    n_proposers: int,
    lease_q4: int,
    max_delay_ticks: int = 0,
    max_rate: int = QUARTERS,
    clk_slack: int = 0,
    max_restarts: int = 0,
) -> None:
    """Raise if ticking through ``t_end`` would overflow the packed layout
    (a ballot or deadline minted past :func:`max_pack_tick` silently
    corrupts neighbouring fields — never let one form)."""
    if max_restarts > MAX_RESTARTS:
        raise ValueError(
            f"{max_restarts} restarts of one proposer exceed the "
            f"{RESTART_SHIFT}-bit restart-counter carve (max {MAX_RESTARTS}); "
            f"split the schedule across engine epochs"
        )
    limit = max_pack_tick(
        n_proposers, lease_q4, max_delay_ticks, max_rate, clk_slack,
        max_restarts,
    )
    if t_end > limit:
        raise ValueError(
            f"tick {t_end} exceeds the packed int32 layout's budget "
            f"({limit} ticks at P={n_proposers}, lease_q4={lease_q4}, "
            f"max delay {max_delay_ticks}, max clock rate {max_rate}/4, "
            f"max restarts {max_restarts}); "
            f"split the workload across engines or shorten the trace"
        )


class PackedLeaseState(NamedTuple):
    """The compute-format lease plane (see module docstring). All int32.

    ``acc_lease`` packs the accepted (expiry, ballot) pair; the accepted
    proposer is derived (``ballot % P``), not stored. The owner plane is a
    single believed-owner row — legal PaxosLease histories never hold two
    concurrent beliefs (§4), and the tick math flags the overwrite if an
    illegal history ever would.
    """

    promised: jax.Array     # [A, N] highest promised ballot (0 = none)
    acc_lease: jax.Array    # [A, N] expiry_q4 << PACK_SHIFT | ballot (0 = none)
    owner_id: jax.Array     # [1, N] believed owner (-1 = none)
    owner_lease: jax.Array  # [1, N] expiry_q4 << PACK_SHIFT | ballot (0 = none)


def pack_state(state: LeaseArrayState) -> PackedLeaseState:
    """Public -> compute format. With >1 owner bit per cell (an illegal
    state) the highest proposer id wins, like ``ref.owner_row``."""
    acc_on = state.accepted_ballot > 0
    acc_lease = jnp.where(
        acc_on, pack_pair(state.lease_expiry, state.accepted_ballot), 0
    )
    p_ids = jax.lax.broadcasted_iota(jnp.int32, state.owner_mask.shape, 0)
    own = state.owner_mask > 0
    owner_id = jnp.max(
        jnp.where(own, p_ids, NO_PROPOSER), axis=0, keepdims=True
    )
    top = own & (p_ids == owner_id)
    owner_lease = jnp.sum(
        jnp.where(top, pack_pair(state.owner_expiry, state.owner_ballot), 0),
        axis=0, keepdims=True,
    )
    return PackedLeaseState(
        promised=state.highest_promised,
        acc_lease=acc_lease.astype(jnp.int32),
        owner_id=owner_id.astype(jnp.int32),
        owner_lease=owner_lease.astype(jnp.int32),
    )


def unpack_state(packed: PackedLeaseState, n_proposers: int) -> LeaseArrayState:
    """Compute -> public format (acc_prop rederived as ``ballot % P``)."""
    acc_b = packed_ballot(packed.acc_lease)
    acc_on = acc_b > 0
    shape_p = (n_proposers, packed.promised.shape[1])
    p_ids = jax.lax.broadcasted_iota(jnp.int32, shape_p, 0)
    own = (p_ids == packed.owner_id) & (packed.owner_lease > 0)
    return LeaseArrayState(
        highest_promised=packed.promised,
        accepted_ballot=acc_b,
        accepted_proposer=jnp.where(
            acc_on, ballot_proposer(acc_b, n_proposers), NO_PROPOSER
        ),
        lease_expiry=jnp.where(acc_on, packed_q4(packed.acc_lease), 0),
        owner_mask=own.astype(jnp.int32),
        owner_expiry=jnp.where(own, packed_q4(packed.owner_lease), 0),
        owner_ballot=jnp.where(own, packed_ballot(packed.owner_lease), 0),
    )
