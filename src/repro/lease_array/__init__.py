"""Vectorized lease plane (§8: PaxosLease for many resources).

N independent PaxosLease cells x A acceptors x P proposers as dense int32
arrays, advanced in lockstep one tick at a time.

Every fault dimension is a named plane in one declarative **Scenario**
pytree (``scenario.py``): proposer attempts/releases ``[T, N]``, acceptor
reachability ``[T, A]``, asymmetric per-(proposer, acceptor) link
delay/drop matrices ``[T, P, A]`` (the symmetric ``[T, A]`` form
broadcasts), and per-node clock-rate planes ``prop_rate [T, P]`` /
``acc_rate [T, A]`` — §4's "no synchronized clocks" as data: every
node-side timer runs in that node's accumulated local time, proposers
discount their own timer by T·(1-ε)/(1+ε) (``drift_eps``), and the
differential referee replays drifted traces bit-exactly against the
event sim's ``NodeClock``. The engine consumes a ``Scenario`` whole (``run_trace``) or
one ``TickInputs`` slice at a time (``step``); registering a new fault
plane (``register_plane``) extends the schema without changing any
signature — the §1 failure model ("delayed, reordered, lost, crash and
restart") as a registry, not an argument list.

Two network models share one scanner: the synchronous zero-delay tick
(a whole prepare/propose round resolves in one tick) and the delayed
*in-flight message plane* (``netplane.py``): dense per-phase
request/response slot arrays — plus §7 release discards riding the same
slots — so rounds span multiple ticks and any leg can arrive late, be
lost, or land after its round was abandoned. Zero-delay scenarios are
bit-identical across the two models and both backends.

  scenario.py — the Scenario/TickInputs pytrees + the plane registry
  state.py    — array layout, quarter-tick time base, (tick, proposer)
                ballots, and the packed int32 compute format (one int per
                (deadline, ballot) pair; see docs/perf.md)
  netplane.py — in-flight message + proposer round planes, shared tick math
  ref.py      — pure-jnp tick bodies on the packed layout (sync + delayed)
  kernel.py   — time-resident fused Pallas window kernels: a whole [T]
                scenario in ONE launch, state VMEM-resident across windows
  ops.py      — jit'd dispatch (jnp | pallas interpret | pallas TPU),
                padding, and the fused lease_window_scan entry point
  engine.py   — stateful driver: per-tick step, the fused (and, with >1
                device, cell-sharded) run_trace, and the batched
                scenario-sweep dispatch (engine.sweep)
  trace.py    — fault/timing traces + the event-sim differential referee
                (per-link message timing pinned onto sim.network.Network)
  directory.py— shard-ownership directory on top (cluster/shards.py fast path)

See docs/scenario_api.md for the migration table from the legacy
one-kwarg-per-fault-dimension API (kept as deprecation shims).
"""
from .engine import LeaseArrayEngine, SweepResult
from .netplane import NetPlaneState, init_netplane, pack_link, pack_slot
from .ops import (
    lease_plane_step,
    lease_plane_step_delayed,
    lease_plane_tick,
    lease_window_scan,
)
from .scenario import (
    PLANES,
    PlaneSpec,
    Scenario,
    TickInputs,
    make_tick,
    register_plane,
)
from .state import (
    DEFAULT_RATE,
    NO_PROPOSER,
    LeaseArrayState,
    PackedLeaseState,
    ballot_of,
    guarded_lease_q4,
    init_state,
    lease_quarters,
    max_pack_tick,
    pack_state,
    unpack_state,
)
from .trace import Trace, random_trace, replay_array, replay_event_sim

__all__ = [
    "DEFAULT_RATE",
    "LeaseArrayEngine",
    "LeaseArrayState",
    "NO_PROPOSER",
    "NetPlaneState",
    "PLANES",
    "PackedLeaseState",
    "PlaneSpec",
    "Scenario",
    "SweepResult",
    "TickInputs",
    "Trace",
    "ballot_of",
    "guarded_lease_q4",
    "init_netplane",
    "init_state",
    "lease_plane_step",
    "lease_plane_step_delayed",
    "lease_plane_tick",
    "lease_quarters",
    "lease_window_scan",
    "make_tick",
    "max_pack_tick",
    "pack_link",
    "pack_slot",
    "pack_state",
    "random_trace",
    "register_plane",
    "replay_array",
    "replay_event_sim",
    "unpack_state",
]
