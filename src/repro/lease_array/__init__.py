"""Vectorized lease plane (§8: PaxosLease for many resources).

N independent PaxosLease cells x A acceptors x P proposers as dense int32
arrays, advanced in lockstep one tick at a time — under two network
models: the synchronous zero-delay tick (a whole prepare/propose round
resolves in one tick) and the delayed *in-flight message plane*
(`netplane.py`): dense per-phase request/response arrays with per-tick
per-acceptor delay and drop schedules, so rounds span multiple ticks and
responses arrive late, get lost, or land after the proposer abandoned the
round — the §1 failure model, at array scale.

  state.py    — array layout, quarter-tick time base, (tick, proposer) ballots
  netplane.py — in-flight message + proposer round planes, shared tick math
  ref.py      — pure-jnp oracles for one tick (sync + delayed)
  kernel.py   — fused Pallas kernels (one VMEM pass per tick, both models)
  ops.py      — jit'd dispatch (jnp | pallas interpret | pallas TPU) + padding
  engine.py   — stateful driver: per-tick step and lax.scan trace runners
  trace.py    — fault/timing/delay/drop traces + the event-sim differential
                referee (message timing pinned onto sim.network.Network)
  directory.py— shard-ownership directory on top (cluster/shards.py fast path)
"""
from .engine import LeaseArrayEngine
from .netplane import NetPlaneState, init_netplane
from .ops import lease_plane_step, lease_plane_step_delayed
from .state import NO_PROPOSER, LeaseArrayState, ballot_of, init_state, lease_quarters
from .trace import Trace, random_trace, replay_array, replay_event_sim

__all__ = [
    "LeaseArrayEngine",
    "LeaseArrayState",
    "NO_PROPOSER",
    "NetPlaneState",
    "Trace",
    "ballot_of",
    "init_netplane",
    "init_state",
    "lease_plane_step",
    "lease_plane_step_delayed",
    "lease_quarters",
    "random_trace",
    "replay_array",
    "replay_event_sim",
]
