"""Vectorized lease plane (§8: PaxosLease for many resources).

N independent PaxosLease cells x A acceptors x P proposers as dense int32
arrays, advanced in lockstep one synchronous tick at a time:

  state.py    — array layout, quarter-tick time base, (tick, proposer) ballots
  ref.py      — pure-jnp oracle for one tick
  kernel.py   — fused Pallas kernel (expiry+release+prepare+quorum+propose)
  ops.py      — jit'd dispatch (jnp | pallas interpret | pallas TPU) + padding
  engine.py   — stateful driver: per-tick step and lax.scan trace runner
  trace.py    — fault/timing traces + the event-sim differential referee
  directory.py— shard-ownership directory on top (cluster/shards.py fast path)
"""
from .engine import LeaseArrayEngine
from .ops import lease_plane_step
from .state import NO_PROPOSER, LeaseArrayState, ballot_of, init_state, lease_quarters
from .trace import Trace, random_trace, replay_array, replay_event_sim

__all__ = [
    "LeaseArrayEngine",
    "LeaseArrayState",
    "NO_PROPOSER",
    "Trace",
    "ballot_of",
    "init_state",
    "lease_plane_step",
    "lease_quarters",
    "random_trace",
    "replay_array",
    "replay_event_sim",
]
