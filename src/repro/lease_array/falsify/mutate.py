"""Structure-aware mutation operators over stacked scenario planes.

A population is the ``Scenario.stack`` form: a dict of ``[B, T, ...]``
int32 numpy planes. Every operator edits ONE structural feature of each
assigned member — move an attempt by a tick, nudge one node's clock rate,
drop one leg of a quorum — rather than resampling noise, so offspring
stay in the neighborhood their parent's margin score was earned in.

All operators are vectorized over the members they are assigned to
(fancy-indexed writes, no per-member Python loop: mutation must not be
the bottleneck of a million-scenario search) and are **closed under
``Scenario.validate``**: writes are clipped to each plane's registered
floors (delays >= 0, clock rates >= 1 via ``MutationSpace.rate_lo``),
proposer ids stay in ``[-1, P)``, masks stay 0/1. Determinism: the only
randomness is the caller's ``np.random.Generator`` — one seed, one
mutant batch, bit-for-bit.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from ..scenario import CORRUPTION_PLANES, EXTEND_PLANES, RESTART_PLANES
from ..state import DEFAULT_RATE, MAX_RESTARTS, NO_PROPOSER

__all__ = ["MUTATION_OPS", "MutationSpace", "mutate"]


@dataclass(frozen=True)
class MutationSpace:
    """The bounds mutants must stay inside: the scenario geometry plus the
    fault-plane ranges the search explores. ``rate_lo >= 1`` and
    ``delay_hi >= 0`` keep every operator closed under
    ``Scenario.validate`` (the registry's ``min_value`` floors)."""

    n_ticks: int
    n_cells: int
    n_acceptors: int
    n_proposers: int
    delay_hi: int = 2      # per-leg delay ceiling (whole ticks)
    rate_lo: int = 3       # clock-rate floor (>= 1; 3..5 bounds eps=0.25)
    rate_hi: int = 5       # clock-rate ceiling
    corrupt: bool = False  # also mutate the acc_stale/acc_equiv planes
    restart: bool = False  # also mutate the acc_restart/prop_restart planes
    extend: bool = False   # also mutate the §6 extends plane
    #: per-proposer restart ceiling (the packed ballot's RESTART_SHIFT
    #: carve); crash inserts that would overflow it are dropped, keeping
    #: every mutant inside check_pack_budget's refusal boundary
    max_restarts: int = MAX_RESTARTS
    lease_ticks: int = 2   # M in whole ticks — the deaf-boundary reach

    def op_names(self) -> tuple[str, ...]:
        cor, rst = set(CORRUPTION_PLANES), set(RESTART_PLANES)
        ext = set(EXTEND_PLANES)
        names = tuple(
            n for n, (_, planes) in MUTATION_OPS.items()
            if not set(planes) & (cor | rst | ext)
        )
        if self.corrupt:
            names += tuple(
                n for n, (_, planes) in MUTATION_OPS.items()
                if set(planes) & cor
            )
        if self.restart:
            names += tuple(
                n for n, (_, planes) in MUTATION_OPS.items()
                if set(planes) & rst
            )
        if self.extend:
            names += tuple(
                n for n, (_, planes) in MUTATION_OPS.items()
                if set(planes) & ext
            )
        return names


def _coords(rng: np.random.Generator, b: np.ndarray, *sizes: int):
    """One random coordinate per member of ``b`` along each extra axis."""
    return tuple(rng.integers(0, s, b.size) for s in sizes)


# every operator: fn(planes, b, rng, space) mutating planes in place for
# the member indices ``b`` (planes are already this generation's copies)
def _op_shift_attempt(planes, b, rng, sp):
    """Move one cell's attempt by ±1 tick (the classic delivery nudge)."""
    t, n = _coords(rng, b, sp.n_ticks, sp.n_cells)
    t2 = np.clip(t + rng.choice((-1, 1), b.size), 0, sp.n_ticks - 1)
    a = planes["attempts"]
    v = a[b, t, n].copy()
    a[b, t, n] = NO_PROPOSER
    a[b, t2, n] = v


def _op_flip_attempt(planes, b, rng, sp):
    """Retarget one (tick, cell) attempt slot: new proposer id or none."""
    t, n = _coords(rng, b, sp.n_ticks, sp.n_cells)
    planes["attempts"][b, t, n] = rng.integers(
        NO_PROPOSER, sp.n_proposers, b.size
    )


def _op_flip_release(planes, b, rng, sp):
    """Retarget one (tick, cell) release slot: new proposer id or none."""
    t, n = _coords(rng, b, sp.n_ticks, sp.n_cells)
    planes["releases"][b, t, n] = rng.integers(
        NO_PROPOSER, sp.n_proposers, b.size
    )


def _op_nudge_prop_rate(planes, b, rng, sp):
    """±1 quarter-tick on one proposer's clock step at one tick."""
    t, p = _coords(rng, b, sp.n_ticks, sp.n_proposers)
    r = planes["prop_rate"]
    r[b, t, p] = np.clip(
        r[b, t, p] + rng.choice((-1, 1), b.size), sp.rate_lo, sp.rate_hi
    )


def _op_nudge_acc_rate(planes, b, rng, sp):
    """±1 quarter-tick on one acceptor's clock step at one tick."""
    t, a = _coords(rng, b, sp.n_ticks, sp.n_acceptors)
    r = planes["acc_rate"]
    r[b, t, a] = np.clip(
        r[b, t, a] + rng.choice((-1, 1), b.size), sp.rate_lo, sp.rate_hi
    )


def _op_shift_delay(planes, b, rng, sp):
    """±1 tick on one (tick, proposer, acceptor) link leg's delay."""
    t, p, a = _coords(rng, b, sp.n_ticks, sp.n_proposers, sp.n_acceptors)
    d = planes["delay"]
    d[b, t, p, a] = np.clip(
        d[b, t, p, a] + rng.choice((-1, 1), b.size), 0, sp.delay_hi
    )


def _op_drop_leg(planes, b, rng, sp):
    """Toggle loss of one (tick, proposer, acceptor) link leg — drop (or
    restore) one leg of a quorum."""
    t, p, a = _coords(rng, b, sp.n_ticks, sp.n_proposers, sp.n_acceptors)
    d = planes["drop"]
    d[b, t, p, a] = 1 - d[b, t, p, a]


def _op_flip_acc_up(planes, b, rng, sp):
    """Toggle one acceptor's reachability at one tick."""
    t, a = _coords(rng, b, sp.n_ticks, sp.n_acceptors)
    u = planes["acc_up"]
    u[b, t, a] = 1 - u[b, t, a]


def _op_flip_stale(planes, b, rng, sp):
    """Toggle one acceptor's stale-ballot injection at one tick
    (corruption negative control only)."""
    t, a = _coords(rng, b, sp.n_ticks, sp.n_acceptors)
    s = planes["acc_stale"]
    s[b, t, a] = 1 - s[b, t, a]


def _op_flip_equiv(planes, b, rng, sp):
    """Toggle one acceptor's equivocating response at one tick
    (corruption negative control only)."""
    t, a = _coords(rng, b, sp.n_ticks, sp.n_acceptors)
    e = planes["acc_equiv"]
    e[b, t, a] = 1 - e[b, t, a]


def _op_flip_extend(planes, b, rng, sp):
    """Retarget one (tick, cell) §6 extend slot: new proposer id or none.
    Most writes are inert (the gate requires the LIVE owner); the hits
    probe a renewal round against everything else in flight."""
    t, n = _coords(rng, b, sp.n_ticks, sp.n_cells)
    planes["extends"][b, t, n] = rng.integers(
        NO_PROPOSER, sp.n_proposers, b.size
    )


def _op_shift_extend(planes, b, rng, sp):
    """Move one cell's extend by ±1 tick — the renewal round slides
    against expiry ties, releases and deaf windows."""
    t, n = _coords(rng, b, sp.n_ticks, sp.n_cells)
    t2 = np.clip(t + rng.choice((-1, 1), b.size), 0, sp.n_ticks - 1)
    e = planes["extends"]
    v = e[b, t, n].copy()
    e[b, t, n] = NO_PROPOSER
    e[b, t2, n] = v


def _op_crash_insert(planes, b, rng, sp):
    """Toggle one node restart (crash/restart plane operators only join
    the pool when MutationSpace.restart is set): an acceptor — blank +
    deaf for M — or a proposer — restart-counter bump. Proposer toggles
    stay closed under the RESTART_SHIFT carve: an insert that would push
    that proposer past ``sp.max_restarts`` total restarts is dropped."""
    acc = rng.random(b.size) < 0.5
    t, a = _coords(rng, b, sp.n_ticks, sp.n_acceptors)
    t2, p = _coords(rng, b, sp.n_ticks, sp.n_proposers)
    ra = planes["acc_restart"]
    ba, ta, aa = b[acc], t[acc], a[acc]
    ra[ba, ta, aa] = 1 - ra[ba, ta, aa]
    rp = planes["prop_restart"]
    bp, tp, pp = b[~acc], t2[~acc], p[~acc]
    rp[bp, tp, pp] = 1 - rp[bp, tp, pp]
    over = rp[bp].sum(axis=1)[np.arange(bp.size), pp] > sp.max_restarts
    rp[bp[over], tp[over], pp[over]] = 0


def _op_crash_shift(planes, b, rng, sp):
    """Move one acceptor-restart slot by ±1 tick — the whole deaf window
    slides against the quorum traffic around it."""
    t, a = _coords(rng, b, sp.n_ticks, sp.n_acceptors)
    t2 = np.clip(t + rng.choice((-1, 1), b.size), 0, sp.n_ticks - 1)
    r = planes["acc_restart"]
    v = r[b, t, a].copy()
    r[b, t, a] = 0
    r[b, t2, a] |= v


def _op_deaf_boundary_nudge(planes, b, rng, sp):
    """Plant one acceptor restart so its M-long deaf window expires right
    around a random (tick, cell) attempt slot (±1 tick of jitter) — the
    §4-critical boundary where an acceptor rejoins, blank, exactly as a
    foreign quorum wants its vote."""
    t, _, a = _coords(rng, b, sp.n_ticks, sp.n_cells, sp.n_acceptors)
    jitter = rng.integers(-1, 2, b.size)
    t0 = np.clip(t - sp.lease_ticks + jitter, 0, sp.n_ticks - 1)
    planes["acc_restart"][b, t0, a] = 1


#: name -> (operator, planes it writes); corruption-plane operators join
#: the pool only when MutationSpace.corrupt is set, restart-plane
#: operators only when MutationSpace.restart is set
MUTATION_OPS = {
    "shift_attempt": (_op_shift_attempt, ("attempts",)),
    "flip_attempt": (_op_flip_attempt, ("attempts",)),
    "flip_release": (_op_flip_release, ("releases",)),
    "nudge_prop_rate": (_op_nudge_prop_rate, ("prop_rate",)),
    "nudge_acc_rate": (_op_nudge_acc_rate, ("acc_rate",)),
    "shift_delay": (_op_shift_delay, ("delay",)),
    "drop_leg": (_op_drop_leg, ("drop",)),
    "flip_acc_up": (_op_flip_acc_up, ("acc_up",)),
    "flip_stale": (_op_flip_stale, ("acc_stale",)),
    "flip_equiv": (_op_flip_equiv, ("acc_equiv",)),
    "flip_extend": (_op_flip_extend, ("extends",)),
    "shift_extend": (_op_shift_extend, ("extends",)),
    "crash_insert": (_op_crash_insert, ("acc_restart", "prop_restart")),
    "crash_shift": (_op_crash_shift, ("acc_restart",)),
    "deaf_boundary_nudge": (_op_deaf_boundary_nudge, ("acc_restart",)),
}


def mutate(
    planes: dict,
    rng: np.random.Generator,
    space: MutationSpace,
) -> tuple[dict, np.ndarray]:
    """One mutation per population member: each of the B members draws one
    operator uniformly from ``space.op_names()`` and applies it at a
    random coordinate. Returns ``(mutant_planes, op_index)`` — a NEW dict
    (mutated planes copied, untouched planes shared) plus the per-member
    operator index into ``space.op_names()`` for lineage tags.
    """
    names = space.op_names()
    B = planes["attempts"].shape[0]
    op_idx = rng.integers(0, len(names), B)
    touched = set()
    for i in range(len(names)):
        touched.update(MUTATION_OPS[names[i]][1])
    out = {
        k: (np.array(v, np.int32) if k in touched else np.asarray(v))
        for k, v in planes.items()
    }
    for i, name in enumerate(names):
        b = np.flatnonzero(op_idx == i)
        if b.size:
            MUTATION_OPS[name][0](out, b, rng, space)
    return out, op_idx


def default_rate_planes(B: int, T: int, P: int, A: int) -> dict:
    """Drift-free [B, T, P]/[B, T, A] rate planes (the DEFAULT_RATE fill)."""
    return {
        "prop_rate": np.full((B, T, P), DEFAULT_RATE, np.int32),
        "acc_rate": np.full((B, T, A), DEFAULT_RATE, np.int32),
    }
