"""Coverage-guided falsification of the §4 at-most-one-owner guarantee.

The sweep driver replays thousands of fault scenarios per dispatch; this
package turns that throughput into a bug-hunter: a PRNG-keyed population
of :class:`~repro.lease_array.scenario.Scenario` planes is evolved toward
the invariant boundary with structure-aware mutations
(:mod:`~repro.lease_array.falsify.mutate`), scored by the in-dispatch
margin reductions (``engine.sweep(collect="margins")``), and elitist-
selected on boundary proximity (:mod:`~repro.lease_array.falsify.search`).
Any violating survivor is minimized by the greedy shrinker
(:mod:`~repro.lease_array.falsify.shrink`) and identified by its plane
digest + mutation lineage. ``falsify/corpus/`` checks in the known bug
species (the PR 5 guarded-expiry tie, the PR 2 §3-step-5 ghost lease) as
regression fixtures the margin scorer must keep ranking near the
boundary. See docs/falsification.md.

Run it: ``python -m repro.lease_array.falsify --mode corrupt --expect
violation`` (the corruption-plane negative control proving the alarm can
fire) / ``--mode honest --expect none`` (the actual falsification run).
"""
from .corpus import CORPUS_DIR, load_corpus, load_scenario, save_scenario
from .mutate import MUTATION_OPS, MutationSpace, mutate
from .search import (
    FalsifyConfig,
    FalsifyResult,
    margin_score,
    random_population,
    search,
)
from .shrink import shrink

__all__ = [
    "CORPUS_DIR",
    "FalsifyConfig",
    "FalsifyResult",
    "MUTATION_OPS",
    "MutationSpace",
    "load_corpus",
    "load_scenario",
    "margin_score",
    "mutate",
    "random_population",
    "save_scenario",
    "search",
    "shrink",
]
