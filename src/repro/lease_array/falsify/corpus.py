"""The seed corpus: known §4 bug species as checked-in Scenario fixtures.

Each fixture is a small JSON file — geometry, engine config, and only the
non-default planes — encoding a scenario that once tripped (or grazed)
the invariant: the PR 5 guarded-expiry tie (``tie.json``) and the PR 2
§3-step-5 ghost lease (``ghost.json``). Both are *fixed* bugs, so the
scenarios no longer violate — they sit exactly ON the boundary, and the
regression test (tests/test_falsify.py) asserts the margin scorer keeps
ranking them in the top percentile of a random batch: a falsifier that
cannot re-find known species cannot be trusted to find new ones.

The JSON is intentionally plain (nested lists, no pickles) so a shrunk
survivor can be pasted into a bug report or checked in as a new fixture
with ``save_scenario``.
"""
from __future__ import annotations

import json
from pathlib import Path

import numpy as np

from ..scenario import PLANES, Scenario, plane_digest

__all__ = ["CORPUS_DIR", "load_corpus", "load_scenario", "save_scenario"]

CORPUS_DIR = Path(__file__).resolve().parent / "corpus"


def save_scenario(path, scenario: Scenario, *, meta: dict = None) -> None:
    """Write one scenario as a corpus JSON fixture. Planes that are
    entirely their registered default are omitted (the loader refills
    them), keeping fixtures reviewable; ``meta`` is free-form provenance
    (species name, the PR that fixed it, expected margins...). The
    scenario's ``plane_digest`` is stamped in so a drifted fixture is
    detectable."""
    planes = {}
    for name, spec in PLANES.items():
        arr = np.asarray(scenario.planes[name])
        if not (arr == spec.default).all():
            planes[name] = arr.tolist()
    # digest the stored (non-default) planes only: a plane registered
    # AFTER this fixture was saved defaults in on load and must not
    # invalidate the stored hash
    doc = {
        "meta": dict(meta or {}),
        "digest": plane_digest(planes),
        "n_ticks": scenario.n_ticks,
        "n_cells": scenario.n_cells,
        "n_acceptors": scenario.n_acceptors,
        "n_proposers": scenario.n_proposers,
        "planes": planes,
    }
    Path(path).write_text(json.dumps(doc, indent=1) + "\n")


def load_scenario(path) -> tuple[Scenario, dict]:
    """Load one corpus fixture back into a validated ``Scenario`` (omitted
    planes refill with their registered defaults; the stored digest is
    re-checked). Returns ``(scenario, meta)``."""
    doc = json.loads(Path(path).read_text())
    stored = {
        k: np.asarray(v, np.int32) for k, v in doc["planes"].items()
    }
    got = plane_digest(stored)
    if got != doc["digest"]:
        raise ValueError(
            f"corpus fixture {path} drifted: stored digest {doc['digest']} "
            f"but planes hash to {got} (was a plane edited by hand?)"
        )
    sc = Scenario.build(
        doc["n_ticks"],
        n_cells=doc["n_cells"],
        n_acceptors=doc["n_acceptors"],
        n_proposers=doc["n_proposers"],
        **stored,
    )
    return sc, doc["meta"]


def load_corpus(directory=None) -> dict[str, tuple[Scenario, dict]]:
    """Every ``*.json`` fixture in the corpus directory, keyed by stem."""
    d = CORPUS_DIR if directory is None else Path(directory)
    return {
        p.stem: load_scenario(p) for p in sorted(d.glob("*.json"))
    }
