"""Greedy minimizer for violating scenarios (the survivor triage step).

Hypothesis-style shrinking, specialized to scenario planes: repeatedly
try simplifications that keep the §4 violation alive — truncate trailing
ticks, then reset plane entries to their registered defaults in halving
blocks (delta debugging), finishing with single-entry passes. Each probe
is one single-scenario ``engine.sweep`` (read-only, so one engine serves
every probe); the probe budget mirrors the test suite's hypothesis
profiles — a small default for the smoke path, a deep budget for
``@slow``/main-branch runs.

The result is the smallest scenario this pass ladder reaches: fewer
nonzero fault entries and fewer ticks, same violation — the form to
check into ``falsify/corpus/`` or to replay through the event-sim
referee (``trace.trace_from_scenario``) for triage.
"""
from __future__ import annotations

import numpy as np

from ..engine import LeaseArrayEngine
from ..scenario import PLANES, Scenario

__all__ = ["shrink"]


def _violates(eng: LeaseArrayEngine, planes: dict) -> bool:
    res = eng.sweep(
        Scenario({k: v[None] for k, v in planes.items()}), verify=False,
    )
    return bool(res.max_owner_count[0] > 1)


def shrink(
    scenario: Scenario,
    engine: LeaseArrayEngine,
    *,
    budget: int = 200,
    log=None,
) -> Scenario:
    """Minimize ``scenario`` while ``engine.sweep`` still reports a §4
    violation for it. Deterministic (no randomness — pass order is plane
    registry order); returns the original scenario unchanged if it does
    not violate to begin with. ``budget`` caps the number of sweep
    probes; ``log`` is an optional ``callable(str)``."""
    planes = {k: np.array(v, np.int32) for k, v in scenario.planes.items()}
    probes = 0

    def spend(p: dict) -> bool:
        nonlocal probes
        if probes >= budget:
            return False
        probes += 1
        return _violates(engine, p)

    if not spend(planes):
        return scenario

    # pass 1: truncate trailing ticks by halving (each new T recompiles
    # the scanner, so stay logarithmic)
    T = planes["attempts"].shape[0]
    while T > 1:
        t2 = max(1, T // 2)
        cut = {k: v[:t2] for k, v in planes.items()}
        if spend(cut):
            planes, T = {k: np.array(v) for k, v in cut.items()}, t2
        else:
            break
    if log is not None:
        log(f"shrink: {T} ticks after truncation")

    # pass 2: per plane, reset entries to the registered default in
    # halving tick-blocks, then singly (fixed T — one compiled shape)
    for name, spec in PLANES.items():
        arr = planes[name]
        default = spec.default
        block = T
        while block >= 1:
            t = 0
            while t < T:
                sl = slice(t, min(t + block, T))
                if not (arr[sl] == default).all():
                    trial = dict(planes)
                    cand = np.array(arr)
                    cand[sl] = default
                    trial[name] = cand
                    if spend(trial):
                        planes, arr = trial, cand
                t += block
            block //= 2
            if probes >= budget:
                break
        if probes >= budget:
            break
    if log is not None:
        nz = sum(
            int((planes[k] != s.default).sum()) for k, s in PLANES.items()
        )
        log(f"shrink: {nz} non-default entries after {probes} probes")
    return Scenario(planes)
