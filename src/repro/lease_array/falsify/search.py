"""The coverage-guided search loop: evolve scenario planes toward §4.

One generation = ONE ``engine.sweep(collect="margins")`` dispatch over the
whole population (vmap inside jit — the margin reductions never
materialize ``[B, T, N]``), then host-side elitist selection on
:func:`margin_score` and a vectorized mutation pass
(:func:`~repro.lease_array.falsify.mutate.mutate`). Shape-stable across
generations, so the batched scanner compiles once and a million-scenario
run is ~``generations`` dispatches.

Every member carries a **lineage tag** ``s<seed>.g<gen>.p<parent>.<op>``
(chained, most-recent first) so a violating survivor is reproducible
without the search: ``engine.sweep`` stamps the tag plus the member's
plane digest into the violation error, and :class:`FalsifyResult` carries
the violating ``Scenario`` itself.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import NamedTuple, Optional

import numpy as np

from ..engine import LeaseArrayEngine
from ..scenario import Scenario, plane_digest
from ..state import DEFAULT_RATE, MAX_RESTARTS, NO_PROPOSER
from .mutate import MutationSpace, mutate

__all__ = [
    "FalsifyConfig",
    "FalsifyResult",
    "margin_score",
    "random_population",
    "search",
]

#: lineage tags keep this many most-recent hops (older history adds no
#: reproduction power — the planes themselves are the ground truth)
_MAX_LINEAGE_HOPS = 6

#: margin-component weights: a vote still missing from a foreign quorum is
#: scored as 256 quarter-ticks of distance; expiry/guard distances count
#: at 64 per quarter-tick so a 4-quarter miss outranks a missing vote
_W_VOTES, _W_Q4 = 256, 64


@dataclass(frozen=True)
class FalsifyConfig:
    """Geometry + fault ranges + budget of one falsification run.

    The defaults are the **canonical falsifier cell**: small geometry
    (margins care about boundary proximity, not scale), ``lease_ticks=2``
    with ``drift_eps=0.25`` (guard_q4 = 5 — a rate-5 proposer clock meets
    its own guarded expiry on a whole tick, the PR 5 tie species),
    ``round_ticks=3`` (round_q4 = 12 — just enough abandon headroom for a
    delayed accept quorum to reach the §3 step-5 ghost guard; at
    ``round_ticks=2`` that species is statically unreachable), and
    every honest fault plane enabled (drift + delay + drop + outages).
    ``corrupt=True`` adds the acc_stale/acc_equiv adversarial planes —
    the negative control where the search MUST reach a violation.
    """

    n_cells: int = 4
    n_acceptors: int = 3
    n_proposers: int = 4
    n_ticks: int = 16
    lease_ticks: int = 2
    round_ticks: int = 3
    drift_eps: float = 0.25
    backend: str = "jnp"
    # population / budget
    seed: int = 0
    pop_size: int = 256
    generations: int = 8
    elite_frac: float = 0.25
    # initial-population fault densities
    p_attempt: float = 0.5
    p_release: float = 0.1
    p_down: float = 0.05
    max_delay: int = 2
    p_drop: float = 0.1
    drift: bool = True
    corrupt: bool = False
    p_corrupt: float = 0.05
    #: enable the crash/restart planes: diskless acceptor restarts (blank
    #: + deaf for M) and proposer restart-counter bumps — honest faults,
    #: so the search must NOT find a violation through them
    restarts: bool = False
    p_restart: float = 0.03
    #: enable the §6 extends plane: owner in-flight renewals — honest
    #: behavior (the gate requires the live owner), so the search must
    #: NOT find a violation through them either
    extends: bool = False
    p_extend: float = 0.15

    @property
    def rate_bounds(self) -> tuple[int, int]:
        """Integer clock-rate steps honoring ``drift_eps`` (state.py's
        guard math): eps=0.25 -> [3, 5] around DEFAULT_RATE=4."""
        lo = max(1, int(np.ceil(DEFAULT_RATE * (1.0 - self.drift_eps))))
        hi = max(lo, int(DEFAULT_RATE * (1.0 + self.drift_eps)))
        return lo, hi

    def mutation_space(self) -> MutationSpace:
        lo, hi = self.rate_bounds
        return MutationSpace(
            n_ticks=self.n_ticks, n_cells=self.n_cells,
            n_acceptors=self.n_acceptors, n_proposers=self.n_proposers,
            delay_hi=self.max_delay, rate_lo=lo, rate_hi=hi,
            corrupt=self.corrupt, restart=self.restarts,
            extend=self.extends,
            lease_ticks=self.lease_ticks,
        )

    def engine(self) -> LeaseArrayEngine:
        return LeaseArrayEngine(
            self.n_cells, n_acceptors=self.n_acceptors,
            n_proposers=self.n_proposers, lease_ticks=self.lease_ticks,
            round_ticks=self.round_ticks, drift_eps=self.drift_eps,
            backend=self.backend,
        )


class FalsifyResult(NamedTuple):
    """What one :func:`search` run found (and how hard it looked)."""

    found: bool                      # did any member trip §4?
    violation: Optional[Scenario]    # the violating scenario (unshrunk)
    lineage: Optional[str]           # its mutation lineage tag
    digest: Optional[str]            # its plane_digest
    generations: int                 # generations actually run
    evaluations: int                 # scenarios evaluated in total
    survivor_scores: np.ndarray      # [B] final-generation margin scores
    random_scores: np.ndarray        # [B] generation-0 (random) scores
    survivor_margins: dict           # final-generation raw margins [B]
    config: FalsifyConfig

    def concentrated(self) -> bool:
        """The search-worked signal the artifact reports: the survivor
        population sits strictly closer to the §4 boundary than the
        random batch it started from (median margin score)."""
        return float(np.median(self.survivor_scores)) < float(
            np.median(self.random_scores)
        )


def margin_score(margins: dict) -> np.ndarray:
    """[B] int64 boundary-proximity score — LOWER is closer to a §4
    violation. The primary distance is the smallest weighted margin
    component (one missing quorum vote = 256; one quarter-tick of
    expiry-tie, ghost-guard, or deaf-window distance = 64); concurrent
    open rounds subtract a small contention bonus (capped far below one
    primary unit) so equal-margin members with more simultaneous rounds
    rank first. ``MARGIN_BIG`` sentinels ("never got close") stay
    astronomically large, int64 keeps the weighting overflow-free."""
    m = {k: np.asarray(v, np.int64) for k, v in margins.items()}
    primary = np.minimum(
        m["votes_gap"] * _W_VOTES,
        np.minimum(
            m["tie_q4"] * _W_Q4,
            np.minimum(m["ghost_q4"] * _W_Q4, m["deaf_q4"] * _W_Q4),
        ),
    )
    return primary - np.minimum(m["open_rounds"], _W_Q4 - 1)


def random_population(rng: np.random.Generator, cfg: FalsifyConfig) -> dict:
    """The seeded generation-0 planes: iid per-entry draws at the config's
    fault densities, [B, T, ...] numpy int32 (the ``Scenario.stack``
    layout). Unlike ``trace.random_trace`` there is no same-cell spacing:
    overwriting an in-flight slot is loss, which the protocol must (and
    does) tolerate — the falsifier explores it on purpose."""
    B, T = cfg.pop_size, cfg.n_ticks
    N, A, P = cfg.n_cells, cfg.n_acceptors, cfg.n_proposers
    i32 = np.int32

    def ids(p):
        return np.where(
            rng.random((B, T, N)) < p,
            rng.integers(0, P, (B, T, N)), NO_PROPOSER,
        ).astype(i32)

    planes = {
        "attempts": ids(cfg.p_attempt),
        "releases": ids(cfg.p_release),
        "extends": (
            ids(cfg.p_extend) if cfg.extends
            else np.full((B, T, N), NO_PROPOSER, i32)
        ),
        "acc_up": (rng.random((B, T, A)) >= cfg.p_down).astype(i32),
        "delay": rng.integers(0, cfg.max_delay + 1, (B, T, P, A)).astype(i32),
        "drop": (rng.random((B, T, P, A)) < cfg.p_drop).astype(i32),
    }
    lo, hi = cfg.rate_bounds
    if cfg.drift:
        planes["prop_rate"] = rng.integers(lo, hi + 1, (B, T, P)).astype(i32)
        planes["acc_rate"] = rng.integers(lo, hi + 1, (B, T, A)).astype(i32)
    else:
        planes["prop_rate"] = np.full((B, T, P), DEFAULT_RATE, i32)
        planes["acc_rate"] = np.full((B, T, A), DEFAULT_RATE, i32)
    fill = (
        (lambda: (rng.random((B, T, A)) < cfg.p_corrupt).astype(i32))
        if cfg.corrupt else
        (lambda: np.zeros((B, T, A), i32))
    )
    planes["acc_stale"] = fill()
    planes["acc_equiv"] = fill()
    if cfg.restarts:
        planes["acc_restart"] = (
            rng.random((B, T, A)) < cfg.p_restart
        ).astype(i32)
        prop = (rng.random((B, T, P)) < cfg.p_restart / 2).astype(i32)
        # the RESTART_SHIFT carve caps per-proposer totals: zero every
        # restart past the cap so the batch clears check_pack_budget
        prop[np.cumsum(prop, axis=1) > MAX_RESTARTS] = 0
        planes["prop_restart"] = prop
    else:
        planes["acc_restart"] = np.zeros((B, T, A), i32)
        planes["prop_restart"] = np.zeros((B, T, P), i32)
    return planes


def _scenario_at(planes: dict, b: int) -> Scenario:
    return Scenario({k: np.array(np.asarray(v)[b]) for k, v in planes.items()})


def search(cfg: FalsifyConfig, *, engine: Optional[LeaseArrayEngine] = None,
           log=None) -> FalsifyResult:
    """Run the falsification loop to the configured budget (or the first
    violation). ``engine`` overrides the config-built one (it must match
    the geometry; sweeps never advance it). ``log`` is an optional
    ``callable(str)`` for per-generation progress."""
    rng = np.random.default_rng(cfg.seed)
    eng = engine if engine is not None else cfg.engine()
    space = cfg.mutation_space()
    op_names = space.op_names()
    planes = random_population(rng, cfg)
    B = cfg.pop_size
    tags = [f"s{cfg.seed}.g0.r{i}" for i in range(B)]
    elite_k = max(1, int(B * cfg.elite_frac))
    evaluations = 0
    random_scores = None
    scores = margins = None

    for gen in range(cfg.generations):
        res = eng.sweep(
            Scenario(planes), collect="margins", verify=False, tags=tags,
        )
        evaluations += B
        scores = margin_score(res.margins)
        margins = res.margins
        if random_scores is None:
            random_scores = scores.copy()
        bad = np.flatnonzero(res.max_owner_count > 1)
        if bad.size:
            b = int(bad[0])
            sc = _scenario_at(planes, b)
            return FalsifyResult(
                found=True, violation=sc, lineage=tags[b],
                digest=plane_digest(sc.planes),
                generations=gen + 1, evaluations=evaluations,
                survivor_scores=scores, random_scores=random_scores,
                survivor_margins=margins, config=cfg,
            )
        if log is not None:
            log(
                f"gen {gen}: best={int(scores.min())} "
                f"median={int(np.median(scores))}"
            )
        if gen == cfg.generations - 1:
            break
        # elitist selection: keep the closest-to-boundary members
        # verbatim, refill by mutating parents sampled from the elite
        order = np.argsort(scores, kind="stable")
        elite = order[:elite_k]
        parents = rng.choice(elite, size=B - elite_k)
        children = {
            k: np.asarray(v)[parents] for k, v in planes.items()
        }
        children, op_idx = mutate(children, rng, space)
        planes = {
            k: np.concatenate([np.asarray(v)[elite], children[k]])
            for k, v in planes.items()
        }
        new_tags = [tags[i] for i in elite]
        for j, p in enumerate(parents):
            hops = tags[p].split("<-")[: _MAX_LINEAGE_HOPS - 1]
            new_tags.append(
                f"s{cfg.seed}.g{gen + 1}.p{int(p)}."
                f"{op_names[op_idx[j]]}<-" + "<-".join(hops)
            )
        tags = new_tags

    return FalsifyResult(
        found=False, violation=None, lineage=None, digest=None,
        generations=cfg.generations, evaluations=evaluations,
        survivor_scores=scores, random_scores=random_scores,
        survivor_margins=margins, config=cfg,
    )


def replace_config(cfg: FalsifyConfig, **kw) -> FalsifyConfig:
    """``dataclasses.replace`` re-exported next to the config it serves."""
    return replace(cfg, **kw)
