"""CLI: one seeded fixed-budget falsification run + a JSON artifact.

    python -m repro.lease_array.falsify --mode honest --expect none
    python -m repro.lease_array.falsify --mode corrupt --expect violation
    python -m repro.lease_array.falsify --mode honest --restarts --expect none

``--mode corrupt`` enables the adversarial acc_stale/acc_equiv planes —
the negative control where the search MUST reach a §4 violation (the
alarm provably fires); ``--mode honest`` runs the real falsification
sweep over drift + delay + drop + outages, where it must NOT. ``--expect``
turns either statement into the process exit code (the CI contract:
``falsify-smoke`` runs both). The artifact (``--out``) records the
config, margin-score distributions (random generation-0 vs final
survivors), the concentration verdict, and — on a violation — the
shrunk offender's planes, digest, and mutation lineage.
"""
from __future__ import annotations

import argparse
import json
import sys
from dataclasses import asdict
from pathlib import Path

import numpy as np

from ..scenario import PLANES, plane_digest
from .search import FalsifyConfig, search
from .shrink import shrink


def _pcts(scores: np.ndarray) -> dict:
    qs = (0, 1, 5, 25, 50, 75, 100)
    return {
        f"p{q}": int(v) for q, v in zip(qs, np.percentile(scores, qs))
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.lease_array.falsify",
        description="coverage-guided §4 falsification at sweep speed",
    )
    ap.add_argument("--mode", choices=("honest", "corrupt"), default="honest")
    ap.add_argument(
        "--restarts", action="store_true",
        help="also explore the crash/restart planes (diskless acceptor "
             "restarts + proposer restart counters) — honest faults in "
             "either mode, so --expect stays mode-driven",
    )
    ap.add_argument(
        "--extends", action="store_true",
        help="also explore the §6 extends plane (owner in-flight "
             "renewals) — honest behavior in either mode, so --expect "
             "stays mode-driven",
    )
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--pop", type=int, default=256)
    ap.add_argument("--generations", type=int, default=8)
    ap.add_argument("--backend", default="jnp")
    ap.add_argument(
        "--expect", choices=("violation", "none"), default=None,
        help="exit nonzero unless the run ends this way (the CI contract)",
    )
    ap.add_argument(
        "--out", type=Path, default=None,
        help="write the survivors/margins JSON artifact here",
    )
    ap.add_argument(
        "--shrink-budget", type=int, default=120,
        help="sweep probes the survivor shrinker may spend (0 = skip)",
    )
    args = ap.parse_args(argv)

    cfg = FalsifyConfig(
        seed=args.seed, pop_size=args.pop, generations=args.generations,
        backend=args.backend, corrupt=args.mode == "corrupt",
        restarts=args.restarts, extends=args.extends,
    )
    res = search(cfg, log=lambda m: print(f"[falsify] {m}", flush=True))

    doc = {
        "mode": args.mode,
        "config": asdict(cfg),
        "found": res.found,
        "generations": res.generations,
        "evaluations": res.evaluations,
        "random_scores": _pcts(res.random_scores),
        "survivor_scores": _pcts(res.survivor_scores),
        "survivor_margins": {
            k: _pcts(v) for k, v in res.survivor_margins.items()
        },
        "concentrated": res.concentrated(),
    }
    if res.found:
        sc = res.violation
        if args.shrink_budget > 0:
            # shrink against a fresh engine (sweeps never advance it)
            sc = shrink(
                sc, cfg.engine(), budget=args.shrink_budget,
                log=lambda m: print(f"[falsify] {m}", flush=True),
            )
        doc["violation"] = {
            "lineage": res.lineage,
            "digest": res.digest,
            "shrunk_digest": plane_digest(sc.planes),
            "shrunk_ticks": sc.n_ticks,
            "planes": {
                k: np.asarray(v).tolist()
                for k, v in sc.planes.items()
                if not (np.asarray(v) == PLANES[k].default).all()
            },
        }
        print(
            f"[falsify] VIOLATION after {res.evaluations} scenarios: "
            f"digest={res.digest} lineage={res.lineage}"
        )
    else:
        print(
            f"[falsify] no violation in {res.evaluations} scenarios "
            f"(median margin: random={int(np.median(res.random_scores))} "
            f"-> survivors={int(np.median(res.survivor_scores))})"
        )
    if args.out is not None:
        args.out.write_text(json.dumps(doc, indent=1) + "\n")
        print(f"[falsify] artifact -> {args.out}")

    if args.expect == "violation" and not res.found:
        print("[falsify] FAIL: expected a violation (negative control)")
        return 1
    if args.expect == "none" and res.found:
        print("[falsify] FAIL: the honest engine violated §4")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
