"""Fault/timing traces replayable through BOTH lease engines.

A trace is the *entire* timing of the world — which proposer attempts which
cell at which tick, who releases, which acceptors are unreachable. Replaying
one trace through the event-driven ``core/`` engine and through the
vectorized ``lease_array`` plane must produce identical per-tick ownership
(tests/test_lease_array_differential.py asserts it, plus §4 at-most-one-owner
at every tick).

Exact-match construction (why this works, not just approximately):

  - zero-delay network -> a whole prepare/propose round resolves at one
    simulation instant, FIFO event order = call order;
  - one attempting proposer per (cell, tick) -> no same-instant races;
  - lease timespan ``T = lease_ticks + 0.25`` sim-seconds -> every expiry
    lands strictly *between* integer ticks, so tick-boundary sampling is
    never ambiguous (the array plane's quarter-tick arithmetic encodes the
    same schedule as ``4*L + 1`` quarters);
  - event-sim ballots are pinned to ``run = tick + 1`` per attempt, so both
    engines order ballots identically by (tick, proposer id);
  - acceptor downtime is *network* unreachability: messages drop, local
    expiry timers keep running — in both engines.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from ..configs.paxoslease_cell import CellConfig
from ..core.cell import build_cell
from ..sim.network import NetConfig
from .state import NO_PROPOSER

TICK_EPS = 0.1  # sample offset into a tick; < 0.25 so no expiry slips in


def cell_resource(n: int) -> str:
    return f"cell:{n}"


@dataclass
class Trace:
    n_cells: int
    n_acceptors: int
    n_proposers: int
    lease_ticks: int
    attempts: np.ndarray  # [T, N] int32: proposer attempting (or -1)
    releases: np.ndarray  # [T, N] int32: proposer releasing (or -1)
    acc_up: np.ndarray    # [T, A] bool: acceptor reachability

    @property
    def n_ticks(self) -> int:
        return self.attempts.shape[0]


def random_trace(
    seed: int,
    *,
    n_ticks: int = 200,
    n_cells: int = 16,
    n_acceptors: int = 5,
    n_proposers: int = 4,
    lease_ticks: int = 3,
    p_attempt: float = 0.35,
    p_release: float = 0.05,
    p_down_flip: float = 0.02,
) -> Trace:
    """Randomized trace: per (tick, cell) at most one attempting proposer
    (the no-same-instant-race construction above); releases name a random
    proposer (a no-op unless it actually owns — both engines agree on
    no-ops too); acceptor up/down flips as a Markov chain so outages are
    sticky, exercising quorum loss and recovery."""
    rng = np.random.default_rng(seed)
    attempts = np.where(
        rng.random((n_ticks, n_cells)) < p_attempt,
        rng.integers(0, n_proposers, (n_ticks, n_cells)),
        NO_PROPOSER,
    ).astype(np.int32)
    releases = np.where(
        rng.random((n_ticks, n_cells)) < p_release,
        rng.integers(0, n_proposers, (n_ticks, n_cells)),
        NO_PROPOSER,
    ).astype(np.int32)
    acc_up = np.empty((n_ticks, n_acceptors), bool)
    up = np.ones(n_acceptors, bool)
    for t in range(n_ticks):
        up ^= rng.random(n_acceptors) < p_down_flip
        acc_up[t] = up
    return Trace(
        n_cells, n_acceptors, n_proposers, lease_ticks,
        attempts, releases, acc_up,
    )


def replay_array(trace: Trace, *, backend: str = "jnp"):
    """Owners [T, N] + per-tick owner counts via the vectorized plane."""
    from .engine import LeaseArrayEngine

    eng = LeaseArrayEngine(
        trace.n_cells,
        n_acceptors=trace.n_acceptors,
        n_proposers=trace.n_proposers,
        lease_ticks=trace.lease_ticks,
        backend=backend,
    )
    return eng.run_trace(trace.attempts, trace.releases, trace.acc_up)


def replay_event_sim(trace: Trace, *, strict_monitor: bool = True) -> np.ndarray:
    """Owners [T, N] by replaying the trace through the event-driven core/
    engine (dedicated acceptor ensemble + detached proposer fleet, zero-delay
    deterministic network). The trace is the only source of timing: renewal
    is disabled and autonomous retries are quiesced after every tick."""
    cfg = CellConfig(
        n_acceptors=trace.n_acceptors,
        max_lease_time=trace.lease_ticks + 10.0,
        lease_timespan=trace.lease_ticks + 0.25,
    )
    cell = build_cell(
        cfg,
        n_proposers=trace.n_proposers,
        seed=0,
        net=NetConfig(delay_min=0.0, delay_max=0.0),
        strict_monitor=strict_monitor,
        combined_roles=False,
    )
    acc_addrs = [n.addr for n in cell.nodes if n.acceptor is not None]
    props = {n.node_id: n.proposer for n in cell.nodes if n.proposer is not None}
    owners = np.full((trace.n_ticks, trace.n_cells), NO_PROPOSER, np.int32)
    up_now = np.ones(trace.n_acceptors, bool)

    for t in range(trace.n_ticks):
        cell.env.run_until(float(t))  # in-between expiries fire here
        for a, addr in enumerate(acc_addrs):
            if trace.acc_up[t, a] != up_now[a]:
                cell.env.network.set_down(addr, not trace.acc_up[t, a])
                up_now[a] = trace.acc_up[t, a]
        # releases strictly before attempts (same order as the array step)
        for n in np.flatnonzero(trace.releases[t] >= 0):
            props[int(trace.releases[t, n])].release(cell_resource(n))
        for n in np.flatnonzero(trace.attempts[t] >= 0):
            p = props[int(trace.attempts[t, n])]
            st = p._state(cell_resource(n))
            st.want, st.renew, st.timespan = True, False, cfg.lease_timespan
            p.ballots.run = t  # next() -> run = t+1: (tick, pid) ballot order
            p._start_round(cell_resource(n))
        cell.env.run_until(t + TICK_EPS)  # drain the zero-delay rounds
        for n in range(trace.n_cells):
            o = cell.monitor.owner_of(cell_resource(n))
            owners[t, n] = NO_PROPOSER if o is None else o
        # quiesce: the trace owns all timing — no backoff retries, no renews
        for p in props.values():
            for st in p._res.values():
                st.want = False
                p._cancel(st, "retry_timer")
                p._cancel(st, "renew_timer")
    return owners
