"""Fault/timing traces replayable through BOTH lease engines.

A trace is the *entire* timing of the world — which proposer attempts which
cell at which tick, who releases, which acceptors are unreachable, and (in
the delayed model) how long every message leg takes and which legs are
lost. Replaying one trace through the event-driven ``core/`` engine and
through the vectorized ``lease_array`` plane must produce identical
per-tick ownership (tests assert it, plus §4 at-most-one-owner at every
tick).

Exact-match construction (why this works, not just approximately):

  - message timing is *pinned*: every protocol message sent at tick ``t``
    on the link to/from acceptor ``a`` takes exactly ``delay[t, a]`` whole
    ticks and is lost iff ``drop[t, a]``. The event sim replays the same
    planes via deterministic delay/drop policies on its ``Network``
    (deliveries land at ``tick + DELIVER_EPS``, inside the drain window,
    after tick-boundary reachability flips, releases and attempts);
  - with all-zero planes a whole prepare/propose round resolves inside one
    tick (FIFO event order = call order) — the PR 1 zero-delay model is
    the special case, bit-identical on both engines;
  - proposers abandon a round ``round_ticks`` ticks after starting it (the
    event sim's round timer fires at ``t0 + round_ticks + ABANDON_EPS`` —
    after that tick's attempts, *before* its deliveries), so a response
    can arrive after its round was abandoned, in both engines;
  - one attempting proposer per (cell, tick), and in delayed traces
    attempts on the same cell are spaced ``> 4 * max_delay`` ticks apart —
    a round's last message leaves the network within ``4 * max_delay``
    ticks, so an in-flight slot in the array plane is never overwritten
    while its message still matters (see ``netplane.py``);
  - lease timespan ``T = lease_ticks + 0.25`` sim-seconds -> every expiry
    lands strictly *between* integer ticks, so tick-boundary sampling is
    never ambiguous (the array plane's quarter-tick arithmetic encodes the
    same schedule as ``4*L + 1`` quarters);
  - event-sim ballots are pinned to ``run = tick + 1`` per attempt, so both
    engines order ballots identically by (tick, proposer id);
  - acceptor downtime is *network* unreachability: messages drop, local
    expiry timers keep running — in both engines. Down acceptors drop
    requests at *delivery* time (a request in flight toward an acceptor
    that goes down is lost), exactly like ``Network.set_down``;
  - §7 releases stay out-of-band (instantaneous, loss-free to reachable
    acceptors): the delay/drop planes govern the four round phases only.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import numpy as np

from ..configs.paxoslease_cell import CellConfig
from ..core.cell import build_cell
from ..core.messages import (
    PrepareRequest,
    PrepareResponse,
    ProposeRequest,
    ProposeResponse,
)
from ..sim.network import NetConfig
from .state import NO_PROPOSER

TICK_EPS = 0.1  # sample offset into a tick; < 0.25 so no expiry slips in
DELIVER_EPS = 0.05  # messages land here within their delivery tick
ABANDON_EPS = 0.02  # round timer fires here: before deliveries, after attempts

#: messages governed by the trace's delay/drop planes
PHASE_MESSAGES = (PrepareRequest, PrepareResponse, ProposeRequest, ProposeResponse)


def cell_resource(n: int) -> str:
    return f"cell:{n}"


@dataclass
class Trace:
    n_cells: int
    n_acceptors: int
    n_proposers: int
    lease_ticks: int
    attempts: np.ndarray  # [T, N] int32: proposer attempting (or -1)
    releases: np.ndarray  # [T, N] int32: proposer releasing (or -1)
    acc_up: np.ndarray    # [T, A] bool: acceptor reachability
    delay: Optional[np.ndarray] = None  # [T, A] int32: per-leg delay (ticks)
    drop: Optional[np.ndarray] = None   # [T, A] bool: per-leg loss
    round_ticks: int = 1  # proposer abandons a round after this many ticks

    @property
    def n_ticks(self) -> int:
        return self.attempts.shape[0]

    @property
    def delayed(self) -> bool:
        """True if the trace carries a nonzero delay or drop plane."""
        return bool(
            (self.delay is not None and self.delay.any())
            or (self.drop is not None and self.drop.any())
        )

    def delay_plane(self) -> np.ndarray:
        if self.delay is None:
            return np.zeros((self.n_ticks, self.n_acceptors), np.int32)
        return self.delay

    def drop_plane(self) -> np.ndarray:
        if self.drop is None:
            return np.zeros((self.n_ticks, self.n_acceptors), bool)
        return self.drop


def random_trace(
    seed: int,
    *,
    n_ticks: int = 200,
    n_cells: int = 16,
    n_acceptors: int = 5,
    n_proposers: int = 4,
    lease_ticks: int = 3,
    p_attempt: float = 0.35,
    p_release: float = 0.05,
    p_down_flip: float = 0.02,
    max_delay_ticks: int = 0,
    p_drop: float = 0.0,
    round_ticks: Optional[int] = None,
) -> Trace:
    """Randomized trace: per (tick, cell) at most one attempting proposer
    (the no-same-instant-race construction above); releases name a random
    proposer (a no-op unless it actually owns — both engines agree on
    no-ops too); acceptor up/down flips as a Markov chain so outages are
    sticky, exercising quorum loss and recovery.

    With ``max_delay_ticks > 0`` / ``p_drop > 0`` the trace also carries
    lossy/laggy message schedules: every leg sent at tick ``t`` to/from
    acceptor ``a`` takes ``delay[t, a]`` ticks (uniform in
    [0, max_delay_ticks]) and is lost with the drop plane. Attempts on the
    same cell are then spaced ``4 * max_delay_ticks + 1`` ticks apart (the
    slot-isolation construction above). ``round_ticks`` defaults to
    ``max_delay_ticks + 1`` so slow rounds genuinely get abandoned and
    responses genuinely arrive late.
    """
    rng = np.random.default_rng(seed)
    attempts = np.where(
        rng.random((n_ticks, n_cells)) < p_attempt,
        rng.integers(0, n_proposers, (n_ticks, n_cells)),
        NO_PROPOSER,
    ).astype(np.int32)
    releases = np.where(
        rng.random((n_ticks, n_cells)) < p_release,
        rng.integers(0, n_proposers, (n_ticks, n_cells)),
        NO_PROPOSER,
    ).astype(np.int32)
    acc_up = np.empty((n_ticks, n_acceptors), bool)
    up = np.ones(n_acceptors, bool)
    for t in range(n_ticks):
        up ^= rng.random(n_acceptors) < p_down_flip
        acc_up[t] = up
    delay = drop = None
    if round_ticks is None:
        round_ticks = max_delay_ticks + 1
    if max_delay_ticks > 0:
        delay = rng.integers(
            0, max_delay_ticks + 1, (n_ticks, n_acceptors)
        ).astype(np.int32)
        # slot isolation: a round's messages leave the network within
        # 4 * max_delay ticks; keep same-cell attempts farther apart
        gap = 4 * max_delay_ticks + 1
        last = np.full(n_cells, -gap, np.int64)
        for t in range(n_ticks):
            ok = (attempts[t] >= 0) & (t - last >= gap)
            attempts[t] = np.where(ok, attempts[t], NO_PROPOSER)
            last = np.where(ok, t, last)
    if p_drop > 0.0:
        drop = rng.random((n_ticks, n_acceptors)) < p_drop
    return Trace(
        n_cells, n_acceptors, n_proposers, lease_ticks,
        attempts, releases, acc_up,
        delay=delay, drop=drop, round_ticks=int(round_ticks),
    )


def replay_array(trace: Trace, *, backend: str = "jnp", netplane: Optional[bool] = None):
    """Owners [T, N] + per-tick owner counts via the vectorized plane.

    ``netplane=None`` picks the model automatically: the delayed in-flight
    plane iff the trace carries nonzero delay/drop planes, else the
    synchronous zero-delay step (they agree bit-for-bit on zero-delay
    traces; ``netplane=True`` forces the delayed path to prove it).
    """
    from .engine import LeaseArrayEngine

    eng = LeaseArrayEngine(
        trace.n_cells,
        n_acceptors=trace.n_acceptors,
        n_proposers=trace.n_proposers,
        lease_ticks=trace.lease_ticks,
        round_ticks=trace.round_ticks,
        backend=backend,
    )
    if netplane is None:
        netplane = trace.delayed
    if not netplane:
        return eng.run_trace(trace.attempts, trace.releases, trace.acc_up)
    return eng.run_trace(
        trace.attempts, trace.releases, trace.acc_up,
        delay=trace.delay_plane(), drop=trace.drop_plane(),
    )


def _pin_network_to_trace(net, trace: Trace, acc_index: dict[str, int]) -> None:
    """Install deterministic delay/drop policies replaying the trace's
    planes: a phase message sent at tick ``t`` on the link to/from acceptor
    ``a`` is dropped iff ``drop[t, a]`` and otherwise delivered at
    ``t + delay[t, a] + DELIVER_EPS``. Releases (and anything else) stay
    instantaneous and loss-free."""
    delay = trace.delay_plane()
    dropm = trace.drop_plane()
    last = trace.n_ticks - 1

    def leg(src: str, dst: str) -> Optional[int]:
        a = acc_index.get(dst)
        return a if a is not None else acc_index.get(src)

    def tick_of(now: float) -> int:
        return min(int(now + 1e-9), last)

    def delay_policy(src, dst, msg, now):
        if not isinstance(msg, PHASE_MESSAGES):
            return 0.0  # out-of-band (Release): deliver at the send instant
        a = leg(src, dst)
        t = tick_of(now)
        return (t + int(delay[t, a])) + DELIVER_EPS - now

    def drop_policy(src, dst, msg, now):
        if not isinstance(msg, PHASE_MESSAGES):
            return False
        a = leg(src, dst)
        return bool(dropm[tick_of(now), a])

    net.set_delay_policy(delay_policy)
    net.set_drop_policy(drop_policy)


def replay_event_sim(trace: Trace, *, strict_monitor: bool = True) -> np.ndarray:
    """Owners [T, N] by replaying the trace through the event-driven core/
    engine (dedicated acceptor ensemble + detached proposer fleet, message
    timing pinned to the trace's delay/drop planes). The trace is the only
    source of timing: renewal is disabled, autonomous retries are quiesced
    after every tick, and rounds are abandoned by the round timer exactly
    ``round_ticks`` ticks after they start."""
    cfg = CellConfig(
        n_acceptors=trace.n_acceptors,
        max_lease_time=trace.lease_ticks + 10.0,
        lease_timespan=trace.lease_ticks + 0.25,
        round_timeout=trace.round_ticks + ABANDON_EPS,
    )
    cell = build_cell(
        cfg,
        n_proposers=trace.n_proposers,
        seed=0,
        net=NetConfig(delay_min=0.0, delay_max=0.0),
        strict_monitor=strict_monitor,
        combined_roles=False,
    )
    acc_addrs = [n.addr for n in cell.nodes if n.acceptor is not None]
    props = {n.node_id: n.proposer for n in cell.nodes if n.proposer is not None}
    _pin_network_to_trace(
        cell.env.network, trace, {addr: a for a, addr in enumerate(acc_addrs)}
    )
    owners = np.full((trace.n_ticks, trace.n_cells), NO_PROPOSER, np.int32)
    up_now = np.ones(trace.n_acceptors, bool)

    for t in range(trace.n_ticks):
        cell.env.run_until(float(t))  # in-between expiries fire here
        for a, addr in enumerate(acc_addrs):
            if trace.acc_up[t, a] != up_now[a]:
                cell.env.network.set_down(addr, not trace.acc_up[t, a])
                up_now[a] = trace.acc_up[t, a]
        # releases strictly before attempts (same order as the array step)
        for n in np.flatnonzero(trace.releases[t] >= 0):
            props[int(trace.releases[t, n])].release(cell_resource(n))
        for n in np.flatnonzero(trace.attempts[t] >= 0):
            p = props[int(trace.attempts[t, n])]
            st = p._state(cell_resource(n))
            st.want, st.renew, st.timespan = True, False, cfg.lease_timespan
            st.round = None  # overwrite any open round; no ballot jumps
            p.ballots.run = t  # next() -> run = t+1: (tick, pid) ballot order
            p._start_round(cell_resource(n))
        # drain this tick: round timers (+0.02), then deliveries (+0.05)
        cell.env.run_until(t + TICK_EPS)
        for n in range(trace.n_cells):
            o = cell.monitor.owner_of(cell_resource(n))
            owners[t, n] = NO_PROPOSER if o is None else o
        # quiesce: the trace owns all timing — no backoff retries, no renews
        for p in props.values():
            for st in p._res.values():
                st.want = False
                p._cancel(st, "retry_timer")
                p._cancel(st, "renew_timer")
    return owners
