"""Fault/timing traces replayable through BOTH lease engines.

A trace is the *entire* timing of the world — which proposer attempts which
cell at which tick, who releases, which acceptors are unreachable, and (in
the delayed model) how long every message leg takes and which legs are
lost. Replaying one trace through the event-driven ``core/`` engine and
through the vectorized ``lease_array`` plane must produce identical
per-tick ownership (tests assert it, plus §4 at-most-one-owner at every
tick). A :class:`Trace` converts to the engine's declarative
:class:`~repro.lease_array.scenario.Scenario` pytree via :meth:`Trace.scenario`.

Exact-match construction (why this works, not just approximately):

  - message timing is *pinned*: every message leg sent at tick ``t`` on the
    link between proposer ``p`` and acceptor ``a`` takes exactly
    ``delay[t, p, a]`` whole ticks and is lost iff ``drop[t, p, a]`` —
    asymmetric per-(proposer, acceptor) link matrices; the symmetric
    per-acceptor ``[T, A]`` form is the P-broadcast special case. The event
    sim replays the same planes via deterministic delay/drop policies on
    its ``Network`` (phase deliveries land at ``tick + DELIVER_EPS``,
    inside the drain window, after tick-boundary reachability flips,
    releases and attempts);
  - with all-zero planes a whole prepare/propose round resolves inside one
    tick (FIFO event order = call order) — the PR 1 zero-delay model is
    the special case, bit-identical on both engines;
  - proposers abandon a round ``round_ticks`` ticks after starting it (the
    event sim's round timer fires at ``t0 + round_ticks + ABANDON_EPS`` —
    after that tick's attempts, *before* its deliveries), so a response
    can arrive after its round was abandoned, in both engines;
  - one attempting proposer per (cell, tick), and in delayed traces
    attempts on the same cell are spaced ``> 4 * max_delay`` ticks apart —
    a round's last message leaves the network within ``4 * max_delay``
    ticks, so an in-flight slot in the array plane is never overwritten
    while its message still matters (see ``netplane.py``);
  - §7 release messages ride the same in-flight plane (``rel_*`` slots):
    the releasing proposer stops believing immediately (the §7 local
    ordering), but each discard leg takes ``delay[t, p, a]`` ticks and is
    droppable like any phase leg. In the event sim they deliver at
    ``REL_EPS`` — after the round-abandon timers, before any phase
    delivery, matching the array tick's step order. Releases on the same
    cell are spaced ``> max_delay`` ticks apart (a release slot holds one
    in-flight discard per (acceptor, cell));
  - lease timespan ``T = lease_ticks + 0.25`` sim-seconds -> every expiry
    lands strictly *between* integer ticks, so tick-boundary sampling is
    never ambiguous (the array plane's quarter-tick arithmetic encodes the
    same schedule as ``4*L + 1`` quarters);
  - event-sim ballots are pinned to ``run = tick + 1`` per attempt, so both
    engines order ballots identically by (tick, proposer id);
  - acceptor downtime is *network* unreachability: messages drop, local
    expiry timers keep running — in both engines. Down acceptors drop
    requests at *delivery* time (a request in flight toward an acceptor
    that goes down is lost), exactly like ``Network.set_down``;
  - clock drift (§4) is pinned the same way: a trace's constant per-node
    ``prop_rate``/``acc_rate`` vectors (integer local quarter-ticks per
    global tick; 4 = rate 1.0) become the event sim's ``NodeClock`` rates
    ``r/4``, so a node's T-local-second timer spans ``4T/r`` global
    seconds — exactly the tick at which the array plane's accumulated
    local clock passes the same local deadline. Every drifted timer lands
    at a fraction ``m/r`` into a tick: with ``r <= MAX_REFEREE_RATE`` a
    nonzero fraction clears every sampling epsilon, and the ``m = 0``
    tie (timer at the exact delivery instant) fires first by scheduler
    insertion order — matching the array tick's expiries-first step
    order. The proposer's §4 drift-guard discount is pinned to the array
    plane's floor-quantized ``guarded_lease_q4`` (the two engines'
    discounts agree to the quarter-tick; a float-exact dyadic local
    timespan), so both believe for identical local spans.
"""
from __future__ import annotations

from dataclasses import dataclass, replace as _dc_replace
from typing import Optional

import numpy as np

from ..configs.paxoslease_cell import CellConfig
from ..core.cell import build_cell
from ..core.messages import (
    PrepareRequest,
    PrepareResponse,
    ProposeRequest,
    ProposeResponse,
    Release,
)
from ..sim.network import NetConfig
from .scenario import PLANES, Scenario, _coerce_plane, _dim_sizes
from .state import (
    DEFAULT_RATE,
    MAX_RESTARTS,
    NO_PROPOSER,
    guarded_lease_q4,
    lease_quarters,
)

#: drifted clock-rate steps the referee can replay exactly: a node at rate
#: ``r`` quarter-ticks per tick places every timer landing at a fraction
#: ``m/r`` into a tick; with r <= 9 any nonzero fraction is >= 1/9, clear
#: of the DELIVER_EPS/TICK_EPS sampling offsets below (m/r == 0 ties are
#: resolved by the scheduler's insertion-order heap exactly like the array
#: step's expiries-before-deliveries order). See the drift notes below.
MAX_REFEREE_RATE = 9

TICK_EPS = 0.1  # sample offset into a tick; < 0.25 so no expiry slips in
DELIVER_EPS = 0.05  # phase messages land here within their delivery tick
REL_EPS = 0.03  # §7 discards land here: after abandons, before phase legs
ABANDON_EPS = 0.02  # round timer fires here: before deliveries, after attempts

#: messages governed by the trace's delay/drop planes (every protocol leg;
#: LearnHints stay out-of-band — advisory, never authoritative)
PHASE_MESSAGES = (PrepareRequest, PrepareResponse, ProposeRequest, ProposeResponse)
PLANE_MESSAGES = PHASE_MESSAGES + (Release,)


def cell_resource(n: int) -> str:
    return f"cell:{n}"


@dataclass
class Trace:
    n_cells: int
    n_acceptors: int
    n_proposers: int
    lease_ticks: int
    attempts: np.ndarray  # [T, N] int32: proposer attempting (or -1)
    releases: np.ndarray  # [T, N] int32: proposer releasing (or -1)
    acc_up: np.ndarray    # [T, A] bool: acceptor reachability
    #: per-leg delay in whole ticks: asymmetric [T, P, A], or the symmetric
    #: per-acceptor [T, A] special case (broadcast over P)
    delay: Optional[np.ndarray] = None
    drop: Optional[np.ndarray] = None   # [T, P, A] or [T, A] bool: per-leg loss
    round_ticks: int = 1  # proposer abandons a round after this many ticks
    #: constant per-node clock-rate steps (local quarter-ticks per global
    #: tick; 4 = rate 1.0). Constant-in-time because the event sim's
    #: NodeClock has one rate per node; the array plane itself accepts
    #: per-tick [T, P]/[T, A] rate planes (property tests use them).
    prop_rate: Optional[np.ndarray] = None  # [P] int
    acc_rate: Optional[np.ndarray] = None   # [A] int
    drift_eps: float = 0.0  # ε the proposers' drift guard assumes
    #: crash/restart schedules (§2's diskless failure model): a 1 at
    #: ``[t, a]`` blanks acceptor ``a`` at tick ``t`` and holds it deaf for
    #: a maximal lease span on ITS clock; a 1 at ``[t, p]`` makes proposer
    #: ``p`` forget everything but its (bumped) stable restart counter
    acc_restarts: Optional[np.ndarray] = None   # [T, A] 0/1
    prop_restarts: Optional[np.ndarray] = None  # [T, P] 0/1
    #: §6 owner-extension schedule: proposer id extending its own live
    #: lease on each cell this tick (-1 = none). An extend is a full fresh
    #: round gated on the extender's own live belief — non-owner extends
    #: are no-ops in BOTH engines, so a generator may guess owners freely
    extends: Optional[np.ndarray] = None        # [T, N] int32

    @property
    def n_ticks(self) -> int:
        return self.attempts.shape[0]

    @property
    def delayed(self) -> bool:
        """True if the trace carries a nonzero delay or drop plane."""
        return bool(
            (self.delay is not None and self.delay.any())
            or (self.drop is not None and self.drop.any())
        )

    @property
    def restarted(self) -> bool:
        """True if the trace carries any crash/restart event."""
        return bool(
            (self.acc_restarts is not None and self.acc_restarts.any())
            or (self.prop_restarts is not None and self.prop_restarts.any())
        )

    @property
    def extended(self) -> bool:
        """True if the trace schedules any §6 owner extension."""
        return bool(
            self.extends is not None and (self.extends != NO_PROPOSER).any()
        )

    @property
    def drifted(self) -> bool:
        """True if any node's clock departs from the drift-free rate."""
        return bool(
            (self.prop_rate is not None
             and (self.prop_rate != DEFAULT_RATE).any())
            or (self.acc_rate is not None
                and (self.acc_rate != DEFAULT_RATE).any())
        )

    def rate_planes(self) -> tuple[np.ndarray, np.ndarray]:
        """The constant per-node rates as [T, P]/[T, A] scenario planes."""
        T = self.n_ticks
        pr = (
            np.full(self.n_proposers, DEFAULT_RATE, np.int32)
            if self.prop_rate is None
            else np.asarray(self.prop_rate, np.int32)
        )
        ar = (
            np.full(self.n_acceptors, DEFAULT_RATE, np.int32)
            if self.acc_rate is None
            else np.asarray(self.acc_rate, np.int32)
        )
        return (
            np.broadcast_to(pr[None, :], (T, self.n_proposers)).copy(),
            np.broadcast_to(ar[None, :], (T, self.n_acceptors)).copy(),
        )

    def scenario(self) -> Scenario:
        """The trace's fault planes as one declarative Scenario pytree
        (defaulted, validated, [T, A] forms broadcast to [T, P, A])."""
        prop_rate, acc_rate = self.rate_planes()
        return Scenario.build(
            self.n_ticks,
            n_cells=self.n_cells,
            n_acceptors=self.n_acceptors,
            n_proposers=self.n_proposers,
            attempts=self.attempts,
            releases=self.releases,
            acc_up=self.acc_up,
            delay=self.delay,
            drop=self.drop,
            prop_rate=prop_rate,
            acc_rate=acc_rate,
            acc_restart=self.acc_restarts,
            prop_restart=self.prop_restarts,
            extends=self.extends,
        )

    def link_planes(self) -> tuple[np.ndarray, np.ndarray]:
        """Canonical [T, P, A] (delay, drop) link matrices, zero-defaulted
        — just the two planes, without materializing a whole Scenario."""
        sizes = _dim_sizes(self.n_cells, self.n_acceptors, self.n_proposers)
        lead = (self.n_ticks,)
        return (
            _coerce_plane(PLANES["delay"], self.delay, sizes, lead, "trace"),
            _coerce_plane(PLANES["drop"], self.drop, sizes, lead, "trace"),
        )


def random_trace(
    seed: int,
    *,
    n_ticks: int = 200,
    n_cells: int = 16,
    n_acceptors: int = 5,
    n_proposers: int = 4,
    lease_ticks: int = 3,
    p_attempt: float = 0.35,
    p_release: float = 0.05,
    p_down_flip: float = 0.02,
    max_delay_ticks: int = 0,
    p_drop: float = 0.0,
    asymmetric: bool = False,
    round_ticks: Optional[int] = None,
    drift_eps: float = 0.0,
    restarts: float = 0.0,
    renew: float = 0.0,
) -> Trace:
    """Randomized trace: per (tick, cell) at most one attempting proposer
    (the no-same-instant-race construction above); releases name a random
    proposer (a no-op unless it actually owns — both engines agree on
    no-ops too); acceptor up/down flips as a Markov chain so outages are
    sticky, exercising quorum loss and recovery.

    With ``max_delay_ticks > 0`` / ``p_drop > 0`` the trace also carries
    lossy/laggy message schedules: every leg sent at tick ``t`` on the
    (p, a) link takes ``delay[t, p, a]`` ticks (uniform in
    [0, max_delay_ticks]) and is lost with the drop plane.
    ``asymmetric=True`` draws per-(proposer, acceptor) ``[T, P, A]``
    planes — heterogeneous links (a straggler replica, one proposer behind
    a lossy uplink); the default draws the symmetric ``[T, A]`` form.
    Attempts on the same cell are then spaced ``4 * max_delay_ticks + 1``
    ticks apart, releases ``max_delay_ticks + 1`` apart (the
    slot-isolation construction above). ``round_ticks`` defaults to
    ``max_delay_ticks + 1`` so slow rounds genuinely get abandoned and
    responses genuinely arrive late.

    With ``drift_eps > 0`` every node also gets a constant drifted clock:
    integer rate steps drawn uniformly from ``[⌈4(1-ε)⌉, ⌊4(1+ε)⌋]``
    local quarter-ticks per tick (ε = 0.25 → {3, 4, 5}), capped at
    ``MAX_REFEREE_RATE`` so the event-sim replay stays exact, and the
    trace records ε for the proposers' §4 guard discount.

    With ``restarts > 0`` the trace also carries crash/restart schedules
    (the §2 diskless failure model): each acceptor crashes per tick with
    that probability (blank + deaf for a maximal lease span, double
    restarts inside one deaf window allowed — they extend it), and each
    proposer with half of it, capped at ``state.MAX_RESTARTS`` total per
    proposer so the restart-counter carve in the packed ballot encoding
    never overflows (the engine refuses hotter schedules).

    With ``renew > 0`` the trace also carries a §6 owner-extension
    schedule (the ``extends`` plane): after every attempt, the attempting
    proposer keeps re-proposing the cell every
    ``max(4·max_delay + 1, round(lease_ticks·renew))`` ticks — the
    ``Proposer.cfg.renew_fraction`` cadence, floored to the slot-isolation
    gap — until the next attempt or its own release touches the cell.
    The generator never simulates who actually won: a non-owner extend is
    a no-op in BOTH engines (the array's ``ext_ok`` gate and the
    referee's ``st.owner`` guard), and an extend is suppressed whenever
    a later attempt on the cell would land inside the extend round's
    in-flight window (the same spacing construction as attempts).
    """
    rng = np.random.default_rng(seed)
    prop_rate = acc_rate = None
    if drift_eps > 0.0:
        lo = max(1, int(np.ceil(DEFAULT_RATE * (1.0 - drift_eps))))
        hi = min(MAX_REFEREE_RATE, int(DEFAULT_RATE * (1.0 + drift_eps)))
        prop_rate = rng.integers(lo, hi + 1, n_proposers).astype(np.int32)
        acc_rate = rng.integers(lo, hi + 1, n_acceptors).astype(np.int32)
    attempts = np.where(
        rng.random((n_ticks, n_cells)) < p_attempt,
        rng.integers(0, n_proposers, (n_ticks, n_cells)),
        NO_PROPOSER,
    ).astype(np.int32)
    releases = np.where(
        rng.random((n_ticks, n_cells)) < p_release,
        rng.integers(0, n_proposers, (n_ticks, n_cells)),
        NO_PROPOSER,
    ).astype(np.int32)
    acc_up = np.empty((n_ticks, n_acceptors), bool)
    up = np.ones(n_acceptors, bool)
    for t in range(n_ticks):
        up ^= rng.random(n_acceptors) < p_down_flip
        acc_up[t] = up
    delay = drop = None
    link_shape = (
        (n_ticks, n_proposers, n_acceptors) if asymmetric
        else (n_ticks, n_acceptors)
    )
    if round_ticks is None:
        round_ticks = max_delay_ticks + 1
    if max_delay_ticks > 0:
        delay = rng.integers(0, max_delay_ticks + 1, link_shape).astype(np.int32)

        def space(rows: np.ndarray, gap: int) -> None:
            # slot isolation: keep same-cell events farther apart than the
            # lifetime of the in-flight messages they generate
            last = np.full(n_cells, -gap, np.int64)
            for t in range(n_ticks):
                ok = (rows[t] >= 0) & (t - last >= gap)
                rows[t] = np.where(ok, rows[t], NO_PROPOSER)
                last = np.where(ok, t, last)

        # a round's messages leave the network within 4 * max_delay ticks;
        # a release's discard legs within max_delay
        space(attempts, 4 * max_delay_ticks + 1)
        space(releases, max_delay_ticks + 1)
    if p_drop > 0.0:
        drop = rng.random(link_shape) < p_drop
    extends = None
    if renew > 0.0:
        gap = 4 * max_delay_ticks + 1
        interval = max(gap, int(round(lease_ticks * renew)), 1)
        extends = np.full((n_ticks, n_cells), NO_PROPOSER, np.int32)
        # next attempt at-or-after each tick, per cell (backward scan):
        # an extend too close before a future attempt would have its
        # in-flight round slots overwritten — suppress it instead
        INF = np.int64(1) << 60
        next_att = np.full((n_ticks + 1, n_cells), INF, np.int64)
        for t in range(n_ticks - 1, -1, -1):
            next_att[t] = np.where(attempts[t] >= 0, t, next_att[t + 1])
        last_prop = np.full(n_cells, NO_PROPOSER, np.int64)
        next_ext = np.full(n_cells, INF, np.int64)
        for t in range(n_ticks):
            hit = attempts[t] >= 0
            # a fresh attempt restarts the cadence from its own tick ...
            last_prop = np.where(hit, attempts[t], last_prop)
            next_ext = np.where(hit, t + interval, next_ext)
            # ... its own release ends it (the owner stops wanting it)
            quit_ = (releases[t] >= 0) & (releases[t] == last_prop)
            last_prop = np.where(quit_, NO_PROPOSER, last_prop)
            due = (
                (last_prop >= 0) & (t >= next_ext) & ~hit
                & (next_att[t + 1] - t >= gap)
            )
            extends[t] = np.where(due, last_prop, NO_PROPOSER)
            next_ext = np.where(due, t + interval, next_ext)
    acc_restarts = prop_restarts = None
    if restarts > 0.0:
        acc_restarts = (
            rng.random((n_ticks, n_acceptors)) < restarts
        ).astype(np.int32)
        prop_restarts = (
            rng.random((n_ticks, n_proposers)) < restarts / 2
        ).astype(np.int32)
        # the ballot carve holds MAX_RESTARTS per proposer: keep the first
        # MAX_RESTARTS draws, drop the rest (the engine refuses overflows)
        for p in range(n_proposers):
            hits = np.flatnonzero(prop_restarts[:, p])
            prop_restarts[hits[MAX_RESTARTS:], p] = 0
    return Trace(
        n_cells, n_acceptors, n_proposers, lease_ticks,
        attempts, releases, acc_up,
        delay=delay, drop=drop, round_ticks=int(round_ticks),
        prop_rate=prop_rate, acc_rate=acc_rate, drift_eps=float(drift_eps),
        acc_restarts=acc_restarts, prop_restarts=prop_restarts,
        extends=extends,
    )


def trace_from_scenario(
    scenario: Scenario,
    *,
    lease_ticks: int,
    round_ticks: int = 1,
    drift_eps: float = 0.0,
) -> Trace:
    """A falsification survivor as a referee-replayable :class:`Trace`
    (the triage hook: shrink a violating scenario, convert, and hand it
    to :func:`replay_event_sim` to see what the reference implementation
    does with the same world). The engine knobs (``lease_ticks``,
    ``round_ticks``, ``drift_eps``) travel outside the Scenario pytree, so
    they are passed explicitly — use the falsifier config's values.

    Two scenario features have no event-sim pin and raise here:
    per-tick *varying* clock rates (``NodeClock`` holds one constant rate
    per node) and nonzero acc_stale/acc_equiv corruption planes (the
    reference acceptors cannot be made Byzantine). Crash/restart planes DO
    convert — ``LeaseNode.crash``/``restart`` pin them exactly — as long
    as they are binary and stay under the per-proposer restart-counter
    carve (checked below). Note the exactness
    caveat: a survivor that re-attempts a cell while that cell's previous
    round is still in flight overwrites the array plane's slot (loss the
    protocol tolerates), which the event sim does not reproduce — the
    cross-engine equality tests only cover traces obeying the spacing
    construction above. Triage agreement on §4 is still the point: the
    referee monitor independently checks at-most-one-owner."""
    p = scenario.planes
    for name in ("acc_stale", "acc_equiv"):
        arr = np.asarray(p[name])
        if arr.any():
            raise ValueError(
                f"scenario carries a nonzero {name} corruption plane; the "
                "event-sim referee has no Byzantine acceptors — triage "
                "honest survivors only"
            )
    rates = []
    for name in ("prop_rate", "acc_rate"):
        arr = np.asarray(p[name], np.int32)
        if (arr != arr[:1]).any():
            raise ValueError(
                f"scenario {name} varies over ticks; the event-sim "
                "NodeClock holds one constant rate per node — constant "
                "rate columns are required for an exact replay"
            )
        rates.append(arr[0].copy())
    prop_rate, acc_rate = rates
    # crash/restart planes convert faithfully — but only 0/1 schedules:
    # a plane value > 1 would mean several restarts of one node inside a
    # single tick, which the event-sim referee replays as one (its crash/
    # restart calls are tick-granular), so refuse rather than mis-pin
    restart_planes = []
    for name in ("acc_restart", "prop_restart"):
        arr = np.asarray(p[name], np.int32)
        if arr.max(initial=0) > 1:
            raise ValueError(
                f"scenario {name} plane carries a value > 1 (several "
                "restarts of one node in one tick); the event-sim referee "
                "is tick-granular — binary restart schedules only"
            )
        restart_planes.append(arr.copy() if arr.any() else None)
    acc_restarts, prop_restarts = restart_planes
    if prop_restarts is not None and (
        prop_restarts.sum(axis=0).max(initial=0) > MAX_RESTARTS
    ):
        raise ValueError(
            f"scenario prop_restart plane restarts one proposer more than "
            f"MAX_RESTARTS={MAX_RESTARTS} times; the packed ballot "
            "restart-counter carve cannot replay it"
        )
    ext = np.asarray(p["extends"], np.int32)
    return Trace(
        scenario.n_cells, scenario.n_acceptors, scenario.n_proposers,
        int(lease_ticks),
        np.asarray(p["attempts"], np.int32),
        np.asarray(p["releases"], np.int32),
        np.asarray(p["acc_up"]) > 0,
        delay=np.asarray(p["delay"], np.int32),
        drop=np.asarray(p["drop"]) > 0,
        round_ticks=int(round_ticks),
        prop_rate=prop_rate, acc_rate=acc_rate,
        drift_eps=float(drift_eps),
        acc_restarts=acc_restarts, prop_restarts=prop_restarts,
        extends=ext.copy() if (ext != NO_PROPOSER).any() else None,
    )


def replay_array(
    trace: Trace, *, backend: str = "jnp", netplane: Optional[bool] = None,
    restart_guard: bool = True,
):
    """Owners [T, N] + per-tick owner counts via the vectorized plane.

    ``netplane=None`` picks the model automatically: the delayed in-flight
    plane iff the trace carries nonzero delay/drop/restart planes, else
    the synchronous zero-delay step (they agree bit-for-bit on zero-delay
    traces; ``netplane=True`` forces the delayed path to prove it).
    ``restart_guard=False`` disables the post-restart deaf window — the
    chaos suite's negative control proving the §3 M-wait necessary.
    """
    from .engine import LeaseArrayEngine

    eng = LeaseArrayEngine(
        trace.n_cells,
        n_acceptors=trace.n_acceptors,
        n_proposers=trace.n_proposers,
        lease_ticks=trace.lease_ticks,
        round_ticks=trace.round_ticks,
        drift_eps=trace.drift_eps,
        backend=backend,
        restart_guard=restart_guard,
    )
    return eng.run_trace(trace.scenario(), netplane=netplane)


def _pin_network_to_trace(
    net, trace: Trace, acc_index: dict[str, int], prop_index: dict[str, int]
) -> None:
    """Install deterministic delay/drop policies replaying the trace's
    planes: a protocol message sent at tick ``t`` on the (p, a) link is
    dropped iff ``drop[t, p, a]`` and otherwise delivered at
    ``t + delay[t, p, a]`` — phase legs at ``+ DELIVER_EPS``, §7 release
    legs at ``+ REL_EPS`` (the array tick delivers due discards before any
    phase message). Anything else (LearnHints) stays instantaneous and
    loss-free.

    Crash/restart pin: an acceptor restart physically destroys that
    node's un-sent state, which in the array plane blanks its in-flight
    *response* slots. The network here holds responses outside the node,
    so the drop policy replays the blanking: a response leg from acceptor
    ``a`` sent at ``t_s``, due at ``t_d = t_s + delay``, is dropped iff a
    restart of ``a`` falls in ``(t_s, t_d]`` (the blank at phase 1.5 of
    tick ``t_r`` precedes the delivery phase, so ``t_r == t_d`` still
    kills the leg; a leg minted the restart tick itself cannot exist —
    the acceptor is already deaf). Request legs TOWARD a restarting
    acceptor survive in the network and die at delivery iff it is still
    deaf, exactly like ``acc_up`` downtime."""
    delay, dropm = trace.link_planes()
    arst = trace.acc_restarts
    last = trace.n_ticks - 1

    def leg(src: str, dst: str) -> tuple[int, int]:
        a = acc_index.get(dst)
        if a is not None:  # proposer -> acceptor: requests, releases
            return prop_index[src], a
        return prop_index[dst], acc_index[src]  # acceptor -> proposer

    def tick_of(now: float) -> int:
        return min(int(now + 1e-9), last)

    def delay_policy(src, dst, msg, now):
        if not isinstance(msg, PLANE_MESSAGES):
            return 0.0  # out-of-band (hints): deliver at the send instant
        p, a = leg(src, dst)
        t = tick_of(now)
        eps = REL_EPS if isinstance(msg, Release) else DELIVER_EPS
        return (t + int(delay[t, p, a])) + eps - now

    def drop_policy(src, dst, msg, now):
        if not isinstance(msg, PLANE_MESSAGES):
            return False
        p, a = leg(src, dst)
        t = tick_of(now)
        if bool(dropm[t, p, a]):
            return True
        if arst is not None and isinstance(
            msg, (PrepareResponse, ProposeResponse)
        ):
            t_d = t + int(delay[t, p, a])
            if arst[t + 1:t_d + 1, a].any():
                return True  # the sender restarts before this leg lands
        return False

    net.set_delay_policy(delay_policy)
    net.set_drop_policy(drop_policy)


def replay_event_sim(trace: Trace, *, strict_monitor: bool = True) -> np.ndarray:
    """Owners [T, N] by replaying the trace through the event-driven core/
    engine (dedicated acceptor ensemble + detached proposer fleet, message
    timing pinned to the trace's delay/drop planes). The trace is the only
    source of timing: renewal is disabled, autonomous retries are quiesced
    after every tick, and rounds are abandoned by the round timer exactly
    ``round_ticks`` ticks after they start.

    Drift: the trace's per-node rate steps become ``NodeClock`` rates
    (``r/4`` local seconds per global second) so every local timer — the
    acceptors' lease expiries, the proposers' round-abandon horizons and
    guarded own timers — stretches or shrinks in global time exactly as
    the array plane's accumulated local clocks do (see the construction
    notes above). The proposers' drift-guard discount is pinned to the
    array's floor-quantized ``guarded_lease_q4`` local quarters — the
    cross-engine discount regression test asserts the two arithmetics
    agree to the quarter-tick, making this a timing pin, not a semantic
    change."""
    for name, rates in (("prop_rate", trace.prop_rate),
                        ("acc_rate", trace.acc_rate)):
        if rates is not None and np.asarray(rates).size:
            lo, hi = int(np.min(rates)), int(np.max(rates))
            if lo < 1 or hi > MAX_REFEREE_RATE:
                raise ValueError(
                    f"trace {name} entries must lie in "
                    f"[1, {MAX_REFEREE_RATE}] for an exact event-sim "
                    f"replay; got [{lo}, {hi}]"
                )
    cfg = CellConfig(
        n_acceptors=trace.n_acceptors,
        max_lease_time=trace.lease_ticks + 10.0,
        lease_timespan=trace.lease_ticks + 0.25,
        round_timeout=trace.round_ticks + ABANDON_EPS,
        clock_drift_bound=trace.drift_eps,
        drift_guard=trace.drift_eps > 0.0,
    )
    acc_base = 1000  # build_cell's detached-acceptor node-id offset
    clock_rates = {}
    if trace.prop_rate is not None:
        clock_rates.update(
            (p, float(r) / DEFAULT_RATE)
            for p, r in enumerate(trace.prop_rate)
        )
    if trace.acc_rate is not None:
        clock_rates.update(
            (acc_base + a, float(r) / DEFAULT_RATE)
            for a, r in enumerate(trace.acc_rate)
        )
    cell = build_cell(
        cfg,
        n_proposers=trace.n_proposers,
        seed=0,
        net=NetConfig(delay_min=0.0, delay_max=0.0),
        clock_rates=clock_rates,
        strict_monitor=strict_monitor,
        combined_roles=False,
    )
    acc_nodes = [n for n in cell.nodes if n.acceptor is not None]
    acc_addrs = [n.addr for n in acc_nodes]
    prop_nodes = {n.node_id: n for n in cell.nodes if n.proposer is not None}
    props = {i: n.proposer for i, n in prop_nodes.items()}
    # Crash/restart pins (§2/§3): an acceptor's deaf window is a maximal
    # lease span on ITS clock — lease_q4 local quarters = lease_q4/r
    # global seconds (LeaseNode.restart waits cfg.max_lease_time global
    # seconds, so pin it per node; the fraction lease_q4/r mod 1 is either
    # 0 — the rejoin fires at the tick boundary, before that tick's
    # flips/attempts, the array's deaf-expiry-first order — or >= 1/r >=
    # 1/MAX_REFEREE_RATE > TICK_EPS, landing the rejoin strictly after
    # the tick's sampling, i.e. the NEXT tick processes requests, exactly
    # the array's ceil(lease_q4/r) deaf span). Proposers have no deaf
    # rule: they rejoin instantly (handled in the loop below).
    lease_q4 = lease_quarters(trace.lease_ticks)
    for a, node in enumerate(acc_nodes):
        r = DEFAULT_RATE if trace.acc_rate is None else int(trace.acc_rate[a])
        # lease_timespan is dead weight on a pure-acceptor node (spans ride
        # in the Propose messages); zero it so the T < M validator accepts
        # the exact quantized deaf wait, which can undercut the global T
        node.cfg = _dc_replace(
            cfg, max_lease_time=lease_q4 / r, lease_timespan=0.0
        )
    for node in prop_nodes.values():
        node.skip_restart_wait = True
    # Pin the §4 guard to the array plane's quarter-tick quantization: the
    # proposer's own timer runs guard_q4 local quarters. The timer STARTS
    # at the majority-open delivery instant (tick + DELIVER_EPS), so its
    # pinned duration is shortened by DELIVER_EPS *global* seconds
    # (= DELIVER_EPS·r/4 local): the belief then ends at global
    # ``u + guard_q4/r`` exactly — mid-tick when guard_q4/r has a
    # fractional part (>= 1/MAX_REFEREE_RATE > TICK_EPS, so sampling and
    # boundary releases see the same liveness the array does), and at the
    # tick boundary when it divides evenly, where the earlier-scheduled
    # timer fires before that tick's releases/attempts/deliveries — the
    # array step's expiries-first order.
    guard_q4 = guarded_lease_q4(
        lease_quarters(trace.lease_ticks), trace.drift_eps
    )
    for pid, p in props.items():
        r = (
            DEFAULT_RATE if trace.prop_rate is None
            else int(trace.prop_rate[pid])
        )
        p._guarded_timespan = lambda t, g=(guard_q4 - DELIVER_EPS * r) / 4.0: g
    _pin_network_to_trace(
        cell.env.network, trace,
        {addr: a for a, addr in enumerate(acc_addrs)},
        {n.addr: n.node_id for n in cell.nodes if n.proposer is not None},
    )
    owners = np.full((trace.n_ticks, trace.n_cells), NO_PROPOSER, np.int32)

    for t in range(trace.n_ticks):
        cell.env.run_until(float(t))  # in-between expiries + rejoins fire here
        for a, node in enumerate(acc_nodes):
            # re-assert reachability every tick: a deaf-window rejoin may
            # have just un-downed a node the plane still wants unreachable
            cell.env.network.set_down(
                node.addr, bool(not trace.acc_up[t, a]) or node.crashed
            )
        # crash/restart injection: after reachability flips, before
        # releases/attempts — the array tick's phase 1.5
        if trace.acc_restarts is not None:
            for a in np.flatnonzero(trace.acc_restarts[t]):
                node = acc_nodes[int(a)]
                node.crash()
                node.restart()  # blank + deaf; re-restarts extend the window
        if trace.prop_restarts is not None:
            for pid in np.flatnonzero(trace.prop_restarts[t]):
                node = prop_nodes[int(pid)]
                node.crash()  # belief dropped, timers cancelled, monitor told
                node.restart()  # stable restart counter bumped, RAM gone
                # instant rejoin: the attempt calls below are synchronous,
                # so the zero-wait rejoin event must be flushed by hand
                node.crashed = False
                cell.env.network.set_down(node.addr, False)
        # releases strictly before attempts (same order as the array step)
        for n in np.flatnonzero(trace.releases[t] >= 0):
            props[int(trace.releases[t, n])].release(cell_resource(n))
        # §6 extends after releases (a same-tick release already cleared
        # st.owner, so the extend is a no-op — the array's phase-3 gate
        # evaluated after phase 2a), before attempts; a colliding attempt
        # takes precedence exactly like the array's ``ext_ok`` requires
        # ``att < 0``, and a non-owner extend is a no-op in both engines
        if trace.extends is not None:
            for n in np.flatnonzero(trace.extends[t] >= 0):
                if trace.attempts[t, n] >= 0:
                    continue
                p = props[int(trace.extends[t, n])]
                st = p._state(cell_resource(n))
                if not st.owner:
                    continue
                st.want, st.renew, st.timespan = (
                    True, False, cfg.lease_timespan
                )
                st.round = None
                p.ballots.run = t  # next() -> run = t+1, like an attempt
                p._start_round(cell_resource(n))
        for n in np.flatnonzero(trace.attempts[t] >= 0):
            p = props[int(trace.attempts[t, n])]
            st = p._state(cell_resource(n))
            st.want, st.renew, st.timespan = True, False, cfg.lease_timespan
            st.round = None  # overwrite any open round; no ballot jumps
            p.ballots.run = t  # next() -> run = t+1: (tick, pid) ballot order
            p._start_round(cell_resource(n))
        # drain this tick: round timers (+0.02), release discards (+0.03),
        # then phase deliveries (+0.05)
        cell.env.run_until(t + TICK_EPS)
        for n in range(trace.n_cells):
            o = cell.monitor.owner_of(cell_resource(n))
            owners[t, n] = NO_PROPOSER if o is None else o
        # quiesce: the trace owns all timing — no backoff retries, no renews
        for p in props.values():
            for st in p._res.values():
                st.want = False
                p._cancel(st, "retry_timer")
                p._cancel(st, "renew_timer")
    return owners
