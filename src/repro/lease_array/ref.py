"""Pure-jnp oracle for one synchronous tick of the vectorized lease plane.

Semantics of a tick (all N cells in lockstep, mirroring the event engine on
a zero-delay network — `trace.replay_event_sim` is the bit-for-bit referee):

  1. expiry     — accepted proposals and ownership beliefs whose quarter-tick
                  deadline has passed are cleared (acceptor timers run even
                  while the acceptor is unreachable, exactly like the event
                  sim where `set_down` drops messages but not local timers).
  2. release    — §7: a releasing proposer first stops believing it owns,
                  then *reachable* acceptors discard iff the accepted ballot
                  matches the ballot the lease was won under.
  3. prepare    — §3 step 2: each attempting proposer (at most one per cell
                  per tick; ballots ordered by (tick, proposer)) gets a
                  promise from every reachable acceptor with
                  ``ballot >= highest_promised`` (equal accepted — the ≤
                  boundary). A response counts as *open* iff the acceptor
                  holds no lease, or holds this proposer's own lease while
                  the proposer still believes it owns (§6 extend).
  4. propose    — §3 step 4: with a majority of opens, every granting
                  acceptor accepts (discarding any previous proposal) and
                  restarts its lease timer; the proposer starts its own
                  timer and becomes owner. No majority -> nothing changes
                  beyond the raised promises.

All of it is branch-free elementwise/sublane-reduction work — the Pallas
kernel (`kernel.py`) fuses the same dataflow into one VMEM pass.

This synchronous step is the zero-delay special case. The *delayed* model
(`lease_step_delayed_ref`) threads the same protocol through the in-flight
message plane (`netplane.py`): rounds span multiple ticks, responses arrive
late, get lost, or land after the proposer abandoned the round.
"""
from __future__ import annotations

import jax.numpy as jnp

from .netplane import NetPlaneState, delayed_tick_math
from .state import NO_PROPOSER, QUARTERS, LeaseArrayState


def lease_step_ref(
    state: LeaseArrayState,
    t,                # scalar int32 tick
    attempt,          # [N] int32 proposer id attempting each cell (-1 = none)
    release,          # [N] int32 proposer id releasing each cell (-1 = none)
    acc_up,           # [A] bool/int32 acceptor reachability this tick
    *,
    majority: int,
    lease_q4: int,    # lease timespan in quarter-ticks
) -> tuple[LeaseArrayState, jnp.ndarray]:
    """Advance every cell one tick; returns (new_state, owner_count[N])."""
    P = state.n_proposers
    t4 = QUARTERS * t
    p_ids = jnp.arange(P, dtype=jnp.int32)[:, None]         # [P, 1]
    up = jnp.asarray(acc_up).astype(jnp.bool_)[:, None]     # [A, 1]

    # -- 1. expiry ---------------------------------------------------------
    acc_live = (state.accepted_ballot > 0) & (state.lease_expiry > t4)
    accepted_ballot = jnp.where(acc_live, state.accepted_ballot, 0)
    accepted_proposer = jnp.where(acc_live, state.accepted_proposer, NO_PROPOSER)
    lease_expiry = jnp.where(acc_live, state.lease_expiry, 0)
    own_live = (state.owner_mask > 0) & (state.owner_expiry > t4)
    owner_mask = own_live.astype(jnp.int32)
    owner_expiry = jnp.where(own_live, state.owner_expiry, 0)
    owner_ballot = jnp.where(own_live, state.owner_ballot, 0)

    # -- 2. release (§7) ---------------------------------------------------
    rel = jnp.asarray(release, jnp.int32)[None, :]           # [1, N]
    rel_owner = (p_ids == rel) & (owner_mask > 0)            # [P, N]
    rel_ballot = jnp.sum(jnp.where(rel_owner, owner_ballot, 0), axis=0, keepdims=True)
    owner_mask = jnp.where(rel_owner, 0, owner_mask)
    discard = up & (rel_ballot > 0) & (accepted_ballot == rel_ballot)  # [A, N]
    accepted_ballot = jnp.where(discard, 0, accepted_ballot)
    accepted_proposer = jnp.where(discard, NO_PROPOSER, accepted_proposer)
    lease_expiry = jnp.where(discard, 0, lease_expiry)

    # -- 3. prepare (§3.2) -------------------------------------------------
    att = jnp.asarray(attempt, jnp.int32)[None, :]           # [1, N]
    has_att = att >= 0
    ballot = jnp.where(has_att, (t + 1) * P + att, 0)        # [1, N]
    att_owns = jnp.any((p_ids == att) & (owner_mask > 0), axis=0, keepdims=True)
    grant = up & has_att & (ballot >= state.highest_promised)
    is_open = grant & (
        (accepted_ballot == 0) | ((accepted_proposer == att) & att_owns)
    )
    opens = jnp.sum(is_open.astype(jnp.int32), axis=0, keepdims=True)  # [1, N]
    won = opens >= majority
    highest_promised = jnp.where(grant, ballot, state.highest_promised)

    # -- 4. propose (§3.4) + proposer update -------------------------------
    accept = grant & won
    accepted_ballot = jnp.where(accept, ballot, accepted_ballot)
    accepted_proposer = jnp.where(accept, att, accepted_proposer)
    lease_expiry = jnp.where(accept, t4 + lease_q4, lease_expiry)
    new_owner = (p_ids == att) & won                          # [P, N]
    owner_mask = jnp.where(new_owner, 1, owner_mask)
    owner_expiry = jnp.where(new_owner, t4 + lease_q4, owner_expiry)
    owner_ballot = jnp.where(new_owner, ballot, owner_ballot)

    new_state = LeaseArrayState(
        highest_promised=highest_promised,
        accepted_ballot=accepted_ballot,
        accepted_proposer=accepted_proposer,
        lease_expiry=lease_expiry,
        owner_mask=owner_mask,
        owner_expiry=owner_expiry,
        owner_ballot=owner_ballot,
    )
    owner_count = jnp.sum(owner_mask, axis=0)                 # [N]
    return new_state, owner_count


def link_matrix(m, n_proposers: int, n_acceptors: int) -> jnp.ndarray:
    """Normalize a delay/drop input to the canonical [P, A] link matrix.

    Accepts the asymmetric per-(proposer, acceptor) ``[P, A]`` form or the
    legacy symmetric per-acceptor ``[A]`` form (broadcast over P)."""
    m = jnp.asarray(m).astype(jnp.int32)
    if m.ndim == 1:
        m = jnp.broadcast_to(m[None, :], (n_proposers, n_acceptors))
    if m.shape != (n_proposers, n_acceptors):
        raise ValueError(
            f"delay/drop must be [A]={n_acceptors} or "
            f"[P, A]=({n_proposers}, {n_acceptors}); got {m.shape}"
        )
    return m


def flat_links(m, n_proposers: int, n_acceptors: int, n_cells: int) -> jnp.ndarray:
    """A link matrix as the ``[P*A, N]`` blocks ``netplane._link_rows``
    gathers from: row ``p*A + a``, broadcast along cells. The one encoding
    of the flattened-link layout, shared by the jnp oracle and the Pallas
    kernel wrapper."""
    return jnp.broadcast_to(
        link_matrix(m, n_proposers, n_acceptors).reshape(n_proposers * n_acceptors, 1),
        (n_proposers * n_acceptors, n_cells),
    )


def lease_step_delayed_ref(
    state: LeaseArrayState,
    net: NetPlaneState,
    t,                # scalar int32 tick
    attempt,          # [N] int32 proposer id attempting each cell (-1 = none)
    release,          # [N] int32 proposer id releasing each cell (-1 = none)
    acc_up,           # [A] bool/int32 acceptor reachability this tick
    delay,            # [P, A] (or legacy [A]) int32 link delays for sends this tick
    drop,             # [P, A] (or legacy [A]) bool/int32 link drop masks
    *,
    majority: int,
    lease_q4: int,
    round_q4: int,    # timeout-and-abandon horizon in quarter-ticks
) -> tuple[LeaseArrayState, NetPlaneState, jnp.ndarray]:
    """One tick of the delayed (in-flight message) model; pure-jnp oracle.

    Returns (new_state, new_net, owner_count[N]). The whole tick body lives
    in `netplane.delayed_tick_math`, which the Pallas kernel shares.
    """
    A, N = state.highest_promised.shape
    P = state.n_proposers
    row = lambda r: jnp.asarray(r, jnp.int32).reshape(1, N)
    col = lambda c: jnp.broadcast_to(
        jnp.asarray(c).astype(jnp.int32)[:, None], (A, N)
    )
    lease, netp, count = delayed_tick_math(
        tuple(state), tuple(net), t,
        row(attempt), row(release), col(acc_up),
        flat_links(delay, P, A, N), flat_links(drop, P, A, N),
        majority=majority, lease_q4=lease_q4, round_q4=round_q4,
    )
    return LeaseArrayState(*lease), NetPlaneState(*netp), count.reshape(N)


def owner_row(state: LeaseArrayState) -> jnp.ndarray:
    """Per-cell owner id (or NO_PROPOSER). With the at-most-one-owner
    invariant intact there is at most one set bit per column."""
    p_ids = jnp.arange(state.n_proposers, dtype=jnp.int32)[:, None]
    return jnp.max(
        jnp.where(state.owner_mask > 0, p_ids, NO_PROPOSER), axis=0
    )
