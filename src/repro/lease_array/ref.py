"""Pure-jnp oracle for one tick of the vectorized lease plane.

Semantics of a tick (all N cells in lockstep, mirroring the event engine on
a zero-delay network — `trace.replay_event_sim` is the bit-for-bit referee):

  1. expiry     — accepted proposals and ownership beliefs whose quarter-tick
                  deadline has passed are cleared (acceptor timers run even
                  while the acceptor is unreachable, exactly like the event
                  sim where `set_down` drops messages but not local timers).
  2. release    — §7: a releasing proposer first stops believing it owns,
                  then *reachable* acceptors discard iff the accepted ballot
                  matches the ballot the lease was won under.
  3. prepare    — §3 step 2: each attempting proposer (at most one per cell
                  per tick; ballots ordered by (tick, proposer)) gets a
                  promise from every reachable acceptor with
                  ``ballot >= highest_promised`` (equal accepted — the ≤
                  boundary). A response counts as *open* iff the acceptor
                  holds no lease, or holds this proposer's own lease while
                  the proposer still believes it owns (§6 extend).
  4. propose    — §3 step 4: with a majority of opens, every granting
                  acceptor accepts (discarding any previous proposal) and
                  restarts its lease timer; the proposer starts its own
                  timer and becomes owner. No majority -> nothing changes
                  beyond the raised promises.

The tick body (`sync_tick_math`) runs on the PACKED layout
(`state.PackedLeaseState`): one int32 per (expiry, ballot) pair, a single
believed-owner row instead of the [P, N] owner planes (§4 makes that
lossless for legal histories; an illegal second belief surfaces as an
owner count of 2 at the tick it would appear). It is branch-free
elementwise/sublane-reduction work shared verbatim by the jnp scan driver
and the fused Pallas window kernel (`kernel.py`) — the backends agree
bit-for-bit by construction. `lease_step_ref` wraps it in the public
`LeaseArrayState` format for per-tick callers and older tests.

This synchronous step is the zero-delay special case. The *delayed* model
(`lease_step_delayed_ref`) threads the same protocol through the in-flight
message plane (`netplane.py`): rounds span multiple ticks, responses arrive
late, get lost, or land after the proposer abandoned the round. Crash and
restart faults live there too: a diskless acceptor restart blanks its
column (promises, accepted lease, in-flight responses) and holds it deaf
for M local quarter-ticks before it may answer again (§3), while a
proposer restart abandons its open rounds and bumps the restart counter
carved into its packed ballots (§2; ``state.RESTART_SHIFT``).

Clock drift (§4): every node-side deadline is minted from and compared
against that node's LOCAL clock — the ``pclk``/``aclk`` columns fed per
tick from the scenario's ``prop_rate``/``acc_rate`` planes (accumulated
local quarter-ticks; `scenario.py`) — and the proposer's own timer runs
the discounted ``guard_q4 = lease_q4·(1-ε)/(1+ε)`` (`state.
guarded_lease_q4`), so at most one node believes it owns even when clocks
tick at different (ε-bounded) rates. All-DEFAULT_RATE planes make every
clock read ``4t`` and reproduce the rate-1 engine bit-for-bit.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .netplane import NetPlaneState, delayed_tick_math, pack_link
from .state import (
    NO_PROPOSER,
    PACK_MASK,
    PACK_SHIFT,
    LeaseArrayState,
    PackedLeaseState,
    ballot_proposer,
    clock_select,
    pack_pair,
    pack_state,
    rate1_clock,
    unpack_state,
)


def sync_tick_math(
    lease: tuple,     # PackedLeaseState fields, [A, bn] / [1, bn] blocks
    t,                # scalar int32 tick
    attempt,          # [1, bn] int32 proposer id attempting (-1 = none)
    release,          # [1, bn] int32 proposer id releasing (-1 = none)
    up,               # [A, 1|bn] int32 acceptor reachability this tick
    pclk,             # [P, 1|bn] int32 proposer local clocks (quarter-ticks)
    aclk,             # [A, 1|bn] int32 acceptor local clocks (quarter-ticks)
    *,
    majority: int,
    lease_q4: int,
    n_proposers: int,
    guard_q4: int = None,  # proposer's guarded own timer (default: no drift)
) -> tuple[tuple, jnp.ndarray]:
    """One synchronous tick on the packed layout; returns
    (lease', owner_count[1, bn]). Shared by the jnp scan and the Pallas
    window kernel. ``owner_count`` is 0/1 plus 1 at any tick a win would
    overwrite a live *other* belief — the §4 alarm (see netplane docs).

    Node timers live in each node's LOCAL quarter-ticks (§4: clocks may
    drift): an acceptor row's deadlines are minted from and compared
    against ``aclk``'s row, the single owner row against the *owner's*
    entry of ``pclk`` (`state.clock_select`). With every clock at the
    drift-free DEFAULT_RATE the clock planes equal ``4t`` and the math is
    bit-identical to the rate-1 engine. The proposer's own timer is the
    drift-guard discount ``guard_q4`` (`state.guarded_lease_q4`)."""
    promised, acc_lease, own_id, ownp = lease
    P = n_proposers
    if guard_q4 is None:
        guard_q4 = lease_q4
    up = up > 0

    # -- 1. expiry (each node's own local clock) ---------------------------
    acc_lease = jnp.where(acc_lease >= ((aclk + 1) << PACK_SHIFT), acc_lease, 0)
    own_clk = clock_select(pclk, own_id)                           # [1, bn]
    own_live = ownp >= ((own_clk + 1) << PACK_SHIFT)
    ownp = jnp.where(own_live, ownp, 0)
    own_id = jnp.where(own_live, own_id, NO_PROPOSER)

    # -- 2. release (§7) ---------------------------------------------------
    rel = release
    rel_owner = (rel >= 0) & (own_id == rel)
    rel_ballot = jnp.where(rel_owner, ownp & PACK_MASK, 0)         # [1, bn]
    ownp = jnp.where(rel_owner, 0, ownp)
    own_id = jnp.where(rel_owner, NO_PROPOSER, own_id)
    acc_b = acc_lease & PACK_MASK                                  # [A, bn]
    discard = up & (rel_ballot > 0) & (acc_b == rel_ballot)
    acc_lease = jnp.where(discard, 0, acc_lease)
    acc_b = jnp.where(discard, 0, acc_b)

    # -- 3. prepare (§3.2) -------------------------------------------------
    att = attempt
    has_att = att >= 0
    ballot = jnp.where(has_att, (t + 1) * P + att, 0)              # [1, bn]
    att_owns = has_att & (own_id == att)
    grant = up & has_att & (ballot >= promised)
    is_open = grant & (
        (acc_b == 0) | ((ballot_proposer(acc_b, P) == att) & att_owns)
    )
    opens = jnp.sum(is_open.astype(jnp.int32), axis=0, keepdims=True)
    won = opens >= majority
    promised = jnp.where(grant, ballot, promised)

    # -- 4. propose (§3.4) + proposer update -------------------------------
    # acceptor timers restart on THEIR clocks; the winner's own belief runs
    # the guarded (discounted) timespan on ITS clock — the §4 drift guard
    accept = grant & won
    acc_lease = jnp.where(accept, pack_pair(aclk + lease_q4, ballot), acc_lease)
    att_clk = clock_select(pclk, att)                              # [1, bn]
    viol = won & (ownp > 0) & (own_id != att)  # would-be second believer
    own_id = jnp.where(won, att, own_id)
    ownp = jnp.where(won, pack_pair(att_clk + guard_q4, ballot), ownp)

    lease_out = (promised, acc_lease, own_id, ownp)
    owner_count = (ownp > 0).astype(jnp.int32) + viol.astype(jnp.int32)
    return lease_out, owner_count


def _default_clocks(t, n_proposers: int, n_acceptors: int):
    """Drift-free local-clock columns at tick ``t``: every node reads
    ``4t`` local quarter-ticks — the rate-1 special case."""
    return (
        rate1_clock(t, n_proposers)[:, None],
        rate1_clock(t, n_acceptors)[:, None],
    )


def lease_step_ref(
    state: LeaseArrayState,
    t,                # scalar int32 tick
    attempt,          # [N] int32 proposer id attempting each cell (-1 = none)
    release,          # [N] int32 proposer id releasing each cell (-1 = none)
    acc_up,           # [A] bool/int32 acceptor reachability this tick
    *,
    majority: int,
    lease_q4: int,    # lease timespan in quarter-ticks
    guard_q4: int = None,  # drift-guarded proposer timespan (default lease_q4)
    pclk=None,        # [P] int32 proposer local clocks (default: 4t, no drift)
    aclk=None,        # [A] int32 acceptor local clocks (default: 4t, no drift)
) -> tuple[LeaseArrayState, jnp.ndarray]:
    """Advance every cell one tick; returns (new_state, owner_count[N]).
    Public-format wrapper over `sync_tick_math` (packs, ticks, unpacks)."""
    P = state.n_proposers
    dp, da = _default_clocks(t, P, state.n_acceptors)
    lease, count = sync_tick_math(
        tuple(pack_state(state)),
        t,
        jnp.asarray(attempt, jnp.int32)[None, :],
        jnp.asarray(release, jnp.int32)[None, :],
        jnp.asarray(acc_up).astype(jnp.int32)[:, None],
        dp if pclk is None else jnp.asarray(pclk, jnp.int32).reshape(P, 1),
        da if aclk is None else
        jnp.asarray(aclk, jnp.int32).reshape(state.n_acceptors, 1),
        majority=majority, lease_q4=lease_q4, n_proposers=P,
        guard_q4=guard_q4,
    )
    return unpack_state(PackedLeaseState(*lease), P), count.reshape(-1)


def link_matrix(m, n_proposers: int, n_acceptors: int) -> jnp.ndarray:
    """Normalize a delay/drop input to the canonical [P, A] link matrix.

    Accepts the asymmetric per-(proposer, acceptor) ``[P, A]`` form or the
    legacy symmetric per-acceptor ``[A]`` form (broadcast over P)."""
    m = jnp.asarray(m).astype(jnp.int32)
    if m.ndim == 1:
        m = jnp.broadcast_to(m[None, :], (n_proposers, n_acceptors))
    if m.shape != (n_proposers, n_acceptors):
        raise ValueError(
            f"delay/drop must be [A]={n_acceptors} or "
            f"[P, A]=({n_proposers}, {n_acceptors}); got {m.shape}"
        )
    return m


def lease_step_delayed_ref(
    state: LeaseArrayState,
    net: NetPlaneState,
    t,                # scalar int32 tick
    attempt,          # [N] int32 proposer id attempting each cell (-1 = none)
    release,          # [N] int32 proposer id releasing each cell (-1 = none)
    acc_up,           # [A] bool/int32 acceptor reachability this tick
    delay,            # [P, A] (or legacy [A]) int32 link delays for sends this tick
    drop,             # [P, A] (or legacy [A]) bool/int32 link drop masks
    *,
    majority: int,
    lease_q4: int,
    round_q4: int,    # timeout-and-abandon horizon in quarter-ticks
    guard_q4: int = None,  # drift-guarded proposer timespan (default lease_q4)
    pclk=None,        # [P] int32 proposer local clocks (default: 4t, no drift)
    aclk=None,        # [A] int32 acceptor local clocks (default: 4t, no drift)
    extend=None,      # [N] int32 proposer id extending its own lease (§6)
    acc_restart=None,  # [A] 0/1: blank this acceptor (diskless crash+restart)
    acc_deaf=None,     # [A] 0/1: acceptor inside its post-restart M-wait
    prop_restart=None,  # [P] 0/1: bump this proposer's restart counter
    prop_rc=None,      # [P] running restart counters (the ballot carve's rc)
) -> tuple[LeaseArrayState, NetPlaneState, jnp.ndarray]:
    """One tick of the delayed (in-flight message) model; pure-jnp oracle.

    Returns (new_state, new_net, owner_count[N]). The whole tick body lives
    in `netplane.delayed_tick_math`, which the Pallas kernel shares.

    The crash/restart columns are delayed-model only — a restart blanks
    the in-flight response slots and opens a multi-tick deaf window, both
    of which need the net plane to exist (the sync core has no restart
    path). Pass the per-tick columns of the scenario's ``acc_restart``/
    ``prop_restart`` planes plus the engine-accumulated deaf/counter
    columns; giving any of them threads all four (absent ones as zeros,
    a bit-exact no-op).
    """
    A, N = state.highest_promised.shape
    P = state.n_proposers
    dp, da = _default_clocks(t, P, A)
    adv = {}
    if extend is not None:
        adv["extend"] = jnp.asarray(extend, jnp.int32).reshape(1, N)
    if any(x is not None for x in (acc_restart, acc_deaf, prop_restart,
                                   prop_rc)):
        col = lambda x, rows: (
            jnp.zeros((rows, 1), jnp.int32) if x is None
            else jnp.asarray(x, jnp.int32).reshape(rows, 1)
        )
        adv = dict(
            acc_restart=col(acc_restart, A), acc_deaf=col(acc_deaf, A),
            prop_restart=col(prop_restart, P), prop_rc=col(prop_rc, P),
        )
    lease, netp, count = delayed_tick_math(
        tuple(pack_state(state)), tuple(net), t,
        jnp.asarray(attempt, jnp.int32).reshape(1, N),
        jnp.asarray(release, jnp.int32).reshape(1, N),
        jnp.asarray(acc_up).astype(jnp.int32)[:, None],
        dp if pclk is None else jnp.asarray(pclk, jnp.int32).reshape(P, 1),
        da if aclk is None else jnp.asarray(aclk, jnp.int32).reshape(A, 1),
        pack_link(link_matrix(delay, P, A), link_matrix(drop, P, A)),
        majority=majority, lease_q4=lease_q4, round_q4=round_q4,
        n_proposers=P, guard_q4=guard_q4, **adv,
    )
    return (
        unpack_state(PackedLeaseState(*lease), P),
        NetPlaneState(*netp),
        count.reshape(N),
    )


def owner_row(state: LeaseArrayState) -> jnp.ndarray:
    """Per-cell owner id (or NO_PROPOSER). With the at-most-one-owner
    invariant intact there is at most one set bit per column."""
    p_ids = jnp.arange(state.n_proposers, dtype=jnp.int32)[:, None]
    return jnp.max(
        jnp.where(state.owner_mask > 0, p_ids, NO_PROPOSER), axis=0
    )
