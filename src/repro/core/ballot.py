"""Ballot numbers (§2): globally unique, monotonically increasing per
proposer. Composed of (run counter | restart counter | proposer id) with the
run counter at the most significant end; the restart counter is persisted to
stable storage by *proposers* (the only disk touch in the whole protocol —
acceptors are diskless)."""
from __future__ import annotations

from dataclasses import dataclass
from functools import total_ordering


@total_ordering
@dataclass(frozen=True)
class Ballot:
    run: int
    restart: int
    proposer_id: int

    def _key(self):
        return (self.run, self.restart, self.proposer_id)

    def __lt__(self, other: "Ballot") -> bool:
        return self._key() < other._key()

    def __eq__(self, other) -> bool:
        return isinstance(other, Ballot) and self._key() == other._key()

    def __hash__(self):
        return hash(self._key())

    def __repr__(self):
        return f"B({self.run}.{self.restart}.{self.proposer_id})"


class BallotGenerator:
    """NextBallotNumber(). ``restart`` comes from stable storage; ``run``
    resets on restart — uniqueness holds because restart strictly grows."""

    def __init__(self, proposer_id: int, restart_counter: int) -> None:
        self.proposer_id = proposer_id
        self.restart = restart_counter
        self.run = 0

    def next(self, at_least: "Ballot | None" = None) -> Ballot:
        self.run += 1
        if at_least is not None and at_least.run >= self.run:
            # jump past a higher ballot observed in a reject (liveness aid)
            self.run = at_least.run + 1
        return Ballot(self.run, self.restart, self.proposer_id)
