"""A PaxosLease *cell* (§2): n acceptors + any number of proposers, wired
over a SimEnv (or any object with the same interface).

``LeaseNode`` realizes the practical deployment of §2 ("nodes often act as
proposers and acceptors") and enforces the two restart rules:
  - acceptor role: blank RAM + deaf for M seconds before rejoining (§3)
  - proposer role: restart counter incremented on stable storage (§2)
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

from ..configs.paxoslease_cell import CellConfig
from ..sim.env import SimEnv
from .acceptor import Acceptor
from .invariant import LeaseMonitor
from .messages import PrepareRequest, ProposeRequest, Release
from .proposer import Proposer


def acceptor_addr(i: int) -> str:
    return f"acc{i}"


def node_addr(i: int) -> str:
    return f"node{i}"


class LeaseNode:
    def __init__(
        self,
        env: SimEnv,
        node_id: int,
        cfg: CellConfig,
        *,
        monitor: Optional[LeaseMonitor] = None,
        is_acceptor: bool = True,
        is_proposer: bool = True,
        clock_rate: float = 1.0,
        acceptor_addrs: Optional[list[str]] = None,
        hint_addrs: Optional[list[str]] = None,  # §7 release hints to peers
        skip_restart_wait: bool = False,  # for the test PROVING M-wait necessity
    ) -> None:
        self.env = env
        self.node_id = node_id
        self.cfg = cfg
        self.addr = node_addr(node_id)
        self.crashed = False
        self.rejoin_deadline = 0.0  # global; enforced via deafness below
        self.skip_restart_wait = skip_restart_wait
        env.add_node(self.addr, self._on_message, clock_rate=clock_rate)

        set_timer = lambda d, fn: env.set_timer(self.addr, d, fn)
        send = lambda dst, msg: env.send(self.addr, dst, msg)

        self.acceptor = (
            Acceptor(node_id, set_timer=set_timer, send=send) if is_acceptor else None
        )
        self.proposer = None
        if is_proposer:
            persisted = env.stable.load(self.addr)
            restart = persisted.get("restart_counter", 0)
            env.stable.store(self.addr, "restart_counter", restart)  # ensure present
            self.proposer = Proposer(
                node_id,
                acceptor_addrs or [],
                cfg,
                set_timer=set_timer,
                send=send,
                random_backoff=env.random_backoff,
                restart_counter=restart,
                monitor=monitor,
                hint_addrs=[a for a in (hint_addrs or []) if a != self.addr],
                local_now=lambda: env.local_now(self.addr),
            )

    # ---------------------------------------------------------------- faults
    def crash(self) -> None:
        """Stop processing; RAM state is lost on restart (diskless).

        A crashed proposer no longer *believes* anything — its ownership
        intervals end here (the monitor is told so the §4 bookkeeping
        reflects reality; the node itself could never act on it anyway)."""
        self.crashed = True
        self.env.network.set_down(self.addr, True)
        if self.proposer is not None:
            for res, st in list(self.proposer._res.items()):
                st.want = False
                for attr in ("renew_timer", "retry_timer"):
                    self.proposer._cancel(st, attr)
                if st.round is not None:
                    self.proposer._cancel(st.round, "round_timer")
                    self.proposer._cancel(st.round, "lease_timer")
                if st.owner:
                    self.proposer._set_owner(res, st, False)

    def restart(self) -> None:
        """Blank acceptor state; deaf for M before rejoining (§3). The
        proposer role persists only its restart counter."""
        assert self.crashed
        if self.acceptor is not None:
            self.acceptor.restart()
        if self.proposer is not None:
            persisted = self.env.stable.load(self.addr)
            rc = persisted.get("restart_counter", 0) + 1
            self.env.stable.store(self.addr, "restart_counter", rc)
            self.proposer.ballots.restart = rc
            self.proposer.ballots.run = 0
            self.proposer._res.clear()  # RAM state gone; ownership forgotten
        wait = 0.0 if self.skip_restart_wait else self.cfg.max_lease_time
        self.rejoin_deadline = self.env.now + wait
        self.env.set_timer(self.addr, 0.0, lambda: None)  # keep scheduler moving

        def rejoin() -> None:
            if self.env.now + 1e-9 < self.rejoin_deadline:
                return  # a later restart extended the deaf window
            self.crashed = False
            self.env.network.set_down(self.addr, False)

        self.env.sched.at(self.rejoin_deadline, rejoin)

    # -------------------------------------------------------------- dispatch
    def _on_message(self, msg, src: str) -> None:
        if self.crashed:
            return
        if isinstance(msg, (PrepareRequest, ProposeRequest, Release)):
            if self.acceptor is not None:
                self.acceptor.handle(msg, src)
            return
        if self.proposer is not None:
            self.proposer.handle(msg, src)


@dataclass
class Cell:
    env: SimEnv
    cfg: CellConfig
    nodes: list[LeaseNode]
    monitor: LeaseMonitor

    @property
    def proposers(self) -> list[LeaseNode]:
        return [n for n in self.nodes if n.proposer is not None]

    def node(self, i: int) -> LeaseNode:
        return self.nodes[i]


def build_cell(
    cfg: CellConfig,
    *,
    n_proposers: Optional[int] = None,
    seed: int = 0,
    net=None,
    clock_rates: Optional[dict[int, float]] = None,
    strict_monitor: bool = True,
    combined_roles: bool = True,
) -> Cell:
    """Standard topology: ``n_acceptors`` combined nodes (acceptor+proposer)
    plus optional extra pure proposers (elastic workers)."""
    env = SimEnv(seed=seed, net=net)
    monitor = LeaseMonitor(env, strict=strict_monitor)
    rates = clock_rates or {}
    nodes: list[LeaseNode] = []
    n_prop = n_proposers if n_proposers is not None else cfg.n_acceptors
    if combined_roles:
        acc_addrs = [node_addr(i) for i in range(cfg.n_acceptors)]
        prop_addrs = [node_addr(i) for i in range(n_prop)]
        for i in range(max(cfg.n_acceptors, n_prop)):
            nodes.append(
                LeaseNode(
                    env, i, cfg,
                    monitor=monitor,
                    is_acceptor=i < cfg.n_acceptors,
                    is_proposer=i < n_prop,
                    clock_rate=rates.get(i, 1.0),
                    acceptor_addrs=acc_addrs,
                    hint_addrs=prop_addrs,
                )
            )
    else:  # dedicated acceptor ensemble + detached proposer fleet
        acc_base = 1000
        acc_addrs = [node_addr(acc_base + i) for i in range(cfg.n_acceptors)]
        for i in range(cfg.n_acceptors):
            nodes.append(
                LeaseNode(
                    env, acc_base + i, cfg,
                    monitor=monitor,
                    is_acceptor=True,
                    is_proposer=False,
                    clock_rate=rates.get(acc_base + i, 1.0),
                )
            )
        for i in range(n_prop):
            nodes.append(
                LeaseNode(
                    env, i, cfg,
                    monitor=monitor,
                    is_acceptor=False,
                    is_proposer=True,
                    clock_rate=rates.get(i, 1.0),
                    acceptor_addrs=acc_addrs,
                )
            )
    return Cell(env, cfg, nodes, monitor)
