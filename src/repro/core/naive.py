"""The naive majority-vote lease algorithm from §1 — the paper's baseline.

Proposers start a local timer for T and ask every acceptor; an acceptor with
empty state grants and locks up for T, otherwise rejects. Correct (majority
+ timer ordering) but it BLOCKS: with k proposers racing, acceptors split
and nobody reaches majority until the timers expire — and then they likely
split again. ``benchmarks/bench_contention.py`` measures exactly this
against PaxosLease.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Optional

from ..configs.paxoslease_cell import CellConfig
from ..sim.env import SimEnv
from .invariant import LeaseMonitor


@dataclass(frozen=True)
class NaiveRequest:
    req_id: int
    timespan: float


@dataclass(frozen=True)
class NaiveResponse:
    req_id: int
    granted: bool


class NaiveAcceptor:
    def __init__(self, set_timer: Callable, send: Callable) -> None:
        self._set_timer = set_timer
        self._send = send
        self.locked_by: Optional[int] = None
        self._timer = None

    def on_request(self, msg: NaiveRequest, src: str) -> None:
        if self.locked_by is None:
            self.locked_by = msg.req_id
            self._timer = self._set_timer(msg.timespan, self._expire)
            self._send(src, NaiveResponse(msg.req_id, True))
        else:
            self._send(src, NaiveResponse(msg.req_id, False))

    def _expire(self) -> None:
        self.locked_by = None
        self._timer = None


class NaiveProposer:
    def __init__(
        self, node_id: int, acceptors: list[str], cfg: CellConfig, *,
        set_timer: Callable, send: Callable, random_backoff: Callable, monitor=None,
    ) -> None:
        self.node_id = node_id
        self.acceptors = acceptors
        self.cfg = cfg
        self._set_timer = set_timer
        self._send = send
        self._backoff = random_backoff
        self.monitor = monitor
        self._req_seq = node_id * 1_000_000
        self._cur_req: Optional[int] = None
        self._grants: set[str] = set()
        self._rejects: set[str] = set()
        self.owner = False
        self.want = False
        self.stats = {"attempts": 0, "acquired": 0, "blocked_rounds": 0}

    def acquire(self) -> None:
        self.want = True
        self._try()

    def _try(self) -> None:
        if not self.want or self.owner:
            return
        self._req_seq += 1
        self._cur_req = self._req_seq
        self._grants, self._rejects = set(), set()
        self.stats["attempts"] += 1
        # start local timer BEFORE sending (same safety ordering as PaxosLease)
        self._set_timer(self.cfg.lease_timespan, lambda rid=self._cur_req: self._expire(rid))
        self._owned_req: Optional[int] = None
        for a in self.acceptors:
            self._send(a, NaiveRequest(self._cur_req, self.cfg.lease_timespan))
        self._set_timer(max(4 * self.cfg.rtt_estimate, 0.1), lambda rid=self._cur_req: self._round_check(rid))

    def on_response(self, msg: NaiveResponse, src: str) -> None:
        if msg.req_id != self._cur_req or self.owner:
            return
        (self._grants if msg.granted else self._rejects).add(src)
        if len(self._grants) >= self.cfg.majority:
            self.owner = True
            self._owned_req = msg.req_id
            self.stats["acquired"] += 1
            if self.monitor:
                self.monitor.on_acquire(self.node_id, "R")

    def _round_check(self, rid: int) -> None:
        if self.owner or self._cur_req != rid:
            return
        # blocked: no majority. The naive algorithm can only wait out the
        # acceptors' T timers — there is no overwrite mechanism.
        self.stats["blocked_rounds"] += 1
        self._cur_req = None
        if self.want:
            self._set_timer(self._backoff(self.cfg.backoff_min, self.cfg.backoff_max) +
                            self.cfg.lease_timespan, self._try)

    def _expire(self, rid: int) -> None:
        if self.owner and self._owned_req == rid:
            self.owner = False
            if self.monitor:
                self.monitor.on_lose(self.node_id, "R")
            if self.want:
                self._try()


def build_naive_cell(cfg: CellConfig, *, n_proposers: int, seed: int = 0, net=None):
    env = SimEnv(seed=seed, net=net)
    monitor = LeaseMonitor(env)
    acc_addrs = [f"nacc{i}" for i in range(cfg.n_acceptors)]
    acceptors = []
    for i, addr in enumerate(acc_addrs):
        acc = NaiveAcceptor(
            set_timer=lambda d, fn, a=addr: env.set_timer(a, d, fn),
            send=lambda dst, m, a=addr: env.send(a, dst, m),
        )
        env.add_node(addr, lambda m, s, acc=acc: acc.on_request(m, s))
        acceptors.append(acc)
    proposers = []
    for j in range(n_proposers):
        addr = f"nprop{j}"
        p = NaiveProposer(
            j, acc_addrs, cfg,
            set_timer=lambda d, fn, a=addr: env.set_timer(a, d, fn),
            send=lambda dst, m, a=addr: env.send(a, dst, m),
            random_backoff=env.random_backoff,
            monitor=monitor,
        )
        env.add_node(addr, lambda m, s, p=p: p.on_response(m, s))
        proposers.append(p)
    return env, monitor, acceptors, proposers
