"""Proposer (§3 steps 1, 3, 5 + §6 extend + §7 release).

Faithfulness notes:

- The proposer starts its own lease timer at the moment a majority of empty
  prepare responses is in hand, BEFORE broadcasting propose requests — the
  ordering the §4 proof depends on (Fig. 2).
- Votes are counted as *sets of acceptor ids*, not counters, so duplicated
  messages (UDP-style transport) can't double-count.
- Extending (§6) counts a prepare response as "open" also when it carries
  this proposer's own proposal — but only while the proposer still believes
  it is the owner (a restarted proposer lost its timer state and must win a
  fully-empty majority again).
- Only the owner knows it owns the lease. ``on_acquire``/``on_lose`` fire on
  the local transitions; LearnHints are strictly advisory (§3).
- Optional drift guard (beyond-paper, see DESIGN.md): with clock-rate drift
  bounded by eps, the proposer discounts its own timer to T*(1-eps)/(1+eps)
  so it never outlives the acceptors' timers.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Optional

from ..configs.paxoslease_cell import CellConfig
from .ballot import Ballot, BallotGenerator
from .messages import (
    Answer,
    DEFAULT_RESOURCE,
    LearnHint,
    Lease,
    PrepareRequest,
    PrepareResponse,
    Proposal,
    ProposeRequest,
    ProposeResponse,
    Release,
)

IDLE, PREPARING, PROPOSING, DONE = "idle", "preparing", "proposing", "done"


@dataclass
class _Round:
    ballot: Ballot
    round_id: int
    phase: str = PREPARING
    open_from: set = field(default_factory=set)
    rejects: set = field(default_factory=set)
    accepts: set = field(default_factory=set)
    highest_seen: Optional[Ballot] = None
    lease_timer: object = None
    round_timer: object = None
    lease_deadline: Optional[float] = None  # local clock, guarded (§3 step 3)


@dataclass
class _ResState:
    want: bool = False
    renew: bool = True
    timespan: float = 0.0
    round: Optional[_Round] = None
    owner: bool = False
    owner_round_id: int = -1
    last_success_ballot: Optional[Ballot] = None
    owner_deadline: Optional[float] = None  # local clock, guarded expiry
    renew_timer: object = None
    retry_timer: object = None


class Proposer:
    def __init__(
        self,
        node_id: int,
        acceptor_addrs: list[str],
        cfg: CellConfig,
        *,
        set_timer: Callable,
        send: Callable,
        random_backoff: Callable[[float, float], float],
        restart_counter: int = 0,
        monitor=None,
        hint_addrs: Optional[list[str]] = None,
        local_now: Optional[Callable[[], float]] = None,
    ) -> None:
        self.node_id = node_id
        self.acceptors = list(acceptor_addrs)
        self.cfg = cfg
        self._set_timer = set_timer
        self._send = send
        self._backoff = random_backoff
        # optional LOCAL clock read (same drifted clock the timers run on);
        # used only to keep failed-extend retries inside the lease window
        self._local_now = local_now
        self.ballots = BallotGenerator(node_id, restart_counter)
        self.monitor = monitor
        self.hint_addrs = hint_addrs or []
        self._res: dict[str, _ResState] = {}
        self._round_seq = 0
        self.stats = {"rounds": 0, "acquired": 0, "extended": 0, "released": 0, "aborted": 0}

    # ------------------------------------------------------------------ API
    def acquire(self, resource: str = DEFAULT_RESOURCE, timespan: Optional[float] = None,
                renew: bool = True) -> None:
        """Try (and keep trying) to hold the lease on ``resource``."""
        st = self._state(resource)
        st.want = True
        st.renew = renew
        st.timespan = timespan or self.cfg.lease_timespan
        assert st.timespan < self.cfg.max_lease_time, "requires T < M (§2)"
        idle = st.round is None or st.round.phase in (IDLE, DONE)
        if idle and not st.owner and st.retry_timer is None:
            self._start_round(resource)

    def release(self, resource: str = DEFAULT_RESOURCE) -> None:
        """§7: switch to non-owner FIRST, then tell acceptors to discard."""
        st = self._state(resource)
        st.want = False
        self._cancel(st, "renew_timer")
        self._cancel(st, "retry_timer")
        if st.owner:
            self._set_owner(resource, st, False)
            self.stats["released"] += 1
            if st.last_success_ballot is not None:
                for a in self.acceptors:
                    self._send(a, Release(resource, st.last_success_ballot))
                self._hint(resource, "released")
        st.round = None

    def is_owner(self, resource: str = DEFAULT_RESOURCE) -> bool:
        return self._state(resource).owner

    # ------------------------------------------------------------ round flow
    def _state(self, resource: str) -> _ResState:
        return self._res.setdefault(resource, _ResState())

    def _cancel(self, st, attr: str) -> None:
        h = getattr(st, attr)
        if h is not None:
            h.cancel()
            setattr(st, attr, None)

    def _start_round(self, resource: str) -> None:  # §3 step 1
        st = self._state(resource)
        if not st.want:
            return
        self._round_seq += 1
        ballot = self.ballots.next(
            at_least=st.round.highest_seen if st.round else None
        )
        rnd = _Round(ballot=ballot, round_id=self._round_seq)
        st.round = rnd
        self.stats["rounds"] += 1
        rt = self.cfg.round_timeout or max(8 * self.cfg.rtt_estimate, 0.2)
        rnd.round_timer = self._set_timer(rt, lambda r=resource, i=rnd.round_id: self._on_round_timeout(r, i))
        for a in self.acceptors:
            self._send(a, PrepareRequest(resource, ballot))

    def _guarded_timespan(self, t: float) -> float:
        if self.cfg.drift_guard and self.cfg.clock_drift_bound > 0:
            eps = self.cfg.clock_drift_bound
            return t * (1 - eps) / (1 + eps)
        return t

    def on_prepare_response(self, msg: PrepareResponse, src: str) -> None:  # §3 step 3
        st = self._state(msg.resource)
        rnd = st.round
        if rnd is None or rnd.phase != PREPARING or msg.ballot != rnd.ballot:
            return  # some other proposal
        if msg.answer == Answer.REJECT:
            rnd.rejects.add(src)
            if msg.promised is not None:
                rnd.highest_seen = max(rnd.highest_seen or msg.promised, msg.promised)
            if len(rnd.rejects) >= self.cfg.majority:
                self._abort_round(msg.resource)
            return
        counts_as_open = msg.accepted is None or (
            st.owner and msg.accepted.lease.proposer_id == self.node_id  # §6 extend
        )
        if counts_as_open:
            rnd.open_from.add(src)
        if len(rnd.open_from) < self.cfg.majority:
            return
        # majority open: start OUR timer first, then broadcast the proposal
        rnd.phase = PROPOSING
        t_own = self._guarded_timespan(st.timespan)
        if self._local_now is not None:
            rnd.lease_deadline = self._local_now() + t_own
        rnd.lease_timer = self._set_timer(
            t_own, lambda r=msg.resource, i=rnd.round_id: self._on_lease_timeout(r, i)
        )
        proposal = Proposal(rnd.ballot, Lease(self.node_id, st.timespan))
        for a in self.acceptors:
            self._send(a, ProposeRequest(msg.resource, rnd.ballot, proposal))

    def on_propose_response(self, msg: ProposeResponse, src: str) -> None:  # §3 step 5
        st = self._state(msg.resource)
        rnd = st.round
        if rnd is None or rnd.phase != PROPOSING or msg.ballot != rnd.ballot:
            return
        if msg.answer == Answer.REJECT:
            rnd.rejects.add(src)
            return
        rnd.accepts.add(src)
        if len(rnd.accepts) < self.cfg.majority:
            return
        # majority accepted: we hold the lease until OUR timer (started in
        # step 3) expires.
        rnd.phase = DONE  # ignore further (duplicated) accepts
        self._cancel(rnd, "round_timer")
        st.owner_round_id = rnd.round_id
        st.last_success_ballot = rnd.ballot
        st.owner_deadline = rnd.lease_deadline
        was_owner = st.owner
        if not was_owner:
            self._set_owner(msg.resource, st, True)
            self.stats["acquired"] += 1
            self._hint(msg.resource, "acquired")
        else:
            self.stats["extended"] += 1
        if st.renew:
            self._cancel(st, "renew_timer")
            st.renew_timer = self._set_timer(
                st.timespan * self.cfg.renew_fraction,
                lambda r=msg.resource: self._renew(r),
            )

    # ----------------------------------------------------------- timeouts
    def _on_lease_timeout(self, resource: str, round_id: int) -> None:
        """Proposer::OnTimeout — this round's lease window has passed."""
        st = self._state(resource)
        if st.owner and st.owner_round_id == round_id:
            self._set_owner(resource, st, False)
            st.owner_deadline = None
            if st.want:
                self._schedule_retry(resource)
        elif (
            st.round is not None
            and st.round.round_id == round_id
            and st.round.phase == PROPOSING
        ):
            # our own lease window elapsed before a majority accepted: any
            # late accepts must not make us owner — the timer started in
            # step 3 bounds the ownership claim (§3 step 5)
            st.round.phase = DONE

    def _on_round_timeout(self, resource: str, round_id: int) -> None:
        st = self._state(resource)
        if st.round is not None and st.round.round_id == round_id:
            self._abort_round(resource)

    def _abort_round(self, resource: str) -> None:
        """No majority (§5): back off a random amount, retry with a higher
        ballot — the paper's dynamic-deadlock workaround."""
        st = self._state(resource)
        if st.round is not None:
            self._cancel(st.round, "round_timer")
            hs = st.round.highest_seen
            st.round = _Round(  # keep highest_seen for the ballot jump
                ballot=st.round.ballot, round_id=-1, phase=IDLE, highest_seen=hs
            )
        self.stats["aborted"] += 1
        if st.want and not st.owner:
            self._schedule_retry(resource)
        elif st.want and st.owner:
            # failed extend: retry promptly; our lease is still ticking
            self._schedule_retry(resource, fast=True)

    def _schedule_retry(self, resource: str, fast: bool = False) -> None:
        st = self._state(resource)
        if st.retry_timer is not None:
            return
        lo, hi = self.cfg.backoff_min, self.cfg.backoff_max
        if fast:
            lo, hi = lo / 4, hi / 4
        delay = self._backoff(lo, hi)
        if fast and self._local_now is not None and st.owner_deadline is not None:
            # a failed-extend retry landing after the guarded expiry turns
            # the extend into a cold acquire and a handoff; retry no later
            # than halfway into what's left of our own lease window
            remaining = st.owner_deadline - self._local_now()
            delay = min(delay, max(remaining / 2, 0.0))
        st.retry_timer = self._set_timer(delay, lambda r=resource: self._retry(r))

    def _retry(self, resource: str) -> None:
        st = self._state(resource)
        st.retry_timer = None
        if st.want and (st.round is None or st.round.phase in (IDLE, DONE)):
            self._start_round(resource)

    def _renew(self, resource: str) -> None:  # §6
        st = self._state(resource)
        st.renew_timer = None
        if st.want and st.owner:
            self._start_round(resource)

    # ----------------------------------------------------------- plumbing
    def _set_owner(self, resource: str, st: _ResState, owner: bool) -> None:
        st.owner = owner
        if self.monitor is not None:
            if owner:
                self.monitor.on_acquire(self.node_id, resource)
            else:
                self.monitor.on_lose(self.node_id, resource)

    def _hint(self, resource: str, event: str) -> None:
        for addr in self.hint_addrs:
            self._send(addr, LearnHint(resource, self.node_id, event))

    def on_hint(self, msg: LearnHint, src: str) -> None:
        """§7: release hints are advisory — NEVER authoritative for ownership
        — but a 'released' hint for a resource we want lets us retry NOW
        instead of sleeping out the backoff (faster handoff, same safety:
        the prepare/propose round still decides)."""
        if msg.event != "released":
            return
        st = self._res.get(msg.resource)
        if st is not None and st.want and not st.owner:
            self._cancel(st, "retry_timer")
            if st.round is None or st.round.phase in (IDLE, DONE):
                self._start_round(msg.resource)

    def handle(self, msg, src: str) -> bool:
        if isinstance(msg, PrepareResponse):
            self.on_prepare_response(msg, src)
        elif isinstance(msg, ProposeResponse):
            self.on_propose_response(msg, src)
        elif isinstance(msg, LearnHint):
            self.on_hint(msg, src)
        else:
            return False
        return True
