"""Protocol messages (§2). A proposal = (ballot, lease); a lease =
(proposer id, timespan T). Only *timespans* are ever transmitted — never
absolute times — which is why no clock synchrony is needed."""
from __future__ import annotations

import enum
import sys
from dataclasses import dataclass
from typing import Optional

from .ballot import Ballot

DEFAULT_RESOURCE = "R"


class Answer(enum.IntEnum):
    ACCEPT = 0
    REJECT = 1


@dataclass(frozen=True)
class Lease:
    proposer_id: int
    timespan: float  # T — always < M


@dataclass(frozen=True)
class Proposal:
    ballot: Ballot
    lease: Lease


@dataclass(frozen=True)
class PrepareRequest:
    resource: str
    ballot: Ballot


@dataclass(frozen=True)
class PrepareResponse:
    resource: str
    ballot: Ballot
    answer: Answer
    accepted: Optional[Proposal]  # None == 'empty'
    promised: Optional[Ballot] = None  # piggybacked on rejects (liveness aid)


@dataclass(frozen=True)
class ProposeRequest:
    resource: str
    ballot: Ballot
    proposal: Proposal


@dataclass(frozen=True)
class ProposeResponse:
    resource: str
    ballot: Ballot
    answer: Answer


@dataclass(frozen=True)
class Release:
    """§7: release the lease early; acceptors discard state iff the accepted
    ballot matches."""

    resource: str
    ballot: Ballot


@dataclass(frozen=True)
class LearnHint:
    """§3/§7: optional hint ('node i (may have) acquired/released R').
    NEVER authoritative — receivers may use it to wake up or back off, but
    ownership is only ever known to the owner."""

    resource: str
    proposer_id: int
    event: str  # "acquired" | "released"


def message_size_bytes(msg) -> int:
    """Wire-size estimate used by the §8 memory/throughput benchmarks."""
    return sys.getsizeof(msg)
