"""PaxosLease — the paper's contribution (Trencseni, Gazso, Reinhardt 2012):
diskless Paxos-style lease negotiation with no clock-synchrony assumption."""
from .acceptor import Acceptor
from .ballot import Ballot, BallotGenerator
from .cell import Cell, LeaseNode, build_cell
from .invariant import LeaseInvariantViolation, LeaseMonitor
from .messages import (
    Answer,
    DEFAULT_RESOURCE,
    LearnHint,
    Lease,
    PrepareRequest,
    PrepareResponse,
    Proposal,
    ProposeRequest,
    ProposeResponse,
    Release,
)
from .proposer import Proposer

__all__ = [
    "Acceptor", "Answer", "Ballot", "BallotGenerator", "Cell", "DEFAULT_RESOURCE",
    "LearnHint", "Lease", "LeaseInvariantViolation", "LeaseMonitor", "LeaseNode",
    "PrepareRequest", "PrepareResponse", "Proposal", "ProposeRequest",
    "ProposeResponse", "Proposer", "Release", "build_cell",
]
