"""Lease-invariant monitor (§2): "at any given time, there is no more than
one proposer which holds the lease."

Proposers report their LOCAL ownership transitions; the monitor timestamps
them with GLOBAL simulation time (which nodes themselves never see) and
checks that ownership intervals of different proposers never overlap.
This is the referee for every property test — it encodes exactly the claim
proved in §4.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional


@dataclass
class Interval:
    proposer_id: int
    start: float
    end: Optional[float] = None  # None = still owner


class LeaseInvariantViolation(AssertionError):
    pass


class LeaseMonitor:
    def __init__(self, env, *, strict: bool = True) -> None:
        self.env = env
        self.strict = strict
        self.history: dict[str, list[Interval]] = {}
        self.current: dict[str, Interval] = {}
        self.violations: list[str] = []
        self.acquire_times: list[float] = []

    def on_acquire(self, proposer_id: int, resource: str) -> None:
        t = self.env.now
        cur = self.current.get(resource)
        if cur is not None and cur.proposer_id != proposer_id:
            msg = (
                f"LEASE INVARIANT VIOLATED on {resource!r} at t={t:.6f}: "
                f"proposer {proposer_id} acquired while proposer "
                f"{cur.proposer_id} still holds (since t={cur.start:.6f})"
            )
            self.violations.append(msg)
            if self.strict:
                raise LeaseInvariantViolation(msg)
        iv = Interval(proposer_id, t)
        self.current[resource] = iv
        self.history.setdefault(resource, []).append(iv)
        self.acquire_times.append(t)

    def on_lose(self, proposer_id: int, resource: str) -> None:
        t = self.env.now
        cur = self.current.get(resource)
        if cur is not None and cur.proposer_id == proposer_id:
            cur.end = t
            del self.current[resource]
        else:
            # a proposer may lose an ownership the monitor already closed
            for iv in reversed(self.history.get(resource, [])):
                if iv.proposer_id == proposer_id and iv.end is None:
                    iv.end = t
                    break

    # ------------------------------------------------------------- queries
    def owner_of(self, resource: str) -> Optional[int]:
        cur = self.current.get(resource)
        return cur.proposer_id if cur else None

    def total_owned_time(self, resource: str) -> float:
        t = self.env.now
        return sum((iv.end if iv.end is not None else t) - iv.start
                   for iv in self.history.get(resource, []))

    def handoffs(self, resource: str) -> int:
        hist = self.history.get(resource, [])
        return sum(
            1 for a, b in zip(hist, hist[1:]) if a.proposer_id != b.proposer_id
        )

    def assert_clean(self) -> None:
        if self.violations:
            raise LeaseInvariantViolation("\n".join(self.violations))
