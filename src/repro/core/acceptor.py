"""Acceptor (§3 steps 2 & 4): entirely RAM-resident, per-resource state.

State per resource:
  - highest ballot number promised  (never reset except by restart)
  - accepted proposal               (expires after its lease timespan T)

Disklessness: ``restart()`` wipes everything. Safety across restarts is the
node wrapper's job (wait M before rejoining — see ``core.cell.LeaseNode``).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Optional

from .ballot import Ballot
from .messages import (
    Answer,
    PrepareRequest,
    PrepareResponse,
    Proposal,
    ProposeRequest,
    ProposeResponse,
    Release,
)


@dataclass
class _ResState:
    highest_promised: Optional[Ballot] = None
    accepted: Optional[Proposal] = None
    timer: object = None  # TimerHandle for lease expiry


class Acceptor:
    """``set_timer(local_delay, fn) -> handle`` and ``send(dst, msg)`` are
    injected so the same class runs under simulation or a real transport."""

    def __init__(
        self,
        node_id: int,
        *,
        set_timer: Callable,
        send: Callable,
        send_rejects: bool = True,
    ) -> None:
        self.node_id = node_id
        self._set_timer = set_timer
        self._send = send
        self.send_rejects = send_rejects
        self._res: dict[str, _ResState] = {}

    def _state(self, resource: str) -> _ResState:
        return self._res.setdefault(resource, _ResState())

    # ------------------------------------------------------------------ §3.2
    def on_prepare_request(self, msg: PrepareRequest, src: str) -> None:
        st = self._state(msg.resource)
        if st.highest_promised is not None and msg.ballot < st.highest_promised:
            if self.send_rejects:
                self._send(src, PrepareResponse(
                    msg.resource, msg.ballot, Answer.REJECT, None, promised=st.highest_promised
                ))
            return
        st.highest_promised = msg.ballot
        self._send(src, PrepareResponse(msg.resource, msg.ballot, Answer.ACCEPT, st.accepted))

    # ------------------------------------------------------------------ §3.4
    def on_propose_request(self, msg: ProposeRequest, src: str) -> None:
        st = self._state(msg.resource)
        if st.highest_promised is not None and msg.ballot < st.highest_promised:
            if self.send_rejects:
                self._send(src, ProposeResponse(msg.resource, msg.ballot, Answer.REJECT))
            return
        # Accept: discard any previous proposal, (re)start the expiry timer
        # BEFORE sending the response — the order the §4 proof relies on.
        if st.timer is not None:
            st.timer.cancel()
        st.accepted = msg.proposal
        st.timer = self._set_timer(
            msg.proposal.lease.timespan, lambda r=msg.resource, b=msg.ballot: self._on_timeout(r, b)
        )
        self._send(src, ProposeResponse(msg.resource, msg.ballot, Answer.ACCEPT))

    def _on_timeout(self, resource: str, ballot: Ballot) -> None:
        st = self._state(resource)
        if st.accepted is not None and st.accepted.ballot == ballot:
            st.accepted = None
            st.timer = None
        # highest_promised is NEVER reset (except by restart)

    # -------------------------------------------------------------------- §7
    def on_release(self, msg: Release, src: str) -> None:
        st = self._state(msg.resource)
        if st.accepted is not None and st.accepted.ballot == msg.ballot:
            if st.timer is not None:
                st.timer.cancel()
            st.accepted = None
            st.timer = None
        # otherwise do nothing (paper §7)

    # ------------------------------------------------------------- restarts
    def restart(self) -> None:
        """Diskless restart: blank state (the M-wait happens in the node)."""
        for st in self._res.values():
            if st.timer is not None:
                st.timer.cancel()
        self._res.clear()

    # ------------------------------------------------------------- plumbing
    def handle(self, msg, src: str) -> bool:
        if isinstance(msg, PrepareRequest):
            self.on_prepare_request(msg, src)
        elif isinstance(msg, ProposeRequest):
            self.on_propose_request(msg, src)
        elif isinstance(msg, Release):
            self.on_release(msg, src)
        else:
            return False
        return True

    def memory_bytes(self) -> int:
        """Rough per-instance RAM accounting for the §8 benchmark."""
        import sys

        total = 0
        for k, st in self._res.items():
            total += sys.getsizeof(k) + sys.getsizeof(st.highest_promised) + sys.getsizeof(st.accepted)
        return total
