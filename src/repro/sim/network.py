"""Best-effort message transport with loss, duplication, reordering,
variable delay and partitions — the failure model PaxosLease claims to
tolerate (§1: node restarts, splits, loss/reordering, in-transit delays).

Delays and drops are randomized by default; a *policy* hook can pin them
per message instead (``set_delay_policy`` / ``set_drop_policy``), which is
how the lease_array differential referee replays a trace's exact delay/drop
planes through this transport (see ``lease_array.trace.replay_event_sim``).
"""
from __future__ import annotations

import random
from dataclasses import dataclass, field
from typing import Callable, Optional

from .events import Scheduler


@dataclass
class NetConfig:
    delay_min: float = 0.01
    delay_max: float = 0.05
    loss: float = 0.0  # P(drop)
    duplicate: float = 0.0  # P(deliver twice)
    jitter_tail: float = 0.0  # P(huge straggler delay)
    tail_delay: float = 5.0  # straggler delay upper bound


#: loss causes tracked by Network. send-side: the source was crashed, the
#: pair was partitioned, a drop policy said so, or random loss hit.
#: delivery-side: the destination was crashed (or partitioned) mid-flight,
#: or nothing was registered at the address.
DROP_CAUSES = (
    "src_down", "partition", "policy", "loss", "dst_down", "no_handler",
)


class Network:
    def __init__(self, scheduler: Scheduler, cfg: NetConfig, seed: int = 0) -> None:
        self.sched = scheduler
        self.cfg = cfg
        self.rng = random.Random(seed)
        self._handlers: dict[str, Callable] = {}
        self._partitions: set[frozenset] = set()
        self._down: set[str] = set()
        self.sent = 0  # send() calls, whether or not anything got through
        self.delivered = 0  # handler invocations (duplicates count twice)
        self.dropped = {cause: 0 for cause in DROP_CAUSES}
        # (src, dst, msg, now) -> delay in sim-seconds, or None = randomize
        self.delay_policy: Optional[Callable] = None
        # (src, dst, msg, now) -> True to drop at send time
        self.drop_policy: Optional[Callable] = None

    def register(self, addr: str, handler: Callable) -> None:
        self._handlers[addr] = handler

    def set_down(self, addr: str, down: bool = True) -> None:
        (self._down.add if down else self._down.discard)(addr)

    def partition(self, group_a: set[str], group_b: set[str]) -> None:
        for a in group_a:
            for b in group_b:
                self._partitions.add(frozenset((a, b)))

    def heal(self) -> None:
        self._partitions.clear()

    def set_delay_policy(self, fn: Optional[Callable]) -> None:
        """Pin per-message delays: ``fn(src, dst, msg, now) -> float | None``
        (None falls back to the randomized draw)."""
        self.delay_policy = fn

    def set_drop_policy(self, fn: Optional[Callable]) -> None:
        """Pin per-message loss: ``fn(src, dst, msg, now) -> bool``."""
        self.drop_policy = fn

    def _blocked(self, src: str, dst: str) -> bool:
        return frozenset((src, dst)) in self._partitions

    def send(self, src: str, dst: str, msg) -> None:
        self.sent += 1
        if src in self._down:
            self.dropped["src_down"] += 1
            return  # crashed nodes don't speak
        if self._blocked(src, dst):
            self.dropped["partition"] += 1
            return
        if self.drop_policy is not None and self.drop_policy(src, dst, msg, self.sched.now):
            self.dropped["policy"] += 1
            return
        if self.rng.random() < self.cfg.loss:
            self.dropped["loss"] += 1
            return
        if self.delay_policy is not None:
            pinned = self.delay_policy(src, dst, msg, self.sched.now)
            if pinned is not None:  # exactly one copy, deterministic delay
                self.sched.after(
                    pinned, lambda d=dst, s=src, m=msg: self._deliver(s, d, m)
                )
                return
        n_copies = 2 if self.rng.random() < self.cfg.duplicate else 1
        for _ in range(n_copies):
            if self.cfg.jitter_tail and self.rng.random() < self.cfg.jitter_tail:
                delay = self.rng.uniform(self.cfg.delay_max, self.cfg.tail_delay)
            else:
                delay = self.rng.uniform(self.cfg.delay_min, self.cfg.delay_max)
            self.sched.after(delay, lambda d=dst, s=src, m=msg: self._deliver(s, d, m))

    def _deliver(self, src: str, dst: str, msg) -> None:
        if dst in self._down:
            self.dropped["dst_down"] += 1
            return  # crashed mid-flight
        if self._blocked(src, dst):
            self.dropped["partition"] += 1
            return  # partitioned while in transit
        h = self._handlers.get(dst)
        if h is None:
            self.dropped["no_handler"] += 1
            return
        self.delivered += 1
        h(msg, src)

    def stats(self) -> dict:
        """Accounting that distinguishes loss causes. ``sent`` counts send()
        calls; ``delivered`` counts handler invocations (a duplicated message
        can deliver twice, and a message still in the scheduler counts in
        neither ``delivered`` nor ``dropped`` yet)."""
        dropped_total = sum(self.dropped.values())
        return {
            "sent": self.sent,
            "delivered": self.delivered,
            "dropped": dict(self.dropped),
            "dropped_total": dropped_total,
        }
