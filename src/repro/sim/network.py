"""Best-effort message transport with loss, duplication, reordering,
variable delay and partitions — the failure model PaxosLease claims to
tolerate (§1: node restarts, splits, loss/reordering, in-transit delays)."""
from __future__ import annotations

import random
from dataclasses import dataclass, field
from typing import Callable, Optional

from .events import Scheduler


@dataclass
class NetConfig:
    delay_min: float = 0.01
    delay_max: float = 0.05
    loss: float = 0.0  # P(drop)
    duplicate: float = 0.0  # P(deliver twice)
    jitter_tail: float = 0.0  # P(huge straggler delay)
    tail_delay: float = 5.0  # straggler delay upper bound


class Network:
    def __init__(self, scheduler: Scheduler, cfg: NetConfig, seed: int = 0) -> None:
        self.sched = scheduler
        self.cfg = cfg
        self.rng = random.Random(seed)
        self._handlers: dict[str, Callable] = {}
        self._partitions: set[frozenset] = set()
        self._down: set[str] = set()
        self.sent = 0
        self.delivered = 0

    def register(self, addr: str, handler: Callable) -> None:
        self._handlers[addr] = handler

    def set_down(self, addr: str, down: bool = True) -> None:
        (self._down.add if down else self._down.discard)(addr)

    def partition(self, group_a: set[str], group_b: set[str]) -> None:
        for a in group_a:
            for b in group_b:
                self._partitions.add(frozenset((a, b)))

    def heal(self) -> None:
        self._partitions.clear()

    def _blocked(self, src: str, dst: str) -> bool:
        return frozenset((src, dst)) in self._partitions

    def send(self, src: str, dst: str, msg) -> None:
        self.sent += 1
        if src in self._down or self._blocked(src, dst):
            return  # crashed nodes don't speak
        if self.rng.random() < self.cfg.loss:
            return
        n_copies = 2 if self.rng.random() < self.cfg.duplicate else 1
        for _ in range(n_copies):
            if self.cfg.jitter_tail and self.rng.random() < self.cfg.jitter_tail:
                delay = self.rng.uniform(self.cfg.delay_max, self.cfg.tail_delay)
            else:
                delay = self.rng.uniform(self.cfg.delay_min, self.cfg.delay_max)
            self.sched.after(delay, lambda d=dst, s=src, m=msg: self._deliver(s, d, m))

    def _deliver(self, src: str, dst: str, msg) -> None:
        if dst in self._down or self._blocked(src, dst):
            return  # crashed mid-flight or partitioned while in transit
        h = self._handlers.get(dst)
        if h is not None:
            self.delivered += 1
            h(msg, src)
