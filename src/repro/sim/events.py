"""Discrete-event scheduler: the global clock of the simulation.

Nodes never read this clock directly (PaxosLease assumes no synchronized
clocks); only the invariant monitor and the network use global time. Nodes
see time exclusively through their drifted local clocks (``sim.env``).
"""
from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass, field
from typing import Callable, Optional


@dataclass
class TimerHandle:
    fire_at: float
    seq: int
    fn: Optional[Callable] = None
    cancelled: bool = False

    def cancel(self) -> None:
        self.cancelled = True
        self.fn = None


class Scheduler:
    def __init__(self) -> None:
        self._q: list[tuple[float, int, TimerHandle]] = []
        self._seq = itertools.count()
        self.now: float = 0.0

    def at(self, t: float, fn: Callable) -> TimerHandle:
        assert t >= self.now - 1e-12, (t, self.now)
        h = TimerHandle(t, next(self._seq), fn)
        heapq.heappush(self._q, (t, h.seq, h))
        return h

    def after(self, delay: float, fn: Callable) -> TimerHandle:
        return self.at(self.now + max(delay, 0.0), fn)

    def run_until(self, t_end: float) -> None:
        while self._q and self._q[0][0] <= t_end:
            t, _, h = heapq.heappop(self._q)
            self.now = max(self.now, t)
            if not h.cancelled and h.fn is not None:
                fn, h.fn = h.fn, None
                fn()
        self.now = max(self.now, t_end)

    def run_while(self, cond: Callable[[], bool], t_max: float) -> None:
        while self._q and cond() and self._q[0][0] <= t_max:
            t, _, h = heapq.heappop(self._q)
            self.now = max(self.now, t)
            if not h.cancelled and h.fn is not None:
                fn, h.fn = h.fn, None
                fn()

    @property
    def pending(self) -> int:
        return sum(1 for _, _, h in self._q if not h.cancelled)
