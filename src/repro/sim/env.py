"""SimEnv: what a node is allowed to see.

PaxosLease assumes no synchronized clocks: nodes get (a) a local timer whose
rate may drift from true time by a bounded factor, (b) best-effort messaging,
(c) a tiny stable store (proposers persist only their restart counter — the
acceptors are the diskless part). Global time exists only for the network,
the scheduler and the invariant monitor.
"""
from __future__ import annotations

import random
from dataclasses import dataclass
from typing import Callable, Optional

from .events import Scheduler, TimerHandle
from .network import NetConfig, Network


class StableStore:
    """Per-node durable dict that survives crash/restart (proposer restart
    counters only — acceptors never touch it; that is the paper's point)."""

    def __init__(self) -> None:
        self._data: dict[str, dict] = {}
        self.sync_count = 0  # "disk writes" — benchmarked against classic Paxos

    def load(self, node: str) -> dict:
        return dict(self._data.get(node, {}))

    def store(self, node: str, key: str, value) -> None:
        d = self._data.setdefault(node, {})
        if key in d and d[key] == value:
            return  # idempotent re-store: no disk sync happens
        d[key] = value
        self.sync_count += 1


@dataclass
class NodeClock:
    rate: float = 1.0  # local seconds per global second

    def local_duration_to_global(self, d: float) -> float:
        return d / self.rate

    def global_duration_to_local(self, d: float) -> float:
        return d * self.rate


class SimEnv:
    def __init__(self, *, seed: int = 0, net: Optional[NetConfig] = None) -> None:
        self.sched = Scheduler()
        self.network = Network(self.sched, net or NetConfig(), seed=seed)
        self.stable = StableStore()
        self.rng = random.Random(seed + 1)
        self.clocks: dict[str, NodeClock] = {}

    # -- node registration ---------------------------------------------------
    def add_node(self, addr: str, handler: Callable, *, clock_rate: float = 1.0) -> None:
        self.clocks[addr] = NodeClock(clock_rate)
        self.network.register(addr, handler)

    # -- node-visible API ----------------------------------------------------
    def send(self, src: str, dst: str, msg) -> None:
        self.network.send(src, dst, msg)

    def set_timer(self, node: str, local_delay: float, fn: Callable) -> TimerHandle:
        g = self.clocks[node].local_duration_to_global(local_delay)
        return self.sched.after(g, fn)

    def local_now(self, node: str) -> float:
        """The node's own drifted clock reading — the same clock its timers
        run on, never global time (PaxosLease assumes no synchronized
        clocks; a local monotonic read is the same power as a local timer).
        """
        return self.clocks[node].global_duration_to_local(self.sched.now)

    def random_backoff(self, lo: float, hi: float) -> float:
        return self.rng.uniform(lo, hi)

    # -- global (monitor / harness only) --------------------------------------
    @property
    def now(self) -> float:
        return self.sched.now

    def run_until(self, t: float) -> None:
        self.sched.run_until(t)
