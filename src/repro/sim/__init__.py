from .events import Scheduler, TimerHandle
from .network import NetConfig, Network
from .env import SimEnv, StableStore

__all__ = ["NetConfig", "Network", "Scheduler", "SimEnv", "StableStore", "TimerHandle"]
