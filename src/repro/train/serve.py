"""Batched serving engine: prefill + continuous-batching decode.

A fixed pool of batch slots; requests join free slots (their prompt is
prefilled into that slot's cache region), every engine step decodes one
token for all active slots, finished slots are freed immediately. The slot
pool is the serving analogue of the data-shard leases: in the multi-replica
deployment each replica's admission is guarded by its shard of the request
space (see examples/serve_lm.py)."""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from ..models import transformer


@dataclass
class Request:
    rid: int
    prompt: np.ndarray  # (P,) int32
    max_new: int = 16
    out: list = field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, *, slots: int = 4, max_len: int = 256,
                 temperature: float = 0.0, seed: int = 0) -> None:
        assert not cfg.enc_dec, "LM serving only"
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.temperature = temperature
        self.rng = jax.random.PRNGKey(seed)
        self.cache = transformer.init_cache(cfg, slots, max_len)
        self.slot_req: list[Optional[Request]] = [None] * slots
        self.slot_pos = np.zeros(slots, dtype=np.int64)  # next position per slot
        self.queue: list[Request] = []
        self.completed: list[Request] = []
        self.steps = 0

        self._decode = jax.jit(
            lambda p, c, t, pos: transformer.decode_step(cfg, p, c, t, pos)
        )

    # --------------------------------------------------------------- intake
    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _admit(self) -> None:
        for s in range(self.slots):
            if self.slot_req[s] is None and self.queue:
                req = self.queue.pop(0)
                self.slot_req[s] = req
                self._prefill_slot(s, req)

    def _prefill_slot(self, s: int, req: Request) -> None:
        """Feed the prompt token-by-token into this slot's cache lane.

        Positions are per-lane: inactive lanes keep their position frozen, so
        the (harmless) dummy writes land on the slot their next real token
        overwrites. Single-lane prefill through the decode path keeps one
        compiled function for everything (batched prefill is a serving
        optimization measured in §Perf of EXPERIMENTS.md)."""
        for i, tok in enumerate(req.prompt):
            toks = np.zeros((self.slots, 1), np.int32)
            toks[s, 0] = tok
            pos = self.slot_pos.copy()
            pos[s] = i
            logits, self.cache = self._decode(
                self.params, self.cache, jnp.asarray(toks), jnp.asarray(pos, np.int32)
            )
        self.slot_pos[s] = len(req.prompt)
        req._last_logits = np.asarray(logits[s, 0])

    # ---------------------------------------------------------------- decode
    def _sample(self, logits: np.ndarray) -> int:
        if self.temperature <= 0:
            return int(np.argmax(logits))
        self.rng, sub = jax.random.split(self.rng)
        return int(jax.random.categorical(sub, jnp.asarray(logits) / self.temperature))

    def step(self) -> None:
        """One engine tick: admit, decode one token for every active slot."""
        self._admit()
        active = [s for s in range(self.slots) if self.slot_req[s] is not None]
        if not active:
            return
        toks = np.zeros((self.slots, 1), np.int32)
        for s in active:
            req = self.slot_req[s]
            nxt = self._sample(req._last_logits)
            req.out.append(nxt)
            toks[s, 0] = nxt
        pos = self.slot_pos.copy()  # each lane decodes at its own depth
        logits, self.cache = self._decode(
            self.params, self.cache, jnp.asarray(toks), jnp.asarray(pos, np.int32)
        )
        self.steps += 1
        for s in active:
            req = self.slot_req[s]
            req._last_logits = np.asarray(logits[s, 0])
            self.slot_pos[s] += 1
            if len(req.out) >= req.max_new or self.slot_pos[s] >= self.max_len - 1:
                req.done = True
                self.completed.append(req)
                self.slot_req[s] = None

    def run_until_drained(self, max_steps: int = 10_000) -> list[Request]:
        while (self.queue or any(self.slot_req)) and self.steps < max_steps:
            self.step()
        return self.completed
