"""Training loop: jit'd train step (+ optional sharding), microbatch grad
accumulation, lease-guarded (async) checkpointing, crash/restore resume.

Runs the same step function the 512-chip dry-run lowers; on CPU it runs on a
1-device mesh. Fault-tolerance hooks: ``on_step`` (straggler/fault
injection in tests), lease guard for the checkpoint writer, and resume from
the latest checkpoint at construction."""
from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint import AsyncCheckpointer, CheckpointManager, latest_step, restore_checkpoint
from ..configs.base import ModelConfig
from ..data import ShardedLoader, SyntheticTokens
from ..models import init_model, transformer
from ..optim import adamw_init, adamw_update, cosine_schedule


@dataclass
class TrainerConfig:
    steps: int = 100
    batch_size: int = 8
    seq_len: int = 128
    peak_lr: float = 3e-4
    warmup: int = 20
    microbatches: int = 1  # gradient accumulation
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50
    ckpt_async: bool = False
    keep: int = 3
    n_shards: int = 8
    seed: int = 0
    log_every: int = 10


class Trainer:
    def __init__(
        self,
        cfg: ModelConfig,
        tc: TrainerConfig,
        *,
        lease_guard: Optional[Callable[[], bool]] = None,
        owned_shards: Optional[Callable] = None,
        verbose: bool = True,
    ) -> None:
        self.cfg = cfg
        self.tc = tc
        self.verbose = verbose
        self.gen = SyntheticTokens(cfg.vocab_size, tc.seq_len, seed=tc.seed)
        self.loader = ShardedLoader(self.gen, tc.n_shards, tc.batch_size, owned_shards=owned_shards)
        self.step = 0
        self.history: list[dict] = []

        key = jax.random.PRNGKey(tc.seed)
        self.params = init_model(cfg, key)
        self.opt_state = adamw_init(self.params)
        # resume if a checkpoint exists
        if tc.ckpt_dir and latest_step(tc.ckpt_dir) is not None:
            state, step = restore_checkpoint(tc.ckpt_dir)
            self.params = jax.tree.map(
                lambda old, new: jnp.asarray(new, old.dtype), self.params, state["params"]
            )
            self.opt_state = jax.tree.map(
                lambda old, new: jnp.asarray(new, old.dtype), self.opt_state, state["opt"]
            )
            self.step = step
            if verbose:
                print(f"[trainer] resumed from step {step}")

        self.ckpt = None
        self.async_ckpt = None
        if tc.ckpt_dir:
            if tc.ckpt_async:
                self.async_ckpt = AsyncCheckpointer(tc.ckpt_dir, keep=tc.keep, lease_guard=lease_guard)
            else:
                self.ckpt = CheckpointManager(
                    tc.ckpt_dir, every_steps=tc.ckpt_every, keep=tc.keep, lease_guard=lease_guard
                )

        self._train_step = jax.jit(self._make_step(), donate_argnums=(0, 1))

    def _make_step(self):
        cfg, tc = self.cfg, self.tc

        def one_micro(p, batch):
            return jax.value_and_grad(lambda q: transformer.loss_fn(cfg, q, batch), has_aux=True)(p)

        def train_step(params, opt_state, batch):
            if tc.microbatches > 1:
                mb = jax.tree.map(
                    lambda a: a.reshape((tc.microbatches, a.shape[0] // tc.microbatches) + a.shape[1:]),
                    batch,
                )

                def scan_body(acc, b):
                    (loss, metrics), grads = one_micro(params, b)
                    acc = jax.tree.map(jnp.add, acc, grads)
                    return acc, loss

                zero = jax.tree.map(lambda a: jnp.zeros(a.shape, jnp.float32), params)
                gsum, losses = jax.lax.scan(scan_body, zero, mb)
                grads = jax.tree.map(lambda g: g / tc.microbatches, gsum)
                loss = losses.mean()
            else:
                (loss, _metrics), grads = one_micro(params, batch)
            lr = cosine_schedule(
                opt_state["step"], peak_lr=tc.peak_lr, warmup_steps=tc.warmup, total_steps=tc.steps
            )
            params, opt_state, om = adamw_update(params, grads, opt_state, lr=lr)
            return params, opt_state, {"loss": loss, "lr": lr, **om}

        return train_step

    # ------------------------------------------------------------------ run
    def run(self, *, on_step: Optional[Callable[[int, dict], None]] = None) -> list[dict]:
        t_start = time.time()
        while self.step < self.tc.steps:
            batch = self.loader.next_batch()
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            self.params, self.opt_state, metrics = self._train_step(self.params, self.opt_state, batch)
            self.step += 1
            m = {k: float(v) for k, v in metrics.items()}
            m["step"] = self.step
            self.history.append(m)
            if on_step:
                on_step(self.step, m)
            self._maybe_checkpoint()
            if self.verbose and self.step % self.tc.log_every == 0:
                dt = time.time() - t_start
                print(f"[trainer] step {self.step:5d} loss {m['loss']:.4f} "
                      f"lr {m['lr']:.2e} ({dt:.1f}s)", flush=True)
        if self.async_ckpt:
            self.async_ckpt.close()
        return self.history

    def _state_snapshot(self) -> dict:
        return {"params": self.params, "opt": self.opt_state}

    def _maybe_checkpoint(self) -> None:
        if self.ckpt is not None:
            self.ckpt.maybe_save(self.step, self._state_snapshot)
        elif self.async_ckpt is not None and self.step % self.tc.ckpt_every == 0:
            snap = jax.tree.map(np.asarray, self._state_snapshot())
            self.async_ckpt.submit(self.step, snap)
