"""Sharded loader whose shard set can be lease-driven.

``owned_shards`` is a callable so it can be wired straight to a
``ShardWorker.owned`` set from the lease control plane: the loader only
emits batches from shards this worker currently holds, and a shard that
expires mid-epoch simply stops contributing (its new owner resumes it from
the step counter — streams are stateless, see data.synthetic)."""
from __future__ import annotations

from typing import Callable, Iterable, Optional

import numpy as np

from .synthetic import SyntheticTokens


class ShardedLoader:
    def __init__(
        self,
        gen: SyntheticTokens,
        n_shards: int,
        batch_size: int,
        *,
        owned_shards: Optional[Callable[[], Iterable[int]]] = None,
    ) -> None:
        self.gen = gen
        self.n_shards = n_shards
        self.batch_size = batch_size
        self.owned_shards = owned_shards or (lambda: range(n_shards))
        self.step_per_shard: dict[int, int] = {k: 0 for k in range(n_shards)}

    def next_batch(self) -> dict:
        owned = sorted(self.owned_shards())
        if not owned:
            raise RuntimeError("worker owns no shards (lease-starved)")
        per = max(1, self.batch_size // len(owned))
        parts = []
        for k in owned:
            b = self.gen.batch(k, self.step_per_shard[k], per)
            self.step_per_shard[k] += 1
            parts.append(b)
            if sum(p["tokens"].shape[0] for p in parts) >= self.batch_size:
                break
        out = {
            key: np.concatenate([p[key] for p in parts], axis=0)[: self.batch_size]
            for key in parts[0]
        }
        return out
