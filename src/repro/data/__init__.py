from .synthetic import SyntheticTokens
from .loader import ShardedLoader

__all__ = ["ShardedLoader", "SyntheticTokens"]
