import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# Multi-pod dry-run: lower + compile every (architecture x input shape) on
# the production meshes, extract memory/cost/collective analysis, write JSON
# artifacts for the roofline report. The two lines above MUST precede every
# other import (jax locks the device count on first init).
#
# Usage:
#   PYTHONPATH=src python -m repro.launch.dryrun --arch internlm2-1.8b --shape train_4k
#   PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--out-dir artifacts/dryrun]

import argparse
import dataclasses
import json
import pathlib
import time
import traceback

import jax

from ..analysis.costs import cost_analysis_dict
from ..analysis.hlo import parse_collectives
from ..configs import SHAPES, arch_ids, get_config, get_shape, supports_shape
from ..models import frontends, transformer
from . import steps as steps_lib
from .mesh import make_production_mesh


def abstract_opt(cfg, moment_dtype="float32"):
    import jax.numpy as jnp

    dt = jnp.dtype(moment_dtype)
    ab = transformer.abstract_model(cfg)
    mom = jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, dt), ab)
    return {"m": mom, "v": jax.tree.map(lambda x: x, mom), "step": jax.ShapeDtypeStruct((), jnp.int32)}


def _memory_analysis_dict(compiled):
    try:
        ma = compiled.memory_analysis()
    except Exception as e:  # backend may not support it
        return {"error": str(e)}
    if ma is None:
        return {}
    out = {}
    for attr in (
        "argument_size_in_bytes",
        "output_size_in_bytes",
        "temp_size_in_bytes",
        "alias_size_in_bytes",
        "generated_code_size_in_bytes",
    ):
        v = getattr(ma, attr, None)
        if v is not None:
            out[attr] = int(v)
    if not out:
        out["repr"] = str(ma)
    return out


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool, zero1: bool = False,
               rule_overrides=None, unroll: bool = False, microbatches: int = 1,
               param_dtype: str = None, remat: str = None, logits_mode: str = "all",
               moe_ep_hints: bool = False, moment_dtype: str = "float32"):
    """Lower+compile one (arch, shape, mesh) cell; returns the artifact dict.

    The keyword levers are the §Perf hillclimb knobs — each combination is
    recorded as a tagged artifact so before/after deltas are reproducible."""
    cfg = get_config(arch)
    if unroll:
        cfg = dataclasses.replace(cfg, scan_unroll=True)
    if param_dtype:
        cfg = dataclasses.replace(cfg, param_dtype=param_dtype)
    if remat:
        cfg = dataclasses.replace(cfg, remat_policy=remat)
    if moe_ep_hints:
        rule_overrides = {**(rule_overrides or {}), "moe_group": None}
    shape = get_shape(shape_name)
    ok, reason = supports_shape(cfg, shape)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    meta = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_name,
        "kind": shape.kind,
        "n_params": cfg.n_params(),
        "n_params_active": cfg.n_params(active=True),
        "n_matmul_params_active": cfg.matmul_params(active=True),
        "tokens_per_step": shape.tokens_per_step,
    }
    if not ok:
        return {**meta, "status": "skipped", "reason": reason}

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    specs = frontends.input_specs(cfg, shape)
    in_sh, out_sh, rules = steps_lib.step_shardings(
        cfg, shape, mesh, zero1=zero1, rule_overrides=rule_overrides
    )

    from ..parallel.sharding import use_mesh

    t0 = time.time()
    with use_mesh(mesh, {**rules}):
        if shape.kind == "train":
            fn = steps_lib.make_train_step(cfg, microbatches=microbatches)
            args = (transformer.abstract_model(cfg), abstract_opt(cfg, moment_dtype), specs["batch"])
            donate = (0, 1)
        elif shape.kind == "prefill":
            fn = steps_lib.make_prefill_step(cfg, logits_mode=logits_mode)
            args = (transformer.abstract_model(cfg), specs["batch"])
            donate = ()
        else:
            fn = steps_lib.make_decode_step(cfg)
            args = (transformer.abstract_model(cfg), specs["cache"], specs["tokens"], specs["pos"])
            donate = (1,)
        jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh, donate_argnums=donate)
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    ca = cost_analysis_dict(compiled)
    mem = _memory_analysis_dict(compiled)
    trip = {"body": cfg.n_layers}
    coll = parse_collectives(compiled.as_text(), body_trip_counts=trip)
    art = {
        **meta,
        "status": "ok",
        "n_chips": int(n_chips),
        "zero1": zero1,
        "variant": {
            "microbatches": microbatches, "param_dtype": cfg.param_dtype,
            "remat": cfg.remat_policy, "logits_mode": logits_mode,
            "moe_ep_hints": moe_ep_hints, "moment_dtype": moment_dtype,
        },
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "cost_analysis": {
            k: float(v)
            for k, v in ca.items()
            if k in ("flops", "bytes accessed", "transcendentals", "optimal_seconds")
        },
        "memory_analysis": mem,
        "collectives": coll.as_dict(),
    }
    return art


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--zero1", action="store_true")
    ap.add_argument("--unroll", action="store_true")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--param-dtype", default=None)
    ap.add_argument("--remat", default=None)
    ap.add_argument("--logits-mode", default="all", choices=["all", "last"])
    ap.add_argument("--moe-ep-hints", action="store_true")
    ap.add_argument("--moment-dtype", default="float32")
    ap.add_argument("--experts-pod", action="store_true",
                    help="shard the expert axis over the pod axis only (for "
                         "n_experts divisible by pods but not by pod*data)")
    ap.add_argument("--out-dir", default="artifacts/dryrun")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()

    out_dir = pathlib.Path(args.out_dir)
    out_dir.mkdir(parents=True, exist_ok=True)

    cells = []
    if args.all:
        for a in arch_ids():
            for s in SHAPES:
                cells.append((a, s))
    else:
        cells.append((args.arch, args.shape))
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    n_ok = n_skip = n_fail = 0
    for arch, shape in cells:
        for mp in meshes:
            mesh_name = "pod2x16x16" if mp else "pod16x16"
            tag = f"{args.tag}_" if args.tag else ""
            fname = out_dir / f"{tag}{arch}_{shape}_{mesh_name}.json"
            if fname.exists():
                print(f"[dryrun] SKIP (exists) {fname.name}", flush=True)
                continue
            print(f"[dryrun] {arch} x {shape} on {mesh_name} ...", flush=True)
            try:
                art = lower_cell(
                    arch, shape, multi_pod=mp, zero1=args.zero1, unroll=args.unroll,
                    microbatches=args.microbatches, param_dtype=args.param_dtype,
                    remat=args.remat, logits_mode=args.logits_mode,
                    moe_ep_hints=args.moe_ep_hints, moment_dtype=args.moment_dtype,
                    rule_overrides={"experts": ("pod",)} if args.experts_pod else None,
                )
            except Exception:
                art = {
                    "arch": arch, "shape": shape, "mesh": mesh_name,
                    "status": "failed", "traceback": traceback.format_exc(),
                }
            fname.write_text(json.dumps(art, indent=1))
            st = art["status"]
            n_ok += st == "ok"
            n_skip += st == "skipped"
            n_fail += st == "failed"
            msg = f"[dryrun]   -> {st}"
            if st == "ok":
                msg += f" (lower {art['lower_s']}s compile {art['compile_s']}s, " \
                       f"coll {art['collectives']['total_bytes']/1e9:.2f} GB)"
            elif st == "failed":
                msg += "\n" + art["traceback"].splitlines()[-1]
            print(msg, flush=True)
            jax.clear_caches()
    print(f"[dryrun] done: ok={n_ok} skipped={n_skip} failed={n_fail}", flush=True)
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
