"""Step functions (train / prefill / decode) + their sharding trees.

Shared by the dry-run, the training loop and the serving loop so that what we
lower for the 512-chip mesh is exactly what runs.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from ..configs.base import ModelConfig, ShapeConfig
from ..models import frontends, transformer
from ..optim import adamw_init, adamw_update, cosine_schedule
from ..parallel import sharding as shd


# ---------------------------------------------------------------------------
# Step functions
# ---------------------------------------------------------------------------
def make_train_step(cfg: ModelConfig, *, peak_lr=3e-4, warmup=100, total=10000,
                    microbatches: int = 1):
    def train_step(params, opt_state, batch):
        if microbatches > 1:
            # gradient accumulation: peak activation memory / microbatches
            mb = jax.tree.map(
                lambda a: a.reshape((microbatches, a.shape[0] // microbatches) + a.shape[1:]),
                batch,
            )

            def body(acc, b):
                (loss, _m), grads = jax.value_and_grad(
                    lambda p: transformer.loss_fn(cfg, p, b), has_aux=True
                )(params)
                return jax.tree.map(jnp.add, acc, grads), loss

            zero = jax.tree.map(lambda a: jnp.zeros(a.shape, jnp.float32), params)
            gsum, losses = jax.lax.scan(body, zero, mb)
            grads = jax.tree.map(lambda g: g / microbatches, gsum)
            loss = losses.mean()
            metrics = {"ce": loss, "aux": jnp.float32(0.0), "tokens": jnp.float32(0.0)}
        else:
            (loss, metrics), grads = jax.value_and_grad(
                lambda p: transformer.loss_fn(cfg, p, batch), has_aux=True
            )(params)
        lr = cosine_schedule(opt_state["step"], peak_lr=peak_lr, warmup_steps=warmup, total_steps=total)
        params, opt_state, om = adamw_update(params, grads, opt_state, lr=lr)
        out = {"loss": loss, **metrics, **om, "lr": lr}
        return params, opt_state, out

    return train_step


def make_prefill_step(cfg: ModelConfig, *, logits_mode: str = "all"):
    def prefill_step(params, batch):
        logits, cache, _aux = transformer.forward(
            cfg, params, batch, emit_cache=True, logits_mode=logits_mode
        )
        return logits[:, -1:, :], cache

    return prefill_step


def make_decode_step(cfg: ModelConfig):
    def serve_step(params, cache, tokens, pos):
        return transformer.decode_step(cfg, params, cache, tokens, pos)

    return serve_step


# ---------------------------------------------------------------------------
# Logical axes for non-param step inputs
# ---------------------------------------------------------------------------
def batch_axes(cfg: ModelConfig, with_labels: bool) -> dict:
    d: dict = {"tokens": ("batch", None)}
    if with_labels:
        d["labels"] = ("batch", None)
    if cfg.frontend == "vision":
        d["patch_embeds"] = ("batch", None, "embed")
    if cfg.enc_dec:
        d["frames"] = ("batch", None, "embed")
    return d


def cache_axes(cfg: ModelConfig, mesh: Mesh) -> dict:
    """Logical axes for the decode cache; if kv heads don't divide the model
    axis, shard the head_dim instead (partial-dot attention, psum'd by GSPMD)."""
    model_size = mesh.shape.get("model", 1)
    kv_ok = cfg.n_kv_heads % model_size == 0
    kv = ("layers", "batch", None, "kv_heads" if kv_ok else None, None if kv_ok else "head_tp")
    ax: dict = {}
    if cfg.attention_free:
        return {
            "wkv": ("layers", "batch", "rwkv_heads", None, None),
            "tm_prev": ("layers", "batch", "embed"),
            "cm_prev": ("layers", "batch", "embed"),
        }
    ax["k"] = kv
    ax["v"] = kv
    ax["slot_pos"] = ("layers", "batch", None)
    if cfg.hybrid_parallel_ssm:
        ax["ssm"] = ("layers", "batch", "ssm_inner", None)
    if cfg.enc_dec:
        ax["ck"] = kv
        ax["cv"] = kv
    return ax


CACHE_RULES = {"head_tp": "model", "rwkv_heads": "model"}


# ---------------------------------------------------------------------------
# Sharding trees per step kind
# ---------------------------------------------------------------------------
def _ns(mesh, spec):
    return NamedSharding(mesh, spec)


def param_shardings(cfg: ModelConfig, mesh: Mesh, rules: dict):
    axes = transformer.model_axes(cfg)
    ab = transformer.abstract_model(cfg)
    return shd.tree_shardings(mesh, rules, axes, ab)


def opt_shardings(cfg: ModelConfig, mesh: Mesh, rules: dict, *, zero1: bool):
    axes = transformer.model_axes(cfg)
    ab = transformer.abstract_model(cfg)

    def go(ax, a):
        if isinstance(a, dict):
            return {k: go(ax[k], a[k]) for k in a}
        lax_ = shd.zero1_axes(ax, a.shape, mesh, rules) if zero1 else ax
        return _ns(mesh, shd.spec_for(mesh, rules, lax_, a.shape))

    moment = go(axes, ab)
    return {"m": moment, "v": moment, "step": _ns(mesh, PartitionSpec())}


def tree_of_shardings(mesh, rules, axes_tree, spec_tree):
    def go(ax, sp):
        if isinstance(sp, dict):
            return {k: go(ax[k], sp[k]) for k in sp}
        return _ns(mesh, shd.spec_for(mesh, rules, ax, sp.shape))

    return go(axes_tree, spec_tree)


def step_shardings(
    cfg: ModelConfig,
    shape: ShapeConfig,
    mesh: Mesh,
    *,
    zero1: bool = False,
    rule_overrides: Optional[dict] = None,
):
    """Returns (in_shardings, out_shardings) pytrees for the step of ``shape.kind``."""
    rules = shd.make_rules(mesh, {**CACHE_RULES, **(rule_overrides or {})})
    p_sh = param_shardings(cfg, mesh, rules)
    specs = frontends.input_specs(cfg, shape)
    scalar = _ns(mesh, PartitionSpec())

    if shape.kind == "train":
        o_sh = opt_shardings(cfg, mesh, rules, zero1=zero1)
        b_sh = tree_of_shardings(mesh, rules, batch_axes(cfg, True), specs["batch"])
        metrics_sh = {
            k: scalar for k in ["loss", "ce", "aux", "tokens", "grad_norm", "lr"]
        }
        return (p_sh, o_sh, b_sh), (p_sh, o_sh, metrics_sh), rules

    if shape.kind == "prefill":
        b_sh = tree_of_shardings(mesh, rules, batch_axes(cfg, False), specs["batch"])
        c_sh = tree_of_shardings(
            mesh, rules, cache_axes(cfg, mesh), frontends.input_specs(
                cfg, ShapeConfig(shape.name, "decode", shape.seq_len, shape.global_batch)
            )["cache"],
        )
        logits_sh = _ns(mesh, shd.spec_for(mesh, rules, ("batch", None, "vocab"), (shape.global_batch, 1, cfg.vocab_size)))
        return (p_sh, b_sh), (logits_sh, c_sh), rules

    # decode
    c_sh = tree_of_shardings(mesh, rules, cache_axes(cfg, mesh), specs["cache"])
    tok_sh = _ns(mesh, shd.spec_for(mesh, rules, ("batch", None), (shape.global_batch, 1)))
    logits_sh = _ns(mesh, shd.spec_for(mesh, rules, ("batch", None, "vocab"), (shape.global_batch, 1, cfg.vocab_size)))
    return (p_sh, c_sh, tok_sh, scalar), (logits_sh, c_sh), rules


def make_optimizer_state(cfg: ModelConfig, params):
    return adamw_init(params)
