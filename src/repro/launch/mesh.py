"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state. Single pod = (data=16, model=16) -> 256 chips;
multi-pod = (pod=2, data=16, model=16) -> 512 chips.
"""
from __future__ import annotations

import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    import jax

    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices but only {len(devices)} present; "
            "the dry-run sets XLA_FLAGS=--xla_force_host_platform_device_count=512"
        )
    dev_array = np.array(devices[:n]).reshape(shape)
    from jax.sharding import Mesh

    return Mesh(dev_array, axes)


def make_local_mesh(axes=("data", "model")):
    """1x1 mesh on the real local device(s) — used by runnable examples."""
    import jax

    from jax.sharding import Mesh

    n = len(jax.devices())
    shape = (n,) + (1,) * (len(axes) - 1)
    return Mesh(np.array(jax.devices()).reshape(shape), axes)
