"""Production training launcher.

Single-host (CPU/demo) mode runs immediately; multi-host mode documents the
jax.distributed wiring (1 process per host; the PaxosLease control ensemble
runs on the first ``n_acceptors`` hosts' CPUs, every host is a proposer).

  PYTHONPATH=src python -m repro.launch.train --arch lm20m --steps 100
  PYTHONPATH=src python -m repro.launch.train --arch granite-3-8b --reduced --steps 20
"""
from __future__ import annotations

import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="lm20m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch-size", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--ckpt-async", action="store_true")
    ap.add_argument("--coordinator", default="process", choices=["process", "none"],
                    help="'process': in-process lease cell guards the ckpt writer")
    args = ap.parse_args()

    from repro.configs import DEFAULT_CELL, get_config, reduced
    from repro.train import Trainer, TrainerConfig

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)

    lease_guard = None
    if args.coordinator == "process" and args.ckpt_dir:
        # single-host deployment still runs the real protocol (loopback cell):
        # the trainer only writes checkpoints while it holds the writer lease.
        from repro.cluster.coordinator import CKPT_RESOURCE, build_coordinated_cluster

        cell, _ = build_coordinated_cluster(DEFAULT_CELL, n_workers=0, seed=0)
        node = cell.proposers[0]
        node.proposer.acquire(CKPT_RESOURCE, timespan=DEFAULT_CELL.lease_timespan)
        cell.env.run_until(2.0)

        def lease_guard() -> bool:
            cell.env.run_until(cell.env.now + 0.05)  # let renewals tick
            return node.proposer.is_owner(CKPT_RESOURCE)

    tc = TrainerConfig(
        steps=args.steps, batch_size=args.batch_size, seq_len=args.seq_len,
        microbatches=args.microbatches, ckpt_dir=args.ckpt_dir,
        ckpt_every=args.ckpt_every, ckpt_async=args.ckpt_async,
        log_every=max(args.steps // 20, 1),
    )
    tr = Trainer(cfg, tc, lease_guard=lease_guard)
    hist = tr.run()
    print(f"done: loss {hist[0]['loss']:.4f} -> {hist[-1]['loss']:.4f}")


if __name__ == "__main__":
    main()
