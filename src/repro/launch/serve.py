"""Serving launcher: batched continuous-batching engine over a config.

  PYTHONPATH=src python -m repro.launch.serve --arch lm20m --requests 8
"""
from __future__ import annotations

import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="lm20m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    args = ap.parse_args()

    import jax
    import numpy as np

    from repro.configs import get_config, reduced
    from repro.models import init_model
    from repro.train.serve import Request, ServeEngine

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    params = init_model(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, slots=args.slots, max_len=args.max_len)
    rng = np.random.default_rng(0)
    for rid in range(args.requests):
        plen = int(rng.integers(2, 12))
        eng.submit(Request(rid=rid,
                           prompt=rng.integers(0, cfg.vocab_size, plen).astype(np.int32),
                           max_new=args.max_new))
    done = eng.run_until_drained()
    toks = sum(len(r.out) for r in done)
    print(f"served {len(done)} requests / {toks} tokens in {eng.steps} engine steps")


if __name__ == "__main__":
    main()
