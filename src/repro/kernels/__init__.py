"""Pallas TPU kernels for the framework's perf-critical compute layers.

The paper (PaxosLease) has no kernel-level contribution — these serve the
data plane's hot spots:

  flash_attention/  GQA causal/SWA flash attention (online softmax, VMEM
                    scratch accumulators, pl.when block-skip for SWA)
  rwkv6/            chunked WKV6 linear recurrence (MXU matmul form, fp32
                    VMEM state tile carried across sequential grid steps)

Each package has kernel.py (pl.pallas_call + BlockSpec), ops.py (jit'd
wrapper) and ref.py (pure-jnp oracle); validated on CPU with interpret=True
(tests/test_kernels_*.py sweep shapes and dtypes).
"""
