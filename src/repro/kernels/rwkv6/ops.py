"""jit'd public wrapper for the WKV6 kernel, (B, S, H, N) layout."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import wkv6_bhsn


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv6(
    r: jax.Array,  # (B, S, H, N)
    k: jax.Array,
    v: jax.Array,
    logw: jax.Array,
    u: jax.Array,  # (H, N)
    *,
    chunk: int = 32,
    interpret: bool = True,
) -> jax.Array:
    b, s, h, n = r.shape
    fold = lambda a: a.transpose(0, 2, 1, 3).reshape(b * h, s, n)
    ue = jnp.broadcast_to(u[None], (b, h, n)).reshape(b * h, n)
    o = wkv6_bhsn(fold(r), fold(k), fold(v), fold(logw), ue, chunk=chunk, interpret=interpret)
    return o.reshape(b, h, s, n).transpose(0, 2, 1, 3)
