"""Pallas TPU kernel for the chunked WKV6 recurrence (RWKV6 "Finch").

Grid: (B*H, n_chunks); the chunk dimension is sequential and the (N x N)
key->value state lives in fp32 VMEM scratch across chunks. Within a chunk
everything is matmul form (MXU):

    o_intra = tril_strict( (r * e^{cum_ex}) @ (k * e^{-cum})^T ) @ v
              + diag(r . u . k) v
    o_inter = (r * e^{cum_ex}) @ S
    S'      = diag(e^{cum_end}) S + (k * e^{cum_end - cum})^T @ v

Numerics (TPU adaptation vs. the paper-exact pairwise form used by the
oracle in ``repro.models.rwkv6.wkv_chunked``): ``k * e^{-cum}`` can overflow
when the cumulative decay within a chunk is extreme, so ``cum`` is clamped
to >= -CAP (CAP=30). Terms affected by the clamp carry a factor < e^-30 —
below bf16/f32 relevance. Chunk length is kept at 32 (also bounds the clamp
error); the N x N state tile (64 x 64 fp32 = 16 KiB) sits in VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu

    _VMEM = pltpu.VMEM
except Exception:  # pragma: no cover
    _VMEM = None

CAP = 30.0


def _wkv_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, o_ref, s_scr, *, chunk: int, n: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        s_scr[...] = jnp.zeros_like(s_scr[...])

    r = r_ref[0].astype(jnp.float32)  # (Lc, N)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    logw = w_ref[0].astype(jnp.float32)  # <= 0
    u = u_ref[0].astype(jnp.float32)  # (1, N)

    cum = jnp.cumsum(logw, axis=0)  # (Lc, N), decreasing
    cum_ex = cum - logw
    cum_cl = jnp.maximum(cum, -CAP)
    q_in = r * jnp.exp(cum_ex)  # <= |r|
    k_in = k * jnp.exp(-cum_cl)  # bounded by e^CAP
    scores = jax.lax.dot_general(
        q_in, k_in, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    ti = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    si = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    scores = jnp.where(ti > si, scores, 0.0)
    diag = jnp.sum(r * u * k, axis=1, keepdims=True)  # (Lc, 1)
    scores = scores + jnp.where(ti == si, diag, 0.0)
    o_intra = jax.lax.dot_general(
        scores, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    s = s_scr[...]
    o_inter = jax.lax.dot_general(
        q_in, s, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    cum_end = cum[-1:, :]  # (1, N)
    k_dec = k * jnp.exp(cum_end - cum)  # <= |k|
    s_scr[...] = jnp.exp(cum_end).T * s + jax.lax.dot_general(
        k_dec, v, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    o_ref[...] = (o_intra + o_inter).astype(o_ref.dtype)[None]


def wkv6_bhsn(
    r: jax.Array,  # (BH, S, N)
    k: jax.Array,
    v: jax.Array,
    logw: jax.Array,  # (BH, S, N), <= 0
    u: jax.Array,  # (BH, N) bonus, expanded per head
    *,
    chunk: int = 32,
    interpret: bool = True,
) -> jax.Array:
    bh, s, n = r.shape
    assert s % chunk == 0, "pad sequence to a chunk multiple"
    n_chunks = s // chunk
    u3 = u[:, None, :]
    kernel = functools.partial(_wkv_kernel, chunk=chunk, n=n)
    scratch = [] if _VMEM is None else [_VMEM((n, n), jnp.float32)]
    return pl.pallas_call(
        kernel,
        grid=(bh, n_chunks),
        in_specs=[
            pl.BlockSpec((1, chunk, n), lambda h, c: (h, c, 0)),
            pl.BlockSpec((1, chunk, n), lambda h, c: (h, c, 0)),
            pl.BlockSpec((1, chunk, n), lambda h, c: (h, c, 0)),
            pl.BlockSpec((1, chunk, n), lambda h, c: (h, c, 0)),
            pl.BlockSpec((1, 1, n), lambda h, c: (h, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, n), lambda h, c: (h, c, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, n), r.dtype),
        scratch_shapes=scratch,
        interpret=interpret,
    )(r, k, v, logw, u3)
