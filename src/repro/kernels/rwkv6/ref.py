"""Oracle for the WKV6 kernel: the exact sequential recurrence."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def wkv6_ref(r, k, v, logw, u):
    """r,k,v,logw: (BH, S, N); u: (BH, N). Sequential scan — exact."""
    rf, kf, vf, wf, uf = (a.astype(jnp.float32) for a in (r, k, v, logw, u))

    def body(s, inp):
        rt, kt, vt, wt = inp  # (BH, N)
        kv = kt[:, :, None] * vt[:, None, :]  # (BH, N, N)
        o = jnp.einsum("bn,bnm->bm", rt, s + uf[:, :, None] * kv)
        s = jnp.exp(wt)[:, :, None] * s + kv
        return s, o

    bh, seq, n = r.shape
    s0 = jnp.zeros((bh, n, n), jnp.float32)
    xs = tuple(jnp.moveaxis(a, 1, 0) for a in (rf, kf, vf, wf))
    _, outs = jax.lax.scan(body, s0, xs)
    return jnp.moveaxis(outs, 0, 1).astype(r.dtype)
