"""Pallas TPU flash attention (GQA, causal, sliding-window) — forward.

Grid: (B*Hq, n_q_blocks, n_kv_blocks); the kv dimension is innermost and
sequential ("arbitrary") so VMEM scratch accumulators (m, l, acc) carry the
online softmax across kv blocks. GQA is handled in the K/V index_map: query
head h reads kv head h // group_size — no tensor replication.

TPU adaptation notes (vs. the CUDA flash-attention formulation):
  - blocks are (block_q x Dh) / (block_k x Dh) VMEM tiles sized for the MXU
    (multiples of 128 on the matmul dims; Dh < 128 is lane-padded),
  - out-of-window / fully-future blocks are skipped via ``pl.when``
    predication — this realizes the SWA block-skip that the pure-jnp path
    only masks,
  - accumulation in fp32 scratch; inputs may be bf16.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:  # scratch memory space: TPU backend name moved across versions
    from jax.experimental.pallas import tpu as pltpu

    _VMEM = pltpu.VMEM
except Exception:  # pragma: no cover
    _VMEM = None

NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
    *, scale: float, causal: bool, window, block_q: int, block_k: int, n_k: int,
):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr[...], NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr[...])
        acc_scr[...] = jnp.zeros_like(acc_scr[...])

    q_start = qi * block_q
    k_start = ki * block_k
    # Block-level skip: entirely in the future (causal) or behind the window.
    live = jnp.asarray(True)
    if causal:
        live = jnp.logical_and(live, k_start <= q_start + block_q - 1)
    if window is not None:
        live = jnp.logical_and(live, k_start + block_k - 1 > q_start - window)

    @pl.when(live)
    def _compute():
        q = q_ref[0].astype(jnp.float32)  # (block_q, Dh)
        k = k_ref[0].astype(jnp.float32)  # (block_k, Dh)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # (block_q, block_k)
        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = jnp.ones((block_q, block_k), jnp.bool_)
        if causal:
            mask = jnp.logical_and(mask, k_pos <= q_pos)
        if window is not None:
            mask = jnp.logical_and(mask, k_pos > q_pos - window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]  # (block_q, 1)
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_scr[...] = m_new

    @pl.when(ki == n_k - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[...] = (acc_scr[...] / denom).astype(o_ref.dtype)[None]


def flash_attention_bhsd(
    q: jax.Array,  # (BHq, Sq, Dh)
    k: jax.Array,  # (BHkv, Sk, Dh)
    v: jax.Array,
    *,
    causal: bool = True,
    window=None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = True,
) -> jax.Array:
    bhq, sq, dh = q.shape
    bhkv, sk, _ = k.shape
    assert bhq % bhkv == 0
    group = bhq // bhkv
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    assert sq % block_q == 0 and sk % block_k == 0, "pad seq to block multiple"
    n_q, n_k = sq // block_q, sk // block_k
    scale = 1.0 / math.sqrt(dh)

    kernel = functools.partial(
        _flash_kernel,
        scale=scale, causal=causal, window=window,
        block_q=block_q, block_k=block_k, n_k=n_k,
    )
    scratch = []
    if _VMEM is not None:
        scratch = [
            _VMEM((block_q, 1), jnp.float32),
            _VMEM((block_q, 1), jnp.float32),
            _VMEM((block_q, dh), jnp.float32),
        ]
    grid = (bhq, n_q, n_k)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, dh), lambda h, qi, ki: (h, qi, 0)),
            pl.BlockSpec((1, block_k, dh), lambda h, qi, ki, g=group: (h // g, ki, 0)),
            pl.BlockSpec((1, block_k, dh), lambda h, qi, ki, g=group: (h // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, dh), lambda h, qi, ki: (h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((bhq, sq, dh), q.dtype),
        scratch_shapes=scratch,
        interpret=interpret,
    )(q, k, v)
