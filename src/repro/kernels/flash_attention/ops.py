"""jit'd public wrapper: (B, S, H, Dh) layout -> flash kernel layout."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import flash_attention_bhsd


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "block_q", "block_k", "interpret")
)
def flash_attention(
    q: jax.Array,  # (B, Sq, Hq, Dh)
    k: jax.Array,  # (B, Sk, Hkv, Dh)
    v: jax.Array,
    *,
    causal: bool = True,
    window=None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = True,  # False on real TPUs
) -> jax.Array:
    b, sq, hq, dh = q.shape
    _, sk, hkv, _ = k.shape
    qt = q.transpose(0, 2, 1, 3).reshape(b * hq, sq, dh)
    kt = k.transpose(0, 2, 1, 3).reshape(b * hkv, sk, dh)
    vt = v.transpose(0, 2, 1, 3).reshape(b * hkv, sk, dh)
    o = flash_attention_bhsd(
        qt, kt, vt, causal=causal, window=window,
        block_q=block_q, block_k=block_k, interpret=interpret,
    )
    return o.reshape(b, hq, sq, dh).transpose(0, 2, 1, 3)
