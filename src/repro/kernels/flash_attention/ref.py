"""Pure-jnp oracle for the flash attention kernel."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(q, k, v, *, causal=True, window=None):
    """q: (BHq, Sq, Dh); k,v: (BHkv, Sk, Dh) -> (BHq, Sq, Dh)."""
    bhq, sq, dh = q.shape
    bhkv, sk, _ = k.shape
    g = bhq // bhkv
    kf = jnp.repeat(k.astype(jnp.float32), g, axis=0)
    vf = jnp.repeat(v.astype(jnp.float32), g, axis=0)
    s = jnp.einsum("hqd,hkd->hqk", q.astype(jnp.float32), kf) / math.sqrt(dh)
    q_pos = jnp.arange(sq)[:, None]
    k_pos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask[None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("hqk,hkd->hqd", p, vf).astype(q.dtype)
