"""Sharded checkpoint I/O: one .npz per top-level param group + a JSON
manifest. Writes are crash-safe (tmp dir + atomic rename); restore reshards
onto whatever mesh the reader is running (arrays are stored unsharded here —
a multi-host deployment would write per-host shard files keyed by the same
manifest paths)."""
from __future__ import annotations

import hashlib
import json
import os
import pathlib
import shutil
import tempfile
import time
from typing import Optional

import jax
import numpy as np


def _flatten(tree, prefix=()) -> dict:
    out = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten(tree[k], prefix + (str(k),)))
    else:
        out["/".join(prefix)] = tree
    return out


def _unflatten(flat: dict) -> dict:
    tree: dict = {}
    for path, v in flat.items():
        parts = path.split("/")
        t = tree
        for p in parts[:-1]:
            t = t.setdefault(p, {})
        t[parts[-1]] = v
    return tree


def save_checkpoint(ckpt_dir: str | pathlib.Path, step: int, state: dict, *, keep: int = 3) -> pathlib.Path:
    """state: arbitrary pytree-of-dicts (params/opt/extra). Returns final dir."""
    ckpt_dir = pathlib.Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    flat = {k: np.asarray(jax.device_get(v)) for k, v in _flatten(state).items()}
    tmp = pathlib.Path(tempfile.mkdtemp(dir=ckpt_dir, prefix=f".tmp_step{step}_"))
    try:
        np.savez(tmp / "arrays.npz", **flat)
        manifest = {
            "step": int(step),
            "time": time.time(),
            "keys": sorted(flat),
            "shapes": {k: list(v.shape) for k, v in flat.items()},
            "dtypes": {k: str(v.dtype) for k, v in flat.items()},
        }
        manifest["digest"] = hashlib.sha256(
            json.dumps(manifest["shapes"], sort_keys=True).encode()
        ).hexdigest()[:16]
        (tmp / "manifest.json").write_text(json.dumps(manifest, indent=1))
        final = ckpt_dir / f"step_{step:08d}"
        if final.exists():
            shutil.rmtree(final)
        os.rename(tmp, final)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    _gc(ckpt_dir, keep)
    return final


def _gc(ckpt_dir: pathlib.Path, keep: int) -> None:
    steps = sorted(p for p in ckpt_dir.glob("step_*") if p.is_dir())
    for p in steps[:-keep]:
        shutil.rmtree(p, ignore_errors=True)
    for p in ckpt_dir.glob(".tmp_*"):
        shutil.rmtree(p, ignore_errors=True)


def latest_step(ckpt_dir: str | pathlib.Path) -> Optional[int]:
    ckpt_dir = pathlib.Path(ckpt_dir)
    steps = sorted(ckpt_dir.glob("step_*"))
    if not steps:
        return None
    return int(steps[-1].name.split("_")[1])


def restore_checkpoint(ckpt_dir: str | pathlib.Path, step: Optional[int] = None,
                       shardings=None) -> tuple[dict, int]:
    """Returns (state, step). ``shardings``: optional matching pytree of
    NamedShardings to place leaves directly on the mesh (resharding restore)."""
    ckpt_dir = pathlib.Path(ckpt_dir)
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    d = ckpt_dir / f"step_{step:08d}"
    manifest = json.loads((d / "manifest.json").read_text())
    with np.load(d / "arrays.npz") as z:
        flat = {k: z[k] for k in manifest["keys"]}
    state = _unflatten(flat)
    if shardings is not None:
        flat_sh = _flatten(shardings)
        state = _unflatten({
            k: jax.device_put(v, flat_sh[k]) if k in flat_sh else v
            for k, v in flat.items()
        })
    return state, int(manifest["step"])
