"""Checkpoint management with lease-guarded writers and async I/O.

The writer-election problem ("exactly one process should write step-aligned
checkpoints, even across partitions/failovers") is solved with a PaxosLease
instance on ``ckpt-writer``: the holder writes, everyone else doesn't, and a
hung writer loses the lease after T without any fencing protocol. The guard
is injected as a callable so the manager works both under the simulated
control plane and standalone (guard = always-true)."""
from __future__ import annotations

import queue
import threading
from typing import Callable, Optional

from .io import restore_checkpoint, save_checkpoint


class CheckpointManager:
    def __init__(
        self,
        ckpt_dir: str,
        *,
        every_steps: int = 100,
        keep: int = 3,
        lease_guard: Optional[Callable[[], bool]] = None,
    ) -> None:
        self.ckpt_dir = ckpt_dir
        self.every_steps = every_steps
        self.keep = keep
        self.lease_guard = lease_guard or (lambda: True)
        self.saved_steps: list[int] = []
        self.skipped_no_lease = 0

    def maybe_save(self, step: int, state_fn: Callable[[], dict]) -> bool:
        """state_fn is called only if we actually save (avoids device_get)."""
        if step % self.every_steps != 0:
            return False
        if not self.lease_guard():
            self.skipped_no_lease += 1
            return False
        save_checkpoint(self.ckpt_dir, step, state_fn(), keep=self.keep)
        self.saved_steps.append(step)
        return True

    def restore_latest(self, shardings=None):
        return restore_checkpoint(self.ckpt_dir, shardings=shardings)


class AsyncCheckpointer:
    """Background-thread writer: the training loop hands over (step, state)
    snapshots (device_get'ed on the worker thread) and keeps stepping —
    compute/IO overlap. One in-flight save at a time; extra requests are
    coalesced to the newest."""

    def __init__(self, ckpt_dir: str, *, keep: int = 3,
                 lease_guard: Optional[Callable[[], bool]] = None) -> None:
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self.lease_guard = lease_guard or (lambda: True)
        self._q: queue.Queue = queue.Queue(maxsize=1)
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._stop = threading.Event()
        self._busy = threading.Event()
        self.saved_steps: list[int] = []
        self.errors: list[str] = []
        self._thread.start()

    def submit(self, step: int, state: dict) -> bool:
        if not self.lease_guard():
            return False
        try:
            self._q.put_nowait((step, state))
            return True
        except queue.Full:  # coalesce: drop the older pending snapshot
            try:
                self._q.get_nowait()
            except queue.Empty:
                pass
            self._q.put_nowait((step, state))
            return True

    def _run(self) -> None:
        while not self._stop.is_set():
            try:
                step, state = self._q.get(timeout=0.1)
            except queue.Empty:
                continue
            self._busy.set()
            try:
                save_checkpoint(self.ckpt_dir, step, state, keep=self.keep)
                self.saved_steps.append(step)
            except Exception as e:  # pragma: no cover
                self.errors.append(f"step {step}: {e!r}")
            finally:
                self._busy.clear()

    def close(self, *, flush: bool = True) -> None:
        import time

        if flush:
            deadline = time.time() + 30
            while (not self._q.empty() or self._busy.is_set()) and time.time() < deadline:
                time.sleep(0.01)
        self._stop.set()
        self._thread.join(timeout=5)
