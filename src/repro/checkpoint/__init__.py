from .io import latest_step, restore_checkpoint, save_checkpoint
from .manager import AsyncCheckpointer, CheckpointManager

__all__ = [
    "AsyncCheckpointer",
    "CheckpointManager",
    "latest_step",
    "restore_checkpoint",
    "save_checkpoint",
]
