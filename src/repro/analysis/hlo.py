"""Parse collective ops out of compiled HLO text.

``cost_analysis()`` does not expose collective bytes, so we regex the
post-SPMD module: every all-reduce / all-gather / reduce-scatter / all-to-all
/ collective-permute result shape is summed (result-shape bytes are a ring-
transfer proxy for bytes moved per device).

``lax.scan`` lowers to a while loop whose body HLO appears ONCE, so
collectives reachable from a while-body computation are scaled by the trip
count supplied by the caller (= n_layers for the layer scan). Reachability is
computed over the real call graph (``body=%comp``, ``calls=%comp``,
``condition=%comp`` edges) — collectives usually sit inside fusion
computations called from the body, not in the body computation itself.
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all", "collective-permute")

_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
_COMP_RE = re.compile(r"^\s*(?:ENTRY\s+)?%?([\w.\-]+)\s*(?:\([^)]*\))?\s*->.*\{\s*$")
_OP_RE = re.compile(r"=\s*(\([^=]*?\)|\S+)\s+(" + "|".join(COLLECTIVES) + r")(-(start|done))?\(")
_EDGE_RE = re.compile(r"(?:calls|body|condition|to_apply|branch_computations)=\{?%?([\w.\-]+(?:,\s*%?[\w.\-]+)*)\}?")
_BODY_RE = re.compile(r"\bbody=%?([\w.\-]+)")


def _bytes_of_type(tstr: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(tstr):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


@dataclass
class CollectiveStats:
    per_op: dict = field(default_factory=lambda: defaultdict(int))  # op -> bytes
    per_op_count: dict = field(default_factory=lambda: defaultdict(int))
    total_bytes: int = 0

    def as_dict(self):
        return {
            "total_bytes": self.total_bytes,
            "by_op_bytes": dict(self.per_op),
            "by_op_count": dict(self.per_op_count),
        }


def _scan(hlo_text: str):
    """One pass: collectives per computation + call-graph edges + while bodies."""
    current = ""
    found = []  # (comp, op, bytes)
    edges: dict[str, set] = defaultdict(set)
    body_roots: set[str] = set()
    seen_comps: set[str] = set()
    for line in hlo_text.splitlines():
        m = _COMP_RE.match(line)
        if m and "{" in line:
            current = m.group(1)
            seen_comps.add(current)
            continue
        for em in _EDGE_RE.finditer(line):
            for name in em.group(1).split(","):
                edges[current].add(name.strip().lstrip("%"))
        bm = _BODY_RE.search(line)
        if bm:
            body_roots.add(bm.group(1))
        om = _OP_RE.search(line)
        if om:
            tstr, op, _, startdone = om.group(1), om.group(2), om.group(3), om.group(4)
            if startdone == "done":
                continue
            found.append((current, op, _bytes_of_type(tstr)))
    return found, edges, body_roots


def _reachable(roots: set, edges: dict) -> set:
    out, stack = set(), list(roots)
    while stack:
        c = stack.pop()
        if c in out:
            continue
        out.add(c)
        stack.extend(edges.get(c, ()))
    return out

def parse_collectives(hlo_text: str, *, body_trip_counts: dict | None = None) -> CollectiveStats:
    """body_trip_counts: {"body": L} scales every collective reachable from a
    while-loop body by L (the layer-scan trip count). Collectives outside any
    loop (grad sync, logits) count once."""
    mult_default = 1
    trip = 1
    if body_trip_counts:
        trip = max(body_trip_counts.values())
    found, edges, body_roots = _scan(hlo_text)
    in_loop = _reachable(body_roots, edges)
    stats = CollectiveStats()
    for comp, op, nbytes in found:
        mult = trip if comp in in_loop else mult_default
        stats.per_op[op] += nbytes * mult
        stats.per_op_count[op] += mult
        stats.total_bytes += nbytes * mult
    return stats
