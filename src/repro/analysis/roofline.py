"""Analytic roofline model per (arch, shape, mesh).

Why analytic: XLA's HloCostAnalysis counts a ``lax.scan`` body ONCE (verified
empirically — see EXPERIMENTS.md §Dry-run), so compiled cost_analysis under-
counts scanned-layer models by ~n_layers. We therefore derive FLOPs/bytes/
collective-bytes from the configs (every matmul in the model is enumerated
below) and cross-validate against cost_analysis on an UNROLLED reduced config
(tests/test_roofline_validation.py) and against the HLO-parsed collectives.

Terms (per training/serving step):
  compute    = total_FLOPs / (chips * peak_FLOP/s)
  memory     = per_device_HBM_bytes / HBM_bw
  collective = per_device_collective_bytes / link_bw

Hardware: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

from ..configs.base import ModelConfig, ShapeConfig

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

MOE_GROUP = 512  # must match models.moe.moe_dispatch default


@dataclass
class MeshShape:
    pod: int
    data: int
    model: int

    @property
    def chips(self) -> int:
        return self.pod * self.data * self.model

    @property
    def dp(self) -> int:
        return self.pod * self.data


MESHES = {"pod16x16": MeshShape(1, 16, 16), "pod2x16x16": MeshShape(2, 16, 16)}


# ---------------------------------------------------------------------------
# FLOPs (totals across all chips, forward pass; train multiplies below)
# ---------------------------------------------------------------------------
def _attn_flops_fwd(cfg: ModelConfig, batch: int, s_q: int, s_kv_eff: float) -> float:
    """QK^T + PV matmuls, all layers."""
    per_layer = 2 * 2 * batch * cfg.n_heads * cfg.head_dim * s_q * s_kv_eff
    return per_layer * cfg.n_layers


def _rwkv_mix_flops_fwd(cfg: ModelConfig, tokens: float, chunk: int = 32) -> float:
    h = cfg.d_model // cfg.rwkv.head_size
    n = cfg.rwkv.head_size
    per_tok_head = 4 * chunk * n + 4 * n * n  # intra matmuls + state/inter
    return per_tok_head * h * cfg.n_layers * tokens


def _ssm_flops_fwd(cfg: ModelConfig, tokens: float) -> float:
    di, st = cfg.ssm.d_inner, cfg.ssm.state_size
    return 8.0 * di * st * tokens * cfg.n_layers  # elementwise scan + C/B contractions


def _moe_dispatch_flops_fwd(cfg: ModelConfig, tokens: float, group: int = MOE_GROUP) -> float:
    """Dispatch + combine one-hot einsums: each costs 2*T*(E*C)*d with
    E*C ~= group*top_k*capacity per group — LINEAR in the group size."""
    moe = cfg.moe
    slots = group * moe.top_k * moe.capacity_factor  # ~ E*C per group
    return 4.0 * tokens * slots * cfg.d_model * cfg.n_layers


def flops_fwd(cfg: ModelConfig, shape: ShapeConfig, variant: dict | None = None) -> float:
    """Forward FLOPs of one step, totals across chips.

    variant flags (all default off = the naive baseline implementation):
      swa_block_skip — sliding-window block skipping (the Pallas flash
        kernel realizes it; the jnp chunked path computes masked blocks)
      logits_last    — prefill unembeds only the final position
    """
    variant = variant or {}
    b = shape.global_batch
    if shape.kind == "decode":
        toks = float(b)
        mm = 2.0 * cfg.matmul_params(active=True) * toks
        if cfg.attention_free:
            h = cfg.d_model // cfg.rwkv.head_size
            n = cfg.rwkv.head_size
            mix = 4.0 * n * n * h * cfg.n_layers * toks
            return mm + mix
        s_cache = min(shape.seq_len, cfg.sliding_window) if cfg.sliding_window else shape.seq_len
        attn = _attn_flops_fwd(cfg, b, 1, s_cache)
        if cfg.hybrid_parallel_ssm:
            attn += _ssm_flops_fwd(cfg, toks)
        if cfg.enc_dec:
            attn += 2 * 2 * b * cfg.n_heads * cfg.head_dim * 1 * cfg.encoder_seq * cfg.n_layers
        return mm + attn

    toks = float(b * shape.seq_len)
    mm = 2.0 * cfg.matmul_params(active=True) * toks
    extra = 0.0
    if cfg.attention_free:
        extra += _rwkv_mix_flops_fwd(cfg, toks)
    else:
        s_kv = shape.seq_len / 2.0  # causal average
        if cfg.sliding_window and variant.get("swa_block_skip"):
            # the jnp chunked path computes (masked) full blocks; only the
            # Pallas kernel's pl.when block-skip realizes the SWA saving
            s_kv = min(s_kv, float(cfg.sliding_window))
        extra += _attn_flops_fwd(cfg, b, shape.seq_len, s_kv)
        if cfg.hybrid_parallel_ssm:
            extra += _ssm_flops_fwd(cfg, toks)
        if cfg.enc_dec:
            # encoder self-attn (full 1500^2) + decoder cross-attn (S x 1500)
            e = cfg.encoder_seq
            extra += 2 * 2 * b * cfg.n_heads * cfg.head_dim * e * e * cfg.n_encoder_layers
            extra += 2 * 2 * b * cfg.n_heads * cfg.head_dim * shape.seq_len * e * cfg.n_layers
            # encoder matmul params are in matmul_params already
    if cfg.moe is not None:
        extra += _moe_dispatch_flops_fwd(cfg, toks)
    if variant.get("logits_last") and shape.kind == "prefill":
        # unembedding shrinks from T tokens to B tokens
        extra -= 2.0 * cfg.vocab_size * cfg.d_model * (toks - b)
    return mm + extra


_TRAIN_MULT = {"nothing": 3.0, "dots": 10.0 / 3.0, "full": 4.0}


def flops_step(cfg: ModelConfig, shape: ShapeConfig, variant: dict | None = None) -> float:
    variant = variant or {}
    f = flops_fwd(cfg, shape, variant)
    if shape.kind == "train":
        policy = variant.get("remat", cfg.remat_policy)
        return f * _TRAIN_MULT.get(policy, 3.0)
    return f


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """The 6*N*D (or 6*N_active*D) yardstick the assignment asks for."""
    n = cfg.matmul_params(active=True)
    mult = 6.0 if shape.kind == "train" else 2.0
    return mult * n * shape.tokens_per_step


# ---------------------------------------------------------------------------
# Per-device HBM bytes
# ---------------------------------------------------------------------------
def _param_bytes_per_device(cfg: ModelConfig, mesh: MeshShape, *, active_only: bool) -> float:
    n = cfg.n_params(active=active_only)
    # experts shard over dp when divisible; everything else over model only
    if cfg.moe is not None and not active_only:
        moe_p = cfg.n_layers * cfg._moe_params(active=False)
        rest = n - moe_p
        ep = mesh.dp if cfg.moe.n_experts % mesh.dp == 0 else 1
        return moe_p / (ep * mesh.model) + rest / mesh.model
    return n / mesh.model


def hbm_bytes_per_device(cfg: ModelConfig, shape: ShapeConfig, mesh: MeshShape,
                         variant: dict | None = None) -> float:
    variant = variant or {}
    pbytes = 2 if variant.get("param_dtype") == "bfloat16" else 4
    if shape.kind == "decode":
        p = _param_bytes_per_device(cfg, mesh, active_only=False) * 2  # bf16 read
        cache = _cache_bytes_total(cfg, shape) / mesh.chips * 2  # read + write
        return p + cache
    toks_loc = shape.tokens_per_step / mesh.dp
    policy = variant.get("remat", cfg.remat_policy)
    act_tensors = {"nothing": 16, "dots": 10, "full": 6}.get(policy, 12)
    act = toks_loc * cfg.d_model * cfg.n_layers * act_tensors * 2 * 2  # r+w, bf16
    p_loc = _param_bytes_per_device(cfg, mesh, active_only=False)
    if shape.kind == "prefill":
        return p_loc * 2 + act / 2 + _cache_bytes_total(cfg, shape) / mesh.chips
    # train: bf16 fwd+bwd reads + grad w + adam m,v r/w + master param r/w
    opt_div = mesh.dp if variant.get("zero1") else 1
    param_traffic = p_loc * (2 * 3 + pbytes) + p_loc * (16 + 8) / opt_div
    return param_traffic + act


def _cache_bytes_total(cfg: ModelConfig, shape: ShapeConfig) -> float:
    b = shape.global_batch
    if cfg.attention_free:
        h = cfg.d_model // cfg.rwkv.head_size
        n = cfg.rwkv.head_size
        return cfg.n_layers * b * (h * n * n * 4 + 2 * cfg.d_model * 2)
    sc = min(shape.seq_len, cfg.sliding_window) if cfg.sliding_window else shape.seq_len
    kv = cfg.n_layers * b * sc * cfg.n_kv_heads * cfg.head_dim * 2 * 2
    if cfg.hybrid_parallel_ssm:
        kv += cfg.n_layers * b * cfg.ssm.d_inner * cfg.ssm.state_size * 4
    if cfg.enc_dec:
        kv += cfg.n_layers * b * cfg.encoder_seq * cfg.n_kv_heads * cfg.head_dim * 2 * 2
    return kv


# ---------------------------------------------------------------------------
# Per-device collective bytes
# ---------------------------------------------------------------------------
def collective_bytes_per_device(cfg: ModelConfig, shape: ShapeConfig, mesh: MeshShape,
                                variant: dict | None = None, *,
                                grad_dtype_bytes: int | None = None) -> float:
    variant = variant or {}
    if grad_dtype_bytes is None:
        grad_dtype_bytes = 2 if variant.get("param_dtype") == "bfloat16" else 4
    d = cfg.d_model
    if shape.kind == "decode":
        b_loc = max(shape.global_batch // mesh.dp, 1)
        per_layer = 2 * 2 * b_loc * 1 * d * 2  # 2 TP all-reduces, ring 2x, bf16
        return per_layer * cfg.n_layers
    toks_loc = shape.tokens_per_step / mesh.dp
    tp = 2 * 2 * toks_loc * d * 2 * cfg.n_layers  # fwd; bwd doubles it
    if shape.kind == "train":
        tp *= 2
        n_rep = cfg.n_params(active=False)
        if cfg.moe is not None and cfg.moe.n_experts % mesh.dp == 0:
            n_rep -= cfg.n_layers * cfg._moe_params(active=False)  # EP: no DP grad sync
            # EP all-to-all: tokens*topk*cf*d each way, fwd+bwd
            a2a = 2 * 2 * toks_loc * cfg.moe.top_k * cfg.moe.capacity_factor * d * 2 * cfg.n_layers
            tp += a2a
        dp_grad = 2 * (n_rep / mesh.model) * grad_dtype_bytes
        return tp + dp_grad
    return tp


# ---------------------------------------------------------------------------
# Terms
# ---------------------------------------------------------------------------
def roofline_terms(cfg: ModelConfig, shape: ShapeConfig, mesh: MeshShape,
                   variant: dict | None = None,
                   coll_bytes_parsed: float | None = None) -> dict:
    """When available, the HLO-parsed per-device collective bytes from the
    compiled dry-run artifact override the analytic estimate (GSPMD's chosen
    collective schedule — e.g. weight-gather vs activation all-reduce — is
    what actually runs; the analytic formula documents the Megatron-style
    expectation)."""
    f = flops_step(cfg, shape, variant)
    hbm = hbm_bytes_per_device(cfg, shape, mesh, variant)
    coll = coll_bytes_parsed if coll_bytes_parsed is not None else \
        collective_bytes_per_device(cfg, shape, mesh, variant)
    t_c = f / (mesh.chips * PEAK_FLOPS)
    t_m = hbm / HBM_BW
    t_x = coll / LINK_BW
    terms = {"compute_s": t_c, "memory_s": t_m, "collective_s": t_x}
    dominant = max(terms, key=terms.get)
    mf = model_flops(cfg, shape)
    bound = max(t_c, t_m, t_x)
    return {
        **terms,
        "dominant": dominant.replace("_s", ""),
        "flops_total": f,
        "model_flops": mf,
        "useful_flops_frac": mf / f if f else 0.0,
        "hbm_bytes_per_dev": hbm,
        "coll_bytes_per_dev": coll,
        "step_time_bound_s": bound,
        "roofline_frac": (mf / (mesh.chips * PEAK_FLOPS)) / bound if bound else 0.0,
    }


# ---------------------------------------------------------------------------
# Lease plane (PaxosLease array engine)
# ---------------------------------------------------------------------------
def lease_plane_roofline(
    n_cells: int,
    n_acceptors: int = 5,
    n_proposers: int = 8,
    *,
    delayed: bool = True,
    window: int = 16,
    block_n: int = 512,
) -> dict:
    """Analytic roofline of the fused lease-plane window kernel per tick on
    TPU v5e (docs/perf.md walks through the numbers).

    The kernel is pure int32 VPU work — no MXU — so the interesting bound
    is memory. Two regimes:

      - ``resident``: the per-tick HBM traffic of the time-resident window
        kernel — only the streamed scenario planes move (attempt/release
        rows and the per-tick owner/count outputs; acc_up and the [P, A]
        link matrices are O(1) per tick), ~16 bytes/cell-tick. State never
        leaves VMEM inside a window.
      - ``per_tick_dispatch``: the same tick if every state plane
        round-trips HBM (the pre-fused per-tick driver): all packed lease
        (+ netplane) planes in AND out each tick.

    The ratio is the architectural headline of the fusion: the window
    kernel removes ~(state bytes / streamed bytes) of HBM traffic — about
    ``(2A + 14) x 2 / 16`` for the delayed model — and one kernel launch
    replaces T of them.
    """
    b = 4  # int32
    a = n_acceptors
    # packed planes: lease = 2x[A,N] + 2x[1,N]; netplane = 6x[A,N] + 6x[1,N]
    state_planes = (2 * a + 2) + ((6 * a + 6) if delayed else 0)
    streamed = 2 + 2  # attempt+release rows in, owner+count rows out
    # cell-independent per-tick streams: acc_up [A], the local-clock
    # columns pclk [P] / aclk [A] (drift, PR 5), and the fused [P, A]
    # link matrix (delayed model only) — O(1) in N but P-proportional
    bcast_bytes = b * (
        a + n_proposers + a + (n_proposers * a if delayed else 0)
    )
    resident_bytes = streamed * b * n_cells + bcast_bytes
    dispatch_bytes = (2 * state_planes + streamed) * b * n_cells + bcast_bytes
    # VPU work: ~110 [A, N]-sized int ops per delayed tick (~25 sync)
    ops = (110 if delayed else 25) * a * n_cells
    vpu_int_ops_per_s = PEAK_FLOPS / 2  # int32 VPU lanes, no MXU: ~0.5x bf16
    t_resident = resident_bytes / HBM_BW
    t_dispatch = dispatch_bytes / HBM_BW
    t_compute = ops / vpu_int_ops_per_s
    # VMEM is a PER-PROGRAM footprint: each grid step holds ONE block_n-wide
    # cell block's state plus one window of its streamed slabs, independent
    # of n_cells
    bn = min(block_n, n_cells)
    vmem_bytes = (
        state_planes * b * bn  # resident state of one cell block
        + streamed * b * bn * window  # one window's streamed slabs
    )
    return {
        "resident_hbm_bytes_per_tick": resident_bytes,
        "dispatch_hbm_bytes_per_tick": dispatch_bytes,
        "hbm_traffic_ratio": dispatch_bytes / resident_bytes,
        "compute_s_per_tick": t_compute,
        "memory_s_per_tick_resident": t_resident,
        "memory_s_per_tick_dispatch": t_dispatch,
        "bound": "compute" if t_compute > t_resident else "memory",
        "vmem_bytes_at_window": vmem_bytes,
        "cell_ticks_per_s_bound": n_cells / max(t_compute, t_resident),
    }
