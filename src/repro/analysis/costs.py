"""Normalize ``Compiled.cost_analysis()`` across JAX versions.

Older JAX returns a plain ``{metric: value}`` dict; newer JAX returns a
list with one dict per device/partition (``[{...}]``). Everything downstream
(roofline validation, dry-run artifacts) wants a single flat dict, so this
is the one place that knows about both shapes.
"""
from __future__ import annotations


def normalize_cost_analysis(ca) -> dict:
    """Collapse a raw ``cost_analysis()`` result into one ``{str: float}``.

    Accepts a dict, a list/tuple of dicts (summed entry-wise — per-device
    costs add up; single-element lists are the common case), or None/empty.
    """
    if ca is None:
        return {}
    if isinstance(ca, dict):
        return dict(ca)
    if isinstance(ca, (list, tuple)):
        out: dict = {}
        for part in ca:
            if not isinstance(part, dict):
                continue
            for k, v in part.items():
                try:
                    v = float(v)
                except (TypeError, ValueError):
                    out.setdefault(k, v)
                    continue
                if k == "optimal_seconds":
                    # partitions run concurrently: the plane's optimal time
                    # is the slowest partition, not the sum
                    out[k] = max(out.get(k, 0.0), v)
                else:
                    out[k] = out.get(k, 0.0) + v
        return out
    raise TypeError(f"unrecognized cost_analysis() shape: {type(ca)!r}")


def cost_analysis_dict(compiled) -> dict:
    """``compiled.cost_analysis()`` as a flat dict, whatever the JAX version."""
    return normalize_cost_analysis(compiled.cost_analysis())
