"""The finding currency every leaselint checker speaks.

A checker returns a (possibly empty) list of :class:`Finding`s; the CLI
(`python -m repro.analysis.staticcheck`) aggregates them into the findings
JSON artifact CI uploads and exits nonzero iff any survived. Severity is
deliberately absent: every finding is a proof obligation the tree failed,
not a style nit — style stays in ruff's lane.
"""
from __future__ import annotations

import json
from dataclasses import asdict, dataclass


@dataclass(frozen=True)
class Finding:
    """One static-check violation.

    checker: which pass found it ("intervals" | "purity" | "launch" |
             "conventions").
    rule:    the machine-readable rule id (e.g. "int32-overflow",
             "pack-budget", "float-op", "write-race", "undocumented-plane").
    where:   where it was found — a jaxpr equation, a BlockSpec index, or
             a ``path:line`` location.
    detail:  the human-readable explanation (what was proven false and
             with which numbers).
    """

    checker: str
    rule: str
    where: str
    detail: str

    def __str__(self) -> str:  # the one-line CLI rendering
        return f"[{self.checker}:{self.rule}] {self.where}: {self.detail}"


def findings_to_json(findings: list[Finding], **meta) -> str:
    """Serialize findings (+ run metadata) for the CI artifact."""
    return json.dumps(
        {
            "ok": not findings,
            "n_findings": len(findings),
            "findings": [asdict(f) for f in findings],
            **meta,
        },
        indent=2,
        sort_keys=True,
    )
