"""Static audit of the window-kernel launch geometry.

The Pallas grid iterates ``(cell_block, window)`` with the window axis
minor; correctness of the whole time-resident design rests on three
properties of the BlockSpecs that nothing at runtime checks:

  - **bounds**: every block an index map selects lies inside its logical
    array (Pallas silently clamps out-of-range blocks, which would alias
    the last block instead of failing);
  - **write-race**: output index maps must partition the cell axis —
    two grid instances may write the same output region only if they are
    the same cell block revisited across *windows* (that revisit is the
    point: the block stays VMEM-resident, serialized by the minor axis).
    Any same-region write from two different cell blocks is a data race;
  - **coverage**: the union of written regions must tile each output
    exactly, or part of the result is whatever XLA left in the buffer;
  - **VMEM residency**: one grid step's working set (every block of every
    spec) must fit the per-core VMEM budget, and must not undercut the
    analytic accounting in ``analysis/roofline.py`` — if the plan counts
    fewer resident bytes than the roofline model, a state plane fell out
    of the plan and the two descriptions have drifted.

The checker consumes the same :class:`repro.lease_array.kernel.LaunchPlan`
the ``pallas_call`` entry points run, so there is no second description of
the launch to keep in sync.

Block index maps return *block* indices (units of one block shape), so
regions are aligned tiles: two blocks of the same spec either coincide
exactly or are disjoint — partial overlap cannot happen, which keeps the
race check exact rather than approximate.
"""
from __future__ import annotations

import math

from .findings import Finding

#: conservative per-core VMEM floor (v4-class TensorCore); newer parts have
#: more, but a plan that fits here fits everywhere we run
VMEM_BUDGET_BYTES = 16 * 2**20

#: refuse to enumerate absurd grids instead of silently sampling
_MAX_GRID_POINTS = 1 << 16

_BYTES = 4  # everything in the lease plane is int32


def _block_shape(spec):
    """Concrete block shape with squeezed (None) dims as 1, or None for
    memory-space-only specs (the SMEM scan scalars)."""
    bs = getattr(spec, "block_shape", None)
    if bs is None:
        return None
    return tuple(1 if d is None else int(d) for d in bs)


def _grid_points(grid):
    pts = []
    for i in range(grid[0]):
        for w in range(grid[1]):
            pts.append((i, w))
    return pts


def check_launch_plan(
    plan,
    *,
    delayed: bool,
    n_acceptors: int = 5,
    n_proposers: int = 8,
    vmem_budget_bytes: int = VMEM_BUDGET_BYTES,
    what: str = "window kernel",
) -> list[Finding]:
    """Audit one :class:`LaunchPlan`. Pure host-side arithmetic — nothing
    is traced or executed."""
    findings: list[Finding] = []
    grid = tuple(int(g) for g in plan.grid)
    if math.prod(grid) > _MAX_GRID_POINTS:
        return [Finding(
            "launch", "grid-too-large", what,
            f"grid {grid} has {math.prod(grid)} instances, beyond the "
            f"{_MAX_GRID_POINTS} the checker will enumerate; shrink the "
            f"audit geometry (the rules are geometry-independent)",
        )]
    pts = _grid_points(grid)
    vmem = 0

    def audit(kind, specs, shapes):
        nonlocal vmem
        for k, (spec, shape) in enumerate(zip(specs, shapes)):
            where = f"{what} {kind}[{k}]"
            bs = _block_shape(spec)
            if bs is None:  # SMEM scalar vector: no tiling to audit
                continue
            vmem += _BYTES * math.prod(bs)
            if len(bs) != len(shape):
                findings.append(Finding(
                    "launch", "rank-mismatch", where,
                    f"block shape {bs} has rank {len(bs)} but the array "
                    f"is {shape}",
                ))
                continue
            index_map = spec.index_map
            regions: dict[tuple, tuple] = {}  # region -> first grid point
            for pt in pts:
                try:
                    idx = tuple(int(x) for x in index_map(*pt))
                except Exception as e:  # arity/typing bug in the map
                    findings.append(Finding(
                        "launch", "index-map-error", where,
                        f"index map failed at grid point {pt}: {e!r}",
                    ))
                    regions = {}
                    break
                if len(idx) != len(bs):
                    findings.append(Finding(
                        "launch", "index-map-error", where,
                        f"index map returned {len(idx)} coords for a "
                        f"rank-{len(bs)} block at grid point {pt}",
                    ))
                    regions = {}
                    break
                for d, (b, n, j) in enumerate(zip(bs, shape, idx)):
                    if j < 0 or (j + 1) * b > n:
                        findings.append(Finding(
                            "launch", "block-out-of-bounds", where,
                            f"grid point {pt} selects block {idx}: axis "
                            f"{d} spans [{j * b}, {(j + 1) * b}) outside "
                            f"the array extent {n}",
                        ))
                if kind == "out":
                    prev = regions.get(idx)
                    if prev is None:
                        regions[idx] = pt
                    elif prev[0] != pt[0]:
                        findings.append(Finding(
                            "launch", "write-race", where,
                            f"grid points {prev} and {pt} (different cell "
                            f"blocks) both write block {idx}; output index "
                            f"maps must partition the cell axis — only "
                            f"window-axis revisits of the SAME cell block "
                            f"are race-free",
                        ))
            if kind == "out" and regions:
                covered = len(regions) * math.prod(bs)
                total = math.prod(shape)
                if covered < total:
                    findings.append(Finding(
                        "launch", "incomplete-coverage", where,
                        f"written blocks cover {covered} of {total} "
                        f"elements of {shape}; the rest is uninitialized "
                        f"output",
                    ))

    audit("in", plan.in_specs, plan.in_shapes)
    audit("out", plan.out_specs, plan.out_shapes)

    # -- VMEM residency -----------------------------------------------------
    if vmem > vmem_budget_bytes:
        findings.append(Finding(
            "launch", "vmem-budget", what,
            f"one grid step holds {vmem} bytes of blocks, over the "
            f"{vmem_budget_bytes}-byte VMEM budget; shrink block_n or "
            f"window",
        ))
    try:
        from ..roofline import lease_plane_roofline

        n_cells = plan.block_n * grid[0]
        analytic = lease_plane_roofline(
            n_cells, n_acceptors, n_proposers,
            delayed=delayed, window=plan.tw, block_n=plan.block_n,
        )["vmem_bytes_at_window"]
        if vmem < analytic:
            findings.append(Finding(
                "launch", "vmem-accounting", what,
                f"plan blocks sum to {vmem} bytes but the roofline model "
                f"expects at least {analytic} resident; a state plane has "
                f"fallen out of the launch plan",
            ))
    except Exception as e:  # roofline import/shape drift is itself a finding
        findings.append(Finding(
            "launch", "vmem-accounting", what,
            f"could not cross-check against analysis/roofline.py: {e!r}",
        ))
    return findings


def check_window_launches(
    n_cells: int = 4096,
    n_acceptors: int = 5,
    n_proposers: int = 8,
    n_ticks: int = 64,
    *,
    block_n: int = 512,
    window: int = 16,
) -> list[Finding]:
    """Audit both shipped window kernels at a representative geometry."""
    from ...lease_array.kernel import delayed_launch_plan, sync_launch_plan

    A, P = n_acceptors, n_proposers
    findings = check_launch_plan(
        sync_launch_plan(A, n_cells, P, n_ticks,
                         block_n=block_n, window=window),
        delayed=False, n_acceptors=A, n_proposers=P,
        what="lease_window_sync_pallas",
    )
    findings += check_launch_plan(
        delayed_launch_plan(A, n_cells, P, n_ticks,
                            block_n=block_n, window=window),
        delayed=True, n_acceptors=A, n_proposers=P,
        what="lease_window_delayed_pallas",
    )
    findings += check_launch_plan(
        delayed_launch_plan(A, n_cells, P, n_ticks,
                            block_n=block_n, window=window, extend=True),
        delayed=True, n_acceptors=A, n_proposers=P,
        what="lease_window_delayed_pallas[extend]",
    )
    return findings
