"""Purity/dtype lint over traced jaxprs: the tick cores (and the window
kernels wrapping them) must stay pure int32 VPU work.

Three rules, each today enforced only by convention:

  - ``float-op``: the §4 safety argument is exact integer arithmetic; a
    float creeping into the tick core (a stray ``/``, a float literal)
    breaks bit-for-bit backend agreement and the interval proof alike.
  - ``int64-promotion``: a silent widen (Python int literal over 2^31,
    ``jnp.sum`` with a promoted accumulator) would make the packed layout
    *look* safe while the int32 kernels still wrap.
  - ``gather-in-pallas``: the Pallas backend path must resolve per-leg
    link rows with the compile-time P-loop (``netplane.legs_select`` /
    ``state.clock_select``), never a dynamic gather — gather indices
    materializing in HBM is exactly what the fused kernel exists to avoid
    (the ``legs_select`` vs ``legs_gather`` rule).

The walk recurses into every sub-jaxpr (pjit, scan/fori_loop bodies,
``pallas_call`` kernels), so tracing ``lease_window_*_pallas`` checks the
code that actually runs inside the kernel.
"""
from __future__ import annotations

import numpy as np

from .findings import Finding

#: primitives that materialize dynamic indices (the Pallas-path ban)
GATHER_PRIMS = frozenset({
    "gather", "scatter", "scatter-add", "dynamic_slice", "dynamic_gather",
    "dynamic_update_slice",
})

_WIDE_INTS = (np.int64, np.uint64)


def _walk(jaxpr, visit, path=""):
    for i, eqn in enumerate(jaxpr.eqns):
        where = f"{path}eqn {i} `{eqn.primitive.name}`"
        visit(eqn, where)
        for name, p in eqn.params.items():
            subs = p if isinstance(p, (list, tuple)) else (p,)
            for s in subs:
                inner = getattr(s, "jaxpr", None)
                if inner is not None and hasattr(inner, "eqns"):
                    _walk(inner, visit, f"{where}/{name}/")
                elif hasattr(s, "eqns"):
                    _walk(s, visit, f"{where}/{name}/")


def check_jaxpr_purity(
    closed, *, pallas_path: bool = False, what: str = "tick core",
) -> list[Finding]:
    """Lint one (closed) jaxpr. ``pallas_path=True`` additionally bans
    gather-family primitives (the block-local select rule)."""
    findings: list[Finding] = []
    seen: set[tuple] = set()

    def visit(eqn, where):
        prim = eqn.primitive.name
        for v in eqn.outvars:
            aval = getattr(v, "aval", None)
            dt = getattr(aval, "dtype", None)
            if dt is None:
                continue
            if np.issubdtype(dt, np.floating) or np.issubdtype(dt, np.complexfloating):
                key = ("float", prim)
                if key not in seen:
                    seen.add(key)
                    findings.append(Finding(
                        "purity", "float-op", where,
                        f"{what} produces a {dt} value via `{prim}`; the "
                        f"packed tick math must be exact int32",
                    ))
            elif dt.type in _WIDE_INTS:
                key = ("int64", prim)
                if key not in seen:
                    seen.add(key)
                    findings.append(Finding(
                        "purity", "int64-promotion", where,
                        f"{what} silently promotes to {dt} via `{prim}`; "
                        f"the int32 kernels would wrap where this widened",
                    ))
        if pallas_path and prim in GATHER_PRIMS:
            key = ("gather", prim, where)
            if key not in seen:
                seen.add(key)
                findings.append(Finding(
                    "purity", "gather-in-pallas", where,
                    f"`{prim}` reaches the Pallas backend path of {what}; "
                    f"use the compile-time P-loop selects "
                    f"(netplane.legs_select / state.clock_select) instead",
                ))

    _walk(closed.jaxpr, visit)
    return findings


def check_tick_cores(
    n_proposers: int = 8,
    n_acceptors: int = 5,
    lease_q4: int = 13,
    round_q4: int = 4,
    guard_q4: int = 13,
) -> list[Finding]:
    """Lint the real tick cores on both leg strategies:

    - sync core and the delayed core with ``legs_select`` must pass the
      full Pallas-path rules (these are the bodies the window kernels run);
    - the delayed core with ``legs_gather`` is the XLA-only oracle, where
      gather is allowed by design — but floats/int64 still aren't.
    """
    from .intervals import trace_tick_core

    majority = n_acceptors // 2 + 1
    args = (n_proposers, n_acceptors, lease_q4, round_q4, guard_q4, majority)
    findings = check_jaxpr_purity(
        trace_tick_core(*args, sync=True),
        pallas_path=True, what="sync_tick_math",
    )
    findings += check_jaxpr_purity(
        trace_tick_core(*args, sync=False, legs="select"),
        pallas_path=True, what="delayed_tick_math[legs_select]",
    )
    findings += check_jaxpr_purity(
        trace_tick_core(*args, sync=False, legs="gather"),
        pallas_path=False, what="delayed_tick_math[legs_gather]",
    )
    # the corruption-plane variants (falsifier negative controls) run the
    # same backends, so they obey the same rules
    findings += check_jaxpr_purity(
        trace_tick_core(*args, sync=False, legs="select", corrupt=True),
        pallas_path=True, what="delayed_tick_math[legs_select,corrupt]",
    )
    findings += check_jaxpr_purity(
        trace_tick_core(*args, sync=False, legs="gather", corrupt=True),
        pallas_path=False, what="delayed_tick_math[legs_gather,corrupt]",
    )
    # the §6 extend variant runs the same backends: same rules
    findings += check_jaxpr_purity(
        trace_tick_core(*args, sync=False, legs="select", extend=True),
        pallas_path=True, what="delayed_tick_math[legs_select,extend]",
    )
    findings += check_jaxpr_purity(
        trace_tick_core(*args, sync=False, legs="gather", extend=True),
        pallas_path=False, what="delayed_tick_math[legs_gather,extend]",
    )
    return findings


def check_window_kernels(
    n_cells: int = 1024,
    n_acceptors: int = 5,
    n_proposers: int = 8,
    n_ticks: int = 32,
    *,
    block_n: int = 512,
    window: int = 16,
) -> list[Finding]:
    """Trace the whole ``lease_window_{sync,delayed}_pallas`` entry points
    (shapes only — nothing executes) and lint everything inside the
    ``pallas_call``, fori_loop bodies included, under the Pallas rules."""
    import jax
    import jax.numpy as jnp

    from ...lease_array.kernel import (
        lease_window_delayed_pallas,
        lease_window_sync_pallas,
    )
    from ...lease_array.netplane import NetPlaneState, init_netplane
    from ...lease_array.state import PackedLeaseState, init_state, pack_state

    A, P, N, T = n_acceptors, n_proposers, n_cells, n_ticks
    i32 = jnp.int32
    sds = jax.ShapeDtypeStruct
    packed = PackedLeaseState(
        *(sds(a.shape, i32) for a in pack_state(init_state(N, A, P)))
    )
    net = NetPlaneState(*(sds(a.shape, i32) for a in init_netplane(N, A)))
    t0 = sds((), i32)
    planes = dict(
        attempts=sds((T, N), i32), releases=sds((T, N), i32),
        acc_up=sds((T, A), i32), pclk=sds((T, P), i32),
        aclk=sds((T, A), i32),
    )
    kw = dict(majority=A // 2 + 1, lease_q4=13, n_proposers=P,
              block_n=block_n, window=window, interpret=True)

    sync_jaxpr = jax.make_jaxpr(
        lambda p, t, a, r, u, pc, ac: lease_window_sync_pallas(
            p, t, a, r, u, pc, ac, **kw
        )
    )(packed, t0, *planes.values())
    delayed_jaxpr = jax.make_jaxpr(
        lambda p, n, t, a, r, u, pc, ac, lk: lease_window_delayed_pallas(
            p, n, t, a, r, u, pc, ac, lk, round_q4=4, **kw
        )
    )(packed, net, t0, *planes.values(), sds((T, P, A), i32))

    corrupt_jaxpr = jax.make_jaxpr(
        lambda p, n, t, a, r, u, pc, ac, lk, st, eq:
        lease_window_delayed_pallas(
            p, n, t, a, r, u, pc, ac, lk, round_q4=4, stale=st, equiv=eq,
            **kw
        )
    )(packed, net, t0, *planes.values(), sds((T, P, A), i32),
      sds((T, A), i32), sds((T, A), i32))

    extend_jaxpr = jax.make_jaxpr(
        lambda p, n, t, a, r, u, pc, ac, lk, ex:
        lease_window_delayed_pallas(
            p, n, t, a, r, u, pc, ac, lk, round_q4=4, extends=ex, **kw
        )
    )(packed, net, t0, *planes.values(), sds((T, P, A), i32),
      sds((T, N), i32))

    findings = check_jaxpr_purity(
        sync_jaxpr, pallas_path=True, what="lease_window_sync_pallas"
    )
    findings += check_jaxpr_purity(
        delayed_jaxpr, pallas_path=True, what="lease_window_delayed_pallas"
    )
    findings += check_jaxpr_purity(
        corrupt_jaxpr, pallas_path=True,
        what="lease_window_delayed_pallas[corrupt]",
    )
    findings += check_jaxpr_purity(
        extend_jaxpr, pallas_path=True,
        what="lease_window_delayed_pallas[extend]",
    )
    return findings


def check_honest_strip(
    n_cells: int = 16,
    n_acceptors: int = 3,
    n_proposers: int = 4,
    n_ticks: int = 4,
) -> list[Finding]:
    """The all-default ``extends`` plane (and the corruption/restart
    planes with it) must leave the honest dispatch jaxpr BYTE-IDENTICAL
    to one that never mentioned the plane: ``ops.strip_default_planes``
    is the host-side gate ``lease_window_scan`` applies before its jit,
    so honest replays never compile (or cache-miss on) the fault
    variants. Traces the real impl both ways and diffs the jaxprs."""
    import jax
    import numpy as np

    from ...lease_array import ops
    from ...lease_array.netplane import init_netplane
    from ...lease_array.scenario import PLANES, Scenario
    from ...lease_array.state import init_state

    A, P, N, T = n_acceptors, n_proposers, n_cells, n_ticks
    honest = Scenario.build(
        T, n_cells=N, n_acceptors=A, n_proposers=P,
        delay=np.ones((T, A), np.int32),  # delayed model: extends' home
    )
    planes = dict(honest.planes)
    assert (np.asarray(planes["extends"]) == PLANES["extends"].default).all()
    without = ops.strip_default_planes(
        {k: v for k, v in planes.items() if k != "extends"}
    )
    stripped = ops.strip_default_planes(planes)

    state = init_state(N, A, P)
    net = init_netplane(N, A)
    kw = dict(majority=A // 2 + 1, lease_q4=13, round_q4=8, guard_q4=13,
              backend="jnp", sync=False, block_n=8, window=2,
              restart_guard=True, skip_stable=True)

    def jaxpr_of(pl):
        return str(jax.make_jaxpr(
            lambda s, n_, t, p: ops._window_scan_impl(
                s, n_, t, None, None, p, **kw
            )
        )(state, net, np.int32(0), pl))

    findings: list[Finding] = []
    if "extends" in stripped:
        findings.append(Finding(
            "purity", "honest-strip", "ops.strip_default_planes",
            "an all-default extends plane survived the host-side strip; "
            "every honest replay would compile the extend variant",
        ))
    elif jaxpr_of(stripped) != jaxpr_of(without):
        findings.append(Finding(
            "purity", "honest-strip", "ops._window_scan_impl",
            "the honest dispatch jaxpr with a stripped all-default "
            "extends plane differs from one traced without the plane — "
            "the strip no longer restores the honest computation",
        ))
    return findings
