"""AST/doc convention lint over ``src/repro/lease_array`` + ``tests``.

Three repo conventions, each previously enforced only by review:

  - ``plane-docs``: every ``register_plane`` entry must be documented —
    the plane table in docs/scenario_api.md is *generated* from the
    registry (``scenario.plane_table_md``); this rule fails when the two
    drift or a plane is registered with an empty ``doc``.
  - ``deprecated-shim``: the PR 3 shims (``lease_plane_step``,
    ``lease_plane_step_delayed``) may appear only where they are defined,
    re-exported, or tested. Everywhere else is a regression back to the
    per-kwarg API.
  - ``deadline-compare``: node-side deadline fields are minted in each
    node's *local* quarter-ticks (the §4 drift model). A comparison of a
    deadline field against anything that is not a local-clock value (or
    the constant-0 presence test) silently mixes clock domains — exactly
    the bug class ``state.clock_select`` and the guarded-expiry helpers
    exist to prevent.

All rules are pure-source checks (``ast`` + text); ``check_source_text``
exposes the deadline rule to the mutation fixtures without touching the
tree.
"""
from __future__ import annotations

import ast
from pathlib import Path

from .findings import Finding

#: files allowed to *name* the deprecated shims: definition site,
#: re-export, and the shim-behavior tests themselves
SHIM_ALLOWLIST = frozenset({
    "src/repro/lease_array/ops.py",
    "src/repro/lease_array/__init__.py",
    "tests/test_deprecations.py",
})
SHIM_NAMES = frozenset({"lease_plane_step", "lease_plane_step_delayed"})

#: packed node-side deadline fields (minted in local quarter-ticks)
DEADLINE_NAMES = frozenset({
    "ownp", "owner_lease", "acc_lease",
    "owner_expiry", "lease_expiry", "rnd_expiry", "rnd_deadline",
})
#: identifier substrings that mark a value as local-clock time
_CLOCK_TOKENS = ("clk", "clock")

_PLANE_TABLE_BEGIN = "<!-- plane-table:begin"
_PLANE_TABLE_END = "<!-- plane-table:end -->"


def _names_in(node) -> set[str]:
    out = set()
    for n in ast.walk(node):
        if isinstance(n, ast.Name):
            out.add(n.id)
        elif isinstance(n, ast.Attribute):
            out.add(n.attr)
    return out


def _is_zero_const(node) -> bool:
    return isinstance(node, ast.Constant) and node.value == 0


def _is_clockish(node) -> bool:
    return any(
        any(tok in name for tok in _CLOCK_TOKENS) for name in _names_in(node)
    )


def _lint_tree(tree: ast.AST, relpath: str) -> list[Finding]:
    findings: list[Finding] = []
    shim_ok = relpath in SHIM_ALLOWLIST
    deadline_scope = relpath.startswith("src/repro/lease_array/")
    for node in ast.walk(tree):
        if not shim_ok:
            name = None
            if isinstance(node, ast.Name) and node.id in SHIM_NAMES:
                name = node.id
            elif isinstance(node, ast.Attribute) and node.attr in SHIM_NAMES:
                name = node.attr
            elif isinstance(node, ast.ImportFrom):
                hit = [a.name for a in node.names if a.name in SHIM_NAMES]
                name = hit[0] if hit else None
            if name is not None:
                findings.append(Finding(
                    "conventions", "deprecated-shim",
                    f"{relpath}:{node.lineno}",
                    f"`{name}` is a deprecated shim; build a TickInputs "
                    f"with make_tick and call lease_plane_tick (see "
                    f"docs/scenario_api.md's migration table)",
                ))
        if deadline_scope and isinstance(node, ast.Compare):
            sides = [node.left, *node.comparators]
            for a, b in zip(sides, sides[1:]):
                for dl, other in ((a, b), (b, a)):
                    names = _names_in(dl)
                    if not (names & DEADLINE_NAMES):
                        continue
                    if _is_zero_const(other):  # presence test, clock-free
                        continue
                    if "PACK_MASK" in names:  # ballot-field extraction,
                        continue              # not a deadline comparison
                    if _is_clockish(other) or _is_clockish(dl):
                        continue
                    field = sorted(_names_in(dl) & DEADLINE_NAMES)[0]
                    findings.append(Finding(
                        "conventions", "deadline-compare",
                        f"{relpath}:{node.lineno}",
                        f"deadline field `{field}` compared against a "
                        f"non-clock value; node-side deadlines live in "
                        f"local quarter-ticks — compare against the "
                        f"clock_select'ed local clock (or a constant-0 "
                        f"presence test), never global time",
                    ))
    return findings


def check_source_text(src: str, relpath: str) -> list[Finding]:
    """Lint one source string as if it lived at ``relpath`` (the hook the
    mutation fixtures use)."""
    try:
        tree = ast.parse(src, filename=relpath)
    except SyntaxError as e:
        return [Finding(
            "conventions", "syntax-error", f"{relpath}:{e.lineno}", str(e),
        )]
    return _lint_tree(tree, relpath)


def _repo_root() -> Path:
    # src/repro/analysis/staticcheck/conventions.py -> repo root is 5 up
    return Path(__file__).resolve().parents[4]


def check_plane_docs(
    doc_text: str | None = None, *, root: Path | None = None,
) -> list[Finding]:
    """The single-source-of-truth rule: the generated plane table must
    match the one committed in docs/scenario_api.md, and every registered
    plane must carry a non-empty doc."""
    from ...lease_array.scenario import PLANES, plane_table_md

    findings = [
        Finding(
            "conventions", "undocumented-plane",
            f"register_plane({name!r})",
            "registered plane has an empty doc; the generated plane table "
            "would ship a blank meaning column",
        )
        for name, spec in PLANES.items() if not spec.doc.strip()
    ]
    doc_path = (root or _repo_root()) / "docs" / "scenario_api.md"
    if doc_text is None:
        try:
            doc_text = doc_path.read_text()
        except OSError as e:
            return findings + [Finding(
                "conventions", "undocumented-plane", str(doc_path),
                f"cannot read the scenario API doc: {e}",
            )]
    begin = doc_text.find(_PLANE_TABLE_BEGIN)
    end = doc_text.find(_PLANE_TABLE_END)
    if begin < 0 or end < 0:
        return findings + [Finding(
            "conventions", "undocumented-plane", "docs/scenario_api.md",
            f"plane-table markers missing ({_PLANE_TABLE_BEGIN} ... "
            f"{_PLANE_TABLE_END}); the table is generated from the "
            f"registry by scenario.plane_table_md()",
        )]
    committed = doc_text[begin:end]
    # drop the marker comment itself (it may span lines); keep table rows
    committed = "\n".join(
        ln for ln in committed.splitlines() if ln.startswith("|")
    ) + "\n"
    generated = plane_table_md()
    if committed != generated:
        want = {ln.split("|")[1].strip(" `") for ln in generated.splitlines()[2:]}
        have = {ln.split("|")[1].strip(" `") for ln in committed.splitlines()[2:] if ln.count("|") > 2}
        missing = sorted(want - have)
        hint = (
            f"planes missing from the doc table: {missing}" if missing
            else "the committed table text no longer matches the registry"
        )
        findings.append(Finding(
            "conventions", "undocumented-plane", "docs/scenario_api.md",
            f"plane table drifted from the registry — {hint}; re-run "
            f"`python -m repro.analysis.staticcheck --write-plane-table`",
        ))
    return findings


def check_conventions(root: Path | None = None) -> list[Finding]:
    """Run every convention rule over the real tree."""
    root = root or _repo_root()
    findings = check_plane_docs(root=root)
    scopes = ("src/repro/lease_array", "tests")
    for scope in scopes:
        for path in sorted((root / scope).rglob("*.py")):
            rel = path.relative_to(root).as_posix()
            findings += check_source_text(path.read_text(), rel)
    return findings
