"""The `leaselint` entry point: run every static checker, print findings,
emit the CI JSON artifact.

    python -m repro.analysis.staticcheck [--json PATH] [--skip-mutation]
    python -m repro.analysis.staticcheck --write-plane-table

Exit status is 0 iff no checker produced a finding AND every seeded
mutation fixture was caught (a checker that stops firing is itself a
finding). `--write-plane-table` regenerates the registry-derived plane
table inside docs/scenario_api.md and exits.
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path

from .findings import Finding, findings_to_json

#: the default geometries every `make check` run proves
_RATES = (4, 9)  # DEFAULT_RATE and MAX_REFEREE_RATE
_P, _A, _LEASE_Q4 = 8, 5, 13


def _check_intervals() -> list[Finding]:
    """Tentpole self-checks on the real cores:

    - the derived bound must equal ``state.max_pack_tick`` exactly for the
      default (P=8) geometry at both the drift-free and the worst referee
      clock rate, with and without the restart-counter ballot carve
      (``max_restarts`` shrinks the run field by RESTART_SHIFT bits — the
      hand formula and the traced restart-mode core must agree on by how
      much);
    - a config whose *round horizon* blows int32 — invisible to the
      runtime hand check, which only budgets ballots and lease deadlines,
      and skipped entirely under tracing — must be rejected.
    """
    from ...lease_array.state import MAX_RESTARTS, max_pack_tick
    from .intervals import TickConfig, analyze_tick_config, derived_max_pack_tick

    findings: list[Finding] = []
    for rate in _RATES:
        for mr in (0, 1, MAX_RESTARTS):
            hand = max_pack_tick(_P, _LEASE_Q4, 0, max_rate=rate,
                                 max_restarts=mr)
            derived = derived_max_pack_tick(_P, _LEASE_Q4, 0, max_rate=rate,
                                            max_restarts=mr)
            if hand != derived:
                findings.append(Finding(
                    "intervals", "bound-mismatch",
                    f"max_pack_tick(P={_P}, rate={rate}, restarts={mr})",
                    f"hand bound {hand} != interval-derived bound {derived}; "
                    f"state.max_pack_tick and the traced tick core disagree "
                    f"about the pack budget",
                ))
    # regression for the traced-away gap: an absurd round-abandon horizon
    # overflows `rnd_clk + round_q4` inside the core; check_pack_budget
    # never looks at round_q4 and is skipped under tracing anyway
    hot = TickConfig(
        t_end=100, n_proposers=_P, n_acceptors=_A,
        lease_q4=_LEASE_Q4, round_q4=2_147_483_600,
    )
    if not analyze_tick_config(hot):
        findings.append(Finding(
            "intervals", "lost-rejection", "round_q4=2147483600",
            "a round horizon that overflows int32 inside the core was "
            "proven 'safe'; the interval analysis has lost the regression "
            "the runtime check cannot see",
        ))
    return findings


def _check_purity() -> list[Finding]:
    from .purity import check_honest_strip, check_tick_cores, check_window_kernels

    return (
        check_tick_cores(_P, _A, _LEASE_Q4)
        + check_window_kernels(n_cells=1024, n_ticks=32)
        + check_honest_strip()
    )


def _check_launch() -> list[Finding]:
    from .launch import check_window_launches

    return check_window_launches()


def _check_conventions() -> list[Finding]:
    from .conventions import check_conventions

    return check_conventions()


def _check_mutation() -> list[Finding]:
    from .fixtures import run_mutation_tests

    return run_mutation_tests()


_CHECKERS = (
    ("intervals", _check_intervals),
    ("purity", _check_purity),
    ("launch", _check_launch),
    ("conventions", _check_conventions),
    ("mutation", _check_mutation),
)


def run_all(*, skip_mutation: bool = False) -> list[Finding]:
    """Run every leaselint pass over the real tree; returns all findings."""
    findings: list[Finding] = []
    for name, fn in _CHECKERS:
        if skip_mutation and name == "mutation":
            continue
        findings += fn()
    return findings


def write_plane_table(root: Path | None = None) -> Path:
    """Regenerate the registry-derived plane table between the
    ``plane-table`` markers of docs/scenario_api.md."""
    from ...lease_array.scenario import plane_table_md
    from .conventions import _PLANE_TABLE_BEGIN, _PLANE_TABLE_END, _repo_root

    path = (root or _repo_root()) / "docs" / "scenario_api.md"
    text = path.read_text()
    begin = text.find(_PLANE_TABLE_BEGIN)
    end = text.find(_PLANE_TABLE_END)
    if begin < 0 or end < 0:
        raise SystemExit(
            f"{path}: plane-table markers not found; add "
            f"{_PLANE_TABLE_BEGIN} ... --> and {_PLANE_TABLE_END} around "
            f"the table first"
        )
    close = text.index("-->", begin) + len("-->")
    path.write_text(
        text[:close] + "\n" + plane_table_md() + text[end:]
    )
    return path


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.staticcheck",
        description="leaselint: static proof of pack budget, kernel "
                    "purity, launch safety and repo conventions",
    )
    ap.add_argument(
        "--json", metavar="PATH", default=None,
        help="write the findings JSON artifact here (CI uploads it)",
    )
    ap.add_argument(
        "--skip-mutation", action="store_true",
        help="skip the checker self-test against the seeded mutants",
    )
    ap.add_argument(
        "--write-plane-table", action="store_true",
        help="regenerate the docs/scenario_api.md plane table from the "
             "registry and exit",
    )
    args = ap.parse_args(argv)

    if args.write_plane_table:
        path = write_plane_table()
        print(f"plane table regenerated in {path}")
        return 0

    findings = run_all(skip_mutation=args.skip_mutation)
    for f in findings:
        print(f)
    checkers = [n for n, _ in _CHECKERS if not (args.skip_mutation and n == "mutation")]
    payload = findings_to_json(
        findings,
        checkers=checkers,
        config={
            "n_proposers": _P, "n_acceptors": _A, "lease_q4": _LEASE_Q4,
            "rates": list(_RATES),
        },
    )
    if args.json:
        Path(args.json).write_text(payload + "\n")
        print(f"findings artifact: {args.json}")
    if findings:
        print(f"leaselint: {len(findings)} finding(s)")
        return 1
    print(f"leaselint: clean ({', '.join(checkers)})")
    return 0


if __name__ == "__main__":  # pragma: no cover
    sys.exit(main())
