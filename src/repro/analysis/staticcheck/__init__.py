"""leaselint — static proofs over the lease engine's real jaxprs.

Four passes, one finding currency, gating CI via ``make check``:

- :mod:`.intervals` — interval abstract interpretation of the int32 tick
  cores: proves no intermediate escapes int32 and no pack exceeds its
  field budget for a given config, and *derives* ``max_pack_tick`` to
  cross-check the hand bound in ``state.py``;
- :mod:`.purity` — dtype/purity lint over the traced cores and window
  kernels (no floats, no silent int64, no gathers on the Pallas path);
- :mod:`.launch` — audits the shared :class:`~repro.lease_array.kernel.
  LaunchPlan`: block bounds, write-race-free partition of the cell axis,
  output coverage, VMEM residency vs the roofline accounting;
- :mod:`.conventions` — AST/doc lints (registry-generated plane table,
  no deprecated shims, deadline comparisons stay in local clock domain).

:mod:`.fixtures` mutation-tests all four (seeded mutants must be caught,
clean twins must pass); :mod:`.cli` is the ``python -m`` entry point.
"""
from .cli import main, run_all, write_plane_table
from .conventions import check_conventions, check_plane_docs, check_source_text
from .findings import Finding, findings_to_json
from .fixtures import run_mutation_tests
from .intervals import (
    TickConfig,
    analyze_tick_config,
    derived_max_pack_tick,
    trace_tick_core,
)
from .launch import check_launch_plan, check_window_launches
from .purity import check_jaxpr_purity, check_tick_cores, check_window_kernels

__all__ = [
    "Finding",
    "findings_to_json",
    "TickConfig",
    "analyze_tick_config",
    "derived_max_pack_tick",
    "trace_tick_core",
    "check_jaxpr_purity",
    "check_tick_cores",
    "check_window_kernels",
    "check_launch_plan",
    "check_window_launches",
    "check_conventions",
    "check_plane_docs",
    "check_source_text",
    "run_mutation_tests",
    "run_all",
    "write_plane_table",
    "main",
]
