"""Seeded mutation fixtures: one deliberately-broken variant per checker.

A static checker that never fires is indistinguishable from one that
works, so every leaselint pass ships with a mutant it MUST flag and a
clean twin it MUST pass — the twin proves the fixture isolates the
mutation rather than tripping on scaffolding. `run_mutation_tests` runs
every pair and returns findings about the *checkers* (empty means
every mutant was caught and every twin passed); the CLI and
tests/test_staticcheck.py both gate on it.

The mutants:

  - **overflowing shift** (intervals): the deadline is packed with
    ``<< (2 * PACK_SHIFT)`` — the copy-paste double of the field shift.
    Interval analysis must prove the escape from int32.
  - **doubled restart carve** (intervals, restart mode): the ballot run
    field minted with ``<< (2 * RESTART_SHIFT)``. At the restart-mode
    budget boundary the honest carve fits PACK_MASK *exactly*, so the
    doubled shift bleeds the ballot into the deadline field and the
    pack-budget rule must fire.
  - **injected float op** (purity): the local-clock scale written as
    ``* 1.25`` instead of the exact ``* 5 // 4``.
  - **overlapping BlockSpec** (launch): a state output's index map
    collapsed to ``lambda i, w: (0, 0)`` — every cell block writes block
    (0, 0), a write race the grid cannot serialize.
  - **undocumented plane** (conventions): a doc plane table missing rows
    for registered planes, plus a deadline compared against global time.
"""
from __future__ import annotations

import functools

from .findings import Finding

_P, _LEASE_Q4, _T_END = 8, 13, 4094  # the default P=8 geometry and bound
#: restart-mode twin of _T_END: the carve costs RESTART_SHIFT run-field
#: bits, so max_pack_tick(P=8, max_restarts=3) collapses to 1022 — and the
#: final honest ballot ((1023 << 2) | 3) * 8 + 7 == PACK_MASK exactly
_MAX_RESTARTS, _RESTART_T_END = 3, 1022


@functools.lru_cache(maxsize=None)
def _pack_core(
    shift: int, float_scale: bool = False, restart_shift: int | None = None
):
    """A minimal deadline-packing core (the fragment of the tick math the
    pack budget lives in), parameterized so one knob seeds each mutant."""
    import jax
    import jax.numpy as jnp

    i32 = jnp.int32
    sds = jax.ShapeDtypeStruct

    def fn(ownp, t, pclk, rc):
        if restart_shift is None:
            ballot = (t + 1) * _P + (_P - 1)
        else:  # the restart-carve mint of state.ballot_of
            ballot = (((t + 1) << restart_shift) | rc) * _P + (_P - 1)
        if float_scale:
            clk = (pclk * 1.25).astype(i32)  # MUTANT: float on the tick path
        else:
            clk = pclk * 5 // 4
        deadline = clk + _LEASE_Q4
        packed = (deadline << shift) | ballot
        return jnp.maximum(ownp, packed)

    closed = jax.make_jaxpr(fn)(
        sds((1, 8), i32), sds((), i32), sds((1, 8), i32), sds((), i32)
    )
    layout = (("ownp", "state"), ("t", "t"), ("pclk", "clk"), ("rc", "rc"))
    return closed, layout


def _pack_cfg():
    from .intervals import TickConfig

    return TickConfig(t_end=_T_END, n_proposers=_P, lease_q4=_LEASE_Q4)


def fixture_overflowing_shift() -> list[Finding]:
    """Mutant for the interval checker: doubled pack shift."""
    from .intervals import PACK_SHIFT, analyze_tick_config

    core, layout = _pack_core(2 * PACK_SHIFT)
    return analyze_tick_config(_pack_cfg(), core=core, layout=layout)


def fixture_overflowing_shift_clean() -> list[Finding]:
    from .intervals import PACK_SHIFT, analyze_tick_config

    core, layout = _pack_core(PACK_SHIFT)
    return analyze_tick_config(_pack_cfg(), core=core, layout=layout)


def _restart_cfg():
    from .intervals import TickConfig

    return TickConfig(
        t_end=_RESTART_T_END, n_proposers=_P, lease_q4=_LEASE_Q4,
        max_restarts=_MAX_RESTARTS,
    )


def fixture_doubled_restart_shift() -> list[Finding]:
    """Mutant for the interval checker, restart mode: doubled restart
    carve. The honest carve fits PACK_MASK exactly at t_end=1022, so the
    doubled shift reaches ((1023 << 4) | 3) * 8 + 7 = 130975 and bleeds
    into the deadline field."""
    from ...lease_array.state import RESTART_SHIFT
    from .intervals import PACK_SHIFT, analyze_tick_config

    core, layout = _pack_core(PACK_SHIFT, restart_shift=2 * RESTART_SHIFT)
    return analyze_tick_config(_restart_cfg(), core=core, layout=layout)


def fixture_doubled_restart_shift_clean() -> list[Finding]:
    from ...lease_array.state import RESTART_SHIFT
    from .intervals import PACK_SHIFT, analyze_tick_config

    core, layout = _pack_core(PACK_SHIFT, restart_shift=RESTART_SHIFT)
    return analyze_tick_config(_restart_cfg(), core=core, layout=layout)


def fixture_float_op() -> list[Finding]:
    """Mutant for the purity lint: float clock scale."""
    from .purity import check_jaxpr_purity

    core, _ = _pack_core(15, float_scale=True)
    return check_jaxpr_purity(core, pallas_path=True, what="pack core")


def fixture_float_op_clean() -> list[Finding]:
    from .purity import check_jaxpr_purity

    core, _ = _pack_core(15)
    return check_jaxpr_purity(core, pallas_path=True, what="pack core")


def _mutant_plan():
    from jax.experimental import pallas as pl

    from ...lease_array.kernel import delayed_launch_plan

    plan = delayed_launch_plan(5, 2048, _P, 32)
    specs = list(plan.out_specs)
    specs[0] = pl.BlockSpec(
        specs[0].block_shape, lambda i, w: (0, 0)  # MUTANT: cell axis gone
    )
    return plan._replace(out_specs=tuple(specs))


def fixture_overlapping_blockspec() -> list[Finding]:
    """Mutant for the launch checker: output index map ignores the cell
    block, so grid instances race on block (0, 0)."""
    from .launch import check_launch_plan

    return check_launch_plan(
        _mutant_plan(), delayed=True, n_proposers=_P, what="mutant kernel"
    )


def fixture_overlapping_blockspec_clean() -> list[Finding]:
    from ...lease_array.kernel import delayed_launch_plan
    from .launch import check_launch_plan

    return check_launch_plan(
        delayed_launch_plan(5, 2048, _P, 32),
        delayed=True, n_proposers=_P, what="clean kernel",
    )


_STALE_DOC = """\
<!-- plane-table:begin -->
| plane | per-tick shape | default | meaning |
|-------|----------------|---------|---------|
| `attempts` | `[N]` | `-1` | proposer id attempting each cell this tick (-1 = none) |
<!-- plane-table:end -->
"""

_BAD_DEADLINE_SRC = (
    "own_live = ownp >= ((t4 + 1) << PACK_SHIFT)\n"  # global time, no guard
)


def fixture_undocumented_plane() -> list[Finding]:
    """Mutant for the convention lint: a doc plane table that predates
    most of the registry, plus a deadline minted against global time."""
    from .conventions import check_plane_docs, check_source_text

    findings = check_plane_docs(_STALE_DOC)
    findings += check_source_text(
        _BAD_DEADLINE_SRC, "src/repro/lease_array/mutant.py"
    )
    return findings


def fixture_undocumented_plane_clean() -> list[Finding]:
    from .conventions import check_conventions

    return check_conventions()


#: checker -> (mutant fixture, rules the mutant must trip, clean twin)
FIXTURES: dict[str, tuple] = {
    "intervals": (
        fixture_overflowing_shift,
        {"int32-overflow", "pack-budget"},
        fixture_overflowing_shift_clean,
    ),
    "restart-intervals": (
        fixture_doubled_restart_shift,
        {"pack-budget"},
        fixture_doubled_restart_shift_clean,
    ),
    "purity": (
        fixture_float_op,
        {"float-op"},
        fixture_float_op_clean,
    ),
    "launch": (
        fixture_overlapping_blockspec,
        {"write-race"},
        fixture_overlapping_blockspec_clean,
    ),
    "conventions": (
        fixture_undocumented_plane,
        {"undocumented-plane", "deadline-compare"},
        fixture_undocumented_plane_clean,
    ),
}


def run_mutation_tests() -> list[Finding]:
    """Self-test every checker against its seeded mutant + clean twin.
    Returns findings about the CHECKERS; empty means the suite has teeth."""
    out: list[Finding] = []
    for checker, (mutant, want_rules, clean) in FIXTURES.items():
        rules = {f.rule for f in mutant()}
        if not rules & want_rules:
            out.append(Finding(
                "mutation", "mutant-not-caught", f"{checker} fixture",
                f"the seeded mutant produced rules {sorted(rules)}; "
                f"expected at least one of {sorted(want_rules)} — the "
                f"{checker} checker has lost its teeth",
            ))
        leftovers = clean()
        if leftovers:
            out.append(Finding(
                "mutation", "clean-twin-flagged", f"{checker} fixture",
                f"the clean twin raised {len(leftovers)} finding(s) "
                f"(first: {leftovers[0]}); the fixture no longer isolates "
                f"the mutation",
            ))
    return out
