"""Interval abstract interpretation over the lease tick-core jaxprs.

The packed int32 layout (``q4 << PACK_SHIFT | ballot``, ``state.py``) is a
bit budget: ballots must fit in PACK_SHIFT bits, deadlines in the rest,
and every intermediate of the tick math must stay inside int32. The only
runtime guard (``state.check_pack_budget``) is host-side and *skipped
under tracing* — this module closes that gap statically.

How: trace ``ref.sync_tick_math`` / ``netplane.delayed_tick_math`` to a
jaxpr once per protocol config (the cores are branch-free int32 math, so
the jaxpr IS the semantics for every backend — jnp scan and Pallas window
kernel alike), then walk the equations with an interval domain:

  - every input gets an interval from the scenario config: ``t`` in
    ``[0, t_end]``, local clocks in ``[0, max_rate*t_end + clk_slack]``,
    link words in ``[0, 2*max_delay + 1]``, attempt/release ids in
    ``[-1, P-1]``;
  - state planes (promised ballots, packed leases, in-flight slots, round
    rows) start at their init values and iterate to a fixpoint: the tick
    is re-interpreted with last round's output intervals joined in until
    nothing widens — the loop invariant of the scan, derived not assumed;
  - arithmetic is exact on unbounded Python ints, so ``add``/``mul``/
    ``shift_left`` results falling outside int32 are flagged
    (``int32-overflow``) — the check the traced graph can't do;
  - ``or`` carries *pack provenance*: a ``shift_left`` by a constant k
    tags its result, and ``(x << k) | low`` demands ``low`` fit in k bits
    — the ``pack-budget`` rule, which is exactly "ballot <= PACK_MASK"
    at every ``pack_pair``/``pack_slot`` site.

``derived_max_pack_tick`` inverts the checker: binary-search the largest
``t_end`` with no findings. For delay-free configs it reproduces
``state.max_pack_tick`` exactly (tests assert ±0); with link delays the
hand formula double-charges the clock budget and the derived bound is
strictly ≥ — the hand check stays safe, just conservative.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, replace
from typing import NamedTuple, Optional

import numpy as np

from ...lease_array.state import (
    MAX_PACK_Q4,
    PACK_SHIFT,
    QUARTERS,
    lease_quarters,
)
from .findings import Finding

INT32_MIN = -(2**31)
INT32_MAX = 2**31 - 1

#: fixpoint passes before giving up and widening to full int32
_MAX_FIXPOINT_ITERS = 64


class IV(NamedTuple):
    """A closed integer interval [lo, hi] on unbounded Python ints."""

    lo: int
    hi: int

    def join(self, other: "IV") -> "IV":
        return IV(min(self.lo, other.lo), max(self.hi, other.hi))

    def __contains__(self, v: int) -> bool:
        return self.lo <= v <= self.hi


INT32 = IV(INT32_MIN, INT32_MAX)
BOOL = IV(0, 1)


class AbsVal(NamedTuple):
    """Interval + pack provenance: ``shift=k`` means the value is exactly
    some nonnegative field shifted left by the constant k (low k bits
    zero), so an ``or`` against it is field packing, not bit soup."""

    iv: IV
    shift: Optional[int] = None


def _clamp_i32(iv: IV) -> IV:
    return IV(max(iv.lo, INT32_MIN), min(iv.hi, INT32_MAX))


def _bitlen_cap(hi: int) -> int:
    """Smallest 2^m - 1 >= hi (hi >= 0): the or-result ceiling."""
    return (1 << int(hi).bit_length()) - 1


# ---------------------------------------------------------------------------
# the scenario config under analysis
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class TickConfig:
    """Everything the interval analysis needs to bound a replay: the
    protocol constants baked into the traced core plus the scenario-wide
    extremes of the inputs (mirroring ``state.max_pack_tick``'s
    parameters, with ``clk_slack`` = how far ahead of ``max_rate * t`` the
    engine's accumulated clocks already run)."""

    t_end: int
    n_proposers: int = 8
    n_acceptors: int = 5
    lease_ticks: int = 3
    round_q4: int = QUARTERS
    guard_q4: Optional[int] = None  # None = lease_q4 (the eps=0 case)
    max_delay: int = 0
    max_rate: int = QUARTERS
    clk_slack: int = 0
    sync: bool = False
    lease_q4: Optional[int] = None  # overrides lease_ticks when given
    corrupt: bool = False  # thread the acc_stale/acc_equiv planes
    #: > 0 threads the crash/restart planes AND switches ballots onto the
    #: restart-carve encoding (state.RESTART_SHIFT): the highest per-
    #: proposer restart counter any tick can carry
    max_restarts: int = 0
    extend: bool = False  # thread the §6 extends plane

    @property
    def majority(self) -> int:
        return self.n_acceptors // 2 + 1

    @property
    def restart(self) -> bool:
        return self.max_restarts > 0

    @property
    def eff_lease_q4(self) -> int:
        if self.lease_q4 is not None:
            return int(self.lease_q4)
        return lease_quarters(self.lease_ticks)

    @property
    def eff_guard_q4(self) -> int:
        return self.eff_lease_q4 if self.guard_q4 is None else int(self.guard_q4)

    @property
    def eff_rate(self) -> int:
        return max(int(self.max_rate), QUARTERS)


# ---------------------------------------------------------------------------
# tracing the tick cores (once per protocol config; intervals re-run free)
# ---------------------------------------------------------------------------
#: invar layout of each traced core: (name, kind) per flat argument.
#: kind "state" participates in the fixpoint; the rest are config inputs.
_SYNC_ARGS = (
    ("promised", "state"), ("acc_lease", "state"),
    ("own_id", "state_id"), ("ownp", "state"),
    ("t", "t"), ("attempt", "pid"), ("release", "pid"),
    ("up", "bool"), ("pclk", "clk"), ("aclk", "clk"),
)
_NET_STATE = (
    ("preq", "state"), ("presp", "state"), ("presp_pay", "state_id"),
    ("poreq", "state"), ("poresp", "state"), ("rel_s", "state"),
    ("rnd_ballot", "state"), ("rnd_phase", "state"),
    ("rnd_expiry", "state"), ("rnd_deadline", "state"),
    ("rnd_open_bits", "state"), ("rnd_acc_bits", "state"),
)
_DELAYED_ARGS = _SYNC_ARGS[:4] + _NET_STATE + _SYNC_ARGS[4:] + (
    ("link", "link"),
)
#: the corruption-plane variant: two extra [A, 1] boolean planes
#: (falsifier negative controls — acc_stale / acc_equiv)
_CORRUPT_ARGS = _DELAYED_ARGS + (("stale", "bool"), ("equiv", "bool"))
#: the crash/restart variant: the per-tick restart/deaf indicator planes
#: plus the running restart-counter plane ([0, max_restarts], the "rc"
#: kind) that the restart-mode ballot mint ORs under RESTART_SHIFT
_RESTART_TAIL = (
    ("acc_restart", "bool"), ("acc_deaf", "bool"),
    ("prop_restart", "rc"), ("prop_rc", "rc"),
)
#: the §6 extend variant: one extra [1, bn] proposer-id plane (the owner
#: extending its own live lease) merged into the attempt stream
_EXTEND_TAIL = (("extend", "pid"),)


@functools.lru_cache(maxsize=None)
def trace_tick_core(
    n_proposers: int,
    n_acceptors: int,
    lease_q4: int,
    round_q4: int,
    guard_q4: int,
    majority: int,
    *,
    sync: bool = False,
    legs: str = "gather",
    block_n: int = 8,
    corrupt: bool = False,
    restart: bool = False,
    extend: bool = False,
):
    """``jax.make_jaxpr`` of one tick core with the protocol constants
    closed over, on tiny block shapes (intervals are shape-oblivious
    except for iota/reduction extents, which use the real A/P). Returns
    a ClosedJaxpr; cached — the expensive trace happens once per config,
    every ``t_end`` probe of the binary search re-walks it for free."""
    import jax
    import jax.numpy as jnp

    from ...lease_array import netplane as _netplane
    from ...lease_array.ref import sync_tick_math

    A, P, bn = n_acceptors, n_proposers, block_n
    i32 = jnp.int32
    sds = jax.ShapeDtypeStruct
    lease_shapes = [sds((A, bn), i32), sds((A, bn), i32),
                    sds((1, bn), i32), sds((1, bn), i32)]
    common = [sds((), i32), sds((1, bn), i32), sds((1, bn), i32),
              sds((A, 1), i32), sds((P, 1), i32), sds((A, 1), i32)]

    if sync:
        def fn(pr, al, oi, op, t, att, rel, up, pclk, aclk):
            lease, count = sync_tick_math(
                (pr, al, oi, op), t, att, rel, up, pclk, aclk,
                majority=majority, lease_q4=lease_q4,
                n_proposers=P, guard_q4=guard_q4,
            )
            return (*lease, count)

        return jax.make_jaxpr(fn)(*lease_shapes, *common)

    net_shapes = [sds((A, bn), i32)] * 6 + [sds((1, bn), i32)] * 6
    legs_fn = _netplane.legs_select if legs == "select" else _netplane.legs_gather

    def fn(*args):
        lease, net = args[:4], args[4:16]
        rest = list(args[16:])
        adv = {}
        if extend:
            adv["extend"] = rest.pop()
        if restart:
            arst, deaf, prst, prc = rest[-4:]
            rest = rest[:-4]
            adv.update(
                acc_restart=arst, acc_deaf=deaf,
                prop_restart=prst, prop_rc=prc,
            )
        if corrupt:
            stale, equiv = rest[-2:]
            rest = rest[:-2]
            adv.update(stale=stale, equiv=equiv)
        t, att, rel, up, pclk, aclk, link = rest
        lease, net, count = _netplane.delayed_tick_math(
            lease, net, t, att, rel, up, pclk, aclk, link,
            majority=majority, lease_q4=lease_q4, round_q4=round_q4,
            n_proposers=P, guard_q4=guard_q4, legs=legs_fn, **adv,
        )
        return (*lease, *net, count)

    extra = [sds((A, 1), i32)] * 2 if corrupt else []
    if restart:
        extra = extra + [
            sds((A, 1), i32), sds((A, 1), i32),
            sds((P, 1), i32), sds((P, 1), i32),
        ]
    if extend:
        extra = extra + [sds((1, bn), i32)]
    return jax.make_jaxpr(fn)(
        *lease_shapes, *net_shapes, *common, sds((P, A), i32), *extra
    )


def _input_intervals(cfg: TickConfig) -> dict[str, AbsVal]:
    """Config inputs → intervals. Clocks are accumulated local quarter-
    ticks: at most ``max_rate`` per tick plus any pre-existing slack."""
    clk_hi = cfg.eff_rate * cfg.t_end + cfg.clk_slack
    return {
        "t": AbsVal(IV(0, cfg.t_end)),
        "pid": AbsVal(IV(-1, cfg.n_proposers - 1)),
        "bool": AbsVal(BOOL),
        "clk": AbsVal(IV(0, clk_hi)),
        "link": AbsVal(IV(0, 2 * cfg.max_delay + 1)),
        "rc": AbsVal(IV(0, cfg.max_restarts)),
    }


def _init_state(kind: str) -> AbsVal:
    # fresh engines: every packed plane is 0, id planes are NO_PROPOSER
    return AbsVal(IV(-1, -1)) if kind == "state_id" else AbsVal(IV(0, 0))


# ---------------------------------------------------------------------------
# the abstract interpreter
# ---------------------------------------------------------------------------
def _shift_amount(v: AbsVal) -> Optional[int]:
    """The shift count iff statically a single value."""
    return v.iv.lo if v.iv.lo == v.iv.hi else None


class _Interp:
    """One abstract walk of a (closed) jaxpr. Collects findings only when
    ``report`` is set — fixpoint warm-up passes stay silent so a single
    violation isn't reported once per iteration."""

    def __init__(self, report: Optional[list[Finding]] = None) -> None:
        self.report = report
        self._seen_unknown: set[str] = set()

    # -- findings ----------------------------------------------------------
    def _finding(self, rule: str, where: str, detail: str) -> None:
        if self.report is not None:
            self.report.append(Finding("intervals", rule, where, detail))

    def _check_i32(self, iv: IV, prim: str, where: str) -> IV:
        if iv.lo < INT32_MIN or iv.hi > INT32_MAX:
            self._finding(
                "int32-overflow", where,
                f"`{prim}` result can reach [{iv.lo}, {iv.hi}], outside "
                f"int32 [{INT32_MIN}, {INT32_MAX}] — the packed tick math "
                f"would silently wrap",
            )
            iv = _clamp_i32(iv)
        return iv

    # -- primitive rules ---------------------------------------------------
    def eval_jaxpr(self, jaxpr, consts, args: list[AbsVal]) -> list[AbsVal]:
        env: dict = {}

        def read(atom) -> AbsVal:
            import jax

            if isinstance(atom, jax.core.Literal):
                v = int(np.asarray(atom.val).min())
                hi = int(np.asarray(atom.val).max())
                return AbsVal(IV(v, hi))
            return env[atom]

        for var, const in zip(jaxpr.constvars, consts):
            arr = np.asarray(const)
            env[var] = AbsVal(IV(int(arr.min()), int(arr.max())))
        for var, val in zip(jaxpr.invars, args):
            env[var] = val

        for eqn in jaxpr.eqns:
            outs = self._eval_eqn(eqn, [read(v) for v in eqn.invars])
            for var, val in zip(eqn.outvars, outs):
                env[var] = val
        return [read(v) for v in jaxpr.outvars]

    def _eval_eqn(self, eqn, ins: list[AbsVal]) -> list[AbsVal]:
        prim = eqn.primitive.name
        where = f"eqn `{prim}`"
        out_aval = eqn.outvars[0].aval if eqn.outvars else None
        is_bool = out_aval is not None and out_aval.dtype == np.bool_

        # calls (pjit et al.): recurse into the sub-jaxpr
        sub = eqn.params.get("jaxpr")
        if sub is not None and hasattr(sub, "jaxpr"):
            outs = self.eval_jaxpr(sub.jaxpr, sub.consts, ins)
            return outs

        if prim in ("broadcast_in_dim", "reshape", "squeeze", "slice",
                    "transpose", "copy", "stop_gradient", "expand_dims"):
            return [ins[0]]  # shape-only: value set (and provenance) unchanged
        if prim == "gather":
            return [AbsVal(ins[0].iv)]
        if prim == "convert_element_type":
            iv = ins[0].iv
            if is_bool:
                iv = IV(max(0, min(iv.lo, 1)), max(0, min(iv.hi, 1)))
            return [AbsVal(iv)]
        if prim == "iota":
            dim = eqn.params["dimension"]
            n = eqn.params["shape"][dim]
            return [AbsVal(IV(0, max(0, n - 1)))]

        a = ins[0].iv if ins else None
        b = ins[1].iv if len(ins) > 1 else None

        if prim == "add":
            iv = self._check_i32(IV(a.lo + b.lo, a.hi + b.hi), prim, where)
            return [AbsVal(iv)]
        if prim == "sub":
            iv = self._check_i32(IV(a.lo - b.hi, a.hi - b.lo), prim, where)
            return [AbsVal(iv)]
        if prim == "mul":
            prods = [a.lo * b.lo, a.lo * b.hi, a.hi * b.lo, a.hi * b.hi]
            iv = self._check_i32(IV(min(prods), max(prods)), prim, where)
            return [AbsVal(iv)]
        if prim == "shift_left":
            s_lo = max(0, b.lo)
            s_hi = max(0, b.hi)
            cand = [a.lo << s_lo, a.lo << s_hi, a.hi << s_lo, a.hi << s_hi]
            raw = IV(min(cand), max(cand))
            if raw.hi > INT32_MAX and a.lo >= 0:
                # name the budget in pack terms when the shift is a pack
                k = _shift_amount(ins[1])
                if k == PACK_SHIFT:
                    self._finding(
                        "pack-budget", where,
                        f"packed deadline field can reach {a.hi} quarter-"
                        f"ticks but only [0, {MAX_PACK_Q4}] fits above "
                        f"PACK_SHIFT={PACK_SHIFT} in int32",
                    )
                    raw = _clamp_i32(raw)
                else:
                    raw = self._check_i32(raw, prim, where)
            else:
                raw = self._check_i32(raw, prim, where)
            shift = _shift_amount(ins[1]) if a.lo >= 0 else None
            return [AbsVal(raw, shift=shift)]
        if prim in ("shift_right_arithmetic", "shift_right_logical"):
            if prim == "shift_right_logical" and a.lo < 0:
                return [AbsVal(INT32)]  # not expected in the cores
            s_lo, s_hi = max(0, b.lo), max(0, b.hi)
            cand = [a.lo >> s_lo, a.lo >> s_hi, a.hi >> s_lo, a.hi >> s_hi]
            return [AbsVal(IV(min(cand), max(cand)))]
        if prim == "or":
            return [self._eval_or(ins[0], ins[1], is_bool, where)]
        if prim == "and":
            if is_bool:
                return [AbsVal(IV(min(a.lo, b.lo), min(a.hi, b.hi)))]
            if a.lo >= 0 or b.lo >= 0:
                hi = min(a.hi, b.hi) if (a.lo >= 0 and b.lo >= 0) else (
                    a.hi if a.lo >= 0 else b.hi
                )
                return [AbsVal(IV(0, max(0, hi)))]
            return [AbsVal(INT32)]
        if prim == "xor":
            if is_bool:
                return [AbsVal(BOOL)]
            if a.lo >= 0 and b.lo >= 0:
                return [AbsVal(IV(0, max(_bitlen_cap(a.hi), _bitlen_cap(b.hi))))]
            return [AbsVal(INT32)]
        if prim == "not":
            if is_bool:
                return [AbsVal(IV(1 - a.hi, 1 - a.lo))]
            return [AbsVal(IV(-a.hi - 1, -a.lo - 1))]
        if prim in ("eq", "ne", "lt", "le", "gt", "ge"):
            return [AbsVal(BOOL)]
        if prim == "max":
            return [AbsVal(IV(max(a.lo, b.lo), max(a.hi, b.hi)))]
        if prim == "min":
            return [AbsVal(IV(min(a.lo, b.lo), min(a.hi, b.hi)))]
        if prim == "clamp":
            lo_iv, x, hi_iv = ins[0].iv, ins[1].iv, ins[2].iv
            return [AbsVal(IV(max(x.lo, lo_iv.lo), min(x.hi, hi_iv.hi)))]
        if prim == "rem":
            if b.lo > 0:
                hi = b.hi - 1
                if a.lo >= 0:
                    return [AbsVal(IV(0, min(a.hi, hi)))]
                return [AbsVal(IV(-hi, hi))]  # lax.rem: sign of dividend
            return [AbsVal(INT32)]
        if prim == "sign":
            sgn = lambda v: (v > 0) - (v < 0)
            return [AbsVal(IV(sgn(a.lo), sgn(a.hi)))]
        if prim == "div":
            if b.lo > 0 or b.hi < 0:  # divisor can't be 0
                # lax.div truncates toward zero
                tdiv = lambda p, q: abs(p) // abs(q) * (1 if (p >= 0) == (q > 0) else -1)
                cand = [tdiv(p, q) for p in (a.lo, a.hi) for q in (b.lo, b.hi)]
                return [AbsVal(IV(min(cand), max(cand)))]
            return [AbsVal(INT32)]
        if prim == "select_n":
            iv = ins[1].iv
            for case in ins[2:]:
                iv = iv.join(case.iv)
            return [AbsVal(iv)]
        if prim == "reduce_sum":
            n = 1
            src = eqn.invars[0].aval.shape
            for ax in eqn.params["axes"]:
                n *= src[ax]
            iv = self._check_i32(IV(n * a.lo, n * a.hi), prim, where)
            return [AbsVal(iv)]
        if prim in ("reduce_max", "reduce_min", "reduce_or", "reduce_and"):
            return [AbsVal(a)]

        # unknown primitive: stay sound (full int32 / bool) and say so once
        if prim not in self._seen_unknown:
            self._seen_unknown.add(prim)
            self._finding(
                "unknown-primitive", where,
                f"no interval rule for `{prim}`; result widened to full "
                f"int32 — add a rule to staticcheck/intervals.py",
            )
        fallback = AbsVal(BOOL if is_bool else INT32)
        return [fallback for _ in eqn.outvars]

    def _eval_or(self, x: AbsVal, y: AbsVal, is_bool: bool, where: str) -> AbsVal:
        if is_bool:
            return AbsVal(IV(max(x.iv.lo, y.iv.lo), max(x.iv.hi, y.iv.hi)))
        # pack rule: (field << k) | low is exact addition iff low fits in k
        # bits; a low side that can't fit is a pack-budget violation (it
        # would bleed into the deadline field)
        for hi_side, lo_side in ((x, y), (y, x)):
            if hi_side.shift is None:
                continue
            k = hi_side.shift
            budget = (1 << k) - 1
            if 0 <= lo_side.iv.lo and lo_side.iv.hi <= budget:
                return AbsVal(IV(
                    hi_side.iv.lo + lo_side.iv.lo,
                    hi_side.iv.hi + lo_side.iv.hi,
                ))
            self._finding(
                "pack-budget", where,
                f"low field of a `<< {k} | ...` pack can reach "
                f"[{lo_side.iv.lo}, {lo_side.iv.hi}] but the packed layout "
                f"budgets [0, {budget}]"
                + (" (= PACK_MASK: a ballot past the 15-bit budget)"
                   if k == PACK_SHIFT else ""),
            )
            return AbsVal(_clamp_i32(IV(
                min(hi_side.iv.lo, lo_side.iv.lo),
                hi_side.iv.hi + max(0, lo_side.iv.hi),
            )))
        if x.iv.lo >= 0 and y.iv.lo >= 0:
            return AbsVal(IV(
                max(x.iv.lo, y.iv.lo),
                max(_bitlen_cap(x.iv.hi), _bitlen_cap(y.iv.hi)),
            ))
        return AbsVal(INT32)  # bitwise: can't leave int32


# ---------------------------------------------------------------------------
# the public checker
# ---------------------------------------------------------------------------
def _core_and_layout(cfg: TickConfig, legs: str):
    closed = trace_tick_core(
        cfg.n_proposers, cfg.n_acceptors, cfg.eff_lease_q4, cfg.round_q4,
        cfg.eff_guard_q4, cfg.majority, sync=cfg.sync, legs=legs,
        corrupt=cfg.corrupt, restart=cfg.restart, extend=cfg.extend,
    )
    if cfg.sync:
        layout = _SYNC_ARGS
    else:
        layout = _CORRUPT_ARGS if cfg.corrupt else _DELAYED_ARGS
        if cfg.restart:
            layout = layout + _RESTART_TAIL
        if cfg.extend:
            layout = layout + _EXTEND_TAIL
    return closed, layout


def analyze_tick_config(
    cfg: TickConfig, *, legs: str = "gather", core=None, layout=None,
) -> list[Finding]:
    """Prove (or refute) that replaying ticks ``[0, cfg.t_end]`` keeps
    every tick-core intermediate inside int32 and every pack inside its
    field budget. Returns the violations (empty = proven safe).

    ``core``/``layout`` override the traced core — the mutation fixtures
    use this to feed a seeded-bad variant through the same checker.
    """
    if core is None:
        core, layout = _core_and_layout(cfg, legs)
    jaxpr, consts = core.jaxpr, core.consts
    cfg_ivs = _input_intervals(cfg)
    n_state = sum(1 for _, kind in layout if kind.startswith("state"))
    state = [
        _init_state(kind) for _, kind in layout if kind.startswith("state")
    ]

    def args_for(state_vals):
        vals, si = [], 0
        for _, kind in layout:
            if kind.startswith("state"):
                vals.append(state_vals[si])
                si += 1
            else:
                vals.append(cfg_ivs[kind])
        return vals

    # fixpoint: join each pass's state outputs back into the state inputs
    silent = _Interp(report=None)
    for _ in range(_MAX_FIXPOINT_ITERS):
        outs = silent.eval_jaxpr(jaxpr, consts, args_for(state))
        new = [
            AbsVal(s.iv.join(o.iv))
            for s, o in zip(state, outs[:n_state])
        ]
        if all(n.iv == s.iv for n, s in zip(new, state)):
            break
        state = new
    else:  # pragma: no cover - the cores converge in a handful of passes
        state = [AbsVal(INT32)] * n_state

    # the reporting pass, on the converged invariant
    findings: list[Finding] = []
    _Interp(report=findings).eval_jaxpr(jaxpr, consts, args_for(state))
    return findings


def derived_max_pack_tick(
    n_proposers: int,
    lease_q4: int,
    max_delay_ticks: int = 0,
    max_rate: int = QUARTERS,
    clk_slack: int = 0,
    *,
    n_acceptors: int = 5,
    round_q4: int = QUARTERS,
    guard_q4: Optional[int] = None,
    sync: bool = False,
    max_restarts: int = 0,
) -> int:
    """``state.max_pack_tick`` as a *derived* result: the largest ``t_end``
    the interval analysis proves safe, by monotone binary search (larger
    horizons only widen intervals, so safety is downward-closed).

    Signature mirrors the hand formula so tests can diff them on a grid.
    """
    base = TickConfig(
        t_end=0, n_proposers=n_proposers, n_acceptors=n_acceptors,
        lease_q4=lease_q4, round_q4=round_q4, guard_q4=guard_q4,
        max_delay=max_delay_ticks, max_rate=max_rate, clk_slack=clk_slack,
        sync=sync, max_restarts=max_restarts,
    )
    core, layout = _core_and_layout(base, "gather")

    def safe(t_end: int) -> bool:
        return not analyze_tick_config(
            replace(base, t_end=t_end), core=core, layout=layout
        )

    if not safe(0):
        return -1  # the config can't even start (e.g. clk_slack too hot)
    lo, hi = 0, 1
    while safe(hi):
        lo, hi = hi, hi * 2
        if hi > INT32_MAX:
            return INT32_MAX  # pragma: no cover - ballots overflow far sooner
    while hi - lo > 1:
        mid = (lo + hi) // 2
        lo, hi = (mid, hi) if safe(mid) else (lo, mid)
    return lo
