"""§6 extend + §7 release.

- extend: a renewing master holds the lease continuously over 100x T with
  zero handoffs (mastership retention).
- release: handoff latency to the next waiter after an explicit release vs.
  waiting for natural expiry (release is ~T/2 faster on average)."""
from __future__ import annotations

import numpy as np

from repro.configs import CellConfig
from repro.core import build_cell
from repro.sim.network import NetConfig

from .common import WallTimer

NET = NetConfig(delay_min=0.005, delay_max=0.02)


def run():
    rows = []
    cfg = CellConfig(n_acceptors=5, max_lease_time=30.0, lease_timespan=8.0,
                     renew_fraction=0.5)
    with WallTimer() as wt:
        cell = build_cell(cfg, n_proposers=3, seed=0, net=NET)
        cell.proposers[0].proposer.acquire()
        for p in cell.proposers[1:]:
            p.proposer.acquire()  # hungry rivals throughout
        horizon = 100 * cfg.lease_timespan
        cell.env.run_until(horizon)
        cell.monitor.assert_clean()
    frac = cell.monitor.total_owned_time("R") / horizon
    owner = cell.monitor.owner_of("R")
    extends = cell.nodes[owner].proposer.stats["extended"] if owner is not None else 0
    rows.append((
        "extend_retention_100T",
        wt.dt / 100 * 1e6,
        f"owned_frac={frac:.4f}, handoffs={cell.monitor.handoffs('R')}, "
        f"extends={extends}",
    ))

    # release vs expiry handoff latency
    lat = {"release": [], "expiry": []}
    with WallTimer() as wt:
        for mode in ("release", "expiry"):
            for seed in range(20):
                cell = build_cell(cfg, n_proposers=2, seed=seed, net=NET)
                p0, p1 = (n.proposer for n in cell.proposers[:2])
                p0.acquire(renew=False)
                cell.env.run_until(1.0)
                p1.acquire()
                cell.env.run_until(2.0)
                t0 = cell.env.now
                if mode == "release":
                    p0.release()
                gained = [t for t in cell.monitor.acquire_times if t > t0]
                cell.env.run_until(t0 + 2 * cfg.lease_timespan)
                gained = [t for t in cell.monitor.acquire_times if t > t0]
                if gained:
                    lat[mode].append(min(gained) - t0)
    rows.append((
        "release_handoff_latency",
        wt.dt / 40 * 1e6,
        f"median release={np.median(lat['release']):.2f}s vs "
        f"expiry={np.median(lat['expiry']):.2f}s (T={cfg.lease_timespan}s)",
    ))
    return rows
