# One function per paper table. Print ``name,us_per_call,derived`` CSV.
from __future__ import annotations

import sys
import time


def main() -> None:
    from . import (
        bench_acquisition,
        bench_contention,
        bench_extend_release,
        bench_failover,
        bench_lease_array,
        bench_liveness,
        bench_memory,
        bench_throughput,
        roofline,
    )

    modules = [
        ("fig2_acquisition", bench_acquisition),
        ("s1_contention", bench_contention),
        ("s5_liveness", bench_liveness),
        ("s6_s7_extend_release", bench_extend_release),
        ("s8_memory", bench_memory),
        ("s8_throughput", bench_throughput),
        ("s8_lease_array", bench_lease_array),
        ("s9_failover", bench_failover),
        ("roofline", roofline),
    ]
    print("name,us_per_call,derived")
    failures = 0
    for label, mod in modules:
        t0 = time.time()
        try:
            for name, us, derived in mod.run():
                print(f'{name},{us:.2f},"{derived}"')
        except Exception as e:  # pragma: no cover
            failures += 1
            print(f'{label},NaN,"ERROR: {e!r}"', file=sys.stdout)
        sys.stdout.flush()
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
