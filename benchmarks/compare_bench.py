"""Diff two ``BENCH_lease_array.json`` files row by row and gate on
regressions.

    python -m benchmarks.compare_bench BASELINE.json CANDIDATE.json

Prints a per-row delta table (negative = the candidate got faster) and
exits nonzero on regressions. Rows present in only one file are listed but
never fail the gate — new benchmarks and retired rows are expected as the
suite grows. ``make bench-compare`` runs a fresh bench and diffs it
against the committed baseline; CI uploads the report as an artifact next
to the JSON.

The gate is header-aware: wall-clock numbers only compare honestly on the
same hardware, so the strict threshold (default 25%, ``--threshold``)
applies to raw deltas when both files report the same
platform/device-kind/device-count stamp (``bench_lease_array.emit_json``
writes it). Across machines — e.g. CI diffing a runner's numbers against
a baseline committed from a dev box — each row instead gates on its ratio
to a reference row present in both files (``--reference``, default
``lease_array_scan``): machine speed cancels out of ``row / reference``,
so the strict threshold still applies to *relative* slowdowns, while raw
wall-clock deltas only fail at the catastrophic threshold
(``--cross-machine-threshold``, default 300%; also the fallback when the
reference row is missing). ``--strict`` forces the raw same-machine gate
regardless.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

MACHINE_KEYS = ("platform", "device_kind", "n_devices", "jax_backend")


def load_rows(path: Path) -> tuple[dict, dict]:
    doc = json.loads(path.read_text())
    return doc, {r["name"]: r for r in doc.get("rows", [])}


def describe(doc: dict) -> str:
    return (
        f"rev={doc.get('git_rev', '?')} "
        f"backend={doc.get('jax_backend', '?')} "
        f"device={doc.get('device_kind', '?')} x{doc.get('n_devices', '?')} "
        f"({doc.get('platform', '?')})"
    )


def same_machine(a: dict, b: dict) -> bool:
    return all(
        a.get(k) is not None and a.get(k) == b.get(k) for k in MACHINE_KEYS
    )


def compare(
    base_path: Path,
    cand_path: Path,
    threshold: float,
    cross_threshold: float = 3.0,
    strict: bool = False,
    reference: str = "lease_array_scan",
) -> int:
    base_doc, base = load_rows(base_path)
    cand_doc, cand = load_rows(cand_path)
    comparable = strict or same_machine(base_doc, cand_doc)
    # cross-machine: gate each row's ratio to the reference row instead —
    # machine speed cancels out of row/reference, raw deltas only gate at
    # the catastrophic threshold
    ref = None
    if not comparable and not strict:
        b_ref = base.get(reference, {}).get("us_per_cell_tick", 0.0)
        c_ref = cand.get(reference, {}).get("us_per_cell_tick", 0.0)
        if b_ref > 0 and c_ref > 0:
            ref = (b_ref, c_ref)
    gate = threshold if comparable else cross_threshold
    print(f"baseline : {base_path}  [{describe(base_doc)}]")
    print(f"candidate: {cand_path}  [{describe(cand_doc)}]")
    if not comparable:
        if ref:
            print(
                f"machine stamps differ: cross-machine mode — raw deltas "
                f"gate at {gate:.0%} (catastrophic), ratios to "
                f"{reference!r} gate at {threshold:.0%}"
            )
        else:
            print(
                f"machine stamps differ and no shared {reference!r} row: "
                f"rows gate at {gate:.0%} (catastrophic only); deltas "
                f"below are indicative"
            )
    print()
    header = f"{'row':<36} {'base us':>10} {'cand us':>10} {'delta':>8}"
    if ref:
        header += f" {'rel':>8}"
    print(header)
    print("-" * len(header))
    regressions = []
    for name in base:
        if name not in cand:
            print(f"{name:<36} {base[name]['us_per_cell_tick']:>10.4f} "
                  f"{'—':>10} {'gone':>8}")
            continue
        b = base[name]["us_per_cell_tick"]
        c = cand[name]["us_per_cell_tick"]
        delta = (c - b) / b if b else 0.0
        rel_col = ""
        flag = ""
        if delta > gate:
            regressions.append((name, b, c, delta, "raw"))
            flag = "  << REGRESSION"
        elif ref and name != reference and b > 0:
            rel = (c / ref[1]) / (b / ref[0]) - 1.0
            rel_col = f" {rel:>+7.1%}"
            if rel > threshold:
                regressions.append((name, b, c, rel, f"vs {reference}"))
                flag = "  << REGRESSION (relative)"
        elif not comparable and delta > threshold:
            flag = "  (over same-machine threshold; cross-machine run)"
        print(f"{name:<36} {b:>10.4f} {c:>10.4f} {delta:>+7.1%}"
              f"{rel_col}{flag}")
    for name in cand:
        if name not in base:
            print(f"{name:<36} {'—':>10} "
                  f"{cand[name]['us_per_cell_tick']:>10.4f} {'new':>8}")
    print()
    if regressions:
        print(f"FAIL: {len(regressions)} row(s) regressed:")
        for name, b, c, delta, kind in regressions:
            print(f"  {name}: {b:.4f} -> {c:.4f} us/cell-tick "
                  f"({delta:+.1%} {kind})")
        return 1
    if ref:
        print(f"OK: no shared row regressed more than {gate:.0%} raw or "
              f"{threshold:.0%} relative to {reference!r}")
    else:
        print(f"OK: no shared row regressed more than {gate:.0%}")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="diff two lease-plane bench JSON files"
    )
    ap.add_argument("baseline", type=Path)
    ap.add_argument("candidate", type=Path)
    ap.add_argument(
        "--threshold", type=float, default=0.25,
        help="same-machine gate: fail on any shared row slower by more "
             "than this fraction (default 0.25)",
    )
    ap.add_argument(
        "--cross-machine-threshold", type=float, default=3.0,
        help="gate when the two files' machine stamps differ "
             "(default 3.0 = only a 4x cliff fails)",
    )
    ap.add_argument(
        "--strict", action="store_true",
        help="apply the same-machine threshold even across machines",
    )
    ap.add_argument(
        "--reference", default="lease_array_scan",
        help="row used to normalize cross-machine comparisons: each row's "
             "ratio to it gates at --threshold even when the machine "
             "stamps differ (default lease_array_scan)",
    )
    args = ap.parse_args(argv)
    return compare(
        args.baseline, args.candidate, args.threshold,
        args.cross_machine_threshold, args.strict, args.reference,
    )


if __name__ == "__main__":
    sys.exit(main())
