"""§8 'fine-grained locking': protocol throughput — lease negotiations per
second through one cell (simulated time) and Python events/sec (wall)."""
from __future__ import annotations

from repro.configs import CellConfig
from repro.core import build_cell
from repro.sim.network import NetConfig

from .common import WallTimer

N_RES = 3000


def run():
    cfg = CellConfig(n_acceptors=5, max_lease_time=60.0, lease_timespan=30.0)
    net = NetConfig(delay_min=0.0005, delay_max=0.002)
    cell = build_cell(cfg, n_proposers=5, seed=0, net=net)
    with WallTimer() as wt:
        for r in range(N_RES):
            cell.proposers[r % 5].proposer.acquire(f"res:{r}", renew=False)
        cell.env.run_until(10.0)
    acquired = len(cell.monitor.acquire_times)
    sim_rate = acquired / 10.0
    msgs = cell.env.network.delivered
    return [(
        "lease_throughput",
        wt.dt / max(msgs, 1) * 1e6,
        f"acquired={acquired}/{N_RES} in 10s sim ({sim_rate:.0f} leases/s/cell), "
        f"{msgs} msgs, {msgs/max(acquired,1):.1f} msgs/lease (min 4x5=20 w/ bcast)",
    )]
