"""§1: the naive majority algorithm blocks under contention, PaxosLease
does not. Reports full-deadlock probability (naive) vs time-to-first-owner
(PaxosLease) for 3 and 5 simultaneous proposers."""
from __future__ import annotations

import numpy as np

from repro.configs import CellConfig
from repro.core import build_cell
from repro.core.naive import build_naive_cell
from repro.sim.network import NetConfig

from .common import WallTimer

NET = NetConfig(delay_min=0.01, delay_max=0.02)
SEEDS = 60


def run():
    rows = []
    for n_prop in (3, 5):
        cfg = CellConfig(n_acceptors=3 if n_prop == 3 else 5, max_lease_time=60.0,
                         lease_timespan=15.0, backoff_min=0.05, backoff_max=0.3)
        blocked = 0
        with WallTimer() as wt:
            for seed in range(SEEDS):
                env, monitor, _, props = build_naive_cell(cfg, n_proposers=n_prop, seed=seed, net=NET)
                for p in props:
                    p.acquire()
                env.run_until(10.0)
                blocked += monitor.owner_of("R") is None
        rows.append((
            f"naive_blocking_p{n_prop}",
            wt.dt / SEEDS * 1e6,
            f"P(static deadlock at t=10s)={blocked/SEEDS:.2f}",
        ))

        acq_times = []
        with WallTimer() as wt:
            for seed in range(SEEDS):
                cell = build_cell(cfg, n_proposers=n_prop, seed=seed, net=NET)
                for p in cell.proposers:
                    p.proposer.acquire()
                cell.env.run_until(10.0)
                cell.monitor.assert_clean()
                acq_times.append(cell.monitor.acquire_times[0]
                                 if cell.monitor.acquire_times else float("inf"))
        acq = np.array(acq_times)
        rows.append((
            f"paxoslease_contention_p{n_prop}",
            wt.dt / SEEDS * 1e6,
            f"P(blocked)={float(np.mean(~np.isfinite(acq))):.2f}, "
            f"median t_acquire={float(np.median(acq)):.3f}s",
        ))
    return rows
