"""Fig. 2: lease acquisition takes two round-trips.

Sweeps the one-way network delay and reports acquisition latency in units of
RTT — PaxosLease's prepare+propose costs exactly 2 RTTs on a clean network,
independent of the absolute delay."""
from __future__ import annotations

from repro.configs import CellConfig
from repro.core import build_cell
from repro.sim.network import NetConfig

from .common import WallTimer


def run():
    rows = []
    for delay in (0.005, 0.05, 0.25):
        cfg = CellConfig(n_acceptors=5, max_lease_time=60.0, lease_timespan=10.0,
                         round_timeout=max(1.0, 8 * delay))
        net = NetConfig(delay_min=delay, delay_max=delay)
        with WallTimer() as wt:
            cell = build_cell(cfg, n_proposers=1, seed=0, net=net)
            cell.proposers[0].proposer.acquire()
            cell.env.run_until(20 * delay)
        t_acq = cell.monitor.acquire_times[0]
        rtts = t_acq / (2 * delay)
        msgs = cell.env.network.delivered
        rows.append((
            f"acquisition_rtt_delay{int(delay*1000)}ms",
            wt.dt / max(msgs, 1) * 1e6,
            f"latency={t_acq:.4f}s = {rtts:.2f} RTT (paper: 2)",
        ))
    return rows
