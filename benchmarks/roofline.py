"""Roofline table: merge dry-run artifacts (compiled memory/collectives)
with the analytic model (flops/bytes — scan-aware) into EXPERIMENTS.md
§Roofline rows. Also usable standalone:

  PYTHONPATH=src python -m benchmarks.roofline [--mesh pod16x16] [--md out.md]
"""
from __future__ import annotations

import argparse
import json
import pathlib

from repro.analysis.roofline import MESHES, roofline_terms
from repro.configs import SHAPES, arch_ids, get_config, get_shape, supports_shape

ART = pathlib.Path(__file__).resolve().parent.parent / "artifacts" / "dryrun"


def load_artifact(arch, shape, mesh, tag: str = ""):
    prefix = f"{tag}_" if tag else ""
    f = ART / f"{prefix}{arch}_{shape}_{mesh}.json"
    if f.exists():
        return json.loads(f.read_text())
    return None


def cell_row(arch: str, shape_name: str, mesh_name: str, variant: dict | None = None,
             tag: str = "") -> dict:
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    ok, reason = supports_shape(cfg, shape)
    row = {"arch": arch, "shape": shape_name, "mesh": mesh_name}
    if not ok:
        row["status"] = "skipped"
        row["note"] = reason
        return row
    art = load_artifact(arch, shape_name, mesh_name, tag=tag)
    coll = art["collectives"]["total_bytes"] if art and art.get("status") == "ok" else None
    t = roofline_terms(cfg, shape, MESHES[mesh_name], variant, coll_bytes_parsed=coll)
    row.update(status="ok", **{k: t[k] for k in (
        "compute_s", "memory_s", "collective_s", "dominant",
        "model_flops", "flops_total", "useful_flops_frac", "roofline_frac")})
    if art and art.get("status") == "ok":
        row["compiled_temp_gb"] = art["memory_analysis"].get("temp_size_in_bytes", 0) / 1e9
        row["compiled_args_gb"] = art["memory_analysis"].get("argument_size_in_bytes", 0) / 1e9
        row["hlo_coll_gb"] = art["collectives"]["total_bytes"] / 1e9
        row["compile_s"] = art["compile_s"]
    return row


def table(mesh_name: str) -> list[dict]:
    return [cell_row(a, s, mesh_name) for a in arch_ids() for s in SHAPES]


def to_markdown(rows: list[dict]) -> str:
    hdr = ("| arch | shape | compute s | memory s | collective s | dominant | "
           "useful/HLO flops | roofline frac | HLO coll GB/dev | temp GB/dev |")
    sep = "|" + "---|" * 10
    out = [hdr, sep]
    for r in rows:
        if r["status"] == "skipped":
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | skipped | — | — | — | — |")
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3e} | {r['memory_s']:.3e} "
            f"| {r['collective_s']:.3e} | **{r['dominant']}** | {r['useful_flops_frac']:.2f} "
            f"| {r['roofline_frac']:.2f} | {r.get('hlo_coll_gb', float('nan')):.1f} "
            f"| {r.get('compiled_temp_gb', float('nan')):.1f} |"
        )
    return "\n".join(out)


def run():
    """CSV rows for benchmarks.run: one summary line per shape class."""
    rows = []
    tab = [r for r in table("pod16x16") if r["status"] == "ok"]
    for shape in SHAPES:
        sub = [r for r in tab if r["shape"] == shape]
        if not sub:
            continue
        dom = max(set(x["dominant"] for x in sub),
                  key=lambda d: sum(x["dominant"] == d for x in sub))
        mean_frac = sum(x["roofline_frac"] for x in sub) / len(sub)
        rows.append((
            f"roofline_{shape}",
            0.0,
            f"{len(sub)} archs, typical bottleneck={dom}, "
            f"mean roofline_frac={mean_frac:.2f}",
        ))
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="pod16x16", choices=list(MESHES))
    ap.add_argument("--md")
    args = ap.parse_args()
    md = to_markdown(table(args.mesh))
    if args.md:
        pathlib.Path(args.md).write_text(md + "\n")
    print(md)
