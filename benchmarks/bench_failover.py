"""§9 (Keyspace role): master lease failover. Crash the master at a random
time; measure the gap until another node holds the lease. Expected bound:
remaining T + backoff + 2 RTT; never a violation."""
from __future__ import annotations

import numpy as np

from repro.cluster.coordinator import build_coordinated_cluster
from repro.configs import CellConfig, MASTER_CELL
from repro.sim.network import NetConfig

from .common import WallTimer

NET = NetConfig(delay_min=0.005, delay_max=0.03, loss=0.02)
SEEDS = 30


def run():
    cfg = MASTER_CELL  # 3 replicas, T=7, renew at 0.4T — the Keyspace shape
    gaps = []
    with WallTimer() as wt:
        for seed in range(SEEDS):
            cell, coord = build_coordinated_cluster(cfg, n_workers=0, seed=seed, net=NET)
            for n in cell.proposers:
                coord.campaign(n)
            cell.env.run_until(5.0)
            master = coord.master()
            if master is None:
                continue
            t_crash = 5.0 + (seed % 7)
            cell.env.run_until(t_crash)
            if coord.master() is not None:
                cell.nodes[coord.master()].crash()
            cell.env.run_until(t_crash + 4 * cfg.lease_timespan)
            cell.monitor.assert_clean()
            gaps.extend(coord.failover_times())
    g = np.array(gaps)
    return [(
        "master_failover",
        wt.dt / SEEDS * 1e6,
        f"n={len(g)}, median={np.median(g):.2f}s, p95={np.percentile(g, 95):.2f}s, "
        f"bound T+backoff={cfg.lease_timespan + cfg.backoff_max:.1f}s, violations=0",
    )]
