"""Shared benchmark utilities. Every bench module exposes
``run() -> list[(name, us_per_call, derived)]`` where ``us_per_call`` is the
wall-clock python cost per simulated protocol event (for throughput claims)
and ``derived`` is the paper-anchored quantity being reproduced."""
from __future__ import annotations

import time


class WallTimer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.dt = time.perf_counter() - self.t0


def fmt(x: float, nd=3) -> str:
    return f"{x:.{nd}g}"
