"""§8 at scale: cells/sec of the vectorized lease plane vs the event-driven
simulator on identical randomized workloads.

The event engine pays Python per message (the per-message overhead that
dominates quorum-protocol throughput in practice); the array plane pays one
batched dispatch for *all* cells — and, since PR 4, for all TICKS too: the
``lease_fused_scan`` row drives the fused window scan (packed int32 layout,
cell axis shard_map-ed across every visible device), while
``lease_array_scan`` keeps timing the per-tick ``lax.scan`` driver it always
measured, so the fused speedup is visible inside one file. Reported as
cell-ticks/sec.

``python -m benchmarks.bench_lease_array`` runs every mode and writes the
machine-readable ``BENCH_lease_array.json`` (schema at the bottom) so the
perf trajectory is tracked across PRs; ``make bench-json`` wraps it. The
__main__ entry re-execs itself with one JAX host device per CPU core so the
sharded driver has something to shard over (a real accelerator platform is
unaffected). ``benchmarks/compare_bench.py`` diffs two of these files and
gates CI on regressions.
"""
from __future__ import annotations

import os
import subprocess
import sys

if __name__ == "__main__" and "_LEASE_BENCH_CHILD" not in os.environ:
    # re-exec BEFORE jax is imported: expose every CPU core as a device so
    # the sharded fused driver can split the cell axis across them
    flags = os.environ.get("XLA_FLAGS", "")
    if "--xla_force_host_platform_device_count" not in flags:
        n = os.cpu_count() or 1
        os.environ["XLA_FLAGS"] = (
            f"{flags} --xla_force_host_platform_device_count={n}".strip()
        )
    os.environ["_LEASE_BENCH_CHILD"] = "1"
    os.execv(
        sys.executable,
        [sys.executable, "-m", "benchmarks.bench_lease_array", *sys.argv[1:]],
    )

import json
import platform
from pathlib import Path

import numpy as np

from repro.lease_array import (
    LeaseArrayEngine,
    make_tick,
    random_trace,
    replay_array,
    replay_event_sim,
)

from .common import WallTimer, fmt

BEST_OF = 3  # timed reps per row (after warm-up); best wall time wins


def timed(fn, reps=BEST_OF):
    """Best-of-N wall time of ``fn`` (call it warm first): the bench gates
    CI on per-row deltas, so single-shot scheduler noise must not fail the
    25% regression threshold on a loaded 2-core runner."""
    best_dt, best_out = None, None
    for _ in range(reps):
        with WallTimer() as wt:
            out = fn()
        if best_dt is None or wt.dt < best_dt:
            best_dt, best_out = wt.dt, out
    return best_dt, best_out

EVENT_CELLS, EVENT_TICKS = 96, 30
ARRAY_CELLS, ARRAY_TICKS = 4096, 128
KERNEL_CELLS, KERNEL_TICKS = 1024, 32
DELAY_CELLS, DELAY_TICKS = 1024, 96
DELAY_DEPTHS = (0, 1, 2, 4)
SWEEP_SCENARIOS, SWEEP_CELLS, SWEEP_TICKS = 1024, 32, 16


def _trace(n_cells, n_ticks, seed=0):
    return random_trace(
        seed, n_ticks=n_ticks, n_cells=n_cells,
        n_acceptors=5, n_proposers=8, lease_ticks=4,
        p_attempt=0.4, p_release=0.05, p_down_flip=0.0,
    )


def _pertick_replay(trace, *, netplane=False):
    """The trace through the pre-fused per-tick lax.scan driver (ONE
    lease_plane_tick dispatch body per tick) — the dispatch-overhead
    baseline the fused rows are measured against."""
    import jax.numpy as jnp

    from repro.lease_array import init_netplane, init_state
    from repro.lease_array.engine import _scenario_scanner
    from repro.lease_array.state import (
        QUARTERS,
        guarded_lease_q4,
        lease_quarters,
    )

    lease_q4 = lease_quarters(trace.lease_ticks)
    scanner = _scenario_scanner(
        trace.n_acceptors // 2 + 1,
        lease_q4,
        QUARTERS * trace.round_ticks,
        "jnp",
        not netplane,
        guarded_lease_q4(lease_q4, trace.drift_eps),
    )
    planes = {
        k: jnp.asarray(v) for k, v in trace.scenario().planes.items()
    }
    state = init_state(trace.n_cells, trace.n_acceptors, trace.n_proposers)
    net = init_netplane(trace.n_cells, trace.n_acceptors)
    _, _, owners, counts = scanner(state, net, jnp.int32(0), None, planes)
    return np.asarray(owners), np.asarray(counts)


def run():
    rows = []

    ev = _trace(EVENT_CELLS, EVENT_TICKS)
    dt, _ = timed(lambda: replay_event_sim(ev, strict_monitor=True), reps=2)
    ev_rate = EVENT_CELLS * EVENT_TICKS / dt
    rows.append((
        "lease_event_sim",
        dt / (EVENT_CELLS * EVENT_TICKS) * 1e6,
        f"{EVENT_CELLS} cells x {EVENT_TICKS} ticks: {fmt(ev_rate)} cell-ticks/s",
    ))

    ar = _trace(ARRAY_CELLS, ARRAY_TICKS)
    _pertick_replay(_trace(ARRAY_CELLS, ARRAY_TICKS, seed=1))  # warm the jit
    dt, (owners, counts) = timed(lambda: _pertick_replay(ar))
    assert counts.max() <= 1, "at-most-one-owner violated in the array plane"
    ar_rate = ARRAY_CELLS * ARRAY_TICKS / dt
    rows.append((
        "lease_array_scan",
        dt / (ARRAY_CELLS * ARRAY_TICKS) * 1e6,
        f"{ARRAY_CELLS} cells x {ARRAY_TICKS} ticks, per-tick scan driver: "
        f"{fmt(ar_rate)} cell-ticks/s ({fmt(ar_rate / ev_rate)}x event sim), "
        f"owned={float((owners >= 0).mean()):.2f}",
    ))

    # the fused window scan (run_trace's default path): packed layout, one
    # dispatch for the whole trace, cell axis sharded across devices
    replay_array(_trace(ARRAY_CELLS, ARRAY_TICKS, seed=1))  # warm
    dt, (owners, counts) = timed(lambda: replay_array(ar))
    assert counts.max() <= 1
    fused_rate = ARRAY_CELLS * ARRAY_TICKS / dt
    rows.append((
        "lease_fused_scan",
        dt / (ARRAY_CELLS * ARRAY_TICKS) * 1e6,
        f"{ARRAY_CELLS} cells x {ARRAY_TICKS} ticks, fused+sharded scan: "
        f"{fmt(fused_rate)} cell-ticks/s "
        f"({fused_rate / ar_rate:.2f}x the per-tick scan driver)",
    ))

    # dispatch cost, kept visible: ONE host-driven tick (warm) is dominated
    # by launch overhead, which the fused scan pays once per trace instead
    # of once per tick
    eng = LeaseArrayEngine(ARRAY_CELLS, n_acceptors=5, n_proposers=8,
                           lease_ticks=4)
    attempt = np.arange(ARRAY_CELLS, dtype=np.int32) % eng.n_proposers
    tick = make_tick(n_cells=ARRAY_CELLS, n_acceptors=5, n_proposers=8,
                     attempts=attempt)
    eng.step(tick)  # warm
    dt, _ = timed(lambda: eng.step(tick))
    rows.append((
        "kernel_launch_overhead",
        dt / ARRAY_CELLS * 1e6,
        f"one dispatched tick over {ARRAY_CELLS} cells "
        f"({dt * 1e3:.2f} ms/dispatch — the per-tick driver pays this "
        f"every tick, the fused scan once per trace)",
    ))

    # the Pallas window kernel under the scan driver, interpret mode: the
    # CI-portable correctness harness for the TPU kernel (interpret-mode
    # wall time is a python-loop artifact, not a kernel speed claim)
    kt = _trace(KERNEL_CELLS, KERNEL_TICKS)
    replay_array(
        _trace(KERNEL_CELLS, KERNEL_TICKS, seed=1), backend="pallas"
    )  # warm
    dt, (owners_k, counts_k) = timed(
        lambda: replay_array(kt, backend="pallas"), reps=2
    )
    owners_j, _ = replay_array(kt)
    assert np.array_equal(owners_k, owners_j), "kernel != jnp oracle"
    rows.append((
        "lease_kernel_scan",
        dt / (KERNEL_CELLS * KERNEL_TICKS) * 1e6,
        f"{KERNEL_CELLS} cells x {KERNEL_TICKS} ticks, fused window kernel "
        f"(interpret mode, bit-exact vs jnp oracle; compile with "
        f"backend='pallas_tpu' on real TPUs)",
    ))
    return rows


def _delayed_trace(max_delay: int, n_ticks: int, seed: int = 5, asymmetric=False):
    return random_trace(
        seed, n_ticks=n_ticks, n_cells=DELAY_CELLS,
        n_acceptors=5, n_proposers=8, lease_ticks=8,
        p_attempt=0.8, p_release=0.05, p_down_flip=0.0,
        max_delay_ticks=max_delay, p_drop=0.05 if max_delay else 0.0,
        asymmetric=asymmetric, round_ticks=max(3, max_delay + 1),
    )


def run_delayed(depths=DELAY_DEPTHS):
    """Delay-depth sweep of the in-flight message plane: cell-ticks/sec of
    the netplane scan at increasing per-leg delay bounds (depth 0 = the
    zero-delay special case run through the same delayed step), plus the
    resulting ownership density — lease dynamics vs latency regime, the
    Keyspace/cloud-report axis (arXiv 1209.3913, 1404.6719). The deepest
    sweep point re-runs with asymmetric [T, P, A] link matrices, both
    through the fused scan (the historic row name) and through the
    per-tick driver (the in-file baseline for the fused speedup)."""
    rows = []
    sweep = [(d, False) for d in depths] + [(max(depths), True)]
    for depth, asym in sweep:
        tr = _delayed_trace(depth, DELAY_TICKS, asymmetric=asym)
        # warm with the SAME trace length: the scan jit is shape-specialized,
        # so a short warm-up trace would leave the compile inside the timer
        replay_array(
            _delayed_trace(depth, DELAY_TICKS, seed=6, asymmetric=asym),
            netplane=True,
        )
        dt, (owners, counts) = timed(
            lambda: replay_array(tr, netplane=True)
        )
        assert counts.max() <= 1, "at-most-one-owner violated in the netplane"
        rate = DELAY_CELLS * DELAY_TICKS / dt
        name = f"lease_netplane_delay{depth}" + ("_asym" if asym else "")
        rows.append((
            name,
            dt / (DELAY_CELLS * DELAY_TICKS) * 1e6,
            f"{DELAY_CELLS} cells x {DELAY_TICKS} ticks, delay<={depth} "
            f"drop={0.05 if depth else 0.0}"
            f"{' [P, A] asymmetric links' if asym else ''}: "
            f"{fmt(rate)} cell-ticks/s, "
            f"owned={float((owners >= 0).mean()):.2f}",
        ))
        if asym:  # the per-tick baseline on the identical workload
            _pertick_replay(
                _delayed_trace(depth, DELAY_TICKS, seed=6, asymmetric=True),
                netplane=True,
            )  # warm
            dt, _ = timed(lambda: _pertick_replay(tr, netplane=True))
            base_rate = DELAY_CELLS * DELAY_TICKS / dt
            rows.append((
                f"{name}_pertick",
                dt / (DELAY_CELLS * DELAY_TICKS) * 1e6,
                f"same workload through the per-tick scan driver: "
                f"{fmt(base_rate)} cell-ticks/s "
                f"(the fused row is {rate / base_rate:.2f}x faster)",
            ))
    return rows


def run_drift(depth: int = 2):
    """The drifted-clock path: the same netplane scan with per-node
    clock-rate planes (ε = 0.25 → integer rate steps in {3, 4, 5}) and the
    T·(1-ε)/(1+ε) proposer discount threaded through every deadline —
    through BOTH drivers, so the committed baseline gates the drift
    plumbing (local-clock prefix sums + per-cell owner-clock selects) on
    the fused path (``lease_netplane_drift``) and the per-tick driver
    (``lease_drift_pertick``, the ``_pertick`` naming convention of the
    asym row)."""
    def drift_trace(seed):
        return random_trace(
            seed, n_ticks=DELAY_TICKS, n_cells=DELAY_CELLS,
            n_acceptors=5, n_proposers=8, lease_ticks=8,
            p_attempt=0.8, p_release=0.05, p_down_flip=0.0,
            max_delay_ticks=depth, p_drop=0.05, round_ticks=depth + 1,
            drift_eps=0.25,
        )

    tr = drift_trace(7)
    replay_array(drift_trace(8), netplane=True)  # same-shape warm-up compile
    dt, (owners, counts) = timed(lambda: replay_array(tr, netplane=True))
    assert counts.max() <= 1, "§4 violated under drift in the bench trace"
    rate = DELAY_CELLS * DELAY_TICKS / dt
    rows = [(
        "lease_netplane_drift",
        dt / (DELAY_CELLS * DELAY_TICKS) * 1e6,
        f"{DELAY_CELLS} cells x {DELAY_TICKS} ticks, drift eps=0.25 "
        f"(rates 3-5/4) + delay<={depth} drop=0.05, fused scan: "
        f"{fmt(rate)} cell-ticks/s, "
        f"owned={float((owners >= 0).mean()):.2f}",
    )]
    _pertick_replay(drift_trace(8), netplane=True)  # warm
    dt, (_, counts) = timed(lambda: _pertick_replay(tr, netplane=True))
    assert counts.max() <= 1
    base_rate = DELAY_CELLS * DELAY_TICKS / dt
    rows.append((
        "lease_drift_pertick",
        dt / (DELAY_CELLS * DELAY_TICKS) * 1e6,
        f"same drifted workload through the per-tick scan driver: "
        f"{fmt(base_rate)} cell-ticks/s "
        f"(the fused row is {rate / base_rate:.2f}x faster)",
    ))
    return rows


def run_restart(depth: int = 4):
    """The crash/restart planes' cost next to the delay rows they extend:
    all-acceptor ROLLING diskless restarts (two staggered waves — every
    acceptor blanks and goes deaf for M twice per trace, never a whole
    quorum at once) plus one proposer restart-counter bump each (inside
    the RESTART_SHIFT carve), over the deepest delay regime. Restart mode
    switches the whole dispatch to carved ballots + deaf/counter streams,
    so this row prices exactly what the all-default strip avoids."""
    def storm_trace(seed):
        tr = _delayed_trace(depth, DELAY_TICKS, seed=seed)
        T, A, P = DELAY_TICKS, tr.n_acceptors, tr.n_proposers
        rst = np.zeros((T, A), np.int32)
        for wave in (16, 56):
            for a in range(A):
                rst[wave + 4 * a, a] = 1
        prst = np.zeros((T, P), np.int32)
        for p in range(P):
            prst[8 + 6 * p, p] = 1
        tr.acc_restarts, tr.prop_restarts = rst, prst
        return tr

    tr = storm_trace(9)
    replay_array(storm_trace(10), netplane=True)  # same-shape warm-up
    dt, (owners, counts) = timed(lambda: replay_array(tr, netplane=True))
    assert counts.max() <= 1, "§4 violated under the restart storm"
    rate = DELAY_CELLS * DELAY_TICKS / dt
    return [(
        "lease_restart_storm",
        dt / (DELAY_CELLS * DELAY_TICKS) * 1e6,
        f"{DELAY_CELLS} cells x {DELAY_TICKS} ticks, delay<={depth} "
        f"drop=0.05 + rolling acceptor restarts (2 waves x "
        f"{tr.n_acceptors} acceptors) + 1 restart-counter bump/proposer: "
        f"{fmt(rate)} cell-ticks/s, "
        f"owned={float((owners >= 0).mean()):.2f}",
    )]


RENEW_CELLS, RENEW_TICKS = 1024, 384
RENEW_LEASE, RENEW_CADENCE, RENEW_DELAY = 96, 64, 4


def _renew_storm_trace():
    """The §6 steady state: every cell acquired at t=0 and then extended in
    synchronized waves every RENEW_CADENCE ticks forever. The cadence is
    window-aligned (64 = 4 x the engine's 16-tick windows) so the ticks
    between extend rounds are genuinely quiescent — the workload the
    kernel's stable-window fast path exists for. The cadence must sit
    inside [4·delay+1, lease): shorter overwrites the open extend round
    (netplane phase 3), longer lapses the lease mid-renewal."""
    from repro.lease_array.trace import Trace

    T, N = RENEW_TICKS, RENEW_CELLS
    att = np.full((T, N), -1, np.int32)
    ext = np.full((T, N), -1, np.int32)
    cells = np.arange(N, dtype=np.int32)
    att[0] = cells % 8
    for te in range(RENEW_CADENCE, T, RENEW_CADENCE):
        ext[te] = cells % 8
    return Trace(
        N, 5, 8, RENEW_LEASE,
        att, np.full((T, N), -1, np.int32), np.ones((T, 5), np.int32),
        delay=np.full((T, 5), RENEW_DELAY, np.int32),
        round_ticks=4 * RENEW_DELAY + 1, extends=ext,
    )


def run_renew():
    """The renewal-collapse fix, measured: owner extensions (§6, the
    extends plane) sustain ownership through many lease generations at
    delay ≤ 4 — the geometry that collapsed to owned_frac 0.05 before the
    extend plane existed — A/B'd with the quiescence fast path compiled
    out, plus a deposed-owner failover handoff driven through the shard
    directory at array scale."""
    tr = _renew_storm_trace()
    sc = tr.scenario()
    owners_ref, counts = replay_array(tr, netplane=True)  # jnp oracle
    assert counts.max() <= 1, "§4 violated in the renewal storm"
    warm = 2 * RENEW_DELAY + 1  # first acquisition lands after one RTT
    owned = float((np.asarray(owners_ref)[warm:] >= 0).mean())
    assert owned >= 0.95, f"renewal collapse: owned_frac {owned}"

    rows, rates = [], {}
    for skip in (True, False):
        def replay(skip=skip):
            eng = LeaseArrayEngine(
                RENEW_CELLS, n_acceptors=5, n_proposers=8,
                lease_ticks=RENEW_LEASE, round_ticks=4 * RENEW_DELAY + 1,
                backend="pallas", skip_stable=skip,
            )
            return eng.run_trace(sc, netplane=True)

        replay()  # warm the (skip_stable-keyed) jit cache
        dt, (owners, _) = timed(replay)
        assert np.array_equal(np.asarray(owners), np.asarray(owners_ref)), \
            "skip path must be bitwise invisible"
        rates[skip] = RENEW_CELLS * RENEW_TICKS / dt
        name = "lease_renewal_storm" + ("" if skip else "_noskip")
        what = (
            "quiescence skip on" if skip
            else f"skip compiled out (the skip row is "
            f"{rates[True] / rates[False]:.2f}x faster)"
        )
        rows.append((
            name,
            dt / (RENEW_CELLS * RENEW_TICKS) * 1e6,
            f"{RENEW_CELLS} cells x {RENEW_TICKS} ticks, extend waves every "
            f"{RENEW_CADENCE} ticks at delay<={RENEW_DELAY}, window kernel, "
            f"{what}: {fmt(rates[skip])} cell-ticks/s, "
            f"owned={owned:.2f} past the first acquisition",
        ))

    # deposed-owner handoff through the closed-loop shard directory: stall
    # one of 8 workers, retarget the rest, count ticks until its shards are
    # re-owned by peers (bench_failover.py's scenario at array scale)
    from repro.lease_array.directory import LeaseArrayDirectory

    state = {}

    def handoff():
        d = LeaseArrayDirectory(RENEW_CELLS, n_acceptors=5, lease_ticks=24,
                                max_workers=8, max_delay_ticks=2)
        for i in range(8):
            d.add_worker(i, RENEW_CELLS // 8)
        d.tick(40)
        assert d.coverage() == 1.0, "storm warmup failed to acquire"
        d.stall(0)
        for i in range(1, 8):
            d.set_target(i, RENEW_CELLS // 7 + 1)
        ticks = 0
        while (d.owned_count(0) > 0 or d.coverage() < 0.95) and ticks < 400:
            d.tick(1)
            ticks += 1
        assert d.owned_count(0) == 0 and d.coverage() >= 0.95
        state["ticks"] = ticks
        return ticks

    dt, _ = timed(handoff, reps=2)
    total = RENEW_CELLS * (40 + state["ticks"])
    rows.append((
        "lease_failover_handoff",
        dt / total * 1e6,
        f"{RENEW_CELLS} shards, 8 workers, delay<=2: a stalled owner's "
        f"{RENEW_CELLS // 8} shards lapse and are re-acquired by peers in "
        f"{state['ticks']} ticks ({fmt(total / dt)} cell-ticks/s through "
        f"the per-tick directory control loop)",
    ))
    return rows


def run_sweep():
    """The scenario-sweep driver: a stacked batch of fault scenarios in ONE
    dispatch (vmap inside, shard_map across devices), §4 verified."""
    from repro.lease_array import Scenario

    traces = [
        random_trace(
            s, n_ticks=SWEEP_TICKS, n_cells=SWEEP_CELLS,
            n_acceptors=3, n_proposers=4, lease_ticks=3,
            p_attempt=0.5, p_release=0.05, p_down_flip=0.05,
        )
        for s in range(SWEEP_SCENARIOS)
    ]
    stacked = Scenario.stack([t.scenario() for t in traces])
    eng = LeaseArrayEngine(SWEEP_CELLS, n_acceptors=3, n_proposers=4,
                           lease_ticks=3)
    eng.sweep(stacked)  # warm
    dt, res = timed(lambda: eng.sweep(stacked))
    assert int(res.max_owner_count.max()) <= 1
    total = SWEEP_SCENARIOS * SWEEP_CELLS * SWEEP_TICKS
    return [(
        "lease_sweep_batch",
        dt / total * 1e6,
        f"{SWEEP_SCENARIOS} scenarios x {SWEEP_CELLS} cells x "
        f"{SWEEP_TICKS} ticks in one dispatch: "
        f"{fmt(total / dt)} cell-ticks/s, "
        f"owned={float(res.owned_frac.mean()):.2f}",
    )]


def run_falsify():
    """Falsification-search throughput: one steady-state generation of the
    coverage-guided search — a margins-mode sweep over the whole
    population plus the host-side selection + mutation pass."""
    import numpy as np

    from repro.lease_array import Scenario
    from repro.lease_array.falsify import (
        FalsifyConfig, margin_score, mutate, random_population,
    )

    cfg = FalsifyConfig(pop_size=4096)
    eng = cfg.engine()
    rng = np.random.default_rng(0)
    space = cfg.mutation_space()
    planes = random_population(rng, cfg)

    def generation(planes):
        res = eng.sweep(
            Scenario(planes), collect="margins", verify=False,
        )
        scores = margin_score(res.margins)
        order = np.argsort(scores, kind="stable")
        elite = order[: cfg.pop_size // 4]
        parents = rng.choice(elite, size=cfg.pop_size - elite.size)
        children = {k: np.asarray(v)[parents] for k, v in planes.items()}
        children, _ = mutate(children, rng, space)
        return {
            k: np.concatenate([np.asarray(v)[elite], children[k]])
            for k, v in planes.items()
        }, res

    planes, _ = generation(planes)  # warm (compile) + first evolution
    dt, (planes, res) = timed(lambda: generation(planes))
    assert int(res.max_owner_count.max()) <= 1
    return [(
        "lease_falsify_throughput",
        dt / (cfg.pop_size * cfg.n_cells * cfg.n_ticks) * 1e6,
        f"{cfg.pop_size} scenarios/generation "
        f"({cfg.n_cells} cells x {cfg.n_ticks} ticks, margins+mutation): "
        f"{fmt(cfg.pop_size / dt)} scenarios/s",
    )]


JSON_PATH = Path(__file__).resolve().parent.parent / "BENCH_lease_array.json"


def _git_rev() -> str:
    cwd = Path(__file__).resolve().parent
    try:
        rev = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            capture_output=True, text=True, timeout=10, cwd=cwd,
        ).stdout.strip() or "unknown"
        dirty = subprocess.run(
            ["git", "status", "--porcelain"],
            capture_output=True, text=True, timeout=10, cwd=cwd,
        ).stdout.strip()
        return f"{rev}+dirty" if dirty else rev
    except Exception:
        return "unknown"


def emit_json(path=JSON_PATH) -> dict:
    """Run every mode and write the machine-readable trajectory record:
    ``{"rows": [{"name", "us_per_cell_tick", "detail"}, ...], ...}`` —
    lower ``us_per_cell_tick`` is better; names are stable across PRs. The
    header stamps git rev, JAX backend, and device kind/count so the bench
    trajectory stays interpretable across machines and PRs."""
    import jax

    rows = (
        run() + run_delayed() + run_drift() + run_restart() + run_renew()
        + run_sweep() + run_falsify()
    )
    doc = {
        "benchmark": "lease_array",
        "git_rev": _git_rev(),
        "platform": platform.platform(),
        "python": platform.python_version(),
        "jax_backend": jax.default_backend(),
        "device_kind": jax.devices()[0].device_kind,
        "n_devices": len(jax.devices()),
        "rows": [
            {"name": n, "us_per_cell_tick": round(us, 4), "detail": d}
            for n, us, d in rows
        ],
    }
    Path(path).write_text(json.dumps(doc, indent=2) + "\n")
    return doc


if __name__ == "__main__":
    out = sys.argv[1] if len(sys.argv) > 1 else JSON_PATH
    doc = emit_json(out)
    for r in doc["rows"]:
        print(f'{r["name"]},{r["us_per_cell_tick"]:.2f},"{r["detail"]}"')
    print(f"wrote {out}")
