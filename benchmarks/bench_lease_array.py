"""§8 at scale: cells/sec of the vectorized lease plane vs the event-driven
simulator on identical randomized workloads.

The event engine pays Python per message (the per-message overhead that
dominates quorum-protocol throughput in practice); the array plane pays one
batched step for *all* cells per tick. Reported as cell-ticks/sec, plus the
single-batched-step width (the acceptance floor is >= 4096 concurrent cells).

``python -m benchmarks.bench_lease_array`` runs every mode and writes the
machine-readable ``BENCH_lease_array.json`` (schema at the bottom) so the
perf trajectory is tracked across PRs; ``make bench-json`` wraps it.
"""
from __future__ import annotations

import json
import platform
from pathlib import Path

import numpy as np

from repro.lease_array import LeaseArrayEngine, random_trace, replay_array, replay_event_sim

from .common import WallTimer, fmt

EVENT_CELLS, EVENT_TICKS = 96, 30
ARRAY_CELLS, ARRAY_TICKS = 4096, 128
KERNEL_CELLS = 4096
DELAY_CELLS, DELAY_TICKS = 1024, 96
DELAY_DEPTHS = (0, 1, 2, 4)


def _trace(n_cells, n_ticks, seed=0):
    return random_trace(
        seed, n_ticks=n_ticks, n_cells=n_cells,
        n_acceptors=5, n_proposers=8, lease_ticks=4,
        p_attempt=0.4, p_release=0.05, p_down_flip=0.0,
    )


def run():
    rows = []

    ev = _trace(EVENT_CELLS, EVENT_TICKS)
    with WallTimer() as wt:
        replay_event_sim(ev, strict_monitor=True)
    ev_rate = EVENT_CELLS * EVENT_TICKS / wt.dt
    rows.append((
        "lease_event_sim",
        wt.dt / (EVENT_CELLS * EVENT_TICKS) * 1e6,
        f"{EVENT_CELLS} cells x {EVENT_TICKS} ticks: {fmt(ev_rate)} cell-ticks/s",
    ))

    ar = _trace(ARRAY_CELLS, ARRAY_TICKS)
    replay_array(_trace(ARRAY_CELLS, 2))  # warm the scan jit cache
    with WallTimer() as wt:
        owners, counts = replay_array(ar)
    assert counts.max() <= 1, "at-most-one-owner violated in the array plane"
    ar_rate = ARRAY_CELLS * ARRAY_TICKS / wt.dt
    rows.append((
        "lease_array_scan",
        wt.dt / (ARRAY_CELLS * ARRAY_TICKS) * 1e6,
        f"{ARRAY_CELLS} cells x {ARRAY_TICKS} ticks in one scan: "
        f"{fmt(ar_rate)} cell-ticks/s ({fmt(ar_rate / ev_rate)}x event sim), "
        f"owned={float((owners >= 0).mean()):.2f}",
    ))

    # one fused batched step at the acceptance width (kernel path)
    eng = LeaseArrayEngine(
        KERNEL_CELLS, n_acceptors=5, n_proposers=8, lease_ticks=4,
        backend="pallas",
    )
    attempt = np.arange(KERNEL_CELLS, dtype=np.int32) % eng.n_proposers
    eng.step(attempt)  # warm the kernel
    with WallTimer() as wt:
        owner = eng.step(attempt)
    rows.append((
        "lease_array_kernel_step",
        wt.dt / KERNEL_CELLS * 1e6,
        f"one fused pallas step over {KERNEL_CELLS} cells "
        f"(owned {int((owner >= 0).sum())}/{KERNEL_CELLS})",
    ))
    return rows


def _delayed_trace(max_delay: int, n_ticks: int, seed: int = 5, asymmetric=False):
    return random_trace(
        seed, n_ticks=n_ticks, n_cells=DELAY_CELLS,
        n_acceptors=5, n_proposers=8, lease_ticks=8,
        p_attempt=0.8, p_release=0.05, p_down_flip=0.0,
        max_delay_ticks=max_delay, p_drop=0.05 if max_delay else 0.0,
        asymmetric=asymmetric, round_ticks=max(3, max_delay + 1),
    )


def run_delayed(depths=DELAY_DEPTHS):
    """Delay-depth sweep of the in-flight message plane: cell-ticks/sec of
    the netplane scan at increasing per-leg delay bounds (depth 0 = the
    zero-delay special case run through the same delayed step), plus the
    resulting ownership density — lease dynamics vs latency regime, the
    Keyspace/cloud-report axis (arXiv 1209.3913, 1404.6719). The last row
    re-runs the deepest sweep point with asymmetric [T, P, A] link
    matrices (per-(proposer, acceptor) Scenario planes)."""
    rows = []
    sweep = [(d, False) for d in depths] + [(max(depths), True)]
    for depth, asym in sweep:
        tr = _delayed_trace(depth, DELAY_TICKS, asymmetric=asym)
        # warm with the SAME trace length: the scan jit is shape-specialized,
        # so a short warm-up trace would leave the compile inside the timer
        replay_array(
            _delayed_trace(depth, DELAY_TICKS, seed=6, asymmetric=asym),
            netplane=True,
        )
        with WallTimer() as wt:
            owners, counts = replay_array(tr, netplane=True)
        assert counts.max() <= 1, "at-most-one-owner violated in the netplane"
        rate = DELAY_CELLS * DELAY_TICKS / wt.dt
        name = f"lease_netplane_delay{depth}" + ("_asym" if asym else "")
        rows.append((
            name,
            wt.dt / (DELAY_CELLS * DELAY_TICKS) * 1e6,
            f"{DELAY_CELLS} cells x {DELAY_TICKS} ticks, delay<={depth} "
            f"drop={0.05 if depth else 0.0}"
            f"{' [P, A] asymmetric links' if asym else ''}: "
            f"{fmt(rate)} cell-ticks/s, "
            f"owned={float((owners >= 0).mean()):.2f}",
        ))
    return rows


JSON_PATH = Path(__file__).resolve().parent.parent / "BENCH_lease_array.json"


def emit_json(path=JSON_PATH) -> dict:
    """Run every mode and write the machine-readable trajectory record:
    ``{"rows": [{"name", "us_per_cell_tick", "detail"}, ...], ...}`` —
    lower ``us_per_cell_tick`` is better; names are stable across PRs."""
    rows = run() + run_delayed()
    doc = {
        "benchmark": "lease_array",
        "platform": platform.platform(),
        "python": platform.python_version(),
        "rows": [
            {"name": n, "us_per_cell_tick": round(us, 4), "detail": d}
            for n, us, d in rows
        ],
    }
    Path(path).write_text(json.dumps(doc, indent=2) + "\n")
    return doc


if __name__ == "__main__":
    doc = emit_json()
    for r in doc["rows"]:
        print(f'{r["name"]},{r["us_per_cell_tick"]:.2f},"{r["detail"]}"')
    print(f"wrote {JSON_PATH}")
