"""§8: ~100 bytes per PaxosLease instance -> ~10M resource leases per GB,
plus zero acceptor disk syncs (the 'diskless' headline).

Reports both the wire-format/struct estimate (the paper's accounting) and
the actual Python-object overhead of this implementation."""
from __future__ import annotations

import sys

from repro.configs import CellConfig
from repro.core import build_cell
from repro.core.ballot import Ballot
from repro.core.messages import Lease, Proposal
from repro.sim.network import NetConfig

from .common import WallTimer

N_RES = 2000


def _struct_bytes() -> int:
    """Packed-struct accounting as the paper would count it: per resource an
    acceptor stores highest_promised (3x8B) + accepted proposal (ballot 24B +
    proposer id 8B + timespan 8B) + timer handle (~16B) + resource key (~16B)."""
    return 3 * 8 + (24 + 8 + 8) + 16 + 16


def run():
    cfg = CellConfig(n_acceptors=3, max_lease_time=60.0, lease_timespan=20.0)
    cell = build_cell(cfg, n_proposers=3, seed=0,
                      net=NetConfig(delay_min=0.001, delay_max=0.003))
    with WallTimer() as wt:
        for r in range(N_RES):
            owner = r % 3
            cell.proposers[owner].proposer.acquire(f"res:{r}", renew=False)
        cell.env.run_until(5.0)
    owned = sum(
        1 for r in range(N_RES) if cell.monitor.owner_of(f"res:{r}") is not None
    )
    acc = cell.nodes[0].acceptor
    py_bytes = acc.memory_bytes() / max(len(acc._res), 1)
    # deep-ish: include dict slot overhead
    py_bytes += sys.getsizeof(acc._res) / max(len(acc._res), 1)
    struct = _struct_bytes()
    per_gb = 1e9 / struct
    rows = [
        (
            "memory_per_instance",
            wt.dt / N_RES * 1e6,
            f"struct={struct}B (paper ~100B), python_obj={py_bytes:.0f}B, "
            f"leases/GB={per_gb/1e6:.1f}M (paper ~10M), owned={owned}/{N_RES}",
        ),
        (
            "acceptor_disk_syncs",
            0.0,
            f"acceptor stable-storage writes during {N_RES} leases: 0 (diskless); "
            f"proposer restart-counter writes: {cell.env.stable.sync_count} (one per proposer)",
        ),
    ]
    return rows
