"""§Perf report generator: renders the hillclimb iteration tables in
EXPERIMENTS.md directly from the tagged dry-run artifacts, so every number
in the doc is reproducible from `artifacts/dryrun/`.

  PYTHONPATH=src python -m benchmarks.perf_report
"""
from __future__ import annotations

import json
import pathlib

from repro.analysis.roofline import MESHES, roofline_terms
from repro.configs import get_config, get_shape

ART = pathlib.Path(__file__).resolve().parent.parent / "artifacts" / "dryrun"

CELLS = {
    "kimi-k2-1t-a32b x train_4k": [
        ("k0 baseline", "kimi-k2-1t-a32b_train_4k_pod16x16", {}),
        ("k1 +bf16 params", "k1_bf16_kimi-k2-1t-a32b_train_4k_pod16x16", {"param_dtype": "bfloat16"}),
        ("k2 +ZeRO-1", "k2_zero1_kimi-k2-1t-a32b_train_4k_pod16x16", {"param_dtype": "bfloat16", "zero1": True}),
        ("k3 +EP hints (refuted)", "k3_ephints_kimi-k2-1t-a32b_train_4k_pod16x16", {"param_dtype": "bfloat16", "zero1": True}),
        ("k4 +microbatch 4", "k4_mb4_kimi-k2-1t-a32b_train_4k_pod16x16", {"param_dtype": "bfloat16", "zero1": True, "microbatches": 4}),
        ("k5 final (bf16 moments)", "k5_final_kimi-k2-1t-a32b_train_4k_pod16x16", {"param_dtype": "bfloat16", "zero1": True}),
        ("k6 final multi-pod", "k6_final_multipod_kimi-k2-1t-a32b_train_4k_pod2x16x16", {"param_dtype": "bfloat16", "zero1": True}),
    ],
    "granite-3-8b x train_4k": [
        ("g0 baseline (dots)", "granite-3-8b_train_4k_pod16x16", {}),
        ("g1 remat full", "g1_rematfull_granite-3-8b_train_4k_pod16x16", {"remat": "full"}),
        ("g2 +microbatch 8", "g2_mb8_granite-3-8b_train_4k_pod16x16", {"remat": "full"}),
        ("g3 +bf16 +ZeRO-1", "g3_bf16_zero1_granite-3-8b_train_4k_pod16x16", {"remat": "full", "param_dtype": "bfloat16", "zero1": True}),
        ("g4 microbatch 16", "g4_mb16_granite-3-8b_train_4k_pod16x16", {"remat": "full", "param_dtype": "bfloat16", "zero1": True}),
    ],
    "mixtral-8x22b x prefill_32k": [
        ("m0 baseline", "mixtral-8x22b_prefill_32k_pod16x16", {}),
        ("m1 last-token unembed", "m1_logitslast_mixtral-8x22b_prefill_32k_pod16x16", {"logits_last": True}),
        ("m2 +bf16 (refuted)", "m2_bf16_mixtral-8x22b_prefill_32k_pod16x16", {"logits_last": True, "param_dtype": "bfloat16"}),
        ("m3 +SWA block-skip (kernel)", "m1_logitslast_mixtral-8x22b_prefill_32k_pod16x16", {"logits_last": True, "swa_block_skip": True}),
        ("m4 multi-pod experts-over-pod", "m4_expertspod_mixtral-8x22b_prefill_32k_pod2x16x16", {"logits_last": True, "swa_block_skip": True, "param_dtype": "bfloat16"}),
    ],
    "whisper-large-v3 x prefill_32k (mini)": [
        ("w0 baseline", "whisper-large-v3_prefill_32k_pod16x16", {}),
        ("w1 last-token unembed", "w1_logitslast_whisper-large-v3_prefill_32k_pod16x16", {"logits_last": True}),
    ],
}


def row(label: str, fname: str, variant: dict) -> str:
    f = ART / f"{fname}.json"
    if not f.exists():
        return f"| {label} | (artifact missing) |"
    a = json.loads(f.read_text())
    if a.get("status") != "ok":
        return f"| {label} | {a['status']} |"
    arch, shape, mesh = a["arch"], a["shape"], a["mesh"]
    t = roofline_terms(
        get_config(arch), get_shape(shape), MESHES[mesh], variant,
        coll_bytes_parsed=a["collectives"]["total_bytes"],
    )
    ma = a["memory_analysis"]
    return (
        f"| {label} | {t['compute_s']:.3f} | {t['memory_s']:.3f} | {t['collective_s']:.3f} "
        f"| {ma['temp_size_in_bytes']/1e9:.1f} | {ma['argument_size_in_bytes']/1e9:.1f} "
        f"| {a['collectives']['total_bytes']/1e9:.1f} | {t['roofline_frac']:.2f} |"
    )


def main() -> None:
    for cell, iters in CELLS.items():
        print(f"\n### {cell}\n")
        print("| iteration | compute s | memory s | collective s | temp GB/dev | args GB/dev | HLO coll GB/dev | roofline frac |")
        print("|---|---|---|---|---|---|---|---|")
        for label, fname, variant in iters:
            print(row(label, fname, variant))


if __name__ == "__main__":
    main()
