"""§5: dynamic deadlock (dueling proposers) is broken by randomized backoff.
Compares fixed (degenerate) backoff against the paper's randomized backoff:
time until somebody first holds the lease, and ballot inflation."""
from __future__ import annotations

import numpy as np

from repro.configs import CellConfig
from repro.core import build_cell
from repro.sim.network import NetConfig

from .common import WallTimer

# near-deterministic network so duels don't resolve by jitter luck
NET = NetConfig(delay_min=0.02, delay_max=0.021)
SEEDS = 40


def _time_to_own(cfg, seed):
    cell = build_cell(cfg, n_proposers=2, seed=seed, net=NET)
    for p in cell.proposers:
        p.proposer.acquire()
    cell.env.run_until(60.0)
    rounds = sum(p.proposer.stats["rounds"] for p in cell.proposers)
    t = cell.monitor.acquire_times[0] if cell.monitor.acquire_times else float("inf")
    return t, rounds


def run():
    rows = []
    for label, lo, hi in (("fixed", 0.4, 0.4000001), ("randomized", 0.1, 0.8)):
        cfg = CellConfig(n_acceptors=3, max_lease_time=60.0, lease_timespan=10.0,
                         backoff_min=lo, backoff_max=hi, round_timeout=0.3)
        times, rounds = [], []
        with WallTimer() as wt:
            for seed in range(SEEDS):
                t, r = _time_to_own(cfg, seed)
                times.append(t)
                rounds.append(r)
        arr = np.array(times)
        stuck = float(np.mean(~np.isfinite(arr)))
        med = float(np.median(arr[np.isfinite(arr)])) if np.isfinite(arr).any() else float("nan")
        rows.append((
            f"duel_backoff_{label}",
            wt.dt / SEEDS * 1e6,
            f"P(livelocked at 60s)={stuck:.2f}, median t_first_own={med:.2f}s, "
            f"ballot churn={np.mean(rounds):.1f} rounds/60s "
            f"(round-timeout + backoff realize the paper's workaround)",
        ))
    return rows
