"""Fault-tolerant training cluster, end to end — the paper's technique doing
its production job.

Simulated control plane (PaxosLease cells) + real JAX training (data plane):
  1. 3 control nodes elect a coordinator and a checkpoint writer,
  2. 4 elastic workers lease data shards (§8 fine-grained leases),
  3. the checkpoint-writer trains + checkpoints under its lease,
  4. FAULTS: a worker straggles (shards reassigned by expiry), the writer
     crashes (lease fails over), training resumes from the checkpoint,
  5. a new worker joins the pool mid-run (elastic scale-up).

Run:  PYTHONPATH=src python examples/fault_tolerant_cluster.py
"""
import dataclasses
import tempfile

from repro.cluster.coordinator import CKPT_RESOURCE, MASTER_RESOURCE, build_coordinated_cluster
from repro.cluster.shards import ShardLeaseManager
from repro.configs import CellConfig, get_config, reduced
from repro.sim.network import NetConfig
from repro.train import Trainer, TrainerConfig


def main() -> None:
    cfg = CellConfig(n_acceptors=3, max_lease_time=30.0, lease_timespan=5.0,
                     backoff_min=0.1, backoff_max=0.5)
    net = NetConfig(delay_min=0.005, delay_max=0.05, loss=0.05)
    cell, coord = build_coordinated_cluster(cfg, n_workers=4, seed=7, net=net)
    env, mon = cell.env, cell.monitor
    log = lambda m: print(f"[t={env.now:6.2f}s] {m}")

    # --- 1. coordinator + checkpoint-writer election -------------------------
    for n in cell.proposers[:3]:
        coord.campaign(n)
        n.proposer.acquire(CKPT_RESOURCE, timespan=5.0)
    env.run_until(3.0)
    master = coord.master()
    writer = mon.owner_of(CKPT_RESOURCE)
    log(f"coordinator = control node {master}, checkpoint writer = node {writer}")

    # --- 2. workers lease data shards ----------------------------------------
    mgr = ShardLeaseManager(cell, n_shards=8, shard_timespan=4.0, scan_period=0.4)
    workers = [mgr.add_worker(cell.proposers[3 + i], target=2) for i in range(4)]
    env.run_until(15.0)
    log(f"shard coverage {mgr.coverage()*100:.0f}%  map {mgr.owner_map()}")

    # --- 3. train under the writer lease --------------------------------------
    model = dataclasses.replace(reduced(get_config("qwen1.5-0.5b")), vocab_size=512)
    writer_node = cell.nodes[writer]
    with tempfile.TemporaryDirectory() as ckpt_dir:
        tc = TrainerConfig(steps=30, batch_size=4, seq_len=64, ckpt_dir=ckpt_dir,
                           ckpt_every=10, log_every=10, n_shards=8)
        tr = Trainer(model, tc, verbose=False,
                     lease_guard=lambda: writer_node.proposer.is_owner(CKPT_RESOURCE),
                     owned_shards=lambda: workers[0].owned or {0})
        tr.run()
        log(f"trained 30 steps (loss {tr.history[0]['loss']:.3f} -> "
            f"{tr.history[-1]['loss']:.3f}), checkpoints {tr.ckpt.saved_steps}")

        # --- 4a. straggler: worker 1 stalls; its shards migrate ---------------
        victim = workers[1]
        stalled_shards = set(victim.owned)
        mgr.stall(victim.node.node_id)
        for w in workers:
            if w is not victim:
                w.target = 3
        log(f"worker {victim.node.node_id} STRAGGLING (held shards {stalled_shards})")
        deadline = env.now + 60
        while env.now < deadline and (mgr.coverage() < 1.0 or victim.owned):
            env.run_until(env.now + 1.0)
        log(f"shards reassigned by lease expiry: coverage {mgr.coverage()*100:.0f}% "
            f"map {mgr.owner_map()}")

        # --- 4b. writer crash: lease fails over, training resumes -------------
        writer_node.crash()
        log(f"checkpoint writer node {writer} CRASHED")
        other = cell.nodes[(writer + 1) % 3]
        while not other.proposer.is_owner(CKPT_RESOURCE):
            env.run_until(env.now + 0.5)
        log(f"writer lease failed over to node {other.node_id} "
            f"(gap ~{cfg.lease_timespan}s, no disks, no synchronized clocks)")
        tc2 = dataclasses.replace(tc, steps=45)
        tr2 = Trainer(model, tc2, verbose=False,
                      lease_guard=lambda: other.proposer.is_owner(CKPT_RESOURCE),
                      owned_shards=lambda: workers[0].owned or {0})
        log(f"new writer resumed training from step {tr2.step}")
        tr2.run()
        log(f"trained to step {tr2.step}, checkpoints {sorted(set(tr2.ckpt.saved_steps))}")

    # --- 5. elastic scale-up ---------------------------------------------------
    from repro.core.cell import LeaseNode

    new_id = len(cell.nodes)
    newcomer = LeaseNode(env, new_id, cfg, monitor=mon, is_acceptor=False,
                         is_proposer=True,
                         acceptor_addrs=[cell.nodes[i].addr for i in range(3)])
    cell.nodes.append(newcomer)
    w_new = mgr.add_worker(newcomer, target=2)
    for w in workers:
        if not w.stalled:
            w.target = 2
    env.run_until(env.now + 30)
    log(f"worker {new_id} joined elastically; owns {len(w_new.owned)} shards; "
        f"final map {mgr.owner_map()}")

    mon.assert_clean()
    print("\nlease invariant held through every fault (0 violations)")


if __name__ == "__main__":
    main()
