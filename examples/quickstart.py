"""Quickstart: a PaxosLease cell in 60 seconds.

Builds a 5-node cell (every node is acceptor + proposer, as in Keyspace),
walks through acquire -> extend -> owner crash -> failover -> release, and
prints the timeline the invariant monitor saw.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
from repro.configs import CellConfig
from repro.core import build_cell
from repro.sim.network import NetConfig


def main() -> None:
    cfg = CellConfig(n_acceptors=5, max_lease_time=60.0, lease_timespan=10.0)
    net = NetConfig(delay_min=0.01, delay_max=0.05, loss=0.05, duplicate=0.05)
    cell = build_cell(cfg, n_proposers=5, seed=42, net=net)
    env, mon = cell.env, cell.monitor

    log = lambda msg: print(f"[t={env.now:7.2f}s] {msg}")

    # 1. node 0 acquires the lease (two round-trips)
    cell.nodes[0].proposer.acquire()
    env.run_until(1.0)
    log(f"owner = node {mon.owner_of('R')} (acquired in "
        f"{mon.acquire_times[0]*1000:.0f} ms ~ 2 RTT)")

    # 2. rivals contend but cannot take it; the owner keeps extending (§6)
    for n in cell.nodes[1:3]:
        n.proposer.acquire()
    env.run_until(45.0)
    log(f"after 45s of contention: owner = node {mon.owner_of('R')}, "
        f"extends = {cell.nodes[0].proposer.stats['extended']}, handoffs = {mon.handoffs('R')}")

    # 3. the owner crashes; the lease expires; a rival takes over
    cell.nodes[0].crash()
    log("node 0 (owner) crashed")
    env.run_until(env.now + cfg.lease_timespan + 5.0)
    log(f"failover complete: owner = node {mon.owner_of('R')}")

    # 4. graceful release (§7): the next waiter takes over without waiting T
    owner = mon.owner_of("R")
    t0 = env.now
    cell.nodes[owner].proposer.release()
    log(f"node {owner} released the lease")
    env.run_until(env.now + 5.0)
    log(f"new owner = node {mon.owner_of('R')} after "
        f"{min(t for t in mon.acquire_times if t > t0) - t0:.2f}s (vs T={cfg.lease_timespan}s)")

    # 5. the referee: no two proposers ever overlapped
    mon.assert_clean()
    print("\nOwnership intervals:")
    for iv in mon.history["R"]:
        end = f"{iv.end:7.2f}" if iv.end is not None else "   open"
        print(f"  node {iv.proposer_id}: [{iv.start:7.2f} .. {end}]")
    print("\nlease invariant held throughout (0 violations)")


if __name__ == "__main__":
    main()
