"""End-to-end training driver: train an LM on synthetic data with
lease-guarded checkpointing and resume.

Default is the CPU-friendly ~20M-param config for a visible loss curve in
minutes; ``--arch lm100m`` runs the ~100M-parameter config (same code path),
and any assigned architecture id works at reduced size via ``--reduced``.

Run:  PYTHONPATH=src python examples/train_lm.py --steps 300
      PYTHONPATH=src python examples/train_lm.py --arch lm100m --steps 200
"""
import argparse

from repro.configs import get_config, reduced
from repro.train import Trainer, TrainerConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="lm20m")
    ap.add_argument("--reduced", action="store_true",
                    help="shrink the arch to smoke size (for assigned archs)")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch-size", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=6e-4)
    ap.add_argument("--ckpt-dir", default="artifacts/train_lm_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--ckpt-async", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    n = cfg.n_params()
    print(f"training {cfg.name}: {n/1e6:.1f}M params, "
          f"{args.steps} steps x {args.batch_size}x{args.seq_len} tokens")

    tc = TrainerConfig(
        steps=args.steps,
        batch_size=args.batch_size,
        seq_len=args.seq_len,
        peak_lr=args.lr,
        warmup=min(50, args.steps // 10),
        microbatches=args.microbatches,
        ckpt_dir=args.ckpt_dir,
        ckpt_every=args.ckpt_every,
        ckpt_async=args.ckpt_async,
        log_every=max(args.steps // 30, 1),
    )
    tr = Trainer(cfg, tc)
    hist = tr.run()
    first, last = hist[0]["loss"], hist[-1]["loss"]
    print(f"\nloss: {first:.3f} -> {last:.3f} "
          f"({'improved' if last < first else 'NO IMPROVEMENT'})")
    if tr.ckpt:
        print(f"checkpoints at steps {tr.ckpt.saved_steps} in {args.ckpt_dir}")


if __name__ == "__main__":
    main()
