"""Batched-request serving driver (the end-to-end inference example).

A small LM serves a stream of prompt requests through the continuous-
batching engine: requests queue up, join free slots, decode together, and
free their slot on completion — mixed prompt lengths, per-lane positions.

Run:  PYTHONPATH=src python examples/serve_lm.py --requests 12 --slots 4
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.models import init_model
from repro.train.serve import Request, ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="lm20m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    print(f"serving {cfg.name} ({cfg.n_params()/1e6:.1f}M params), "
          f"{args.slots} slots, {args.requests} requests")
    params = init_model(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, slots=args.slots, max_len=args.max_len,
                      temperature=args.temperature)

    rng = np.random.default_rng(0)
    t0 = time.time()
    for rid in range(args.requests):
        plen = int(rng.integers(2, 12))
        prompt = rng.integers(0, cfg.vocab_size, size=plen).astype(np.int32)
        eng.submit(Request(rid=rid, prompt=prompt, max_new=args.max_new))
    done = eng.run_until_drained()
    dt = time.time() - t0

    tokens = sum(len(r.out) for r in done)
    print(f"\ncompleted {len(done)} requests, {tokens} new tokens in {dt:.1f}s "
          f"({tokens/dt:.1f} tok/s on CPU), {eng.steps} engine steps "
          f"(batching efficiency {tokens/max(eng.steps,1):.2f} tok/step)")
    for r in sorted(done, key=lambda r: r.rid)[:5]:
        print(f"  req {r.rid}: prompt[{len(r.prompt)}] -> {r.out}")


if __name__ == "__main__":
    main()
